"""Figs. 7–8 — VM provisioning-delay sensitivity (45..180 s, paper §5.3)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig

from .common import run_policy, summarize, write_csv

DELAYS_S = (45, 90, 135, 180)


def run(full: bool = False) -> List[Dict]:
    rows = []
    for delay in DELAYS_S:
        cfg = PlatformConfig().with_(vm_provision_delay_ms=delay * 1000)
        for pol in (EBPSM, MSLBL_MW):
            eng, res = run_policy(cfg, pol, 6.0, full)
            row = {"prov_delay_s": delay, "policy": pol.name}
            row.update(summarize(res))
            for name, cnt in eng.pool.vm_count_by_type.items():
                row[f"vms_{name}"] = cnt
            rows.append(row)
    write_csv("fig7_fig8_prov_delay", rows)
    return rows

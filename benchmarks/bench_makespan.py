"""Fig. 3 + Fig. 4 — makespan / budget-met / VM usage across arrival rates
for all five policies.  One simulation per (rate × policy) feeds both
figures (the paper derives them from the same runs).

Also times the same policy grid through the batched JAX engine
(``core.jax_engine.simulate_batch``) against the sequential reference and
reports the wall-clock speedup + result parity — the perf trajectory the
CI artifact (BENCH_makespan.json) tracks.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.engine import SimEngine
from repro.core.jax_engine import simulate_batch
from repro.core.scheduler import ALL_POLICIES, EBPSM, EBPSM_NC, EBPSM_NS
from repro.core.types import PlatformConfig, clone_workload
from repro.workflows.workload import WorkloadSpec, generate_workload

from .common import run_policy, summarize, write_csv

RATES = (0.5, 1.0, 6.0, 12.0)

# Ref-vs-batched comparison grid (EBPSM-family: the auctioned policies).
CMP_POLICIES = (EBPSM, EBPSM_NS, EBPSM_NC)
CMP_SEEDS = (0, 1, 2)


def run(full: bool = False) -> List[Dict]:
    cfg = PlatformConfig()
    rows = []
    for rate in RATES:
        for pol in ALL_POLICIES:
            eng, res = run_policy(cfg, pol, rate, full)
            row = {"rate_wf_per_min": rate, "policy": pol.name}
            row.update(summarize(res))
            for name, cnt in eng.pool.vm_count_by_type.items():
                row[f"vms_{name}"] = cnt
            rows.append(row)
    write_csv("fig3_fig4_makespan_budget_vm", rows)
    return rows


def _cmp_workload(cfg: PlatformConfig, full: bool):
    n = 120 if full else 40
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=60.0, seed=17,
                        sizes=("small", "medium") if full else ("small",),
                        budget_lo=0.5, budget_hi=1.0)
    return generate_workload(cfg, spec)


def artifact(rows: List[Dict], full: bool = False) -> Dict:
    """BENCH_makespan.json — sequential reference vs batched engine on the
    same policy × seed grid: wall-clock speedup, scheduling decisions/sec,
    and exactness check.  At CI scale the queue×pool products stay below
    the auction threshold, so this tracks the grid driver itself: the
    batched engine's rendezvous scheduling (full per-member locality, no
    per-timestamp lockstep) vs one ``SimEngine`` run per member, with
    both sides paying identical structural-sharing clones.  The CI gate
    (benchmarks.check_speedup) holds the speedup above its floor; the
    device win lives in the large-workflow regime and in
    BENCH_sched_throughput.json."""
    cfg = PlatformConfig()
    wl = _cmp_workload(cfg, full)
    n_tasks = sum(w.n_tasks for w in wl)

    # Both sides start from the same pre-built workload and pay one
    # structural-sharing clone per member (engines mutate budgets), so
    # the walls measure engine work only, symmetrically.  Each side is
    # timed three times and keeps its best wall — the ratio then tracks
    # engine behavior, not shared-runner noise or first-call warmup.
    t_ref = float("inf")
    t_bat = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        ref = {}
        for pol in CMP_POLICIES:
            for seed in CMP_SEEDS:
                res = SimEngine(cfg, pol, clone_workload(wl), seed=seed).run()
                ref[(pol.name, seed)] = res
        t_ref = min(t_ref, time.perf_counter() - t0)

        t0 = time.perf_counter()
        grid = simulate_batch(cfg, CMP_POLICIES, wl, seed=list(CMP_SEEDS))
        t_bat = min(t_bat, time.perf_counter() - t0)

    exact = all(
        [w.finish_ms for w in ref[(e.policy, e.seed)].workflows]
        == [w.finish_ms for w in e.result.workflows]
        for e in grid.entries
    )
    mean_mk = {
        e.policy: sum(w.makespan_ms for w in e.result.workflows)
        / len(e.result.workflows) / 1000.0
        for e in grid.entries if e.seed == CMP_SEEDS[0]
    }
    decisions = n_tasks * len(grid.entries)
    return {
        "bench": "makespan",
        "scale": "full" if full else "ci",
        "grid_members": len(grid.entries),
        "tasks_per_member": n_tasks,
        "ref_wall_s": t_ref,
        "batched_wall_s": t_bat,
        "speedup_batched_vs_ref": t_ref / t_bat if t_bat > 0 else 0.0,
        "ref_decisions_per_sec": decisions / t_ref if t_ref > 0 else 0.0,
        "batched_decisions_per_sec": decisions / t_bat if t_bat > 0 else 0.0,
        "bit_exact": exact,
        "mean_makespan_s_by_policy": mean_mk,
        "fig_rows": len(rows),
    }

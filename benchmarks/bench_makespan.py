"""Fig. 3 + Fig. 4 — makespan / budget-met / VM usage across arrival rates
for all five policies.  One simulation per (rate × policy) feeds both
figures (the paper derives them from the same runs).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import ALL_POLICIES
from repro.core.types import PlatformConfig

from .common import run_policy, summarize, write_csv

RATES = (0.5, 1.0, 6.0, 12.0)


def run(full: bool = False) -> List[Dict]:
    cfg = PlatformConfig()
    rows = []
    for rate in RATES:
        for pol in ALL_POLICIES:
            eng, res = run_policy(cfg, pol, rate, full)
            row = {"rate_wf_per_min": rate, "policy": pol.name}
            row.update(summarize(res))
            for name, cnt in eng.pool.vm_count_by_type.items():
                row[f"vms_{name}"] = cnt
            rows.append(row)
    write_csv("fig3_fig4_makespan_budget_vm", rows)
    return rows

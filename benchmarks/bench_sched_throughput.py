"""Scheduler-core throughput: Alg. 2 pair-scoring decisions/second.

Compares the pure-Python reference (core.scheduler.select, per task) with
the vectorized jnp oracle and the Pallas affinity kernel at WaaS scale.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.affinity.ops import affinity

SIZES = ((64, 128), (256, 512), (1024, 1024))


def _inputs(T: int, V: int, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.uniform(10, 900, T), jnp.float32),
        jnp.asarray(rng.uniform(1, 150, T), jnp.float32),
        jnp.asarray(rng.uniform(5, 500, T), jnp.float32),
        jnp.asarray(rng.uniform(0, 200, (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0., 400., 10000.], (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0, 1, 2, 3], (T, V)), jnp.int32),
        jnp.asarray(rng.choice([2., 4., 8., 16.], V), jnp.float32),
        jnp.full((V,), 20.0, jnp.float32),
        jnp.asarray(rng.choice([1., 2., 4., 8.], V), jnp.float32),
    )


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warm + compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, tuple(r))
    return (time.perf_counter() - t0) / reps


def run(full: bool = False) -> List[Dict]:
    from .common import write_csv
    rows = []
    for T, V in SIZES:
        args = _inputs(T, V)
        t_ref = _time(lambda *a: affinity(*a, gs_read=50., gs_write=30.,
                                          bp_ms=1000., use_pallas=False),
                      *args)
        t_pal = _time(lambda *a: affinity(*a, gs_read=50., gs_write=30.,
                                          bp_ms=1000., use_pallas=True),
                      *args)
        rows.append({"T": T, "V": V,
                     "jnp_us": t_ref * 1e6, "pallas_us": t_pal * 1e6,
                     "jnp_Mpairs_s": T * V / t_ref / 1e6,
                     "pallas_Mpairs_s": T * V / t_pal / 1e6})
    write_csv("sched_throughput", rows)
    return rows

"""Scheduler-core throughput: Alg. 2 pair-scoring decisions/second.

Compares the per-task pure-Python reference loop (what
``core.scheduler.select`` does per ready task) with the vectorized jnp
oracle and the Pallas affinity kernel at WaaS scale.  The acceptance bar
for the batched engine stack is ≥10× over the Python reference at the
(1024 tasks, 1024 VMs) point.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.affinity.ops import affinity

SIZES = ((64, 128), (256, 512), (1024, 1024))
CEIL_TOL = 1.0 - 1e-6  # matches core.costs.ceil_ms


def _inputs(T: int, V: int, seed=0):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.uniform(10, 900, T), jnp.float32),
        jnp.asarray(rng.uniform(1, 150, T), jnp.float32),
        jnp.asarray(rng.uniform(5, 500, T), jnp.float32),
        jnp.asarray(rng.uniform(0, 200, (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0., 400., 10000.], (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0, 1, 2, 3], (T, V)), jnp.int32),
        jnp.asarray(rng.choice([2., 4., 8., 16.], V), jnp.float32),
        jnp.full((V,), 20.0, jnp.float32),
        jnp.asarray(rng.choice([1., 2., 4., 8.], V), jnp.float32),
    )


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warm + compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, tuple(r))
    return (time.perf_counter() - t0) / reps


def _python_reference(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                      vm_mips, vm_bw, vm_price,
                      gs_read=50.0, gs_write=30.0, bp_ms=1000.0):
    """The sequential scheduler's inner loop, per task over every VM —
    plain Python floats, same tie-breaking as the kernel."""
    T, V = len(size_mi), len(vm_mips)
    best_vm = [-1] * T
    for t in range(T):
        bt = budget[t]
        key = None
        for v in range(V):
            tv = tier[t][v]
            if tv == 0:
                continue
            in_ms = math.ceil(
                missing_mb[t][v] * (1.0 / vm_bw[v] + 1.0 / gs_read)
                * 1000.0 * CEIL_TOL)
            out_ms = math.ceil(
                out_mb[t] * (1.0 / vm_bw[v] + 1.0 / gs_write)
                * 1000.0 * CEIL_TOL)
            rt_ms = math.ceil(size_mi[t] / vm_mips[v] * 1000.0 * CEIL_TOL)
            pipe = in_ms + rt_ms + out_ms + cont_ms[t][v]
            cost = math.ceil(pipe / bp_ms) * vm_price[v]
            if cost > bt + 1e-6:
                continue
            cand = (tv, pipe, v)
            if key is None or cand < key:
                key = cand
                best_vm[t] = v
    return best_vm


def run(full: bool = False) -> List[Dict]:
    from .common import write_csv
    rows = []
    for T, V in SIZES:
        args = _inputs(T, V)
        t_ref = _time(lambda *a: affinity(*a, gs_read=50., gs_write=30.,
                                          bp_ms=1000., use_pallas=False),
                      *args)
        t_pal = _time(lambda *a: affinity(*a, gs_read=50., gs_write=30.,
                                          bp_ms=1000., use_pallas=True),
                      *args)
        py_args = [np.asarray(a).tolist() for a in args]
        t0 = time.perf_counter()
        _python_reference(*py_args)
        t_py = time.perf_counter() - t0
        rows.append({"T": T, "V": V,
                     "jnp_us": t_ref * 1e6, "pallas_us": t_pal * 1e6,
                     "python_us": t_py * 1e6,
                     "jnp_Mpairs_s": T * V / t_ref / 1e6,
                     "pallas_Mpairs_s": T * V / t_pal / 1e6,
                     "python_decisions_s": T / t_py,
                     "jnp_decisions_s": T / t_ref,
                     "speedup_jnp_vs_python": t_py / t_ref})
    write_csv("sched_throughput", rows)
    return rows


def artifact(rows: List[Dict]) -> Dict:
    """BENCH_sched_throughput.json — perf trajectory tracking."""
    top = max(rows, key=lambda r: r["T"] * r["V"])
    return {
        "bench": "sched_throughput",
        "top_size": {"T": top["T"], "V": top["V"]},
        "python_decisions_per_sec": top["python_decisions_s"],
        "jnp_decisions_per_sec": top["jnp_decisions_s"],
        "speedup_jnp_vs_python": top["speedup_jnp_vs_python"],
        "rows": rows,
    }

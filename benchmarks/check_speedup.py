"""CI gate over BENCH_makespan.json: the batched engine must stay at or
above the speedup floor vs the sequential reference, with parity intact.

``python -m benchmarks.check_speedup [--floor F] [--path P]``

Exit non-zero when the artifact is missing, the batched-vs-reference
speedup regressed below the floor, or the bit-exactness check failed.
The default floor (0.95) leaves headroom for shared-runner noise; local
runs track ≥ 1.0 (see CHANGES.md for the recorded trajectory).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_PATH = "artifacts/bench/BENCH_makespan.json"
DEFAULT_FLOOR = 0.95


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT_PATH)
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR)
    args = ap.parse_args()

    path = pathlib.Path(args.path)
    if not path.exists():
        sys.exit(f"missing benchmark artifact: {path}")
    art = json.loads(path.read_text())
    speedup = float(art.get("speedup_batched_vs_ref", 0.0))
    bit_exact = bool(art.get("bit_exact", False))
    print(
        f"batched-vs-reference speedup {speedup:.3f} (floor {args.floor}), "
        f"bit_exact={bit_exact}, grid_members={art.get('grid_members')}"
    )
    if not bit_exact:
        sys.exit("FAIL: batched engine lost bit-exact parity with reference")
    if speedup < args.floor:
        sys.exit(
            f"FAIL: speedup {speedup:.3f} regressed below floor {args.floor}"
        )
    print("benchmark gate OK")


if __name__ == "__main__":
    main()

"""CI gates over the benchmark artifacts.

``python -m benchmarks.check_speedup [--floor F] [--path P]
[--grid-path P2] [--grid-floor G]``

* ``BENCH_makespan.json`` — the batched engine must stay at or above the
  speedup floor vs the sequential reference, with parity intact.
* ``BENCH_grid_wall.json`` (when present or ``--require-grid``) — the
  paper-smoke grid's wall in the current dispatch modes must beat the
  legacy (PR 3-style) mode by the grid floor, and the aggregate-round
  auction must demonstrably engage (``batched_calls > 0`` with at least
  one auctioned member below the old per-member 2048-pair threshold).

Exit non-zero when an artifact is missing, a speedup regressed below its
floor, or a structural check failed.  The default floors leave headroom
for shared-runner noise; local runs track higher (see CHANGES.md for the
recorded trajectory).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_PATH = "artifacts/bench/BENCH_makespan.json"
DEFAULT_FLOOR = 0.95
DEFAULT_GRID_PATH = "artifacts/bench/BENCH_grid_wall.json"
# Workers-vs-legacy on a 2-core runner tracks ~2.2-2.5x locally; the CI
# floor tolerates slow shared runners.  Serial-vs-legacy tracks ~1.3x.
DEFAULT_GRID_FLOOR = 1.25


def _check_makespan(path: pathlib.Path, floor: float) -> None:
    if not path.exists():
        sys.exit(f"missing benchmark artifact: {path}")
    art = json.loads(path.read_text())
    speedup = float(art.get("speedup_batched_vs_ref", 0.0))
    bit_exact = bool(art.get("bit_exact", False))
    print(
        f"batched-vs-reference speedup {speedup:.3f} (floor {floor}), "
        f"bit_exact={bit_exact}, grid_members={art.get('grid_members')}"
    )
    if not bit_exact:
        sys.exit("FAIL: batched engine lost bit-exact parity with reference")
    if speedup < floor:
        sys.exit(
            f"FAIL: speedup {speedup:.3f} regressed below floor {floor}"
        )


def _check_grid_wall(path: pathlib.Path, floor: float,
                     required: bool) -> None:
    if not path.exists():
        if required:
            sys.exit(f"missing grid-wall artifact: {path}")
        print(f"grid-wall artifact absent ({path}); gate skipped")
        return
    art = json.loads(path.read_text())
    best = art.get("speedup_workers_vs_legacy") \
        or art.get("speedup_serial_vs_legacy", 0.0)
    best = float(best)
    workers_wall = art.get("wall_workers_s")
    print(
        f"grid-wall speedup vs legacy {best:.3f} (floor {floor}); "
        f"legacy {art.get('wall_legacy_s', 0):.2f}s -> "
        f"serial {art.get('wall_serial_s', 0):.2f}s / "
        f"workers[{art.get('workers')}] "
        + (f"{workers_wall:.2f}s; " if workers_wall else "n/a; ")
        + f"batched_calls={art.get('dispatch', {}).get('batched_calls')}"
    )
    if best < floor:
        sys.exit(
            f"FAIL: grid-wall speedup {best:.3f} below floor {floor}"
        )
    if not art.get("auction_engaged"):
        sys.exit("FAIL: aggregate-round auction never engaged "
                 "(batched_calls == 0)")
    if not art.get("auction_engaged_below_member_threshold"):
        sys.exit("FAIL: no auctioned member below the legacy per-member "
                 "2048-pair threshold — the aggregate dispatcher is not "
                 "doing its job")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT_PATH)
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR)
    ap.add_argument("--grid-path", default=DEFAULT_GRID_PATH)
    ap.add_argument("--grid-floor", type=float, default=DEFAULT_GRID_FLOOR)
    ap.add_argument("--require-grid", action="store_true",
                    help="fail (rather than skip) when the grid-wall "
                         "artifact is missing")
    args = ap.parse_args()

    _check_makespan(pathlib.Path(args.path), args.floor)
    _check_grid_wall(pathlib.Path(args.grid_path), args.grid_floor,
                     args.require_grid)
    print("benchmark gate OK")


if __name__ == "__main__":
    main()

"""CI gates over the benchmark artifacts.

``python -m benchmarks.check_speedup [--floor F] [--path P]
[--grid-path P2] [--grid-floor G]``

* ``BENCH_makespan.json`` — the batched engine must stay at or above the
  speedup floor vs the sequential reference, with parity intact.
* ``BENCH_grid_wall.json`` (when present or ``--require-grid``) — the
  paper-smoke grid's wall in the current dispatch modes must beat the
  legacy (PR 3-style) mode by the grid floor, and the aggregate-round
  auction must demonstrably engage (``batched_calls > 0`` with at least
  one auctioned member below the old per-member 2048-pair threshold).
  When the artifact carries a ``redistribution`` block, the Algorithm-3
  share of wall on the heavy calibration cell must stay under
  ``--redist-ceiling`` (it was ~0.45 before the array path), and the
  array path must hold bit-exact parity with the scalar oracle on the
  A/B sub-cell.

Exit non-zero when an artifact is missing, a speedup regressed below its
floor, or a structural check failed.  The default floors leave headroom
for shared-runner noise; local runs track higher (see CHANGES.md for the
recorded trajectory).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_PATH = "artifacts/bench/BENCH_makespan.json"
DEFAULT_FLOOR = 0.95
DEFAULT_GRID_PATH = "artifacts/bench/BENCH_grid_wall.json"
# Workers-vs-legacy on a 2-core runner tracks ~2.2-2.5x locally; the CI
# floor tolerates slow shared runners.  Serial-vs-legacy tracks ~1.3x.
DEFAULT_GRID_FLOOR = 1.25
# Algorithm-3 redistribution share of wall on the heavy calibration
# cell.  Tracks ~0.18 locally (from ~0.45 scalar-only); shares are
# ratios of same-process walls, so they travel across machines far
# better than absolute times.
DEFAULT_REDIST_CEILING = 0.20
DEFAULT_STREAM_PATH = "artifacts/bench/BENCH_stream_scale.json"
# Object/SoA tracemalloc-peak ratio at the ≥1k-member point.  Tracks
# 1.06 locally; traced peaks are deterministic allocation sums, so the
# floor needs far less headroom than a wall-clock gate would.
DEFAULT_STREAM_FLOOR = 1.03
# SoA wall must stay within this factor of the object baseline's wall
# at the ≥1k point.  Loose by design: the two walls track parity with
# ±10% run-to-run noise even on an idle dev machine (0.89-1.07
# observed), so this guard only catches a catastrophic slowdown.
STREAM_WALL_GUARD = 0.75


def _check_makespan(path: pathlib.Path, floor: float) -> None:
    if not path.exists():
        sys.exit(f"missing benchmark artifact: {path}")
    art = json.loads(path.read_text())
    speedup = float(art.get("speedup_batched_vs_ref", 0.0))
    bit_exact = bool(art.get("bit_exact", False))
    print(
        f"batched-vs-reference speedup {speedup:.3f} (floor {floor}), "
        f"bit_exact={bit_exact}, grid_members={art.get('grid_members')}"
    )
    if not bit_exact:
        sys.exit("FAIL: batched engine lost bit-exact parity with reference")
    if speedup < floor:
        sys.exit(
            f"FAIL: speedup {speedup:.3f} regressed below floor {floor}"
        )


def _check_redistribution(art: dict, ceiling: float) -> None:
    rd = art.get("redistribution")
    if not rd:
        print("redistribution block absent; share ceiling skipped")
        return
    share = float(rd["heavy"]["share"])
    parity = bool(rd.get("parity_bit_exact", False))
    print(
        f"redistribute share {share:.4f} (ceiling {ceiling}) on "
        f"{rd['heavy']['n_workflows']}-wf heavy cell "
        f"(pre-array reference "
        f"{rd.get('pre_array_reference', {}).get('share', 'n/a')}); "
        f"array-vs-scalar parity={parity}, "
        f"round coalesce={rd.get('round_coalesce_ratio', 0):.2f}"
    )
    if not parity:
        sys.exit("FAIL: array-path Algorithm 3 lost bit-exact parity "
                 "with the scalar oracle")
    if share >= ceiling:
        sys.exit(
            f"FAIL: redistribute_share_of_wall {share:.4f} at or above "
            f"ceiling {ceiling}"
        )


def _check_grid_wall(path: pathlib.Path, floor: float,
                     required: bool, redist_ceiling: float) -> None:
    if not path.exists():
        if required:
            sys.exit(f"missing grid-wall artifact: {path}")
        print(f"grid-wall artifact absent ({path}); gate skipped")
        return
    art = json.loads(path.read_text())
    best = art.get("speedup_workers_vs_legacy") \
        or art.get("speedup_serial_vs_legacy", 0.0)
    best = float(best)
    workers_wall = art.get("wall_workers_s")
    print(
        f"grid-wall speedup vs legacy {best:.3f} (floor {floor}); "
        f"legacy {art.get('wall_legacy_s', 0):.2f}s -> "
        f"serial {art.get('wall_serial_s', 0):.2f}s / "
        f"workers[{art.get('workers')}] "
        + (f"{workers_wall:.2f}s; " if workers_wall else "n/a; ")
        + f"batched_calls={art.get('dispatch', {}).get('batched_calls')}"
    )
    if best < floor:
        sys.exit(
            f"FAIL: grid-wall speedup {best:.3f} below floor {floor}"
        )
    if not art.get("auction_engaged"):
        sys.exit("FAIL: aggregate-round auction never engaged "
                 "(batched_calls == 0)")
    if not art.get("auction_engaged_below_member_threshold"):
        sys.exit("FAIL: no auctioned member below the legacy per-member "
                 "2048-pair threshold — the aggregate dispatcher is not "
                 "doing its job")
    _check_redistribution(art, redist_ceiling)


def _check_stream_scale(path: pathlib.Path, floor: float,
                        required: bool) -> None:
    if not path.exists():
        if required:
            sys.exit(f"missing stream-scale artifact: {path}")
        print(f"stream-scale artifact absent ({path}); gate skipped")
        return
    art = json.loads(path.read_text())
    sf = art["state_footprint"]
    ratio = float(sf["object_over_soa_peak_ratio"])
    wall_ratio = float(art.get("wall_object_over_soa_at_max", 0.0))
    print(
        f"stream-scale [{sf['members']} members]: object/SoA traced-peak "
        f"ratio {ratio:.4f} (floor {floor}); "
        f"SoA {sf['traced_peak_soa_mb']:.1f} MB vs object "
        f"{sf['traced_peak_object_mb']:.1f} MB; "
        f"wall object/SoA {wall_ratio:.3f} (guard {STREAM_WALL_GUARD}); "
        f"parity={art.get('parity_bit_exact')}"
    )
    if not art.get("parity_bit_exact"):
        sys.exit("FAIL: SoA stream state lost bit-exact parity with the "
                 "object layout")
    if ratio < floor:
        sys.exit(
            f"FAIL: object/SoA peak-memory ratio {ratio:.4f} below floor "
            f"{floor} — the SoA layout stopped paying for itself"
        )
    if wall_ratio < STREAM_WALL_GUARD:
        sys.exit(
            f"FAIL: SoA wall at the ≥1k-member point regressed beyond "
            f"{1/STREAM_WALL_GUARD:.2f}x the object baseline "
            f"(object/SoA {wall_ratio:.3f})"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT_PATH)
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR)
    ap.add_argument("--grid-path", default=DEFAULT_GRID_PATH)
    ap.add_argument("--grid-floor", type=float, default=DEFAULT_GRID_FLOOR)
    ap.add_argument("--require-grid", action="store_true",
                    help="fail (rather than skip) when the grid-wall "
                         "artifact is missing")
    ap.add_argument("--redist-ceiling", type=float,
                    default=DEFAULT_REDIST_CEILING,
                    help="max Algorithm-3 redistribute share of wall on "
                         "the heavy calibration cell")
    ap.add_argument("--stream-path", default=DEFAULT_STREAM_PATH)
    ap.add_argument("--stream-floor", type=float, default=None,
                    help="min object/SoA traced-peak ratio at the "
                         "stream-scale bench's ≥1k-member point "
                         f"(default {DEFAULT_STREAM_FLOOR} when the "
                         "artifact is present); also checks SoA/object "
                         "parity and the wall guard")
    ap.add_argument("--require-stream", action="store_true",
                    help="fail (rather than skip) when the stream-scale "
                         "artifact is missing")
    args = ap.parse_args()

    _check_makespan(pathlib.Path(args.path), args.floor)
    _check_grid_wall(pathlib.Path(args.grid_path), args.grid_floor,
                     args.require_grid, args.redist_ceiling)
    _check_stream_scale(pathlib.Path(args.stream_path),
                        args.stream_floor if args.stream_floor is not None
                        else DEFAULT_STREAM_FLOOR,
                        args.require_stream or args.stream_floor is not None)
    print("benchmark gate OK")


if __name__ == "__main__":
    main()

"""End-to-end wall clock of the paper-smoke evaluation grid.

Times ``repro.exp.run.run_grid("paper-smoke")`` — the exact grid the
``exp-smoke`` CI job gates on — in three dispatch modes on the same
machine, same process, warmed:

* ``legacy``  — PR 3-style dispatch: scalar ``select`` everywhere and
  the per-member ``queue×pool ≥ AUCTION_MIN_PAIRS_GRID`` auction rule
  (which essentially never fires at smoke scale).  A conservative
  baseline: it still benefits from every non-dispatch optimization in
  the current tree, so the recorded speedups *understate* the drop
  against the real PR 3 checkout (see ``pr3_reference``).
* ``serial``  — current defaults: aggregate-round auction
  (``AUCTION_MIN_PAIRS_ROUND``), vectorized/fused ``select``, serial
  tail drain, one process.
* ``workers`` — same, fanned over a warm ``--workers`` process pool
  (cells are independent; the pool is started before timing and its
  cold-start cost is recorded separately).

The artifact (``BENCH_grid_wall.json``) carries the walls, the
speedups, and the serial run's aggregate-auction dispatch stats
(``batched_calls``, aggregate-pairs histogram, per-member pair extremes)
— the observable proof that the auction now engages on rounds whose
individual members sit far below the old 2048-pair threshold.
``benchmarks.check_speedup --grid-floor`` gates the workers-vs-legacy
speedup in CI.
"""
from __future__ import annotations

import os
import platform as _platform
import sys
import time
from typing import Dict, List, Optional

from repro.core import scheduler as _sched
from repro.exp.run import grid_executor, run_grid
from repro.exp.scenarios import get_scenario
from repro.kernels.affinity import ops as aff_ops

GRID = "paper-smoke"
REPEATS = 3

# PR 3 checkout (17a77de) measured on the dev machine with the same
# best-of protocol (warmed, in-process): recorded for provenance — CI
# machines differ, so the CI gate uses the same-run legacy mode above.
PR3_REFERENCE_WALL_S = 1.21

_LAST: Optional[Dict] = None


def host_info() -> Dict:
    """Machine fingerprint recorded in the artifact: wall-clock numbers
    (and the ``check_speedup --grid-floor`` gate) are only comparable
    between runs whose host blocks match."""
    import jax
    import numpy

    return {
        "cpu_count": os.cpu_count(),
        "platform": _platform.platform(),
        "machine": _platform.machine(),
        "processor": _platform.processor(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "numpy": numpy.__version__,
        "jax_default_backend": jax.default_backend(),
    }


def _best_wall(fn, repeats: int = REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _measure(full: bool = False) -> Dict:
    sc = get_scenario(GRID)
    repeats = REPEATS + 2 if full else REPEATS

    # Warm every code path once (jit traces, cost tables, scenario gen).
    art_serial = run_grid(sc, trace=True)

    forced = _sched._SCALAR_FORCED
    _sched._SCALAR_FORCED = True
    try:
        wall_legacy = _best_wall(
            lambda: run_grid(sc, trace=True, batched="member"), repeats)
    finally:
        _sched._SCALAR_FORCED = forced

    wall_serial = _best_wall(lambda: run_grid(sc, trace=True), repeats)

    n_workers = min(2, os.cpu_count() or 1)
    wall_workers = None
    workers_cold_s = None
    if n_workers > 1:
        t0 = time.perf_counter()
        ex = grid_executor(n_workers)
        try:
            run_grid(sc, trace=True, workers=n_workers, executor=ex)  # warm
            workers_cold_s = time.perf_counter() - t0
            wall_workers = _best_wall(
                lambda: run_grid(sc, trace=True, workers=n_workers,
                                 executor=ex),
                repeats)
        finally:
            ex.shutdown()

    d = art_serial["dispatch"]
    return {
        "bench": "grid_wall",
        "grid": GRID,
        "host": host_info(),
        "repeats": repeats,
        "n_cells": art_serial["n_cells"],
        "wall_legacy_s": wall_legacy,
        "wall_serial_s": wall_serial,
        "wall_workers_s": wall_workers,
        "workers": n_workers if wall_workers is not None else 1,
        "workers_cold_start_s": workers_cold_s,
        "speedup_serial_vs_legacy": wall_legacy / wall_serial,
        "speedup_workers_vs_legacy": (
            wall_legacy / wall_workers if wall_workers else None),
        "pr3_reference": {
            "wall_s": PR3_REFERENCE_WALL_S,
            "commit": "17a77de",
            "note": "same protocol, dev machine; legacy mode above is the "
                    "in-tree (conservative) stand-in for CI gating",
        },
        "speedup_vs_pr3_reference": (
            PR3_REFERENCE_WALL_S / (wall_workers or wall_serial)),
        "use_pallas_resolved": aff_ops.resolve_use_pallas("auto"),
        "dispatch": d,
        "auction_engaged": d["batched_calls"] > 0,
        "auction_engaged_below_member_threshold": bool(
            d["batched_cycles"] > 0
            and d["min_member_pairs_batched"] < 2048),
    }


def run(full: bool = False) -> List[Dict]:
    global _LAST
    _LAST = _measure(full)
    keys = ("wall_legacy_s", "wall_serial_s", "wall_workers_s",
            "speedup_serial_vs_legacy", "speedup_workers_vs_legacy",
            "speedup_vs_pr3_reference")
    row = {k: _LAST[k] for k in keys}
    row["batched_calls"] = _LAST["dispatch"]["batched_calls"]
    row["serial_cycles"] = _LAST["dispatch"]["serial_cycles"]
    row["batched_cycles"] = _LAST["dispatch"]["batched_cycles"]
    return [row]


def artifact(rows: List[Dict]) -> Dict:
    assert _LAST is not None, "run() must precede artifact()"
    return _LAST

"""End-to-end wall clock of the paper-smoke evaluation grid.

Times ``repro.exp.run.run_grid("paper-smoke")`` — the exact grid the
``exp-smoke`` CI job gates on — in three dispatch modes on the same
machine, same process, warmed:

* ``legacy``  — PR 3-style dispatch: scalar ``select`` everywhere and
  the per-member ``queue×pool ≥ AUCTION_MIN_PAIRS_GRID`` auction rule
  (which essentially never fires at smoke scale).  A conservative
  baseline: it still benefits from every non-dispatch optimization in
  the current tree, so the recorded speedups *understate* the drop
  against the real PR 3 checkout (see ``pr3_reference``).
* ``serial``  — current defaults: aggregate-round auction
  (``AUCTION_MIN_PAIRS_ROUND``), vectorized/fused ``select``, serial
  tail drain, one process.
* ``workers`` — same, fanned over a warm ``--workers`` process pool
  (cells are independent; the pool is started before timing and its
  cold-start cost is recorded separately).

The artifact (``BENCH_grid_wall.json``) carries the walls, the
speedups, and the serial run's aggregate-auction dispatch stats
(``batched_calls``, aggregate-pairs histogram, per-member pair extremes)
— the observable proof that the auction now engages on rounds whose
individual members sit far below the old 2048-pair threshold.
``benchmarks.check_speedup --grid-floor`` gates the workers-vs-legacy
speedup in CI.

It also carries a ``redistribution`` block: the Algorithm-3 share of
wall on the heavy calibration cell (cybershake @ 12 wf/min, tight
budgets, 100 workflows — the cell behind the ROADMAP's "~45% of wall"
measurement), plus a CI-sized sub-cell that A/Bs the array path against
the scalar oracle (bit-exact parity required) and the opt-in
round-batched mode (coalescing ratio + metric deltas, since its
semantics legitimately differ).  ``benchmarks.check_speedup
--redist-ceiling`` gates the heavy-cell share and the parity flag.
"""
from __future__ import annotations

import os
import platform as _platform
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.core import budget as _budget
from repro.core import scheduler as _sched
from repro.core.jax_engine import BatchSimEngine, predistribute_workload
from repro.core.types import PlatformConfig, clone_workload
from repro.exp.run import grid_executor, run_grid
from repro.exp.scenarios import POLICY_BY_NAME, get_scenario
from repro.kernels.affinity import ops as aff_ops
from repro.workflows.workload import cell_workload

GRID = "paper-smoke"
REPEATS = 3

# The heavy redistribution calibration: the cell where Algorithm 3 cost
# ~45% of the wall before the array path (see docs/PROFILING.md).  The
# share gate runs at full scale — redistribution share *shrinks* as the
# cell grows (selection cost grows superlinearly in queue x pool), so a
# smaller cell would overstate the share and a larger one would hide a
# regression.
REDIST_CELL = dict(app="cybershake", rate=12.0, budget=(0.0, 0.25),
                   workload_seed=0, sizes=("small", "medium", "large"))
REDIST_HEAVY_N = 100
# A/B legs (scalar oracle, parity, round mode) run on a smaller slice of
# the same cell so the whole block stays CI-sized.
REDIST_AB_N = 40
# Dev-machine share before this tree's array path existed (scalar-only
# Algorithm 3 at REDIST_HEAVY_N) — provenance for the docs narrative;
# the CI gate re-measures the current array share, not this.
REDIST_PRE_ARRAY_SHARE = 0.4432

# PR 3 checkout (17a77de) measured on the dev machine with the same
# best-of protocol (warmed, in-process): recorded for provenance — CI
# machines differ, so the CI gate uses the same-run legacy mode above.
PR3_REFERENCE_WALL_S = 1.21

_LAST: Optional[Dict] = None


def host_info() -> Dict:
    """Machine fingerprint recorded in the artifact: wall-clock numbers
    (and the ``check_speedup --grid-floor`` gate) are only comparable
    between runs whose host blocks match."""
    import jax
    import numpy

    return {
        "cpu_count": os.cpu_count(),
        "platform": _platform.platform(),
        "machine": _platform.machine(),
        "processor": _platform.processor(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "numpy": numpy.__version__,
        "jax_default_backend": jax.default_backend(),
    }


def _best_wall(fn, repeats: int = REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _redist_run(n: int, array: bool,
                mode: str = "finish") -> Tuple[Dict, Tuple]:
    """One EBPSM run of the calibration cell with profiling on.

    Returns the profile-derived numbers and a per-workflow result
    signature ``(wid, finish_ms, cost)`` for bit-exact comparisons.
    Profiling opts in via the per-engine ``profile=True`` kwarg — no
    ``os.environ`` mutation, so concurrent runs stay unaffected.
    """
    was_array = _budget._ARRAY_REDIST
    _budget._ARRAY_REDIST = array
    try:
        cfg = PlatformConfig()
        wl = cell_workload(cfg, REDIST_CELL["app"], REDIST_CELL["rate"],
                           REDIST_CELL["budget"],
                           REDIST_CELL["workload_seed"], n,
                           REDIST_CELL["sizes"])
        pol = POLICY_BY_NAME["EBPSM"]
        proto, spares = predistribute_workload(cfg, wl, pol.budget_mode)
        engine = BatchSimEngine(cfg, [(pol, clone_workload(proto), 0)],
                                predistributed=[spares], redistribute=mode,
                                profile=True)
        res = engine.run()[0]
        prof = engine.dispatch_stats()["profile"]
        wfs = sorted(res.workflows, key=lambda w: w.wid)
        sig = tuple((w.wid, w.finish_ms, w.cost) for w in wfs)
        met = sum(1 for w in wfs if w.cost <= w.budget + 1e-9)
        out = {
            "n_workflows": n,
            "mode": mode,
            "array_path": array,
            "wall_s": prof["engine_wall_s"],
            "redistribute_s": prof["redistribute_s"],
            "share": prof["redistribute_share_of_wall"],
            "redistributions": int(prof["redistributions"]),
            "redistribute_events": int(prof["redistribute_events"]),
            "mean_makespan_ms": (sum(w.finish_ms - w.arrival_ms
                                     for w in wfs) / len(wfs)),
            "mean_cost": sum(w.cost for w in wfs) / len(wfs),
            "budget_met": met / len(wfs),
        }
        return out, sig
    finally:
        _budget._ARRAY_REDIST = was_array


def _measure_redistribution() -> Dict:
    """The Algorithm-3 redistribution block of the artifact."""
    heavy, _ = _redist_run(REDIST_HEAVY_N, array=True)
    ab_array, sig_array = _redist_run(REDIST_AB_N, array=True)
    ab_scalar, sig_scalar = _redist_run(REDIST_AB_N, array=False)
    ab_round, _ = _redist_run(REDIST_AB_N, array=True, mode="round")
    return {
        "cell": {**REDIST_CELL, "budget": list(REDIST_CELL["budget"]),
                 "sizes": list(REDIST_CELL["sizes"]), "policy": "EBPSM"},
        "heavy": heavy,
        "ab_array": ab_array,
        "ab_scalar": ab_scalar,
        "parity_bit_exact": sig_array == sig_scalar,
        "ab_round": ab_round,
        "round_coalesce_ratio": (
            ab_round["redistributions"]
            / max(ab_round["redistribute_events"], 1)),
        "round_mean_makespan_delta_pct": 100.0 * (
            ab_round["mean_makespan_ms"] / ab_array["mean_makespan_ms"] - 1),
        "round_budget_met_delta": (
            ab_round["budget_met"] - ab_array["budget_met"]),
        "pre_array_reference": {
            "share": REDIST_PRE_ARRAY_SHARE,
            "note": "scalar-only Algorithm 3 at the heavy cell, dev "
                    "machine; the CI gate re-measures the live share",
        },
    }


def _measure(full: bool = False) -> Dict:
    sc = get_scenario(GRID)
    repeats = REPEATS + 2 if full else REPEATS

    # Warm every code path once (jit traces, cost tables, scenario gen).
    art_serial = run_grid(sc, trace=True)

    forced = _sched._SCALAR_FORCED
    _sched._SCALAR_FORCED = True
    try:
        wall_legacy = _best_wall(
            lambda: run_grid(sc, trace=True, batched="member"), repeats)
    finally:
        _sched._SCALAR_FORCED = forced

    wall_serial = _best_wall(lambda: run_grid(sc, trace=True), repeats)

    n_workers = min(2, os.cpu_count() or 1)
    wall_workers = None
    workers_cold_s = None
    if n_workers > 1:
        t0 = time.perf_counter()
        ex = grid_executor(n_workers)
        try:
            run_grid(sc, trace=True, workers=n_workers, executor=ex)  # warm
            workers_cold_s = time.perf_counter() - t0
            wall_workers = _best_wall(
                lambda: run_grid(sc, trace=True, workers=n_workers,
                                 executor=ex),
                repeats)
        finally:
            ex.shutdown()

    redistribution = _measure_redistribution()

    d = art_serial["dispatch"]
    return {
        "bench": "grid_wall",
        "grid": GRID,
        "host": host_info(),
        "redistribution": redistribution,
        "repeats": repeats,
        "n_cells": art_serial["n_cells"],
        "wall_legacy_s": wall_legacy,
        "wall_serial_s": wall_serial,
        "wall_workers_s": wall_workers,
        "workers": n_workers if wall_workers is not None else 1,
        "workers_cold_start_s": workers_cold_s,
        "speedup_serial_vs_legacy": wall_legacy / wall_serial,
        "speedup_workers_vs_legacy": (
            wall_legacy / wall_workers if wall_workers else None),
        "pr3_reference": {
            "wall_s": PR3_REFERENCE_WALL_S,
            "commit": "17a77de",
            "note": "same protocol, dev machine; legacy mode above is the "
                    "in-tree (conservative) stand-in for CI gating",
        },
        "speedup_vs_pr3_reference": (
            PR3_REFERENCE_WALL_S / (wall_workers or wall_serial)),
        "use_pallas_resolved": aff_ops.resolve_use_pallas("auto"),
        "dispatch": d,
        "auction_engaged": d["batched_calls"] > 0,
        "auction_engaged_below_member_threshold": bool(
            d["batched_cycles"] > 0
            and d["min_member_pairs_batched"] < 2048),
    }


def run(full: bool = False) -> List[Dict]:
    global _LAST
    _LAST = _measure(full)
    keys = ("wall_legacy_s", "wall_serial_s", "wall_workers_s",
            "speedup_serial_vs_legacy", "speedup_workers_vs_legacy",
            "speedup_vs_pr3_reference")
    row = {k: _LAST[k] for k in keys}
    row["batched_calls"] = _LAST["dispatch"]["batched_calls"]
    row["serial_cycles"] = _LAST["dispatch"]["serial_cycles"]
    row["batched_cycles"] = _LAST["dispatch"]["batched_cycles"]
    rd = _LAST["redistribution"]
    row["redist_share_heavy"] = rd["heavy"]["share"]
    row["redist_parity_bit_exact"] = rd["parity_bit_exact"]
    return [row]


def artifact(rows: List[Dict]) -> Dict:
    assert _LAST is not None, "run() must precede artifact()"
    return _LAST

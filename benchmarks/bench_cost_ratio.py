"""Table 3 — cost/budget ratio percentiles of budget-violated cases."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.scheduler import EBPSM
from repro.core.types import PlatformConfig

from .common import run_policy, write_csv

RATES = (0.5, 1.0, 6.0, 12.0)
PERCENTILES = (10, 30, 50, 70, 90)


def run(full: bool = False) -> List[Dict]:
    cfg = PlatformConfig()
    rows = []
    for rate in RATES:
        _, res = run_policy(cfg, EBPSM, rate, full)
        ratios = res.violated_ratios()
        row: Dict = {"rate_wf_per_min": rate, "n_violations": len(ratios),
                     "n_workflows": len(res.workflows)}
        for p in PERCENTILES:
            row[f"p{p}"] = (float(np.percentile(ratios, p))
                            if ratios else 1.0)
        rows.append(row)
    write_csv("table3_cost_ratio", rows)
    return rows


def artifact(rows: List[Dict]) -> Dict:
    """BENCH_cost_ratio.json — Table 3 trajectory: how far above budget
    the violated workflows land, per arrival rate (lower is better; the
    paper's claim is that violations stay marginal)."""
    worst_p90 = max(r["p90"] for r in rows)
    violation_rate = sum(r["n_violations"] for r in rows) / max(
        sum(r["n_workflows"] for r in rows), 1)
    return {
        "bench": "cost_ratio",
        "policy": "EBPSM",
        "rates": [r["rate_wf_per_min"] for r in rows],
        "violation_rate": violation_rate,
        "worst_p90_cost_budget_ratio": worst_p90,
        "rows": rows,
    }

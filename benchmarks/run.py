"""Benchmark driver — one module per paper table/figure + the bridge +
roofline.  ``python -m benchmarks.run [--full] [--only NAME]``.

Prints ``name,seconds,key=value...`` lines and writes one CSV per bench
into artifacts/bench/.
"""
from __future__ import annotations

import argparse
import inspect
import time
import traceback

from . import (bench_container_delay, bench_cost_ratio,
               bench_cpu_degradation, bench_grid_wall, bench_makespan,
               bench_prov_delay, bench_roofline, bench_sched_throughput,
               bench_stream_scale, bench_waas_ml)
from .common import print_rows, write_json

BENCHES = {
    "makespan": (bench_makespan, "Fig3+4 makespan/budget/VMs vs rate"),
    "cpu_degradation": (bench_cpu_degradation, "Fig5-6 CPU degradation"),
    "prov_delay": (bench_prov_delay, "Fig7-8 provisioning delay"),
    "container_delay": (bench_container_delay, "Fig9 container delay"),
    "cost_ratio": (bench_cost_ratio, "Table3 violated cost/budget"),
    "sched_throughput": (bench_sched_throughput, "Alg2 kernel throughput"),
    "grid_wall": (bench_grid_wall, "paper-smoke grid end-to-end wall"),
    "stream_scale": (bench_stream_scale,
                     "SoA vs object state at open-stream member scale"),
    "waas_ml": (bench_waas_ml, "WaaS->ML bridge platform"),
    "roofline": (bench_roofline, "roofline from dry-run artifacts"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale workloads (1000 workflows)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    if args.only and args.only not in BENCHES:
        raise SystemExit(
            f"unknown benchmark {args.only!r}; choose from {sorted(BENCHES)}")

    failures = []
    for name, (mod, desc) in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(full=args.full)
            dt = time.time() - t0
            print(f"\n### {name},{dt:.1f}s — {desc} ({len(rows)} rows)")
            print_rows(name, rows[:24])
            if hasattr(mod, "artifact"):
                if "full" in inspect.signature(mod.artifact).parameters:
                    art = mod.artifact(rows, full=args.full)
                else:
                    art = mod.artifact(rows)
                path = write_json(f"BENCH_{name}", art)
                print(f"artifact: {path}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"### {name} FAILED: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete; CSVs in artifacts/bench/")


if __name__ == "__main__":
    main()

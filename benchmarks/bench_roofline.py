"""§Roofline — three-term analysis per (arch × shape) from dry-run
artifacts (run ``python -m repro.launch.dryrun --all --both-meshes``
first; cells without artifacts are reported as missing).
"""
from __future__ import annotations

from typing import Dict, List

from repro.launch.roofline import analyze, load_artifacts

from .common import write_csv


def run(full: bool = False) -> List[Dict]:
    rows = []
    for tag in ("singlepod", "multipod"):
        for art in load_artifacts("artifacts/dryrun", tag):
            if "skipped" in art:
                rows.append({"mesh": tag, "arch": art["arch"],
                             "shape": art["shape"],
                             "skipped": art["skipped"]})
                continue
            a = analyze(art)
            rows.append({
                "mesh": tag, "arch": art["arch"], "shape": art["shape"],
                "compute_s": a["compute_s"], "memory_s": a["memory_s"],
                "collective_s": a["collective_s"],
                "dominant": a["dominant"],
                "useful_flops_ratio": a["useful_flops_ratio"],
                "roofline_fraction": a["roofline_fraction"],
                "hbm_fit": a["hbm_fit_ok"],
                "compile_s": art["compile_s"],
            })
    write_csv("roofline", rows)
    return rows

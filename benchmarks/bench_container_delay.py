"""Fig. 9 — container-initiating-delay sensitivity for EBPSM (10..50 s)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import EBPSM
from repro.core.types import PlatformConfig

from .common import run_policy, summarize, write_csv

DELAYS_S = (10, 20, 30, 40, 50)


def run(full: bool = False) -> List[Dict]:
    rows = []
    for delay in DELAYS_S:
        # keep the paper's 0.4 s init epsilon; scale the download component
        cfg = PlatformConfig().with_(
            container_download_ms=delay * 1000 - 400)
        eng, res = run_policy(cfg, EBPSM, 6.0, full)
        row = {"container_delay_s": delay, "policy": "EBPSM"}
        row.update(summarize(res))
        rows.append(row)
    write_csv("fig9_container_delay", rows)
    return rows

"""WaaS→ML bridge headline: EBPSM vs baselines scheduling multi-tenant
TPU-slice ML jobs (fine-tune + serve over the 10 assigned archs), with
stage costs taken from the compiled dry-run artifacts when present.
"""
from __future__ import annotations

from typing import Dict, List

from repro.waas.platform import compare_policies, straggler_experiment

from .common import write_csv


def run(full: bool = False) -> List[Dict]:
    n = 120 if full else 40
    rows = []
    for rep in compare_policies(n_jobs=n, rate=2.0, seed=7):
        d = rep.metrics.to_dict()
        d.pop("tier_hist", None)  # nested dict: not a CSV scalar
        d["total_slices"] = rep.sim.total_vms
        rows.append(d)
    write_csv("waas_ml_platform", rows)

    st = straggler_experiment(n_jobs=max(n // 2, 15), rate=2.0, seed=7)
    srows = []
    for pol, entries in st.items():
        for dmax, mk, met in entries:
            srows.append({"policy": pol, "max_degradation": dmax,
                          "mean_makespan_s": mk, "budget_met": met})
    write_csv("waas_ml_stragglers", srows)
    return rows + srows

"""Figs. 5–6 — CPU performance-degradation sensitivity (EBPSM vs MSLBL_MW).

Degradation ~ N(max/2, 1%) clipped at max, max ∈ {20..80}% (paper §5.2).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig

from .common import run_policy, summarize, write_csv

DEGRADATIONS = (0.2, 0.4, 0.6, 0.8)


def run(full: bool = False) -> List[Dict]:
    rows = []
    for dmax in DEGRADATIONS:
        cfg = PlatformConfig().with_(
            cpu_degradation_mean=dmax / 2, cpu_degradation_std=0.01,
            cpu_degradation_max=dmax)
        for pol in (EBPSM, MSLBL_MW):
            eng, res = run_policy(cfg, pol, 6.0, full)
            row = {"max_degradation": dmax, "policy": pol.name}
            row.update(summarize(res))
            for name, cnt in eng.pool.vm_count_by_type.items():
                row[f"vms_{name}"] = cnt
            rows.append(row)
    write_csv("fig5_fig6_cpu_degradation", rows)
    return rows


def artifact(rows: List[Dict]) -> Dict:
    """BENCH_cpu_degradation.json — Figs. 5–6 trajectory: EBPSM's
    budget-update loop must keep absorbing degradation better than
    MSLBL_MW's static safety net (budget-met gap per degradation step)."""
    by_deg: Dict[float, Dict[str, Dict]] = {}
    for r in rows:
        by_deg.setdefault(r["max_degradation"], {})[r["policy"]] = r
    steps = []
    for dmax, pols in sorted(by_deg.items()):
        e, m = pols.get("EBPSM"), pols.get("MSLBL_MW")
        steps.append({
            "max_degradation": dmax,
            "ebpsm_budget_met": e["budget_met"] if e else None,
            "mslbl_budget_met": m["budget_met"] if m else None,
            "ebpsm_mean_makespan_s": e["mean_makespan_s"] if e else None,
            "mslbl_mean_makespan_s": m["mean_makespan_s"] if m else None,
        })
    gaps = [s["ebpsm_budget_met"] - s["mslbl_budget_met"]
            for s in steps
            if s["ebpsm_budget_met"] is not None
            and s["mslbl_budget_met"] is not None]
    return {
        "bench": "cpu_degradation",
        "steps": steps,
        "min_budget_met_gap_ebpsm_minus_mslbl": min(gaps) if gaps else None,
    }

"""Figs. 5–6 — CPU performance-degradation sensitivity (EBPSM vs MSLBL_MW).

Degradation ~ N(max/2, 1%) clipped at max, max ∈ {20..80}% (paper §5.2).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig

from .common import run_policy, summarize, write_csv

DEGRADATIONS = (0.2, 0.4, 0.6, 0.8)


def run(full: bool = False) -> List[Dict]:
    rows = []
    for dmax in DEGRADATIONS:
        cfg = PlatformConfig().with_(
            cpu_degradation_mean=dmax / 2, cpu_degradation_std=0.01,
            cpu_degradation_max=dmax)
        for pol in (EBPSM, MSLBL_MW):
            eng, res = run_policy(cfg, pol, 6.0, full)
            row = {"max_degradation": dmax, "policy": pol.name}
            row.update(summarize(res))
            for name, cnt in eng.pool.vm_count_by_type.items():
                row[f"vms_{name}"] = cnt
            rows.append(row)
    write_csv("fig5_fig6_cpu_degradation", rows)
    return rows

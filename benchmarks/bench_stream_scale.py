"""Open-stream member scaling: SoA pooled state vs the object baseline.

The SoA refactor (``core.types.StreamState`` + the pooled backing in
``core.jax_engine.BatchSimEngine``) exists so thousands of open-stream
members ride a handful of flat numpy arrays instead of one Python object
graph per workflow (per-wf dataclass + unscheduled ``set`` +
pending-parent ``dict`` + per-wf ``RedistState`` mirrors).  This bench
measures what that buys at stream scale:

* **members-vs-wall curve** — the same member population run through
  ``BatchSimEngine`` in both layouts at each point.  Per-member wall
  *grows* with the point size (every member is an independent full
  simulation and rendezvous rounds scale with the merged stream) — the
  meaningful comparison is SoA vs object at the same point, and the
  gap widens in SoA's favor at the ≥1k point;
* **state-footprint block** — tracemalloc-traced peak at the largest
  point in both layouts: the pooled arrays replace the object graph's
  per-workflow sets/dicts (hundreds of bytes per task) with ~26 B/task
  of flat arrays; the traced peak also carries layout-independent
  simulation state (VM pools, events, results), so the ratio
  understates the pure state-layout win;
* **parity** — both layouts must produce bit-identical per-workflow
  results at every point (the full matrix lives in
  ``tests/test_dispatcher_matrix.py``);
* a **peak-RSS block** + host metadata like ``bench_grid_wall``.

``benchmarks.check_speedup --stream-floor`` gates the object/SoA traced
peak ratio at the ≥1k point (recorded trajectory: 1.06x on the dev
machine — deterministic allocations, so it travels across machines far
better than walls), plus the parity flag and a loose wall-ratio guard
(SoA walls track parity with ±10% noise; the guard only catches a
catastrophic slowdown).
"""
from __future__ import annotations

import resource
import time
import tracemalloc
from typing import Dict, List, Optional, Tuple

from repro.core.jax_engine import BatchSimEngine, predistribute_workload
from repro.core.scheduler import EBPSM
from repro.core.types import PlatformConfig, clone_workload
from repro.workflows.workload import WorkloadSpec, generate_workload

from .bench_grid_wall import host_info

# Members per point: the last point is the ≥1k-member regime the SoA
# layer targets.  Every member is a small 3-workflow stream — distinct
# workload draws cycled across members, cloned per member exactly like
# the grid/online harnesses do.
MEMBER_POINTS = (64, 256, 1024)
WORKFLOWS_PER_MEMBER = 3
N_PROTO_WORKLOADS = 8

_LAST: Optional[Dict] = None


def _protos(cfg: PlatformConfig):
    out = []
    for i in range(N_PROTO_WORKLOADS):
        wl = generate_workload(cfg, WorkloadSpec(
            n_workflows=WORKFLOWS_PER_MEMBER, arrival_rate_per_min=12.0,
            seed=100 + i, sizes=("small",), budget_lo=0.5, budget_hi=1.0))
        out.append(predistribute_workload(cfg, wl, EBPSM.budget_mode))
    return out


def _members(cfg: PlatformConfig, protos, n: int):
    members, pre = [], []
    for i in range(n):
        proto, spares = protos[i % len(protos)]
        members.append((EBPSM, clone_workload(proto), i))
        pre.append(spares)
    return members, pre


def _run(cfg: PlatformConfig, protos, n: int, soa: bool,
         traced: bool = False) -> Tuple[float, float, List]:
    """One engine pass → (wall_s, traced_peak_bytes, result signature)."""
    members, pre = _members(cfg, protos, n)
    peak = 0.0
    if traced:
        tracemalloc.start()
    t0 = time.perf_counter()
    engine = BatchSimEngine(cfg, members, predistributed=pre, soa=soa)
    results = engine.run()
    wall = time.perf_counter() - t0
    if traced:
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
    sig = [(w.wid, w.finish_ms, w.cost)
           for res in results for w in res.workflows]
    return wall, float(peak), sig


def _measure(full: bool = False) -> Dict:
    cfg = PlatformConfig()
    protos = _protos(cfg)
    points: List[Dict] = []
    for n in MEMBER_POINTS:
        wall_soa, _, sig_soa = _run(cfg, protos, n, soa=True)
        wall_obj, _, sig_obj = _run(cfg, protos, n, soa=False)
        points.append({
            "members": n,
            "workflows": n * WORKFLOWS_PER_MEMBER,
            "wall_soa_s": wall_soa,
            "wall_object_s": wall_obj,
            "per_member_soa_ms": wall_soa / n * 1e3,
            "per_member_object_ms": wall_obj / n * 1e3,
            "parity_bit_exact": sig_soa == sig_obj,
        })
    n_max = MEMBER_POINTS[-1]
    # Separate traced passes: tracemalloc slows execution severalfold,
    # so the memory story and the wall story never share a run.
    _, peak_soa, _ = _run(cfg, protos, n_max, soa=True, traced=True)
    _, peak_obj, _ = _run(cfg, protos, n_max, soa=False, traced=True)
    last = points[-1]
    ru = resource.getrusage(resource.RUSAGE_SELF)
    return {
        "bench": "stream_scale",
        "host": host_info(),
        "members_points": list(MEMBER_POINTS),
        "workflows_per_member": WORKFLOWS_PER_MEMBER,
        "points": points,
        "parity_bit_exact": all(p["parity_bit_exact"] for p in points),
        "state_footprint": {
            "members": n_max,
            "traced_peak_soa_mb": peak_soa / 1e6,
            "traced_peak_object_mb": peak_obj / 1e6,
            "traced_peak_per_member_soa_kb": peak_soa / n_max / 1e3,
            "traced_peak_per_member_object_kb": peak_obj / n_max / 1e3,
            "object_over_soa_peak_ratio": (peak_obj / peak_soa
                                           if peak_soa else 0.0),
        },
        "wall_object_over_soa_at_max": (
            last["wall_object_s"] / last["wall_soa_s"]
            if last["wall_soa_s"] else 0.0),
        "peak_rss": {
            # Linux ru_maxrss is KiB; process-wide high-water mark, so
            # it includes every earlier point (recorded for provenance,
            # not a per-layout comparison — that's the traced block).
            "ru_maxrss_mb": ru.ru_maxrss / 1024.0,
            "note": "process high-water mark across all points",
        },
    }


def run(full: bool = False) -> List[Dict]:
    global _LAST
    _LAST = _measure(full)
    rows = []
    for p in _LAST["points"]:
        rows.append({k: p[k] for k in
                     ("members", "workflows", "wall_soa_s", "wall_object_s",
                      "per_member_soa_ms", "per_member_object_ms",
                      "parity_bit_exact")})
    sf = _LAST["state_footprint"]
    rows[-1]["soa_peak_mb"] = sf["traced_peak_soa_mb"]
    rows[-1]["object_peak_mb"] = sf["traced_peak_object_mb"]
    rows[-1]["mem_ratio"] = sf["object_over_soa_peak_ratio"]
    return rows


def artifact(rows: List[Dict]) -> Dict:
    assert _LAST is not None, "run() must precede artifact()"
    return _LAST

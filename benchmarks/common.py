"""Shared benchmark machinery: workload construction + result tables.

Default scale is CI-friendly (~120 workflows ≈ 45k tasks per point);
``--full`` reproduces the paper's 1000-workflow workloads.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import SimEngine
from repro.core.scheduler import Policy
from repro.core.types import PlatformConfig, SimResult
from repro.workflows.workload import WorkloadSpec, generate_workload

OUT_DIR = os.environ.get("BENCH_OUT", "artifacts/bench")


def workload(cfg: PlatformConfig, rate: float, full: bool, seed: int = 11):
    """Default: CI-scale (150 wfs, small+medium ≈ 11k tasks per point).
    --full: the paper's scale (1000 wfs incl. large ≈ 380k tasks — hours
    of simulated scheduling; the large class alone multiplies queue×pool
    work ~50×, which is exactly the regime the batched JAX cycles and the
    affinity kernel exist for)."""
    n = 1000 if full else 150
    sizes = ("small", "medium", "large") if full else ("small", "medium")
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=sizes)
    return generate_workload(cfg, spec)


def run_policy(cfg: PlatformConfig, policy: Policy, rate: float, full: bool,
               seed: int = 11, trace: bool = False):
    eng = SimEngine(cfg, policy, workload(cfg, rate, full, seed), seed=0,
                    trace=trace)
    res = eng.run()
    return eng, res


def summarize(res: SimResult) -> Dict[str, Any]:
    by_app = res.makespans_by_app()
    row: Dict[str, Any] = {
        "mean_makespan_s": float(np.mean([w.makespan_ms for w in
                                          res.workflows])) / 1000,
        "budget_met": res.budget_met_fraction,
        "utilization": res.avg_vm_utilization,
        "total_vms": res.total_vms,
        "wall_s": round(res.wall_s, 2),
    }
    for app, ms in sorted(by_app.items()):
        row[f"mk_{app}_s"] = float(np.mean(ms)) / 1000
    return row


def write_csv(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        keys = sorted({k for r in rows for k in r}, key=str)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
    return path


def write_json(name: str, payload: Dict[str, Any]) -> str:
    """One JSON artifact per tracked benchmark (BENCH_<name>.json) so the
    perf trajectory is diffable across PRs."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return path


def print_rows(name: str, rows: Sequence[Dict[str, Any]],
               cols: Optional[Sequence[str]] = None) -> None:
    print(f"\n== {name} ==")
    if not rows:
        print("(no rows)")
        return
    cols = cols or [k for k in rows[0] if not k.startswith("mk_")]
    print(" | ".join(f"{c:>16s}" for c in cols))
    for r in rows:
        print(" | ".join(
            f"{r.get(c, ''):>16.4g}" if isinstance(r.get(c), float)
            else f"{str(r.get(c, '')):>16s}" for c in cols))

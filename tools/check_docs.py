"""Docs link checker — CI gate for the docs layer.

``python tools/check_docs.py [--root DIR]``

Checks, for ``README.md``, ``ROADMAP.md`` and every ``docs/*.md``:

* every relative markdown link ``[text](target)`` resolves to an
  existing file (anchors are stripped; external ``http(s):``/``mailto:``
  links are skipped — this repo's docs should work offline);
* every backticked repo path that *looks* like a file reference
  (``src/...``, ``docs/...``, ``tests/...``, ``benchmarks/...``,
  ``tools/...``, ``.github/...``, ``artifacts/...`` with an extension)
  points at a real file or directory.  Generated artifact paths
  (``artifacts/...``) are exempt — they exist only after a bench run.

``--run-quickstart`` additionally executes the README's quickstart
snippets *as written* — the first fenced ``python`` block (the
``simulate_batch`` grid example) and the first ``paper-smoke``
command from a fenced ``bash`` block — so documentation drift breaks
the docs CI job, not a user's first five minutes.  The link check
itself stays dependency-free (stdlib only); the quickstart needs the
pinned requirements installed.

Exit non-zero with one line per broken reference.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys
from typing import List

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# Backticked tokens that look like repo file paths: at least one slash,
# a known top-level prefix, and an extension or trailing slash.
PATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|tools|\.github)/[\w\-./]+)`")

DOC_GLOBS = ("README.md", "ROADMAP.md", "docs/*.md")


def _targets(root: pathlib.Path) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for pat in DOC_GLOBS:
        out.extend(sorted(root.glob(pat)))
    return out


def check_file(root: pathlib.Path, doc: pathlib.Path) -> List[str]:
    errors: List[str] = []
    text = doc.read_text()
    rel = doc.relative_to(root)

    for m in LINK_RE.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if (not target or target.startswith(("http://", "https://",
                                            "mailto:"))):
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{rel}: broken link -> {m.group(1)}")

    for m in PATH_RE.finditer(text):
        p = m.group(1).rstrip("/")
        if not (root / p).exists():
            errors.append(f"{rel}: referenced path missing -> {p}")

    return errors


def run_quickstart(root: pathlib.Path) -> None:
    """Execute the README quickstart snippets verbatim."""
    text = (root / "README.md").read_text()
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")

    py_blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    if not py_blocks:
        sys.exit("README.md has no fenced python quickstart block")
    print("== README python quickstart ==")
    print(py_blocks[0].rstrip())
    subprocess.run([sys.executable, "-c", py_blocks[0]], check=True,
                   env=env, cwd=root)

    bash_lines = [line.strip()
                  for block in re.findall(r"```bash\n(.*?)```", text, re.S)
                  for line in block.splitlines()]
    cmd = next((line for line in bash_lines
                if "paper-smoke" in line and "--check-floors" not in line),
               None)
    if cmd is None:
        sys.exit("README.md has no paper-smoke quickstart command")
    print(f"== README bash quickstart ==\n{cmd}")
    subprocess.run(cmd, shell=True, check=True, env=env, cwd=root)
    print("quickstart OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".",
                    help="repo root (default: cwd)")
    ap.add_argument("--run-quickstart", action="store_true",
                    help="also execute the README quickstart snippets "
                         "(needs the pinned requirements installed)")
    args = ap.parse_args()
    root = pathlib.Path(args.root).resolve()

    docs = _targets(root)
    if not docs:
        sys.exit(f"no docs found under {root} ({', '.join(DOC_GLOBS)})")

    errors: List[str] = []
    for doc in docs:
        errors.extend(check_file(root, doc))

    for doc in docs:
        print(f"checked {doc.relative_to(root)}")
    if errors:
        sys.exit("BROKEN DOC REFERENCES:\n  " + "\n  ".join(errors))
    print(f"docs OK ({len(docs)} files, no broken references)")

    if args.run_quickstart:
        run_quickstart(root)


if __name__ == "__main__":
    main()

"""Trace-schema validator — CI gate for ``repro.obs`` exports.

``python tools/check_trace.py PATH [PATH ...]``

Each PATH is a ``*.trace.json`` / ``*.events.jsonl`` file or a
directory scanned (non-recursively) for both.  Validates against the
versioned schema in :mod:`repro.obs.events` / :mod:`repro.obs.export`:

* **Chrome traces** (``*.trace.json``): top-level ``traceEvents`` is a
  non-empty list; ``metadata.schema == "repro-obs-trace"`` with a
  ``version`` this checker understands; every event has ``ph`` in
  {M, X, C} with integer ``pid``/``tid``; slice (``X``) and counter
  (``C``) events carry non-negative integer ``ts`` (and ``dur`` for
  slices); counter events carry a numeric ``args.value``.
* **Event dumps** (``*.events.jsonl``): first line is a header with
  ``schema == "repro-obs-events"``, a known ``version`` and an
  ``n_events`` matching the number of body lines; every body line has
  a ``kind`` from ``events.KIND_NAMES``, an integer ``t_ms >= 0`` and
  exactly the fields ``events.SCHEMA`` declares for that kind.  Schema
  v2 added the chaos kinds ``vm_revoke`` (spot revocation),
  ``task_fail`` / ``task_retry`` (transient failures) and
  ``straggler_detect`` — dumps from chaos runs must carry them with
  their declared fields like any other kind.

``--stats`` additionally prints a per-kind event-count table for each
event dump (quick visibility into what a chaos run actually injected).

Exit codes: 0 = all files valid, 1 = validation failures (one line
each), 2 = no trace files found under the given paths.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterator, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.events import EVENT_SCHEMA_VERSION, KIND_NAMES, SCHEMA  # noqa: E402
from repro.obs.export import EVENTS_SCHEMA, TRACE_SCHEMA  # noqa: E402

# kind name -> expected field names (beyond kind/t_ms), from the column
# schema the exporter writes.
_FIELDS_OF = {KIND_NAMES[k]: tuple(name for name, _col in spec)
              for k, spec in SCHEMA.items()}


def _iter_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isdir(p):
            for name in sorted(os.listdir(p)):
                if name.endswith(".trace.json") or name.endswith(
                        ".events.jsonl"):
                    yield os.path.join(p, name)
        else:
            yield p


def _is_int(v: object) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def check_trace_json(path: str) -> List[str]:
    errs: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable JSON ({e})"]
    meta = doc.get("metadata")
    if not isinstance(meta, dict) or meta.get("schema") != TRACE_SCHEMA:
        errs.append(f"{path}: metadata.schema != {TRACE_SCHEMA!r}")
    elif not (_is_int(meta.get("version"))
              and 1 <= meta["version"] <= EVENT_SCHEMA_VERSION):
        errs.append(f"{path}: unsupported metadata.version "
                    f"{meta.get('version')!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        errs.append(f"{path}: traceEvents missing or empty")
        return errs
    for i, e in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("M", "X", "C"):
            errs.append(f"{where}: unexpected ph {ph!r}")
            continue
        if not (_is_int(e.get("pid")) and _is_int(e.get("tid"))):
            errs.append(f"{where}: pid/tid must be ints")
        if ph in ("X", "C"):
            if not (_is_int(e.get("ts")) and e["ts"] >= 0):
                errs.append(f"{where}: ts must be a non-negative int")
            if not isinstance(e.get("args"), dict):
                errs.append(f"{where}: args must be an object")
        if ph == "X" and not (_is_int(e.get("dur")) and e["dur"] >= 0):
            errs.append(f"{where}: dur must be a non-negative int")
        if ph == "C":
            v = e.get("args", {}).get("value")
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errs.append(f"{where}: counter args.value must be numeric")
    return errs


def check_events_jsonl(path: str,
                       stats: "dict | None" = None) -> List[str]:
    """Validate one event dump; when ``stats`` is a dict, tally
    per-kind event counts into it (the ``--stats`` table)."""
    errs: List[str] = []
    try:
        with open(path) as f:
            lines = f.read().splitlines()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    if not lines:
        return [f"{path}: empty file"]
    try:
        header = json.loads(lines[0])
    except json.JSONDecodeError as e:
        return [f"{path}: header line is not JSON ({e})"]
    if header.get("schema") != EVENTS_SCHEMA:
        errs.append(f"{path}: header schema != {EVENTS_SCHEMA!r}")
    elif not (_is_int(header.get("version"))
              and 1 <= header["version"] <= EVENT_SCHEMA_VERSION):
        errs.append(f"{path}: unsupported header version "
                    f"{header.get('version')!r}")
    body = lines[1:]
    if header.get("n_events") != len(body):
        errs.append(f"{path}: header n_events={header.get('n_events')!r} "
                    f"but {len(body)} event lines")
    for i, line in enumerate(body, start=2):
        where = f"{path}:{i}"
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"{where}: not JSON ({e})")
            continue
        kind = row.get("kind")
        if kind not in _FIELDS_OF:
            errs.append(f"{where}: unknown kind {kind!r}")
            continue
        if stats is not None:
            stats[kind] = stats.get(kind, 0) + 1
        if not (_is_int(row.get("t_ms")) and row["t_ms"] >= 0):
            errs.append(f"{where}: t_ms must be a non-negative int")
        want = set(_FIELDS_OF[kind]) | {"kind", "t_ms"}
        got = set(row)
        if got != want:
            missing = sorted(want - got)
            extra = sorted(got - want)
            errs.append(f"{where}: kind {kind!r} fields mismatch "
                        f"(missing={missing}, extra={extra})")
    return errs


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+",
                    help="trace files or directories to validate")
    ap.add_argument("--stats", action="store_true",
                    help="print per-kind event counts for each event dump "
                         "(schema-v2 chaos kinds — vm_revoke, task_fail, "
                         "task_retry, straggler_detect — show up here "
                         "when a chaos run injected them)")
    args = ap.parse_args(argv)
    files = list(_iter_files(args.paths))
    if not files:
        print("check_trace: no *.trace.json / *.events.jsonl files found",
              file=sys.stderr)
        return 2
    failures: List[str] = []
    checked: List[Tuple[str, int]] = []
    kind_stats: "dict[str, dict]" = {}
    for path in files:
        if path.endswith(".events.jsonl"):
            per_file: "dict | None" = {} if args.stats else None
            errs = check_events_jsonl(path, stats=per_file)
            if per_file is not None:
                kind_stats[path] = per_file
        else:
            errs = check_trace_json(path)
        failures.extend(errs)
        checked.append((path, len(errs)))
    for path, n in checked:
        print(f"  {'FAIL' if n else 'ok  '} {path}")
    if args.stats:
        for path, counts in kind_stats.items():
            total = sum(counts.values())
            print(f"\n  {path}: {total} events")
            for kind, n in sorted(counts.items(),
                                  key=lambda kv: (-kv[1], kv[0])):
                print(f"    {kind:20s} {n}")
    if failures:
        print(f"\ncheck_trace: {len(failures)} problem(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"check_trace: {len(checked)} file(s) valid "
          f"(schema v{EVENT_SCHEMA_VERSION})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

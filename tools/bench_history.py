"""Benchmark trend table — ingest ``BENCH_*.json`` artifacts.

``python tools/bench_history.py [DIR ...] [--out FILE] [--check]``

Scans the given directories (default: ``artifacts/bench`` and
``artifacts/exp``) for the benchmark artifacts the suite emits
(``benchmarks/run.py``, ``repro.exp.run``) and prints one markdown
trend table: current headline numbers next to the recorded historical
references baked into each artifact (the PR-3 grid wall, the
pre-array-path Algorithm-3 share), with the delta.

Default mode is informational (always exits 0).  ``--check`` turns the
table into a regression gate: exit 1 when any *dimensionless* metric
(speedups, shares, ratios — never absolute walls, which don't compare
across machines) regresses beyond ``--tolerance`` (default 25%)
relative to its recorded reference.  The bench-smoke CI job runs it in
this mode so a silent perf slide fails the build instead of scrolling
by in a log.  ``--out`` additionally writes the table to a file (CI
appends it to the job summary).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

DEFAULT_DIRS = ("artifacts/bench", "artifacts/exp")


def _get(d: Dict, *path):
    for key in path:
        if not isinstance(d, dict) or key not in d:
            return None
        d = d[key]
    return d


def _fmt(v: Optional[float], unit: str = "") -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float):
        return f"{v:.4g}{unit}"
    return f"{v}{unit}"


def _delta(cur: Optional[float], ref: Optional[float],
           lower_is_better: bool = False) -> str:
    if cur is None or ref is None or not ref:
        return ""
    pct = (cur - ref) / ref * 100.0
    arrow = ""
    if abs(pct) >= 0.05:
        better = (pct < 0) if lower_is_better else (pct > 0)
        arrow = " ✓" if better else " ✗"
    return f"{pct:+.1f}%{arrow}"


def rows_for(doc: Dict, path: str) -> List[Dict]:
    """Structured metric rows for one artifact.  ``gate=True`` rows are
    dimensionless (machine-portable) and participate in ``--check``;
    absolute wall times stay informational."""
    bench = doc.get("bench", os.path.basename(path))
    out: List[Dict] = []

    def row(metric, cur, ref, ref_label, lower_is_better=False, unit="",
            gate=False):
        out.append({"bench": bench, "metric": metric, "cur": cur,
                    "ref": ref, "ref_label": ref_label,
                    "lower_is_better": lower_is_better, "unit": unit,
                    "gate": gate})

    if bench == "grid_wall":
        row("serial wall", _get(doc, "wall_serial_s"),
            _get(doc, "pr3_reference", "wall_s"),
            f"PR3 @{_get(doc, 'pr3_reference', 'commit') or '?'}",
            lower_is_better=True, unit="s")
        row("speedup vs PR3", _get(doc, "speedup_vs_pr3_reference"),
            1.0, "parity", gate=True)
        row("redistribute share (heavy)",
            _get(doc, "redistribution", "heavy", "share"),
            _get(doc, "redistribution", "pre_array_reference", "share"),
            "pre-array scalar", lower_is_better=True, gate=True)
    elif bench == "makespan":
        row("batched vs ref speedup", _get(doc, "speedup_batched_vs_ref"),
            1.0, "sequential oracle", gate=True)
        row("batched wall", _get(doc, "batched_wall_s"),
            _get(doc, "ref_wall_s"), "sequential oracle",
            lower_is_better=True, unit="s")
    elif bench == "stream_scale":
        row("object/SoA peak RSS ratio",
            _get(doc, "state_footprint", "object_over_soa_peak_ratio"),
            1.0, "parity", gate=True)
        row("object/SoA wall @max members",
            _get(doc, "wall_object_over_soa_at_max"), 1.0, "parity",
            gate=True)
    elif bench == "paper_grid":
        row("grid wall", _get(doc, "wall_s"), None, "", unit="s")
        row("EBPSM/MSLBL makespan ratio",
            _get(doc, "ebpsm_vs_mslbl_makespan_ratio"), 1.0,
            "MSLBL parity", lower_is_better=True, gate=True)
        met = _get(doc, "summary_by_policy", "EBPSM", "budget_met_min")
        row("EBPSM budget-met (min)", met,
            _get(doc, "ebpsm_budget_met_floor"), "CI floor", gate=True)
    else:
        # Unknown artifact: surface its scalar numerics so new benches
        # show up in the trend table without a code change here.
        for key in sorted(doc):
            v = doc[key]
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                row(key, v, None, "")
    return out


def collect_rows(dirs: List[str]) -> "tuple[List[str], List[Dict]]":
    """(file list, structured rows) for every artifact under ``dirs``.
    Unreadable artifacts produce a row with ``metric='unreadable'``."""
    files: List[str] = []
    for d in dirs:
        files.extend(sorted(glob.glob(os.path.join(d, "BENCH_*.json"))))
    rows: List[Dict] = []
    for path in files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            rows.append({"bench": os.path.basename(path),
                         "metric": f"unreadable ({e})", "cur": None,
                         "ref": None, "ref_label": "",
                         "lower_is_better": False, "unit": "",
                         "gate": False})
            continue
        rows.extend(rows_for(doc, path))
    return files, rows


def build_table(files: List[str], rows: List[Dict],
                dirs: List[str]) -> str:
    lines = ["| bench | metric | current | reference | ref source | delta |",
             "|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            "| " + " | ".join([
                r["bench"], r["metric"], _fmt(r["cur"], r["unit"]),
                _fmt(r["ref"], r["unit"]), r["ref_label"],
                _delta(r["cur"], r["ref"], r["lower_is_better"])]) + " |")
    if not files:
        return ("bench_history: no BENCH_*.json artifacts under "
                + ", ".join(dirs)
                + " (run benchmarks/run.py or repro.exp.run first)\n")
    header = (f"### Benchmark trend ({len(rows)} metrics from "
              f"{len(files)} artifact(s))\n\n")
    return header + "\n".join(lines) + "\n"


def regressions(rows: List[Dict], tolerance: float) -> List[str]:
    """Gate-row regressions beyond ``tolerance`` (relative, against the
    recorded reference, oriented per row)."""
    fails: List[str] = []
    for r in rows:
        if not r["gate"] or r["cur"] is None or not r["ref"]:
            continue
        cur, ref = float(r["cur"]), float(r["ref"])
        rel = (cur - ref) / abs(ref)
        worse = rel if r["lower_is_better"] else -rel
        if worse > tolerance:
            fails.append(
                f"{r['bench']}: {r['metric']} = {cur:.4g} vs reference "
                f"{ref:.4g} ({r['ref_label']}): {rel:+.1%} is past the "
                f"{tolerance:.0%} tolerance")
    return fails


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="*", default=list(DEFAULT_DIRS),
                    help="directories to scan for BENCH_*.json "
                         f"(default: {' '.join(DEFAULT_DIRS)})")
    ap.add_argument("--out", default=None,
                    help="also write the markdown table to this file")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when a dimensionless metric (speedup, "
                         "share, ratio) regresses beyond --tolerance vs "
                         "its recorded reference (absolute walls are "
                         "never gated — they don't compare across "
                         "machines)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression tolerance for --check "
                         "(default 0.25 = 25%%)")
    args = ap.parse_args(argv)
    dirs = args.dirs or list(DEFAULT_DIRS)
    files, rows = collect_rows(dirs)
    table = build_table(files, rows, dirs)
    print(table, end="")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(table)
    if args.check:
        fails = regressions(rows, args.tolerance)
        if fails:
            print(f"\nbench_history --check: {len(fails)} regression(s):")
            for line in fails:
                print(f"  {line}")
            return 1
        n_gated = sum(1 for r in rows
                      if r["gate"] and r["cur"] is not None and r["ref"])
        print(f"\nbench_history --check: {n_gated} gated metric(s) within "
              f"{args.tolerance:.0%} of reference")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

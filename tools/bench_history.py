"""Benchmark trend table — ingest ``BENCH_*.json`` artifacts.

``python tools/bench_history.py [DIR ...] [--out FILE]``

Scans the given directories (default: ``artifacts/bench`` and
``artifacts/exp``) for the benchmark artifacts the suite emits
(``benchmarks/run.py``, ``repro.exp.run``) and prints one markdown
trend table: current headline numbers next to the recorded historical
references baked into each artifact (the PR-3 grid wall, the
pre-array-path Algorithm-3 share), with the delta.

Informational only — always exits 0; the gating lives in
``benchmarks/check_speedup.py`` and the CI workflow.  ``--out``
additionally writes the table to a file (CI appends it to the job
summary).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

DEFAULT_DIRS = ("artifacts/bench", "artifacts/exp")

#: rows: (bench name, metric label, extractor, reference extractor)
#: extractors return None when the artifact doesn't carry the field —
#: the row degrades to "n/a" instead of failing on older artifacts.


def _get(d: Dict, *path):
    for key in path:
        if not isinstance(d, dict) or key not in d:
            return None
        d = d[key]
    return d


def _fmt(v: Optional[float], unit: str = "") -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float):
        return f"{v:.4g}{unit}"
    return f"{v}{unit}"


def _delta(cur: Optional[float], ref: Optional[float],
           lower_is_better: bool = False) -> str:
    if cur is None or ref is None or not ref:
        return ""
    pct = (cur - ref) / ref * 100.0
    arrow = ""
    if abs(pct) >= 0.05:
        better = (pct < 0) if lower_is_better else (pct > 0)
        arrow = " ✓" if better else " ✗"
    return f"{pct:+.1f}%{arrow}"


def rows_for(doc: Dict, path: str) -> List[List[str]]:
    bench = doc.get("bench", os.path.basename(path))
    out: List[List[str]] = []

    def row(metric, cur, ref, ref_label, lower_is_better=False, unit=""):
        out.append([bench, metric, _fmt(cur, unit), _fmt(ref, unit),
                    ref_label, _delta(cur, ref, lower_is_better)])

    if bench == "grid_wall":
        row("serial wall", _get(doc, "wall_serial_s"),
            _get(doc, "pr3_reference", "wall_s"),
            f"PR3 @{_get(doc, 'pr3_reference', 'commit') or '?'}",
            lower_is_better=True, unit="s")
        row("speedup vs PR3", _get(doc, "speedup_vs_pr3_reference"),
            1.0, "parity")
        row("redistribute share (heavy)",
            _get(doc, "redistribution", "heavy", "share"),
            _get(doc, "redistribution", "pre_array_reference", "share"),
            "pre-array scalar", lower_is_better=True)
    elif bench == "makespan":
        row("batched vs ref speedup", _get(doc, "speedup_batched_vs_ref"),
            1.0, "sequential oracle")
        row("batched wall", _get(doc, "batched_wall_s"),
            _get(doc, "ref_wall_s"), "sequential oracle",
            lower_is_better=True, unit="s")
    elif bench == "stream_scale":
        row("object/SoA peak RSS ratio",
            _get(doc, "state_footprint", "object_over_soa_peak_ratio"),
            1.0, "parity")
        row("object/SoA wall @max members",
            _get(doc, "wall_object_over_soa_at_max"), 1.0, "parity")
    elif bench == "paper_grid":
        row("grid wall", _get(doc, "wall_s"), None, "", unit="s")
        row("EBPSM/MSLBL makespan ratio",
            _get(doc, "ebpsm_vs_mslbl_makespan_ratio"), 1.0,
            "MSLBL parity", lower_is_better=True)
        met = _get(doc, "summary_by_policy", "EBPSM", "budget_met_min")
        row("EBPSM budget-met (min)", met,
            _get(doc, "ebpsm_budget_met_floor"), "CI floor")
    else:
        # Unknown artifact: surface its scalar numerics so new benches
        # show up in the trend table without a code change here.
        for key in sorted(doc):
            v = doc[key]
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                row(key, v, None, "")
    return out


def build_table(dirs: List[str]) -> str:
    files: List[str] = []
    for d in dirs:
        files.extend(sorted(glob.glob(os.path.join(d, "BENCH_*.json"))))
    lines = ["| bench | metric | current | reference | ref source | delta |",
             "|---|---|---|---|---|---|"]
    n_rows = 0
    for path in files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            lines.append(f"| {os.path.basename(path)} | unreadable ({e}) "
                         "| | | | |")
            continue
        for r in rows_for(doc, path):
            lines.append("| " + " | ".join(r) + " |")
            n_rows += 1
    if not files:
        return ("bench_history: no BENCH_*.json artifacts under "
                + ", ".join(dirs)
                + " (run benchmarks/run.py or repro.exp.run first)\n")
    header = (f"### Benchmark trend ({n_rows} metrics from "
              f"{len(files)} artifact(s))\n\n")
    return header + "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="*", default=list(DEFAULT_DIRS),
                    help="directories to scan for BENCH_*.json "
                         f"(default: {' '.join(DEFAULT_DIRS)})")
    ap.add_argument("--out", default=None,
                    help="also write the markdown table to this file")
    args = ap.parse_args(argv)
    table = build_table(args.dirs or list(DEFAULT_DIRS))
    print(table, end="")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(table)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

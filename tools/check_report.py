"""Monitor-report validator — CI gate for ``repro.obs.report`` exports.

``python tools/check_report.py PATH [PATH ...] [--require-alert KIND]``

Each PATH is a ``*.monitor.json`` file or a directory scanned
(non-recursively) for them.  Validates against the versioned schema in
:mod:`repro.obs.report` (``repro-obs-monitor`` v1):

* header: ``schema == "repro-obs-monitor"`` with a ``version`` this
  checker understands, a string ``label`` and integer ``horizon_ms``;
* samples: ``samples.t_ms`` is a non-decreasing list of non-negative
  ints; every entry of ``samples.series`` is a finite-float list of the
  same length;
* SLO table: one row per QoS class listed in ``qos`` (plus per-series
  breakdowns are allowed), each carrying its SLI / target pairs;
* alerts: every record has a ``kind`` from
  ``repro.obs.slo.ALERT_KIND_NAMES``, integer timestamps with
  ``fired_ms <= cleared_ms`` (or ``cleared_ms == -1`` while open), and
  ``alerts_by_kind`` tallies exactly the ``alerts`` list;
* sibling dashboard: ``<label>.dashboard.html`` exists next to the JSON
  and contains the ``repro-obs-dashboard`` marker.

``--require-alert KIND`` (repeatable) asserts that at least one alert
of that kind fired *across all checked files* — the chaos-smoke CI gate
uses it to pin the budget-burn and straggler-spike detectors.

Exit codes: 0 = all files valid, 1 = validation failures (one line
each), 2 = no monitor files found under the given paths.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Iterator, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.report import (DASHBOARD_MARKER, MONITOR_SCHEMA,  # noqa: E402
                              MONITOR_SCHEMA_VERSION)
from repro.obs.slo import ALERT_KIND_NAMES  # noqa: E402

_KNOWN_KINDS = set(ALERT_KIND_NAMES.values())


def _iter_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isdir(p):
            for name in sorted(os.listdir(p)):
                if name.endswith(".monitor.json"):
                    yield os.path.join(p, name)
        else:
            yield p


def _is_int(v: object) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v: object) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_monitor_json(path: str) -> Tuple[List[str], Dict[str, int]]:
    """Validate one ``*.monitor.json``; returns (errors, alert tallies)."""
    errs: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable JSON ({e})"], {}
    if doc.get("schema") != MONITOR_SCHEMA:
        errs.append(f"{path}: schema != {MONITOR_SCHEMA!r}")
    elif not (_is_int(doc.get("version"))
              and 1 <= doc["version"] <= MONITOR_SCHEMA_VERSION):
        errs.append(f"{path}: unsupported version {doc.get('version')!r}")
    if not isinstance(doc.get("label"), str) or not doc.get("label"):
        errs.append(f"{path}: label must be a non-empty string")
    if not (_is_int(doc.get("horizon_ms")) and doc["horizon_ms"] >= 0):
        errs.append(f"{path}: horizon_ms must be a non-negative int")

    samples = doc.get("samples")
    if not isinstance(samples, dict):
        errs.append(f"{path}: samples missing")
        samples = {}
    t_ms = samples.get("t_ms", [])
    if not isinstance(t_ms, list) or not all(
            _is_int(t) and t >= 0 for t in t_ms):
        errs.append(f"{path}: samples.t_ms must be non-negative ints")
    elif any(b < a for a, b in zip(t_ms, t_ms[1:])):
        errs.append(f"{path}: samples.t_ms must be non-decreasing")
    series = samples.get("series", {})
    if not isinstance(series, dict) or not series:
        errs.append(f"{path}: samples.series missing or empty")
        series = {}
    for name, vals in series.items():
        if not isinstance(vals, list) or len(vals) != len(t_ms):
            errs.append(f"{path}: series {name!r} length "
                        f"{len(vals) if isinstance(vals, list) else '?'} "
                        f"!= {len(t_ms)} samples")
        elif not all(_is_num(v) for v in vals):
            errs.append(f"{path}: series {name!r} has non-numeric values")

    slo = doc.get("slo", {})
    qos = doc.get("qos", [])
    if not isinstance(slo, dict):
        errs.append(f"{path}: slo must be an object")
        slo = {}
    for qname in (qos if isinstance(qos, list) else []):
        if qname not in slo:
            errs.append(f"{path}: slo table missing QoS class {qname!r}")
    for qname, row in slo.items():
        for field in ("n_completions", "budget_met", "target_budget_met",
                      "p95_slowdown", "target_p95_slowdown",
                      "p95_queue_wait_ms", "target_queue_wait_ms",
                      "alerts_open"):
            if not _is_num(row.get(field)):
                errs.append(f"{path}: slo[{qname!r}].{field} missing or "
                            f"non-numeric")

    tallies: Dict[str, int] = {}
    alerts = doc.get("alerts", [])
    if not isinstance(alerts, list):
        errs.append(f"{path}: alerts must be a list")
        alerts = []
    for i, a in enumerate(alerts):
        where = f"{path}: alerts[{i}]"
        if not isinstance(a, dict):
            errs.append(f"{where}: not an object")
            continue
        kind = a.get("kind")
        if kind not in _KNOWN_KINDS:
            errs.append(f"{where}: unknown kind {kind!r}")
        else:
            tallies[kind] = tallies.get(kind, 0) + 1
        if not isinstance(a.get("scope"), str):
            errs.append(f"{where}: scope must be a string")
        fired = a.get("fired_ms")
        cleared = a.get("cleared_ms")
        if not (_is_int(fired) and fired >= 0):
            errs.append(f"{where}: fired_ms must be a non-negative int")
        if not _is_int(cleared) or (cleared != -1 and (
                not _is_int(fired) or cleared < fired)):
            errs.append(f"{where}: cleared_ms must be -1 (open) or "
                        f">= fired_ms")
        for field in ("value", "threshold"):
            if not _is_num(a.get(field)):
                errs.append(f"{where}: {field} must be numeric")
    by_kind = doc.get("alerts_by_kind", {})
    if by_kind != tallies:
        errs.append(f"{path}: alerts_by_kind {by_kind!r} inconsistent "
                    f"with alerts list (expected {tallies!r})")

    dash = path[:-len(".monitor.json")] + ".dashboard.html" \
        if path.endswith(".monitor.json") else None
    if dash is not None:
        try:
            with open(dash) as f:
                html = f.read()
        except OSError:
            errs.append(f"{path}: sibling dashboard {dash} missing")
        else:
            if DASHBOARD_MARKER not in html:
                errs.append(f"{dash}: missing marker {DASHBOARD_MARKER!r}")
    return errs, tallies


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+",
                    help="monitor.json files or directories to validate")
    ap.add_argument("--require-alert", action="append", default=[],
                    metavar="KIND",
                    help="fail unless >= 1 alert of this kind fired across "
                         "all checked files (repeatable; kinds: "
                         + ", ".join(sorted(_KNOWN_KINDS)) + ")")
    args = ap.parse_args(argv)
    for kind in args.require_alert:
        if kind not in _KNOWN_KINDS:
            ap.error(f"--require-alert {kind!r}: unknown alert kind")
    files = list(_iter_files(args.paths))
    if not files:
        print("check_report: no *.monitor.json files found",
              file=sys.stderr)
        return 2
    failures: List[str] = []
    total: Dict[str, int] = {}
    checked: List[Tuple[str, int]] = []
    for path in files:
        errs, tallies = check_monitor_json(path)
        failures.extend(errs)
        for k, n in tallies.items():
            total[k] = total.get(k, 0) + n
        checked.append((path, len(errs)))
    for path, n in checked:
        print(f"  {'FAIL' if n else 'ok  '} {path}")
    for kind in args.require_alert:
        if total.get(kind, 0) < 1:
            failures.append(f"required alert kind {kind!r} never fired "
                            f"(tallies: {total or '{}'})")
    if failures:
        print(f"\ncheck_report: {len(failures)} problem(s):",
              file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    fired = ", ".join(f"{k}={n}" for k, n in sorted(total.items())) or "none"
    print(f"check_report: {len(checked)} file(s) valid "
          f"(schema v{MONITOR_SCHEMA_VERSION}; alerts: {fired})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

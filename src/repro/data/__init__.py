"""data substrate."""

"""Deterministic synthetic data pipeline.

Shardable by construction: batch ``i`` of host ``h`` is a pure function of
(seed, step, h, i), so any host can regenerate any shard — exactly the
property elastic restarts need (no data-state checkpoint beyond ``step``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 4096
    global_batch: int = 256
    vocab: int = 32_000


def batch_at(cfg: DataConfig, step: int, model_cfg: Optional[ModelConfig] = None
             ) -> Dict[str, np.ndarray]:
    """The full global batch for ``step`` (hosts slice their shard)."""
    rng = np.random.default_rng((cfg.seed, step))
    B, L = cfg.global_batch, cfg.seq_len
    fam = model_cfg.family if model_cfg is not None else "dense"
    if fam == "audio":
        d = model_cfg.frame_dim
        frames = rng.normal(size=(B, L, d)).astype(np.float32)
        labels = rng.integers(0, model_cfg.vocab, (B, L), dtype=np.int32)
        mask = rng.random((B, L)) < 0.08            # HuBERT-style mask rate
        return {"frames": frames, "labels": labels, "mask": mask}
    vocab = model_cfg.vocab if model_cfg is not None else cfg.vocab
    # Zipf-ish marginals + markov-ish structure: cheap but non-degenerate.
    tokens = rng.integers(0, vocab, (B, L), dtype=np.int32)
    out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": tokens}
    if fam == "vlm":
        out["patches"] = rng.normal(
            size=(B, model_cfg.n_patches, model_cfg.patch_dim)
        ).astype(np.float32)
        mask = np.ones((B, L), bool)
        mask[:, :model_cfg.n_patches] = False       # no loss on patch prefix
        out["mask"] = mask
    return out


def stream(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None,
           start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step, model_cfg)
        step += 1

"""ckpt substrate."""

"""ckpt substrate: atomic step-dir checkpoints for params pytrees and
versioned simulation-stream snapshots (see ``checkpoint``)."""
from .checkpoint import (STREAM_SCHEMA_VERSION, latest_step, prune, restore,
                         restore_section, restore_stream, save,
                         save_sections, save_stream)

__all__ = [
    "STREAM_SCHEMA_VERSION",
    "latest_step",
    "prune",
    "restore",
    "restore_section",
    "restore_stream",
    "save",
    "save_sections",
    "save_stream",
]

"""Sharded checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per array leaf (flattened
key path) plus ``manifest.json`` (tree structure, shapes, dtypes, step,
kind).  Writes are atomic (tmp dir + rename), restores can land on a
*different* mesh: arrays are loaded on host and ``device_put`` against
the new shardings — the elastic re-shard path node-failure recovery uses.

Two checkpoint kinds share the scheme:

* ``kind="params"`` — pytree sections (model params / optimizer state),
  written by :func:`save_sections` (or the :func:`save` convenience
  wrapper) and read back section-by-section with
  :func:`restore_section` / :func:`restore`;
* ``kind="stream"`` — a versioned simulation-stream snapshot
  (``BatchSimEngine.snapshot()``: named numpy arrays + one opaque
  residue blob), written by :func:`save_stream` and read back with
  :func:`restore_stream`.  ``STREAM_SCHEMA_VERSION`` gates forward
  compatibility: a restore refuses manifests newer than it understands.

On a real multi-host pod each host would write only its owned shards
(process-local slice of each NamedSharding); the manifest format already
records the source sharding to support that — see DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np

PyTree = Any

# Manifest schema version for ``kind="stream"`` checkpoints.  Bump when
# the array block / residue contract changes; ``restore_stream`` refuses
# manifests newer than this.  v2: chaos residue (attempt/preemption
# counters + injection tallies) — v1 snapshots still restore (benign
# defaults fill the missing keys).  The live SLO monitor needs no
# version of its own: it rides the residue's opaque event-log pickle as
# ``elog.sub`` (repro.obs.monitor), so pre-monitor snapshots restore
# with monitoring simply absent.
STREAM_SCHEMA_VERSION = 2


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _atomic_step_dir(ckpt_dir: str, step: int):
    """(tmp, final) pair for an atomic ``step_<N>`` write: stage into
    ``tmp``, then ``os.rename`` to ``final`` (same filesystem)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=ckpt_dir)
    return tmp, final


def _commit(tmp: str, final: str) -> None:
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def save_sections(ckpt_dir: str, step: int,
                  sections: Mapping[str, Optional[PyTree]],
                  extra: Optional[Dict] = None) -> str:
    """Atomic pytree checkpoint: one named section per pytree (``None``
    sections are skipped).  Returns the final directory."""
    tmp, final = _atomic_step_dir(ckpt_dir, step)
    manifest: Dict[str, Any] = {"step": step, "kind": "params",
                                "extra": extra or {}}
    try:
        for name, tree in sections.items():
            manifest[name] = {}
            if tree is None:
                continue
            for key, leaf in _flatten(tree):
                arr = np.asarray(jax.device_get(leaf))
                fn = f"{name}__{key.replace('/', '__')}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest[name][key] = {"file": fn, "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        _commit(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def save(ckpt_dir: str, step: int, params: PyTree,
         opt: Optional[PyTree] = None, extra: Optional[Dict] = None) -> str:
    """Convenience wrapper: the classic params(+opt) checkpoint."""
    return save_sections(ckpt_dir, step, {"params": params, "opt": opt},
                         extra=extra)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_section(ckpt_dir: str, step: Optional[int], template: PyTree,
                    shardings: Optional[PyTree] = None,
                    section: str = "params") -> Tuple[PyTree, int]:
    """Restore ``section`` onto ``template``'s tree structure.

    ``shardings`` (optional pytree of NamedSharding, possibly for a mesh
    *different* from the one that wrote the checkpoint) re-shards on load —
    elastic restart across mesh changes.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = _flatten(template)
    sh_flat = _flatten(shardings) if shardings is not None else None
    out = []
    for i, (key, leaf) in enumerate(flat):
        meta = manifest[section][key]
        arr = np.load(os.path.join(d, meta["file"]))
        want = tuple(np.shape(leaf))
        if want != arr.shape:
            raise ValueError(
                f"checkpoint {section}/{key} has shape {arr.shape}, "
                f"template expects {want} — a re-shard may change the "
                "mesh, never the array shapes")
        if sh_flat is not None:
            arr = jax.device_put(arr, sh_flat[i][1])
        out.append(arr)
    _, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, out), step


# Back-compat alias (the pre-generalization public name).
restore = restore_section


# ---------------------------------------------------------------------------
# Stream snapshots (kind="stream")
# ---------------------------------------------------------------------------


def save_stream(ckpt_dir: str, step: int, snap: Mapping[str, Any],
                meta: Optional[Dict] = None) -> str:
    """Atomic write of a simulation-stream snapshot.

    ``snap`` is the ``{"arrays", "residue", "version", ...}`` dict the
    engines produce (``SimState.snapshot`` / ``BatchSimEngine.snapshot``):
    each named numpy array lands as its own ``.npy``; the opaque
    ``residue`` bytes land as ``residue.pkl``; ``meta`` (scenario name,
    partial rows, …) round-trips through the manifest as JSON.
    """
    tmp, final = _atomic_step_dir(ckpt_dir, step)
    manifest: Dict[str, Any] = {
        "step": step,
        "kind": "stream",
        "stream_version": int(snap.get("version", STREAM_SCHEMA_VERSION)),
        "n_members": snap.get("n_members"),
        "arrays": {},
        "meta": meta or {},
    }
    try:
        for name, arr in snap["arrays"].items():
            arr = np.asarray(arr)
            fn = "arr__" + name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["arrays"][name] = {"file": fn,
                                        "shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "residue.pkl"), "wb") as f:
            f.write(snap["residue"])
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        _commit(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def restore_stream(ckpt_dir: str, step: Optional[int] = None
                   ) -> Tuple[Dict[str, Any], int, Dict]:
    """Load a stream snapshot → ``(snap, step, meta)``.

    ``snap`` has the exact shape the engines' ``load_snapshot`` expects.
    Refuses manifests written by a newer schema, and refuses
    ``kind="params"`` directories loudly rather than mis-parsing them.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    kind = manifest.get("kind", "params")
    if kind != "stream":
        raise ValueError(f"{d} is a {kind!r} checkpoint, not a stream "
                         "snapshot (use restore_section)")
    version = int(manifest.get("stream_version", 1))
    if version > STREAM_SCHEMA_VERSION:
        raise ValueError(
            f"stream snapshot schema v{version} is newer than supported "
            f"v{STREAM_SCHEMA_VERSION} — upgrade before resuming")
    arrays = {name: np.load(os.path.join(d, meta["file"]))
              for name, meta in manifest["arrays"].items()}
    with open(os.path.join(d, "residue.pkl"), "rb") as f:
        residue = f.read()
    snap: Dict[str, Any] = {"arrays": arrays, "residue": residue,
                            "version": version}
    if manifest.get("n_members") is not None:
        snap["n_members"] = manifest["n_members"]
    return snap, step, manifest.get("meta", {})


def prune(ckpt_dir: str, keep: int = 3) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)

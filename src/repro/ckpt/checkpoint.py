"""Sharded checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per parameter leaf (flattened
key path) plus ``manifest.json`` (tree structure, shapes, dtypes, step,
mesh descriptor).  Writes are atomic (tmp dir + rename), restores can land
on a *different* mesh: arrays are loaded on host and ``device_put`` against
the new shardings — the elastic re-shard path node-failure recovery uses.

On a real multi-host pod each host would write only its owned shards
(process-local slice of each NamedSharding); the manifest format already
records the source sharding to support that — see DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, params: PyTree,
         opt: Optional[PyTree] = None, extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=ckpt_dir)
    manifest: Dict[str, Any] = {"step": step, "params": {}, "opt": {},
                                "extra": extra or {}}
    try:
        for name, tree in (("params", params), ("opt", opt)):
            if tree is None:
                continue
            for key, leaf in _flatten(tree):
                arr = np.asarray(jax.device_get(leaf))
                fn = f"{name}__{key.replace('/', '__')}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest[name][key] = {"file": fn, "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int], template: PyTree,
            shardings: Optional[PyTree] = None, section: str = "params"
            ) -> Tuple[PyTree, int]:
    """Restore ``section`` onto ``template``'s tree structure.

    ``shardings`` (optional pytree of NamedSharding, possibly for a mesh
    *different* from the one that wrote the checkpoint) re-shards on load —
    elastic restart across mesh changes.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = _flatten(template)
    sh_flat = _flatten(shardings) if shardings is not None else None
    out = []
    for i, (key, leaf) in enumerate(flat):
        meta = manifest[section][key]
        arr = np.load(os.path.join(d, meta["file"]))
        if sh_flat is not None:
            arr = jax.device_put(arr, sh_flat[i][1])
        out.append(arr)
    _, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, out), step


def prune(ckpt_dir: str, keep: int = 3) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)

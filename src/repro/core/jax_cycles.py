"""Batched scheduling cycles: Algorithm 2 as a JAX computation.

The sequential reference processes the ready queue task-by-task, scoring
every idle VM per task (O(T·V) Python).  This module scores ALL pairs at
once with the affinity kernel (jnp oracle or the Pallas kernel) and
resolves VM conflicts with an auction: every unplaced task picks its best
VM; the earliest task in queue order wins each VM; losers retry against
the shrunken pool.  Because pair scores are static within a cycle (caches
only change when pipelines start), the fixed point equals the sequential
outcome exactly — property-tested in tests/test_jax_cycles.py.

Tier encoding per (task, VM): 0 = out of scope (busy/wrong owner),
1 = all inputs cached, 2 = container active, 3 = idle.  Provisioning
(tier 4/5) can't conflict and stays in the per-task fallback.

Pair arrays are built from the :class:`~repro.sim.cloud.VMPool`
live-state registry, not from per-VM Python calls: VM-type attributes
are vmid-indexed gathers, container-delay vectors come from the pool's
incremental ``app_image`` / ``app_active`` sets, and sharing-scope masks
from ``tag_members`` — each computed once per distinct app/tag per
cycle.  Auction rounds write into resident padded ``[B, T, V]`` buffers
(:class:`_RoundBuffers`) instead of re-allocating pad+stack copies, so
the vmapped kernel call pays no per-round host rebuild cost.

Two drivers consume the auction:

* :func:`batched_cycle` — one simulation's cycle (used by ``SimEngine``
  when the queue×pool product is large);
* :func:`multi_cycle` — many independent simulations' cycles at once
  (used by ``core.jax_engine.BatchSimEngine``): each round stacks every
  active member's proposal into one ``[B, T, V]`` tensor and scores it
  with a single vmapped kernel call.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..kernels.affinity import ops as aff_ops
from ..sim.cloud import VM, VMPool
from .scheduler import Placement, Policy, select
from .types import PlatformConfig, Task


def build_pair_arrays(cfg: PlatformConfig, policy: Policy,
                      tasks: Sequence[Tuple[Task, str, object, List]],
                      vms: Sequence[VM],
                      pool: VMPool):
    """tasks: [(task, app, owner_tag, inputs)] in queue order; ``vms`` are
    idle VMs in ascending-vmid order (the auction's column space)."""
    T, V = len(tasks), len(vms)
    size = np.empty(T, np.float32)
    out_mb = np.empty(T, np.float32)
    budget = np.empty(T, np.float32)
    missing = np.zeros((T, V), np.float32)
    cont = np.zeros((T, V), np.float32)
    tier = np.zeros((T, V), np.int32)

    ids = np.fromiter((vm.vmid for vm in vms), np.int64, V)
    vm_ids = {vmid: j for j, vmid in enumerate(ids.tolist())}
    # vmid-indexed gathers from the pool's static per-VM attribute arrays.
    mips = pool.mips[ids]
    bw = pool.bandwidth[ids]
    price = pool.price[ids]

    # Per-(vm, app) container state from the pool's incremental app
    # indexes — O(|holders|) per distinct app, no per-VM Python calls.
    cont_by_app = {}
    for app in {app for _, app, _, _ in tasks}:
        is_active = np.zeros(V, bool)
        if not policy.use_containers:
            cvec = np.zeros(V, np.float32)
        else:
            cvec = np.full(V, cfg.container_provision_ms, np.float32)
            for vid in pool.app_image.get(app, ()):
                j = vm_ids.get(vid)
                if j is not None:
                    cvec[j] = cfg.container_init_ms
            for vid in pool.app_active.get(app, ()):
                j = vm_ids.get(vid)
                if j is not None:
                    cvec[j] = 0.0
                    is_active[j] = True
        cont_by_app[app] = (cvec, is_active)

    # Sharing-scope masks, one per distinct owner tag this cycle.
    scope_by_tag = {}
    for tag in {tag for _, _, tag, _ in tasks}:
        s = np.zeros(V, bool)
        for vid in pool.tag_members.get(tag, ()):
            j = vm_ids.get(vid)
            if j is not None:
                s[j] = True
        scope_by_tag[tag] = s

    data_index = pool.data_index
    for i, (task, app, tag, inputs) in enumerate(tasks):
        size[i] = task.size_mi
        out_mb[i] = task.out_mb
        budget[i] = task.budget
        scope = scope_by_tag[tag]
        cvec, is_active = cont_by_app[app]
        cont[i] = cvec
        if policy.locality_tiers:
            have_all = scope.copy()
            miss = np.zeros(V, np.float32)
            for key, mb in inputs:
                holders = data_index.get(key, ())
                hold = np.zeros(V, bool)
                for vid in holders:
                    j = vm_ids.get(vid)
                    if j is not None:
                        hold[j] = True
                miss += np.where(hold, 0.0, mb)
                if mb > 0:
                    have_all &= hold
            missing[i] = miss
            t = np.where(have_all, 1,
                         np.where(is_active & policy.use_containers, 2, 3))
        else:
            missing[i] = sum(mb for _, mb in inputs)
            t = np.full(V, 3, np.int32)
        tier[i] = np.where(scope, t, 0)
    return (size, out_mb, budget, missing, cont, tier, mips, bw, price)


def _p2(n: int) -> int:
    """Next power of two ≥ max(n, 2) — shape buckets so the jitted kernel
    is reused across cycles instead of recompiling per shape (padding
    rows/cols are tier-0 ⇒ infeasible ⇒ inert)."""
    return 1 << max(n - 1, 1).bit_length()


class _RoundBuffers:
    """Resident padded pair buffers for auction rounds.

    One ``(Bp, Tp, Vp)`` bucket's arrays stay allocated across rounds,
    cycles and simulations; a round resets them (cheap memsets to the
    inert padding values) and each active member writes its rows in
    place.  This replaces the per-round pad-and-stack allocation storm
    the vmapped kernel call used to pay.

    The cache is thread-local (each thread driving engines gets its own
    buffers — rounds from concurrent runs never interleave on shared
    arrays) and only buckets up to ``MAX_RESIDENT_ELEMS`` pair elements
    stay resident; paper-scale outliers allocate fresh per round rather
    than pinning hundreds of MB at module scope.
    """

    __slots__ = ("key", "bufs")

    # Largest B·T·V bucket kept alive between rounds (~4M pair elements
    # ⇒ ≲50 MB across the six [B,T,V] arrays).
    MAX_RESIDENT_ELEMS = 1 << 22

    def __init__(self):
        self.key = None
        self.bufs = None

    def get(self, Bp: int, Tp: int, Vp: int):
        if self.key == (Bp, Tp, Vp):
            size, out_mb, budget, missing, cont, tier, mips, bw, price = \
                self.bufs
            size.fill(0.0)
            out_mb.fill(0.0)
            budget.fill(-1.0)
            missing.fill(0.0)
            cont.fill(0.0)
            tier.fill(0)
            mips.fill(1.0)
            bw.fill(1.0)
            price.fill(1.0)
            return self.bufs
        bufs = (
            np.zeros((Bp, Tp), np.float32),        # size
            np.zeros((Bp, Tp), np.float32),        # out_mb
            np.full((Bp, Tp), -1.0, np.float32),   # budget (inert: -1)
            np.zeros((Bp, Tp, Vp), np.float32),    # missing
            np.zeros((Bp, Tp, Vp), np.float32),    # cont
            np.zeros((Bp, Tp, Vp), np.int32),      # tier (inert: 0)
            np.ones((Bp, Vp), np.float32),         # mips (no div-by-zero)
            np.ones((Bp, Vp), np.float32),         # bw
            np.ones((Bp, Vp), np.float32),         # price
        )
        if Bp * Tp * Vp <= self.MAX_RESIDENT_ELEMS:
            self.key, self.bufs = (Bp, Tp, Vp), bufs
        # else: one-shot buffers — leave any cached smaller bucket intact.
        return bufs


class _ThreadLocalBuffers(threading.local):
    def __init__(self):
        self.rb = _RoundBuffers()


_ROUND_BUFFERS = _ThreadLocalBuffers()


class CycleRequest:
    """One simulation's auction state inside a (possibly multi-sim) cycle.

    Owns the pair arrays, the queue-order task list, the availability
    mask, and the serial-dictatorship commit rule.  ``multi_cycle`` only
    orchestrates rounds; all per-simulation semantics live here.
    """

    def __init__(self, cfg: PlatformConfig, policy: Policy,
                 tasks, vms: Sequence[VM], pool: VMPool):
        self.cfg = cfg
        self.policy = policy
        self.tasks = list(tasks)
        self.vms = list(vms)
        T, V = len(tasks), len(vms)
        self.T, self.V = T, V
        self.col = {vm.vmid: j for j, vm in enumerate(self.vms)}
        self.placements: List[Optional[Placement]] = [None] * T
        self.unplaced: List[int] = list(range(T)) if V else []
        self.avail = np.ones(V, bool)
        self.stalled = False
        if T and V:
            (self.size, self.out_mb, self.budget, self.missing, self.cont,
             self.tier, self.mips, self.bw, self.price) = build_pair_arrays(
                cfg, policy, tasks, vms, pool)

    @property
    def active(self) -> bool:
        return bool(self.unplaced) and bool(self.avail.any()) \
            and not self.stalled

    def propose_into(self, bufs, b: int) -> None:
        """Write this member's current unplaced rows into batch row ``b``
        of the shared resident buffers (already reset to inert padding)."""
        size, out_mb, budget, missing, cont, tier, mips, bw, price = bufs
        sel = self.unplaced
        Tr, V = len(sel), self.V
        size[b, :Tr] = self.size[sel]
        out_mb[b, :Tr] = self.out_mb[sel]
        budget[b, :Tr] = self.budget[sel]
        missing[b, :Tr, :V] = self.missing[sel]
        cont[b, :Tr, :V] = self.cont[sel]
        tier[b, :Tr, :V] = self.tier[sel] * self.avail[None, :]
        mips[b, :V] = self.mips
        bw[b, :V] = self.bw
        price[b, :V] = self.price

    def _resolve_infeasible(self, ti: int) -> Placement:
        """Sequential tier-4/5 resolution for a task the kernel found no
        in-budget VM for, evaluated against the auction's *current*
        availability set — the same ``select`` call, at the same point in
        the serial order, the sequential reference makes.  Insufficient-
        budget cycles therefore produce the reference interleaving even
        when the tier-5 rule reuses (and thereby consumes) an idle VM."""
        task, app, tag, inputs = self.tasks[ti]
        pool = [vm for j, vm in enumerate(self.vms) if self.avail[j]]
        return select(self.cfg, self.policy, task, -1, app, inputs,
                      task.budget, pool, owner_tag=tag)

    def commit(self, best, tiers, fins, costs_) -> None:
        """Serial-dictatorship prefix commit: the winner of each VM is its
        earliest claimant, and only winners EARLIER than the first loser
        commit this round.  A later round-1 winner could otherwise steal
        the VM an earlier loser takes next — exactly the interleaving
        the sequential reference produces.

        Tasks with no feasible VM (best < 0) resolve *in serial position*
        through :meth:`_resolve_infeasible` — the insufficient-budget
        tier-5 rule may take an idle VM, in which case every later task
        this round is deferred (``halted``) and re-auctions against the
        shrunken pool, exactly as the sequential reference would see it."""
        claims: dict = {}
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if j >= 0 and j not in claims:
                claims[j] = ti
        losers = [ti for row, ti in enumerate(self.unplaced)
                  if int(best[row]) >= 0 and claims[int(best[row])] != ti]
        first_loser = min(losers) if losers else None
        next_unplaced = []
        committed = False
        halted = False
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if halted or (first_loser is not None and ti > first_loser):
                next_unplaced.append(ti)
                continue
            if j < 0:
                p = self._resolve_infeasible(ti)
                self.placements[ti] = p
                committed = True
                if p.vm is not None:
                    # Tier-5 reuse consumed a VM the kernel scored as
                    # infeasible; later tasks must re-auction without it.
                    self.avail[self.col[p.vm.vmid]] = False
                    halted = True
                continue
            if claims[j] == ti:
                self.placements[ti] = Placement(
                    self.vms[j], None, int(tiers[row]),
                    int(fins[row]), float(costs_[row]))
                self.avail[j] = False
                committed = True
            else:
                next_unplaced.append(ti)
        self.unplaced = next_unplaced
        self.stalled = not committed


def multi_cycle(cfg: PlatformConfig, requests: Sequence[CycleRequest],
                use_pallas: bool = False
                ) -> List[List[Optional[Placement]]]:
    """Run every request's auction to its fixed point, scoring all active
    members' rounds with ONE batched kernel call per round.

    Members are independent simulations, so rounds interleave freely; a
    member drops out as soon as it has no unplaced task, no available VM,
    or a round commits nothing.  Rounds fill the resident power-of-two
    ``(B, T, V)`` buffers (``_RoundBuffers``) so the vmapped kernel
    recompiles per bucket, not per round, and allocates nothing per call.
    """
    while True:
        active = [r for r in requests if r.active]
        if not active:
            break
        Tp = max(_p2(len(r.unplaced)) for r in active)
        Vp = max(_p2(r.V) for r in active)
        # Batch dim rounds to 1, 2, 4, … (a solo auction stays unpadded);
        # rows beyond the active members keep the inert padding.
        Bp = 1 << max(len(active) - 1, 0).bit_length()
        bufs = _ROUND_BUFFERS.rb.get(Bp, Tp, Vp)
        for b, r in enumerate(active):
            r.propose_into(bufs, b)
        res = aff_ops.affinity_batch(
            *bufs,
            gs_read=cfg.gs_read_mbps, gs_write=cfg.gs_write_mbps,
            bp_ms=float(cfg.billing_period_ms), use_pallas=use_pallas)
        best = np.asarray(res.best_vm)
        tiers = np.asarray(res.best_tier)
        fins = np.asarray(res.est_finish)
        costs_ = np.asarray(res.est_cost)
        for b, r in enumerate(active):
            r.commit(best[b], tiers[b], fins[b], costs_[b])
    return [r.placements for r in requests]


def batched_cycle(cfg: PlatformConfig, policy: Policy,
                  tasks, vms: Sequence[VM], pool: VMPool,
                  use_pallas: bool = False
                  ) -> List[Optional[Placement]]:
    """Returns, per task (queue order), a reuse Placement or None (task
    needs the provisioning fallback)."""
    if not tasks:
        return []
    if not vms:
        return [None] * len(tasks)
    req = CycleRequest(cfg, policy, tasks, vms, pool)
    return multi_cycle(cfg, [req], use_pallas=use_pallas)[0]

"""Batched scheduling cycles: Algorithm 2 as a JAX computation.

The sequential reference processes the ready queue task-by-task, scoring
every idle VM per task (O(T·V) Python).  This module scores ALL pairs at
once with the affinity kernel (jnp oracle or the Pallas kernel) and
resolves VM conflicts with an auction: every unplaced task picks its best
VM; the earliest task in queue order wins each VM; losers retry against
the shrunken pool.  Because pair scores are static within a cycle (caches
only change when pipelines start), the fixed point equals the sequential
outcome exactly — property-tested in tests/test_jax_cycles.py.

Tier encoding per (task, VM): 0 = out of scope (busy/wrong owner),
1 = all inputs cached, 2 = container active, 3 = idle.  Provisioning
(tier 4/5) can't conflict and stays in the per-task fallback.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels.affinity import ops as aff_ops
from ..sim.cloud import VM, VM_IDLE, DataKey
from .scheduler import Placement, Policy
from .types import PlatformConfig, Task


def build_pair_arrays(cfg: PlatformConfig, policy: Policy,
                      tasks: Sequence[Tuple[Task, str, object, List]],
                      vms: Sequence[VM],
                      data_index: Dict[DataKey, set]):
    """tasks: [(task, app, owner_tag, inputs)] in queue order."""
    T, V = len(tasks), len(vms)
    size = np.empty(T, np.float32)
    out_mb = np.empty(T, np.float32)
    budget = np.empty(T, np.float32)
    missing = np.zeros((T, V), np.float32)
    cont = np.zeros((T, V), np.float32)
    tier = np.zeros((T, V), np.int32)

    vm_ids = {vm.vmid: j for j, vm in enumerate(vms)}
    mips = np.array([vm.vmt.mips for vm in vms], np.float32)
    bw = np.array([vm.vmt.bandwidth_mbps for vm in vms], np.float32)
    price = np.array([vm.vmt.cost_per_bp for vm in vms], np.float32)

    # Per-(vm, app) container state, computed once per distinct app.
    apps = sorted({app for _, app, _, _ in tasks})
    cont_by_app = {}
    active = np.array([hash(vm.active_container) if vm.active_container
                       else 0 for vm in vms])
    for app in apps:
        cvec = np.array([vm.container_ms(cfg, app, policy.use_containers)
                         for vm in vms], np.float32)
        is_active = np.array([vm.active_container == app for vm in vms],
                             dtype=bool)
        cont_by_app[app] = (cvec, is_active)

    for i, (task, app, tag, inputs) in enumerate(tasks):
        size[i] = task.size_mi
        out_mb[i] = task.out_mb
        budget[i] = task.budget
        scope = np.array([vm.owner_tag == tag for vm in vms], dtype=bool)
        cvec, is_active = cont_by_app[app]
        cont[i] = cvec
        if policy.locality_tiers:
            have_all = scope.copy()
            miss = np.zeros(V, np.float32)
            for key, mb in inputs:
                holders = data_index.get(key, ())
                hold = np.zeros(V, bool)
                for vid in holders:
                    j = vm_ids.get(vid)
                    if j is not None:
                        hold[j] = True
                miss += np.where(hold, 0.0, mb)
                if mb > 0:
                    have_all &= hold
            missing[i] = miss
            t = np.where(have_all, 1,
                         np.where(is_active & policy.use_containers, 2, 3))
        else:
            missing[i] = sum(mb for _, mb in inputs)
            t = np.full(V, 3, np.int32)
        tier[i] = np.where(scope, t, 0)
    return (size, out_mb, budget, missing, cont, tier, mips, bw, price)


def batched_cycle(cfg: PlatformConfig, policy: Policy,
                  tasks, vms: Sequence[VM], data_index,
                  use_pallas: bool = False
                  ) -> List[Optional[Placement]]:
    """Returns, per task (queue order), a reuse Placement or None (task
    needs the provisioning fallback)."""
    if not tasks:
        return []
    if not vms:
        return [None] * len(tasks)
    arrays = build_pair_arrays(cfg, policy, tasks, vms, data_index)
    size, out_mb, budget, missing, cont, tier, mips, bw, price = arrays
    T, V = tier.shape
    placements: List[Optional[Placement]] = [None] * T
    unplaced = list(range(T))
    avail = np.ones(V, bool)

    # Pad (T, V) to power-of-two buckets so the jitted kernel is reused
    # across cycles instead of recompiling per shape (padding rows/cols
    # are tier-0 ⇒ infeasible ⇒ inert).
    def p2(n: int) -> int:
        return 1 << max(n - 1, 1).bit_length()

    Vp = p2(V)
    missing_p, cont_p, tier_p = (np.pad(missing, ((0, 0), (0, Vp - V))),
                                 np.pad(cont, ((0, 0), (0, Vp - V))),
                                 np.pad(tier, ((0, 0), (0, Vp - V))))
    mips_p = np.pad(mips, (0, Vp - V), constant_values=1.0)
    bw_p = np.pad(bw, (0, Vp - V), constant_values=1.0)
    price_p = np.pad(price, (0, Vp - V), constant_values=1.0)

    while unplaced and avail.any():
        Tr = len(unplaced)
        Tp = p2(Tr)
        pr = (0, Tp - Tr)
        avail_p = np.pad(avail, (0, Vp - V))
        t_eff = np.pad(tier_p[unplaced] * avail_p[None, :].astype(np.int32),
                       (pr, (0, 0)))
        res = aff_ops.affinity(
            np.pad(size[unplaced], pr), np.pad(out_mb[unplaced], pr),
            np.pad(budget[unplaced], pr, constant_values=-1.0),
            np.pad(missing_p[unplaced], (pr, (0, 0))),
            np.pad(cont_p[unplaced], (pr, (0, 0))), t_eff,
            mips_p, bw_p, price_p,
            gs_read=cfg.gs_read_mbps, gs_write=cfg.gs_write_mbps,
            bp_ms=float(cfg.billing_period_ms), use_pallas=use_pallas)
        best = np.asarray(res.best_vm)[:Tr]
        tiers = np.asarray(res.best_tier)[:Tr]
        fins = np.asarray(res.est_finish)[:Tr]
        costs_ = np.asarray(res.est_cost)[:Tr]

        # Serial-dictatorship prefix commit: the winner of each VM is its
        # earliest claimant, and only winners EARLIER than the first loser
        # commit this round.  A later round-1 winner could otherwise steal
        # the VM an earlier loser takes next — exactly the interleaving
        # the sequential reference produces.  Tasks with no feasible VM
        # (best < 0) resolve immediately: their availability set is a
        # superset of the sequential one (only earlier tasks have
        # committed), so sequential would provision too.
        claims: dict = {}
        for row, ti in enumerate(unplaced):
            j = int(best[row])
            if j >= 0 and j not in claims:
                claims[j] = ti
        losers = [ti for row, ti in enumerate(unplaced)
                  if int(best[row]) >= 0 and claims[int(best[row])] != ti]
        first_loser = min(losers) if losers else None
        next_unplaced = []
        committed = False
        for row, ti in enumerate(unplaced):
            j = int(best[row])
            if j < 0:
                continue  # provisioning fallback (final)
            if claims[j] == ti and (first_loser is None or ti < first_loser):
                placements[ti] = Placement(vms[j], None, int(tiers[row]),
                                           int(fins[row]), float(costs_[row]))
                avail[j] = False
                committed = True
            else:
                next_unplaced.append(ti)
        unplaced = next_unplaced
        if not committed:
            break
    return placements

"""Batched scheduling cycles: Algorithm 2 as a JAX computation.

The sequential reference processes the ready queue task-by-task, scoring
every idle VM per task (O(T·V) Python).  This module scores ALL pairs at
once with the affinity kernel (jnp oracle or the Pallas kernel) and
resolves VM conflicts with an auction: every unplaced task picks its best
VM; the earliest task in queue order wins each VM; losers retry against
the shrunken pool.  Because pair scores are static within a cycle (caches
only change when pipelines start), the fixed point equals the sequential
outcome exactly — property-tested in tests/test_jax_cycles.py.

Tier encoding per (task, VM): 0 = out of scope (busy/wrong owner),
1 = all inputs cached, 2 = container active, 3 = idle.  Provisioning
(tier 4/5) can't conflict and stays in the per-task fallback.

Two drivers consume the auction:

* :func:`batched_cycle` — one simulation's cycle (used by ``SimEngine``
  when the queue×pool product is large);
* :func:`multi_cycle` — many independent simulations' cycles at once
  (used by ``core.jax_engine.BatchSimEngine``): each round stacks every
  active member's proposal into one ``[B, T, V]`` tensor and scores it
  with a single vmapped kernel call.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..kernels.affinity import ops as aff_ops
from ..sim.cloud import VM, VM_IDLE, DataKey
from .scheduler import Placement, Policy
from .types import PlatformConfig, Task


def build_pair_arrays(cfg: PlatformConfig, policy: Policy,
                      tasks: Sequence[Tuple[Task, str, object, List]],
                      vms: Sequence[VM],
                      data_index: Dict[DataKey, set]):
    """tasks: [(task, app, owner_tag, inputs)] in queue order."""
    T, V = len(tasks), len(vms)
    size = np.empty(T, np.float32)
    out_mb = np.empty(T, np.float32)
    budget = np.empty(T, np.float32)
    missing = np.zeros((T, V), np.float32)
    cont = np.zeros((T, V), np.float32)
    tier = np.zeros((T, V), np.int32)

    vm_ids = {vm.vmid: j for j, vm in enumerate(vms)}
    mips = np.array([vm.vmt.mips for vm in vms], np.float32)
    bw = np.array([vm.vmt.bandwidth_mbps for vm in vms], np.float32)
    price = np.array([vm.vmt.cost_per_bp for vm in vms], np.float32)

    # Per-(vm, app) container state, computed once per distinct app.
    apps = sorted({app for _, app, _, _ in tasks})
    cont_by_app = {}
    for app in apps:
        cvec = np.array([vm.container_ms(cfg, app, policy.use_containers)
                         for vm in vms], np.float32)
        is_active = np.array([vm.active_container == app for vm in vms],
                             dtype=bool)
        cont_by_app[app] = (cvec, is_active)

    for i, (task, app, tag, inputs) in enumerate(tasks):
        size[i] = task.size_mi
        out_mb[i] = task.out_mb
        budget[i] = task.budget
        scope = np.array([vm.owner_tag == tag for vm in vms], dtype=bool)
        cvec, is_active = cont_by_app[app]
        cont[i] = cvec
        if policy.locality_tiers:
            have_all = scope.copy()
            miss = np.zeros(V, np.float32)
            for key, mb in inputs:
                holders = data_index.get(key, ())
                hold = np.zeros(V, bool)
                for vid in holders:
                    j = vm_ids.get(vid)
                    if j is not None:
                        hold[j] = True
                miss += np.where(hold, 0.0, mb)
                if mb > 0:
                    have_all &= hold
            missing[i] = miss
            t = np.where(have_all, 1,
                         np.where(is_active & policy.use_containers, 2, 3))
        else:
            missing[i] = sum(mb for _, mb in inputs)
            t = np.full(V, 3, np.int32)
        tier[i] = np.where(scope, t, 0)
    return (size, out_mb, budget, missing, cont, tier, mips, bw, price)


def _p2(n: int) -> int:
    """Next power of two ≥ max(n, 2) — shape buckets so the jitted kernel
    is reused across cycles instead of recompiling per shape (padding
    rows/cols are tier-0 ⇒ infeasible ⇒ inert)."""
    return 1 << max(n - 1, 1).bit_length()


class CycleRequest:
    """One simulation's auction state inside a (possibly multi-sim) cycle.

    Owns the pair arrays, the queue-order task list, the availability
    mask, and the serial-dictatorship commit rule.  ``multi_cycle`` only
    orchestrates rounds; all per-simulation semantics live here.
    """

    def __init__(self, cfg: PlatformConfig, policy: Policy,
                 tasks, vms: Sequence[VM],
                 data_index: Dict[DataKey, set]):
        self.vms = list(vms)
        T, V = len(tasks), len(vms)
        self.T, self.V = T, V
        self.placements: List[Optional[Placement]] = [None] * T
        self.unplaced: List[int] = list(range(T)) if V else []
        self.avail = np.ones(V, bool)
        self.stalled = False
        if T and V:
            (self.size, self.out_mb, self.budget, self.missing, self.cont,
             self.tier, self.mips, self.bw, self.price) = build_pair_arrays(
                cfg, policy, tasks, vms, data_index)

    @property
    def active(self) -> bool:
        return bool(self.unplaced) and bool(self.avail.any()) \
            and not self.stalled

    def propose(self, Tp: int, Vp: int):
        """Pad this member's current unplaced rows into the shared
        ``(Tp, Vp)`` bucket.  Padding is inert: tier 0, budget −1,
        mips/bw/price 1 (no div-by-zero)."""
        sel = self.unplaced
        Tr, V = len(sel), self.V
        pr = (0, Tp - Tr)
        pc = (0, Vp - V)
        avail_p = np.pad(self.avail, pc)
        t_eff = np.pad(
            np.pad(self.tier[sel], ((0, 0), pc))
            * avail_p[None, :].astype(np.int32),
            (pr, (0, 0)))
        return (np.pad(self.size[sel], pr),
                np.pad(self.out_mb[sel], pr),
                np.pad(self.budget[sel], pr, constant_values=-1.0),
                np.pad(self.missing[sel], (pr, pc)),
                np.pad(self.cont[sel], (pr, pc)),
                t_eff,
                np.pad(self.mips, pc, constant_values=1.0),
                np.pad(self.bw, pc, constant_values=1.0),
                np.pad(self.price, pc, constant_values=1.0))

    def commit(self, best, tiers, fins, costs_) -> None:
        """Serial-dictatorship prefix commit: the winner of each VM is its
        earliest claimant, and only winners EARLIER than the first loser
        commit this round.  A later round-1 winner could otherwise steal
        the VM an earlier loser takes next — exactly the interleaving
        the sequential reference produces.  Tasks with no feasible VM
        (best < 0) resolve immediately: their availability set is a
        superset of the sequential one (only earlier tasks have
        committed), so sequential would provision too."""
        claims: dict = {}
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if j >= 0 and j not in claims:
                claims[j] = ti
        losers = [ti for row, ti in enumerate(self.unplaced)
                  if int(best[row]) >= 0 and claims[int(best[row])] != ti]
        first_loser = min(losers) if losers else None
        next_unplaced = []
        committed = False
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if j < 0:
                continue  # provisioning fallback (final)
            if claims[j] == ti and (first_loser is None or ti < first_loser):
                self.placements[ti] = Placement(
                    self.vms[j], None, int(tiers[row]),
                    int(fins[row]), float(costs_[row]))
                self.avail[j] = False
                committed = True
            else:
                next_unplaced.append(ti)
        self.unplaced = next_unplaced
        self.stalled = not committed


def multi_cycle(cfg: PlatformConfig, requests: Sequence[CycleRequest],
                use_pallas: bool = False
                ) -> List[List[Optional[Placement]]]:
    """Run every request's auction to its fixed point, scoring all active
    members' rounds with ONE batched kernel call per round.

    Members are independent simulations, so rounds interleave freely; a
    member drops out as soon as it has no unplaced task, no available VM,
    or a round commits nothing.  The batch is padded to power-of-two
    (B, T, V) buckets so the vmapped kernel recompiles per bucket, not
    per round.
    """
    while True:
        active = [r for r in requests if r.active]
        if not active:
            break
        Tp = max(_p2(len(r.unplaced)) for r in active)
        Vp = max(_p2(r.V) for r in active)
        # Batch dim rounds to 1, 2, 4, … (a solo auction stays unpadded).
        Bp = 1 << max(len(active) - 1, 0).bit_length()
        proposals = [r.propose(Tp, Vp) for r in active]
        # Inert members pad the batch dim: tier-0 rows place nothing.
        while len(proposals) < Bp:
            proposals.append((
                np.zeros(Tp, np.float32), np.zeros(Tp, np.float32),
                np.full(Tp, -1.0, np.float32), np.zeros((Tp, Vp), np.float32),
                np.zeros((Tp, Vp), np.float32), np.zeros((Tp, Vp), np.int32),
                np.ones(Vp, np.float32), np.ones(Vp, np.float32),
                np.ones(Vp, np.float32)))
        stacked = [np.stack(cols) for cols in zip(*proposals)]
        res = aff_ops.affinity_batch(
            *stacked,
            gs_read=cfg.gs_read_mbps, gs_write=cfg.gs_write_mbps,
            bp_ms=float(cfg.billing_period_ms), use_pallas=use_pallas)
        best = np.asarray(res.best_vm)
        tiers = np.asarray(res.best_tier)
        fins = np.asarray(res.est_finish)
        costs_ = np.asarray(res.est_cost)
        for b, r in enumerate(active):
            r.commit(best[b], tiers[b], fins[b], costs_[b])
    return [r.placements for r in requests]


def batched_cycle(cfg: PlatformConfig, policy: Policy,
                  tasks, vms: Sequence[VM], data_index,
                  use_pallas: bool = False
                  ) -> List[Optional[Placement]]:
    """Returns, per task (queue order), a reuse Placement or None (task
    needs the provisioning fallback)."""
    if not tasks:
        return []
    if not vms:
        return [None] * len(tasks)
    req = CycleRequest(cfg, policy, tasks, vms, data_index)
    return multi_cycle(cfg, [req], use_pallas=use_pallas)[0]

"""Batched scheduling cycles: Algorithm 2 as a JAX computation.

The sequential reference processes the ready queue task-by-task, scoring
every idle VM per task (O(T·V) Python).  This module scores ALL pairs at
once with the affinity kernel (jnp oracle or the Pallas kernel) and
resolves VM conflicts with an auction: every unplaced task picks its best
VM; the earliest task in queue order wins each VM; losers retry against
the shrunken pool.  Because pair scores are static within a cycle (caches
only change when pipelines start), the fixed point equals the sequential
outcome exactly — property-tested in tests/test_jax_cycles.py.

Tier encoding per (task, VM): 0 = out of scope (busy/wrong owner),
1 = all inputs cached, 2 = container active, 3 = idle.  Provisioning
(tier 4/5) can't conflict and stays in the per-task fallback.

Pair arrays are built from the :class:`~repro.sim.cloud.VMPool`
live-state registry, not from per-VM Python calls: VM-type attributes
are vmid-indexed gathers, container-delay vectors come from the pool's
incremental ``app_image`` / ``app_active`` sets, and sharing-scope masks
from ``tag_members`` — each computed once per distinct app/tag per
cycle.  Auction rounds write into resident padded ``[B, T, V]`` buffers
(:class:`_RoundBuffers`) instead of re-allocating pad+stack copies, so
the vmapped kernel call pays no per-round host rebuild cost.

Two drivers consume the auction:

* :func:`batched_cycle` — one simulation's cycle (used by ``SimEngine``
  when the queue×pool product is large);
* :func:`multi_cycle` — many independent simulations' cycles at once
  (used by ``core.jax_engine.BatchSimEngine``): each round stacks every
  active member's proposal into one ``[B, T, V]`` tensor and scores it
  with a single vmapped kernel call.

Tuning knobs (see the README "Tuning knobs" table): ``AUCTION_TAIL_PAIRS``
(=192) drains a member's auction tail through per-task ``select`` once
its remaining queue×pool product drops below it — identical outcomes
(the fixed point *is* the sequential interleaving), it just stops paying
per-round kernel dispatch for a handful of pairs.  The thresholds that
decide whether a cycle rides this module at all
(``AUCTION_MIN_PAIRS_ROUND``, legacy ``AUCTION_MIN_PAIRS_GRID``) live in
``core.jax_engine``.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..kernels.affinity import ops as aff_ops
from ..sim.cloud import VM, VMPool
from .scheduler import Placement, Policy, select
from .types import PlatformConfig, Task


def build_pair_arrays(cfg: PlatformConfig, policy: Policy,
                      tasks: Sequence[Tuple[Task, str, object, List]],
                      vms: Sequence[VM],
                      pool: VMPool):
    """tasks: [(task, app, owner_tag, inputs)] in queue order; ``vms`` are
    idle VMs in ascending-vmid order (the auction's column space)."""
    T, V = len(tasks), len(vms)
    size = np.empty(T, np.float32)
    out_mb = np.empty(T, np.float32)
    budget = np.empty(T, np.float32)
    missing = np.zeros((T, V), np.float32)
    cont = np.zeros((T, V), np.float32)
    tier = np.zeros((T, V), np.int32)

    ids = np.fromiter((vm.vmid for vm in vms), np.int64, V)
    vm_ids = {vmid: j for j, vmid in enumerate(ids.tolist())}
    # vmid-indexed gathers from the pool's static per-VM attribute arrays.
    mips = pool.mips[ids]
    bw = pool.bandwidth[ids]
    price = pool.price[ids]

    # Per-(vm, app) container state from the pool's incremental app
    # indexes — O(|holders|) per distinct app, no per-VM Python calls.
    cont_by_app = {}
    for app in {app for _, app, _, _ in tasks}:
        is_active = np.zeros(V, bool)
        if not policy.use_containers:
            cvec = np.zeros(V, np.float32)
        else:
            cvec = np.full(V, cfg.container_provision_ms, np.float32)
            for vid in pool.app_image.get(app, ()):
                j = vm_ids.get(vid)
                if j is not None:
                    cvec[j] = cfg.container_init_ms
            for vid in pool.app_active.get(app, ()):
                j = vm_ids.get(vid)
                if j is not None:
                    cvec[j] = 0.0
                    is_active[j] = True
        cont_by_app[app] = (cvec, is_active)

    # Sharing-scope masks, one per distinct owner tag this cycle.
    scope_by_tag = {}
    for tag in {tag for _, _, tag, _ in tasks}:
        s = np.zeros(V, bool)
        for vid in pool.tag_members.get(tag, ()):
            j = vm_ids.get(vid)
            if j is not None:
                s[j] = True
        scope_by_tag[tag] = s

    data_index = pool.data_index
    for i, (task, app, tag, inputs) in enumerate(tasks):
        size[i] = task.size_mi
        out_mb[i] = task.out_mb
        budget[i] = task.budget
        scope = scope_by_tag[tag]
        cvec, is_active = cont_by_app[app]
        cont[i] = cvec
        if policy.locality_tiers:
            have_all = scope.copy()
            miss = np.zeros(V, np.float32)
            for key, mb in inputs:
                holders = data_index.get(key, ())
                hold = np.zeros(V, bool)
                for vid in holders:
                    j = vm_ids.get(vid)
                    if j is not None:
                        hold[j] = True
                miss += np.where(hold, 0.0, mb)
                if mb > 0:
                    have_all &= hold
            missing[i] = miss
            t = np.where(have_all, 1,
                         np.where(is_active & policy.use_containers, 2, 3))
        else:
            missing[i] = sum(mb for _, mb in inputs)
            t = np.full(V, 3, np.int32)
        tier[i] = np.where(scope, t, 0)
    return (size, out_mb, budget, missing, cont, tier, mips, bw, price)


# Below this remaining queue×pool pair product a request finishes its
# auction serially instead of riding further kernel rounds — the commit
# rule's conflict tails otherwise pay per-round device dispatch for a
# handful of pairs.  Serial and kernel resolution are bit-exact.
AUCTION_TAIL_PAIRS = 192


def _p2(n: int) -> int:
    """Next power of two ≥ max(n, 2) — shape buckets so the jitted kernel
    is reused across cycles instead of recompiling per shape (padding
    rows/cols are tier-0 ⇒ infeasible ⇒ inert)."""
    return 1 << max(n - 1, 1).bit_length()


class _RoundBuffers:
    """Resident padded pair buffers for auction rounds, bucketed by
    power-of-two ``(Bp, Tp, Vp)`` shape.

    The old cache held exactly ONE bucket: mixed-size rounds (a big
    round followed by small ones, the normal shape of the aggregate
    dispatcher) thrashed it — every bucket flip reallocated and refilled
    nine arrays, and the jitted kernel re-traced.  Now:

    * multiple buckets stay resident (dict, LRU-evicted once the summed
      ``B·T·V`` exceeds ``MAX_RESIDENT_ELEMS``), each traced once;
    * a round reuses the smallest resident bucket that covers its shape
      (up to ``COVER_SLACK``× element blowup — padding is inert, and
      riding a slightly-larger resident bucket beats allocating and
      tracing a new one), growing buckets geometrically via the
      power-of-two dims;
    * resets clear only the region the bucket's previous round actually
      wrote (tracked per bucket), not the whole allocation — small
      rounds in a big bucket pay memsets proportional to their own size.

    The cache is thread-local (each thread driving engines gets its own
    buffers — rounds from concurrent runs never interleave on shared
    arrays); over-cap outliers allocate fresh per round rather than
    pinning hundreds of MB at module scope.
    """

    __slots__ = ("buckets", "used", "lru")

    # Largest summed B·T·V kept alive between rounds (~4M pair elements
    # ⇒ ≲50 MB across the six [B,T,V] arrays).
    MAX_RESIDENT_ELEMS = 1 << 22
    # Max element blowup tolerated when riding a larger resident bucket.
    COVER_SLACK = 4

    def __init__(self):
        self.buckets = {}   # (Bp, Tp, Vp) -> bufs tuple
        self.used = {}      # (Bp, Tp, Vp) -> (B, T, V) region to reset
        self.lru = []       # keys, most-recently-used last

    @staticmethod
    def _alloc(Bp: int, Tp: int, Vp: int):
        return (
            np.zeros((Bp, Tp), np.float32),        # size
            np.zeros((Bp, Tp), np.float32),        # out_mb
            np.full((Bp, Tp), -1.0, np.float32),   # budget (inert: -1)
            np.zeros((Bp, Tp, Vp), np.float32),    # missing
            np.zeros((Bp, Tp, Vp), np.float32),    # cont
            np.zeros((Bp, Tp, Vp), np.int32),      # tier (inert: 0)
            np.ones((Bp, Vp), np.float32),         # mips (no div-by-zero)
            np.ones((Bp, Vp), np.float32),         # bw
            np.ones((Bp, Vp), np.float32),         # price
        )

    @staticmethod
    def _reset(bufs, region) -> None:
        B, T, V = region
        if B == 0:
            return
        size, out_mb, budget, missing, cont, tier, mips, bw, price = bufs
        size[:B, :T] = 0.0
        out_mb[:B, :T] = 0.0
        budget[:B, :T] = -1.0
        missing[:B, :T, :V] = 0.0
        cont[:B, :T, :V] = 0.0
        tier[:B, :T, :V] = 0
        mips[:B, :V] = 1.0
        bw[:B, :V] = 1.0
        price[:B, :V] = 1.0

    def _touch(self, key) -> None:
        if self.lru and self.lru[-1] == key:
            return
        try:
            self.lru.remove(key)
        except ValueError:
            pass
        self.lru.append(key)

    def get(self, Bp: int, Tp: int, Vp: int):
        req = Bp * Tp * Vp
        best = None
        for key in self.buckets:
            if key[0] >= Bp and key[1] >= Tp and key[2] >= Vp:
                if best is None or (key[0] * key[1] * key[2]
                                    < best[0] * best[1] * best[2]):
                    best = key
        if best is not None \
                and best[0] * best[1] * best[2] <= self.COVER_SLACK * req:
            bufs = self.buckets[best]
            self._reset(bufs, self.used[best])
            # Upper bound of what this round may write (propose_into
            # writes member rows within the requested dims only).
            self.used[best] = (Bp, Tp, Vp)
            self._touch(best)
            return bufs
        bufs = self._alloc(Bp, Tp, Vp)
        if req <= self.MAX_RESIDENT_ELEMS:
            key = (Bp, Tp, Vp)
            self.buckets[key] = bufs
            self.used[key] = (Bp, Tp, Vp)
            self._touch(key)
            total = sum(k[0] * k[1] * k[2] for k in self.buckets)
            while total > self.MAX_RESIDENT_ELEMS and len(self.lru) > 1:
                old = self.lru.pop(0)
                total -= old[0] * old[1] * old[2]
                del self.buckets[old]
                del self.used[old]
        # else: one-shot buffers — leave resident buckets intact.
        return bufs


class _ThreadLocalBuffers(threading.local):
    def __init__(self):
        self.rb = _RoundBuffers()


_ROUND_BUFFERS = _ThreadLocalBuffers()

# Mesh placement seam for the round buffers (stubbed: TPU tuning is a
# later ROADMAP item).  ``parallel.sharding.round_buffer_placement`` is
# imported lazily — sharding pulls in the model registry, which has no
# business on the simulation hot path.
_ROUND_BUFFER_MESH = None
_ROUND_BUFFER_PLACEMENT = None


def set_round_buffer_mesh(mesh) -> None:
    """Install a device mesh for future round-buffer placement.  With
    ``mesh=None`` (the default state) buffers stay host-staged numpy;
    with a mesh, the replicated placement is computed and recorded but
    — today — only consulted by tests: the actual device_put of the
    ``[B, T, V]`` stacks is the deferred TPU-tuning work."""
    global _ROUND_BUFFER_MESH, _ROUND_BUFFER_PLACEMENT
    _ROUND_BUFFER_MESH = mesh
    if mesh is None:
        _ROUND_BUFFER_PLACEMENT = None
        return
    from ..parallel.sharding import round_buffer_placement
    _ROUND_BUFFER_PLACEMENT = round_buffer_placement(mesh)


class CycleRequest:
    """One simulation's auction state inside a (possibly multi-sim) cycle.

    Owns the pair arrays, the queue-order task list, the availability
    mask, and the serial-dictatorship commit rule.  ``multi_cycle`` only
    orchestrates rounds; all per-simulation semantics live here.
    """

    def __init__(self, cfg: PlatformConfig, policy: Policy,
                 tasks, vms: Sequence[VM], pool: VMPool,
                 tables: Optional[Sequence] = None):
        self.cfg = cfg
        self.policy = policy
        self.pool = pool
        self.tables = tables   # per-task CostTables for serial resolution
        self.tasks = list(tasks)
        self.vms = list(vms)
        T, V = len(tasks), len(vms)
        self.T, self.V = T, V
        self.col = {vm.vmid: j for j, vm in enumerate(self.vms)}
        self.placements: List[Optional[Placement]] = [None] * T
        self.unplaced: List[int] = list(range(T)) if V else []
        self.avail = np.ones(V, bool)
        self.stalled = False
        if T and V:
            (self.size, self.out_mb, self.budget, self.missing, self.cont,
             self.tier, self.mips, self.bw, self.price) = build_pair_arrays(
                cfg, policy, tasks, vms, pool)

    @property
    def active(self) -> bool:
        return bool(self.unplaced) and bool(self.avail.any()) \
            and not self.stalled

    def propose_into(self, bufs, b: int) -> None:
        """Write this member's current unplaced rows into batch row ``b``
        of the shared resident buffers (already reset to inert padding)."""
        size, out_mb, budget, missing, cont, tier, mips, bw, price = bufs
        sel = self.unplaced
        Tr, V = len(sel), self.V
        size[b, :Tr] = self.size[sel]
        out_mb[b, :Tr] = self.out_mb[sel]
        budget[b, :Tr] = self.budget[sel]
        missing[b, :Tr, :V] = self.missing[sel]
        cont[b, :Tr, :V] = self.cont[sel]
        tier[b, :Tr, :V] = self.tier[sel] * self.avail[None, :]
        mips[b, :V] = self.mips
        bw[b, :V] = self.bw
        price[b, :V] = self.price

    def _select_serial(self, ti: int) -> Placement:
        """The per-task reference rule for task ``ti`` against the
        auction's *current* availability set — the same ``select`` call,
        at the same point in the serial order, the sequential reference
        makes.  Used both for kernel-infeasible rows (insufficient-budget
        tier-4/5 resolution) and for the serial tail drain."""
        task, app, tag, inputs = self.tasks[ti]
        avail = [vm for j, vm in enumerate(self.vms) if self.avail[j]]
        return select(self.cfg, self.policy, task, -1, app, inputs,
                      task.budget, avail, owner_tag=tag, pool=self.pool,
                      table=self.tables[ti] if self.tables else None)

    def finish_serial(self) -> None:
        """Drain every remaining unplaced task with the per-task
        reference rule, in queue order, against the live availability
        set.  The auction's fixed point *is* sequential per-task
        processing (the property the whole module rests on), so the tail
        is bit-exact either way — and a few Python selects beat a long
        conflict tail of near-empty kernel rounds."""
        for ti in self.unplaced:
            p = self._select_serial(ti)
            self.placements[ti] = p
            if p.vm is not None:
                self.avail[self.col[p.vm.vmid]] = False
        self.unplaced = []

    def commit(self, best, tiers, fins, costs_) -> None:
        """Serial-dictatorship prefix commit: the winner of each VM is its
        earliest claimant, and only winners EARLIER than the first loser
        commit this round.  A later round-1 winner could otherwise steal
        the VM an earlier loser takes next — exactly the interleaving
        the sequential reference produces.

        Tasks with no feasible VM (best < 0) resolve *in serial position*
        through :meth:`_select_serial` — the insufficient-budget
        tier-5 rule may take an idle VM, in which case every later task
        this round is deferred (``halted``) and re-auctions against the
        shrunken pool, exactly as the sequential reference would see it."""
        claims: dict = {}
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if j >= 0 and j not in claims:
                claims[j] = ti
        losers = [ti for row, ti in enumerate(self.unplaced)
                  if int(best[row]) >= 0 and claims[int(best[row])] != ti]
        first_loser = min(losers) if losers else None
        next_unplaced = []
        committed = False
        halted = False
        for row, ti in enumerate(self.unplaced):
            j = int(best[row])
            if halted or (first_loser is not None and ti > first_loser):
                next_unplaced.append(ti)
                continue
            if j < 0:
                p = self._select_serial(ti)
                self.placements[ti] = p
                committed = True
                if p.vm is not None:
                    # Tier-5 reuse consumed a VM the kernel scored as
                    # infeasible; later tasks must re-auction without it.
                    self.avail[self.col[p.vm.vmid]] = False
                    halted = True
                continue
            if claims[j] == ti:
                self.placements[ti] = Placement(
                    self.vms[j], None, int(tiers[row]),
                    int(fins[row]), float(costs_[row]))
                self.avail[j] = False
                committed = True
            else:
                next_unplaced.append(ti)
        self.unplaced = next_unplaced
        self.stalled = not committed


def multi_cycle(cfg: PlatformConfig, requests: Sequence[CycleRequest],
                use_pallas: object = "auto"
                ) -> List[List[Optional[Placement]]]:
    """Run every request's auction to its fixed point, scoring all active
    members' rounds with ONE batched kernel call per round.

    Members are independent simulations, so rounds interleave freely; a
    member drops out as soon as it has no unplaced task, no available VM,
    or a round commits nothing.  Rounds fill the resident power-of-two
    ``(B, T, V)`` buffers (``_RoundBuffers``) so the vmapped kernel
    recompiles per bucket, not per round, and allocates nothing per call;
    on accelerators the staged device copies are donated back to XLA.

    Requests whose remaining task×VM pair product drops below
    ``AUCTION_TAIL_PAIRS`` leave the fixed point and drain serially
    (:meth:`CycleRequest.finish_serial`, bit-exact): conflict tails
    otherwise stretch into dozens of near-empty kernel rounds whose
    dispatch overhead dwarfs the scoring they do.

    ``use_pallas``: False / True / "auto" (Pallas on TPU, jnp elsewhere).
    """
    pallas = aff_ops.resolve_use_pallas(use_pallas)
    donate = aff_ops.donation_supported()
    while True:
        active = []
        for r in requests:
            if not r.active:
                continue
            if len(r.unplaced) * int(r.avail.sum()) < AUCTION_TAIL_PAIRS:
                r.finish_serial()
            else:
                active.append(r)
        if not active:
            break
        Tp = max(_p2(len(r.unplaced)) for r in active)
        Vp = max(_p2(r.V) for r in active)
        # Batch dim rounds to 1, 2, 4, … (a solo auction stays unpadded);
        # rows beyond the active members keep the inert padding.
        Bp = 1 << max(len(active) - 1, 0).bit_length()
        bufs = _ROUND_BUFFERS.rb.get(Bp, Tp, Vp)
        for b, r in enumerate(active):
            r.propose_into(bufs, b)
        res = aff_ops.affinity_batch(
            *bufs,
            gs_read=cfg.gs_read_mbps, gs_write=cfg.gs_write_mbps,
            bp_ms=float(cfg.billing_period_ms), use_pallas=pallas,
            donate=donate)
        best = np.asarray(res.best_vm)
        tiers = np.asarray(res.best_tier)
        fins = np.asarray(res.est_finish)
        costs_ = np.asarray(res.est_cost)
        for b, r in enumerate(active):
            r.commit(best[b], tiers[b], fins[b], costs_[b])
    return [r.placements for r in requests]


def batched_cycle(cfg: PlatformConfig, policy: Policy,
                  tasks, vms: Sequence[VM], pool: VMPool,
                  use_pallas: object = "auto", tables=None
                  ) -> List[Optional[Placement]]:
    """Returns, per task (queue order), a reuse Placement or None (task
    needs the provisioning fallback)."""
    if not tasks:
        return []
    if not vms:
        return [None] * len(tasks)
    req = CycleRequest(cfg, policy, tasks, vms, pool, tables=tables)
    return multi_cycle(cfg, [req], use_pallas=use_pallas)[0]

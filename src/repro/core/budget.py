"""Budget distribution — Algorithm 1 (DistributeBudget/SFTD) and
Algorithm 3 (UpdateBudget) of the paper.

The distribution assigns every task a sub-budget.  Pass 1 levels the DAG
(Deadline Top Level, Eq. 7), orders tasks by ascending EFT within each level
(Eq. 8) to form the estimated execution order ``S``; pass 2 allocates the
cheapest-VM cost to every task and then spends any leftover budget upgrading
the *earliest* tasks in ``S`` to the fastest affordable VM type
(Slowest-First Task-based Distribution).

All per-(task, VM type) estimates are read from the precomputed
:mod:`core.cost_tables` table (one ``[T, V]`` grid per workflow family,
shared across clones and both engines) instead of per-call scalar cost
evaluation — Algorithm 3's per-finish redistribution, the shared hot path
of both engines, reduces to indexed table reads.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from . import cost_tables, costs
from .types import PlatformConfig, Task, VMType, Workflow


def assign_levels(wf: Workflow) -> None:
    """Eq. (7): level(t) = 0 for entries else max(level(parents)) + 1."""
    order = topological_order(wf)
    for tid in order:
        t = wf.tasks[tid]
        t.level = 0 if not t.parents else 1 + max(wf.tasks[p].level for p in t.parents)


def topological_order(wf: Workflow) -> List[int]:
    """Kahn topological order with deterministic (lowest-tid) tie-breaks."""
    indeg = [len(t.parents) for t in wf.tasks]
    import heapq

    heap = [i for i, d in enumerate(indeg) if d == 0]
    heapq.heapify(heap)
    out: List[int] = []
    while heap:
        u = heapq.heappop(heap)
        out.append(u)
        for c in wf.tasks[u].children:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(heap, c)
    assert len(out) == len(wf.tasks), "cycle in workflow"
    return out


def input_mb(wf: Workflow, task: Task) -> float:
    """Total input volume d_t^in (external + every parent's output)."""
    out_of = [t.out_mb for t in wf.tasks]
    return costs.total_input_mb(task, out_of)


def estimated_eft(
    cfg: PlatformConfig, wf: Workflow, ref_vmt: VMType
) -> List[int]:
    """Eq. (8): EFT on a reference VM type (cheapest), in ms."""
    try:
        ref_idx = cfg.vm_types.index(ref_vmt)
        pt_of = cost_tables.table_for(cfg, wf).proc_ms[:, ref_idx]
    except ValueError:  # off-catalogue reference type: scalar fallback
        pt_of = [
            costs.processing_ms(cfg, ref_vmt, t, input_mb(wf, t))
            for t in wf.tasks
        ]
    eft = [0] * wf.n_tasks
    for tid in topological_order(wf):
        t = wf.tasks[tid]
        start = max((eft[p] for p in t.parents), default=0)
        eft[tid] = start + int(pt_of[tid])
    return eft


def execution_order(cfg: PlatformConfig, wf: Workflow) -> List[int]:
    """Estimated execution order S: level-major, EFT-ascending within level."""
    assign_levels(wf)
    ref = cfg.vm_types[0]  # cheapest type as the reference estimator
    eft = estimated_eft(cfg, wf, ref)
    order = sorted(
        range(wf.n_tasks),
        key=lambda tid: (wf.tasks[tid].level, eft[tid], tid),
    )
    for rank, tid in enumerate(order):
        wf.tasks[tid].rank = rank
    return order


def distribute_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    budget: float,
    task_ids: Optional[Sequence[int]] = None,
) -> float:
    """Algorithm 1.  Mutates ``task.budget``; returns the undistributed
    remainder (spare budget — Alg. 3 folds it into the next update so no
    money is ever lost).

    Pass 1 allocates the cheapest-VM conservative cost to tasks in order
    *while the pool lasts* (the paper's ``while β > 0``); once exhausted,
    later tasks receive whatever fraction remains (possibly zero).  Budget
    is strictly conserved: Σ sub-budgets ≤ β always.

    Pass 2 (SFTD) upgrades the earliest tasks in ``S`` to the fastest type
    still affordable with the leftover.

    ``task_ids`` restricts distribution to a subset (used by Algorithm 3 to
    redistribute over unscheduled tasks); order within the subset follows the
    original estimated execution order (``task.rank``).

    Both passes read the workflow's :class:`~core.cost_tables.CostTable`:
    pass 1 is a masked cumulative reduction over the cheapest-type column,
    pass 2 sweeps the precomputed ``[U, V]`` tier-cost slice.
    """
    if task_ids is None:
        order = execution_order(cfg, wf)
    else:
        order = sorted(task_ids, key=lambda tid: wf.tasks[tid].rank)
    if not order:
        return budget

    table = cost_tables.table_for(cfg, wf)
    order_arr = np.asarray(order, np.int64)
    # Pass 1: cheapest-VM conservative cost, allocated while the pool
    # lasts — give_i = min(want_i, max(β − Σ_{<i} give, 0)), as a masked
    # cumulative table reduction (cfg.vm_types[0] is the cheapest type,
    # mirroring the reference estimator in execution_order).
    want = table.est_full_cost[order_arr, 0]
    cum = np.cumsum(want)
    alloc = np.minimum(want, np.maximum(budget - (cum - want), 0.0))
    remaining = max(budget - float(alloc.sum()), 0.0)

    # Pass 2 (SFTD): sweep the order earliest-first, upgrading each task's
    # allocation by ONE VM-type tier per visit ("upgrade ... for a faster VM
    # type starting from the earliest tasks"), until a sweep changes nothing.
    # One-tier sweeps keep the allocation distribution unimodal — the whole
    # workflow climbs the VM ladder together instead of splitting into a
    # fastest/cheapest bimodal mix (which would pollute the shared pool with
    # slow cache-carrier VMs).
    if remaining > 0:
        tier_cost = table.est_full_cost[order_arr[:, None],
                                        table.by_speed[None, :]]
        K = tier_cost.shape[1]
        # Current tier: highest tier fully covered by the allocation.
        covered = alloc[:, None] >= tier_cost - 1e-9
        any_cov = covered.any(axis=1)
        highest = K - 1 - np.argmax(covered[:, ::-1], axis=1)
        tier_of = np.where(any_cov, highest, 0)
        changed = True
        while remaining > 1e-9 and changed:
            changed = False
            for u in range(len(order)):
                k = int(tier_of[u])
                if k + 1 >= K:
                    continue
                delta = float(tier_cost[u, k + 1]) - float(alloc[u])
                if 0 < delta <= remaining + 1e-9:
                    alloc[u] = tier_cost[u, k + 1]
                    tier_of[u] = k + 1
                    remaining -= delta
                    changed = True
                elif delta <= 0:
                    tier_of[u] = k + 1
                    changed = True

    for pos, tid in enumerate(order):
        wf.tasks[tid].budget = float(alloc[pos])
    return max(remaining, 0.0)


def update_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    finished_tid: int,
    actual_cost: float,
    spare_budget: float,
    unscheduled: Sequence[int],
) -> float:
    """Algorithm 3.  Returns the new spare budget.

    The finished task's allocation plus the spare budget absorb the actual
    cost; any surplus (or debt) flows into the pool redistributed over the
    unscheduled tasks, so uncertainty never propagates into a violation.
    The undistributed remainder of the redistribution persists as the spare
    (conservation: money is never created or silently dropped).
    """
    t_f = wf.tasks[finished_tid]
    pool = sum(wf.tasks[tid].budget for tid in unscheduled)
    headroom = t_f.budget + spare_budget
    if actual_cost <= headroom:
        pool += headroom - actual_cost
    else:
        pool -= actual_cost - headroom
    pool = max(pool, 0.0)
    if unscheduled:
        return distribute_budget(cfg, wf, pool, task_ids=list(unscheduled))
    return pool


def min_max_workflow_cost(cfg: PlatformConfig, wf: Workflow) -> tuple:
    """Budget-range estimate used by workload generation (Section 5).

    Minimum: sequential execution of every task on the cheapest type.
    Maximum: every task on its own fastest-type VM (max parallel spend).
    """
    table = cost_tables.table_for(cfg, wf)
    cheapest = cfg.vm_types[0]
    fastest_idx = max(range(len(cfg.vm_types)),
                      key=lambda i: cfg.vm_types[i].mips)
    lo = float(table.cost_bare[:, 0].sum())
    # Sequential on one VM: charge provisioning + one container once.
    lo += costs.billed_cost(
        cfg, cheapest, cfg.vm_provision_delay_ms + cfg.container_provision_ms
    )
    hi = float(table.est_full_cost[:, fastest_idx].sum())
    return lo, hi

"""Budget distribution — Algorithm 1 (DistributeBudget/SFTD) and
Algorithm 3 (UpdateBudget) of the paper.

The distribution assigns every task a sub-budget.  Pass 1 levels the DAG
(Deadline Top Level, Eq. 7), orders tasks by ascending EFT within each level
(Eq. 8) to form the estimated execution order ``S``; pass 2 allocates the
cheapest-VM cost to every task and then spends any leftover budget upgrading
the *earliest* tasks in ``S`` to the fastest affordable VM type
(Slowest-First Task-based Distribution).

All per-(task, VM type) estimates are read from the precomputed
:mod:`core.cost_tables` table (one ``[T, V]`` grid per workflow family,
shared across clones and both engines) instead of per-call scalar cost
evaluation — Algorithm 3's per-finish redistribution, the shared hot path
of both engines, reduces to indexed table reads.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from . import cost_tables, costs
from .types import PlatformConfig, Task, VMType, Workflow


def assign_levels(wf: Workflow) -> None:
    """Eq. (7): level(t) = 0 for entries else max(level(parents)) + 1."""
    order = topological_order(wf)
    for tid in order:
        t = wf.tasks[tid]
        t.level = 0 if not t.parents else 1 + max(wf.tasks[p].level for p in t.parents)


def topological_order(wf: Workflow) -> List[int]:
    """Kahn topological order with deterministic (lowest-tid) tie-breaks."""
    indeg = [len(t.parents) for t in wf.tasks]
    import heapq

    heap = [i for i, d in enumerate(indeg) if d == 0]
    heapq.heapify(heap)
    out: List[int] = []
    while heap:
        u = heapq.heappop(heap)
        out.append(u)
        for c in wf.tasks[u].children:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(heap, c)
    assert len(out) == len(wf.tasks), "cycle in workflow"
    return out


def input_mb(wf: Workflow, task: Task) -> float:
    """Total input volume d_t^in (external + every parent's output)."""
    out_of = [t.out_mb for t in wf.tasks]
    return costs.total_input_mb(task, out_of)


def estimated_eft(
    cfg: PlatformConfig, wf: Workflow, ref_vmt: VMType
) -> List[int]:
    """Eq. (8): EFT on a reference VM type (cheapest), in ms."""
    try:
        ref_idx = cfg.vm_types.index(ref_vmt)
        pt_of = cost_tables.table_for(cfg, wf).proc_ms[:, ref_idx]
    except ValueError:  # off-catalogue reference type: scalar fallback
        pt_of = [
            costs.processing_ms(cfg, ref_vmt, t, input_mb(wf, t))
            for t in wf.tasks
        ]
    eft = [0] * wf.n_tasks
    for tid in topological_order(wf):
        t = wf.tasks[tid]
        start = max((eft[p] for p in t.parents), default=0)
        eft[tid] = start + int(pt_of[tid])
    return eft


def execution_order(cfg: PlatformConfig, wf: Workflow) -> List[int]:
    """Estimated execution order S: level-major, EFT-ascending within level."""
    assign_levels(wf)
    ref = cfg.vm_types[0]  # cheapest type as the reference estimator
    eft = estimated_eft(cfg, wf, ref)
    order = sorted(
        range(wf.n_tasks),
        key=lambda tid: (wf.tasks[tid].level, eft[tid], tid),
    )
    for rank, tid in enumerate(order):
        wf.tasks[tid].rank = rank
    wf.rank_cache = None   # ranks changed; drop the memoized list
    return order


# Subsets up to this size take the pure-Python distribution path: ~20
# numpy dispatches cost more than the loop at Algorithm 3's per-finish
# call sizes.  Both paths execute the identical float64 operation
# sequence, so the cutover is invisible in results (bit-exact).
_PY_DISTRIBUTE_MAX = 64


def _sum_like_numpy(values: List[float]) -> float:
    """``float(np.sum(np.asarray(values)))`` without the array round-trip
    for the small-n regime, preserving numpy's exact summation order:
    n < 8 is a plain sequential reduction; 8 ≤ n ≤ 128 is the 8-lane
    pairwise block numpy uses below its recursion blocksize.  Falls back
    to numpy above that, and the replication is verified at import
    (``_SUM_VERIFIED``) so a change in numpy's reduction would be
    caught, not silently diverge."""
    n = len(values)
    if not _SUM_VERIFIED or n > 128:
        return float(np.sum(np.asarray(values)))
    if n < 8:
        s = 0.0
        for x in values:
            s += x
        return s
    r0, r1, r2, r3, r4, r5, r6, r7 = values[:8]
    i = 8
    stop = n - (n % 8)
    while i < stop:
        r0 += values[i]
        r1 += values[i + 1]
        r2 += values[i + 2]
        r3 += values[i + 3]
        r4 += values[i + 4]
        r5 += values[i + 5]
        r6 += values[i + 6]
        r7 += values[i + 7]
        i += 8
    s = ((r0 + r1) + (r2 + r3)) + ((r4 + r5) + (r6 + r7))
    while i < n:
        s += values[i]
        i += 1
    return s


def _verify_sum_compat() -> bool:
    global _SUM_VERIFIED
    _SUM_VERIFIED = True   # let _sum_like_numpy take the scalar paths
    rng = np.random.default_rng(0)
    for n in (*range(1, 18), 31, 64, 65, 127, 128):
        a = (rng.random(n) * rng.integers(1, 1000, n)).tolist()
        if _sum_like_numpy(a) != float(np.sum(np.asarray(a))):
            return False
    return True


_SUM_VERIFIED = _verify_sum_compat()


def _distribute_small(wf: Workflow, table, budget: float,
                      order: List[int]) -> float:
    """Pure-Python Algorithm 1 passes for small ``order`` subsets.

    Mirrors the vectorized body below operation-for-operation: pass 1 is
    the same sequential cumulative sum (``np.cumsum`` adds in index
    order) with ``remaining`` from the numpy-order total, and the SFTD
    sweep reads the table's plain-list mirror.

    The sweep keeps a *live* row list instead of re-scanning everything:
    ``remaining`` only ever decreases, and a row's upgrade delta is
    unchanged until the row itself upgrades — so a row that once fails
    the paid-upgrade check can never succeed later and is dropped, and a
    row at the top tier is done.  The rows it visits make exactly the
    decisions the full re-scan would (skipped rows change nothing), so
    allocations are bit-identical.
    """
    cheap = table.cheap_list
    running = 0.0
    alloc: List[float] = []
    for tid in order:
        w = cheap[tid]
        running = running + w
        avail = budget - (running - w)
        if avail < 0.0:
            avail = 0.0
        alloc.append(w if w < avail else avail)
    remaining = max(budget - _sum_like_numpy(alloc), 0.0)

    if remaining > 1e-9:
        tier_list = table.tier_list
        K = len(tier_list[0])
        top = K - 1
        # "Everyone tops out" shortcut: with nondecreasing tier costs,
        # the sweep's total consumption to bring every row to the top
        # tier is exactly Σ(top − alloc); when the remainder covers that
        # with margin (the 1e-6 safety dwarfs any accumulated rounding in
        # the ≤ U·K subtractions the sweep would make, so every paid
        # check the sweep would run is guaranteed to pass), the fixed
        # point is known without sweeping.
        if table.tiers_monotone:
            top_l = table.top_list
            need = 0.0
            for u, tid in enumerate(order):
                need += top_l[tid] - alloc[u]
            if remaining > need + 1e-6:
                remaining -= need
                tasks = wf.tasks
                for pos, tid in enumerate(order):
                    tasks[tid].budget = top_l[tid]
                return max(remaining, 0.0)
        # First sweep fused with tier-discovery: current tier = highest
        # covered (same `alloc >= tier_cost - 1e-9` predicate as the
        # array path), then the usual one-tier upgrade attempt.  Upgrade
        # attempts continue through the whole sweep even once
        # ``remaining`` dips under the sweep-entry threshold — exactly
        # the reference loop's within-sweep behavior.
        live: List[list] = []   # [u, k, row] for rows that may still move
        monotone = table.tiers_monotone
        for u, a in enumerate(alloc):
            row = tier_list[order[u]]
            if monotone:
                # Nondecreasing row ⇒ the covered set is a prefix: walk
                # up and stop at the first uncovered tier (same result
                # as the descending scan, fewer comparisons — most rows
                # sit at low tiers).
                k = 0
                for j in range(1, K):
                    if a >= row[j] - 1e-9:
                        k = j
                    else:
                        break
            else:
                k = 0
                for j in range(top, -1, -1):
                    if a >= row[j] - 1e-9:
                        k = j
                        break
            if k >= top:
                continue
            delta = row[k + 1] - a
            if 0 < delta <= remaining + 1e-9:
                alloc[u] = row[k + 1]
                remaining -= delta
                k += 1
            elif delta <= 0:
                k += 1
            else:
                continue  # paid check failed: can never succeed later
            if k < top:
                live.append([u, k, row])
        while live and remaining > 1e-9:
            nxt: List[list] = []
            for item in live:
                u, k, row = item
                delta = row[k + 1] - alloc[u]
                if 0 < delta <= remaining + 1e-9:
                    alloc[u] = row[k + 1]
                    remaining -= delta
                elif delta > 0:
                    continue  # dropped forever
                item[1] = k = k + 1
                if k < top:
                    nxt.append(item)
            live = nxt

    tasks = wf.tasks
    for pos, tid in enumerate(order):
        tasks[tid].budget = alloc[pos]
    return max(remaining, 0.0)


def distribute_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    budget: float,
    task_ids: Optional[Sequence[int]] = None,
    presorted: bool = False,
) -> float:
    """Algorithm 1.  Mutates ``task.budget``; returns the undistributed
    remainder (spare budget — Alg. 3 folds it into the next update so no
    money is ever lost).

    Pass 1 allocates the cheapest-VM conservative cost to tasks in order
    *while the pool lasts* (the paper's ``while β > 0``); once exhausted,
    later tasks receive whatever fraction remains (possibly zero).  Budget
    is strictly conserved: Σ sub-budgets ≤ β always.

    Pass 2 (SFTD) upgrades the earliest tasks in ``S`` to the fastest type
    still affordable with the leftover.

    ``task_ids`` restricts distribution to a subset (used by Algorithm 3 to
    redistribute over unscheduled tasks); order within the subset follows the
    original estimated execution order (``task.rank``).

    Both passes read the workflow's :class:`~core.cost_tables.CostTable`:
    pass 1 is a masked cumulative reduction over the cheapest-type column,
    pass 2 sweeps the precomputed ``[U, V]`` tier-cost slice.
    """
    if task_ids is None:
        order = execution_order(cfg, wf)
    elif presorted:
        order = task_ids
    else:
        ranks = wf.rank_cache
        if ranks is None:
            # Ranks are frozen once the arrival-time distribution ran;
            # the per-finish Algorithm 3 path sorts against this list
            # instead of a per-call attribute-chasing lambda.
            wf.rank_cache = ranks = [t.rank for t in wf.tasks]
        order = sorted(task_ids, key=ranks.__getitem__)
    if not order:
        return budget

    table = cost_tables.table_for(cfg, wf)
    if len(order) <= _PY_DISTRIBUTE_MAX:
        return _distribute_small(wf, table, budget, order)
    order_arr = np.asarray(order, np.int64)
    # Pass 1: cheapest-VM conservative cost, allocated while the pool
    # lasts — give_i = min(want_i, max(β − Σ_{<i} give, 0)), as a masked
    # cumulative table reduction (cfg.vm_types[0] is the cheapest type,
    # mirroring the reference estimator in execution_order).
    want = table.est_full_cost[order_arr, 0]
    cum = np.cumsum(want)
    alloc = np.minimum(want, np.maximum(budget - (cum - want), 0.0))
    remaining = max(budget - float(alloc.sum()), 0.0)

    # Pass 2 (SFTD): sweep the order earliest-first, upgrading each task's
    # allocation by ONE VM-type tier per visit ("upgrade ... for a faster VM
    # type starting from the earliest tasks"), until a sweep changes nothing.
    # One-tier sweeps keep the allocation distribution unimodal — the whole
    # workflow climbs the VM ladder together instead of splitting into a
    # fastest/cheapest bimodal mix (which would pollute the shared pool with
    # slow cache-carrier VMs).
    give = alloc.tolist()
    if remaining > 1e-9 and table.tiers_monotone:
        # Same "everyone tops out" shortcut as the small-subset path,
        # with the identical scalar accumulation so both paths stay
        # bit-exact around the size cutover.
        top_l = table.top_list
        need = 0.0
        for u, tid in enumerate(order):
            need += top_l[tid] - give[u]
        if remaining > need + 1e-6:
            remaining -= need
            tasks = wf.tasks
            for tid in order:
                tasks[tid].budget = top_l[tid]
            return max(remaining, 0.0)
    if remaining > 0:
        tier_cost = table.tier_cost[order_arr]
        K = tier_cost.shape[1]
        # Current tier: highest tier fully covered by the allocation.
        covered = alloc[:, None] >= tier_cost - 1e-9
        any_cov = covered.any(axis=1)
        highest = K - 1 - np.argmax(covered[:, ::-1], axis=1)
        tier_of = np.where(any_cov, highest, 0).tolist()
        # The sweep itself runs on plain Python floats (the same IEEE
        # doubles the array holds — ``tolist`` is value-preserving), which
        # is several times faster than per-element numpy indexing on the
        # per-finish Algorithm 3 hot path.
        tc = tier_cost.tolist()
        changed = True
        while remaining > 1e-9 and changed:
            changed = False
            for u in range(len(give)):
                k = tier_of[u]
                if k + 1 >= K:
                    continue
                delta = tc[u][k + 1] - give[u]
                if 0 < delta <= remaining + 1e-9:
                    give[u] = tc[u][k + 1]
                    tier_of[u] = k + 1
                    remaining -= delta
                    changed = True
                elif delta <= 0:
                    tier_of[u] = k + 1
                    changed = True

    tasks = wf.tasks
    for pos, tid in enumerate(order):
        tasks[tid].budget = give[pos]
    return max(remaining, 0.0)


def update_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    finished_tid: int,
    actual_cost: float,
    spare_budget: float,
    unscheduled: Sequence[int],
) -> float:
    """Algorithm 3.  Returns the new spare budget.

    The finished task's allocation plus the spare budget absorb the actual
    cost; any surplus (or debt) flows into the pool redistributed over the
    unscheduled tasks, so uncertainty never propagates into a violation.
    The undistributed remainder of the redistribution persists as the spare
    (conservation: money is never created or silently dropped).

    ``unscheduled`` may come in any order (the engine hands over its raw
    set): the rank order of the estimated execution sequence S — which
    the redistribution consumes anyway — is the one deterministic order
    used for both the pool summation and the distribution, computed once.
    """
    tasks = wf.tasks
    t_f = tasks[finished_tid]
    if unscheduled:
        ranks = wf.rank_cache
        if ranks is None:
            wf.rank_cache = ranks = [t.rank for t in tasks]
        order = sorted(unscheduled, key=ranks.__getitem__)
        pool = sum([tasks[tid].budget for tid in order])
    else:
        order = None
        pool = 0.0
    headroom = t_f.budget + spare_budget
    if actual_cost <= headroom:
        pool += headroom - actual_cost
    else:
        pool -= actual_cost - headroom
    pool = max(pool, 0.0)
    if order:
        return distribute_budget(cfg, wf, pool, task_ids=order,
                                 presorted=True)
    return pool


def min_max_workflow_cost(cfg: PlatformConfig, wf: Workflow) -> tuple:
    """Budget-range estimate used by workload generation (Section 5).

    Minimum: sequential execution of every task on the cheapest type.
    Maximum: every task on its own fastest-type VM (max parallel spend).
    """
    table = cost_tables.table_for(cfg, wf)
    cheapest = cfg.vm_types[0]
    fastest_idx = max(range(len(cfg.vm_types)),
                      key=lambda i: cfg.vm_types[i].mips)
    lo = float(table.cost_bare[:, 0].sum())
    # Sequential on one VM: charge provisioning + one container once.
    lo += costs.billed_cost(
        cfg, cheapest, cfg.vm_provision_delay_ms + cfg.container_provision_ms
    )
    hi = float(table.est_full_cost[:, fastest_idx].sum())
    return lo, hi

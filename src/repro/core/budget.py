"""Budget distribution — Algorithm 1 (DistributeBudget/SFTD) and
Algorithm 3 (UpdateBudget) of the paper.

The distribution assigns every task a sub-budget.  Pass 1 levels the DAG
(Deadline Top Level, Eq. 7), orders tasks by ascending EFT within each level
(Eq. 8) to form the estimated execution order ``S``; pass 2 allocates the
cheapest-VM cost to every task and then spends any leftover budget upgrading
the *earliest* tasks in ``S`` to the fastest affordable VM type
(Slowest-First Task-based Distribution).

All per-(task, VM type) estimates are read from the precomputed
:mod:`core.cost_tables` table (one ``[T, V]`` grid per workflow family,
shared across clones and both engines) instead of per-call scalar cost
evaluation — Algorithm 3's per-finish redistribution, the shared hot path
of both engines, reduces to indexed table reads.

Algorithm 3 has two implementations that must stay bit-exact with each
other (gated by ``tests/test_redistribute.py``):

* :func:`update_budget` — the scalar reference (sort, pool, sweep);
* :func:`update_budget_fast` — the array path: a per-workflow
  :class:`RedistState` keeps the estimated execution order ``S`` as an
  index array plus an unscheduled *mask*, so each per-finish call is a
  mask compress + table gathers + the bulk SFTD sweep
  (:func:`_bulk_sweep`) instead of a Python sort and per-tier rescan.

Tuning knobs (see the README "Tuning knobs" table):

* ``REPRO_SCALAR_REDIST=1`` — force the scalar :func:`update_budget`
  oracle on the engine hot path (read at import into
  ``_ARRAY_REDIST``); the array path is the default.
* ``_PY_DISTRIBUTE_MAX`` (=64) — subsets at or below this size take the
  pure-Python distribution path on *both* implementations; the cutover
  is bit-invisible.

The round-batched redistribution mode (``redistribute="round"`` on the
engines) banks per-finish surpluses and flushes them through
:func:`update_budget_pooled` once per workflow per scheduling cycle —
semantics-changing (surplus flows coalesce), so it is opt-in and
A/B-gated rather than bit-parity-gated (see docs/PROFILING.md).
"""
from __future__ import annotations

import os as _os
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import cost_tables, costs
from .types import PlatformConfig, Task, VMType, Workflow


def assign_levels(wf: Workflow) -> None:
    """Eq. (7): level(t) = 0 for entries else max(level(parents)) + 1."""
    order = topological_order(wf)
    for tid in order:
        t = wf.tasks[tid]
        t.level = 0 if not t.parents else 1 + max(wf.tasks[p].level for p in t.parents)


def topological_order(wf: Workflow) -> List[int]:
    """Kahn topological order with deterministic (lowest-tid) tie-breaks."""
    indeg = [len(t.parents) for t in wf.tasks]
    import heapq

    heap = [i for i, d in enumerate(indeg) if d == 0]
    heapq.heapify(heap)
    out: List[int] = []
    while heap:
        u = heapq.heappop(heap)
        out.append(u)
        for c in wf.tasks[u].children:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(heap, c)
    assert len(out) == len(wf.tasks), "cycle in workflow"
    return out


def input_mb(wf: Workflow, task: Task) -> float:
    """Total input volume d_t^in (external + every parent's output)."""
    out_of = [t.out_mb for t in wf.tasks]
    return costs.total_input_mb(task, out_of)


def estimated_eft(
    cfg: PlatformConfig, wf: Workflow, ref_vmt: VMType
) -> List[int]:
    """Eq. (8): EFT on a reference VM type (cheapest), in ms."""
    try:
        ref_idx = cfg.vm_types.index(ref_vmt)
        pt_of = cost_tables.table_for(cfg, wf).proc_ms[:, ref_idx]
    except ValueError:  # off-catalogue reference type: scalar fallback
        pt_of = [
            costs.processing_ms(cfg, ref_vmt, t, input_mb(wf, t))
            for t in wf.tasks
        ]
    eft = [0] * wf.n_tasks
    for tid in topological_order(wf):
        t = wf.tasks[tid]
        start = max((eft[p] for p in t.parents), default=0)
        eft[tid] = start + int(pt_of[tid])
    return eft


def execution_order(cfg: PlatformConfig, wf: Workflow) -> List[int]:
    """Estimated execution order S: level-major, EFT-ascending within level."""
    assign_levels(wf)
    ref = cfg.vm_types[0]  # cheapest type as the reference estimator
    eft = estimated_eft(cfg, wf, ref)
    order = sorted(
        range(wf.n_tasks),
        key=lambda tid: (wf.tasks[tid].level, eft[tid], tid),
    )
    for rank, tid in enumerate(order):
        wf.tasks[tid].rank = rank
    wf.rank_cache = None   # ranks changed; drop the memoized list
    return order


# Subsets up to this size take the pure-Python distribution path: ~20
# numpy dispatches cost more than the loop at Algorithm 3's per-finish
# call sizes.  Both paths execute the identical float64 operation
# sequence, so the cutover is invisible in results (bit-exact).
_PY_DISTRIBUTE_MAX = 64


def _sum_like_numpy(values: List[float]) -> float:
    """``float(np.sum(np.asarray(values)))`` without the array round-trip
    for the small-n regime, preserving numpy's exact summation order:
    n < 8 is a plain sequential reduction; 8 ≤ n ≤ 128 is the 8-lane
    pairwise block numpy uses below its recursion blocksize.  Falls back
    to numpy above that, and the replication is verified at import
    (``_SUM_VERIFIED``) so a change in numpy's reduction would be
    caught, not silently diverge."""
    n = len(values)
    if not _SUM_VERIFIED or n > 128:
        return float(np.sum(np.asarray(values)))
    if n < 8:
        s = 0.0
        for x in values:
            s += x
        return s
    r0, r1, r2, r3, r4, r5, r6, r7 = values[:8]
    i = 8
    stop = n - (n % 8)
    while i < stop:
        r0 += values[i]
        r1 += values[i + 1]
        r2 += values[i + 2]
        r3 += values[i + 3]
        r4 += values[i + 4]
        r5 += values[i + 5]
        r6 += values[i + 6]
        r7 += values[i + 7]
        i += 8
    s = ((r0 + r1) + (r2 + r3)) + ((r4 + r5) + (r6 + r7))
    while i < n:
        s += values[i]
        i += 1
    return s


def _verify_sum_compat() -> bool:
    global _SUM_VERIFIED
    _SUM_VERIFIED = True   # let _sum_like_numpy take the scalar paths
    rng = np.random.default_rng(0)
    for n in (*range(1, 18), 31, 64, 65, 127, 128):
        a = (rng.random(n) * rng.integers(1, 1000, n)).tolist()
        if _sum_like_numpy(a) != float(np.sum(np.asarray(a))):
            return False
    return True


_SUM_VERIFIED = _verify_sum_compat()


def _distribute_small(wf: Workflow, table, budget: float,
                      order: List[int]) -> float:
    """Pure-Python Algorithm 1 passes for small ``order`` subsets.

    Mirrors the vectorized body below operation-for-operation: pass 1 is
    the same sequential cumulative sum (``np.cumsum`` adds in index
    order) with ``remaining`` from the numpy-order total, and the SFTD
    sweep reads the table's plain-list mirror.

    The sweep keeps a *live* row list instead of re-scanning everything:
    ``remaining`` only ever decreases, and a row's upgrade delta is
    unchanged until the row itself upgrades — so a row that once fails
    the paid-upgrade check can never succeed later and is dropped, and a
    row at the top tier is done.  The rows it visits make exactly the
    decisions the full re-scan would (skipped rows change nothing), so
    allocations are bit-identical.
    """
    cheap = table.cheap_list
    running = 0.0
    alloc: List[float] = []
    for tid in order:
        w = cheap[tid]
        running = running + w
        avail = budget - (running - w)
        if avail < 0.0:
            avail = 0.0
        alloc.append(w if w < avail else avail)
    remaining = max(budget - _sum_like_numpy(alloc), 0.0)

    if remaining > 1e-9:
        tier_list = table.tier_list
        K = len(tier_list[0])
        top = K - 1
        # "Everyone tops out" shortcut: with nondecreasing tier costs,
        # the sweep's total consumption to bring every row to the top
        # tier is exactly Σ(top − alloc); when the remainder covers that
        # with margin (the 1e-6 safety dwarfs any accumulated rounding in
        # the ≤ U·K subtractions the sweep would make, so every paid
        # check the sweep would run is guaranteed to pass), the fixed
        # point is known without sweeping.
        if table.tiers_monotone:
            top_l = table.top_list
            need = 0.0
            for u, tid in enumerate(order):
                need += top_l[tid] - alloc[u]
            if remaining > need + 1e-6:
                remaining -= need
                tasks = wf.tasks
                for pos, tid in enumerate(order):
                    tasks[tid].budget = top_l[tid]
                return max(remaining, 0.0)
        # First sweep fused with tier-discovery: current tier = highest
        # covered (same `alloc >= tier_cost - 1e-9` predicate as the
        # array path), then the usual one-tier upgrade attempt.  Upgrade
        # attempts continue through the whole sweep even once
        # ``remaining`` dips under the sweep-entry threshold — exactly
        # the reference loop's within-sweep behavior.
        live: List[list] = []   # [u, k, row] for rows that may still move
        monotone = table.tiers_monotone
        for u, a in enumerate(alloc):
            row = tier_list[order[u]]
            if monotone:
                # Nondecreasing row ⇒ the covered set is a prefix: walk
                # up and stop at the first uncovered tier (same result
                # as the descending scan, fewer comparisons — most rows
                # sit at low tiers).
                k = 0
                for j in range(1, K):
                    if a >= row[j] - 1e-9:
                        k = j
                    else:
                        break
            else:
                k = 0
                for j in range(top, -1, -1):
                    if a >= row[j] - 1e-9:
                        k = j
                        break
            if k >= top:
                continue
            delta = row[k + 1] - a
            if 0 < delta <= remaining + 1e-9:
                alloc[u] = row[k + 1]
                remaining -= delta
                k += 1
            elif delta <= 0:
                k += 1
            else:
                continue  # paid check failed: can never succeed later
            if k < top:
                live.append([u, k, row])
        while live and remaining > 1e-9:
            nxt: List[list] = []
            for item in live:
                u, k, row = item
                delta = row[k + 1] - alloc[u]
                if 0 < delta <= remaining + 1e-9:
                    alloc[u] = row[k + 1]
                    remaining -= delta
                elif delta > 0:
                    continue  # dropped forever
                item[1] = k = k + 1
                if k < top:
                    nxt.append(item)
            live = nxt

    tasks = wf.tasks
    for pos, tid in enumerate(order):
        tasks[tid].budget = alloc[pos]
    return max(remaining, 0.0)


def distribute_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    budget: float,
    task_ids: Optional[Sequence[int]] = None,
    presorted: bool = False,
) -> float:
    """Algorithm 1.  Mutates ``task.budget``; returns the undistributed
    remainder (spare budget — Alg. 3 folds it into the next update so no
    money is ever lost).

    Pass 1 allocates the cheapest-VM conservative cost to tasks in order
    *while the pool lasts* (the paper's ``while β > 0``); once exhausted,
    later tasks receive whatever fraction remains (possibly zero).  Budget
    is strictly conserved: Σ sub-budgets ≤ β always.

    Pass 2 (SFTD) upgrades the earliest tasks in ``S`` to the fastest type
    still affordable with the leftover.

    ``task_ids`` restricts distribution to a subset (used by Algorithm 3 to
    redistribute over unscheduled tasks); order within the subset follows the
    original estimated execution order (``task.rank``).

    Both passes read the workflow's :class:`~core.cost_tables.CostTable`:
    pass 1 is a masked cumulative reduction over the cheapest-type column,
    pass 2 sweeps the precomputed ``[U, V]`` tier-cost slice.
    """
    if task_ids is None:
        order = execution_order(cfg, wf)
    elif presorted:
        order = task_ids
    else:
        ranks = wf.rank_cache
        if ranks is None:
            # Ranks are frozen once the arrival-time distribution ran;
            # the per-finish Algorithm 3 path sorts against this list
            # instead of a per-call attribute-chasing lambda.
            wf.rank_cache = ranks = [t.rank for t in wf.tasks]
        order = sorted(task_ids, key=ranks.__getitem__)
    if not order:
        return budget

    table = cost_tables.table_for(cfg, wf)
    if len(order) <= _PY_DISTRIBUTE_MAX:
        return _distribute_small(wf, table, budget, order)
    order_arr = np.asarray(order, np.int64)
    # Pass 1: cheapest-VM conservative cost, allocated while the pool
    # lasts — give_i = min(want_i, max(β − Σ_{<i} give, 0)), as a masked
    # cumulative table reduction (cfg.vm_types[0] is the cheapest type,
    # mirroring the reference estimator in execution_order).
    want = table.est_full_cost[order_arr, 0]
    cum = np.cumsum(want)
    alloc = np.minimum(want, np.maximum(budget - (cum - want), 0.0))
    remaining = max(budget - float(alloc.sum()), 0.0)

    # Pass 2 (SFTD): sweep the order earliest-first, upgrading each task's
    # allocation by ONE VM-type tier per visit ("upgrade ... for a faster VM
    # type starting from the earliest tasks"), until a sweep changes nothing.
    # One-tier sweeps keep the allocation distribution unimodal — the whole
    # workflow climbs the VM ladder together instead of splitting into a
    # fastest/cheapest bimodal mix (which would pollute the shared pool with
    # slow cache-carrier VMs).
    give = alloc.tolist()
    if remaining > 1e-9 and table.tiers_monotone:
        # Same "everyone tops out" shortcut as the small-subset path,
        # with the identical scalar accumulation so both paths stay
        # bit-exact around the size cutover.
        top_l = table.top_list
        need = 0.0
        for u, tid in enumerate(order):
            need += top_l[tid] - give[u]
        if remaining > need + 1e-6:
            remaining -= need
            tasks = wf.tasks
            for tid in order:
                tasks[tid].budget = top_l[tid]
            return max(remaining, 0.0)
    if remaining > 0:
        tier_cost = table.tier_cost[order_arr]
        K = tier_cost.shape[1]
        # Current tier: highest tier fully covered by the allocation.
        covered = alloc[:, None] >= tier_cost - 1e-9
        any_cov = covered.any(axis=1)
        highest = K - 1 - np.argmax(covered[:, ::-1], axis=1)
        tier_of = np.where(any_cov, highest, 0).tolist()
        # The sweep itself runs on plain Python floats (the same IEEE
        # doubles the array holds — ``tolist`` is value-preserving), which
        # is several times faster than per-element numpy indexing on the
        # per-finish Algorithm 3 hot path.
        tc = tier_cost.tolist()
        changed = True
        while remaining > 1e-9 and changed:
            changed = False
            for u in range(len(give)):
                k = tier_of[u]
                if k + 1 >= K:
                    continue
                delta = tc[u][k + 1] - give[u]
                if 0 < delta <= remaining + 1e-9:
                    give[u] = tc[u][k + 1]
                    tier_of[u] = k + 1
                    remaining -= delta
                    changed = True
                elif delta <= 0:
                    tier_of[u] = k + 1
                    changed = True

    tasks = wf.tasks
    for pos, tid in enumerate(order):
        tasks[tid].budget = give[pos]
    return max(remaining, 0.0)


def update_budget(
    cfg: PlatformConfig,
    wf: Workflow,
    finished_tid: int,
    actual_cost: float,
    spare_budget: float,
    unscheduled: Sequence[int],
) -> float:
    """Algorithm 3.  Returns the new spare budget.

    The finished task's allocation plus the spare budget absorb the actual
    cost; any surplus (or debt) flows into the pool redistributed over the
    unscheduled tasks, so uncertainty never propagates into a violation.
    The undistributed remainder of the redistribution persists as the spare
    (conservation: money is never created or silently dropped).

    ``unscheduled`` may come in any order (the engine hands over its raw
    set): the rank order of the estimated execution sequence S — which
    the redistribution consumes anyway — is the one deterministic order
    used for both the pool summation and the distribution, computed once.
    """
    tasks = wf.tasks
    t_f = tasks[finished_tid]
    if unscheduled:
        ranks = wf.rank_cache
        if ranks is None:
            wf.rank_cache = ranks = [t.rank for t in tasks]
        order = sorted(unscheduled, key=ranks.__getitem__)
        pool = sum([tasks[tid].budget for tid in order])
    else:
        order = None
        pool = 0.0
    headroom = t_f.budget + spare_budget
    if actual_cost <= headroom:
        pool += headroom - actual_cost
    else:
        pool -= actual_cost - headroom
    pool = max(pool, 0.0)
    if order:
        return distribute_budget(cfg, wf, pool, task_ids=order,
                                 presorted=True)
    return pool


# ---------------------------------------------------------------------------
# Array-path Algorithm 3 (the engine hot path)
# ---------------------------------------------------------------------------

# REPRO_SCALAR_REDIST=1 forces the scalar update_budget reference on the
# engine hot path — the oracle knob for parity tests and bisection, the
# exact analogue of scheduler.py's REPRO_SCALAR_SELECT.
_ARRAY_REDIST = _os.environ.get("REPRO_SCALAR_REDIST") != "1"


class RedistState:
    """Live per-workflow state for the array-path Algorithm 3.

    The scalar :func:`update_budget` pays three per-call costs that scale
    with the unscheduled count ``U``: sorting the engine's raw set into
    rank order, gathering the pool from task attributes, and the per-tier
    SFTD rescan.  This state removes the first two: the estimated
    execution order ``S`` is stored once as an index array, scheduling
    only ever *clears* mask bits (:meth:`mark_scheduled`), so the
    rank-ordered unscheduled rows are a boolean compress; and
    ``budget_vec`` mirrors every task's current sub-budget as float64 so
    the pool gather is one fancy index (summed in the scalar reference's
    exact order — see :func:`update_budget_fast`).

    Because the row set only changes at :meth:`mark_scheduled`, every
    pure function of the rows is memoized between scheduling events —
    the compress itself, the cheapest-column gather and its cumulative
    sum (pass 1 of Algorithm 1 depends on the pool only through two
    scalars), the ``[U, K]`` tier slice, and a running ``top_sum`` that
    turns the "everyone tops out" screen into two flops (the cached sum
    drifts from the exact reduction by at most ~n·eps, which the
    screen's margin dominates — it only ever errs toward running the
    exact check).  A typical engine trace schedules a burst of tasks,
    then redistributes across many finishes with the same row set, so
    the caches hit on most calls.

    Lives on the engine's per-workflow ``_WfState`` (never on the
    :class:`Workflow` itself: structural-sharing clones share task lists
    across grid members, while the mask/budget mirror is per-member
    mutable state).
    """

    __slots__ = ("order_all", "pos_of", "mask", "budget_vec", "top_sum",
                 "_top_list", "_rows", "_rows_list", "_want", "_cum",
                 "_want_sum", "_tcr")

    def __init__(self, cfg: PlatformConfig, wf: Workflow,
                 unscheduled: Optional[Sequence[int]] = None,
                 backing: Optional[Tuple[np.ndarray, np.ndarray,
                                         np.ndarray, np.ndarray]] = None):
        """``backing``: optional ``(order, pos, mask, budget)`` array
        segments — slices of a ``core.types.StreamState`` pool — to fill
        and use in place of fresh per-workflow allocations.  Values and
        semantics are identical either way (the arrays are just owned by
        a shared backing instead of this object)."""
        ranks = wf.rank_cache
        if ranks is None:
            wf.rank_cache = ranks = [t.rank for t in wf.tasks]
        n = wf.n_tasks
        # Ranks are a permutation (execution_order assigns positions), so
        # the stable argsort equals the scalar path's sorted(..., key=rank).
        order = np.argsort(np.asarray(ranks, np.int64), kind="stable")
        if backing is None:
            self.order_all = order                 # S: tids, rank-ascending
            pos = np.empty(n, np.int64)
        else:
            out_order, pos, out_mask, out_budget = backing
            out_order[:] = order
            self.order_all = order = out_order
        pos[order] = np.arange(n, dtype=np.int64)
        self.pos_of = pos                          # tid -> position in S
        if backing is None:
            mask = np.ones(n, bool) if unscheduled is None \
                else np.zeros(n, bool)
        else:
            mask = out_mask
            mask[:] = unscheduled is None
        if unscheduled is not None:
            pos_l = pos.tolist()
            for tid in unscheduled:
                mask[pos_l[tid]] = True
        self.mask = mask
        if backing is None:
            self.budget_vec = np.array([t.budget for t in wf.tasks],
                                       np.float64)
        else:
            out_budget[:] = [t.budget for t in wf.tasks]
            self.budget_vec = out_budget
        self._rows = None
        self._rows_list = None
        self._want = None
        self._cum = None
        self._want_sum = 0.0
        self._tcr = None
        table = cost_tables.table_for(cfg, wf)
        if table.tiers_monotone:
            self._top_list = table.top_list
            r = self.rows()
            self.top_sum = float(table.top_arr[r].sum()) if r.size else 0.0
        else:
            self._top_list = None
            self.top_sum = 0.0

    def mark_scheduled(self, tid: int) -> None:
        self.mask[self.pos_of[tid]] = False
        self._rows = None
        self._rows_list = None
        self._want = None
        self._cum = None
        self._tcr = None
        if self._top_list is not None:
            self.top_sum -= self._top_list[tid]

    def mark_unscheduled(self, tid: int) -> None:
        """Exact inverse of :meth:`mark_scheduled` — readmit a requeued
        task (chaos re-execution) into the redistribution pool."""
        self.mask[self.pos_of[tid]] = True
        self._rows = None
        self._rows_list = None
        self._want = None
        self._cum = None
        self._tcr = None
        if self._top_list is not None:
            self.top_sum += self._top_list[tid]

    def rows(self) -> np.ndarray:
        """Unscheduled tids in rank order (the compress of S)."""
        r = self._rows
        if r is None:
            r = self._rows = self.order_all[self.mask]
        return r


def update_budget_fast(
    cfg: PlatformConfig,
    wf: Workflow,
    rs: RedistState,
    finished_tid: int,
    actual_cost: float,
    spare_budget: float,
) -> float:
    """Array-path Algorithm 3 — bit-exact with :func:`update_budget`.

    The pool is summed with the builtin over the gathered row budgets
    (``tolist`` is value-preserving, and the rows are in rank order —
    the identical float sequence the scalar reference reduces), the
    headroom fold is the same scalar expression, and the redistribution
    runs through :func:`_distribute_rows`, which replicates
    :func:`distribute_budget` operation-for-operation.

    One shortcut the scalar path lacks: a zero pool redistributed over
    already-all-zero budgets is the identity (pass 1 allocates zero to
    every row and the sweep never runs), so the call returns without
    touching the tasks — the common steady state of debt-heavy regimes.
    """
    rows = rs.rows()
    if rows.size:
        vals = rs.budget_vec[rows]
        pool = sum(vals.tolist())
    else:
        pool = 0.0
    headroom = wf.tasks[finished_tid].budget + spare_budget
    if actual_cost <= headroom:
        pool += headroom - actual_cost
    else:
        pool -= actual_cost - headroom
    pool = max(pool, 0.0)
    if not rows.size:
        return pool
    if pool == 0.0 and not vals.any():
        return 0.0
    return _distribute_rows(cfg, wf, rs, rows, pool, vals)


def update_budget_pooled(
    cfg: PlatformConfig,
    wf: Workflow,
    rs: RedistState,
    surplus: float,
    spare_budget: float,
) -> float:
    """Round-batched Algorithm 3 (array path): one redistribution for a
    whole rendezvous round's worth of task-finish events.

    ``surplus`` is the banked ``Σ (budget_f − actual_f)`` over the
    coalesced finishes.  In exact arithmetic the chained per-finish
    updates and this pooled form conserve the same money; in float they
    differ (surplus flows reorder), which is why the mode is opt-in and
    A/B-gated rather than parity-gated.  Bit-exact with
    :func:`update_budget_pooled_scalar` (the oracle form).
    """
    rows = rs.rows()
    if rows.size:
        vals = rs.budget_vec[rows]
        pool = sum(vals.tolist())
    else:
        pool = 0.0
    pool += spare_budget + surplus
    pool = max(pool, 0.0)
    if not rows.size:
        return pool
    if pool == 0.0 and not vals.any():
        return 0.0
    return _distribute_rows(cfg, wf, rs, rows, pool, vals)


def update_budget_pooled_scalar(
    cfg: PlatformConfig,
    wf: Workflow,
    surplus: float,
    spare_budget: float,
    unscheduled: Sequence[int],
) -> float:
    """Scalar oracle for :func:`update_budget_pooled` (same pooled
    semantics on the reference sort/sum/distribute path); the engine uses
    it when ``REPRO_SCALAR_REDIST=1`` forces the scalar hot path."""
    tasks = wf.tasks
    if unscheduled:
        ranks = wf.rank_cache
        if ranks is None:
            wf.rank_cache = ranks = [t.rank for t in tasks]
        order = sorted(unscheduled, key=ranks.__getitem__)
        pool = sum([tasks[tid].budget for tid in order])
    else:
        order = None
        pool = 0.0
    pool += spare_budget + surplus
    pool = max(pool, 0.0)
    if order:
        return distribute_budget(cfg, wf, pool, task_ids=order,
                                 presorted=True)
    return pool


def _distribute_rows(
    cfg: PlatformConfig,
    wf: Workflow,
    rs: RedistState,
    rows: np.ndarray,
    budget: float,
    old: Optional[np.ndarray] = None,
) -> float:
    """Algorithm 1 over the rank-ordered row array — the redistribution
    core of the array path, bit-exact with
    ``distribute_budget(..., task_ids=rows, presorted=True)``.

    Small subsets delegate to the shared pure-Python path (identical
    object); larger ones replicate the numpy branch: the same pass-1
    cumulative reduction over the contiguous cheapest column (gathered
    once per row set and memoized on ``rs``), the same
    scalar-accumulated "everyone tops out" shortcut behind the cached
    ``top_sum`` screen, and the SFTD sweep via :func:`_bulk_sweep`.
    Also syncs ``rs.budget_vec`` with the written ``task.budget``
    values.  ``old`` is the caller's already-gathered current row
    budgets (skips re-gathering for the diff-only writeback).
    """
    table = cost_tables.table_for(cfg, wf)
    tasks = wf.tasks
    if rows.size <= _PY_DISTRIBUTE_MAX:
        order = rs._rows_list
        if order is None or len(order) != rows.size:
            order = rs._rows_list = rows.tolist()
        rem = _distribute_small(wf, table, budget, order)
        rs.budget_vec[rows] = [tasks[tid].budget for tid in order]
        return rem

    if old is None:
        old = rs.budget_vec[rows]

    def writeback(new: np.ndarray) -> None:
        # task.budget mirrors budget_vec by invariant, so only rows whose
        # value moved need the (Python-priced) attribute write; the
        # written floats are identical either way.
        changed = np.flatnonzero(old != new)
        if changed.size:
            for tid, b in zip(rows[changed].tolist(),
                              new[changed].tolist()):
                tasks[tid].budget = b
            rs.budget_vec[rows] = new
    # Pass 1 — identical ops to distribute_budget's numpy branch
    # (cheap_arr is a contiguous copy of est_full_cost[:, 0]).  The
    # gather and its cumsum depend only on the row set, so they are
    # memoized on the state; the pool enters through two scalars.
    want = rs._want
    if want is None:
        want = rs._want = table.cheap_arr[rows]
        rs._cum = np.cumsum(want)
        rs._want_sum = float(want.sum())
    cum = rs._cum
    total_want = float(cum[-1])
    if budget >= total_want + 1e-6 + 1e-12 * (abs(budget) + total_want):
        # Fully funded with margin: every per-row ``budget − (cum−want)``
        # provably rounds at or above ``want`` (the margin dwarfs the one
        # subtraction's rounding), so pass 1 allocates exactly ``want``
        # and the pairwise sum is the cached one.  Boundary cases fall
        # through to the literal expression.
        alloc = want.copy()
        alloc_sum = rs._want_sum
    else:
        alloc = np.minimum(want, np.maximum(budget - (cum - want), 0.0))
        alloc_sum = float(alloc.sum())
    remaining = max(budget - alloc_sum, 0.0)

    if remaining > 1e-9 and rs._top_list is not None:
        # "Everyone tops out" shortcut.  The reference accumulates
        # ``need`` with an exact scalar loop; the cached running
        # ``top_sum`` gives a two-flop screen — when the remainder
        # provably can't clear the exact need (the usual exhaustion
        # regime), the loop and the shortcut are skipped without any
        # observable difference, since the reference discards ``need``
        # on a non-firing shortcut too.  The screen's error term covers
        # the cached sum's drift (≤ ~n·eps relative) with orders of
        # magnitude to spare, so it only errs toward running the loop.
        need_est = rs.top_sum - alloc_sum
        err = 1e-9 * (abs(rs.top_sum) + abs(alloc_sum) + 1.0)
        if remaining > need_est - err + 1e-6:
            # May fire: replicate the reference's exact accumulation
            # order (top − give, row-ascending).
            top_v = table.top_arr[rows]
            need = 0.0
            for t, g in zip(top_v.tolist(), alloc.tolist()):
                need += t - g
            if remaining > need + 1e-6:
                remaining -= need
                writeback(top_v)
                return max(remaining, 0.0)
    if remaining > 1e-9:
        tcr = rs._tcr
        if tcr is None:
            tcr = rs._tcr = table.tier_cost[rows]
        remaining = _bulk_sweep(table, tcr, alloc, remaining)
    writeback(alloc)
    return max(remaining, 0.0)


def _discover_tiers(tcr: np.ndarray, alloc: np.ndarray, K: int):
    """Current tier of each row: highest tier covered by the allocation
    — the numpy reference branch's exact predicate.  Returns
    ``(tier, alive)``."""
    covered = alloc[:, None] >= tcr - 1e-9
    any_cov = covered.any(axis=1)
    highest = K - 1 - np.argmax(covered[:, ::-1], axis=1)
    tier = np.where(any_cov, highest, 0)
    return tier, np.flatnonzero(tier < K - 1)


def _commit_candidates(ci: np.ndarray, cd: np.ndarray, remaining: float):
    """Sequential paid checks over a sweep's boundary candidates,
    vectorized where provable.  Returns ``(committed_positions,
    remaining)`` with ``remaining`` advanced by the exact per-row chain.

    The longest cumulative-sum prefix that provably fits commits in
    bulk: before prefix candidate ``i`` the reference's remainder is at
    least ``remaining − Σ_{j≤i} d_j`` up to the chain's accumulated
    rounding, and the margin (the same shape as the sweep predicates)
    dominates both that and the cumsum-vs-chain reassociation, so every
    prefix check passes.  ``remaining`` still advances by the exact
    subtraction chain.  The tail is then pre-filtered against the
    post-prefix remainder — the remainder only decreases, so a tail
    candidate already above it can never commit at its later visit —
    and the few survivors run the reference's decision loop verbatim.
    """
    cum = np.cumsum(cd)
    margin = 1e-6 + 1e-12 * (abs(remaining) + float(cum[-1])) * ci.size
    m = int(np.searchsorted(cum, remaining - margin, side="right"))
    if m:
        for d in cd[:m].tolist():
            remaining -= d
        if m == ci.size:
            return ci, remaining
    tail_d = cd[m:]
    keep = tail_d <= remaining + 1e-9
    if not keep.any():
        return ci[:m], remaining
    commit: List[int] = []
    for pos, d in zip(ci[m:][keep].tolist(), tail_d[keep].tolist()):
        if 0 < d <= remaining + 1e-9:
            remaining -= d
            commit.append(pos)
        # else: dead — the remainder shrank past it mid-sweep
    if not commit:
        return ci[:m], remaining
    cp = np.asarray(commit, np.int64)
    if m:
        cp = np.concatenate([ci[:m], cp])
    return cp, remaining


def _bulk_sweep(table, tcr: np.ndarray, alloc: np.ndarray,
                remaining: float) -> float:
    """SFTD sweep, one whole sweep per step, mutating ``alloc`` in place.

    The reference sweep visits rows in order, upgrading each by one tier
    when the paid check ``0 < delta ≤ remaining + 1e-9`` passes, and
    rescans until a sweep changes nothing.  Two vectorized regimes cover
    it bit-exactly:

    * **Guaranteed success** — the entry remainder exceeds the summed
      paid deltas by a conservative margin (covering both the
      pairwise-sum error of the total and the accumulated rounding of
      the sequential chain), so *every* sequential paid check provably
      passes: before row ``i`` the reference's remainder is at least
      ``remaining − Σ_{j<i} d_j`` up to that rounding, which the margin
      dominates.  Give/tier updates commit as array writes; ``remaining``
      still advances by the exact per-row subtraction chain (the same
      float sequence the reference executes), keeping the returned spare
      bit-identical.

    * **Exhaustion** — otherwise, a paid row whose delta exceeds even
      the sweep-entry remainder can never succeed (the remainder only
      decreases and a row's delta is fixed until its tier moves — the
      same live-list argument as :func:`_distribute_small`): those rows
      die permanently.  Free advances (``delta ≤ 0``) don't touch the
      remainder and commit vectorized; the boundary candidates go
      through :func:`_commit_candidates` (guaranteed prefix + exact
      tail).

    Monotone tier tables (the usual case) take a specialized iteration:
    after discovery every delta is positive (the highest-covered tier
    bounds the allocation strictly below the next tier's cost, and a
    committed row lands exactly on a tier value), so the paid/free
    bookkeeping collapses — zero deltas (duplicate adjacent tier costs)
    are detected with one ``all()`` and routed to the generic step.
    Discovery itself short-circuits when no row covers tier 1 (always
    true right after pass 1 unless tier costs nearly coincide): every
    row's highest covered tier is then 0, matching the reference's
    ``where(any_cov, highest, 0)`` without the ``[n, K]`` scan.

    A row that neither advanced nor died keeps its state and is
    revisited next sweep, exactly like the reference rescan.
    """
    K = tcr.shape[1]
    if K < 2:
        return remaining
    mono = table.tiers_monotone
    if mono and not (alloc >= tcr[:, 1] - 1e-9).any():
        # No row covers tier 1 ⇒ (monotone) none covers any higher tier
        # ⇒ every row sits at tier 0 (covered there or not — the
        # reference assigns 0 either way).
        tier = np.zeros(alloc.size, np.int64)
        alive = np.arange(alloc.size)
    else:
        tier, alive = _discover_tiers(tcr, alloc, K)
    while remaining > 1e-9 and alive.size:
        nxt = tcr[alive, tier[alive] + 1]
        delta = nxt - alloc[alive]
        if mono and delta.all():
            # Monotone fast step: every row is a paid upgrade.
            total = float(delta.sum())
            margin = 1e-6 + 1e-12 * (abs(remaining) + total) * alive.size
            if remaining > total + margin:
                alloc[alive] = nxt
                tier[alive] += 1
                for d in delta.tolist():     # exact reference chain
                    remaining -= d
                alive = alive[tier[alive] < K - 1]
                continue
            ci = np.flatnonzero(delta <= remaining + 1e-9)
            if not ci.size:
                break                        # everyone died: fixed point
            cp, remaining = _commit_candidates(ci, delta[ci], remaining)
            if not cp.size:
                break
            rc = alive[cp]
            alloc[rc] = nxt[cp]
            tier[rc] += 1
            alive = rc[tier[rc] < K - 1]
            continue
        # Generic step (non-monotone tables, or zero/negative deltas).
        paid = delta > 0.0
        pd = delta[paid]
        total = float(pd.sum())
        margin = 1e-6 + 1e-12 * (abs(remaining) + total) * alive.size
        if remaining > total + margin:
            # Guaranteed success: commit the whole sweep in bulk.
            alloc[alive[paid]] = nxt[paid]
            tier[alive] += 1                 # free rows advance too
            for d in pd.tolist():            # exact reference chain
                remaining -= d
            alive = alive[tier[alive] < K - 1]
            continue
        advanced = ~paid                     # free rows always advance
        if advanced.any():
            tier[alive[advanced]] += 1
        cand = paid & (delta <= remaining + 1e-9)
        ci = np.flatnonzero(cand)
        if ci.size:
            cp, remaining = _commit_candidates(ci, delta[ci], remaining)
            if cp.size:
                rc = alive[cp]
                alloc[rc] = nxt[cp]
                tier[rc] += 1
                advanced[cp] = True
        if not advanced.any():
            break                            # nothing changed: fixed point
        alive = alive[advanced]
        alive = alive[tier[alive] < K - 1]
    return remaining


def min_max_workflow_cost(cfg: PlatformConfig, wf: Workflow) -> tuple:
    """Budget-range estimate used by workload generation (Section 5).

    Minimum: sequential execution of every task on the cheapest type.
    Maximum: every task on its own fastest-type VM (max parallel spend).
    """
    table = cost_tables.table_for(cfg, wf)
    cheapest = cfg.vm_types[0]
    fastest_idx = max(range(len(cfg.vm_types)),
                      key=lambda i: cfg.vm_types[i].mips)
    lo = float(table.cost_bare[:, 0].sum())
    # Sequential on one VM: charge provisioning + one container once.
    lo += costs.billed_cost(
        cfg, cheapest, cfg.vm_provision_delay_ms + cfg.container_provision_ms
    )
    hi = float(table.est_full_cost[:, fastest_idx].sum())
    return lo, hi

"""Discrete-event WaaS simulation engine (reference implementation).

Event-driven, heap-ordered, integer-millisecond clock.  Scheduling cycles run
after all events at a timestamp are applied — exactly the paper's trigger
rule ("the arrival of a new workflow's job and the completion of a task").

The state-transition semantics live in :class:`SimState` — arrival / finish /
VM_READY / REAP handling, the execution pipeline, budget redistribution via
Algorithm 3, and the cycle commit protocol.  Two engines drive that one
source of truth:

* :class:`SimEngine` (here) — the sequential semantic oracle, one
  (policy, workload) per run;
* ``core.jax_engine.BatchSimEngine`` — lockstep rounds over a whole
  experiment grid with the per-cycle scoring batched onto the device
  (property-tested bit-exact against this engine in
  ``tests/test_jax_engine.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
import math as _math
import os as _os
import pickle as _pickle
import time as _time
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from . import budget as budget_mod
from . import cost_tables, costs
from .mslbl import distribute_budget_mslbl
from .scheduler import Placement, Policy, select
from .types import (
    MS,
    PlatformConfig,
    SimResult,
    StreamState,
    Task,
    Workflow,
    WorkflowResult,
    degradation_tables,
)
from ..chaos import ChaosConfig, chaos_draws
from ..obs import events as obs_events
from ..obs import monitor as obs_monitor
from ..obs import timeseries as obs_ts
from ..obs.events import EventLog
from ..sim.cloud import (VM, VM_BUSY, VM_IDLE, VM_PROVISIONING,
                         VM_TERMINATED, DataKey, VMPool)

ARRIVAL, FINISH, VM_READY, REAP, REVOKE = 0, 1, 2, 3, 4

# Auction engagement threshold for a solo SimEngine cycle (queue × pool
# pair count).  The grid engine amortizes device calls across members and
# uses the lower core.jax_engine.AUCTION_MIN_PAIRS_GRID.
AUCTION_MIN_PAIRS = 8192

# Queue-order metadata for one cycle's drained tasks: (wid, tid, inputs).
CycleMeta = Tuple[int, int, List[Tuple[DataKey, float]]]


def _profile_enabled() -> bool:
    """Opt-in per-phase timing (``REPRO_PROFILE=1``).

    Off by default: the counters wrap the per-dispatch hot path with two
    ``perf_counter`` calls each, which is measurable at paper scale.  Read
    per ``SimState`` so tests can toggle via monkeypatch.
    """
    return _os.environ.get("REPRO_PROFILE") == "1"


def _object_state_forced() -> bool:
    """``REPRO_OBJECT_STATE=1`` forces the legacy per-workflow object
    state (`_WfState` dicts/sets) instead of the structure-of-arrays
    ``StreamState`` default — the debugging/bisection escape hatch, the
    state-layer analogue of ``REPRO_SCALAR_SELECT`` /
    ``REPRO_SCALAR_REDIST``.  Read per ``SimState`` so tests can toggle
    it without re-importing."""
    return _os.environ.get("REPRO_OBJECT_STATE") == "1"

# Version tag for SimState.snapshot() payloads (bumped on layout
# changes; repro.ckpt.checkpoint.restore_stream refuses newer ones).
# v2: chaos residue (attempt/preemption counters, injection tallies) and
#     the extended _Running fields (start_ms, rt_ms, est_rt_ms).
#     The live monitor (repro.obs.monitor) needs no version of its own:
#     it rides the opaque elog pickle as ``elog.sub`` — v2 snapshots
#     written before the monitor existed restore with ``sub = None``.
STREAM_SNAPSHOT_VERSION = 2


def new_profile() -> Dict[str, float]:
    """Fresh per-phase counter block (seconds + call counts)."""
    return {
        "distribute_s": 0.0,      # Algorithm 1 / MSLBL arrival distribution
        "redistribute_s": 0.0,    # Algorithm 3 redistribution (either mode)
        "select_s": 0.0,          # per-task scheduler.select calls
        "pipeline_s": 0.0,        # execution-pipeline math + cache updates
        "distributions": 0.0,
        "redistributions": 0.0,       # Algorithm-3 distribute invocations
        "redistribute_events": 0.0,   # task finishes feeding them (≥ above
        #                               in round mode: events coalesce)
        "selects": 0.0,
        "pipelines": 0.0,             # _start_pipeline timer pairs
    }


# Calibrated-once cost of one perf_counter bracket (two calls), the unit
# the self-measured profile_overhead_s is denominated in.
_PAIR_COST_S: Optional[float] = None


def _perf_pair_cost_s() -> float:
    global _PAIR_COST_S
    if _PAIR_COST_S is None:
        n = 10000
        t0 = _time.perf_counter()
        for _ in range(n):
            _time.perf_counter()
            _time.perf_counter()
        _PAIR_COST_S = (_time.perf_counter() - t0) / n
    return _PAIR_COST_S


def profile_overhead_s(prof: Dict[str, float]) -> float:
    """Self-measured cost of the profiling counters themselves: every
    instrumented phase wraps its body in one ``perf_counter`` bracket,
    so the overhead is (brackets taken) × (calibrated bracket cost).
    Surfaced as ``dispatch_stats()["profile"]["profile_overhead_s"]`` so
    consumers can judge whether the counters perturb what they time."""
    pairs = (prof.get("distributions", 0.0)
             + prof.get("redistributions", 0.0)
             + prof.get("selects", 0.0)
             + prof.get("pipelines", 0.0))
    return pairs * _perf_pair_cost_s()


@dataclasses.dataclass(slots=True)
class _WfState:
    """Legacy per-workflow object state (``REPRO_OBJECT_STATE=1``).

    Shares the accessor-method interface of :class:`_WfView` so every
    ``SimState`` transition is state-layout-agnostic; the two layouts
    are parity-gated in ``tests/test_dispatcher_matrix.py``."""

    wf: Workflow
    spare: float = 0.0
    cost: float = 0.0
    remaining: int = 0
    finish_ms: int = 0
    unscheduled: Set[int] = dataclasses.field(default_factory=set)
    pending_parents: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Array-path Algorithm 3 (core.budget.RedistState), built lazily at
    # the first redistribution; None when the scalar oracle is forced.
    redist: Optional[budget_mod.RedistState] = None
    # Round-batched mode: surplus banked since the last flush, and the
    # number of finish events it coalesces.
    pending_surplus: float = 0.0
    pending_events: int = 0

    def begin_arrival(self) -> None:
        wf = self.wf
        self.remaining = wf.n_tasks
        self.unscheduled = set(range(wf.n_tasks))
        self.pending_parents = {t.tid: len(t.parents) for t in wf.tasks}

    def unscheduled_seq(self) -> Sequence[int]:
        """Unscheduled tids, any order (the scalar Algorithm-3 oracle
        sorts by rank internally, so ordering is semantics-free)."""
        return self.unscheduled

    def discard_unscheduled(self, tid: int) -> None:
        self.unscheduled.discard(tid)

    def add_unscheduled(self, tid: int) -> None:
        """Chaos re-execution: a revoked/failed task rejoins the pool."""
        self.unscheduled.add(tid)

    def dec_pending(self, child: int) -> bool:
        """Decrement the child's pending-parent count; True ⇒ released."""
        v = self.pending_parents[child] - 1
        self.pending_parents[child] = v
        return v == 0

    def make_redist(self, cfg: PlatformConfig) -> budget_mod.RedistState:
        self.redist = budget_mod.RedistState(cfg, self.wf, self.unscheduled)
        return self.redist


class _WfView:
    """Per-workflow accessor over the shared :class:`StreamState` arrays
    (the default state layout).

    Same interface as :class:`_WfState`; the scalar fields are numpy
    array cells (``float()``/``int()`` narrowing on read keeps every
    value a Python scalar, so downstream float algebra and JSON output
    are bit-identical with the object path), and the unscheduled set /
    pending-parent dict become segment slices of the pooled per-task
    arrays.  ``redist`` wraps the StreamState Algorithm-3 pool segments
    instead of allocating per-workflow mirrors."""

    __slots__ = ("wf", "redist", "_ss", "_w", "_t0", "_n")

    def __init__(self, wf: Workflow, ss: StreamState, wid: int, t0: int):
        self.wf = wf
        self.redist = None
        self._ss = ss
        self._w = wid
        self._t0 = t0
        self._n = wf.n_tasks

    # -- per-workflow scalars ------------------------------------------------
    @property
    def spare(self) -> float:
        return float(self._ss.spare[self._w])

    @spare.setter
    def spare(self, v: float) -> None:
        self._ss.spare[self._w] = v

    @property
    def cost(self) -> float:
        return float(self._ss.cost[self._w])

    @cost.setter
    def cost(self, v: float) -> None:
        self._ss.cost[self._w] = v

    @property
    def remaining(self) -> int:
        return int(self._ss.remaining[self._w])

    @remaining.setter
    def remaining(self, v: int) -> None:
        self._ss.remaining[self._w] = v

    @property
    def finish_ms(self) -> int:
        return int(self._ss.finish_ms[self._w])

    @finish_ms.setter
    def finish_ms(self, v: int) -> None:
        self._ss.finish_ms[self._w] = v

    @property
    def pending_surplus(self) -> float:
        return float(self._ss.pending_surplus[self._w])

    @pending_surplus.setter
    def pending_surplus(self, v: float) -> None:
        self._ss.pending_surplus[self._w] = v

    @property
    def pending_events(self) -> int:
        return int(self._ss.pending_events[self._w])

    @pending_events.setter
    def pending_events(self, v: int) -> None:
        self._ss.pending_events[self._w] = v

    # -- per-task segments ---------------------------------------------------
    def begin_arrival(self) -> None:
        ss, w, t0, n = self._ss, self._w, self._t0, self._n
        ss.arrived[w] = True
        ss.remaining[w] = n
        ss.unscheduled[t0:t0 + n] = True
        ss.pending_parents[t0:t0 + n] = \
            [len(t.parents) for t in self.wf.tasks]

    def unscheduled_seq(self) -> Sequence[int]:
        t0 = self._t0
        return np.flatnonzero(
            self._ss.unscheduled[t0:t0 + self._n]).tolist()

    def discard_unscheduled(self, tid: int) -> None:
        self._ss.unscheduled[self._t0 + tid] = False

    def add_unscheduled(self, tid: int) -> None:
        self._ss.unscheduled[self._t0 + tid] = True

    def dec_pending(self, child: int) -> bool:
        pp = self._ss.pending_parents
        i = self._t0 + child
        v = pp[i] - 1
        pp[i] = v
        return v == 0

    def make_redist(self, cfg: PlatformConfig) -> budget_mod.RedistState:
        ss, t0 = self._ss, self._t0
        seg = slice(t0, t0 + self._n)
        self.redist = budget_mod.RedistState(
            cfg, self.wf, self.unscheduled_seq(),
            backing=(ss.redist_order[seg], ss.redist_pos[seg],
                     ss.redist_mask[seg], ss.redist_budget[seg]))
        return self.redist


@dataclasses.dataclass(slots=True)
class _Running:
    wid: int
    tid: int
    vm: VM
    triggered_provision: bool
    actual_cost: float = 0.0
    # Chaos bookkeeping (set only when injection is enabled): pipeline
    # start for pro-rated revocation billing, the (possibly inflated)
    # compute leg and its undegraded estimate for straggler detection.
    start_ms: int = 0
    end_ms: int = 0
    rt_ms: int = 0
    est_rt_ms: int = 0


class SimState:
    """One simulation's mutable state + the transition semantics.

    Engine-agnostic: every method advances state deterministically; *when*
    events are drained and *how* the scheduling cycle is scored is the
    driving engine's business.
    """

    def __init__(
        self,
        cfg: PlatformConfig,
        policy: Policy,
        workflows: Sequence[Workflow],
        seed: int = 0,
        trace: bool = False,
        predistributed: Optional[Dict[int, float]] = None,
        redistribute: str = "finish",
        soa: Optional[bool] = None,
        stream: Optional[StreamState] = None,
        profile: Optional[bool] = None,
        events: Union[None, bool, EventLog] = None,
        chaos: Optional[ChaosConfig] = None,
        monitor: Union[None, bool, "obs_monitor.Monitor"] = None,
    ):
        """``predistributed``: wid → spare budget for workflows whose
        arrival-time budget distribution (Algorithm 1 / MSLBL) already ran
        on these task objects.  The distribution is deterministic in
        (cfg, workflow, budget) — policy- and seed-independent — so a grid
        engine computes it once per (workload, budget_mode) and shares the
        result across members instead of recomputing per member.

        ``redistribute``: ``"finish"`` (default) runs Algorithm 3 once per
        task finish — the paper's trigger, bit-exact with the scalar
        reference; ``"round"`` banks each finish's surplus and runs one
        pooled redistribution per workflow per scheduling cycle
        (``flush_redistributions``) — surplus flows coalesce, so results
        may differ in float; the A/B quality comparison lives in
        ``benchmarks/bench_grid_wall.py``.

        ``soa``: True/False/None — per-workflow mutable state layout.
        None (default) resolves to the structure-of-arrays
        ``StreamState`` unless ``REPRO_OBJECT_STATE=1`` forces the
        legacy object layout; both are bit-exact (parity-gated in
        ``tests/test_dispatcher_matrix.py``).

        ``stream``: optional pre-allocated :class:`StreamState` (or a
        :meth:`StreamState.view` segment of an engine-pooled backing)
        sized for this simulation; implies ``soa``.

        ``profile``: True/False/None — per-phase wall-clock counters.
        None (default) defers to ``REPRO_PROFILE=1``; the kwarg lets
        tests and benchmarks toggle per engine without mutating
        ``os.environ``.

        ``events``: None/bool/:class:`~repro.obs.events.EventLog` —
        structured event tracing (repro.obs).  None defers to
        ``REPRO_TRACE=1``; True allocates a fresh log; a log instance
        is used as-is.  Off ⇒ ``self.elog is None`` and every emission
        site is a single attribute-load + None check (same zero-cost
        discipline as ``profile``).

        ``chaos``: optional :class:`repro.chaos.ChaosConfig` — spot
        revocation, task-failure and straggler injection (deterministic
        in (seed, config); see repro.chaos).  ``None`` or an all-zero
        config disables injection entirely: ``self.chaos is None`` and
        every chaos branch is one attribute-load + None test.

        ``monitor``: None/bool/:class:`~repro.obs.monitor.Monitor` —
        the live SLO monitor (repro.obs.monitor).  None defers to
        ``REPRO_MONITOR=1``; when on it subscribes to the event log's
        emit path (``elog.sub``), allocating a log if tracing was off.
        The monitor is reachable from the pickled ``elog`` residue, so
        stream snapshots carry it and resume replays its windows and
        alerts bit-identically."""
        if redistribute not in ("finish", "round"):
            raise ValueError(f"redistribute={redistribute!r} "
                             "(expected 'finish' or 'round')")
        self.cfg = cfg
        self.policy = policy
        self.redistribute = redistribute
        self.workflows = list(workflows)
        self.predistributed = predistributed
        self.pool = VMPool(cfg)
        self.queue: List[Tuple[int, int, int]] = []  # (est_ms, wid, tid)
        self.events: List[Tuple[int, int, int, tuple]] = []
        self._seq = 0
        self.now = 0
        self.n_events = 0
        self.wf_state: Dict[int, Union[_WfState, "_WfView"]] = {}
        self.running: Dict[Tuple[int, int], _Running] = {}
        self.vm_bound: Dict[int, Tuple[int, int]] = {}  # vmid -> (wid, tid)
        self.trace_rows: List[tuple] = [] if trace else None
        # Resource-sharing counters (actuals, accumulated at pipeline
        # start): data-cache bytes served locally vs staged, and container
        # activations by warmth (0 ms / init-only / full download).
        self.data_mb_total = 0.0
        self.data_mb_hit = 0.0
        self.container_warm = 0
        self.container_init = 0
        self.container_cold = 0
        # Opt-in per-phase wall-clock counters (REPRO_PROFILE=1): how much
        # of a run the Algorithm 1/3 budget algebra, selection, and the
        # pipeline math each cost — see BatchSimEngine.dispatch_stats().
        self.profile: Optional[Dict[str, float]] = (
            new_profile()
            if (profile if profile is not None else _profile_enabled())
            else None)
        # Structured event log (repro.obs) — None unless opted in; every
        # emission below is guarded by one `is not None` test.
        self.elog: Optional[EventLog] = obs_events.resolve_events(events)
        # Live SLO monitor (repro.obs.monitor): subscribes to the emit
        # path.  Monitoring implies an event log (the monitor has no
        # other input); with both off the hot path is untouched.
        self.monitor = obs_monitor.resolve_monitor(monitor)
        if self.monitor is not None:
            if self.elog is None:
                self.elog = EventLog()
            self.elog.sub = self.monitor
        total_tasks = sum(w.n_tasks for w in self.workflows)
        # Global per-task degradation tables, indexed by task global id.
        # Kept as plain-float lists: the pipeline math runs per dispatch
        # and numpy scalar arithmetic is several times slower than float
        # (values identical — tolist is value-preserving).
        cpu_deg, bw_in_deg, bw_out_deg = degradation_tables(
            cfg, total_tasks, seed
        )
        self.cpu_deg = cpu_deg.tolist()
        self.bw_in_deg = bw_in_deg.tolist()
        self.bw_out_deg = bw_out_deg.tolist()
        # Fault injection (repro.chaos): None unless a config with at
        # least one live knob is passed; the draw tables are derived
        # state (pure function of config × seed × total_tasks), while
        # the attempt/preemption counters and injection tallies below
        # are mutable state that rides the snapshot residue.
        self.chaos: Optional[ChaosConfig] = (
            chaos if chaos is not None and chaos.enabled else None)
        self.chaos_draws = chaos_draws(self.chaos, total_tasks, seed)
        self.task_attempts: Dict[Tuple[int, int], int] = {}
        self.task_preempts: Dict[Tuple[int, int], int] = {}
        self.revocations = 0
        self.task_failures = 0
        self.task_retries = 0
        self.stragglers_detected = 0
        self.wasted_cost = 0.0
        self.spot_provisioned = 0
        self._task_base: Dict[int, int] = {}
        base = 0
        for w in self.workflows:
            self._task_base[w.wid] = base
            base += w.n_tasks
        # State layout: SoA StreamState (default) vs legacy objects.
        self.soa = (not _object_state_forced()) if soa is None else bool(soa)
        if stream is not None:
            if not self.soa:
                raise ValueError("stream= requires the SoA state layout")
            self.stream: Optional[StreamState] = stream
        else:
            self.stream = (StreamState(len(self.workflows), total_tasks)
                           if self.soa else None)

    # ---- event plumbing ----------------------------------------------------
    def _push(self, t_ms: int, kind: int, payload: tuple) -> None:
        heapq.heappush(self.events, (t_ms, self._seq, kind, payload))
        self._seq += 1

    def _gid(self, wid: int, tid: int) -> int:
        return self._task_base[wid] + tid

    def seed_arrivals(self) -> None:
        for wf in self.workflows:
            self._push(wf.arrival_ms, ARRIVAL, (wf.wid,))

    @property
    def done(self) -> bool:
        return not self.events

    def advance(self) -> bool:
        """Drain every event at the next timestamp; True ⇒ a scheduling
        cycle must follow (the paper's trigger rule)."""
        t_ms = self.events[0][0]
        self.now = t_ms
        need_cycle = False
        while self.events and self.events[0][0] == t_ms:
            _, _, kind, payload = heapq.heappop(self.events)
            self.n_events += 1
            if kind == ARRIVAL:
                self._handle_arrival(payload[0])
                need_cycle = True
            elif kind == FINISH:
                self._handle_finish(*payload)
                need_cycle = True
            elif kind == VM_READY:
                self._handle_vm_ready(payload[0])
            elif kind == REAP:
                self._handle_reap(*payload)
            elif kind == REVOKE:
                # True (⇒ cycle) only when a task was requeued.
                need_cycle |= self._handle_revoke(payload[0])
        return need_cycle

    def post_cycle(self) -> None:
        """Deprovisioning step that follows every scheduling cycle."""
        if self.policy.idle_threshold_ms == 0:
            self.reap_now()

    # ---- handlers --------------------------------------------------------------
    def _handle_arrival(self, wid: int) -> None:
        wf = self.workflows[wid]
        if self.soa:
            st = _WfView(wf, self.stream, wid, self._task_base[wid])
        else:
            st = _WfState(wf=wf)
        st.begin_arrival()
        self.wf_state[wid] = st
        ev = self.elog
        if ev is not None:
            ev.append(obs_events.WF_ARRIVE, self.now, wid, wf.n_tasks,
                      x=wf.budget)
        if self.predistributed is not None and wid in self.predistributed:
            st.spare = self.predistributed[wid]  # tasks already carry budgets
            dist_mode = 2
        elif self.policy.budget_mode == "mslbl":
            t0 = _time.perf_counter() if self.profile is not None else 0.0
            distribute_budget_mslbl(self.cfg, wf, wf.budget)
            if self.profile is not None:
                self.profile["distribute_s"] += _time.perf_counter() - t0
                self.profile["distributions"] += 1
            dist_mode = 1
        else:
            t0 = _time.perf_counter() if self.profile is not None else 0.0
            st.spare = budget_mod.distribute_budget(self.cfg, wf, wf.budget)
            if self.profile is not None:
                self.profile["distribute_s"] += _time.perf_counter() - t0
                self.profile["distributions"] += 1
            dist_mode = 0
        if ev is not None:
            ev.append(obs_events.BUDGET_DISTRIBUTE, self.now, wid,
                      dist_mode, x=st.spare)
        for tid in wf.entry_tasks():
            heapq.heappush(self.queue, (self.now, wid, tid))
            if ev is not None:
                ev.append(obs_events.TASK_READY, self.now, wid, tid)

    def _inputs_of(self, wf: Workflow, task: Task) -> List[Tuple[DataKey, float]]:
        # Static per task (DAG and sizes are immutable once built) and
        # read at least twice per task (selection + pipeline start):
        # memoized on the Task (clones share the list — same wid, same
        # DAG by construction).
        ins = task.inputs_cache
        if ins is not None:
            return ins
        ins = []
        if task.ext_in_mb > 0:
            ins.append((("ext", wf.wid, task.tid), task.ext_in_mb))
        for name, mb in task.shared_in:   # cross-tenant shared data
            ins.append((("shared", name, 0), mb))
        for p in task.parents:
            ins.append((("out", wf.wid, p), wf.tasks[p].out_mb))
        task.inputs_cache = ins
        return ins

    def _handle_finish(self, wid: int, tid: int, attempt: int = 0) -> None:
        ch = self.chaos
        if ch is not None \
                and attempt != self.task_attempts.get((wid, tid), 0):
            return  # stale FINISH of an attempt a revocation already killed
        run = self.running.pop((wid, tid))
        st = self.wf_state[wid]
        wf = st.wf
        task = wf.tasks[tid]
        vm = run.vm
        if ch is not None and ch.fail_prob > 0.0 \
                and self.chaos_draws.fails(self._gid(wid, tid), attempt):
            self._fail_attempt(run, st, wid, tid, attempt)
            return
        # Cache this task's output locally (the resource-sharing policy).
        vm.cache_put(self.cfg, ("out", wid, tid), task.out_mb,
                     self.pool.data_index)
        self.pool.mark_idle(vm, self.now)
        self.vm_bound.pop(vm.vmid, None)
        self._arm_reap(vm)
        # Actual cost (Eq. 5) and budget bookkeeping.
        actual = self._actual_cost_of(run)
        st.cost += actual
        st.remaining -= 1
        st.finish_ms = max(st.finish_ms, self.now)
        ev = self.elog
        if ev is not None:
            ev.append(obs_events.TASK_FINISH, self.now, wid, tid, vm.vmid,
                      x=actual)
            ev.append(obs_events.VM_IDLE, self.now, vm.vmid)
        if ch is not None and run.rt_ms > ch.straggler_factor * run.est_rt_ms:
            # Straggler detection: the *platform-observable* rule — the
            # compute leg exceeded straggler_factor × the undegraded
            # estimate — so natural degradation outliers can trip it too
            # when the factor is set below the degradation ceiling.
            self.stragglers_detected += 1
            if ev is not None:
                ev.append(obs_events.STRAGGLER_DETECT, self.now, wid, tid,
                          vm.vmid, run.rt_ms,
                          x=run.rt_ms / max(run.est_rt_ms, 1))
        if self.policy.budget_mode == "mslbl":
            st.spare += task.budget - actual
            if ev is not None:
                ev.append(obs_events.BUDGET_SPARE, self.now, wid, tid,
                          x=task.budget - actual, y=st.spare)
        elif self.redistribute == "round":
            # Round-batched Algorithm 3: bank the surplus; the pooled
            # redistribution runs once per workflow per scheduling cycle
            # (flush_redistributions), coalescing every finish in between.
            st.pending_surplus += task.budget - actual
            st.pending_events += 1
            if self.profile is not None:
                self.profile["redistribute_events"] += 1
            if ev is not None:
                ev.append(obs_events.BUDGET_SPARE, self.now, wid, tid,
                          x=task.budget - actual, y=st.pending_surplus)
        else:
            # Algorithm 3: one redistribution per task finish.  The array
            # path (core.budget.RedistState) is bit-exact with the scalar
            # reference, which REPRO_SCALAR_REDIST=1 forces back on.
            prof = self.profile
            t0 = _time.perf_counter() if prof is not None else 0.0
            if budget_mod._ARRAY_REDIST:
                rd = st.redist
                if rd is None:
                    rd = st.make_redist(self.cfg)
                st.spare = budget_mod.update_budget_fast(
                    self.cfg, wf, rd, tid, actual, st.spare
                )
            else:
                st.spare = budget_mod.update_budget(
                    self.cfg, wf, tid, actual, st.spare,
                    st.unscheduled_seq()
                )
            if prof is not None:
                prof["redistribute_s"] += _time.perf_counter() - t0
                prof["redistributions"] += 1
                prof["redistribute_events"] += 1
            if ev is not None:
                ev.append(obs_events.BUDGET_REDISTRIBUTE, self.now, wid,
                          tid, 1, x=task.budget - actual, y=st.spare)
        if ev is not None and st.remaining == 0:
            ev.append(obs_events.WF_DONE, self.now, wid, x=st.cost,
                      y=wf.budget)
        # Release ready children.
        for c in task.children:
            if st.dec_pending(c):
                heapq.heappush(self.queue, (self.now, wid, c))
                if ev is not None:
                    ev.append(obs_events.TASK_READY, self.now, wid, c)

    def _actual_cost_of(self, run: _Running) -> float:
        return run.actual_cost  # computed at dispatch time

    # ---- chaos transitions (repro.chaos) ---------------------------------------
    def _fail_attempt(self, run: _Running, st: Union["_WfState", "_WfView"],
                      wid: int, tid: int, attempt: int) -> None:
        """An execution attempt failed: the VM worked (and bills) in full
        but produced no output — no cache_put, no child release; the task
        requeues through the debt-absorbing path."""
        vm = run.vm
        self.pool.mark_idle(vm, self.now)
        self.vm_bound.pop(vm.vmid, None)
        self._arm_reap(vm)
        actual = self._actual_cost_of(run)
        self.task_failures += 1
        self.task_attempts[(wid, tid)] = attempt + 1
        ev = self.elog
        if ev is not None:
            ev.append(obs_events.TASK_FAIL, self.now, wid, tid, vm.vmid,
                      attempt, x=actual)
            ev.append(obs_events.VM_IDLE, self.now, vm.vmid)
        self._requeue_task(st, wid, tid, actual)

    def _requeue_task(self, st: Union["_WfState", "_WfView"], wid: int,
                      tid: int, wasted: float) -> None:
        """Put a killed/failed task back on the ready queue (its parents
        all finished, so it is ready by construction).  The wasted spend
        is real cost (Eq. 5 has no refunds) and is absorbed out of the
        workflow's remaining budget pool via Algorithm 3."""
        st.cost += wasted
        self.wasted_cost += wasted
        self.task_retries += 1
        st.add_unscheduled(tid)
        if st.redist is not None:
            st.redist.mark_unscheduled(tid)
        self._absorb_chaos_debt(st, wasted)
        heapq.heappush(self.queue, (self.now, wid, tid))
        if self.elog is not None:
            key = (wid, tid)
            self.elog.append(obs_events.TASK_RETRY, self.now, wid, tid,
                             self.task_attempts.get(key, 0),
                             self.task_preempts.get(key, 0))

    def _absorb_chaos_debt(self, st: Union["_WfState", "_WfView"],
                           amount: float) -> None:
        """Charge wasted spend to the budget layer: MSLBL pays from its
        spare pot; round-batched banking nets it against pending surplus;
        per-finish Algorithm 3 runs a pooled redistribution with the
        debt as negative surplus (spare + unscheduled sub-budgets absorb
        it, clamped at zero — overruns show up as budget violations,
        exactly like benign cost overruns)."""
        if amount <= 0.0:
            return
        ev = self.elog
        if self.policy.budget_mode == "mslbl":
            st.spare -= amount
            if ev is not None:
                ev.append(obs_events.BUDGET_SPARE, self.now, st.wf.wid, -1,
                          x=-amount, y=st.spare)
        elif self.redistribute == "round":
            st.pending_surplus -= amount
            st.pending_events += 1
            if self.profile is not None:
                self.profile["redistribute_events"] += 1
        else:
            prof = self.profile
            t0 = _time.perf_counter() if prof is not None else 0.0
            if budget_mod._ARRAY_REDIST:
                rd = st.redist
                if rd is None:
                    rd = st.make_redist(self.cfg)
                st.spare = budget_mod.update_budget_pooled(
                    self.cfg, st.wf, rd, -amount, st.spare
                )
            else:
                st.spare = budget_mod.update_budget_pooled_scalar(
                    self.cfg, st.wf, -amount, st.spare,
                    st.unscheduled_seq()
                )
            if prof is not None:
                prof["redistribute_s"] += _time.perf_counter() - t0
                prof["redistributions"] += 1
                prof["redistribute_events"] += 1
            if ev is not None:
                ev.append(obs_events.BUDGET_REDISTRIBUTE, self.now,
                          st.wf.wid, -2, 1, x=-amount, y=st.spare)

    def _handle_revoke(self, vmid: int) -> bool:
        """A spot lease's drawn lifetime elapsed.  Kill the VM whatever
        it was doing — the in-flight task's spend so far is sunk (billed
        per started period at the spot price), its attempt is abandoned
        (the stale FINISH event is invalidated by the attempt counter)
        and it requeues through the normal auction path.  Returns True
        iff a task was requeued (⇒ a scheduling cycle must follow)."""
        vm = self.pool.vms[vmid]
        if vm.status == VM_TERMINATED:
            return False    # reaped/idle-closed before the lifetime elapsed
        bound = self.vm_bound.pop(vmid, None)
        self.revocations += 1
        busy = 1 if vm.status == VM_BUSY else 0
        wid = tid = -1
        wasted = 0.0
        st = None
        if bound is not None:
            wid, tid = bound
            st = self.wf_state[wid]
            run = self.running.pop((wid, tid), None)
            if run is not None:
                # Billing stops at the revocation: started periods of the
                # elapsed pipeline (plus the provision delay the lease
                # triggered, per the benign billing rule).
                elapsed = self.now - run.start_ms
                if run.triggered_provision:
                    elapsed += self.cfg.vm_provision_delay_ms
                if elapsed > 0:
                    bp = self.cfg.billing_period_ms
                    wasted = ((elapsed + bp - 1) // bp) * vm.price_per_bp
                # The dispatch pre-charged the full pipeline to busy_ms;
                # give back the part the revocation cut off.
                vm.busy_ms -= max(0, run.end_ms - self.now)
            key = (wid, tid)
            self.task_attempts[key] = self.task_attempts.get(key, 0) + 1
            self.task_preempts[key] = self.task_preempts.get(key, 0) + 1
        self.pool.revoke(vm, self.now)
        if self.elog is not None:
            self.elog.append(obs_events.VM_REVOKE, self.now, vmid, wid, tid,
                             busy, x=wasted)
        if bound is not None:
            self._requeue_task(st, wid, tid, wasted)
        return bound is not None

    def _provision_for(self, wid: int, tid: int, app: str,
                       vmt_idx: int) -> VM:
        """Provision a VM for a task that found no suitable idle one,
        bind it, and arm its ready event.  Under spot pricing the lease
        is discounted and carries a pre-drawn revocation deadline —
        unless the task has been preempted ``escalate_after`` times
        already, in which case it escalates to on-demand (full price,
        non-revocable)."""
        tag = self.policy.owner_tag(wid, app)
        ch = self.chaos
        if ch is None or not ch.spot_enabled or (
                ch.escalate_after is not None
                and self.task_preempts.get((wid, tid), 0)
                >= ch.escalate_after):
            vm = self.pool.provision(vmt_idx, self.now, tag)
        else:
            vmt = self.cfg.vm_types[vmt_idx]
            vm = self.pool.provision(
                vmt_idx, self.now, tag, spot=True,
                price_per_bp=vmt.cost_per_bp * (1.0 - ch.spot_discount))
            self.spot_provisioned += 1
            if ch.revocation_rate > 0.0:
                self._push(
                    self.now + self.chaos_draws.vm_lifetime_ms(vm.vmid),
                    REVOKE, (vm.vmid,))
        self.vm_bound[vm.vmid] = (wid, tid)
        self._push(vm.ready_ms, VM_READY, (vm.vmid,))
        if self.elog is not None:
            self.elog.append(obs_events.VM_PROVISION, self.now, vm.vmid,
                             vm.vmt_idx)
        return vm

    def _handle_vm_ready(self, vmid: int) -> None:
        vm = self.pool.vms[vmid]
        if vm.status == VM_PROVISIONING:
            ev = self.elog
            if ev is not None:
                ev.append(obs_events.VM_READY, self.now, vmid)
            bound = self.vm_bound.get(vmid)
            if bound is not None:
                self.pool.mark_busy(vm)
                self._start_pipeline(*bound, vm, triggered_provision=True)
            else:
                self.pool.mark_idle(vm, self.now)
                if ev is not None:
                    ev.append(obs_events.VM_IDLE, self.now, vmid)
                self._arm_reap(vm)

    def _arm_reap(self, vm: VM) -> None:
        """Schedule the deferred reap for the idle period that just opened;
        the payload pins the current idle epoch so any reuse invalidates
        the event."""
        if self.policy.idle_threshold_ms > 0:
            self._push(self.now + self.policy.idle_threshold_ms, REAP,
                       (vm.vmid, vm.idle_epoch))

    def _handle_reap(self, vmid: int, idle_epoch: int) -> None:
        """A deferred reap kills its VM only if the idle epoch it was armed
        for is still the current one — any reuse in between (even a
        zero-length pipeline that returns to idle within the same
        millisecond) bumps the epoch and invalidates the reap."""
        vm = self.pool.vms[vmid]
        if vm.status == VM_IDLE and vm.idle_epoch == idle_epoch:
            self.pool.terminate(vm, self.now)
            if self.elog is not None:
                self.elog.append(obs_events.VM_REAP, self.now, vmid)

    def reap_now(self) -> None:
        ev = self.elog
        for vm in self.pool.idle_vms():
            self.pool.terminate(vm, self.now)
            if ev is not None:
                ev.append(obs_events.VM_REAP, self.now, vm.vmid)

    # ---- round-batched Algorithm 3 (redistribute="round") --------------------
    def flush_redistributions(self) -> None:
        """Run the banked pooled redistribution of every workflow with a
        task in the current ready queue — their sub-budgets are about to
        be read by selection.  Workflows with banked surplus but nothing
        queued keep coalescing until they queue again (or finalize)."""
        if self.redistribute != "round" or not self.queue:
            return
        for wid in sorted({e[1] for e in self.queue}):
            st = self.wf_state[wid]
            if st.pending_events:
                self._flush_wf(st)

    def _flush_wf(self, st: Union[_WfState, _WfView]) -> None:
        prof = self.profile
        t0 = _time.perf_counter() if prof is not None else 0.0
        if budget_mod._ARRAY_REDIST:
            rd = st.redist
            if rd is None:
                rd = st.make_redist(self.cfg)
            st.spare = budget_mod.update_budget_pooled(
                self.cfg, st.wf, rd, st.pending_surplus, st.spare
            )
        else:
            st.spare = budget_mod.update_budget_pooled_scalar(
                self.cfg, st.wf, st.pending_surplus, st.spare,
                st.unscheduled_seq()
            )
        if prof is not None:
            prof["redistribute_s"] += _time.perf_counter() - t0
            prof["redistributions"] += 1
        if self.elog is not None:
            self.elog.append(obs_events.BUDGET_REDISTRIBUTE, self.now,
                             st.wf.wid, -1, st.pending_events,
                             x=st.pending_surplus, y=st.spare)
        st.pending_surplus = 0.0
        st.pending_events = 0

    # ---- scheduling cycles (Alg. 2) ------------------------------------------
    def sequential_cycle(self, idle: Optional[List[VM]] = None) -> None:
        """Per-task reference cycle: drain the ready queue in order, calling
        ``scheduler.select`` against the live idle pool for each task."""
        self.flush_redistributions()
        idle = self.pool.idle_vms() if idle is None else idle
        while self.queue:
            est, wid, tid = heapq.heappop(self.queue)
            st = self.wf_state[wid]
            wf = st.wf
            task = wf.tasks[tid]
            budget_eff = task.budget
            if self.policy.budget_mode == "mslbl" and st.spare > 0:
                budget_eff += st.spare
            inputs = self._inputs_of(wf, task)
            t0 = _time.perf_counter() if self.profile is not None else 0.0
            placement = select(
                self.cfg,
                self.policy,
                task,
                wid,
                wf.app,
                inputs,
                budget_eff,
                idle,
                table=cost_tables.table_for(self.cfg, wf),
                pool=self.pool,
            )
            if self.profile is not None:
                self.profile["select_s"] += _time.perf_counter() - t0
                self.profile["selects"] += 1
            ev = self.elog
            if self.policy.budget_mode == "mslbl":
                # Spare consumed by how much the estimate exceeds the base.
                used = max(0.0, placement.est_cost - task.budget)
                spend = min(used, max(st.spare, 0.0))
                st.spare -= spend
                if ev is not None and spend > 0.0:
                    ev.append(obs_events.BUDGET_SPARE, self.now, wid, tid,
                              x=-spend, y=st.spare)
            st.discard_unscheduled(tid)
            if st.redist is not None:
                st.redist.mark_scheduled(tid)
            if ev is not None:
                ev.append(obs_events.TASK_PLACE, self.now, wid, tid,
                          placement.vm.vmid if placement.vm else -1,
                          placement.tier, x=placement.est_cost)
            if placement.vm is not None:
                vm = placement.vm
                self.pool.mark_busy(vm)
                idle = [v for v in idle if v.vmid != vm.vmid]
                self.vm_bound[vm.vmid] = (wid, tid)
                self._start_pipeline(wid, tid, vm, triggered_provision=False)
            else:
                self._provision_for(wid, tid, wf.app, placement.new_vmt_idx)
            if self.trace_rows is not None:
                self.trace_rows.append(
                    (self.now, wid, tid, placement.tier, placement.est_cost,
                     placement.vm.vmid if placement.vm else -1)
                )

    def drain_queue_for_cycle(self) -> Tuple[list, List[CycleMeta], list]:
        """Pop the whole ready queue in heap order; returns the
        (task, app, owner_tag, inputs) rows the auction scores, the
        (wid, tid, inputs) metadata the commit step needs, and the
        per-task cost tables the auction's serial resolution reads."""
        self.flush_redistributions()
        ordered = []
        while self.queue:
            ordered.append(heapq.heappop(self.queue))
        tasks = []
        metas: List[CycleMeta] = []
        tables = []
        for est, wid, tid in ordered:
            st = self.wf_state[wid]
            task = st.wf.tasks[tid]
            tag = self.policy.owner_tag(wid, st.wf.app)
            inputs = self._inputs_of(st.wf, task)
            tasks.append((task, st.wf.app, tag, inputs))
            metas.append((wid, tid, inputs))
            tables.append(cost_tables.table_for(self.cfg, st.wf))
        return tasks, metas, tables

    def apply_cycle_placements(
        self,
        metas: Sequence[CycleMeta],
        placements: Sequence[Optional[Placement]],
        idle: List[VM],
    ) -> None:
        """Commit an auction's outcome in queue order.  ``None`` placements
        fall back to the per-task reference selection against the VMs the
        auction left untaken (provisioning can't conflict, so the fallback
        is final)."""
        remaining = {vm.vmid for vm in idle}
        for (wid, tid, inputs), p in zip(metas, placements):
            st = self.wf_state[wid]
            task = st.wf.tasks[tid]
            if p is None:
                pool = [vm for vm in idle if vm.vmid in remaining
                        and vm.status == VM_IDLE]
                p = select(self.cfg, self.policy, task, wid, st.wf.app,
                           inputs, task.budget, pool,
                           table=cost_tables.table_for(self.cfg, st.wf),
                           pool=self.pool)
            st.discard_unscheduled(tid)
            if st.redist is not None:
                st.redist.mark_scheduled(tid)
            ev = self.elog
            if ev is not None:
                ev.append(obs_events.TASK_PLACE, self.now, wid, tid,
                          p.vm.vmid if p.vm else -1, p.tier, x=p.est_cost)
            if p.vm is not None:
                vm = p.vm
                self.pool.mark_busy(vm)
                remaining.discard(vm.vmid)
                self.vm_bound[vm.vmid] = (wid, tid)
                self._start_pipeline(wid, tid, vm, triggered_provision=False)
            else:
                self._provision_for(wid, tid, st.wf.app, p.new_vmt_idx)
            if self.trace_rows is not None:
                self.trace_rows.append((self.now, wid, tid, p.tier,
                                        p.est_cost,
                                        p.vm.vmid if p.vm else -1))

    # ---- execution pipeline ---------------------------------------------------
    def _start_pipeline(
        self, wid: int, tid: int, vm: VM, triggered_provision: bool
    ) -> None:
        tp0 = _time.perf_counter() if self.profile is not None else 0.0
        st = self.wf_state[wid]
        wf = st.wf
        task = wf.tasks[tid]
        gid = self._gid(wid, tid)
        # 1. container (actual, mutates image cache + the pool's app indexes).
        # Classify warmth from the VM's pre-activation state (the ground
        # truth), not from the returned delay — degenerate configs can make
        # the init and full-provision delays coincide.
        warmth = obs_events.WARMTH_NONE
        if self.policy.use_containers:
            if vm.active_container == wf.app:
                self.container_warm += 1
                warmth = obs_events.WARMTH_WARM
            elif wf.app in vm.image_cache:
                self.container_init += 1
                warmth = obs_events.WARMTH_INIT
            else:
                self.container_cold += 1
                warmth = obs_events.WARMTH_COLD
        c_ms = self.pool.activate_container(vm, wf.app, self.policy.use_containers)
        # 2. input staging: only cache-missing bytes travel.  One pass
        # computes the missing volume and collects the keys to cache
        # (cache_put is a no-op for already-cached keys, so putting only
        # the misses is equivalent).
        inputs = self._inputs_of(wf, task)
        dc = vm.data_cache
        missing = 0.0
        total_mb = 0.0
        to_cache = []
        for item in inputs:
            mb = item[1]
            total_mb += mb
            if item[0] not in dc:
                missing += mb
                to_cache.append(item)
        self.data_mb_total += total_mb
        self.data_mb_hit += total_mb - missing
        for key, mb in to_cache:
            vm.cache_put(self.cfg, key, mb, self.pool.data_index)
        # 3. compute (degraded CPU), 4. write-back to global storage.
        # Eqs. (1)-(3) inlined from core.costs (same float64 op sequence,
        # same tolerance-ceil) — three function hops per task dispatch
        # add up over six-figure task counts.
        cfg = self.cfg
        vmt = vm.vmt
        ceil = _math.ceil
        tol = 1.0 - costs.CEIL_TOL
        if missing > 0.0:
            bw = vmt.bandwidth_mbps * (1.0 - self.bw_in_deg[gid])
            in_ms = int(ceil(
                1000.0 * (missing / bw + missing / cfg.gs_read_mbps) * tol))
        else:
            in_ms = 0
        rt_ms = int(ceil(
            1000.0 * task.size_mi / (vmt.mips * (1.0 - self.cpu_deg[gid]))
            * tol))
        ch = self.chaos
        if ch is not None and ch.straggler_prob > 0.0 \
                and self.chaos_draws.straggler[gid]:
            # Injected straggler: the compute leg runs slowdown× on top
            # of the benign degradation (every attempt — slowness models
            # the task's pathology, not the VM's).
            rt_ms = int(ceil(rt_ms * ch.straggler_slowdown))
        if task.out_mb > 0.0:
            bw = vmt.bandwidth_mbps * (1.0 - self.bw_out_deg[gid])
            out_ms = int(ceil(
                1000.0 * (task.out_mb / bw + task.out_mb / cfg.gs_write_mbps)
                * tol))
        else:
            out_ms = 0
        pipe_ms = c_ms + in_ms + rt_ms + out_ms
        finish = self.now + pipe_ms
        vm.busy_ms += pipe_ms
        billed = pipe_ms + (
            cfg.vm_provision_delay_ms if triggered_provision else 0
        )
        bp = cfg.billing_period_ms
        # Bills at the lease's own rate: identical to vmt.cost_per_bp on
        # on-demand VMs, discounted on spot leases (repro.chaos).
        actual_cost = ((billed + bp - 1) // bp) * vm.price_per_bp
        run = _Running(wid, tid, vm, triggered_provision, actual_cost)
        self.running[(wid, tid)] = run
        if ch is None:
            self._push(finish, FINISH, (wid, tid))
        else:
            # Chaos bookkeeping: pro-rated revocation billing needs the
            # pipeline bounds, straggler detection the compute legs, and
            # the FINISH payload pins the attempt so a revocation's
            # stale event can be told apart from the live re-execution.
            run.start_ms = self.now
            run.end_ms = finish
            run.rt_ms = rt_ms
            run.est_rt_ms = costs.runtime_ms(vmt, task.size_mi)
            self._push(finish, FINISH,
                       (wid, tid, self.task_attempts.get((wid, tid), 0)))
        ev = self.elog
        if ev is not None:
            ev.append(obs_events.VM_BUSY, self.now, vm.vmid)
            if warmth > obs_events.WARMTH_WARM:
                # Activation that cost time (image init or full download).
                ev.append(obs_events.VM_CONTAINER, self.now, vm.vmid,
                          warmth)
            ev.append(obs_events.TASK_START, self.now, wid, tid, vm.vmid,
                      warmth, x=missing, y=total_mb)
        if self.profile is not None:
            self.profile["pipeline_s"] += _time.perf_counter() - tp0
            self.profile["pipelines"] += 1

    # ---- results ---------------------------------------------------------------
    def _fleet_stats(self) -> Tuple[int, float]:
        """(peak concurrent VMs, time-weighted mean fleet size) from the
        pool's lease intervals, via the shared ``obs.timeseries``
        reconstruction — the same path the event-derived fleet series
        uses, so traces and end-of-run aggregates cannot disagree.
        Every VM is terminated by finalize, so both endpoints are
        defined."""
        return obs_ts.peak_and_mean(
            (vm.lease_start_ms for vm in self.pool.vms),
            (vm.terminated_ms if vm.terminated_ms >= 0 else self.now
             for vm in self.pool.vms))

    def finalize(self, wall_s: float = 0.0) -> SimResult:
        if self.redistribute == "round":
            # Flush any still-banked surplus so spare/budget invariants
            # hold post-run (results don't read budgets, but tests and
            # conservation checks do).
            for st in self.wf_state.values():
                if st.pending_events:
                    self._flush_wf(st)
        if self.elog is not None:
            # Close the remaining leases in the event stream before the
            # pool stamps their termination — the event-derived fleet
            # series ends exactly where the lease intervals do.
            for vm in self.pool.vms:
                if vm.terminated_ms < 0:
                    self.elog.append(obs_events.VM_REAP, self.now,
                                     vm.vmid, 1)
        self.pool.finalize(self.now)
        if self.monitor is not None:
            # Flush the remaining sample boundaries (the closing reaps
            # above already streamed through the subscriber) and stamp
            # the horizon; open alerts keep cleared_ms = -1.
            self.monitor.finalize(self.now)
        peak_vms, mean_fleet = self._fleet_stats()
        results = [
            WorkflowResult(
                wid=s.wf.wid,
                app=s.wf.app,
                n_tasks=s.wf.n_tasks,
                budget=s.wf.budget,
                cost=s.cost,
                arrival_ms=s.wf.arrival_ms,
                finish_ms=s.finish_ms,
            )
            for s in self.wf_state.values()
        ]
        return SimResult(
            workflows=results,
            vm_seconds_by_type=self.pool.vm_seconds_by_type,
            vm_busy_seconds_by_type=self.pool.vm_busy_seconds_by_type,
            vm_count_by_type=self.pool.vm_count_by_type,
            total_events=self.n_events,
            wall_s=wall_s,
            data_mb_total=self.data_mb_total,
            data_mb_hit=self.data_mb_hit,
            container_warm=self.container_warm,
            container_init=self.container_init,
            container_cold=self.container_cold,
            peak_vms=peak_vms,
            mean_fleet_vms=mean_fleet,
            revocations=self.revocations,
            task_failures=self.task_failures,
            task_retries=self.task_retries,
            stragglers_detected=self.stragglers_detected,
            wasted_cost=self.wasted_cost,
            spot_vms=self.spot_provisioned,
        )


    # ---- checkpoint / resume ---------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Serializable snapshot: ``{"arrays", "residue", "version"}``.

        ``arrays`` is the StreamState persisted block (gathered from the
        object layout when ``soa=False`` — the interchange format is
        layout-independent, so a snapshot written by either layout
        restores into either) plus the per-task mutable ``Task`` fields
        Algorithm 1/3 writes (budget/level/rank), in global-id order;
        an ``order`` array preserves ``wf_state`` insertion order
        (finalize and metric grouping iterate it).  ``residue`` is one
        pickle of the heap-ordered event/queue lists, clocks, the VM
        pool with in-flight pipelines (pickled together so VM object
        identity between ``running`` and the pool survives), trace rows
        and the resource-sharing counters.  Derived state — Algorithm-3
        pools, cost tables, rank/input caches — is rebuilt lazily and
        bit-identically after :meth:`load_snapshot`."""
        n_wf = len(self.workflows)
        total_tasks = sum(w.n_tasks for w in self.workflows)
        if self.soa:
            arrays = self.stream.snapshot_arrays()
        else:
            arrays = {name: np.zeros(n_wf if per_wf else total_tasks,
                                     dtype=dt)
                      for per_wf, fields in
                      ((True, StreamState.WF_FIELDS),
                       (False, StreamState.TASK_FIELDS))
                      for name, dt in fields}
            for wid, st in self.wf_state.items():
                arrays["arrived"][wid] = True
                for name in ("spare", "cost", "pending_surplus",
                             "remaining", "finish_ms", "pending_events"):
                    arrays[name][wid] = getattr(st, name)
                t0 = self._task_base[wid]
                pp = arrays["pending_parents"]
                for tid, v in st.pending_parents.items():
                    pp[t0 + tid] = v
                un = arrays["unscheduled"]
                for tid in st.unscheduled:
                    un[t0 + tid] = True
        arrays["order"] = np.fromiter(self.wf_state, np.int64,
                                      count=len(self.wf_state))
        arrays["task_budget"] = np.array(
            [t.budget for w in self.workflows for t in w.tasks], np.float64)
        arrays["task_level"] = np.array(
            [t.level for w in self.workflows for t in w.tasks], np.int64)
        arrays["task_rank"] = np.array(
            [t.rank for w in self.workflows for t in w.tasks], np.int64)
        residue = _pickle.dumps({
            "events": self.events,
            "queue": self.queue,
            "seq": self._seq,
            "now": self.now,
            "n_events": self.n_events,
            "pool": self.pool,
            "running": self.running,
            "vm_bound": self.vm_bound,
            "trace_rows": self.trace_rows,
            "data_mb_total": self.data_mb_total,
            "data_mb_hit": self.data_mb_hit,
            "container_warm": self.container_warm,
            "container_init": self.container_init,
            "container_cold": self.container_cold,
            "profile": self.profile,
            "elog": self.elog,
            # Chaos mutable state (v2): attempt/preemption counters and
            # run tallies.  The draw tables are derived state — rebuilt
            # bit-identically from (config, seed) at construction.
            "task_attempts": self.task_attempts,
            "task_preempts": self.task_preempts,
            "chaos_counters": (
                self.revocations, self.task_failures, self.task_retries,
                self.stragglers_detected, self.wasted_cost,
                self.spot_provisioned),
        }, protocol=_pickle.HIGHEST_PROTOCOL)
        return {"arrays": arrays, "residue": residue,
                "version": STREAM_SNAPSHOT_VERSION}

    def load_snapshot(self, snap: Dict[str, object]) -> None:
        """Restore a :meth:`snapshot` into this freshly-constructed state
        (same cfg/policy/workloads/seed/redistribute — the caller
        rebuilds those deterministically; only mutable state loads)."""
        if snap.get("version", 1) > STREAM_SNAPSHOT_VERSION:
            raise ValueError(
                f"snapshot version {snap.get('version')} is newer than "
                f"supported {STREAM_SNAPSHOT_VERSION}")
        arrays: Dict[str, np.ndarray] = snap["arrays"]
        residue = _pickle.loads(snap["residue"])
        # Mutable per-task fields written by Algorithm 1/3 / MSLBL.
        tb = arrays["task_budget"].tolist()
        tl = arrays["task_level"].tolist()
        tr = arrays["task_rank"].tolist()
        i = 0
        for wf in self.workflows:
            wf.rank_cache = None    # rebuilt from the restored ranks
            for t in wf.tasks:
                t.budget = tb[i]
                t.level = tl[i]
                t.rank = tr[i]
                i += 1
        # Per-workflow state, in the checkpointed insertion order.
        order = arrays["order"].tolist()
        self.wf_state = {}
        if self.soa:
            self.stream.load_arrays(arrays)
            for wid in order:
                self.wf_state[wid] = _WfView(
                    self.workflows[wid], self.stream, wid,
                    self._task_base[wid])
        else:
            for wid in order:
                wf = self.workflows[wid]
                t0 = self._task_base[wid]
                n = wf.n_tasks
                st = _WfState(wf=wf)
                st.spare = float(arrays["spare"][wid])
                st.cost = float(arrays["cost"][wid])
                st.pending_surplus = float(arrays["pending_surplus"][wid])
                st.remaining = int(arrays["remaining"][wid])
                st.finish_ms = int(arrays["finish_ms"][wid])
                st.pending_events = int(arrays["pending_events"][wid])
                st.unscheduled = set(np.flatnonzero(
                    arrays["unscheduled"][t0:t0 + n]).tolist())
                st.pending_parents = dict(enumerate(
                    arrays["pending_parents"][t0:t0 + n].tolist()))
                self.wf_state[wid] = st
        # Event plumbing + pool (one pickle: VM identity is preserved
        # between running pipelines, vm_bound and the pool's own maps).
        self.events = residue["events"]
        self.queue = residue["queue"]
        self._seq = residue["seq"]
        self.now = residue["now"]
        self.n_events = residue["n_events"]
        self.pool = residue["pool"]
        self.running = residue["running"]
        self.vm_bound = residue["vm_bound"]
        self.trace_rows = residue["trace_rows"]
        self.data_mb_total = residue["data_mb_total"]
        self.data_mb_hit = residue["data_mb_hit"]
        self.container_warm = residue["container_warm"]
        self.container_init = residue["container_init"]
        self.container_cold = residue["container_cold"]
        self.profile = residue["profile"]
        # Snapshots from before the obs subsystem lack the key; a log
        # restored from the cut replaces whatever the constructor made,
        # so resumed traces are byte-identical with uninterrupted runs.
        self.elog = residue.get("elog")
        # The live monitor rides the elog residue (elog.sub): restoring
        # the log restores its windows, gates and alert history, so a
        # resumed stream replays alerts bit-identically.  Monitoring
        # strictly follows the restored stream — a monitor created by
        # this constructor is dropped if the snapshot ran without one.
        self.monitor = getattr(self.elog, "sub", None)
        # v1 snapshots (pre-chaos) default to the benign zeros.
        self.task_attempts = residue.get("task_attempts", {})
        self.task_preempts = residue.get("task_preempts", {})
        (self.revocations, self.task_failures, self.task_retries,
         self.stragglers_detected, self.wasted_cost,
         self.spot_provisioned) = residue.get(
            "chaos_counters", (0, 0, 0, 0, 0.0, 0))


class SimEngine(SimState):
    """One policy × one workload → SimResult (sequential driver)."""

    def __init__(
        self,
        cfg: PlatformConfig,
        policy: Policy,
        workflows: Sequence[Workflow],
        seed: int = 0,
        trace: bool = False,
        batched: object = "auto",
        predistributed: Optional[Dict[int, float]] = None,
        redistribute: str = "finish",
        soa: Optional[bool] = None,
        profile: Optional[bool] = None,
        events: Union[None, bool, EventLog] = None,
        chaos: Optional[ChaosConfig] = None,
        monitor: Union[None, bool, "obs_monitor.Monitor"] = None,
    ):
        """``batched``: True / False / "auto" — use the JAX batched
        scheduling cycle (core.jax_cycles) when the queue×pool product is
        large.  EBPSM-family policies only; MSLBL mutates spare budget
        mid-cycle and stays sequential.

        ``profile`` / ``events``: per-engine toggles for the phase
        counters and the structured event log (None defers to
        ``REPRO_PROFILE`` / ``REPRO_TRACE``; see :class:`SimState`).

        ``chaos``: fault-injection knobs (:class:`repro.chaos.ChaosConfig`);
        None or all-zero ⇒ the benign engine, bit-for-bit."""
        super().__init__(cfg, policy, workflows, seed=seed, trace=trace,
                         predistributed=predistributed,
                         redistribute=redistribute, soa=soa,
                         profile=profile, events=events, chaos=chaos,
                         monitor=monitor)
        self.batched = batched

    # ---- main loop -----------------------------------------------------------
    def run(self) -> SimResult:
        t0 = _time.time()
        self.seed_arrivals()
        while self.events:
            if self.advance():
                self._schedule_cycle()
                self.post_cycle()
        return self.finalize(wall_s=_time.time() - t0)

    # ---- scheduling cycle (Alg. 2 driver) ------------------------------------
    def _use_batched(self, n_queue: int, n_idle: int) -> bool:
        if self.policy.budget_mode != "ebpsm":
            return False
        if self.batched is True:
            return True
        if self.batched == "auto":
            return n_queue * n_idle >= AUCTION_MIN_PAIRS
        return False

    def _schedule_cycle(self) -> None:
        idle = self.pool.idle_vms()
        if self.queue and self._use_batched(len(self.queue), len(idle)):
            self._schedule_cycle_batched(idle)
            return
        self.sequential_cycle(idle)

    def _schedule_cycle_batched(self, idle: List[VM]) -> None:
        """Whole-queue scheduling via the JAX affinity kernel + auction
        (core.jax_cycles).  Matches the sequential outcome exactly while
        budgets are sufficient (see jax_cycles docstring)."""
        from .jax_cycles import batched_cycle

        tasks, metas, tables = self.drain_queue_for_cycle()
        placements = batched_cycle(self.cfg, self.policy, tasks, idle,
                                   self.pool, tables=tables)
        self.apply_cycle_placements(metas, placements, idle)


def simulate(
    cfg: PlatformConfig,
    policy: Policy,
    workflows: Sequence[Workflow],
    seed: int = 0,
) -> SimResult:
    """Convenience wrapper: run one simulation."""
    return SimEngine(cfg, policy, workflows, seed=seed).run()

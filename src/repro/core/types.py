"""Core entity types for the WaaS platform simulation.

Times are integer **milliseconds** throughout (exact arithmetic, identical
between the Python reference engine and the jitted JAX engine).  Money is in
float cents; task sizes in MI (million instructions); data sizes in MB.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

MS = 1000  # ms per second


# ---------------------------------------------------------------------------
# Infrastructure catalogue
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VMType:
    """An IaaS VM offering (Table 2 of the paper)."""

    name: str
    mips: float           # processing capacity p_vmt (MIPS)
    storage_mb: float     # local storage LS capacity
    cost_per_bp: float    # c_vmt, cents per billing period
    bandwidth_mbps: float  # b_vmt, MB/s (≈ same across types per the paper)


# The paper's Table 2 (c4-like, price linear in CPU), per-second billing.
PAPER_VM_TYPES: Tuple[VMType, ...] = (
    VMType("small", 2.0, 20 * 1024, 1.0, 20.0),
    VMType("medium", 4.0, 40 * 1024, 2.0, 20.0),
    VMType("large", 8.0, 80 * 1024, 4.0, 20.0),
    VMType("xlarge", 16.0, 160 * 1024, 8.0, 20.0),
)


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    """Environment constants (paper Section 5 defaults)."""

    vm_types: Tuple[VMType, ...] = PAPER_VM_TYPES
    billing_period_ms: int = 1 * MS          # per-second billing
    vm_provision_delay_ms: int = 45 * MS     # Ulrich et al. benchmark
    container_download_ms: int = 9_600       # 600 MB at 500 Mbps
    container_init_ms: int = 400             # Piraghaj et al. model
    gs_read_mbps: float = 50.0               # global storage read rate GS_r
    gs_write_mbps: float = 30.0              # global storage write rate GS_w
    provision_interval_ms: int = 1 * MS      # Alg. 4 monitor period prov_int
    idle_threshold_ms: int = 5 * MS          # Alg. 4 threshold_idle (EBPSM)
    # Leitner & Cito performance-variation model.
    cpu_degradation_mean: float = 0.12
    cpu_degradation_std: float = 0.10
    cpu_degradation_max: float = 0.24
    bw_degradation_mean: float = 0.095
    bw_degradation_std: float = 0.05
    bw_degradation_max: float = 0.19
    # Fixed-capacity limits for the vectorized engine.
    max_vms: int = 1024
    cache_slots: int = 64                    # FIFO data-cache entries per VM
    image_slots: int = 8                     # FIFO container-image entries

    @property
    def container_provision_ms(self) -> int:
        """prov_c — full container provisioning (download + init)."""
        return self.container_download_ms + self.container_init_ms

    def with_(self, **kw) -> "PlatformConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Application model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(slots=True)
class Task:
    """A workflow task.

    ``parents``/``children`` index into the owning workflow's task list.
    ``out_mb`` is the size of this task's output dataset d_t^out; a child
    reads every parent's output as its input d_t^in.  ``ext_in_mb`` models
    initial input staged from global storage (entry tasks).

    ``slots=True``: tasks are the most attribute-chased objects in both
    engines; slot access is measurably faster and halves the footprint.
    """

    tid: int
    size_mi: float
    out_mb: float
    ext_in_mb: float = 0.0
    parents: List[int] = dataclasses.field(default_factory=list)
    children: List[int] = dataclasses.field(default_factory=list)
    # Cross-workflow shared inputs [(name, mb)] — e.g. a base-model
    # checkpoint shared by every tenant fine-tuning the same arch (WaaS→ML
    # bridge).  Cache keys are global: ("shared", name, 0).
    shared_in: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)

    # Filled in by budget distribution / scheduling.
    level: int = 0
    rank: int = 0                 # position in estimated execution order S
    budget: float = 0.0           # current sub-budget allocation
    # Engine-memoized [(DataKey, mb)] input list (static per task; clones
    # share it — the DAG and the owning wid are identical by definition).
    inputs_cache: Optional[list] = dataclasses.field(
        default=None, repr=False, compare=False)


@dataclasses.dataclass(slots=True)
class Workflow:
    """A tenant job: a DAG of tasks plus a soft budget constraint."""

    wid: int
    app: str                      # application type == container image id
    tasks: List[Task]
    budget: float = 0.0
    arrival_ms: int = 0
    # Memoized core.cost_tables.CostTable — depends only on the immutable
    # task attributes, so clones share it by reference (see table_for).
    cost_cache: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Memoized [t.rank for t in tasks] (frozen once distribution ran).
    rank_cache: Optional[list] = dataclasses.field(
        default=None, repr=False, compare=False)

    def entry_tasks(self) -> List[int]:
        return [t.tid for t in self.tasks if not t.parents]

    def exit_tasks(self) -> List[int]:
        return [t.tid for t in self.tasks if not t.children]

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def clone(self) -> "Workflow":
        """Per-simulation copy with structural sharing.

        Budget distribution mutates ``Task.budget`` / ``level`` /
        ``rank``, so every grid member needs its own ``Task`` objects —
        but the DAG structure (``parents`` / ``children`` /
        ``shared_in`` lists) is immutable once built and is shared by
        reference.  This replaces per-member ``copy.deepcopy`` in the
        batched engine: O(tasks) instead of O(whole object graph).
        """
        # Positional Task construction: ~4× faster than
        # dataclasses.replace on the clone-per-grid-member hot path
        # (replace re-enters __init__ through kwargs plumbing).
        return Workflow(
            wid=self.wid,
            app=self.app,
            tasks=[
                Task(t.tid, t.size_mi, t.out_mb, t.ext_in_mb, t.parents,
                     t.children, t.shared_in, t.level, t.rank, t.budget,
                     t.inputs_cache)
                for t in self.tasks
            ],
            budget=self.budget,
            arrival_ms=self.arrival_ms,
            cost_cache=self.cost_cache,
            rank_cache=self.rank_cache,
        )

    def validate(self) -> None:
        """Check DAG structure; raises :class:`ValueError` with a concrete
        message on malformed input.

        Generators *and importers* (``tenants.traces``) run this before a
        workflow ever reaches an engine: a cycle or dangling edge must be
        rejected at load time with a clear error, not crash mid-sim.
        """
        n = len(self.tasks)
        if n == 0:
            raise ValueError(f"workflow {self.wid} ({self.app!r}) is empty")
        for i, t in enumerate(self.tasks):
            if t.tid != i:
                raise ValueError(
                    f"workflow {self.wid}: task at position {i} has "
                    f"tid {t.tid} (tids must equal list position)")
            for p in t.parents:
                if not 0 <= p < n:
                    raise ValueError(
                        f"workflow {self.wid}: task {t.tid} names parent "
                        f"{p}, outside 0..{n - 1}")
                if t.tid not in self.tasks[p].children:
                    raise ValueError(
                        f"workflow {self.wid}: dangling edge — task "
                        f"{t.tid} lists parent {p}, but {p} does not list "
                        f"{t.tid} as a child")
            for c in t.children:
                if not 0 <= c < n:
                    raise ValueError(
                        f"workflow {self.wid}: task {t.tid} names child "
                        f"{c}, outside 0..{n - 1}")
                if t.tid not in self.tasks[c].parents:
                    raise ValueError(
                        f"workflow {self.wid}: dangling edge — task "
                        f"{t.tid} lists child {c}, but {c} does not list "
                        f"{t.tid} as a parent")
        # Acyclicity via Kahn's algorithm.
        indeg = [len(t.parents) for t in self.tasks]
        stack = [i for i, d in enumerate(indeg) if d == 0]
        seen = 0
        while stack:
            u = stack.pop()
            seen += 1
            for c in self.tasks[u].children:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
        if seen != n:
            cyc = sorted(i for i, d in enumerate(indeg) if d > 0)
            raise ValueError(
                f"workflow {self.wid}: DAG has a cycle through tasks {cyc}")


def clone_workload(workflows: Sequence[Workflow]) -> List[Workflow]:
    """Structural-sharing copy of a whole workload (see Workflow.clone)."""
    return [wf.clone() for wf in workflows]


# ---------------------------------------------------------------------------
# Structure-of-arrays stream state
# ---------------------------------------------------------------------------


class StreamState:
    """Structure-of-arrays owner of a simulation's per-workflow and
    per-task mutable scalars.

    The engines' hot bookkeeping — spare budget, accumulated cost,
    unscheduled/remaining counts, finish clocks, round-mode surplus
    banks, per-task pending-parent counters, the unscheduled mask, and
    the Algorithm-3 ``RedistState`` pools (rank order, position index,
    row mask, float64 budget mirror) — lives in flat numpy arrays
    indexed by wid (per-workflow fields) or by task global id
    (per-task fields), instead of one Python object graph per workflow.
    ``core.engine`` reads and writes it through thin per-workflow
    accessor views (``_WfView``) so the transition semantics stay
    bit-exact with the legacy object path (``REPRO_OBJECT_STATE=1``).

    Two properties make it the unit of scale-out and checkpointing:

    * :meth:`view` returns a zero-copy segment (numpy slice views) —
      ``core.jax_engine.BatchSimEngine`` allocates ONE pooled backing
      for a whole grid and hands each member a view, so thousands of
      open-stream members share a handful of allocations;
    * :meth:`snapshot_arrays` / :meth:`load_arrays` give the persisted
      array block ``repro.ckpt.checkpoint.save_stream`` writes.  The
      Algorithm-3 pools are *derived* state (a pure function of task
      ranks, budgets, and the unscheduled mask) and are deliberately
      not persisted — restore rebuilds them lazily and bit-identically.
    """

    # (name, dtype): persisted per-workflow fields, indexed by wid.
    WF_FIELDS: Tuple[Tuple[str, str], ...] = (
        ("spare", "f8"), ("cost", "f8"), ("pending_surplus", "f8"),
        ("remaining", "i8"), ("finish_ms", "i8"), ("pending_events", "i8"),
        ("arrived", "?"),
    )
    # Persisted per-task fields, indexed by task global id.
    TASK_FIELDS: Tuple[Tuple[str, str], ...] = (
        ("pending_parents", "i8"), ("unscheduled", "?"),
    )
    # Derived Algorithm-3 pools (RedistState backing) — rebuilt, never
    # persisted.  redist_mask is indexed by *position in rank order*
    # within the workflow's segment, matching RedistState.mask.
    POOL_FIELDS: Tuple[Tuple[str, str], ...] = (
        ("redist_order", "i8"), ("redist_pos", "i8"),
        ("redist_mask", "?"), ("redist_budget", "f8"),
    )

    __slots__ = tuple(n for n, _ in WF_FIELDS) \
        + tuple(n for n, _ in TASK_FIELDS) \
        + tuple(n for n, _ in POOL_FIELDS) \
        + ("n_workflows", "n_tasks")

    def __init__(self, n_workflows: int, n_tasks: int):
        self.n_workflows = n_workflows
        self.n_tasks = n_tasks
        for name, dt in self.WF_FIELDS:
            setattr(self, name, np.zeros(n_workflows, dtype=dt))
        for name, dt in self.TASK_FIELDS + self.POOL_FIELDS:
            setattr(self, name, np.zeros(n_tasks, dtype=dt))

    def view(self, wf_lo: int, wf_hi: int,
             task_lo: int, task_hi: int) -> "StreamState":
        """Zero-copy segment view: writes through to this backing."""
        v = object.__new__(StreamState)
        v.n_workflows = wf_hi - wf_lo
        v.n_tasks = task_hi - task_lo
        for name, _ in self.WF_FIELDS:
            setattr(v, name, getattr(self, name)[wf_lo:wf_hi])
        for name, _ in self.TASK_FIELDS + self.POOL_FIELDS:
            setattr(v, name, getattr(self, name)[task_lo:task_hi])
        return v

    def snapshot_arrays(self) -> Dict[str, np.ndarray]:
        """Copies of the persisted fields (derived pools excluded)."""
        return {name: getattr(self, name).copy()
                for name, _ in self.WF_FIELDS + self.TASK_FIELDS}

    def load_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        """In-place restore of the persisted fields; the derived
        Algorithm-3 pools are reset (rebuilt lazily on first use)."""
        for name, _ in self.WF_FIELDS + self.TASK_FIELDS:
            dst = getattr(self, name)
            dst[:] = arrays[name]
        for name, dt in self.POOL_FIELDS:
            getattr(self, name)[:] = 0


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkflowResult:
    wid: int
    app: str
    n_tasks: int
    budget: float
    cost: float
    arrival_ms: int
    finish_ms: int

    @property
    def makespan_ms(self) -> int:
        return self.finish_ms - self.arrival_ms

    @property
    def budget_met(self) -> bool:
        return self.cost <= self.budget + 1e-6

    @property
    def cost_budget_ratio(self) -> float:
        return self.cost / max(self.budget, 1e-9)


@dataclasses.dataclass
class SimResult:
    """Aggregate output of one simulation run."""

    workflows: List[WorkflowResult]
    vm_seconds_by_type: Dict[str, float]
    vm_busy_seconds_by_type: Dict[str, float]
    vm_count_by_type: Dict[str, int]
    total_events: int = 0
    wall_s: float = 0.0
    # Resource-sharing actuals (the paper's policy claim made measurable):
    # input bytes served from VM-local caches vs staged, and container
    # activations by warmth.  Zeros for policies without containers.
    data_mb_total: float = 0.0
    data_mb_hit: float = 0.0
    container_warm: int = 0
    container_init: int = 0
    container_cold: int = 0
    # Fleet-size-over-time summary (online/open-stream scenarios): the
    # maximum number of concurrently leased VMs and the time-weighted
    # mean over [0, last event].  Computed from the pool's lease
    # intervals at finalize time.
    peak_vms: int = 0
    mean_fleet_vms: float = 0.0
    # Fault-injection tallies (repro.chaos) — zeros on benign runs:
    # spot-lease revocations, failed execution attempts, total task
    # re-executions (failures + preemption-killed attempts), stragglers
    # the platform detected, cost sunk into attempts that produced no
    # output (already included in each workflow's cost — Eq. 5 has no
    # refunds), and spot leases provisioned.
    revocations: int = 0
    task_failures: int = 0
    task_retries: int = 0
    stragglers_detected: int = 0
    wasted_cost: float = 0.0
    spot_vms: int = 0

    @property
    def avg_vm_utilization(self) -> float:
        lease = sum(self.vm_seconds_by_type.values())
        busy = sum(self.vm_busy_seconds_by_type.values())
        return busy / lease if lease > 0 else 0.0

    @property
    def total_vms(self) -> int:
        return sum(self.vm_count_by_type.values())

    @property
    def data_cache_hit_rate(self) -> float:
        """Fraction of input bytes served from a VM-local cache."""
        return self.data_mb_hit / self.data_mb_total \
            if self.data_mb_total > 0 else 0.0

    @property
    def container_hit_rate(self) -> float:
        """Fraction of container activations that skipped the image
        download (active or image-cached)."""
        acts = self.container_warm + self.container_init + self.container_cold
        return (self.container_warm + self.container_init) / acts \
            if acts > 0 else 0.0

    @property
    def budget_met_fraction(self) -> float:
        if not self.workflows:
            return 1.0
        return sum(w.budget_met for w in self.workflows) / len(self.workflows)

    def makespans_by_app(self) -> Dict[str, List[int]]:
        out: Dict[str, List[int]] = {}
        for w in self.workflows:
            out.setdefault(w.app, []).append(w.makespan_ms)
        return out

    def violated_ratios(self) -> List[float]:
        return [w.cost_budget_ratio for w in self.workflows if not w.budget_met]


# ---------------------------------------------------------------------------
# Deterministic performance-variation draws
# ---------------------------------------------------------------------------


def degradation_tables(
    cfg: PlatformConfig, n_tasks: int, seed: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pre-draw per-task CPU and bandwidth degradation factors.

    Returns (cpu_deg, bw_in_deg, bw_out_deg) arrays in [0, max]; both engines
    consume the same tables so results are bit-identical.
    """
    rng = np.random.default_rng(seed)
    cpu = np.clip(
        rng.normal(cfg.cpu_degradation_mean, cfg.cpu_degradation_std, n_tasks),
        0.0,
        cfg.cpu_degradation_max,
    )
    bw_in = np.clip(
        rng.normal(cfg.bw_degradation_mean, cfg.bw_degradation_std, n_tasks),
        0.0,
        cfg.bw_degradation_max,
    )
    bw_out = np.clip(
        rng.normal(cfg.bw_degradation_mean, cfg.bw_degradation_std, n_tasks),
        0.0,
        cfg.bw_degradation_max,
    )
    return cpu.astype(np.float64), bw_in.astype(np.float64), bw_out.astype(np.float64)

"""Precomputed per-workflow ``[tasks × vm_types]`` cost tables.

Every budget decision in the paper — Algorithm 1 distribution, Algorithm 3
redistribution, the MSLBL_MW budget level, and the scheduler's tier-4/5
provisioning estimates — keeps re-evaluating the *same* static quantity:
Eq. (5) on advertised (undegraded) capacity for a (task, VM type) pair.
Profiling puts that at ~80% of both engines' wall (215k
``estimate_full_cost`` calls for a 40-workflow run).

A :class:`CostTable` evaluates the whole ``[T, V]`` grid once per
(config, workflow) with vectorized numpy float64 — the *same* IEEE
operations as the scalar reference in :mod:`core.costs`, so every entry is
bit-identical to the corresponding scalar call.  Budget distribution and
the scheduler then read table entries instead of recomputing; Algorithm 3
redistribution becomes indexed reductions over the unscheduled rows.

The table depends only on the immutable task attributes (sizes, outputs,
DAG edges) — never on budgets, policies or degradation seeds — so one
table is shared by every structural-sharing clone of a workflow
(``Workflow.clone`` propagates the ``cost_cache`` slot) and by both
engines, keeping batched↔sequential parity bit-exact by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from . import costs
from .types import MS, PlatformConfig, Workflow


def _ceil_ms(x: np.ndarray) -> np.ndarray:
    """Vectorized :func:`core.costs.ceil_ms` (tolerance-ceil to int ms)."""
    return np.ceil(x * (1.0 - costs.CEIL_TOL)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CostTable:
    """Static per-(cfg, workflow) estimate tables.

    All 2-D arrays are ``[T, V]`` with V indexed by ``cfg.vm_types``
    position (``VM.vmt_idx`` order, *not* speed order); ``by_speed``
    holds the type indices sorted by ascending MIPS for consumers that
    sweep the VM-type ladder.
    """

    cfg: PlatformConfig
    in_mb: np.ndarray          # [T] f64 — d_t^in (ext + shared + parents)
    proc_ms: np.ndarray        # [T, V] i64 — Eq. (4) PT, undegraded
    rt_out_ms: np.ndarray      # [T, V] i64 — RT + T^{d_out} (no input leg)
    est_full_cost: np.ndarray  # [T, V] f64 — Eq. (5) max: prov + cont + PT
    cost_bare: np.ndarray      # [T, V] f64 — PT only (no prov, no cont)
    by_speed: np.ndarray       # [V] i64 — type indices, ascending mips
    tier_cost: np.ndarray      # [T, V] f64 — est_full_cost in by_speed order
    # Contiguous 1-D gather columns for the array-path Algorithm 3
    # (``core.budget.update_budget_fast``): row gathers from a contiguous
    # copy beat strided views on the per-finish hot path.  Values are the
    # corresponding est_full_cost / tier_cost columns, bit-identical.
    cheap_arr: np.ndarray      # [T] f64 — est_full_cost[:, 0] contiguous
    top_arr: np.ndarray        # [T] f64 — tier_cost[:, -1] contiguous
    # Plain-Python mirrors (``tolist`` is value-preserving) for the
    # small-subset Algorithm 1/3 and scalar-select fast paths, where
    # per-call numpy dispatch overhead dwarfs the arithmetic.
    cheap_list: list           # [T] — est_full_cost[:, 0] as floats
    tier_list: list            # [T][V] — tier_cost rows as float lists
    rt_list: list              # [T][V] — rt_out_ms rows as int lists
    top_list: list             # [T] — tier_cost[:, -1] (fastest tier)
    # True ⇔ every tier_cost row is nondecreasing in speed order — the
    # precondition for the budget sweep's "everyone tops out" shortcut.
    tiers_monotone: bool

    @property
    def n_tasks(self) -> int:
        return self.proc_ms.shape[0]

    @property
    def n_types(self) -> int:
        return self.proc_ms.shape[1]


def build_table(cfg: PlatformConfig, wf: Workflow) -> CostTable:
    """Evaluate Eqs. (1)–(5) for every (task, VM type) pair at once."""
    mips = np.array([v.mips for v in cfg.vm_types], np.float64)
    bw = np.array([v.bandwidth_mbps for v in cfg.vm_types], np.float64)
    price = np.array([v.cost_per_bp for v in cfg.vm_types], np.float64)

    size = np.array([t.size_mi for t in wf.tasks], np.float64)
    out = np.array([t.out_mb for t in wf.tasks], np.float64)
    out_of = [t.out_mb for t in wf.tasks]
    # Same accumulation as the scalar path (costs.total_input_mb) so the
    # per-task totals are bit-identical to ``budget.input_mb``.
    in_mb = np.array(
        [costs.total_input_mb(t, out_of) for t in wf.tasks], np.float64
    )

    # Eqs. (1)–(3), elementwise over the [T, V] grid.  Undegraded
    # bandwidth is b_vmt · (1 − 0) — identical to the scalar estimate.
    in_ms = np.where(
        in_mb[:, None] > 0.0,
        _ceil_ms(MS * (in_mb[:, None] / bw[None, :]
                       + in_mb[:, None] / cfg.gs_read_mbps)),
        np.int64(0),
    )
    out_ms = np.where(
        out[:, None] > 0.0,
        _ceil_ms(MS * (out[:, None] / bw[None, :]
                       + out[:, None] / cfg.gs_write_mbps)),
        np.int64(0),
    )
    rt_ms = _ceil_ms(MS * size[:, None] / mips[None, :])

    proc_ms = in_ms + rt_ms + out_ms
    rt_out_ms = rt_ms + out_ms

    bp = cfg.billing_period_ms

    def billed(dur_ms: np.ndarray) -> np.ndarray:
        periods = (np.maximum(dur_ms, 0) + bp - 1) // bp
        return periods * price[None, :]

    prov = cfg.vm_provision_delay_ms
    cont = cfg.container_provision_ms
    est_full = billed(proc_ms + prov + cont)
    by_speed = np.argsort(mips, kind="stable").astype(np.int64)
    # Pre-gathered [T, K] slice the SFTD sweep reads row-wise: one
    # fancy-index per redistribution call instead of a 2-D gather.
    tier_cost = np.ascontiguousarray(est_full[:, by_speed])
    return CostTable(
        cfg=cfg,
        in_mb=in_mb,
        proc_ms=proc_ms,
        rt_out_ms=rt_out_ms,
        est_full_cost=est_full,
        cost_bare=billed(proc_ms),
        by_speed=by_speed,
        tier_cost=tier_cost,
        cheap_arr=np.ascontiguousarray(est_full[:, 0]),
        top_arr=np.ascontiguousarray(tier_cost[:, -1]),
        cheap_list=est_full[:, 0].tolist(),
        tier_list=tier_cost.tolist(),
        rt_list=rt_out_ms.tolist(),
        top_list=tier_cost[:, -1].tolist(),
        tiers_monotone=bool((np.diff(tier_cost, axis=1) >= 0).all()),
    )


def table_for(cfg: PlatformConfig, wf: Workflow) -> CostTable:
    """Memoized :func:`build_table` — one table per (cfg, workflow family).

    The cache lives on the workflow's ``cost_cache`` slot, which
    ``Workflow.clone`` shares by reference: a whole grid of
    structural-sharing clones hits one table.  A config change (the
    degradation sweeps rebuild ``PlatformConfig``) invalidates by value.
    """
    cached = wf.cost_cache
    if cached is not None and (cached.cfg is cfg or cached.cfg == cfg):
        return cached
    table = build_table(cfg, wf)
    wf.cost_cache = table
    return table

"""Task→VM selection — Algorithm 2 (EBPSM) and the MSLBL_MW baseline rule.

A ``Policy`` captures exactly how the five algorithms of the paper differ:

==============  ==========  ===========  =========  ==========  ===========
policy          containers  share scope  loc. tiers idle thresh budget mode
==============  ==========  ===========  =========  ==========  ===========
EBPSM           yes         global       yes        5 s         Alg. 1+3
EBPSM_NS        yes         workflow     yes        5 s         Alg. 1+3
EBPSM_WS        no (VM img) app          yes        5 s         Alg. 1+3
EBPSM_NC        no          global       yes        5 s         Alg. 1+3
MSLBL_MW        no          global       no         0 s         MSLBL
==============  ==========  ===========  =========  ==========  ===========

The infrastructure physics (caches, delays, billing) is identical across
policies — only selection, budget handling and deprovisioning differ.

Two implementations of Algorithm 2 share one semantics:

* the **vectorized** path (default when the caller hands over the
  :class:`~repro.sim.cloud.VMPool` registry and the pool is big enough):
  tier partition, missing-input volumes, container delays and both
  argmin reductions are numpy operations over the pool's vmid-indexed
  attribute arrays and incremental ``data_index`` / ``app_image`` /
  ``app_active`` indexes — no per-VM Python loop;
* the **scalar** path — the original per-VM loop, kept as the parity
  oracle (``REPRO_SCALAR_SELECT=1`` forces it everywhere) and as the
  faster branch for tiny pools, where numpy call overhead exceeds the
  loop cost.

Every vectorized quantity is computed with the same float64 IEEE
operations, in the same order, as the scalar reference, so the two paths
are bit-exact (property-tested in tests/test_dispatcher_matrix.py).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import costs
from .cost_tables import CostTable, _ceil_ms
from .types import MS, PlatformConfig, Task, VMType
from ..sim.cloud import VM, VM_IDLE, DataKey, VMPool

# Sentinel: "derive the owner tag from (wid, app)" — callers that already
# hold the tag (the auction path) pass it explicitly, since None is a
# legitimate tag (global sharing scope).
_AUTO_TAG = object()

# Pools smaller than this stay on the scalar loop: ~30 numpy dispatches
# cost more than dozens of per-VM Python iterations (measured crossover
# on CPython 3.10 ≈ 40–60 VMs).  Tests pin it to 0/1 to force the
# vectorized path; REPRO_VECTOR_SELECT_MIN overrides.
VECTOR_SELECT_MIN_VMS = int(os.environ.get("REPRO_VECTOR_SELECT_MIN", "48"))

# The scalar-oracle switch is read once at import: it is a test/debug
# knob (parity oracle), not a per-call runtime toggle, and an environ
# lookup per select call is measurable on the hot path.
_SCALAR_FORCED = os.environ.get("REPRO_SCALAR_SELECT") == "1"

_HUGE_MS = np.int64(1) << 60


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    use_containers: bool
    share_scope: str          # 'global' | 'workflow' | 'app'
    locality_tiers: bool
    idle_threshold_ms: int
    budget_mode: str          # 'ebpsm' | 'mslbl'

    def owner_tag(self, wid: int, app: str):
        if self.share_scope == "workflow":
            return ("wf", wid)
        if self.share_scope == "app":
            return ("app", app)
        return None


EBPSM = Policy("EBPSM", True, "global", True, 5_000, "ebpsm")
EBPSM_NS = Policy("EBPSM_NS", True, "workflow", True, 5_000, "ebpsm")
EBPSM_WS = Policy("EBPSM_WS", False, "app", True, 5_000, "ebpsm")
EBPSM_NC = Policy("EBPSM_NC", False, "global", True, 5_000, "ebpsm")
MSLBL_MW = Policy("MSLBL_MW", False, "global", False, 0, "mslbl")

ALL_POLICIES = (EBPSM, EBPSM_NS, EBPSM_WS, EBPSM_NC, MSLBL_MW)


@dataclasses.dataclass(slots=True)
class Placement:
    """Outcome of one selection decision."""

    vm: Optional[VM]              # reuse this idle VM …
    new_vmt_idx: Optional[int]    # … or provision a fresh VM of this type
    tier: int                     # 1=input-data, 2=container, 3=any idle, 4=new
    est_finish_ms: int
    est_cost: float


def _est_pipeline_ms(
    cfg: PlatformConfig,
    vmt: VMType,
    task: Task,
    missing_mb: float,
    container_ms: int,
    rt_out_ms: Optional[int] = None,
) -> int:
    """Scheduler's estimate: advertised capacity, known cache state.

    ``rt_out_ms`` short-circuits the static RT + write-back legs with the
    precomputed cost-table entry (bit-identical to the scalar sum)."""
    if rt_out_ms is None:
        rt_out_ms = costs.runtime_ms(vmt, task.size_mi) \
            + costs.transfer_out_ms(cfg, vmt, task.out_mb)
    pt = costs.transfer_in_ms(cfg, vmt, missing_mb) + rt_out_ms
    return container_ms + pt


def _est_cost(
    cfg: PlatformConfig, vmt: VMType, pipeline_ms: int, include_prov: bool
) -> float:
    dur = pipeline_ms + (cfg.vm_provision_delay_ms if include_prov else 0)
    return costs.billed_cost(cfg, vmt, dur)


@functools.lru_cache(maxsize=None)
def _speed_desc(cfg: PlatformConfig) -> Tuple[int, ...]:
    """VM-type indices by descending MIPS, ties in catalogue order — the
    exact order ``sorted(..., reverse=True)`` produced in the scalar
    tier-4 sweep."""
    return tuple(sorted(range(len(cfg.vm_types)),
                        key=lambda i: cfg.vm_types[i].mips, reverse=True))


@functools.lru_cache(maxsize=None)
def _cheapest_idx(cfg: PlatformConfig) -> int:
    return min(range(len(cfg.vm_types)),
               key=lambda i: cfg.vm_types[i].cost_per_bp)


def select(
    cfg: PlatformConfig,
    policy: Policy,
    task: Task,
    wid: int,
    app: str,
    inputs: List[Tuple[DataKey, float]],
    budget: float,
    idle_vms: Sequence[VM],
    table: Optional[CostTable] = None,
    owner_tag: object = _AUTO_TAG,
    pool: Optional[VMPool] = None,
) -> Placement:
    """Algorithm 2 for one task.  Always returns a placement (the paper
    assumes budgets are sufficient; when even the cheapest new VM exceeds the
    sub-budget we still fall back to the cheapest type — the budget is a soft
    constraint and Algorithm 3 will recover the debt downstream).

    ``table`` (the workflow's cost table) short-circuits the static
    estimate legs; every table entry is bit-identical to the scalar
    computation, so callers may mix table-carrying and bare calls freely.

    ``pool`` (the live :class:`VMPool` registry) enables the vectorized
    path; without it — or with ``REPRO_SCALAR_SELECT=1``, or below
    ``VECTOR_SELECT_MIN_VMS`` idle VMs in scope — the scalar per-VM loop
    runs instead.  Both paths are bit-exact.  ``idle_vms`` must be in
    ascending-vmid order (every caller's pool queries already are).
    """
    tag = policy.owner_tag(wid, app) if owner_tag is _AUTO_TAG else owner_tag
    scoped = [vm for vm in idle_vms
              if vm.status == VM_IDLE and vm.owner_tag == tag]
    if (pool is not None and len(scoped) >= VECTOR_SELECT_MIN_VMS
            and not _SCALAR_FORCED):
        return _select_vector(cfg, policy, task, app, inputs, budget,
                              scoped, table, pool)
    return _select_scalar(cfg, policy, task, app, inputs, budget, scoped,
                          table)


def _select_scalar(
    cfg: PlatformConfig,
    policy: Policy,
    task: Task,
    app: str,
    inputs: List[Tuple[DataKey, float]],
    budget: float,
    scoped: List[VM],
    table: Optional[CostTable],
) -> Placement:
    """Reference per-VM loop (the REPRO_SCALAR_SELECT=1 oracle).

    The tier-1/2/3 stage is one fused pass: each VM's tier, missing
    volume, pipeline estimate and billed cost are computed inline and
    the per-tier (finish, vmid) minima tracked as scalars — equivalent
    to partitioning into tier lists and scanning each (the tier of a VM
    does not depend on the other VMs), without building any of them.
    ``scoped`` is ascending by vmid, so "first strict improvement wins"
    reproduces the (finish, vmid) tie-break.
    """
    if scoped:
        use_cont = policy.use_containers
        loc = policy.locality_tiers
        rt_l = table.rt_list[task.tid] if table is not None else None
        gsr = cfg.gs_read_mbps
        bp = cfg.billing_period_ms
        c_init = cfg.container_init_ms
        c_prov = cfg.container_provision_ms
        tol = 1.0 - costs.CEIL_TOL
        ceil = math.ceil
        total_in = sum(mb for _, mb in inputs) if not loc else 0.0
        # Per-tier best (pipe, cost, vm); index 0 unused.
        best: List[Optional[Tuple[int, float, VM]]] = [None, None, None, None]
        for vm in scoped:
            if not use_cont:
                c_ms = 0
            elif vm.active_container == app:
                c_ms = 0
            elif app in vm.image_cache:
                c_ms = c_init
            else:
                c_ms = c_prov
            if loc:
                dc = vm.data_cache
                missing = 0.0
                have_all = True
                for key, mb in inputs:
                    if key not in dc:
                        missing += mb
                        if mb > 0:
                            have_all = False
                tier = 1 if have_all else (
                    2 if use_cont and vm.active_container == app else 3)
            else:
                missing = total_in
                tier = 3
            if rt_l is not None:
                ro = rt_l[vm.vmt_idx]
            else:
                ro = costs.runtime_ms(vm.vmt, task.size_mi) \
                    + costs.transfer_out_ms(cfg, vm.vmt, task.out_mb)
            if missing > 0.0:
                pipe = c_ms + int(ceil(
                    1000.0 * (missing / vm.vmt.bandwidth_mbps
                              + missing / gsr) * tol)) + ro
            else:
                pipe = c_ms + ro
            cost = ((pipe + bp - 1) // bp) * vm.vmt.cost_per_bp
            if cost > budget + 1e-9:
                continue
            b = best[tier]
            if b is None or pipe < b[0]:
                best[tier] = (pipe, cost, vm)
        for tier in (1, 2, 3):
            b = best[tier]
            if b is not None:
                return Placement(b[2], None, tier, b[0], b[1])

    # Tier 4: provision the fastest affordable new VM.  The full-input
    # pipeline estimate is exactly the cost table's proc_ms row.
    total_in = sum(mb for _, mb in inputs)
    c_ms = cfg.container_provision_ms if policy.use_containers else 0
    proc = table.proc_ms[task.tid] if table is not None else None

    def full_pipe(idx: int) -> int:
        if proc is not None:
            return int(proc[idx]) + c_ms
        return _est_pipeline_ms(cfg, cfg.vm_types[idx], task, total_in, c_ms)

    for idx in _speed_desc(cfg):
        pipe = full_pipe(idx)
        cost = _est_cost(cfg, cfg.vm_types[idx], pipe, include_prov=True)
        if cost <= budget + 1e-9:
            return Placement(
                None, idx, 4, cfg.vm_provision_delay_ms + pipe, cost
            )

    # Insufficient sub-budget (paper assumes budgets sufficient; the budget
    # is a soft constraint and Algorithm 3 recovers the debt downstream).
    # Take the *cheapest* feasible action: min-cost over reusing any idle VM
    # in scope vs. provisioning a fresh cheapest-type VM.
    cands: List[Placement] = []
    rt_out = table.rt_out_ms[task.tid] if table is not None else None
    for vm in scoped:
        cm = vm.container_ms(cfg, app, policy.use_containers)
        missing = vm.missing_mb(inputs) if policy.locality_tiers else total_in
        pipe = _est_pipeline_ms(
            cfg, vm.vmt, task, missing, cm,
            int(rt_out[vm.vmt_idx]) if rt_out is not None else None)
        cands.append(
            Placement(vm, None, 5, pipe, _est_cost(cfg, vm.vmt, pipe, False))
        )
    idx = _cheapest_idx(cfg)
    pipe = full_pipe(idx)
    cands.append(
        Placement(
            None, idx, 5, cfg.vm_provision_delay_ms + pipe,
            _est_cost(cfg, cfg.vm_types[idx], pipe, include_prov=True),
        )
    )
    return min(
        cands,
        key=lambda p: (p.est_cost, p.est_finish_ms, p.vm.vmid if p.vm else 1 << 30),
    )


def _select_vector(
    cfg: PlatformConfig,
    policy: Policy,
    task: Task,
    app: str,
    inputs: List[Tuple[DataKey, float]],
    budget: float,
    scoped: List[VM],
    table: Optional[CostTable],
    pool: VMPool,
) -> Placement:
    """Algorithm 2 as numpy reductions over the pool registry.

    Per-VM quantities (container delay, missing-input volume, pipeline
    estimate, billed cost) are built from the pool's incremental indexes
    and vmid-indexed float64 attribute arrays; the tier partition and the
    (tier, finish, vmid) argmin are array reductions.  Every float op
    matches the scalar reference's float64 sequence, so the outcome is
    bit-exact (``scoped`` ascending by vmid makes ``argmin``'s
    first-occurrence rule the scalar vmid tie-break).
    """
    V = len(scoped)
    ids = np.fromiter((vm.vmid for vm in scoped), np.int64, V)
    col = {vmid: j for j, vmid in enumerate(ids.tolist())}
    bw = pool.bandwidth[ids]
    price = pool.price[ids]
    bp = cfg.billing_period_ms

    # Container-activation delay vector from the incremental app indexes.
    active = np.zeros(V, bool)
    if policy.use_containers:
        cont = np.full(V, cfg.container_provision_ms, np.int64)
        for vid in pool.app_image.get(app, ()):
            j = col.get(vid)
            if j is not None:
                cont[j] = cfg.container_init_ms
        for vid in pool.app_active.get(app, ()):
            j = col.get(vid)
            if j is not None:
                cont[j] = 0
                active[j] = True
    else:
        cont = np.zeros(V, np.int64)

    # Missing-input MB + all-inputs-cached mask from the data index.
    # Accumulation order matches VM.missing_mb's per-input Python sum.
    total_in = sum(mb for _, mb in inputs)
    if policy.locality_tiers:
        miss = np.zeros(V, np.float64)
        have_all = np.ones(V, bool)
        for key, mb in inputs:
            holders = pool.data_index.get(key)
            if holders:
                hold = np.zeros(V, bool)
                for vid in holders:
                    j = col.get(vid)
                    if j is not None:
                        hold[j] = True
                miss += np.where(hold, 0.0, mb)
                if mb > 0:
                    have_all &= hold
            else:
                miss += mb
                if mb > 0:
                    have_all[:] = False
    else:
        miss = np.full(V, total_in, np.float64)
        have_all = np.zeros(V, bool)

    # Pipeline estimate (Eqs. 1–5 legs) and billed cost, all int64/float64
    # with the scalar op sequence.
    in_ms = np.where(
        miss > 0.0,
        _ceil_ms(MS * (miss / bw + miss / cfg.gs_read_mbps)),
        np.int64(0),
    )
    if table is not None:
        rt_out = table.rt_out_ms[task.tid][pool.type_idx[ids]]
    else:
        mips = pool.mips[ids]
        rt_out = _ceil_ms(MS * task.size_mi / mips)
        if task.out_mb > 0.0:
            rt_out = rt_out + _ceil_ms(
                MS * (task.out_mb / bw + task.out_mb / cfg.gs_write_mbps))
    pipe = cont + in_ms + rt_out
    cost = ((np.maximum(pipe, 0) + bp - 1) // bp) * price

    feas = cost <= budget + 1e-9
    if policy.locality_tiers:
        tier = np.where(have_all, 1, np.where(active, 2, 3))
    else:
        tier = np.full(V, 3, np.int64)
    t_eff = np.where(feas, tier, 9)
    best_t = int(t_eff.min()) if V else 9
    if best_t < 9:
        pipe_eff = np.where(t_eff == best_t, pipe, _HUGE_MS)
        j = int(pipe_eff.argmin())
        return Placement(scoped[j], None, best_t, int(pipe[j]),
                         float(cost[j]))

    # Tier 4: fastest affordable new VM (few types — scalar sweep over the
    # cached speed-descending order, table-backed estimates).
    c_ms = cfg.container_provision_ms if policy.use_containers else 0
    proc = table.proc_ms[task.tid] if table is not None else None

    def full_pipe(idx: int) -> int:
        if proc is not None:
            return int(proc[idx]) + c_ms
        return _est_pipeline_ms(cfg, cfg.vm_types[idx], task, total_in, c_ms)

    for idx in _speed_desc(cfg):
        pipe4 = full_pipe(idx)
        cost4 = _est_cost(cfg, cfg.vm_types[idx], pipe4, include_prov=True)
        if cost4 <= budget + 1e-9:
            return Placement(None, idx, 4,
                             cfg.vm_provision_delay_ms + pipe4, cost4)

    # Tier 5 (insufficient sub-budget): cheapest action over reusing any
    # scoped idle VM vs provisioning the cheapest type.  The reuse pipe
    # and cost vectors above are exactly the scalar candidates.
    idx = _cheapest_idx(cfg)
    pipe5 = full_pipe(idx)
    prov = Placement(
        None, idx, 5, cfg.vm_provision_delay_ms + pipe5,
        _est_cost(cfg, cfg.vm_types[idx], pipe5, include_prov=True),
    )
    if not V:
        return prov
    cmin = cost.min()
    pipe_eff = np.where(cost == cmin, pipe, _HUGE_MS)
    j = int(pipe_eff.argmin())
    if (float(cost[j]), int(pipe[j]), scoped[j].vmid) < (
            prov.est_cost, prov.est_finish_ms, 1 << 30):
        return Placement(scoped[j], None, 5, int(pipe[j]), float(cost[j]))
    return prov

"""Task→VM selection — Algorithm 2 (EBPSM) and the MSLBL_MW baseline rule.

A ``Policy`` captures exactly how the five algorithms of the paper differ:

==============  ==========  ===========  =========  ==========  ===========
policy          containers  share scope  loc. tiers idle thresh budget mode
==============  ==========  ===========  =========  ==========  ===========
EBPSM           yes         global       yes        5 s         Alg. 1+3
EBPSM_NS        yes         workflow     yes        5 s         Alg. 1+3
EBPSM_WS        no (VM img) app          yes        5 s         Alg. 1+3
EBPSM_NC        no          global       yes        5 s         Alg. 1+3
MSLBL_MW        no          global       no         0 s         MSLBL
==============  ==========  ===========  =========  ==========  ===========

The infrastructure physics (caches, delays, billing) is identical across
policies — only selection, budget handling and deprovisioning differ.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from . import costs
from .cost_tables import CostTable
from .types import PlatformConfig, Task, VMType
from ..sim.cloud import VM, VM_IDLE, DataKey

# Sentinel: "derive the owner tag from (wid, app)" — callers that already
# hold the tag (the auction path) pass it explicitly, since None is a
# legitimate tag (global sharing scope).
_AUTO_TAG = object()


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    use_containers: bool
    share_scope: str          # 'global' | 'workflow' | 'app'
    locality_tiers: bool
    idle_threshold_ms: int
    budget_mode: str          # 'ebpsm' | 'mslbl'

    def owner_tag(self, wid: int, app: str):
        if self.share_scope == "workflow":
            return ("wf", wid)
        if self.share_scope == "app":
            return ("app", app)
        return None


EBPSM = Policy("EBPSM", True, "global", True, 5_000, "ebpsm")
EBPSM_NS = Policy("EBPSM_NS", True, "workflow", True, 5_000, "ebpsm")
EBPSM_WS = Policy("EBPSM_WS", False, "app", True, 5_000, "ebpsm")
EBPSM_NC = Policy("EBPSM_NC", False, "global", True, 5_000, "ebpsm")
MSLBL_MW = Policy("MSLBL_MW", False, "global", False, 0, "mslbl")

ALL_POLICIES = (EBPSM, EBPSM_NS, EBPSM_WS, EBPSM_NC, MSLBL_MW)


@dataclasses.dataclass
class Placement:
    """Outcome of one selection decision."""

    vm: Optional[VM]              # reuse this idle VM …
    new_vmt_idx: Optional[int]    # … or provision a fresh VM of this type
    tier: int                     # 1=input-data, 2=container, 3=any idle, 4=new
    est_finish_ms: int
    est_cost: float


def _est_pipeline_ms(
    cfg: PlatformConfig,
    vmt: VMType,
    task: Task,
    missing_mb: float,
    container_ms: int,
    rt_out_ms: Optional[int] = None,
) -> int:
    """Scheduler's estimate: advertised capacity, known cache state.

    ``rt_out_ms`` short-circuits the static RT + write-back legs with the
    precomputed cost-table entry (bit-identical to the scalar sum)."""
    if rt_out_ms is None:
        rt_out_ms = costs.runtime_ms(vmt, task.size_mi) \
            + costs.transfer_out_ms(cfg, vmt, task.out_mb)
    pt = costs.transfer_in_ms(cfg, vmt, missing_mb) + rt_out_ms
    return container_ms + pt


def _est_cost(
    cfg: PlatformConfig, vmt: VMType, pipeline_ms: int, include_prov: bool
) -> float:
    dur = pipeline_ms + (cfg.vm_provision_delay_ms if include_prov else 0)
    return costs.billed_cost(cfg, vmt, dur)


def _best_in(
    cfg: PlatformConfig,
    policy: Policy,
    task: Task,
    app: str,
    inputs: List[Tuple[DataKey, float]],
    budget: float,
    vms: Sequence[VM],
    tier: int,
    table: Optional[CostTable] = None,
) -> Optional[Placement]:
    """Min-(finish, vmid) feasible VM among ``vms`` (Alg. 2 inner choice)."""
    best: Optional[Placement] = None
    rt_out = table.rt_out_ms[task.tid] if table is not None else None
    for vm in vms:
        c_ms = vm.container_ms(cfg, app, policy.use_containers)
        if policy.locality_tiers:
            missing = vm.missing_mb(inputs)
        else:
            # MSLBL's estimate ignores cache contents (conservative).
            missing = sum(mb for _, mb in inputs)
        pipe = _est_pipeline_ms(
            cfg, vm.vmt, task, missing, c_ms,
            int(rt_out[vm.vmt_idx]) if rt_out is not None else None)
        cost = _est_cost(cfg, vm.vmt, pipe, include_prov=False)
        if cost > budget + 1e-9:
            continue
        cand = Placement(vm, None, tier, pipe, cost)
        if best is None or (cand.est_finish_ms, cand.vm.vmid) < (
            best.est_finish_ms,
            best.vm.vmid,
        ):
            best = cand
    return best


def select(
    cfg: PlatformConfig,
    policy: Policy,
    task: Task,
    wid: int,
    app: str,
    inputs: List[Tuple[DataKey, float]],
    budget: float,
    idle_vms: Sequence[VM],
    table: Optional[CostTable] = None,
    owner_tag: object = _AUTO_TAG,
) -> Placement:
    """Algorithm 2 for one task.  Always returns a placement (the paper
    assumes budgets are sufficient; when even the cheapest new VM exceeds the
    sub-budget we still fall back to the cheapest type — the budget is a soft
    constraint and Algorithm 3 will recover the debt downstream).

    ``table`` (the workflow's cost table) short-circuits the static
    estimate legs; every table entry is bit-identical to the scalar
    computation, so callers may mix table-carrying and bare calls freely.
    """
    tag = policy.owner_tag(wid, app) if owner_tag is _AUTO_TAG else owner_tag
    pool = [vm for vm in idle_vms if vm.status == VM_IDLE and vm.owner_tag == tag]

    if policy.locality_tiers and pool:
        tier1 = [vm for vm in pool if vm.has_all_inputs(inputs)]
        p = _best_in(cfg, policy, task, app, inputs, budget, tier1, tier=1,
                     table=table)
        if p is not None:
            return p
        rest = [vm for vm in pool if vm not in tier1]
        if policy.use_containers:
            tier2 = [vm for vm in rest if vm.active_container == app]
            p = _best_in(cfg, policy, task, app, inputs, budget, tier2,
                         tier=2, table=table)
            if p is not None:
                return p
            rest = [vm for vm in rest if vm not in tier2]
        p = _best_in(cfg, policy, task, app, inputs, budget, rest, tier=3,
                     table=table)
        if p is not None:
            return p
    elif pool:
        p = _best_in(cfg, policy, task, app, inputs, budget, pool, tier=3,
                     table=table)
        if p is not None:
            return p

    # Tier 4: provision the fastest affordable new VM.  The full-input
    # pipeline estimate is exactly the cost table's proc_ms row.
    total_in = sum(mb for _, mb in inputs)
    c_ms = cfg.container_provision_ms if policy.use_containers else 0
    proc = table.proc_ms[task.tid] if table is not None else None

    def full_pipe(idx: int) -> int:
        if proc is not None:
            return int(proc[idx]) + c_ms
        return _est_pipeline_ms(cfg, cfg.vm_types[idx], task, total_in, c_ms)

    for idx in sorted(
        range(len(cfg.vm_types)),
        key=lambda i: cfg.vm_types[i].mips,
        reverse=True,
    ):
        pipe = full_pipe(idx)
        cost = _est_cost(cfg, cfg.vm_types[idx], pipe, include_prov=True)
        if cost <= budget + 1e-9:
            return Placement(
                None, idx, 4, cfg.vm_provision_delay_ms + pipe, cost
            )

    # Insufficient sub-budget (paper assumes budgets sufficient; the budget
    # is a soft constraint and Algorithm 3 recovers the debt downstream).
    # Take the *cheapest* feasible action: min-cost over reusing any idle VM
    # in scope vs. provisioning a fresh cheapest-type VM.
    cands: List[Placement] = []
    rt_out = table.rt_out_ms[task.tid] if table is not None else None
    for vm in pool:
        cm = vm.container_ms(cfg, app, policy.use_containers)
        missing = vm.missing_mb(inputs) if policy.locality_tiers else total_in
        pipe = _est_pipeline_ms(
            cfg, vm.vmt, task, missing, cm,
            int(rt_out[vm.vmt_idx]) if rt_out is not None else None)
        cands.append(
            Placement(vm, None, 5, pipe, _est_cost(cfg, vm.vmt, pipe, False))
        )
    idx = min(range(len(cfg.vm_types)), key=lambda i: cfg.vm_types[i].cost_per_bp)
    pipe = full_pipe(idx)
    cands.append(
        Placement(
            None, idx, 5, cfg.vm_provision_delay_ms + pipe,
            _est_cost(cfg, cfg.vm_types[idx], pipe, include_prov=True),
        )
    )
    return min(
        cands,
        key=lambda p: (p.est_cost, p.est_finish_ms, p.vm.vmid if p.vm else 1 << 30),
    )

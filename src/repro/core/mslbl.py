"""MSLBL_MW — the paper's baseline (Chen et al. MSLBL, extended to multiple
workflows per Section 5 of the paper).

Budget distribution: compute the workflow *budget level*
``b = (β − Σ c_min) / (Σ c_max − Σ c_min)`` (clipped to [0,1]) and give each
task ``c_min + b · (c_max − c_min)`` — a safety-net allocation between the
cheapest and fastest execution cost.  Leftover sub-budget of a completed task
rolls over to the next task scheduled (single spare pool per workflow).

``c_min`` / ``c_max`` are the cheapest- and fastest-type columns of the
workflow's precomputed :mod:`core.cost_tables` table — the same numeric
backbone Algorithm 1/3 read, so the EBPSM-vs-MSLBL comparison stays
apples-to-apples down to the bit.
"""
from __future__ import annotations

from . import cost_tables
from .budget import execution_order
from .types import PlatformConfig, Workflow


def distribute_budget_mslbl(cfg: PlatformConfig, wf: Workflow, budget: float) -> None:
    execution_order(cfg, wf)  # also assigns levels/ranks
    table = cost_tables.table_for(cfg, wf)
    cheapest_idx = min(range(len(cfg.vm_types)),
                       key=lambda i: cfg.vm_types[i].mips)
    fastest_idx = max(range(len(cfg.vm_types)),
                      key=lambda i: cfg.vm_types[i].mips)
    c_min = table.est_full_cost[:, cheapest_idx]
    c_max = table.est_full_cost[:, fastest_idx]
    lo, hi = float(c_min.sum()), float(c_max.sum())
    if hi - lo < 1e-9:
        level = 1.0
    else:
        level = (budget - lo) / (hi - lo)
    level = min(max(level, 0.0), 1.0)
    for t in wf.tasks:
        t.budget = float(c_min[t.tid] + level * (c_max[t.tid] - c_min[t.tid]))

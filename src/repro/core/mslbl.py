"""MSLBL_MW — the paper's baseline (Chen et al. MSLBL, extended to multiple
workflows per Section 5 of the paper).

Budget distribution: compute the workflow *budget level*
``b = (β − Σ c_min) / (Σ c_max − Σ c_min)`` (clipped to [0,1]) and give each
task ``c_min + b · (c_max − c_min)`` — a safety-net allocation between the
cheapest and fastest execution cost.  Leftover sub-budget of a completed task
rolls over to the next task scheduled (single spare pool per workflow).
"""
from __future__ import annotations

from typing import List

from . import costs
from .budget import execution_order, input_mb
from .types import PlatformConfig, Workflow


def distribute_budget_mslbl(cfg: PlatformConfig, wf: Workflow, budget: float) -> None:
    order = execution_order(cfg, wf)  # also assigns levels/ranks
    cheapest = min(cfg.vm_types, key=lambda v: v.mips)
    fastest = max(cfg.vm_types, key=lambda v: v.mips)
    c_min: List[float] = []
    c_max: List[float] = []
    for t in wf.tasks:
        mb = input_mb(wf, t)
        c_min.append(costs.estimate_full_cost(cfg, cheapest, t, mb))
        c_max.append(costs.estimate_full_cost(cfg, fastest, t, mb))
    lo, hi = sum(c_min), sum(c_max)
    if hi - lo < 1e-9:
        level = 1.0
    else:
        level = (budget - lo) / (hi - lo)
    level = min(max(level, 0.0), 1.0)
    for t in wf.tasks:
        t.budget = c_min[t.tid] + level * (c_max[t.tid] - c_min[t.tid])

"""Cost and timing model — Eqs. (1)–(5) of the paper, in integer ms.

Estimated quantities use the advertised VM capacity (the scheduler's view);
actual quantities apply the pre-drawn degradation factors (the cloud's view).
"""
from __future__ import annotations

import math
from typing import Optional

from .types import MS, PlatformConfig, Task, VMType

# Tolerance-ceil: discretization to integer ms must agree bit-for-bit
# between this float64 reference and the float32 affinity kernel.  A bare
# ceil flips across integer boundaries under 1-ulp noise (e.g.
# 30/20 + 30/50 rounds to 2100.0000238 in f32, 2099.99999… in f64); the
# relative backoff makes both land on the same integer.
CEIL_TOL = 1e-6


def ceil_ms(x: float) -> int:
    return int(math.ceil(x * (1.0 - CEIL_TOL)))


def transfer_in_ms(cfg: PlatformConfig, vmt: VMType, mb: float, bw_deg: float = 0.0) -> int:
    """Eq. (1): T^{d_in} = d/b_vmt + d/GS_r (ms)."""
    if mb <= 0.0:
        return 0
    bw = vmt.bandwidth_mbps * (1.0 - bw_deg)
    return ceil_ms(MS * (mb / bw + mb / cfg.gs_read_mbps))


def transfer_out_ms(cfg: PlatformConfig, vmt: VMType, mb: float, bw_deg: float = 0.0) -> int:
    """Eq. (2): T^{d_out} = d/b_vmt + d/GS_w (ms)."""
    if mb <= 0.0:
        return 0
    bw = vmt.bandwidth_mbps * (1.0 - bw_deg)
    return ceil_ms(MS * (mb / bw + mb / cfg.gs_write_mbps))


def runtime_ms(vmt: VMType, size_mi: float, cpu_deg: float = 0.0) -> int:
    """Eq. (3): RT = S_t / p_vmt (ms), optionally degraded."""
    p = vmt.mips * (1.0 - cpu_deg)
    return ceil_ms(MS * size_mi / p)


def processing_ms(
    cfg: PlatformConfig,
    vmt: VMType,
    task: Task,
    in_mb: float,
    cpu_deg: float = 0.0,
    bw_in_deg: float = 0.0,
    bw_out_deg: float = 0.0,
) -> int:
    """Eq. (4): PT = T^{d_in} + RT + T^{d_out}.

    ``in_mb`` is the number of MB that must actually be fetched from global
    storage (cached inputs cost nothing — the resource-sharing policy).
    """
    return (
        transfer_in_ms(cfg, vmt, in_mb, bw_in_deg)
        + runtime_ms(vmt, task.size_mi, cpu_deg)
        + transfer_out_ms(cfg, vmt, task.out_mb, bw_out_deg)
    )


def billed_cost(cfg: PlatformConfig, vmt: VMType, duration_ms: int) -> float:
    """Eq. (5) core: ceil(duration / bp) * c_vmt."""
    bp = cfg.billing_period_ms
    periods = (max(duration_ms, 0) + bp - 1) // bp
    return periods * vmt.cost_per_bp


def task_cost(
    cfg: PlatformConfig,
    vmt: VMType,
    task: Task,
    in_mb: float,
    include_vm_provision: bool,
    container_ms: int,
    cpu_deg: float = 0.0,
    bw_in_deg: float = 0.0,
    bw_out_deg: float = 0.0,
) -> float:
    """Eq. (5): C = ceil((prov_vmt + prov_c + PT)/bp) * c_vmt.

    ``include_vm_provision`` charges prov_vmt when this task triggers a fresh
    VM acquisition; ``container_ms`` is the actually-incurred container
    provisioning time (0 when the image is warm).
    """
    dur = processing_ms(cfg, vmt, task, in_mb, cpu_deg, bw_in_deg, bw_out_deg)
    if include_vm_provision:
        dur += cfg.vm_provision_delay_ms
    dur += container_ms
    return billed_cost(cfg, vmt, dur)


def estimate_full_cost(
    cfg: PlatformConfig, vmt: VMType, task: Task, in_mb: float
) -> float:
    """The scheduler's conservative per-task cost estimate.

    Maximum cost per Eq. (5): assumes fresh VM provisioning, full container
    provisioning, and every input (``in_mb``) fetched from global storage
    (no locality).  Used by budget distribution for both EBPSM and MSLBL so
    the comparison is apples-to-apples.
    """
    return task_cost(
        cfg, vmt, task, in_mb, include_vm_provision=True,
        container_ms=cfg.container_provision_ms,
    )


def total_input_mb(task: Task, out_mb_of: list) -> float:
    """d_t^in = external + shared + all parents' outputs."""
    shared = sum(mb for _, mb in task.shared_in)
    return task.ext_in_mb + shared + sum(out_mb_of[p] for p in task.parents)

"""Batched JAX simulation engine: a whole experiment grid per device pass.

The paper's headline comparison (EBPSM variants vs MSLBL_MW across
arrival rates, budgets and seeds) needs hundreds of independent
simulations.  Running them one ``SimEngine`` at a time leaves the device
idle between tiny kernel calls; running them here batches the hot path.

Architecture
------------
Every grid member (policy × workload × seed) owns a :class:`SimState`
(``core.engine``) — the single source of truth for arrival / finish /
VM_READY / REAP handling, the execution pipeline, and Algorithm 3 budget
redistribution.  :class:`BatchSimEngine` drives members as coroutines
that **rendezvous at auction points**:

1. each member runs uninterrupted — full cache locality, zero
   per-timestamp lockstep overhead — until its next scheduling cycle
   that wants the auction (``CycleRequest``) or until it completes;
2. every parked member's request is auctioned together: each auction
   round stacks all pair arrays into one resident ``[B, T, V]`` buffer
   and scores it with a single ``jax.vmap``'d affinity kernel call
   (``kernels.affinity.ops.affinity_batch``, ``core.jax_cycles``);
3. placements commit through the shared ``apply_cycle_placements`` and
   each member resumes toward its next auction point.

Members are independent simulations, so the interleaving is free to
choose; rendezvous maximizes sharing (every batched kernel call carries
*all* members with a pending auction, not just the ones whose event
timestamps happened to coincide) while members that never auction —
below-threshold cycles, MSLBL — run start-to-finish in one slice,
exactly like the sequential reference.

Because the transition semantics are shared code and the auction is the
property-tested ``jax_cycles`` fixed point, results are bit-exact with
the sequential reference (tests/test_jax_engine.py) in the paper's
sufficient-budget regime.  MSLBL mutates spare budget mid-cycle, so
MSLBL members run the per-task reference cycle inside their own slice
(exactly as ``SimEngine`` itself does).

Grid members simulate a structural-sharing clone of their workload
(``Workflow.clone``): per-member ``Task`` objects for the mutable budget
fields, shared immutable DAG lists — not a ``copy.deepcopy`` of the
whole object graph.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from . import budget as budget_mod
from .engine import SimState
from .jax_cycles import CycleRequest, multi_cycle
from .mslbl import distribute_budget_mslbl
from .scheduler import Policy
from .types import PlatformConfig, SimResult, Workflow, clone_workload

# One grid member: (policy, workflows, degradation seed).
GridMember = Tuple[Policy, Sequence[Workflow], int]

# Auction engagement threshold (queue × pool pairs) for grid members.
# Lower than the solo SimEngine's core.engine.AUCTION_MIN_PAIRS: a grid
# round amortizes the device call across every parked member, and the
# auction now replicates the insufficient-budget tier-5 interleaving
# (core.jax_cycles), so mid-size cycles can ride affinity_batch safely.
AUCTION_MIN_PAIRS_GRID = 2048

# What a member yields when it parks at an auction point.
_AuctionPoint = Tuple[SimState, list, list, CycleRequest]


class BatchSimEngine:
    """N independent simulations, rendezvous rounds, batched cycle scoring."""

    def __init__(
        self,
        cfg: PlatformConfig,
        members: Sequence[GridMember],
        trace: bool = False,
        use_pallas: bool = False,
        batched: object = "auto",
        predistributed: Optional[Sequence[Optional[Dict[int, float]]]] = None,
    ):
        """``batched``: True / False / "auto" — "auto" routes a member's
        cycle through the auction only when its queue×pool product
        reaches ``AUCTION_MIN_PAIRS_GRID`` (tiny cycles keep the cheap
        per-task path; outcomes are bit-exact with ``SimEngine`` on
        either path, including insufficient-budget tier-5 cycles).

        ``predistributed``: optional per-member wid → spare maps for
        workloads whose arrival-time budget distribution already ran (see
        ``predistribute_workload`` / ``SimState``)."""
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.batched = batched
        pre = predistributed or [None] * len(members)
        self.states = [
            SimState(cfg, policy, workflows, seed=seed, trace=trace,
                     predistributed=p)
            for (policy, workflows, seed), p in zip(members, pre)
        ]
        self.rounds = 0
        self.batched_calls = 0
        self.wall_s = 0.0  # whole-grid wall clock of the last run()

    def _wants_auction(self, st: SimState, n_idle: int) -> bool:
        """EBPSM-family cycles go through the auction; MSLBL mutates spare
        budget mid-cycle and keeps the per-task reference path."""
        if st.policy.budget_mode != "ebpsm" or not st.queue:
            return False
        if self.batched is True:
            return True
        if self.batched == "auto":
            return len(st.queue) * n_idle >= AUCTION_MIN_PAIRS_GRID
        return False

    def _member_steps(self, st: SimState) -> Iterator[_AuctionPoint]:
        """Run one member until its next auction point (yield) or until it
        completes.  The driver commits the auction's placements before
        resuming, so from the member's view the decision stream is
        identical to ``SimEngine``'s."""
        while not st.done:
            if not st.advance():
                continue
            idle = st.pool.idle_vms()
            if self._wants_auction(st, len(idle)):
                tasks, metas = st.drain_queue_for_cycle()
                yield st, metas, idle, CycleRequest(
                    self.cfg, st.policy, tasks, idle, st.pool)
            else:
                st.sequential_cycle(idle)
                st.post_cycle()

    def run(self) -> List[SimResult]:
        t0 = _time.time()
        for st in self.states:
            st.seed_arrivals()
        live = [self._member_steps(st) for st in self.states]
        while live:
            self.rounds += 1
            owners: List[Tuple[SimState, list, list]] = []
            requests: List[CycleRequest] = []
            parked: List[Iterator[_AuctionPoint]] = []
            for stepper in live:
                point = next(stepper, None)
                if point is None:
                    continue  # member ran to completion
                st, metas, idle, req = point
                owners.append((st, metas, idle))
                requests.append(req)
                parked.append(stepper)
            if not requests:
                break
            self.batched_calls += 1
            all_placements = multi_cycle(self.cfg, requests,
                                         use_pallas=self.use_pallas)
            for (st, metas, idle), placements in zip(owners, all_placements):
                st.apply_cycle_placements(metas, placements, idle)
                st.post_cycle()
            live = parked
        self.wall_s = _time.time() - t0
        # Per-member wall is the amortized share of the grid run (they sum
        # to the total); the whole-grid wall lives on the engine/BatchResult.
        share = self.wall_s / len(self.states) if self.states else 0.0
        return [st.finalize(wall_s=share) for st in self.states]


# ---------------------------------------------------------------------------
# Grid API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GridEntry:
    """One cell of the experiment grid."""

    policy: str
    workload: int          # index into the workloads argument
    seed: int
    result: SimResult


@dataclasses.dataclass
class BatchResult:
    entries: List[GridEntry]
    wall_s: float

    @property
    def results(self) -> List[SimResult]:
        return [e.result for e in self.entries]

    def by_policy(self) -> Dict[str, List[GridEntry]]:
        out: Dict[str, List[GridEntry]] = {}
        for e in self.entries:
            out.setdefault(e.policy, []).append(e)
        return out


def predistribute_workload(
    cfg: PlatformConfig, wl: Sequence[Workflow], budget_mode: str
) -> Tuple[List[Workflow], Dict[int, float]]:
    """Run the arrival-time budget distribution once on a prototype clone.

    Algorithm 1 (and the MSLBL distribution) is deterministic in
    (cfg, workflow, budget) — independent of policy and degradation seed
    — so every grid member with the same workload and budget mode gets
    identical sub-budgets.  Returns the distributed prototype (clone it
    per member) and the wid → spare map to seed each member's
    ``SimState`` with.
    """
    proto = clone_workload(wl)
    spares: Dict[int, float] = {}
    for wf in proto:
        if budget_mode == "mslbl":
            distribute_budget_mslbl(cfg, wf, wf.budget)
            spares[wf.wid] = 0.0
        else:
            spares[wf.wid] = budget_mod.distribute_budget(cfg, wf, wf.budget)
    return proto, spares


def _as_workload_list(
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
) -> List[List[Workflow]]:
    wls = list(workloads)
    if not wls:
        return []
    if isinstance(wls[0], Workflow):
        return [wls]  # a single workload
    return [list(w) for w in wls]


def simulate_batch(
    cfg: PlatformConfig,
    policy: Union[Policy, Sequence[Policy]],
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
    seed: Union[int, Sequence[int]] = 0,
    trace: bool = False,
    use_pallas: bool = False,
    batched: object = "auto",
) -> BatchResult:
    """Evaluate the full grid policies × workloads × seeds in one batched
    engine run.

    ``policy`` / ``seed`` accept a single value or a sequence;
    ``workloads`` accepts one workload (a sequence of ``Workflow``) or a
    sequence of workloads.  Budget distribution mutates tasks, so every
    member simulates a structural-sharing clone (``Workflow.clone``) —
    callers can reuse the same workload objects across the grid.
    """
    policies = [policy] if isinstance(policy, Policy) else list(policy)
    seeds = [seed] if isinstance(seed, int) else list(seed)
    wls = _as_workload_list(workloads)
    members: List[GridMember] = []
    labels: List[Tuple[str, int, int]] = []
    pre: List[Dict[int, float]] = []
    # Arrival-time budget distribution is shared: computed once per
    # (workload, budget_mode), inherited by every member's clone.
    protos: Dict[Tuple[int, str], Tuple[List[Workflow], Dict[int, float]]] = {}
    for pol in policies:
        for wi, wl in enumerate(wls):
            key = (wi, pol.budget_mode)
            if key not in protos:
                protos[key] = predistribute_workload(cfg, wl, pol.budget_mode)
            proto, spares = protos[key]
            for s in seeds:
                members.append((pol, clone_workload(proto), s))
                labels.append((pol.name, wi, s))
                pre.append(spares)
    engine = BatchSimEngine(cfg, members, trace=trace, use_pallas=use_pallas,
                            batched=batched, predistributed=pre)
    results = engine.run()
    entries = [
        GridEntry(policy=name, workload=wi, seed=s, result=res)
        for (name, wi, s), res in zip(labels, results)
    ]
    return BatchResult(entries=entries, wall_s=engine.wall_s)

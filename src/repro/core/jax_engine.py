"""Batched JAX simulation engine: a whole experiment grid per device pass.

The paper's headline comparison (EBPSM variants vs MSLBL_MW across
arrival rates, budgets and seeds) needs hundreds of independent
simulations.  Running them one ``SimEngine`` at a time leaves the device
idle between tiny kernel calls; running them here batches the hot path.

Architecture
------------
Every grid member (policy × workload × seed) owns a :class:`SimState`
(``core.engine``) — the single source of truth for arrival / finish /
VM_READY / REAP handling, the execution pipeline, and Algorithm 3 budget
redistribution.  :class:`BatchSimEngine` drives members as coroutines
that **rendezvous at auction points**:

1. each member runs uninterrupted — full cache locality, zero
   per-timestamp lockstep overhead — until its next scheduling cycle
   with queued tasks (EBPSM family) or until it completes;
2. the driver decides **per rendezvous round, on aggregate size**: when
   the summed queue × pool pair count of every parked member clears
   ``AUCTION_MIN_PAIRS_ROUND``, all parked cycles are auctioned together
   — pair arrays stack into one resident ``[B, T, V]`` buffer scored by
   a single ``jax.vmap``'d affinity kernel call
   (``kernels.affinity.ops.affinity_batch``, ``core.jax_cycles``);
   below the threshold each parked cycle runs the per-task reference
   path instead (bit-exact either way);
3. placements commit through the shared ``apply_cycle_placements`` and
   each member resumes toward its next cycle.

Members are independent simulations, so the interleaving is free to
choose; rendezvous maximizes sharing (every batched kernel call carries
*all* members with a pending cycle, not just the ones whose event
timestamps happened to coincide — dozens of individually small cycles
batch into one device call) while members that never park — MSLBL, or
``batched=False`` — run start-to-finish in one slice, exactly like the
sequential reference.

Because the transition semantics are shared code and the auction is the
property-tested ``jax_cycles`` fixed point, results are bit-exact with
the sequential reference (tests/test_jax_engine.py) in the paper's
sufficient-budget regime.  MSLBL mutates spare budget mid-cycle, so
MSLBL members run the per-task reference cycle inside their own slice
(exactly as ``SimEngine`` itself does).

Grid members simulate a structural-sharing clone of their workload
(``Workflow.clone``): per-member ``Task`` objects for the mutable budget
fields, shared immutable DAG lists — not a ``copy.deepcopy`` of the
whole object graph.
"""
from __future__ import annotations

import dataclasses
import pickle as _pickle
import time as _time
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from . import budget as budget_mod
from ..chaos import ChaosConfig
from .engine import (STREAM_SNAPSHOT_VERSION, SimState,
                     _object_state_forced, profile_overhead_s)
from .jax_cycles import CycleRequest, multi_cycle
from ..obs import events as obs_events
from ..obs import monitor as obs_monitor
from ..obs.events import EventLog
from .mslbl import distribute_budget_mslbl
from .scheduler import Policy
from .types import PlatformConfig, SimResult, StreamState, Workflow, \
    clone_workload

# One grid member: (policy, workflows, degradation seed).
GridMember = Tuple[Policy, Sequence[Workflow], int]

# Legacy per-member auction threshold (queue × pool pairs), kept for the
# ``batched="member"`` compatibility mode the grid-wall benchmark uses as
# its measured baseline.  The default dispatcher decides on *aggregate*
# round size instead (below).
AUCTION_MIN_PAIRS_GRID = 2048

# Aggregate-round auction threshold: at each rendezvous the driver sums
# every parked member's queue × pool pair product and rides one batched
# ``multi_cycle`` whenever the round total clears this.  Much lower than
# the per-member threshold — one resident [B, T, V] kernel call amortizes
# across all parked members, so dozens of small cycles that individually
# never justified a device call now batch into one.
AUCTION_MIN_PAIRS_ROUND = 1536

# What a member yields when it parks at a pending scheduling cycle:
# (state, idle snapshot).  The driver decides serial vs batched.
_CyclePoint = Tuple[SimState, list]


class StreamInterrupted(Exception):
    """Raised by :meth:`BatchSimEngine.run` when the checkpoint hook asks
    the stream to stop after a snapshot — the caller resumes later from
    the written checkpoint (``repro.exp.run --resume``)."""


class BatchSimEngine:
    """N independent simulations, rendezvous rounds, batched cycle scoring."""

    def __init__(
        self,
        cfg: PlatformConfig,
        members: Sequence[GridMember],
        trace: bool = False,
        use_pallas: object = "auto",
        batched: object = "auto",
        predistributed: Optional[Sequence[Optional[Dict[int, float]]]] = None,
        redistribute: str = "finish",
        soa: Optional[bool] = None,
        profile: Optional[bool] = None,
        events: Optional[bool] = None,
        chaos: Optional[ChaosConfig] = None,
        monitor: Optional[bool] = None,
        monitor_maps: Optional[Tuple[Dict[int, str], Dict[str, str],
                                     Dict[int, int]]] = None,
    ):
        """``batched``: False / True / "auto" / "member".

        * ``"auto"`` (default) — the aggregate-round dispatcher: members
          park at every EBPSM scheduling cycle; a rendezvous round rides
          the batched auction when the summed queue×pool pairs of all
          parked members reach ``AUCTION_MIN_PAIRS_ROUND``, else each
          parked cycle runs the per-task reference path.
        * ``True`` — every parked round is auctioned; ``False`` — members
          never park (pure sequential reference, one slice per member).
        * ``"member"`` — the pre-aggregate per-member rule (pairs ≥
          ``AUCTION_MIN_PAIRS_GRID``), kept as the benchmark baseline.

        Outcomes are bit-exact with ``SimEngine`` on every path,
        including insufficient-budget tier-5 cycles.

        ``use_pallas``: False / True / "auto" — "auto" engages the Pallas
        affinity kernel when the default JAX backend is TPU and falls
        back to the jnp oracle elsewhere (both parity-gated).

        ``predistributed``: optional per-member wid → spare maps for
        workloads whose arrival-time budget distribution already ran (see
        ``predistribute_workload`` / ``SimState``).

        ``redistribute``: ``"finish"`` (default, per-task-finish Algorithm
        3, bit-exact with ``SimEngine``) or ``"round"`` — each member
        banks finish surpluses and redistributes once per workflow per
        scheduling cycle, so all finish events inside one rendezvous
        round coalesce into a single array call (shared ``SimState``
        semantics: engine↔engine parity holds in both modes).

        ``soa``: state layout (see ``SimState``).  In SoA mode (the
        default) the engine allocates ONE pooled :class:`StreamState`
        spanning every member and hands each ``SimState`` a zero-copy
        :meth:`StreamState.view` segment — thousands of open-stream
        members share a handful of flat numpy arrays instead of carrying
        per-member object graphs, and driver-level aggregates
        (:meth:`stream_stats`) reduce over the pooled arrays directly.

        ``profile`` / ``events``: per-engine toggles (None defers to
        ``REPRO_PROFILE`` / ``REPRO_TRACE``).  With events on, every
        member ``SimState`` gets its own log (exported per cell by
        ``repro.exp.run --trace-dir``) and the driver keeps a separate
        :class:`EventLog` of grid-level events — rendezvous rounds and
        batched auction calls, timestamped by round index (driver events
        span members, so no single simulated clock applies).

        ``chaos``: fault-injection knobs (:class:`repro.chaos.ChaosConfig`)
        applied to every member — each member's draws are keyed by its own
        seed, and injections stay bit-exact with a ``SimEngine`` run of
        the same (policy, workflows, seed, chaos)."""
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.batched = batched
        self.redistribute = redistribute
        pre = predistributed or [None] * len(members)
        soa_resolved = (not _object_state_forced()) if soa is None \
            else bool(soa)
        self.stream: Optional[StreamState] = None
        views: List[Optional[StreamState]] = [None] * len(members)
        if soa_resolved and members:
            wf_counts = [len(wfs) for _, wfs, _ in members]
            task_counts = [sum(w.n_tasks for w in wfs)
                           for _, wfs, _ in members]
            self.stream = StreamState(sum(wf_counts), sum(task_counts))
            wf_lo = task_lo = 0
            for i, (nw, nt) in enumerate(zip(wf_counts, task_counts)):
                views[i] = self.stream.view(wf_lo, wf_lo + nw,
                                            task_lo, task_lo + nt)
                wf_lo += nw
                task_lo += nt
        ev_enabled = (obs_events._trace_enabled() if events is None
                      else bool(events))
        self.elog: Optional[EventLog] = EventLog() if ev_enabled else None
        # Live SLO monitor: one independent Monitor per member (windows
        # and alerts are per-simulation state), sharing one optional
        # (tenant_of, qos_of, ideal_ms) map tuple — online streams run
        # every policy member over the same tenant workload.  The driver
        # log gets no monitor (GRID_* rounds are not platform signals).
        mon_enabled = (obs_monitor._monitor_enabled() if monitor is None
                       else bool(monitor))
        t_of, q_of, i_ms = monitor_maps or (None, None, None)
        self.states = [
            SimState(cfg, policy, workflows, seed=seed, trace=trace,
                     predistributed=p, redistribute=redistribute,
                     soa=soa_resolved, stream=v, profile=profile,
                     events=ev_enabled, chaos=chaos,
                     monitor=(obs_monitor.Monitor(tenant_of=t_of,
                                                  qos_of=q_of,
                                                  ideal_ms=i_ms)
                              if mon_enabled else False))
            for ((policy, workflows, seed), p, v) in zip(members, pre, views)
        ]
        self._resumed = False
        self.rounds = 0
        self.batched_calls = 0
        self.batched_cycles = 0     # member-cycles scored by the kernel
        self.serial_cycles = 0      # parked member-cycles run per-task
        self.round_pairs: List[int] = []          # aggregate pairs / round
        self.batched_member_pairs: List[int] = []  # per-member pairs when batched
        self.wall_s = 0.0  # whole-grid wall clock of the last run()

    def _member_steps(self, st: SimState) -> Iterator[_CyclePoint]:
        """Run one member until its next pending scheduling cycle (yield)
        or until it completes.  EBPSM-family members park at *every*
        cycle with queued tasks — the driver owns the serial-vs-batched
        decision per rendezvous round; MSLBL mutates spare budget
        mid-cycle and runs the per-task reference path in its own slice,
        exactly like ``SimEngine``."""
        park = self.batched is not False \
            and st.policy.budget_mode == "ebpsm"
        while not st.done:
            if not st.advance():
                continue
            idle = st.pool.idle_vms()
            if park and st.queue:
                yield st, idle
            else:
                st.sequential_cycle(idle)
                st.post_cycle()

    def _round_rides_kernel(self, points: List[_CyclePoint],
                            pairs: List[int]) -> List[bool]:
        """The dispatcher: which parked cycles of this round are auctioned.
        Zero-pair cycles (no idle VMs — pure provisioning fallback) never
        ride: the kernel has nothing to score for them."""
        self.round_pairs.append(sum(pairs))
        if self.batched is True:
            return [p > 0 for p in pairs]
        if self.batched == "member":
            return [p >= AUCTION_MIN_PAIRS_GRID for p in pairs]
        # "auto": one aggregate decision for the whole rendezvous round.
        ride = sum(pairs) >= AUCTION_MIN_PAIRS_ROUND
        return [ride and p > 0 for p in pairs]

    def run(
        self,
        ckpt_hook: Optional[Callable[["BatchSimEngine"], bool]] = None,
    ) -> List[SimResult]:
        """``ckpt_hook``: called at the top of every rendezvous round —
        the one point where every live member sits at a generator yield
        with its pending cycle fully committed, so :meth:`snapshot` is
        a consistent cut (fresh ``_member_steps`` generators over the
        restored states resume bit-identically).  The hook owns the
        save-rate decision; returning True stops the stream by raising
        :class:`StreamInterrupted` (resume later via
        :meth:`load_snapshot` + ``run()``)."""
        t0 = _time.time()
        if not self._resumed:
            for st in self.states:
                st.seed_arrivals()
        live = [self._member_steps(st) for st in self.states]
        while live:
            if ckpt_hook is not None and ckpt_hook(self):
                self.wall_s += _time.time() - t0
                raise StreamInterrupted(
                    f"stream stopped by checkpoint hook at round "
                    f"{self.rounds}")
            self.rounds += 1
            points: List[_CyclePoint] = []
            parked: List[Iterator[_CyclePoint]] = []
            for stepper in live:
                point = next(stepper, None)
                if point is None:
                    continue  # member ran to completion
                points.append(point)
                parked.append(stepper)
            if not points:
                break
            owners: List[Tuple[SimState, list, list]] = []
            requests: List[CycleRequest] = []
            pairs = [len(st.queue) * len(idle) for st, idle in points]
            ride_pairs = 0
            for (st, idle), p, ride in zip(points, pairs,
                                           self._round_rides_kernel(points,
                                                                    pairs)):
                if ride:
                    self.batched_cycles += 1
                    self.batched_member_pairs.append(p)
                    ride_pairs += p
                    tasks, metas, tables = st.drain_queue_for_cycle()
                    owners.append((st, metas, idle))
                    requests.append(CycleRequest(
                        self.cfg, st.policy, tasks, idle, st.pool,
                        tables=tables))
                else:
                    self.serial_cycles += 1
                    st.sequential_cycle(idle)
                    st.post_cycle()
            if self.elog is not None:
                self.elog.append(obs_events.GRID_ROUND, self.rounds,
                                 self.rounds, len(points), len(requests),
                                 sum(pairs))
            if requests:
                self.batched_calls += 1
                if self.elog is not None:
                    self.elog.append(obs_events.GRID_AUCTION, self.rounds,
                                     self.rounds, len(requests),
                                     d=ride_pairs)
                all_placements = multi_cycle(self.cfg, requests,
                                             use_pallas=self.use_pallas)
                for (st, metas, idle), placements in zip(owners,
                                                         all_placements):
                    st.apply_cycle_placements(metas, placements, idle)
                    st.post_cycle()
            live = parked
        # Accumulate (not assign): a resumed stream's wall includes the
        # pre-interrupt segments restored by load_snapshot.
        self.wall_s += _time.time() - t0
        # Per-member wall is the amortized share of the grid run (they sum
        # to the total); the whole-grid wall lives on the engine/BatchResult.
        share = self.wall_s / len(self.states) if self.states else 0.0
        return [st.finalize(wall_s=share) for st in self.states]

    # ---- checkpoint / resume -------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """One consistent cut of the whole stream: every member's
        :meth:`SimState.snapshot` arrays keyed ``m<i>.<name>`` plus the
        engine's dispatch counters, shaped for
        ``repro.ckpt.checkpoint.save_stream``.  Only valid at a
        rendezvous-round boundary (see :meth:`run`)."""
        arrays: Dict[str, np.ndarray] = {}
        residues: List[bytes] = []
        for i, st in enumerate(self.states):
            snap = st.snapshot()
            for name, arr in snap["arrays"].items():
                arrays[f"m{i:04d}.{name}"] = arr
            residues.append(snap["residue"])
        residue = _pickle.dumps({
            "members": residues,
            "counters": {
                "rounds": self.rounds,
                "batched_calls": self.batched_calls,
                "batched_cycles": self.batched_cycles,
                "serial_cycles": self.serial_cycles,
                "round_pairs": self.round_pairs,
                "batched_member_pairs": self.batched_member_pairs,
                "wall_s": self.wall_s,
                "elog": self.elog,
            },
        }, protocol=_pickle.HIGHEST_PROTOCOL)
        return {"arrays": arrays, "residue": residue,
                "version": STREAM_SNAPSHOT_VERSION,
                "n_members": len(self.states)}

    def load_snapshot(self, snap: Dict[str, object]) -> None:
        """Restore a :meth:`snapshot` into this freshly-constructed
        engine (same cfg/members/modes).  The next :meth:`run` skips
        ``seed_arrivals`` and continues the stream bit-identically."""
        if snap.get("n_members", len(self.states)) != len(self.states):
            raise ValueError(
                f"snapshot has {snap.get('n_members')} members, "
                f"engine has {len(self.states)}")
        residue = _pickle.loads(snap["residue"])
        arrays: Dict[str, np.ndarray] = snap["arrays"]
        version = snap.get("version", 1)
        per_member: List[Dict[str, np.ndarray]] = \
            [{} for _ in self.states]
        for key, arr in arrays.items():
            prefix, name = key.split(".", 1)
            per_member[int(prefix[1:])][name] = arr
        for st, member_arrays, member_residue in zip(
                self.states, per_member, residue["members"]):
            st.load_snapshot({"arrays": member_arrays,
                              "residue": member_residue,
                              "version": version})
        c = residue["counters"]
        self.rounds = c["rounds"]
        self.batched_calls = c["batched_calls"]
        self.batched_cycles = c["batched_cycles"]
        self.serial_cycles = c["serial_cycles"]
        self.round_pairs = list(c["round_pairs"])
        self.batched_member_pairs = list(c["batched_member_pairs"])
        self.wall_s = c["wall_s"]
        self.elog = c.get("elog")
        self._resumed = True

    def stream_stats(self) -> Dict[str, float]:
        """Whole-stream aggregates reduced straight off the pooled
        StreamState arrays (no per-member iteration); falls back to the
        per-state objects under ``REPRO_OBJECT_STATE=1``."""
        if self.stream is not None:
            arrived = int(self.stream.arrived.sum())
            open_wfs = int((self.stream.arrived
                            & (self.stream.remaining > 0)).sum())
            tasks_left = int(self.stream.remaining.sum())
            spare = float(self.stream.spare.sum())
        else:
            arrived = open_wfs = tasks_left = 0
            spare = 0.0
            for st in self.states:
                for wst in st.wf_state.values():
                    arrived += 1
                    open_wfs += wst.remaining > 0
                    tasks_left += wst.remaining
                    spare += wst.spare
        return {"workflows_arrived": arrived, "workflows_open": open_wfs,
                "tasks_remaining": tasks_left, "spare_budget": spare}

    def dispatch_stats(self) -> Dict[str, object]:
        """Aggregate-auction observability for benchmarks and reports."""
        hist: Dict[str, int] = {}
        for p in self.round_pairs:
            b = 1 << max(int(p) - 1, 0).bit_length() if p else 0
            key = str(b)
            hist[key] = hist.get(key, 0) + 1
        out: Dict[str, object] = {
            "rounds": self.rounds,
            "redistribute_mode": self.redistribute,
            "batched_calls": self.batched_calls,
            "batched_cycles": self.batched_cycles,
            "serial_cycles": self.serial_cycles,
            "aggregate_pairs_hist": hist,
            "max_member_pairs_batched": max(self.batched_member_pairs,
                                            default=0),
            "min_member_pairs_batched": min(self.batched_member_pairs,
                                            default=0),
        }
        # Structured-event counts (repro.obs): member logs + the driver
        # log, summed per kind; {"enabled": False, ...} when tracing is
        # off so consumers can key on the block unconditionally.
        out["events"] = obs_events.events_block(
            [st.elog for st in self.states] + [self.elog])
        # Live-monitor block (repro.obs.monitor), summed over member
        # monitors; integer-only so worker-chunk merges are exact.
        out["monitor"] = obs_monitor.monitor_block(
            [st.monitor for st in self.states])
        # REPRO_PROFILE=1 per-phase counters, summed across members.  The
        # headline derived number is the Algorithm-3 redistribution share
        # of the grid wall — the quantity behind the ROADMAP's "~45% of a
        # heavy cell" claim and the batched-redistribution decision.
        profs = [st.profile for st in self.states if st.profile is not None]
        if profs:
            agg = {k: float(sum(p[k] for p in profs)) for k in profs[0]}
            # The share's denominator is this engine's own wall; when
            # stats from several (possibly concurrent) engines are merged
            # the consumer must recompute the share from the summed
            # engine walls, not from its elapsed time (see exp.run).
            agg["engine_wall_s"] = self.wall_s
            agg["redistribute_share_of_wall"] = (
                agg["redistribute_s"] / self.wall_s if self.wall_s else 0.0)
            # Self-measured cost of the counters themselves (bracket
            # count × calibrated perf_counter-pair cost) — merge-safe
            # (sums across engines like the other absolute seconds).
            agg["profile_overhead_s"] = profile_overhead_s(agg)
            out["profile"] = agg
        return out


# ---------------------------------------------------------------------------
# Grid API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GridEntry:
    """One cell of the experiment grid."""

    policy: str
    workload: int          # index into the workloads argument
    seed: int
    result: SimResult


@dataclasses.dataclass
class BatchResult:
    entries: List[GridEntry]
    wall_s: float

    @property
    def results(self) -> List[SimResult]:
        return [e.result for e in self.entries]

    def by_policy(self) -> Dict[str, List[GridEntry]]:
        out: Dict[str, List[GridEntry]] = {}
        for e in self.entries:
            out.setdefault(e.policy, []).append(e)
        return out


def predistribute_workload(
    cfg: PlatformConfig, wl: Sequence[Workflow], budget_mode: str
) -> Tuple[List[Workflow], Dict[int, float]]:
    """Run the arrival-time budget distribution once on a prototype clone.

    Algorithm 1 (and the MSLBL distribution) is deterministic in
    (cfg, workflow, budget) — independent of policy and degradation seed
    — so every grid member with the same workload and budget mode gets
    identical sub-budgets.  Returns the distributed prototype (clone it
    per member) and the wid → spare map to seed each member's
    ``SimState`` with.
    """
    proto = clone_workload(wl)
    spares: Dict[int, float] = {}
    for wf in proto:
        if budget_mode == "mslbl":
            distribute_budget_mslbl(cfg, wf, wf.budget)
            spares[wf.wid] = 0.0
        else:
            spares[wf.wid] = budget_mod.distribute_budget(cfg, wf, wf.budget)
    return proto, spares


def _as_workload_list(
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
) -> List[List[Workflow]]:
    wls = list(workloads)
    if not wls:
        return []
    if isinstance(wls[0], Workflow):
        return [wls]  # a single workload
    return [list(w) for w in wls]


def simulate_batch(
    cfg: PlatformConfig,
    policy: Union[Policy, Sequence[Policy]],
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
    seed: Union[int, Sequence[int]] = 0,
    trace: bool = False,
    use_pallas: object = "auto",
    batched: object = "auto",
    redistribute: str = "finish",
    soa: Optional[bool] = None,
    profile: Optional[bool] = None,
    events: Optional[bool] = None,
    chaos: Optional[ChaosConfig] = None,
) -> BatchResult:
    """Evaluate the full grid policies × workloads × seeds in one batched
    engine run.

    ``policy`` / ``seed`` accept a single value or a sequence;
    ``workloads`` accepts one workload (a sequence of ``Workflow``) or a
    sequence of workloads.  Budget distribution mutates tasks, so every
    member simulates a structural-sharing clone (``Workflow.clone``) —
    callers can reuse the same workload objects across the grid.
    """
    policies = [policy] if isinstance(policy, Policy) else list(policy)
    seeds = [seed] if isinstance(seed, int) else list(seed)
    wls = _as_workload_list(workloads)
    members: List[GridMember] = []
    labels: List[Tuple[str, int, int]] = []
    pre: List[Dict[int, float]] = []
    # Arrival-time budget distribution is shared: computed once per
    # (workload, budget_mode), inherited by every member's clone.
    protos: Dict[Tuple[int, str], Tuple[List[Workflow], Dict[int, float]]] = {}
    for pol in policies:
        for wi, wl in enumerate(wls):
            key = (wi, pol.budget_mode)
            if key not in protos:
                protos[key] = predistribute_workload(cfg, wl, pol.budget_mode)
            proto, spares = protos[key]
            for s in seeds:
                members.append((pol, clone_workload(proto), s))
                labels.append((pol.name, wi, s))
                pre.append(spares)
    engine = BatchSimEngine(cfg, members, trace=trace, use_pallas=use_pallas,
                            batched=batched, predistributed=pre,
                            redistribute=redistribute, soa=soa,
                            profile=profile, events=events, chaos=chaos)
    results = engine.run()
    entries = [
        GridEntry(policy=name, workload=wi, seed=s, result=res)
        for (name, wi, s), res in zip(labels, results)
    ]
    return BatchResult(entries=entries, wall_s=engine.wall_s)

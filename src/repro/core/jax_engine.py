"""Batched JAX simulation engine: a whole experiment grid per device pass.

The paper's headline comparison (EBPSM variants vs MSLBL_MW across
arrival rates, budgets and seeds) needs hundreds of independent
simulations.  Running them one ``SimEngine`` at a time leaves the device
idle between tiny kernel calls; running them here batches the hot path.

Architecture
------------
Every grid member (policy × workload × seed) owns a :class:`SimState`
(``core.engine``) — the single source of truth for arrival / finish /
VM_READY / REAP handling, the execution pipeline, and Algorithm 3 budget
redistribution.  :class:`BatchSimEngine` drives all members in lockstep
*rounds*:

1. each live member drains the events at its own next timestamp
   (members have independent clocks — no cross-member interaction
   exists, so rounds need no global time);
2. members whose trigger fired contribute their scheduling cycle as a
   ``CycleRequest`` (``core.jax_cycles``);
3. all requests are auctioned together: each auction round stacks every
   member's (task × VM) pair arrays into one ``[B, T, V]`` tensor and
   scores it with a single ``jax.vmap``'d affinity kernel call
   (``kernels.affinity.ops.affinity_batch``);
4. placements commit through the shared ``apply_cycle_placements``.

Because the transition semantics are shared code and the auction is the
property-tested ``jax_cycles`` fixed point, results are bit-exact with
the sequential reference (tests/test_jax_engine.py) in the paper's
sufficient-budget regime.  MSLBL mutates spare budget mid-cycle, so
MSLBL members run the per-task reference cycle inside the same lockstep
loop (exactly as ``SimEngine`` itself does).
"""
from __future__ import annotations

import copy
import dataclasses
import time as _time
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .engine import SimState
from .jax_cycles import CycleRequest, multi_cycle
from .scheduler import Policy
from .types import PlatformConfig, SimResult, Workflow

# One grid member: (policy, workflows, degradation seed).
GridMember = Tuple[Policy, Sequence[Workflow], int]


class BatchSimEngine:
    """N independent simulations, lockstep rounds, batched cycle scoring."""

    def __init__(
        self,
        cfg: PlatformConfig,
        members: Sequence[GridMember],
        trace: bool = False,
        use_pallas: bool = False,
        batched: object = "auto",
    ):
        """``batched``: True / False / "auto" — same rule as ``SimEngine``:
        "auto" routes a member's cycle through the auction only when its
        queue×pool product is large (so tiny cycles keep the cheap
        per-task path and the member's decisions match ``SimEngine``'s
        default configuration path-for-path)."""
        self.cfg = cfg
        self.use_pallas = use_pallas
        self.batched = batched
        self.states = [
            SimState(cfg, policy, workflows, seed=seed, trace=trace)
            for policy, workflows, seed in members
        ]
        self.rounds = 0
        self.batched_calls = 0
        self.wall_s = 0.0  # whole-grid wall clock of the last run()

    def _wants_auction(self, st: SimState, n_idle: int) -> bool:
        """EBPSM-family cycles go through the auction; MSLBL mutates spare
        budget mid-cycle and keeps the per-task reference path."""
        if st.policy.budget_mode != "ebpsm" or not st.queue:
            return False
        if self.batched is True:
            return True
        if self.batched == "auto":
            return len(st.queue) * n_idle >= 8192
        return False

    def run(self) -> List[SimResult]:
        t0 = _time.time()
        for st in self.states:
            st.seed_arrivals()
        while True:
            live = [st for st in self.states if not st.done]
            if not live:
                break
            self.rounds += 1
            owners: List[Tuple[SimState, list, list]] = []
            requests: List[CycleRequest] = []
            for st in live:
                if not st.advance():
                    continue
                idle = st.pool.idle_vms()
                if self._wants_auction(st, len(idle)):
                    tasks, metas = st.drain_queue_for_cycle()
                    requests.append(CycleRequest(
                        self.cfg, st.policy, tasks, idle,
                        st.pool.data_index))
                    owners.append((st, metas, idle))
                else:
                    st.sequential_cycle(idle)
                    st.post_cycle()
            if requests:
                self.batched_calls += 1
                all_placements = multi_cycle(self.cfg, requests,
                                             use_pallas=self.use_pallas)
                for (st, metas, idle), placements in zip(owners,
                                                         all_placements):
                    st.apply_cycle_placements(metas, placements, idle)
                    st.post_cycle()
        self.wall_s = _time.time() - t0
        # Per-member wall is the amortized share of the grid run (they sum
        # to the total); the whole-grid wall lives on the engine/BatchResult.
        share = self.wall_s / len(self.states) if self.states else 0.0
        return [st.finalize(wall_s=share) for st in self.states]


# ---------------------------------------------------------------------------
# Grid API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GridEntry:
    """One cell of the experiment grid."""

    policy: str
    workload: int          # index into the workloads argument
    seed: int
    result: SimResult


@dataclasses.dataclass
class BatchResult:
    entries: List[GridEntry]
    wall_s: float

    @property
    def results(self) -> List[SimResult]:
        return [e.result for e in self.entries]

    def by_policy(self) -> Dict[str, List[GridEntry]]:
        out: Dict[str, List[GridEntry]] = {}
        for e in self.entries:
            out.setdefault(e.policy, []).append(e)
        return out


def _as_workload_list(
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
) -> List[List[Workflow]]:
    wls = list(workloads)
    if not wls:
        return []
    if isinstance(wls[0], Workflow):
        return [wls]  # a single workload
    return [list(w) for w in wls]


def simulate_batch(
    cfg: PlatformConfig,
    policy: Union[Policy, Sequence[Policy]],
    workloads: Union[Sequence[Workflow], Sequence[Sequence[Workflow]]],
    seed: Union[int, Sequence[int]] = 0,
    trace: bool = False,
    use_pallas: bool = False,
    batched: object = "auto",
) -> BatchResult:
    """Evaluate the full grid policies × workloads × seeds in one batched
    engine run.

    ``policy`` / ``seed`` accept a single value or a sequence;
    ``workloads`` accepts one workload (a sequence of ``Workflow``) or a
    sequence of workloads.  Budget distribution mutates tasks, so every
    member simulates a deep copy — callers can reuse the same workload
    objects across the grid.
    """
    policies = [policy] if isinstance(policy, Policy) else list(policy)
    seeds = [seed] if isinstance(seed, int) else list(seed)
    wls = _as_workload_list(workloads)
    members: List[GridMember] = []
    labels: List[Tuple[str, int, int]] = []
    for pol in policies:
        for wi, wl in enumerate(wls):
            for s in seeds:
                members.append((pol, copy.deepcopy(wl), s))
                labels.append((pol.name, wi, s))
    engine = BatchSimEngine(cfg, members, trace=trace, use_pallas=use_pallas,
                            batched=batched)
    results = engine.run()
    entries = [
        GridEntry(policy=name, workload=wi, seed=s, result=res)
        for (name, wi, s), res in zip(labels, results)
    ]
    return BatchResult(entries=entries, wall_s=engine.wall_s)

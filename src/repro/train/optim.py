"""AdamW with global-norm clipping and warmup-cosine schedule.

Self-contained (no optax dependency).  Optimizer state is a pytree shaped
exactly like the parameters, so it inherits the parameter shardings —
FSDP shards the moments the same way it shards the weights.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.common import RunConfig

PyTree = Any


def init_opt_state(params: PyTree) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs: PyTree) -> Dict[str, Any]:
    """ParamSpec tree for the optimizer state (mirrors params)."""
    return {"mu": param_specs, "nu": param_specs, "step": None}


def lr_schedule(step: jnp.ndarray, base_lr: float, warmup: int = 100,
                total: int = 10_000) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: PyTree, grads: PyTree, opt: Dict[str, Any],
                 run: RunConfig) -> Tuple[PyTree, Dict[str, Any],
                                          Dict[str, jnp.ndarray]]:
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2, eps = run.adam_b1, run.adam_b2, run.adam_eps
    lr = lr_schedule(step, run.learning_rate)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["nu"], grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mh = m / c1
        vh = v / c2
        return (p.astype(jnp.float32)
                - lr * (mh / (jnp.sqrt(vh) + eps)
                        + run.weight_decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": mu, "nu": nu, "step": step}, metrics

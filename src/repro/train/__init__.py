"""train substrate."""

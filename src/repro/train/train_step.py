"""Sharded training step builder.

``build_train_step`` returns a jit-compiled (params, opt, batch) →
(params, opt, metrics) function with explicit in/out shardings derived
from the model's logical axes:

- FSDP × TP 2-D parameter sharding (pod axis extends DP on multi-pod),
- configurable remat (none / dots / full) inside the layer scan,
- optional gradient accumulation over microbatches (``run.microbatch``),
- optional int8 error-feedback compression of the DP gradient reduction
  (``run.grad_compression = 'int8'``) — applied via shard_map around the
  per-microbatch gradient, with the residual carried in the opt state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import RunConfig, TRAIN_RULES
from ..models.registry import Model
from ..parallel import ctx
from ..parallel import sharding as shd
from . import optim

PyTree = Any


def loss_and_grads(model: Model, params, batch):
    def lf(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics
    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def _accum_microbatches(model: Model, params, batch, n_micro: int):
    """Gradient accumulation over microbatches (memory ↓ n_micro×).

    lax.scan normally; a Python loop when ``scan_layers=False`` (the cost
    probes unroll every loop so XLA's loop-once cost analysis stays
    honest — see launch/dryrun.py)."""
    def reshape(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    mb = jax.tree.map(reshape, batch)

    def body(acc, micro):
        loss, metrics, grads = loss_and_grads(model, params, micro)
        acc = jax.tree.map(jnp.add, acc,
                           jax.tree.map(lambda g: g / n_micro, grads))
        return acc, loss

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if model.run.scan_layers:
        grads, losses = jax.lax.scan(body, zero, mb)
        return jnp.mean(losses), {"loss": jnp.mean(losses)}, grads
    acc, losses = zero, []
    for i in range(n_micro):
        micro = jax.tree.map(lambda x: x[i], mb)
        acc, loss = body(acc, micro)
        losses.append(loss)
    mean = jnp.mean(jnp.stack(losses))
    return mean, {"loss": mean}, acc


def train_rules(run: RunConfig):
    rules = dict(TRAIN_RULES)
    if not run.seq_parallel:
        rules["seq_act"] = None
    return rules


def make_train_step(model: Model, mesh: Optional[Mesh] = None):
    run = model.run

    def train_step(params, opt, batch):
        import contextlib
        scope = (ctx.scope(mesh, train_rules(run)) if mesh is not None
                 else contextlib.nullcontext())
        with scope:
            if run.cast_params_once:
                # single tree-cast inside the grad: every FSDP all-gather
                # moves to bf16 (half the gather bytes)
                assert not (run.microbatch and run.microbatch > 1), \
                    "cast_params_once + microbatch not combined yet"

                def lf(p32):
                    pc = jax.tree.map(
                        lambda x: x.astype(run.compute_dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, p32)
                    return model.loss(pc, batch)
                (loss, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(params)
            elif run.microbatch and run.microbatch > 1:
                loss, metrics, grads = _accum_microbatches(
                    model, params, batch, run.microbatch)
            else:
                loss, metrics, grads = loss_and_grads(model, params, batch)
            params, opt, opt_metrics = optim.adamw_update(params, grads, opt,
                                                          run)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt, metrics

    return train_step


def build_train_step(model: Model, mesh: Mesh, shape_name: str = "train_4k",
                     donate: bool = True):
    """jit with explicit shardings; returns (fn, param_sh, opt_sh, batch_sh)."""
    param_sh = shd.model_param_shardings(model, mesh, kind="train")
    opt_sh = {"mu": param_sh, "nu": param_sh,
              "step": shd.replicated(mesh)}
    batch_sh = shd.batch_shardings(model, mesh, shape_name, kind="train")
    metrics_sh = None  # let jit choose (scalars)

    fn = jax.jit(
        make_train_step(model, mesh),
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return fn, param_sh, opt_sh, batch_sh

"""SSM decoder LMs: pure Mamba2 (mamba2-780m) and the Zamba2-style hybrid
(Mamba2 stack + ONE weight-shared attention block applied every
``attn_every`` layers, each application with its own KV cache).

``attn_every = 0`` → pure SSM.  Both support O(1)-state decode, which is
why these two archs run the long_500k shape (sub-quadratic requirement).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, RunConfig, spec, stacked
from .layers import (attention, attn_specs, cross_entropy, decode_attention,
                     embed, embed_specs, logits_out, mlp, mlp_specs, rmsnorm)
from .ssm import (init_ssm_state, ssm_block, ssm_block_decode, ssm_specs,
                  ssm_state_specs)
from .transformer import _remat


def n_attn_apps(cfg: ModelConfig) -> int:
    return 0 if not cfg.attn_every else cfg.n_layers // cfg.attn_every


def hybrid_specs(cfg: ModelConfig) -> Dict[str, Any]:
    per_layer = {"ln": spec((cfg.d_model,), (None,), init="ones"),
                 "ssm": ssm_specs(cfg)}
    s: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "layers": jax.tree.map(lambda sp: stacked(cfg.n_layers, sp), per_layer,
                               is_leaf=lambda x: hasattr(x, "axes")),
        "ln_f": spec((cfg.d_model,), (None,), init="ones"),
    }
    if cfg.attn_every:
        # Zamba2's shared block is a full transformer block (attn + MLP),
        # ONE weight set applied at every attn_every-th layer.
        s["shared_attn"] = {"ln": spec((cfg.d_model,), (None,), init="ones"),
                            "attn": attn_specs(cfg),
                            "ln2": spec((cfg.d_model,), (None,), init="ones"),
                            "mlp": mlp_specs(cfg)}
    return s


def _shared_block(sa, x: jnp.ndarray, positions, cfg: ModelConfig,
                  run: RunConfig) -> jnp.ndarray:
    x = x + attention(sa["attn"], rmsnorm(x, sa["ln"], cfg.rms_eps),
                      positions, cfg, run)
    return x + mlp(sa["mlp"], rmsnorm(x, sa["ln2"], cfg.rms_eps), run)


def _is_attn_layer(cfg: ModelConfig, i: jnp.ndarray) -> jnp.ndarray:
    return (i % cfg.attn_every) == (cfg.attn_every - 1)


def forward(params, batch, cfg: ModelConfig, run: RunConfig) -> jnp.ndarray:
    h = embed(params["embed"], batch["tokens"], run)
    B, L = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    from ..parallel.ctx import constrain

    def base(hh, lp):
        hh = constrain(hh, ("batch", "seq_act", None))
        return hh + ssm_block(lp["ssm"], rmsnorm(hh, lp["ln"], cfg.rms_eps),
                              cfg, run)

    if cfg.attn_every:
        sa = params["shared_attn"]

        def body(hh, xs):
            lp, i = xs
            hh = base(hh, lp)
            hh = jax.lax.cond(
                _is_attn_layer(cfg, i),
                lambda x: _shared_block(sa, x, positions, cfg, run),
                lambda x: x, hh)
            return hh, None
    else:
        def body(hh, xs):
            lp, _ = xs
            return base(hh, lp), None

    if run.scan_layers:
        body = _remat(body, run)
        h, _ = jax.lax.scan(
            body, h,
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    else:   # unrolled (cost probes)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h = base(h, lp)
            if cfg.attn_every and (i % cfg.attn_every) == cfg.attn_every - 1:
                h = _shared_block(params["shared_attn"], h, positions, cfg,
                                  run)
    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    return logits_out(params["embed"], h, cfg, run)


def loss_fn(params, batch, cfg: ModelConfig, run: RunConfig):
    logits = forward(params, batch, cfg, run)
    mask = batch.get("mask")
    m = None if mask is None else mask[:, 1:]
    loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:], m)
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def state_specs(cfg: ModelConfig, batch: int, max_seq: int,
                state_dtype=jnp.float32) -> Dict[str, Any]:
    s: Dict[str, Any] = dict(ssm_state_specs(cfg, batch, cfg.n_layers,
                                             state_dtype))
    apps = n_attn_apps(cfg)
    if apps:
        hd = cfg.hd
        s["k"] = jax.ShapeDtypeStruct(
            (apps, batch, max_seq, cfg.n_kv_heads, hd), jnp.bfloat16)
        s["v"] = jax.ShapeDtypeStruct(
            (apps, batch, max_seq, cfg.n_kv_heads, hd), jnp.bfloat16)
    s["length"] = jax.ShapeDtypeStruct((), jnp.int32)
    return s


def init_state(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    return {k: jnp.zeros(v.shape, v.dtype)
            for k, v in state_specs(cfg, batch, max_seq).items()}


def prefill(params, batch, cfg: ModelConfig, run: RunConfig, max_seq: int):
    """Full-prompt pass producing SSM states + (hybrid) KV caches."""
    from .layers import apply_rope
    h = embed(params["embed"], batch["tokens"], run)
    B, L = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    state = init_state(cfg, B, max_seq)
    sa = params.get("shared_attn")

    # SSM layers under scan (recompute final states via the ssd final-state
    # output); attention caches via in-carry dynamic updates.
    from ..kernels.ssd import ops as ssd_ops

    def body(carry, xs):
        hh, kc, vc = carry
        lp, i = xs
        hn = rmsnorm(hh, lp["ln"], cfg.rms_eps)
        hh = hh + ssm_block(lp["ssm"], hn, cfg, run)
        if cfg.attn_every:
            def do_attn(args):
                hh, kc, vc = args
                hn = rmsnorm(hh, sa["ln"], cfg.rms_eps)
                cdt = run.compute_dtype
                k = jnp.einsum("bld,dhk->blhk", hn, sa["attn"]["wk"].astype(cdt))
                v = jnp.einsum("bld,dhk->blhk", hn, sa["attn"]["wv"].astype(cdt))
                if cfg.qk_norm:
                    k = rmsnorm(k, sa["attn"]["k_norm"], cfg.rms_eps)
                k = apply_rope(k, positions, cfg.rope_theta)
                app = i // cfg.attn_every
                kc = jax.lax.dynamic_update_slice(
                    kc, k.astype(kc.dtype)[None], (app, 0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, v.astype(vc.dtype)[None], (app, 0, 0, 0, 0))
                hh = hh + attention(sa["attn"], hn, positions, cfg, run)
                hh = hh + mlp(sa["mlp"], rmsnorm(hh, sa["ln2"], cfg.rms_eps),
                              run)
                return hh, kc, vc
            hh, kc, vc = jax.lax.cond(_is_attn_layer(cfg, i), do_attn,
                                      lambda a: a, (hh, kc, vc))
        return (hh, kc, vc), None

    apps = n_attn_apps(cfg)
    hd = cfg.hd
    kc = jnp.zeros((max(apps, 1), B, max_seq, cfg.n_kv_heads, hd), jnp.bfloat16)
    vc = jnp.zeros_like(kc)
    if run.scan_layers:
        (h, kc, vc), _ = jax.lax.scan(
            body, (h, kc, vc),
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    else:
        carry = (h, kc, vc)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            carry, _ = body(carry, (lp, jnp.asarray(i, jnp.int32)))
        h, kc, vc = carry

    # Final SSM states: replay each layer's SSD scan final state.  For the
    # serving path we recompute states in a second scan over layers (the
    # first scan cannot also emit per-layer states of different shapes).
    h2 = embed(params["embed"], batch["tokens"], run)

    def body_state(carry, xs):
        hh = carry
        lp, i = xs
        hn = rmsnorm(hh, lp["ln"], cfg.rms_eps)
        st = _ssm_final_state(lp["ssm"], hn, cfg, run)
        hh = hh + ssm_block(lp["ssm"], hn, cfg, run)
        if cfg.attn_every:
            hh = jax.lax.cond(
                _is_attn_layer(cfg, i),
                lambda x: _shared_block(sa, x, positions, cfg, run),
                lambda x: x, hh)
        return hh, st

    if run.scan_layers:
        _, states = jax.lax.scan(
            body_state, h2,
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    else:
        sts = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h2, st = body_state(h2, (lp, jnp.asarray(i, jnp.int32)))
            sts.append(st)
        states = jax.tree.map(lambda *xs: jnp.stack(xs), *sts)

    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    logits = logits_out(params["embed"], h[:, -1:, :], cfg, run)
    state.update(states)
    if apps:
        state["k"], state["v"] = kc, vc
    state["length"] = jnp.asarray(L, jnp.int32)
    return logits, state


def _ssm_final_state(lp_ssm, x, cfg: ModelConfig, run: RunConfig):
    """Final (conv buffers, ssd state) of a layer given its input sequence."""
    from ..kernels.ssd import ops as ssd_ops
    cdt = run.compute_dtype
    from .ssm import _causal_conv, _split_heads
    H = cfg.ssm_heads
    K = cfg.ssm_conv_width
    xt = x @ lp_ssm["w_x"].astype(cdt)
    bt = x @ lp_ssm["w_B"].astype(cdt)
    ct = x @ lp_ssm["w_C"].astype(cdt)
    xz = jax.nn.silu(_causal_conv(xt, lp_ssm["conv_x"].astype(cdt)))
    Bm = jax.nn.silu(_causal_conv(bt, lp_ssm["conv_B"].astype(cdt)))
    Cm = jax.nn.silu(_causal_conv(ct, lp_ssm["conv_C"].astype(cdt)))
    dt = jax.nn.softplus((x @ lp_ssm["w_dt"].astype(cdt)).astype(jnp.float32)
                         + lp_ssm["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp_ssm["A_log"].astype(jnp.float32))
    _, final = ssd_ops.ssd(_split_heads(xz, H), dt, A, Bm, Cm,
                           chunk=min(64, x.shape[1]),
                           use_pallas=run.use_pallas)
    return {
        "ssd": final,
        "conv_x": xt[:, -(K - 1):, :].astype(jnp.float32),
        "conv_B": bt[:, -(K - 1):, :].astype(jnp.float32),
        "conv_C": ct[:, -(K - 1):, :].astype(jnp.float32),
    }


def decode_step(params, state, tokens: jnp.ndarray, cfg: ModelConfig,
                run: RunConfig):
    """tokens: [B,1] → (logits, new state).  O(1) per step for SSM layers,
    O(cache length) for the hybrid's shared-attention applications."""
    h = embed(params["embed"], tokens, run)[:, 0, :]     # [B, d]
    length = state["length"]
    sa = params.get("shared_attn")
    apps = n_attn_apps(cfg)

    def body(carry, xs):
        hh, kc, vc = carry
        lp, i, st = xs
        hn = rmsnorm(hh, lp["ln"], cfg.rms_eps)
        out, new_st = ssm_block_decode(lp["ssm"], hn, st, cfg, run)
        hh = hh + out
        if cfg.attn_every:
            def do_attn(args):
                hh, kc, vc = args
                app = i // cfg.attn_every
                kci = kc[app]
                vci = vc[app]
                hn = rmsnorm(hh[:, None, :], sa["ln"], cfg.rms_eps)
                a, kci, vci = decode_attention(sa["attn"], hn, kci, vci,
                                               length, cfg, run)
                kc = jax.lax.dynamic_update_slice(
                    kc, kci[None].astype(kc.dtype), (app, 0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, vci[None].astype(vc.dtype), (app, 0, 0, 0, 0))
                hh = hh + a[:, 0, :]
                hh = hh + mlp(sa["mlp"],
                              rmsnorm(hh, sa["ln2"], cfg.rms_eps), run)
                return hh, kc, vc
            hh, kc, vc = jax.lax.cond(_is_attn_layer(cfg, i), do_attn,
                                      lambda a: a, (hh, kc, vc))
        return (hh, kc, vc), new_st

    ssm_st = {k: state[k] for k in ("ssd", "conv_x", "conv_B", "conv_C")}
    kc = state.get("k", jnp.zeros((1, h.shape[0], 1, cfg.n_kv_heads, cfg.hd),
                                  jnp.bfloat16))
    vc = state.get("v", jnp.zeros_like(kc))
    if run.scan_layers:
        (h, kc, vc), new_ssm = jax.lax.scan(
            body, (h, kc, vc),
            (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32),
             ssm_st))
    else:
        carry = (h, kc, vc)
        sts = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            st_i = jax.tree.map(lambda x: x[i], ssm_st)
            carry, st = body(carry, (lp, jnp.asarray(i, jnp.int32), st_i))
            sts.append(st)
        h, kc, vc = carry
        new_ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *sts)
    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    logits = logits_out(params["embed"], h[:, None, :], cfg, run)
    new_state = dict(new_ssm)
    if apps:
        new_state["k"], new_state["v"] = kc, vc
    new_state["length"] = length + 1
    return logits, new_state

"""Shared model machinery: configs, parameter specs, sharding rules.

Parameters are plain nested dicts of jnp arrays.  Every leaf is declared
once as a :class:`ParamSpec` carrying its shape, dtype, initializer and
*logical axis names*; the same spec tree yields (a) materialized params,
(b) ``jax.ShapeDtypeStruct`` stand-ins for the dry-run, and (c)
``PartitionSpec`` trees via the mesh sharding rules — so the model
definition and its distribution strategy never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


# ---------------------------------------------------------------------------
# Model configuration — one dataclass covers all 10 assigned families.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # attention query heads (0 for attn-free)
    n_kv_heads: int               # GQA KV heads
    d_ff: int                     # dense FFN width (per-expert width for MoE)
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    # MoE
    n_experts: int = 0
    n_experts_padded: int = 0     # padded for expert-parallel divisibility
    top_k: int = 0
    shared_ff: int = 0            # always-on shared-expert width
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    # Hybrid (Zamba2): one weight-shared attention block applied every
    # ``attn_every`` SSM layers.
    attn_every: int = 0
    # Attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True           # False for encoder-only (HuBERT)
    # VLM frontend stub
    n_patches: int = 0            # patch-embedding positions (precomputed)
    patch_dim: int = 0
    # Audio frontend stub
    frame_dim: int = 0            # precomputed frame-embedding width
    # Norm/init
    rms_eps: float = 1e-6
    init_std: float = 0.02
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing → eligible for long_500k."""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything about *how* to run, as opposed to *what* the model is."""

    seq_len: int = 4096
    global_batch: int = 256
    microbatch: int = 0            # 0 → no gradient accumulation
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "dots"            # none | dots | full
    use_pallas: bool = False       # flip on real TPU; jnp path for dry-run
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    # Distributed-optimization knobs (§Perf / beyond-paper):
    grad_compression: str = "none"   # none | int8  (error-feedback all-reduce)
    scan_layers: bool = True
    seq_parallel: bool = True        # shard the residual stream over 'model'
    cast_params_once: bool = False   # one bf16 tree-cast at step entry →
    #                                  FSDP all-gathers move to bf16 (2× ↓)
    moe_capacity: float = 1.25
    # Serving
    decode_seq_shard: bool = False   # shard KV cache over 'data' by sequence

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names (same rank)
    init: str = "normal"                 # normal | zeros | ones | scaled
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape: Sequence[int], axes: Sequence[Optional[str]], init: str = "normal",
         dtype: Any = jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, dtype)


def stacked(n: int, s: ParamSpec) -> ParamSpec:
    """Stack a per-layer spec along a leading 'layers' axis (for lax.scan)."""
    return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, s: ParamSpec, base_std: float) -> jnp.ndarray:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    std = base_std
    if s.init == "scaled":  # output projections: scale by 1/sqrt(2*fan-in-ish)
        std = base_std / math.sqrt(2.0)
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(rng: jax.Array, spec_tree: PyTree, base_std: float = 0.02) -> PyTree:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(k, s, base_std) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree: PyTree) -> PyTree:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec,
    )


def param_count(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


# ---------------------------------------------------------------------------
# Sharding rules: logical axis name → mesh axis (None = replicated).
#
# 2-D "FSDP × TP" layout: the 'data' mesh axis shards both the batch and the
# fully-sharded parameter axis; the 'model' mesh axis holds tensor-parallel
# (heads / ffn / vocab / experts) shards.  The multi-pod 'pod' axis extends
# data parallelism (hierarchical gradient reduction) unless pipeline mode
# re-purposes it.
# ---------------------------------------------------------------------------

TRAIN_RULES: Dict[str, Optional[str]] = {
    "embed": "data",        # FSDP: shard the big replicated axis over data
    "seq_act": "model",     # sequence parallelism on the residual stream
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "experts": "model",     # expert parallelism over the TP axis
    "expert_ffn": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_w": None,
    "patch": None,
    "batch": "data",
    "seq": None,
    "pod_batch": ("pod", "data"),   # batch sharded over pod×data when multi-pod
}

# Serving: params TP-sharded over 'model', replicated over 'data'; batch over
# 'data'.  (FSDP gather per step would dominate small-batch decode.)
SERVE_RULES: Dict[str, Optional[str]] = dict(TRAIN_RULES)
SERVE_RULES.update({"embed": None, "seq_act": None})

# Long-context decode (batch=1): KV cache / sequence sharded over 'data'.
LONG_RULES: Dict[str, Optional[str]] = dict(SERVE_RULES)
LONG_RULES.update({"batch": None, "seq": "data"})


def logical_to_pspec(axes: Sequence[Optional[str]], rules: Dict[str, Optional[str]],
                     mesh_axis_names: Sequence[str],
                     shape: Optional[Sequence[int]] = None,
                     axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """Map logical axes → PartitionSpec.  When ``shape``/``axis_sizes`` are
    given, shardings that do not divide the dimension are dropped
    (replicated) instead of relying on GSPMD padding."""
    entries = []
    for i, ax in enumerate(axes):
        if ax is None:
            entries.append(None)
            continue
        m = rules.get(ax, None)
        if m is None:
            entries.append(None)
        elif isinstance(m, tuple):
            ms = tuple(x for x in m if x in mesh_axis_names)
            entries.append(ms if ms else None)
        else:
            entries.append(m if m in mesh_axis_names else None)
        if (entries[-1] is not None and shape is not None
                and axis_sizes is not None):
            names = entries[-1] if isinstance(entries[-1], tuple) \
                else (entries[-1],)
            total = 1
            for n in names:
                total *= axis_sizes.get(n, 1)
            if shape[i] % total != 0:
                entries[-1] = None
    # PartitionSpec forbids repeated mesh axes; keep first occurrence.
    seen = set()
    clean = []
    for e in entries:
        names = e if isinstance(e, tuple) else ((e,) if e else ())
        if any(n in seen for n in names):
            clean.append(None)
            continue
        seen.update(names)
        clean.append(e)
    return P(*clean)


def param_pspecs(spec_tree: PyTree, rules: Dict[str, Optional[str]],
                 mesh_axis_names: Sequence[str],
                 axis_sizes: Optional[Dict[str, int]] = None) -> PyTree:
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, rules, mesh_axis_names,
                                   s.shape, axis_sizes),
        spec_tree, is_leaf=is_spec,
    )


def batch_pspec(rules: Dict[str, Optional[str]], mesh_axis_names: Sequence[str],
                multi_pod: bool) -> P:
    ax = "pod_batch" if multi_pod and "pod" in mesh_axis_names else "batch"
    return logical_to_pspec((ax,), rules, mesh_axis_names)


# ---------------------------------------------------------------------------
# Tiny helpers shared across model files
# ---------------------------------------------------------------------------


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(
        x.dtype, jnp.floating) else x, tree)


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test reduction: same family/topology, tiny dims."""
    kw: Dict[str, Any] = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.attn_every == 0 else 4),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab=max(min(cfg.vocab, 512), 64),
        head_dim=32 if cfg.has_attention else 0,
    )
    if cfg.has_attention:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(max(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 1), 4)
    if cfg.n_experts:
        kw["n_experts"] = 8
        kw["n_experts_padded"] = 8
        kw["top_k"] = min(cfg.top_k, 2)
        kw["shared_ff"] = 128 if cfg.shared_ff else 0
    if cfg.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 32
    if cfg.attn_every:
        kw["attn_every"] = 2
    if cfg.n_patches:
        kw["n_patches"] = 16
        kw["patch_dim"] = 64
    if cfg.frame_dim:
        kw["frame_dim"] = 64
    return cfg.with_(**kw)

"""Core layers: RMSNorm, RoPE, GQA attention (train + cached decode), SwiGLU.

Pure functions over parameter dicts.  Attention dispatches to the Pallas
flash kernel when ``run.use_pallas`` (TPU) and to the jnp reference path
otherwise (CPU dry-run / tests) — both produced by the same module so the
oracle and the kernel can never diverge silently.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec, RunConfig, spec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., L, H, D]; positions: [..., L] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., L, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                              # [..., L, 1, D/2]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention parameter specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    hd = cfg.hd
    s: Dict[str, ParamSpec] = {
        "wq": spec((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": spec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": spec((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": spec((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed"),
                   init="scaled"),
    }
    if cfg.qk_norm:
        s["q_norm"] = spec((hd,), (None,), init="ones")
        s["k_norm"] = spec((hd,), (None,), init="ones")
    return s


# ---------------------------------------------------------------------------
# Attention forward (training / prefill) — full sequence
# ---------------------------------------------------------------------------


def _sdpa_ref(q, k, v, causal: bool) -> jnp.ndarray:
    """Reference scaled-dot-product attention.  q,k,v: [B,L,H,D] / [B,S,H,D]."""
    B, Lq, H, D = q.shape
    Lk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, precision=None).astype(jnp.float32)
    logits = logits * scale
    if causal:
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        mask = qi >= ki
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# Above this many logit elements per (q-row block) the plain SDPA would
# materialize an O(L²) tensor; switch to the chunked online-softmax path.
_SDPA_CHUNK_THRESHOLD = 4096 * 4096


def _sdpa_chunked(q, k, v, causal: bool, bq: int = 2048) -> jnp.ndarray:
    """Flash-style attention in pure jnp: statically-unrolled q blocks so
    peak memory is O(bq · Lk) instead of O(Lq · Lk).

    Deliberately a Python loop, NOT lax.scan: XLA's cost analysis counts a
    loop body once, which silently deleted ~98% of prefill attention FLOPs
    from the roofline artifacts (the dry-run reads cost_analysis()).  The
    unrolled form costs correctly and fuses per block on TPU."""
    B, Lq, H, D = q.shape
    Lk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    nq = (Lq + bq - 1) // bq
    pad = nq * bq - Lq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ki = jnp.arange(Lk)
    outs = []
    for i in range(nq):
        qs = qp[:, i * bq:(i + 1) * bq]                       # [B,bq,H,D]
        s = jnp.einsum("bqhd,bkhd->bhqk", qs, k).astype(jnp.float32) * scale
        if causal:
            qi = i * bq + jnp.arange(bq)[:, None] + (Lk - Lq)
            s = jnp.where((qi >= ki[None, :])[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        outs.append(jnp.einsum("bhqk,bkhd->bqhd", p, v))
    return jnp.concatenate(outs, axis=1)[:, :Lq]


def attention(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
              positions: jnp.ndarray, cfg: ModelConfig, run: RunConfig,
              causal: Optional[bool] = None) -> jnp.ndarray:
    """Full-sequence GQA attention.  x: [B, L, d_model]."""
    causal = cfg.causal if causal is None else causal
    cdt = run.compute_dtype
    hd = cfg.hd
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # GQA: repeat KV heads up to query heads.
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if run.use_pallas:
        from ..kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=causal)
    elif q.shape[1] * k.shape[1] > _SDPA_CHUNK_THRESHOLD:
        o = _sdpa_chunked(q, k, v, causal)
    else:
        o = _sdpa_ref(q, k, v, causal)
    return jnp.einsum("blhk,hkd->bld", o, params["wo"].astype(cdt))


# ---------------------------------------------------------------------------
# Attention with KV cache (decode)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    hd = cfg.hd
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def kv_cache_specs(cfg: ModelConfig, batch: int, max_seq: int, n_apps: int = 0,
                   dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the cache (dry-run inputs).  ``n_apps`` > 0
    builds a hybrid-model cache (one per shared-attention application)."""
    layers = n_apps if n_apps else cfg.n_layers
    hd = cfg.hd
    shape = (layers, batch, max_seq, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "length": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_attention(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray, cfg: ModelConfig,
                     run: RunConfig) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x: [B, 1, d].  k/v_cache: [B, S, Hkv, D].

    Returns (out [B,1,d], new_k, new_v).  The new token is written at
    ``length``; attention spans the first ``length+1`` cache slots (masked).
    """
    cdt = run.compute_dtype
    B, S, Hkv, D = k_cache.shape
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, params["k_norm"], cfg.rms_eps)
    pos = jnp.full((B, 1), length, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, length, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, length, 0, 0))
    rep = cfg.n_heads // cfg.n_kv_heads
    kk = k_cache.astype(cdt)
    vv = v_cache.astype(cdt)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    # [B,1,Hq,D] x [B,S,Hkv,D] — group query heads over kv heads.
    qg = q.reshape(B, 1, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kk).astype(jnp.float32) * scale
    mask = (jnp.arange(S) <= length)[None, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(cdt)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, vv).reshape(B, 1, Hkv * rep, D)
    out = jnp.einsum("blhk,hkd->bld", o, params["wo"].astype(cdt))
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    ff = d_ff if d_ff is not None else cfg.d_ff
    return {
        "w_gate": spec((cfg.d_model, ff), ("embed", "ffn")),
        "w_up": spec((cfg.d_model, ff), ("embed", "ffn")),
        "w_down": spec((ff, cfg.d_model), ("ffn", "embed"), init="scaled"),
    }


def mlp(params: Dict[str, jnp.ndarray], x: jnp.ndarray, run: RunConfig) -> jnp.ndarray:
    cdt = run.compute_dtype
    g = x @ params["w_gate"].astype(cdt)
    u = x @ params["w_up"].astype(cdt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(cdt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = {"tok": spec((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        s["unembed"] = spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return s


def embed(params, tokens: jnp.ndarray, run: RunConfig) -> jnp.ndarray:
    return params["tok"].astype(run.compute_dtype)[tokens]


def logits_out(params, x: jnp.ndarray, cfg: ModelConfig, run: RunConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["tok"].astype(run.compute_dtype).T
    else:
        w = params["unembed"].astype(run.compute_dtype)
    return x @ w


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over (optionally masked) positions; fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

"""Mixture-of-Experts layer: top-k routing, two implementations.

1. ``_moe_dense`` — single-device capacity dispatch (scatter/gather).
   Used by smoke tests and as the semantic oracle.
2. ``_moe_shard_map`` — production expert parallelism: experts live on the
   'model' mesh axis; tokens are bucketed per destination shard locally,
   exchanged with ONE tiled all-to-all, processed by the local experts as
   dense [E_local, tokens, d] einsums (MXU-friendly), and returned with a
   second all-to-all.  No scatter crosses a shard boundary, so SPMD never
   falls back to replication — this is the fix for the 2470× FLOP blow-up
   the naive global-scatter version showed in the dry-run (see
   EXPERIMENTS.md §Perf hillclimb #1).

Position-within-expert uses argsort + searchsorted (O(n log n)) instead of
a one-hot cumsum — XLA lowers big cumsums to O(n²) reduce-windows.

Routed-expert counts that don't divide the EP degree are padded
(``n_experts_padded``) with dead experts; the router never selects them.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax ≥ 0.6: public API, replication check kwarg named ``check_vma``
    from jax import shard_map as _jax_shard_map
    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental location, kwarg is ``check_rep``
    from jax.experimental.shard_map import shard_map as _jax_shard_map
    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-portable shard_map (the repl-check kwarg was renamed)."""
    kw = {_SHARD_MAP_CHECK_KW: check_vma}
    return _jax_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

from .common import ModelConfig, ParamSpec, RunConfig, spec
from .layers import mlp, mlp_specs


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    e = cfg.n_experts_padded or cfg.n_experts
    s: Dict[str, ParamSpec] = {
        "router": spec((cfg.d_model, e), ("embed", "experts")),
        "w_gate": spec((e, cfg.d_model, cfg.d_ff), ("experts", "embed", "expert_ffn")),
        "w_up": spec((e, cfg.d_model, cfg.d_ff), ("experts", "embed", "expert_ffn")),
        "w_down": spec((e, cfg.d_ff, cfg.d_model), ("experts", "expert_ffn", "embed"),
                       init="scaled"),
    }
    if cfg.shared_ff:
        s["shared"] = mlp_specs(cfg, d_ff=cfg.shared_ff)
        s["shared_gate"] = spec((cfg.d_model, 1), ("embed", None))
    return s


def _router(params, xt: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xt: [T, d] → (top_w [T,k] f32 normalized, top_e [T,k] i32)."""
    e_pad = cfg.n_experts_padded or cfg.n_experts
    logits = (xt @ params["router"].astype(xt.dtype)).astype(jnp.float32)
    if e_pad > cfg.n_experts:
        pad_mask = jnp.arange(e_pad) < cfg.n_experts
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_e.astype(jnp.int32)


def _positions_within_expert(flat_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Rank of each slot within its expert bucket, FIFO by slot order.
    argsort+searchsorted: O(n log n), no O(n²) reduce-window."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def _expert_ffn(buf: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """buf: [E, C, d] grouped tokens → [E, C, d] (SwiGLU per expert)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Single-shard reference path
# ---------------------------------------------------------------------------


def _moe_dense(params, x: jnp.ndarray, cfg: ModelConfig, run: RunConfig,
               capacity_factor: float) -> jnp.ndarray:
    cdt = run.compute_dtype
    B, S, d = x.shape
    T = B * S
    e_pad = cfg.n_experts_padded or cfg.n_experts
    k = cfg.top_k
    xt = x.reshape(T, d)
    top_w, top_e = _router(params, xt, cfg)

    capacity = max(int(math.ceil(T * k / e_pad * capacity_factor)), 8)
    flat_e = top_e.reshape(-1)
    pos = _positions_within_expert(flat_e, e_pad)
    keep = pos < capacity

    idx = flat_e * capacity + jnp.minimum(pos, capacity - 1)
    src = (jnp.repeat(xt, k, axis=0)
           * keep[:, None].astype(xt.dtype)).astype(cdt)
    buf = jnp.zeros((e_pad * capacity, d), cdt).at[idx].add(src)

    yb = _expert_ffn(buf.reshape(e_pad, capacity, d),
                     params["w_gate"].astype(cdt),
                     params["w_up"].astype(cdt),
                     params["w_down"].astype(cdt)).reshape(e_pad * capacity, d)
    out_k = yb[idx].reshape(T, k, d)
    w = (top_w * keep.reshape(T, k)).astype(cdt)
    return jnp.einsum("tkd,tk->td", out_k, w).reshape(B, S, d)


# ---------------------------------------------------------------------------
# shard_map expert-parallel path
# ---------------------------------------------------------------------------


def _moe_shard_map(params, x: jnp.ndarray, cfg: ModelConfig, run: RunConfig,
                   capacity_factor: float, mesh, batch_axes,
                   seq_axis: Optional[str]) -> jnp.ndarray:
    cdt = run.compute_dtype
    e_pad = cfg.n_experts_padded or cfg.n_experts
    k = cfg.top_k
    tp = mesh.shape["model"]
    e_local = e_pad // tp

    def body(router, w_gate, w_up, w_down, x_loc):
        Bl, Sl, d = x_loc.shape
        Tl = Bl * Sl
        xt = x_loc.reshape(Tl, d)
        top_w, top_e = _router({"router": router}, xt, cfg)

        cap = max(int(math.ceil(Tl * k / e_pad * capacity_factor)), 4)
        flat_e = top_e.reshape(-1)                      # [Tl*k]
        pos = _positions_within_expert(flat_e, e_pad)
        keep = pos < cap
        # destination shard + local expert id
        dst = flat_e // e_local
        loc = flat_e % e_local
        idx = (dst * e_local + loc) * cap + jnp.minimum(pos, cap - 1)

        src = (jnp.repeat(xt, k, axis=0)
               * keep[:, None].astype(xt.dtype)).astype(cdt)
        send = jnp.zeros((tp * e_local * cap, d), cdt).at[idx].add(src)
        send = send.reshape(tp, e_local * cap, d)
        # ONE tiled all-to-all: row j goes to model-shard j.
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        # my experts' tokens from every source: [tp*cap per expert]
        grouped = recv.reshape(tp, e_local, cap, d).transpose(1, 0, 2, 3)
        grouped = grouped.reshape(e_local, tp * cap, d)
        y = _expert_ffn(grouped, w_gate, w_up, w_down)
        y = y.reshape(e_local, tp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y.reshape(tp, e_local * cap, d), "model",
                                  split_axis=0, concat_axis=0, tiled=True)
        out_k = back.reshape(tp * e_local * cap, d)[idx]
        w = (top_w * keep.reshape(Tl, k)).astype(cdt)
        y_tok = jnp.einsum("tkd,tk->td", out_k.reshape(Tl, k, d), w)
        return y_tok.reshape(Bl, Sl, d)

    xspec = P(batch_axes, seq_axis, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), xspec),
        out_specs=xspec,
        check_vma=False,
    )(params["router"].astype(cdt), params["w_gate"].astype(cdt),
      params["w_up"].astype(cdt), params["w_down"].astype(cdt), x)
    return out


def moe(params: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig,
        run: RunConfig, capacity_factor: Optional[float] = None) -> jnp.ndarray:
    """x: [B, S, d] → [B, S, d].  Dispatches to the shard_map EP path when
    a mesh with a 'model' axis is in scope and shapes divide; otherwise
    the dense single-shard path (same semantics up to capacity grouping).
    """
    from ..parallel import ctx
    if capacity_factor is None:
        capacity_factor = getattr(run, "moe_capacity", 1.25)
    cdt = run.compute_dtype
    B, S, d = x.shape
    scope = ctx.current()
    y = None
    if scope is not None:
        mesh, rules = scope
        e_pad = cfg.n_experts_padded or cfg.n_experts
        tp = mesh.shape.get("model", 1)
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = 1
        for a in data_axes:
            dp *= mesh.shape[a]
        if tp > 1 and e_pad % tp == 0 and B % dp == 0:
            seq_axis = "model" if (rules.get("seq_act") == "model"
                                   and S % tp == 0) else None
            y = _moe_shard_map(params, x, cfg, run, capacity_factor,
                               mesh, data_axes, seq_axis)
    if y is None:
        y = _moe_dense(params, x, cfg, run, capacity_factor)

    if cfg.shared_ff:
        xt = x.reshape(B * S, d)
        sg = jax.nn.sigmoid((xt @ params["shared_gate"].astype(cdt))
                            .astype(jnp.float32)).astype(cdt)
        y = y + (mlp(params["shared"], xt, run) * sg).reshape(B, S, d)
    return y


def moe_load_balance_loss(params, x: jnp.ndarray, cfg: ModelConfig,
                          run: RunConfig) -> jnp.ndarray:
    """Auxiliary load-balancing loss (Switch-style fraction·prob)."""
    cdt = run.compute_dtype
    T = x.shape[0] * x.shape[1]
    e_pad = cfg.n_experts_padded or cfg.n_experts
    xt = x.reshape(T, -1)
    logits = (xt @ params["router"].astype(cdt)).astype(jnp.float32)
    if e_pad > cfg.n_experts:
        pad_mask = jnp.arange(e_pad) < cfg.n_experts
        logits = jnp.where(pad_mask[None, :], logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, e_pad, dtype=jnp.float32), axis=0)
    prob = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(frac * prob)

"""Model facade: one object per architecture exposing the whole lifecycle —
specs → init → loss/train → prefill/decode — plus ShapeDtypeStruct input
stand-ins (``input_specs``) and logical-axis annotations for sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hybrid as hybrid_mod
from . import transformer as tf_mod
from .common import (ModelConfig, RunConfig, abstract_params, init_params,
                     param_count, reduce_config)
from .layers import kv_cache_specs


@dataclasses.dataclass
class Model:
    arch: str
    cfg: ModelConfig
    run: RunConfig

    # ---- parameters -------------------------------------------------------
    def specs(self):
        if self.cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.hybrid_specs(self.cfg)
        return tf_mod.decoder_specs(self.cfg)

    def init(self, rng: jax.Array):
        return init_params(rng, self.specs(), self.cfg.init_std)

    def abstract(self, dtype=None):
        """ShapeDtypeStruct params; ``dtype`` overrides floating leaves
        (serve paths hold bf16 weights — cast offline at load time)."""
        tree = abstract_params(self.specs())
        if dtype is None:
            return tree
        import jax.numpy as jnp

        def f(s):
            if jnp.issubdtype(s.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(s.shape, dtype)
            return s
        return jax.tree.map(f, tree)

    def n_params(self) -> int:
        return param_count(self.specs())

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top-k routed experts)."""
        total = self.n_params()
        cfg = self.cfg
        if not cfg.n_experts:
            return total
        e = cfg.n_experts_padded or cfg.n_experts
        per_expert = 3 * cfg.d_model * cfg.d_ff
        return total - (e - cfg.top_k) * cfg.n_layers * per_expert

    # ---- training ---------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        if self.cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.loss_fn(params, batch, self.cfg, self.run)
        return tf_mod.loss_fn(params, batch, self.cfg, self.run)

    def forward(self, params, batch) -> jnp.ndarray:
        if self.cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.forward(params, batch, self.cfg, self.run)
        return tf_mod.forward(params, batch, self.cfg, self.run)

    # ---- serving ----------------------------------------------------------
    def prefill(self, params, batch, max_seq: int):
        if self.cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.prefill(params, batch, self.cfg, self.run,
                                      max_seq)
        if self.cfg.is_encoder_only:
            return tf_mod.forward(params, batch, self.cfg, self.run), None
        return tf_mod.prefill(params, batch, self.cfg, self.run, max_seq)

    def decode_step(self, params, state, tokens):
        if self.cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.decode_step(params, state, tokens, self.cfg,
                                          self.run)
        return tf_mod.decode_step(params, state, tokens, self.cfg, self.run)

    # ---- input stand-ins ---------------------------------------------------
    def input_specs(self, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        train → the training batch; prefill → the prompt batch;
        decode → one new token (the cache/state comes from state_specs).
        """
        from ..configs.shapes import SHAPES, skip_reason
        shape = SHAPES[shape_name]
        reason = skip_reason(self.cfg, shape)
        if reason:
            raise ValueError(f"{self.arch} × {shape_name} skipped: {reason}")
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        i32, f32 = jnp.int32, jnp.float32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            if cfg.family == "audio":
                return {"frames": sds((B, L, cfg.frame_dim), jnp.bfloat16),
                        "labels": sds((B, L), i32),
                        "mask": sds((B, L), jnp.bool_)}
            batch: Dict[str, Any] = {"tokens": sds((B, L), i32),
                                     "labels": sds((B, L), i32)}
            if cfg.family == "vlm":
                batch["patches"] = sds((B, cfg.n_patches, cfg.patch_dim),
                                       jnp.bfloat16)
                batch["mask"] = sds((B, L), jnp.bool_)
            return batch
        if shape.kind == "prefill":
            if cfg.family == "audio":
                return {"frames": sds((B, L, cfg.frame_dim), jnp.bfloat16)}
            batch = {"tokens": sds((B, L), i32)}
            if cfg.family == "vlm":
                batch["patches"] = sds((B, cfg.n_patches, cfg.patch_dim),
                                       jnp.bfloat16)
            return batch
        # decode: one new token; cache/state via state_specs
        return {"tokens": sds((B, 1), i32)}

    def input_axes(self, shape_name: str) -> Dict[str, Tuple]:
        """Logical axes of each input tensor (for sharding via rules)."""
        from ..configs.shapes import SHAPES
        shape = SHAPES[shape_name]
        cfg = self.cfg
        ax: Dict[str, Tuple] = {}
        names = self.input_specs(shape_name).keys()
        for k in names:
            if k == "tokens" or k == "labels" or k == "mask":
                ax[k] = ("batch", "seq" if shape.kind != "decode" else None)
            elif k == "frames":
                ax[k] = ("batch", "seq", None)
            elif k == "patches":
                ax[k] = ("batch", None, None)
        return ax

    def state_specs(self, shape_name: str) -> Optional[Dict[str, Any]]:
        """Decode/prefill-state (KV cache / SSM state) ShapeDtypeStructs.
        For prefill shapes these are the *output* cache specs (used to pin
        output shardings so XLA never replicates a 100+GB cache)."""
        from ..configs.shapes import SHAPES
        shape = SHAPES[shape_name]
        if shape.kind == "train":
            return None
        cfg = self.cfg
        if shape.kind == "prefill" and cfg.is_encoder_only:
            return None
        B, S = shape.global_batch, shape.seq_len
        if cfg.family in ("ssm", "hybrid"):
            return hybrid_mod.state_specs(cfg, B, S)
        return kv_cache_specs(cfg, B, S)

    def state_axes(self) -> Dict[str, Tuple]:
        cfg = self.cfg
        ax = {"length": ()}
        if cfg.family in ("ssm", "hybrid"):
            ax.update({
                "ssd": ("layers", "batch", "ssm_heads", None, None),
                "conv_x": ("layers", "batch", None, "ssm_inner"),
                "conv_B": ("layers", "batch", None, None),
                "conv_C": ("layers", "batch", None, None),
            })
            if hybrid_mod.n_attn_apps(cfg):
                ax["k"] = (None, "batch", "seq", "kv_heads", None)
                ax["v"] = (None, "batch", "seq", "kv_heads", None)
            return ax
        ax["k"] = ("layers", "batch", "seq", "kv_heads", None)
        ax["v"] = ("layers", "batch", "seq", "kv_heads", None)
        return ax


def build(arch: str, run: Optional[RunConfig] = None,
          smoke: bool = False) -> Model:
    from ..configs.registry import get_config
    cfg = get_config(arch)
    if smoke:
        cfg = reduce_config(cfg)
    return Model(arch=arch, cfg=cfg, run=run or RunConfig())

"""Model zoo: 10 assigned architectures behind one functional facade."""
from .common import ModelConfig, RunConfig  # noqa: F401
from .registry import Model, build  # noqa: F401

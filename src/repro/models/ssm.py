"""Mamba2 (SSD) block: projections + causal depthwise conv + SSD scan.

Projections are split per destination sharding: the inner width and the
dt-heads live on the 'model' axis; the (small) B/C state projections stay
replicated — so no resharding collective sits inside the block.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ssd import ops as ssd_ops
from .common import ModelConfig, ParamSpec, RunConfig, spec
from .layers import rmsnorm


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    cw = cfg.ssm_conv_width
    return {
        "w_x": spec((cfg.d_model, di), ("embed", "ssm_inner")),
        "w_z": spec((cfg.d_model, di), ("embed", "ssm_inner")),
        "w_B": spec((cfg.d_model, N), ("embed", None)),
        "w_C": spec((cfg.d_model, N), ("embed", None)),
        "w_dt": spec((cfg.d_model, H), ("embed", "ssm_heads")),
        "dt_bias": spec((H,), ("ssm_heads",), init="zeros"),
        "A_log": spec((H,), ("ssm_heads",), init="zeros"),
        "D": spec((H,), ("ssm_heads",), init="ones"),
        "conv_x": spec((cw, di), ("conv_w", "ssm_inner"), init="normal"),
        "conv_B": spec((cw, N), ("conv_w", None), init="normal"),
        "conv_C": spec((cw, N), ("conv_w", None), init="normal"),
        "gate_norm": spec((di,), ("ssm_inner",), init="ones"),
        "w_out": spec((di, cfg.d_model), ("ssm_inner", "embed"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: [B,L,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4); unrolled taps
        out = out + xp[:, k:k + x.shape[1], :] * w[k][None, None, :]
    return out


def _conv_decode(buf: jnp.ndarray, xt: jnp.ndarray, w: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-step causal conv.  buf: [B,K-1,C] (past inputs); xt: [B,C]."""
    full = jnp.concatenate([buf, xt[:, None, :]], axis=1)       # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", full, w)
    return y, full[:, 1:, :]


def _split_heads(x: jnp.ndarray, H: int) -> jnp.ndarray:
    B, L, di = x.shape
    return x.reshape(B, L, H, di // H)


def ssm_block(params, x: jnp.ndarray, cfg: ModelConfig, run: RunConfig
              ) -> jnp.ndarray:
    """Full-sequence Mamba2 block.  x: [B, L, d_model]."""
    cdt = run.compute_dtype
    H = cfg.ssm_heads
    xz = jax.nn.silu(_causal_conv(x @ params["w_x"].astype(cdt),
                                  params["conv_x"].astype(cdt)))
    Bm = jax.nn.silu(_causal_conv(x @ params["w_B"].astype(cdt),
                                  params["conv_B"].astype(cdt)))
    Cm = jax.nn.silu(_causal_conv(x @ params["w_C"].astype(cdt),
                                  params["conv_C"].astype(cdt)))
    dt = jax.nn.softplus((x @ params["w_dt"].astype(cdt)).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = _split_heads(xz, H)
    y, _ = ssd_ops.ssd(xh, dt, A, Bm, Cm, chunk=min(64, x.shape[1]),
                       use_pallas=run.use_pallas)
    y = y.astype(cdt) + params["D"].astype(cdt)[None, None, :, None] * xh
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    z = jax.nn.silu(x @ params["w_z"].astype(cdt))
    y = rmsnorm(y * z, params["gate_norm"], cfg.rms_eps)
    return y @ params["w_out"].astype(cdt)


# ---------------------------------------------------------------------------
# Decode: recurrent single-token step with (conv buffers + SSD state)
# ---------------------------------------------------------------------------


def ssm_state_specs(cfg: ModelConfig, batch: int, n_layers: int,
                    dtype=jnp.float32) -> Dict[str, jax.ShapeDtypeStruct]:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv_width
    return {
        "ssd": jax.ShapeDtypeStruct((n_layers, batch, H, N, P), dtype),
        "conv_x": jax.ShapeDtypeStruct((n_layers, batch, K - 1, cfg.d_inner), dtype),
        "conv_B": jax.ShapeDtypeStruct((n_layers, batch, K - 1, N), dtype),
        "conv_C": jax.ShapeDtypeStruct((n_layers, batch, K - 1, N), dtype),
    }


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {k: jnp.zeros(s.shape, s.dtype)
            for k, s in ssm_state_specs(cfg, batch, n_layers, dtype).items()}


def ssm_block_decode(params, x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                     cfg: ModelConfig, run: RunConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, d_model]; per-layer state slices (no leading layer axis)."""
    cdt = run.compute_dtype
    H = cfg.ssm_heads
    xt = x @ params["w_x"].astype(cdt)
    bt = x @ params["w_B"].astype(cdt)
    ct = x @ params["w_C"].astype(cdt)
    xc, conv_x = _conv_decode(state["conv_x"].astype(cdt), xt,
                              params["conv_x"].astype(cdt))
    bc, conv_B = _conv_decode(state["conv_B"].astype(cdt), bt,
                              params["conv_B"].astype(cdt))
    cc, conv_C = _conv_decode(state["conv_C"].astype(cdt), ct,
                              params["conv_C"].astype(cdt))
    xc, bc, cc = jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc)
    dt = jax.nn.softplus((x @ params["w_dt"].astype(cdt)).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xc.reshape(x.shape[0], H, cfg.ssm_head_dim)
    y, ssd_state = ssd_ops.ssd_decode(xh, dt, A, bc, cc,
                                      state["ssd"].astype(jnp.float32))
    y = y.astype(cdt) + params["D"].astype(cdt)[None, :, None] * xh
    y = y.reshape(x.shape[0], cfg.d_inner)
    z = jax.nn.silu(x @ params["w_z"].astype(cdt))
    y = rmsnorm(y * z, params["gate_norm"], cfg.rms_eps)
    out = y @ params["w_out"].astype(cdt)
    new_state = {"ssd": ssd_state.astype(state["ssd"].dtype),
                 "conv_x": conv_x.astype(state["conv_x"].dtype),
                 "conv_B": conv_B.astype(state["conv_B"].dtype),
                 "conv_C": conv_C.astype(state["conv_C"].dtype)}
    return out, new_state

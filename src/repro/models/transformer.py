"""Transformer families: dense/MoE decoder LMs, encoder-only (HuBERT),
and the VLM backbone (InternVL2: stubbed patch embeddings + decoder LM).

Layers are stacked with ``jax.lax.scan`` (single-layer compile) and the
layer body is wrapped in a configurable remat policy.  The same parameter
tree serves train, prefill and decode paths.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from .common import ModelConfig, RunConfig, spec, stacked
from .layers import (attention, attn_specs, cross_entropy, decode_attention,
                     embed, embed_specs, logits_out, mlp, mlp_specs, rmsnorm)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {"ln1": spec((cfg.d_model,), (None,), init="ones"),
                         "ln2": spec((cfg.d_model,), (None,), init="ones"),
                         "attn": attn_specs(cfg)}
    if cfg.n_experts:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def decoder_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "layers": jax.tree.map(lambda sp: stacked(cfg.n_layers, sp),
                               layer_specs(cfg),
                               is_leaf=lambda x: hasattr(x, "axes")),
        "ln_f": spec((cfg.d_model,), (None,), init="ones"),
    }
    if cfg.n_patches:      # VLM frontend stub: projection of patch embeds
        s["patch_proj"] = spec((cfg.patch_dim, cfg.d_model), ("patch", "embed"))
    if cfg.frame_dim:      # audio frontend stub: projection of frame embeds
        s["frame_proj"] = spec((cfg.frame_dim, cfg.d_model), ("patch", "embed"))
    return s


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------


def _layer_body(h: jnp.ndarray, lp, positions, cfg: ModelConfig,
                run: RunConfig) -> jnp.ndarray:
    from ..parallel.ctx import constrain
    h = constrain(h, ("batch", "seq_act", None))
    h = h + attention(lp["attn"], rmsnorm(h, lp["ln1"], cfg.rms_eps),
                      positions, cfg, run)
    h = constrain(h, ("batch", "seq_act", None))
    hn = rmsnorm(h, lp["ln2"], cfg.rms_eps)
    if cfg.n_experts:
        h = h + moe_mod.moe(lp["moe"], hn, cfg, run)
    else:
        h = h + mlp(lp["mlp"], hn, run)
    return h


def backbone(params, h: jnp.ndarray, positions, cfg: ModelConfig,
             run: RunConfig) -> jnp.ndarray:
    body = _remat(
        lambda hh, lp: (_layer_body(hh, lp, positions, cfg, run), None), run)
    if run.scan_layers:
        h, _ = jax.lax.scan(body, h, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, _ = body(h, lp)
    return rmsnorm(h, params["ln_f"], cfg.rms_eps)


def embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                 run: RunConfig) -> jnp.ndarray:
    """Token / frame / patch embedding, per family."""
    if cfg.frame_dim:                      # audio encoder: frames only
        return batch["frames"].astype(run.compute_dtype) @ \
            params["frame_proj"].astype(run.compute_dtype)
    h = embed(params["embed"], batch["tokens"], run)
    if cfg.n_patches:                      # VLM: patches overwrite the prefix
        pe = batch["patches"].astype(run.compute_dtype) @ \
            params["patch_proj"].astype(run.compute_dtype)
        h = jnp.concatenate([pe, h[:, cfg.n_patches:, :]], axis=1)
    return h


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            run: RunConfig) -> jnp.ndarray:
    h = embed_inputs(params, batch, cfg, run)
    B, L = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    h = backbone(params, h, positions, cfg, run)
    return logits_out(params["embed"], h, cfg, run)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            run: RunConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward(params, batch, cfg, run)
    mask = batch.get("mask")
    if cfg.is_encoder_only:
        loss = cross_entropy(logits, batch["labels"], mask)
    else:
        # next-token prediction; mask covers padding / patch prefix
        lg = logits[:, :-1]
        lb = batch["labels"][:, 1:]
        m = None if mask is None else mask[:, 1:]
        loss = cross_entropy(lg, lb, m)
    metrics = {"loss": loss}
    if cfg.n_experts:
        aux = 0.0
        h = embed_inputs(params, batch, cfg, run)
        # router balance measured at the input embedding of layer 0 (cheap
        # proxy; the per-layer aux sum is applied on TPU runs)
        aux = moe_mod.moe_load_balance_loss(
            jax.tree.map(lambda x: x[0], params["layers"]["moe"]), h, cfg, run)
        metrics["aux_loss"] = aux
        loss = loss + 0.01 * aux
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig, run: RunConfig, max_seq: int):
    """Run the full prompt, return (last_logits, kv_cache).

    Cached keys are stored post-qk-norm / post-RoPE — the exact layout
    ``decode_attention`` writes — so decode is O(1) per step.
    """
    from .layers import apply_rope
    h = embed_inputs(params, batch, cfg, run)
    B, L = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(hh, lp):
        hn = rmsnorm(hh, lp["ln1"], cfg.rms_eps)
        cdt = run.compute_dtype
        k = jnp.einsum("bld,dhk->blhk", hn, lp["attn"]["wk"].astype(cdt))
        v = jnp.einsum("bld,dhk->blhk", hn, lp["attn"]["wv"].astype(cdt))
        if cfg.qk_norm:
            k = rmsnorm(k, lp["attn"]["k_norm"], cfg.rms_eps)
        k = apply_rope(k, positions, cfg.rope_theta)
        hh = _layer_body(hh, lp, positions, cfg, run)
        return hh, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    if run.scan_layers:
        h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    else:   # unrolled (cost probes): loop bodies visible to cost analysis
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, kv = body(h, lp)
            kvs.append(kv)
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    logits = logits_out(params["embed"], h[:, -1:, :], cfg, run)

    pad = max_seq - L
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "length": jnp.asarray(L, jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig,
                run: RunConfig):
    """tokens: [B, 1] → (logits [B,1,V], updated cache)."""
    h = embed(params["embed"], tokens, run)
    length = cache["length"]

    def body3(hh, xs):   # keep [B,1,d] rank throughout
        lp, kc, vc = xs
        hn = rmsnorm(hh, lp["ln1"], cfg.rms_eps)
        a, kc, vc = decode_attention(lp["attn"], hn, kc, vc, length, cfg, run)
        hh = hh + a
        hn = rmsnorm(hh, lp["ln2"], cfg.rms_eps)
        if cfg.n_experts:
            hh = hh + moe_mod.moe(lp["moe"], hn, cfg, run)
        else:
            hh = hh + mlp(lp["mlp"], hn, run)
        return hh, (kc, vc)

    if run.scan_layers:
        h, (ks, vs) = jax.lax.scan(body3, h, (params["layers"], cache["k"],
                                              cache["v"]))
    else:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, kv = body3(h, (lp, cache["k"][i], cache["v"][i]))
            kvs.append(kv)
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    logits = logits_out(params["embed"], h, cfg, run)
    new_cache = {"k": ks, "v": vs, "length": length + 1}
    return logits, new_cache

"""SLO targets, burn-rate algebra and typed alert records (``repro.obs``).

The live monitor (:mod:`repro.obs.monitor`) evaluates two alert families
over its rolling windows:

* **SLO burn rates** — per-QoS service objectives (budget-met fraction,
  p95 workflow slowdown, p95 queue wait) expressed as *error-budget burn
  rates*: ``burn = (1 - SLI) / (1 - target)``.  Burn 1.0 means the class
  is consuming its error budget exactly as fast as the target allows;
  an alert fires when the short **and** long windows both burn too fast
  (the SRE multi-window rule — short catches the spike, long confirms
  it is sustained) and clears when the short window recovers.
* **Anomaly detectors** — platform-scope threshold + MAD (median
  absolute deviation) rules over the windowed deltas: wasted-spend burn
  (``budget_burn``), straggler-rate spike, fleet provisioning thrash and
  ready-queue buildup.

Everything here is pure and deterministic: alerts are typed records with
fire/clear timestamps on the *simulated* clock, so the same (seed,
config) produces byte-identical alert streams on every engine and across
checkpoint/resume (gated in ``tests/test_monitor.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# ---- alert kinds -----------------------------------------------------------
# SLO burn-rate alerts (scoped per QoS class):
ALERT_SLO_BUDGET = 1        # windowed budget-met fraction burning too fast
ALERT_SLO_SLOWDOWN = 2      # windowed p95 workflow slowdown over ceiling
ALERT_SLO_QUEUE_WAIT = 3    # windowed p95 queue wait over target
# Anomaly detectors (scope "platform"):
ALERT_BUDGET_BURN = 4       # windowed wasted-spend fraction (chaos burn)
ALERT_FLEET_THRASH = 5      # provisioning churn spike (MAD over ticks)
ALERT_STRAGGLER_SPIKE = 6   # straggler-detection rate spike
ALERT_QUEUE_BUILDUP = 7     # ready-queue depth anomaly (MAD over samples)

ALERT_KIND_NAMES: Dict[int, str] = {
    ALERT_SLO_BUDGET: "slo_budget_met",
    ALERT_SLO_SLOWDOWN: "slo_p95_slowdown",
    ALERT_SLO_QUEUE_WAIT: "slo_queue_wait",
    ALERT_BUDGET_BURN: "budget_burn",
    ALERT_FLEET_THRASH: "fleet_thrash",
    ALERT_STRAGGLER_SPIKE: "straggler_spike",
    ALERT_QUEUE_BUILDUP: "queue_buildup",
}


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Per-QoS service objectives the monitor burns against."""

    budget_met: float = 0.80        # target fraction of workflows in budget
    p95_slowdown: float = 16.0      # ceiling on windowed p95 slowdown
    queue_wait_ms: int = 240_000    # ceiling on windowed p95 queue wait


# Defaults keyed by the repo's QoS class names (repro.tenants GOLD /
# SILVER / BRONZE); "all" covers runs without tenant maps.  Tighter
# classes pay for tighter budget draws with tighter objectives.
DEFAULT_TARGETS: Dict[str, SLOTarget] = {
    "gold": SLOTarget(budget_met=0.90, p95_slowdown=8.0,
                      queue_wait_ms=60_000),
    "silver": SLOTarget(budget_met=0.85, p95_slowdown=12.0,
                        queue_wait_ms=120_000),
    "bronze": SLOTarget(budget_met=0.80, p95_slowdown=16.0,
                        queue_wait_ms=240_000),
    "all": SLOTarget(),
}


def target_for(qos: str,
               targets: Optional[Dict[str, SLOTarget]] = None) -> SLOTarget:
    """The SLO target for a QoS class (falls back to ``"all"``)."""
    table = targets if targets is not None else DEFAULT_TARGETS
    return table.get(qos) or table.get("all") or SLOTarget()


def burn_rate(sli: float, target: float) -> float:
    """Error-budget burn rate of an SLI against its target fraction:
    ``(1 - sli) / (1 - target)`` — 0 when the SLI is perfect, 1 when it
    sits exactly at target, >1 when the error budget is burning faster
    than the objective allows.  A degenerate target of 1.0 burns at the
    raw error fraction scaled by 1e3 (never divides by zero)."""
    err_budget = 1.0 - target
    if err_budget <= 0.0:
        return (1.0 - sli) * 1e3
    return max(0.0, 1.0 - sli) / err_budget


def mad_fire(history: np.ndarray, current: float, k: float,
             min_abs: float, min_samples: int) -> bool:
    """Threshold + MAD anomaly rule: ``current`` is anomalous when it
    exceeds ``median(history) + max(k * MAD(history), min_abs)``.  The
    absolute floor ``min_abs`` keeps all-quiet histories (MAD = 0) from
    flagging every nonzero tick; fewer than ``min_samples`` history
    points never fire."""
    if len(history) < min_samples:
        return False
    med = float(np.median(history))
    mad = float(np.median(np.abs(history - med)))
    return current > med + max(k * mad, min_abs)


@dataclasses.dataclass
class Alert:
    """One fired alert: typed kind, QoS scope (or ``"platform"``),
    fire/clear timestamps on the simulated clock (``cleared_ms = -1``
    while open), the value that tripped the rule and its threshold."""

    kind: int
    scope: str
    fired_ms: int
    value: float
    threshold: float
    cleared_ms: int = -1

    @property
    def open(self) -> bool:
        return self.cleared_ms < 0

    def to_dict(self) -> Dict[str, object]:
        return {
            "kind": ALERT_KIND_NAMES.get(self.kind, str(self.kind)),
            "scope": self.scope,
            "fired_ms": int(self.fired_ms),
            "cleared_ms": int(self.cleared_ms),
            "value": float(self.value),
            "threshold": float(self.threshold),
        }


class AlertGate:
    """Hysteresis per (kind, scope): holds the open alert's index into
    the shared alert list; :meth:`step` opens on the fire condition and
    closes on the clear condition.  Pickles with the monitor (plain
    attributes), so resumed streams replay fire/clear bit-identically."""

    __slots__ = ("kind", "scope", "open_idx")

    def __init__(self, kind: int, scope: str):
        self.kind = kind
        self.scope = scope
        self.open_idx = -1

    def step(self, alerts: List[Alert], now_ms: int, fire: bool,
             clear: bool, value: float, threshold: float) -> None:
        if self.open_idx < 0:
            if fire:
                self.open_idx = len(alerts)
                alerts.append(Alert(self.kind, self.scope, now_ms,
                                    float(value), float(threshold)))
        elif clear:
            alerts[self.open_idx].cleared_ms = now_ms
            self.open_idx = -1

"""Live streaming monitor over the :class:`repro.obs.events.EventLog`.

The :class:`Monitor` subscribes to the emit path (``elog.sub``) and
folds every event into rolling aggregates *as it happens* — no post-hoc
scan, O(1) amortized per event, zero-cost when disabled (the hot path
in ``events.append`` is a single ``sub is not None`` check, the same
discipline as ``elog=None`` itself).  State lives in flat numpy ring
buffers sampled on a fixed simulated-time grid:

* gauges per sample tick — fleet size, busy VMs, ready-queue depth
  (total and per QoS class);
* cumulative counters per tick — spend, wasted spend, distributed
  budget, arrivals (total and per QoS), completions, failures,
  revocations, straggler detections, retries, provisioning churn,
  placements;
* recent-completion and recent-placement rings feeding the per-QoS
  windowed SLIs (budget-met fraction, p95 slowdown, p95 queue wait).

On each tick the :mod:`repro.obs.slo` engine evaluates multi-window
burn rates and threshold+MAD anomaly detectors, appending typed
:class:`~repro.obs.slo.Alert` records with fire/clear timestamps.

Determinism: sample ticks advance *before* the incoming event is
applied, so a tick at boundary ``B`` always records the state produced
by events with ``t < B`` — the sampled series depend only on the
(engine-invariant) per-member event stream, never on wall clock.  The
monitor rides stream snapshots for free: it is reachable from the
pickled ``elog`` residue (``elog.sub``), so interrupt/resume replays
windows and alerts bit-identically.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import slo as obs_slo
from .events import (STRAGGLER_DETECT, TASK_FAIL, TASK_FINISH, TASK_PLACE,
                     TASK_READY, TASK_RETRY, TASK_START, VM_PROVISION,
                     VM_REAP, VM_REVOKE, WF_ARRIVE, WF_DONE)

#: Names of the per-tick sampled series, in export order.  Gauges are
#: instantaneous; ``cum_*`` series are cumulative counters (windowed
#: rates are deltas of these).
SERIES_NAMES: Tuple[str, ...] = (
    "fleet", "busy", "queue",
    "cum_cost", "cum_wasted", "cum_budget",
    "cum_arrivals", "cum_completions", "cum_failures", "cum_revocations",
    "cum_stragglers", "cum_retries", "cum_churn", "cum_placements",
)


def _monitor_enabled() -> bool:
    """``REPRO_MONITOR=1`` turns the live monitor on globally (same
    contract as ``REPRO_TRACE`` for the event log)."""
    return os.environ.get("REPRO_MONITOR", "") == "1"


def resolve_monitor(monitor) -> Optional["Monitor"]:
    """Normalize an engine ``monitor=`` argument: a :class:`Monitor`
    passes through, ``True`` builds a default one, ``None`` defers to
    the ``REPRO_MONITOR=1`` environment opt-in, falsy disables."""
    if isinstance(monitor, Monitor):
        return monitor
    if monitor is None:
        return Monitor() if _monitor_enabled() else None
    return Monitor() if monitor else None


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Knobs for the streaming monitor.  Everything is in simulated
    milliseconds; all thresholds are evaluated on the sample grid, so
    the whole configuration is deterministic in (seed, config)."""

    sample_ms: int = 5_000            # tick grid for the sampled series
    short_window_ms: int = 60_000     # fast burn window
    long_window_ms: int = 300_000     # sustained burn window
    sample_capacity: int = 4096       # ring capacity, sample ticks
    completion_capacity: int = 8192   # ring capacity, WF_DONE records
    placement_capacity: int = 16384   # ring capacity, TASK_PLACE records
    # SLO burn-rate gating (multi-window: short>=fire AND long>=fire*
    # long_factor opens; short<clear closes).
    burn_fire: float = 2.0
    burn_clear: float = 1.0
    long_factor: float = 0.5
    min_window_completions: int = 5
    min_window_placements: int = 5
    # Anomaly detectors.
    mad_k: float = 6.0
    mad_window: int = 64              # ticks of history for MAD rules
    mad_min_samples: int = 12
    waste_frac_fire: float = 0.04     # budget_burn: windowed wasted/spend
    waste_frac_clear: float = 0.01
    min_window_spend: float = 1e-9
    straggler_fire: int = 3           # straggler_spike: short-window count
    straggler_clear: int = 1
    fleet_thrash_min: float = 6.0     # churn/tick floor under the MAD rule
    queue_buildup_min: float = 12.0   # depth-over-median floor (MAD rule)
    # Per-QoS SLO targets; ``None`` = :data:`repro.obs.slo.DEFAULT_TARGETS`.
    targets: Optional[Dict[str, obs_slo.SLOTarget]] = None


class Monitor:
    """Streaming monitor instance — attach as ``elog.sub`` (the engines
    do this when constructed with ``monitor=``).

    ``tenant_of`` (wid → tenant), ``qos_of`` (tenant → QoS class) and
    ``ideal_ms`` (wid → critical-path lower bound) switch on the per-QoS
    breakdown and the slowdown SLI; without maps every workflow lands in
    a single ``"all"`` class and slowdown alerts stay dormant.
    """

    def __init__(self, cfg: Optional[MonitorConfig] = None,
                 tenant_of: Optional[Dict[int, str]] = None,
                 qos_of: Optional[Dict[str, str]] = None,
                 ideal_ms: Optional[Dict[int, int]] = None):
        self.cfg = cfg or MonitorConfig()
        if qos_of:
            self.qos_names: Tuple[str, ...] = tuple(sorted(set(
                qos_of.values())))
        else:
            self.qos_names = ("all",)
        qidx = {name: i for i, name in enumerate(self.qos_names)}
        # wid → QoS index, precomputed once (hot path does one dict get).
        self._wid_q: Dict[int, int] = {}
        if tenant_of:
            for wid, ten in tenant_of.items():
                self._wid_q[wid] = qidx.get(
                    (qos_of or {}).get(ten, self.qos_names[0]), 0)
        self._ideal = dict(ideal_ms) if ideal_ms else None
        nq = len(self.qos_names)
        c = self.cfg
        cap = c.sample_capacity
        self.samp_t = np.zeros(cap, np.int64)
        self.s_gauges = np.zeros((cap, 3), np.int64)      # fleet busy queue
        self.s_qqueue = np.zeros((cap, nq), np.int64)     # queue per QoS
        self.s_qarr = np.zeros((cap, nq), np.int64)       # cum arrivals/QoS
        self.s_cum = np.zeros((cap, len(SERIES_NAMES) - 3), np.float64)
        self.comp_t = np.zeros(c.completion_capacity, np.int64)
        self.comp_q = np.zeros(c.completion_capacity, np.int8)
        self.comp_met = np.zeros(c.completion_capacity, np.int8)
        self.comp_slow = np.zeros(c.completion_capacity, np.float64)
        self.comp_total = 0
        self.pl_t = np.zeros(c.placement_capacity, np.int64)
        self.pl_q = np.zeros(c.placement_capacity, np.int8)
        self.pl_wait = np.zeros(c.placement_capacity, np.int64)
        self.pl_total = 0
        # Live gauges / counters (plain scalars on the hot path).
        self.fleet = 0
        self.busy = 0
        self.queue = 0
        self.qqueue = [0] * nq
        self.qarr = [0] * nq
        self.cost = 0.0
        self.wasted = 0.0
        self.budget = 0.0
        self.arrivals = 0
        self.completions = 0
        self.failures = 0
        self.revocations = 0
        self.stragglers = 0
        self.retries = 0
        self.churn = 0
        self.placements = 0
        self.events_seen = 0
        self._ready_at: Dict[Tuple[int, int], int] = {}
        self._arrive_at: Dict[int, int] = {}
        self.ticks = 0
        self.next_tick_ms = c.sample_ms
        self.finalized_ms = -1
        self.alerts: List[obs_slo.Alert] = []
        # Gates in a fixed order (platform detectors, then per-QoS SLO
        # gates in sorted class order) so same-tick alerts serialize
        # identically everywhere.
        self._g_burn = obs_slo.AlertGate(obs_slo.ALERT_BUDGET_BURN,
                                         "platform")
        self._g_thrash = obs_slo.AlertGate(obs_slo.ALERT_FLEET_THRASH,
                                           "platform")
        self._g_strag = obs_slo.AlertGate(obs_slo.ALERT_STRAGGLER_SPIKE,
                                          "platform")
        self._g_queue = obs_slo.AlertGate(obs_slo.ALERT_QUEUE_BUILDUP,
                                          "platform")
        self._g_slo: Dict[Tuple[int, str], obs_slo.AlertGate] = {}
        for q in self.qos_names:
            for kind in (obs_slo.ALERT_SLO_BUDGET, obs_slo.ALERT_SLO_SLOWDOWN,
                         obs_slo.ALERT_SLO_QUEUE_WAIT):
                self._g_slo[(kind, q)] = obs_slo.AlertGate(kind, q)

    # ---- hot path ----------------------------------------------------------
    def on_event(self, kind: int, t: int, a: int, b: int, c: int, d: int,
                 x: float, y: float) -> None:
        """Fold one event (called from ``EventLog.append``).  Ticks are
        flushed *before* the event is applied — see the module note."""
        while t >= self.next_tick_ms:
            self._tick(self.next_tick_ms)
            self.next_tick_ms += self.cfg.sample_ms
        self.events_seen += 1
        if kind == TASK_READY:
            self.queue += 1
            qi = self._wid_q.get(a, 0)
            self.qqueue[qi] += 1
            self._ready_at[(a, b)] = t
        elif kind == TASK_PLACE:
            self.queue -= 1
            qi = self._wid_q.get(a, 0)
            self.qqueue[qi] -= 1
            ready = self._ready_at.pop((a, b), t)
            self.placements += 1
            j = self.pl_total % self.cfg.placement_capacity
            self.pl_t[j] = t
            self.pl_q[j] = qi
            self.pl_wait[j] = t - ready
            self.pl_total += 1
        elif kind == TASK_START:
            self.busy += 1
        elif kind == TASK_FINISH:
            self.busy -= 1
            self.cost += x
        elif kind == TASK_FAIL:
            self.busy -= 1
            self.cost += x
            self.wasted += x
            self.failures += 1
        elif kind == TASK_RETRY:
            self.retries += 1
            self.queue += 1
            qi = self._wid_q.get(a, 0)
            self.qqueue[qi] += 1
            self._ready_at[(a, b)] = t
        elif kind == WF_ARRIVE:
            self.arrivals += 1
            self.budget += x
            self.qarr[self._wid_q.get(a, 0)] += 1
            self._arrive_at[a] = t
        elif kind == WF_DONE:
            self.completions += 1
            qi = self._wid_q.get(a, 0)
            ideal = self._ideal.get(a, 0) if self._ideal else 0
            arrive = self._arrive_at.pop(a, t)
            j = self.comp_total % self.cfg.completion_capacity
            self.comp_t[j] = t
            self.comp_q[j] = qi
            self.comp_met[j] = 1 if x <= y + 1e-9 else 0
            self.comp_slow[j] = ((t - arrive) / ideal if ideal > 0
                                 else float("nan"))
            self.comp_total += 1
        elif kind == VM_PROVISION:
            self.fleet += 1
            self.churn += 1
        elif kind == VM_REAP:
            self.fleet -= 1
            self.churn += 1
        elif kind == VM_REVOKE:
            self.fleet -= 1
            self.churn += 1
            self.busy -= d
            self.cost += x
            self.wasted += x
            self.revocations += 1
        elif kind == STRAGGLER_DETECT:
            self.stragglers += 1
        # Other kinds (BUDGET_*, VM_BUSY/IDLE/CONTAINER, GRID_*) carry no
        # monitored state but still count toward events_seen.

    # ---- sampling ----------------------------------------------------------
    def _tick(self, t: int) -> None:
        """Record one sample at boundary ``t`` and evaluate alerts."""
        cap = self.cfg.sample_capacity
        j = self.ticks % cap
        self.samp_t[j] = t
        self.s_gauges[j, 0] = self.fleet
        self.s_gauges[j, 1] = self.busy
        self.s_gauges[j, 2] = self.queue
        self.s_qqueue[j] = self.qqueue
        self.s_qarr[j] = self.qarr
        self.s_cum[j] = (self.cost, self.wasted, self.budget,
                         self.arrivals, self.completions, self.failures,
                         self.revocations, self.stragglers, self.retries,
                         self.churn, self.placements)
        self.ticks += 1
        self._evaluate(t)

    def _cum_delta(self, col: int, w_ticks: int) -> float:
        """Windowed delta of cumulative column ``col`` at the latest
        tick: value now minus value ``w_ticks`` ticks ago (0 before the
        stream started)."""
        cap = self.cfg.sample_capacity
        i = self.ticks - 1
        cur = float(self.s_cum[i % cap, col])
        k = i - w_ticks
        if k < 0:
            return cur
        if i - k >= cap:        # ring forgot it; clamp to oldest retained
            k = i - cap + 1
        return cur - float(self.s_cum[k % cap, col])

    def _tick_deltas(self, col: int) -> np.ndarray:
        """Per-tick deltas of cumulative column ``col`` over the MAD
        history window, oldest→newest, excluding the current tick."""
        cap = self.cfg.sample_capacity
        i = self.ticks - 1
        lo = max(i - self.cfg.mad_window, i - cap + 1, 0)
        idx = np.arange(lo, i + 1) % cap
        return np.diff(self.s_cum[idx, col])[:-1] if i - lo >= 2 \
            else np.zeros(0, np.float64)

    def _gauge_history(self, col: int) -> np.ndarray:
        """Sampled gauge history over the MAD window, excluding now."""
        cap = self.cfg.sample_capacity
        i = self.ticks - 1
        lo = max(i - self.cfg.mad_window, i - cap + 1, 0)
        idx = np.arange(lo, i) % cap
        return self.s_gauges[idx, col].astype(np.float64)

    # ---- alert evaluation --------------------------------------------------
    def _evaluate(self, t: int) -> None:
        cfg = self.cfg
        ws = max(1, cfg.short_window_ms // cfg.sample_ms)
        wl = max(1, cfg.long_window_ms // cfg.sample_ms)
        al = self.alerts
        # budget_burn: windowed wasted-spend fraction over both windows.
        spend_s = self._cum_delta(0, ws)
        spend_l = self._cum_delta(0, wl)
        frac_s = (self._cum_delta(1, ws) / spend_s
                  if spend_s > cfg.min_window_spend else 0.0)
        frac_l = (self._cum_delta(1, wl) / spend_l
                  if spend_l > cfg.min_window_spend else 0.0)
        self._g_burn.step(
            al, t,
            fire=(frac_s >= cfg.waste_frac_fire
                  and frac_l >= cfg.waste_frac_fire * cfg.long_factor),
            clear=frac_s < cfg.waste_frac_clear,
            value=frac_s, threshold=cfg.waste_frac_fire)
        # straggler_spike: short-window detection count over threshold.
        n_strag = self._cum_delta(7, ws)
        self._g_strag.step(
            al, t,
            fire=n_strag >= cfg.straggler_fire,
            clear=n_strag <= cfg.straggler_clear,
            value=n_strag, threshold=float(cfg.straggler_fire))
        # fleet_thrash: this tick's provisioning churn vs MAD history.
        churn_hist = self._tick_deltas(9)
        churn_now = (self._cum_delta(9, 1) if self.ticks > 1
                     else float(self.s_cum[(self.ticks - 1)
                                           % cfg.sample_capacity, 9]))
        thrash = obs_slo.mad_fire(churn_hist, churn_now, cfg.mad_k,
                                  cfg.fleet_thrash_min, cfg.mad_min_samples)
        self._g_thrash.step(al, t, fire=thrash, clear=not thrash,
                            value=churn_now, threshold=cfg.fleet_thrash_min)
        # queue_buildup: queue depth now vs MAD over its sampled history.
        q_hist = self._gauge_history(2)
        q_now = float(self.queue)
        build = obs_slo.mad_fire(q_hist, q_now, cfg.mad_k,
                                 cfg.queue_buildup_min, cfg.mad_min_samples)
        self._g_queue.step(al, t, fire=build, clear=not build,
                           value=q_now, threshold=cfg.queue_buildup_min)
        # Per-QoS SLO burn rates from the completion/placement rings.
        n = min(self.comp_total, cfg.completion_capacity)
        if n:
            ct = self.comp_t[:n]
            in_s = (ct >= t - cfg.short_window_ms) & (ct < t)
            in_l = (ct >= t - cfg.long_window_ms) & (ct < t)
        m = min(self.pl_total, cfg.placement_capacity)
        if m:
            pt = self.pl_t[:m]
            pin_s = (pt >= t - cfg.short_window_ms) & (pt < t)
            pin_l = (pt >= t - cfg.long_window_ms) & (pt < t)
        for qi, qname in enumerate(self.qos_names):
            tgt = obs_slo.target_for(qname, cfg.targets)
            if n:
                qs = in_s & (self.comp_q[:n] == qi)
                ql = in_l & (self.comp_q[:n] == qi)
                ns, nl = int(qs.sum()), int(ql.sum())
                if min(ns, nl) >= cfg.min_window_completions:
                    burn_s = obs_slo.burn_rate(
                        float(self.comp_met[:n][qs].mean()), tgt.budget_met)
                    burn_l = obs_slo.burn_rate(
                        float(self.comp_met[:n][ql].mean()), tgt.budget_met)
                    self._g_slo[(obs_slo.ALERT_SLO_BUDGET, qname)].step(
                        al, t,
                        fire=(burn_s >= cfg.burn_fire
                              and burn_l >= cfg.burn_fire * cfg.long_factor),
                        clear=burn_s < cfg.burn_clear,
                        value=burn_s, threshold=cfg.burn_fire)
                    slow_s = self.comp_slow[:n][qs]
                    slow_l = self.comp_slow[:n][ql]
                    if (not np.isnan(slow_s).any()
                            and not np.isnan(slow_l).any()):
                        v_s = float(np.percentile(slow_s, 95))
                        v_l = float(np.percentile(slow_l, 95))
                        r_s = v_s / tgt.p95_slowdown
                        self._g_slo[(obs_slo.ALERT_SLO_SLOWDOWN,
                                     qname)].step(
                            al, t,
                            fire=(r_s >= 1.0
                                  and v_l / tgt.p95_slowdown
                                  >= cfg.long_factor),
                            clear=r_s < 1.0,
                            value=v_s, threshold=tgt.p95_slowdown)
            if m:
                qs = pin_s & (self.pl_q[:m] == qi)
                ql = pin_l & (self.pl_q[:m] == qi)
                if (min(int(qs.sum()), int(ql.sum()))
                        >= cfg.min_window_placements):
                    w_s = float(np.percentile(self.pl_wait[:m][qs], 95))
                    w_l = float(np.percentile(self.pl_wait[:m][ql], 95))
                    r_s = w_s / tgt.queue_wait_ms
                    self._g_slo[(obs_slo.ALERT_SLO_QUEUE_WAIT, qname)].step(
                        al, t,
                        fire=(r_s >= 1.0
                              and w_l / tgt.queue_wait_ms >= cfg.long_factor),
                        clear=r_s < 1.0,
                        value=w_s, threshold=float(tgt.queue_wait_ms))

    # ---- lifecycle ---------------------------------------------------------
    def finalize(self, now_ms: int) -> None:
        """Flush remaining sample boundaries up to ``now_ms`` and record
        one final sample at the horizon (post-reap state).  Alerts still
        open keep ``cleared_ms = -1``.  Idempotent per horizon."""
        if self.finalized_ms == now_ms:
            return
        while self.next_tick_ms <= now_ms:
            self._tick(self.next_tick_ms)
            self.next_tick_ms += self.cfg.sample_ms
        cap = self.cfg.sample_capacity
        last = int(self.samp_t[(self.ticks - 1) % cap]) if self.ticks else -1
        if last != now_ms:
            self._tick(now_ms)
        self.finalized_ms = now_ms

    # ---- export helpers ----------------------------------------------------
    def sample_order(self) -> np.ndarray:
        """Chronological ring indices of the retained samples."""
        cap = self.cfg.sample_capacity
        if self.ticks <= cap:
            return np.arange(self.ticks)
        start = self.ticks % cap
        return np.concatenate([np.arange(start, cap), np.arange(start)])

    def series(self) -> Dict[str, np.ndarray]:
        """Retained sampled series by name (chronological)."""
        o = self.sample_order()
        out: Dict[str, np.ndarray] = {"t_ms": self.samp_t[o]}
        for k, name in enumerate(("fleet", "busy", "queue")):
            out[name] = self.s_gauges[o, k]
        for k, name in enumerate(SERIES_NAMES[3:]):
            out[name] = self.s_cum[o, k]
        for qi, qname in enumerate(self.qos_names):
            out[f"queue[{qname}]"] = self.s_qqueue[o, qi]
            out[f"cum_arrivals[{qname}]"] = self.s_qarr[o, qi]
        return out

    def alerts_by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for a in self.alerts:
            name = obs_slo.ALERT_KIND_NAMES.get(a.kind, str(a.kind))
            out[name] = out.get(name, 0) + 1
        return dict(sorted(out.items()))

    def slo_table(self) -> Dict[str, Dict[str, object]]:
        """Whole-run per-QoS SLI summary (over the retained completion /
        placement rings) for the dashboard SLO table."""
        cfg = self.cfg
        out: Dict[str, Dict[str, object]] = {}
        n = min(self.comp_total, cfg.completion_capacity)
        m = min(self.pl_total, cfg.placement_capacity)
        for qi, qname in enumerate(self.qos_names):
            tgt = obs_slo.target_for(qname, cfg.targets)
            row: Dict[str, object] = {
                "target_budget_met": tgt.budget_met,
                "target_p95_slowdown": tgt.p95_slowdown,
                "target_queue_wait_ms": int(tgt.queue_wait_ms),
                "n_completions": 0, "budget_met": 1.0,
                "p95_slowdown": 0.0, "p95_queue_wait_ms": 0.0,
            }
            if n:
                sel = self.comp_q[:n] == qi
                k = int(sel.sum())
                row["n_completions"] = k
                if k:
                    row["budget_met"] = float(self.comp_met[:n][sel].mean())
                    slow = self.comp_slow[:n][sel]
                    if not np.isnan(slow).any():
                        row["p95_slowdown"] = float(np.percentile(slow, 95))
            if m:
                sel = self.pl_q[:m] == qi
                if sel.any():
                    row["p95_queue_wait_ms"] = float(
                        np.percentile(self.pl_wait[:m][sel], 95))
            row["alerts_open"] = sum(
                1 for a in self.alerts if a.scope == qname and a.open)
            out[qname] = row
        return out


def monitor_block(monitors: Sequence[Optional[Monitor]]) -> Dict[str, object]:
    """The ``dispatch_stats()["monitor"]`` block, merged over grid
    members.  Integer-only by design: ``repro.exp.run._merge_stats``
    sums these across worker chunks, and integer sums are exact and
    chunking-order-independent — serial and ``--workers`` artifacts gate
    on byte-identical merged blocks."""
    live = [m for m in monitors if m is not None]
    by_kind: Dict[str, int] = {}
    for m in live:
        for name, k in m.alerts_by_kind().items():
            by_kind[name] = by_kind.get(name, 0) + k
    return {
        "enabled": bool(live),
        "members": len(live),
        "samples": int(sum(m.ticks for m in live)),
        "events": int(sum(m.events_seen for m in live)),
        "completions": int(sum(m.completions for m in live)),
        "alerts_total": int(sum(len(m.alerts) for m in live)),
        "alerts_open": int(sum(1 for m in live
                               for a in m.alerts if a.open)),
        "alerts_by_kind": dict(sorted(by_kind.items())),
    }


def merge_monitor_blocks(blocks: Sequence[Dict]) -> Dict[str, object]:
    """Sum monitor blocks across worker chunks (exp harness)."""
    out: Dict[str, object] = {
        "enabled": any(b.get("enabled") for b in blocks),
        "members": 0, "samples": 0, "events": 0, "completions": 0,
        "alerts_total": 0, "alerts_open": 0,
    }
    by_kind: Dict[str, int] = {}
    for b in blocks:
        for key in ("members", "samples", "events", "completions",
                    "alerts_total", "alerts_open"):
            out[key] += int(b.get(key, 0))
        for name, k in b.get("alerts_by_kind", {}).items():
            by_kind[name] = by_kind.get(name, 0) + int(k)
    out["alerts_by_kind"] = dict(sorted(by_kind.items()))
    return out

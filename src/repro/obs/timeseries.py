"""Time-series derivation over the structured event log (``repro.obs``).

Everything here is a pure function of an :class:`~repro.obs.events.EventLog`
(plus optional wid → tenant/QoS maps): fleet size, busy-VM count,
utilization, per-tenant ready-queue depth, cumulative cost vs cumulative
budget, and per-QoS running mean slowdown — each as a :class:`TimeSeries`
step function over the *simulated* clock, sampleable onto any grid with
:func:`sample`.

:func:`peak_and_mean` is the one shared lease-interval reconstruction:
``SimState.finalize`` reports ``peak_vms`` / ``mean_fleet_vms`` through
it (from the pool's lease intervals), and :func:`fleet_series` derives
the same step function from ``VM_PROVISION`` / ``VM_REAP`` events — so
the event log and the end-of-run aggregates can never disagree
(invariant-gated in ``tests/test_obs.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import events as ev_mod
from .events import EventLog


@dataclasses.dataclass
class TimeSeries:
    """Right-continuous step function: value is ``v[i]`` from ``t_ms[i]``
    until ``t_ms[i+1]`` (0 before the first step)."""

    name: str
    t_ms: np.ndarray    # int64, strictly increasing step times
    v: np.ndarray       # float64, value after each step

    def at(self, t: int) -> float:
        i = int(np.searchsorted(self.t_ms, t, side="right")) - 1
        return float(self.v[i]) if i >= 0 else 0.0

    def final(self) -> float:
        return float(self.v[-1]) if len(self.v) else 0.0

    def to_dict(self) -> Dict[str, list]:
        return {"name": self.name, "t_ms": self.t_ms.tolist(),
                "v": self.v.tolist()}


def step_series(name: str, times: Iterable[int],
                deltas: Iterable[float]) -> TimeSeries:
    """Build a step series from (time, delta) impulses: stable-sort by
    time, cumulative-sum, and coalesce impulses sharing a timestamp."""
    t = np.asarray(list(times), np.int64)
    d = np.asarray(list(deltas), np.float64)
    if len(t) == 0:
        return TimeSeries(name, np.zeros(0, np.int64), np.zeros(0))
    order = np.argsort(t, kind="stable")
    t = t[order]
    cum = np.cumsum(d[order])
    # Keep the last cumulative value at each distinct timestamp.
    last = np.append(t[1:] != t[:-1], True)
    return TimeSeries(name, t[last], cum[last])


def peak_and_mean(starts: Iterable[int],
                  ends: Iterable[int]) -> Tuple[int, float]:
    """(peak concurrency, time-weighted mean) of a set of half-open
    lease intervals — the single reconstruction behind
    ``SimResult.peak_vms`` / ``mean_fleet_vms`` *and* the event-derived
    :func:`fleet_series`.  An end tied with a start at the same
    millisecond releases before the start claims (the sort puts -1
    before +1), matching the pre-obs ``SimState._fleet_stats``."""
    deltas: List[Tuple[int, int]] = []
    horizon = 0
    for s, e in zip(starts, ends):
        deltas.append((int(s), 1))
        deltas.append((int(e), -1))
        horizon = max(horizon, int(e))
    if not deltas or horizon <= 0:
        return 0, 0.0
    deltas.sort()
    peak = cur = 0
    area = 0.0   # concurrency-ms integral
    prev = 0
    for t, d in deltas:
        area += cur * (t - prev)
        prev = t
        cur += d
        peak = max(peak, cur)
    return peak, area / horizon


def _kind_times(log: EventLog, kind: int) -> np.ndarray:
    idx = log._order()
    kinds = log.kind[idx]
    return log.t[idx][kinds == kind]


def fleet_series(log: EventLog) -> TimeSeries:
    """Live-VM count over time (``VM_PROVISION`` opens; ``VM_REAP`` or
    ``VM_REVOKE`` closes — a spot revocation terminates the lease just
    as a reap does, so chaos runs stay consistent with the pool's
    interval accounting)."""
    opens = _kind_times(log, ev_mod.VM_PROVISION)
    closes = np.concatenate([_kind_times(log, ev_mod.VM_REAP),
                             _kind_times(log, ev_mod.VM_REVOKE)])
    return step_series(
        "fleet",
        np.concatenate([opens, closes]),
        np.concatenate([np.ones(len(opens)), -np.ones(len(closes))]))


def busy_series(log: EventLog) -> TimeSeries:
    """Busy-VM count over time (one task pipeline occupies one VM:
    ``TASK_START`` claims; ``TASK_FINISH`` or ``TASK_FAIL`` releases,
    and a ``VM_REVOKE`` with the busy flag set releases the attempt it
    killed)."""
    starts = _kind_times(log, ev_mod.TASK_START)
    idx = log._order()
    kinds = log.kind[idx]
    revoked_busy = log.t[idx][(kinds == ev_mod.VM_REVOKE)
                              & (log.d[idx] == 1)]
    ends = np.concatenate([_kind_times(log, ev_mod.TASK_FINISH),
                           _kind_times(log, ev_mod.TASK_FAIL),
                           revoked_busy])
    return step_series(
        "busy",
        np.concatenate([starts, ends]),
        np.concatenate([np.ones(len(starts)), -np.ones(len(ends))]))


def utilization_series(log: EventLog) -> TimeSeries:
    """busy / fleet at every step of either series (0 when no fleet)."""
    fleet = fleet_series(log)
    busy = busy_series(log)
    t = np.union1d(fleet.t_ms, busy.t_ms).astype(np.int64)
    if len(t) == 0:
        return TimeSeries("utilization", t, np.zeros(0))
    f = sample(fleet, t)
    b = sample(busy, t)
    with np.errstate(divide="ignore", invalid="ignore"):
        u = np.where(f > 0, b / np.maximum(f, 1e-12), 0.0)
    return TimeSeries("utilization", t, u)


def queue_depth_series(
    log: EventLog,
    tenant_of: Optional[Dict[int, str]] = None,
) -> Dict[str, TimeSeries]:
    """Ready-queue depth over time (``TASK_READY`` enqueues,
    ``TASK_PLACE`` drains), keyed by tenant when a wid → tenant map is
    given, else a single ``"all"`` series."""
    idx = log._order()
    kinds = log.kind[idx]
    t = log.t[idx]
    wid = log.a[idx]
    ready = kinds == ev_mod.TASK_READY
    placed = kinds == ev_mod.TASK_PLACE
    times = np.concatenate([t[ready], t[placed]])
    deltas = np.concatenate([np.ones(int(ready.sum())),
                             -np.ones(int(placed.sum()))])
    if tenant_of is None:
        return {"all": step_series("queue_depth", times, deltas)}
    wids = np.concatenate([wid[ready], wid[placed]])
    out: Dict[str, TimeSeries] = {}
    for name in sorted(set(tenant_of.values())):
        member = np.array([tenant_of.get(int(w)) == name for w in wids],
                          bool)
        out[name] = step_series(f"queue_depth/{name}",
                                times[member], deltas[member])
    return out


def cumulative_cost_series(log: EventLog) -> TimeSeries:
    """Cumulative actual cost billed: task finishes plus the sunk spend
    of failed attempts and revoked leases (chaos runs)."""
    idx = log._order()
    kinds = log.kind[idx]
    spend = ((kinds == ev_mod.TASK_FINISH) | (kinds == ev_mod.TASK_FAIL)
             | (kinds == ev_mod.VM_REVOKE))
    return step_series("cumulative_cost", log.t[idx][spend],
                       log.x[idx][spend])


def cumulative_budget_series(log: EventLog) -> TimeSeries:
    """Cumulative budget entering the system at workflow arrivals."""
    idx = log._order()
    arr = log.kind[idx] == ev_mod.WF_ARRIVE
    return step_series("cumulative_budget", log.t[idx][arr],
                       log.x[idx][arr])


def slowdown_series(
    log: EventLog,
    ideal_ms: Dict[int, int],
    qos_of_wid: Optional[Dict[int, str]] = None,
) -> Dict[str, TimeSeries]:
    """Running mean workflow slowdown ((finish − arrival) / ideal) at
    each ``WF_DONE``, keyed by QoS class when a wid → QoS map is given
    (else one ``"all"`` series).  Workflows without an ideal runtime are
    skipped."""
    idx = log._order()
    kinds = log.kind[idx]
    t = log.t[idx]
    wid = log.a[idx]
    arrival: Dict[int, int] = {}
    arr = kinds == ev_mod.WF_ARRIVE
    for w, ts in zip(wid[arr], t[arr]):
        arrival[int(w)] = int(ts)
    done = kinds == ev_mod.WF_DONE
    groups: Dict[str, List[Tuple[int, float]]] = {}
    for w, ts in zip(wid[done], t[done]):
        w = int(w)
        ideal = ideal_ms.get(w)
        if not ideal or w not in arrival:
            continue
        sd = (int(ts) - arrival[w]) / ideal
        key = qos_of_wid.get(w, "all") if qos_of_wid else "all"
        groups.setdefault(key, []).append((int(ts), sd))
    out: Dict[str, TimeSeries] = {}
    for key in sorted(groups):
        pts = groups[key]
        times = np.array([p[0] for p in pts], np.int64)
        means = np.cumsum([p[1] for p in pts]) / np.arange(1, len(pts) + 1)
        out[key] = TimeSeries(f"slowdown/{key}", times,
                              np.asarray(means, np.float64))
    return out


def sample(series: TimeSeries, t_grid: np.ndarray) -> np.ndarray:
    """Step-hold sample of a series at each grid time (0 before the
    first step)."""
    t_grid = np.asarray(t_grid, np.int64)
    if len(series.t_ms) == 0:
        return np.zeros(len(t_grid))
    pos = np.searchsorted(series.t_ms, t_grid, side="right") - 1
    vals = np.where(pos >= 0, series.v[np.maximum(pos, 0)], 0.0)
    return vals


def cell_summary(log: EventLog, n_samples: int = 64) -> Dict[str, object]:
    """Compact per-cell time-series digest (the shape
    ``waas.platform.PlatformReport.series`` carries): peak/mean fleet
    via the shared :func:`peak_and_mean` path plus each headline series
    sampled onto a uniform grid over the simulated horizon."""
    fleet = fleet_series(log)
    busy = busy_series(log)
    util = utilization_series(log)
    cost = cumulative_cost_series(log)
    budget = cumulative_budget_series(log)
    horizon = max([int(s.t_ms[-1]) for s in (fleet, busy, cost, budget)
                   if len(s.t_ms)], default=0)
    grid = np.linspace(0, horizon, n_samples).astype(np.int64) \
        if horizon > 0 else np.zeros(0, np.int64)
    opens = _kind_times(log, ev_mod.VM_PROVISION)
    closes = np.concatenate([_kind_times(log, ev_mod.VM_REAP),
                             _kind_times(log, ev_mod.VM_REVOKE)])
    peak, mean = peak_and_mean(opens.tolist(), closes.tolist())
    return {
        "peak_vms": peak,
        "mean_fleet_vms": mean,
        "horizon_ms": horizon,
        "t_ms": grid.tolist(),
        "series": {s.name: sample(s, grid).tolist()
                   for s in (fleet, busy, util, cost, budget)},
    }

"""Deterministic monitor artifacts: ``monitor.json`` + HTML dashboard.

Renders one :class:`repro.obs.monitor.Monitor` into

* ``<label>.monitor.json`` — the machine-readable schema
  (``repro-obs-monitor`` v1) validated by ``tools/check_report.py``;
* ``<label>.dashboard.html`` — a single-file ops dashboard: stat tiles,
  one inline SVG sparkline per sampled series, an alert timeline, and
  the per-QoS SLO table.  No external assets, no scripts, no wall-clock
  or host fields — the bytes are a pure function of (seed, config), so
  dashboards diff clean across engines and checkpoint/resume (gated in
  ``tests/test_monitor.py``).

Float formatting is fixed-precision everywhere (``%.6g`` for values,
``%.2f`` for SVG coordinates) to keep byte-determinism independent of
repr subtleties.
"""
from __future__ import annotations

import os
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import slo as obs_slo
from .export import _dumps
from .monitor import Monitor

MONITOR_SCHEMA = "repro-obs-monitor"
MONITOR_SCHEMA_VERSION = 1
DASHBOARD_MARKER = "<!-- repro-obs-dashboard v1 -->"

# Fixed sparkline palette, assigned to series in export order.
_COLORS = ("#2563eb", "#16a34a", "#d97706", "#dc2626", "#7c3aed",
           "#0891b2", "#be185d", "#4d7c0f", "#b45309", "#1d4ed8",
           "#9333ea", "#0f766e", "#a16207", "#991b1b")


def _num(v: float) -> float:
    """JSON-safe float: NaN/inf → 0 (``_dumps`` forbids non-finite)."""
    f = float(v)
    return f if np.isfinite(f) else 0.0


def _fmt(v: float) -> str:
    """Fixed-precision human value for the dashboard."""
    return f"{_num(v):.6g}"


def monitor_payload(mon: Monitor, label: str = "cell") -> Dict[str, object]:
    """The ``monitor.json`` document (pre-serialization)."""
    cfg = mon.cfg
    series = mon.series()
    t_ms = [int(v) for v in series.pop("t_ms")]
    horizon = (mon.finalized_ms if mon.finalized_ms >= 0
               else (t_ms[-1] if t_ms else 0))
    return {
        "schema": MONITOR_SCHEMA,
        "version": MONITOR_SCHEMA_VERSION,
        "label": label,
        "config": {
            "sample_ms": int(cfg.sample_ms),
            "short_window_ms": int(cfg.short_window_ms),
            "long_window_ms": int(cfg.long_window_ms),
            "burn_fire": _num(cfg.burn_fire),
            "burn_clear": _num(cfg.burn_clear),
            "mad_k": _num(cfg.mad_k),
        },
        "horizon_ms": int(horizon),
        "qos": list(mon.qos_names),
        "samples": {
            "t_ms": t_ms,
            "series": {name: [_num(v) for v in vals]
                       for name, vals in sorted(series.items())},
        },
        "totals": {
            "events": int(mon.events_seen),
            "samples": int(mon.ticks),
            "arrivals": int(mon.arrivals),
            "completions": int(mon.completions),
            "placements": int(mon.placements),
            "failures": int(mon.failures),
            "retries": int(mon.retries),
            "revocations": int(mon.revocations),
            "stragglers": int(mon.stragglers),
            "cost": _num(mon.cost),
            "wasted_cost": _num(mon.wasted),
            "budget": _num(mon.budget),
        },
        "slo": mon.slo_table(),
        "alerts": [a.to_dict() for a in mon.alerts],
        "alerts_by_kind": mon.alerts_by_kind(),
    }


def monitor_json(mon: Monitor, label: str = "cell") -> str:
    return _dumps(monitor_payload(mon, label))


# ---- HTML dashboard --------------------------------------------------------
_CSS = """
body{font-family:ui-monospace,Menlo,Consolas,monospace;background:#0b1020;
color:#dbe2f0;margin:0;padding:24px}
h1{font-size:18px;margin:0 0 4px}h2{font-size:14px;margin:24px 0 8px;
color:#8fa3c8}
.meta{color:#8fa3c8;font-size:12px;margin-bottom:16px}
.tiles{display:flex;flex-wrap:wrap;gap:8px}
.tile{background:#141b33;border:1px solid #24304f;border-radius:6px;
padding:8px 14px;min-width:96px}
.tile .v{font-size:18px;color:#fff}.tile .k{font-size:11px;color:#8fa3c8}
.spark{display:flex;align-items:center;gap:12px;margin:2px 0}
.spark .name{width:200px;font-size:12px;color:#b8c4dd;text-align:right}
.spark .last{width:90px;font-size:12px;color:#8fa3c8}
table{border-collapse:collapse;font-size:12px}
td,th{border:1px solid #24304f;padding:4px 10px;text-align:right}
th{background:#141b33;color:#8fa3c8}td.l,th.l{text-align:left}
.ok{color:#4ade80}.bad{color:#f87171}.open{color:#fbbf24}
svg{display:block}
""".strip()


def _sparkline(t: Sequence[int], v: Sequence[float], color: str,
               width: int = 560, height: int = 36) -> str:
    """Inline SVG sparkline with fixed-precision coordinates."""
    n = len(v)
    if n == 0:
        return f'<svg width="{width}" height="{height}"></svg>'
    t0, t1 = t[0], t[-1]
    span_t = max(t1 - t0, 1)
    lo = min(_num(x) for x in v)
    hi = max(_num(x) for x in v)
    span_v = hi - lo if hi > lo else 1.0
    pts = []
    for i in range(n):
        x = (t[i] - t0) / span_t * (width - 4) + 2
        y = height - 3 - (_num(v[i]) - lo) / span_v * (height - 6)
        pts.append(f"{x:.2f},{y:.2f}")
    return (f'<svg width="{width}" height="{height}" '
            f'viewBox="0 0 {width} {height}">'
            f'<polyline fill="none" stroke="{color}" stroke-width="1.5" '
            f'points="{" ".join(pts)}"/></svg>')


def _alert_timeline(alerts: Sequence[obs_slo.Alert], horizon_ms: int,
                    width: int = 760, row_h: int = 18) -> str:
    """SVG timeline: one bar per alert from fire to clear (open alerts
    extend to the horizon in the open color)."""
    if not alerts:
        return "<p class='meta'>no alerts fired</p>"
    span = max(horizon_ms, 1)
    h = row_h * len(alerts) + 4
    label_w = 240
    rows: List[str] = []
    for i, a in enumerate(alerts):
        name = obs_slo.ALERT_KIND_NAMES.get(a.kind, str(a.kind))
        end = a.cleared_ms if a.cleared_ms >= 0 else horizon_ms
        x0 = label_w + a.fired_ms / span * (width - label_w - 4)
        x1 = label_w + end / span * (width - label_w - 4)
        color = "#f87171" if a.cleared_ms >= 0 else "#fbbf24"
        y = i * row_h + 2
        rows.append(
            f'<text x="2" y="{y + 12}" fill="#b8c4dd" font-size="11">'
            f'{name} [{a.scope}]</text>'
            f'<rect x="{x0:.2f}" y="{y + 3}" '
            f'width="{max(x1 - x0, 2.0):.2f}" height="{row_h - 8}" '
            f'fill="{color}" rx="2"/>')
    return (f'<svg width="{width}" height="{h}" '
            f'viewBox="0 0 {width} {h}">{"".join(rows)}</svg>')


def _tile(key: str, value: str) -> str:
    return (f'<div class="tile"><div class="v">{value}</div>'
            f'<div class="k">{key}</div></div>')


def dashboard_html(mon: Monitor, label: str = "cell") -> str:
    """Render the single-file dashboard (byte-deterministic)."""
    pay = monitor_payload(mon, label)
    tot = pay["totals"]
    horizon = int(pay["horizon_ms"])
    t_ms = pay["samples"]["t_ms"]
    parts: List[str] = [
        "<!DOCTYPE html>", DASHBOARD_MARKER,
        "<html><head><meta charset='utf-8'>",
        f"<title>repro monitor — {label}</title>",
        f"<style>{_CSS}</style></head><body>",
        f"<h1>repro live monitor — {label}</h1>",
        f"<div class='meta'>horizon {horizon / 1000.0:.1f}s · "
        f"sample {mon.cfg.sample_ms}ms · windows "
        f"{mon.cfg.short_window_ms // 1000}s/"
        f"{mon.cfg.long_window_ms // 1000}s · schema "
        f"{MONITOR_SCHEMA} v{MONITOR_SCHEMA_VERSION}</div>",
        "<div class='tiles'>",
        _tile("events", str(tot["events"])),
        _tile("arrivals", str(tot["arrivals"])),
        _tile("completions", str(tot["completions"])),
        _tile("failures", str(tot["failures"])),
        _tile("revocations", str(tot["revocations"])),
        _tile("stragglers", str(tot["stragglers"])),
        _tile("spend", _fmt(tot["cost"])),
        _tile("wasted", _fmt(tot["wasted_cost"])),
        _tile("budget", _fmt(tot["budget"])),
        _tile("alerts", str(len(pay["alerts"]))),
        "</div>",
        "<h2>window series</h2>",
    ]
    for i, (name, vals) in enumerate(sorted(
            pay["samples"]["series"].items())):
        color = _COLORS[i % len(_COLORS)]
        last = _fmt(vals[-1]) if vals else "-"
        parts.append(
            f"<div class='spark'><div class='name'>{name}</div>"
            f"{_sparkline(t_ms, vals, color)}"
            f"<div class='last'>{last}</div></div>")
    parts.append("<h2>alert timeline</h2>")
    parts.append(_alert_timeline(mon.alerts, horizon))
    parts.append("<h2>per-QoS SLO table</h2>")
    parts.append(
        "<table><tr><th class='l'>qos</th><th>n</th>"
        "<th>budget-met</th><th>target</th><th>p95 slowdown</th>"
        "<th>ceiling</th><th>p95 wait (s)</th><th>target (s)</th>"
        "<th>status</th></tr>")
    for qname, row in pay["slo"].items():
        met_ok = row["budget_met"] >= row["target_budget_met"]
        status = ("<span class='open'>ALERT</span>"
                  if row["alerts_open"]
                  else ("<span class='ok'>OK</span>" if met_ok
                        else "<span class='bad'>MISS</span>"))
        parts.append(
            f"<tr><td class='l'>{qname}</td>"
            f"<td>{row['n_completions']}</td>"
            f"<td>{_fmt(row['budget_met'])}</td>"
            f"<td>{_fmt(row['target_budget_met'])}</td>"
            f"<td>{_fmt(row['p95_slowdown'])}</td>"
            f"<td>{_fmt(row['target_p95_slowdown'])}</td>"
            f"<td>{_fmt(row['p95_queue_wait_ms'] / 1000.0)}</td>"
            f"<td>{_fmt(row['target_queue_wait_ms'] / 1000.0)}</td>"
            f"<td>{status}</td></tr>")
    parts.append("</table>")
    if pay["alerts"]:
        parts.append("<h2>alerts</h2>")
        parts.append(
            "<table><tr><th class='l'>kind</th><th class='l'>scope</th>"
            "<th>fired (s)</th><th>cleared (s)</th><th>value</th>"
            "<th>threshold</th></tr>")
        for a in pay["alerts"]:
            cleared = (_fmt(a["cleared_ms"] / 1000.0)
                       if a["cleared_ms"] >= 0 else "open")
            parts.append(
                f"<tr><td class='l'>{a['kind']}</td>"
                f"<td class='l'>{a['scope']}</td>"
                f"<td>{_fmt(a['fired_ms'] / 1000.0)}</td>"
                f"<td>{cleared}</td><td>{_fmt(a['value'])}</td>"
                f"<td>{_fmt(a['threshold'])}</td></tr>")
        parts.append("</table>")
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"


def write_cell_report(report_dir: str, label: str, mon: Monitor) -> Tuple[str, str]:
    """Write ``<label>.monitor.json`` + ``<label>.dashboard.html`` into
    ``report_dir`` (created if missing).  Returns the two paths."""
    os.makedirs(report_dir, exist_ok=True)
    jpath = os.path.join(report_dir, f"{label}.monitor.json")
    hpath = os.path.join(report_dir, f"{label}.dashboard.html")
    with open(jpath, "w") as fh:
        fh.write(monitor_json(mon, label) + "\n")
    with open(hpath, "w") as fh:
        fh.write(dashboard_html(mon, label))
    return jpath, hpath

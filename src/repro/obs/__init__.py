"""repro.obs — structured simulation tracing and time-series metrics.

Three layers, all pure over the event log:

* :mod:`repro.obs.events` — the typed, numpy-columned event bus the
  engines emit into (off by default; ``REPRO_TRACE=1`` or ``events=``
  opts in);
* :mod:`repro.obs.timeseries` — sampled-over-simulated-time series
  (fleet, utilization, queue depth, cost vs budget, slowdown) and the
  shared lease-interval ``peak_and_mean`` reconstruction;
* :mod:`repro.obs.export` — deterministic Chrome-trace/Perfetto JSON
  and versioned JSONL dumps (``repro.exp.run --trace-dir``).

Schema documentation: docs/PROFILING.md § Event schema.
"""
from .events import (EVENT_SCHEMA_VERSION, EventLog, events_block,
                     resolve_events)
from .export import chrome_trace, events_jsonl, write_cell_trace
from .timeseries import (TimeSeries, cell_summary, peak_and_mean,
                         sample, step_series)

__all__ = [
    "EVENT_SCHEMA_VERSION", "EventLog", "events_block", "resolve_events",
    "chrome_trace", "events_jsonl", "write_cell_trace",
    "TimeSeries", "cell_summary", "peak_and_mean", "sample", "step_series",
]

"""repro.obs — structured simulation tracing, time-series metrics and
the live SLO monitor.

Five layers, all pure over the event log:

* :mod:`repro.obs.events` — the typed, numpy-columned event bus the
  engines emit into (off by default; ``REPRO_TRACE=1`` or ``events=``
  opts in), with an optional streaming subscriber hook (``elog.sub``);
* :mod:`repro.obs.timeseries` — post-hoc sampled-over-simulated-time
  series (fleet, utilization, queue depth, cost vs budget, slowdown)
  and the shared lease-interval ``peak_and_mean`` reconstruction;
* :mod:`repro.obs.monitor` — the *online* counterpart: rolling-window
  aggregates in flat numpy ring buffers folded incrementally on the
  emit path (``REPRO_MONITOR=1`` or ``monitor=`` opts in);
* :mod:`repro.obs.slo` — per-QoS SLO targets, multi-window burn rates,
  threshold+MAD anomaly detectors and typed alert records;
* :mod:`repro.obs.export` / :mod:`repro.obs.report` — deterministic
  Chrome-trace/JSONL dumps (``--trace-dir``) and the per-cell
  ``monitor.json`` + single-file HTML dashboard (``--report-dir``).

Schema documentation: docs/PROFILING.md § Event schema and § Live SLO
monitor.
"""
from .events import (EVENT_SCHEMA_VERSION, EventLog, events_block,
                     resolve_events)
from .export import chrome_trace, events_jsonl, write_cell_trace
from .monitor import (Monitor, MonitorConfig, monitor_block,
                      resolve_monitor)
from .report import (MONITOR_SCHEMA, MONITOR_SCHEMA_VERSION, dashboard_html,
                     monitor_json, monitor_payload, write_cell_report)
from .slo import (ALERT_KIND_NAMES, Alert, AlertGate, SLOTarget, burn_rate,
                  mad_fire)
from .timeseries import (TimeSeries, cell_summary, peak_and_mean,
                         sample, step_series)

__all__ = [
    "EVENT_SCHEMA_VERSION", "EventLog", "events_block", "resolve_events",
    "chrome_trace", "events_jsonl", "write_cell_trace",
    "Monitor", "MonitorConfig", "monitor_block", "resolve_monitor",
    "MONITOR_SCHEMA", "MONITOR_SCHEMA_VERSION", "dashboard_html",
    "monitor_json", "monitor_payload", "write_cell_report",
    "ALERT_KIND_NAMES", "Alert", "AlertGate", "SLOTarget", "burn_rate",
    "mad_fire",
    "TimeSeries", "cell_summary", "peak_and_mean", "sample", "step_series",
]

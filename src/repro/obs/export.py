"""Deterministic trace export (``repro.obs``).

Turns an :class:`~repro.obs.events.EventLog` into:

* **Chrome-trace / Perfetto JSON** (:func:`chrome_trace`) — loadable in
  ``ui.perfetto.dev`` or ``chrome://tracing``.  One thread track per VM
  (named ``vm<id> (<type>)``), one complete-slice (``ph: "X"``) per task
  pipeline colored by tenant (or QoS) category, and counter tracks
  (``ph: "C"``) for the headline ``obs.timeseries`` series: fleet size,
  busy VMs, ready-queue depth, cumulative cost and cumulative budget.
* a **JSONL event dump** (:func:`events_jsonl`) — one header line
  carrying the versioned schema (``EVENT_SCHEMA_VERSION``), then one
  line per event with the named fields from ``events.SCHEMA``.

Both are **byte-deterministic** in the event log: keys sorted, compact
separators, no wall-clock or host fields — the same cell + seed produces
identical bytes across runs, state layouts (SoA vs object) and
checkpoint/resume cuts (gated in ``tests/test_obs.py``, validated by
``tools/check_trace.py``).  Simulated milliseconds map to trace
microseconds (Chrome's ``ts`` unit) as ``ts = t_ms * 1000``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

from . import events as ev_mod
from . import timeseries as ts_mod
from .events import EventLog

TRACE_SCHEMA = "repro-obs-trace"
EVENTS_SCHEMA = "repro-obs-events"

# Chrome-trace reserved color names, assigned to tenants/QoS classes by
# sorted order — stable across runs for a fixed tenant set.
_PALETTE = (
    "thread_state_running", "rail_response", "rail_animation",
    "rail_idle", "rail_load", "cq_build_passed", "cq_build_attempt_runnig",
    "startup", "good", "bad", "terrible", "generic_work",
)


def _dumps(obj: object) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def chrome_trace(
    log: EventLog,
    label: str = "sim",
    vm_type_names: Sequence[str] = (),
    tenant_of: Optional[Dict[int, str]] = None,
    qos_of: Optional[Dict[str, str]] = None,
) -> Dict[str, object]:
    """Build the Chrome-trace JSON object (pure; see module docstring).

    ``tenant_of``: wid → tenant name (slice category + color);
    ``qos_of``: tenant name → QoS class (slice args).  Without maps,
    slices are categorized by workflow id.
    """
    rows = list(log.rows())
    trace_events: List[Dict[str, object]] = []
    # -- track metadata: one named thread per VM -----------------------------
    trace_events.append({
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": label},
    })
    vm_types: Dict[int, int] = {}
    for r in rows:
        if r["kind"] == "vm_provision":
            vm_types[r["vmid"]] = r["vmt"]
    for vmid in sorted(vm_types):
        vmt = vm_types[vmid]
        tname = (vm_type_names[vmt]
                 if 0 <= vmt < len(vm_type_names) else f"type{vmt}")
        trace_events.append({
            "ph": "M", "pid": 0, "tid": vmid + 1, "name": "thread_name",
            "args": {"name": f"vm{vmid} ({tname})"},
        })
        trace_events.append({
            "ph": "M", "pid": 0, "tid": vmid + 1,
            "name": "thread_sort_index", "args": {"sort_index": vmid},
        })
    # -- task slices: pair TASK_START with TASK_FINISH -----------------------
    tenants = sorted(set(tenant_of.values())) if tenant_of else []
    color_of = {t: _PALETTE[i % len(_PALETTE)]
                for i, t in enumerate(tenants)}
    tier_of: Dict[tuple, Dict[str, object]] = {}
    open_slices: Dict[tuple, Dict[str, object]] = {}
    for r in rows:
        kind = r["kind"]
        if kind == "task_place":
            tier_of[(r["wid"], r["tid"])] = r
        elif kind == "task_start":
            open_slices[(r["wid"], r["tid"])] = r
        elif kind == "task_finish":
            start = open_slices.pop((r["wid"], r["tid"]), None)
            if start is None:
                continue
            wid, tid = r["wid"], r["tid"]
            tenant = tenant_of.get(wid) if tenant_of else None
            place = tier_of.get((wid, tid), {})
            args: Dict[str, object] = {
                "wid": wid, "tid": tid, "warmth": start["warmth"],
                "cost": r["cost"], "input_mb": start["total_mb"],
                "staged_mb": start["missing_mb"],
            }
            if "tier" in place:
                args["tier"] = place["tier"]
                args["est_cost"] = place["est_cost"]
            if tenant is not None:
                args["tenant"] = tenant
                if qos_of and tenant in qos_of:
                    args["qos"] = qos_of[tenant]
            slice_ev: Dict[str, object] = {
                "ph": "X", "pid": 0, "tid": r["vmid"] + 1,
                "ts": start["t_ms"] * 1000,
                "dur": (r["t_ms"] - start["t_ms"]) * 1000,
                "name": f"w{wid}/t{tid}",
                "cat": tenant if tenant is not None else f"w{wid}",
                "args": args,
            }
            if tenant is not None:
                slice_ev["cname"] = color_of[tenant]
            trace_events.append(slice_ev)
    # -- counter tracks from the time-series API -----------------------------
    counters = [ts_mod.fleet_series(log), ts_mod.busy_series(log),
                ts_mod.cumulative_cost_series(log),
                ts_mod.cumulative_budget_series(log)]
    counters += ts_mod.queue_depth_series(log, tenant_of).values()
    for series in counters:
        for t, v in zip(series.t_ms.tolist(), series.v.tolist()):
            trace_events.append({
                "ph": "C", "pid": 0, "tid": 0, "ts": int(t) * 1000,
                "name": series.name, "args": {"value": float(v)},
            })
    return {
        "displayTimeUnit": "ms",
        "metadata": {"schema": TRACE_SCHEMA,
                     "version": ev_mod.EVENT_SCHEMA_VERSION,
                     "label": label},
        "traceEvents": trace_events,
    }


def events_jsonl(log: EventLog, label: str = "sim") -> str:
    """The versioned JSONL dump: header line + one line per event."""
    lines = [_dumps({
        "schema": EVENTS_SCHEMA,
        "version": ev_mod.EVENT_SCHEMA_VERSION,
        "label": label,
        "n_events": len(log),
        "dropped": log.dropped,
    })]
    lines.extend(_dumps(row) for row in log.rows())
    return "\n".join(lines) + "\n"


def write_cell_trace(
    trace_dir: str,
    label: str,
    log: EventLog,
    vm_type_names: Sequence[str] = (),
    tenant_of: Optional[Dict[int, str]] = None,
    qos_of: Optional[Dict[str, str]] = None,
    jsonl: bool = True,
) -> List[str]:
    """Write ``<label>.trace.json`` (+ ``<label>.events.jsonl``) under
    ``trace_dir``; returns the written paths.  The label doubles as the
    filename stem, so callers keep it filesystem-safe and unique per
    (cell, policy)."""
    os.makedirs(trace_dir, exist_ok=True)
    trace = chrome_trace(log, label=label, vm_type_names=vm_type_names,
                         tenant_of=tenant_of, qos_of=qos_of)
    paths = []
    tpath = os.path.join(trace_dir, f"{label}.trace.json")
    with open(tpath, "w") as f:
        f.write(_dumps(trace) + "\n")
    paths.append(tpath)
    if jsonl:
        jpath = os.path.join(trace_dir, f"{label}.events.jsonl")
        with open(jpath, "w") as f:
            f.write(events_jsonl(log, label=label))
        paths.append(jpath)
    return paths

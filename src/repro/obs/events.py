"""Structured simulation event bus (``repro.obs``).

A :class:`EventLog` is a numpy-columned append buffer for *typed*
simulation events: every event is one row of fixed numeric columns —
``t_ms`` (simulated clock), ``kind`` (one of the ``WF_*`` / ``TASK_*`` /
``VM_*`` / ``BUDGET_*`` / ``GRID_*`` constants) and six payload columns
(``a b c d`` int64, ``x y`` float64) whose per-kind meaning is declared
once in :data:`SCHEMA`.  The engines (``core.engine.SimState``,
``core.jax_engine.BatchSimEngine``) emit into it from every state
transition; ``obs.timeseries`` derives sampled-over-simulated-time
series from it and ``obs.export`` turns it into Chrome-trace/Perfetto
JSON and a versioned JSONL dump.

Cost model: **off by default and zero-cost when disabled** — the hot
paths hold a local ``ev = self.elog`` and guard every emission with a
single ``is not None`` test, exactly like the ``REPRO_PROFILE``
counters.  When enabled, an append is a handful of scalar array stores
(no tuples, no dicts, no Python objects per event).  ``REPRO_TRACE=1``
is the ambient opt-in (the env analogue of the ``events=`` kwarg), and
``capacity=`` turns the buffer into a ring that keeps the last N events
(``dropped`` counts the overwritten prefix) for long-horizon streams.

Events are simulation state: :meth:`EventLog.__getstate__` makes the log
pickle cleanly, so checkpointed streams (``SimState.snapshot``) carry
their event history and a resumed run exports **byte-identical** traces
(gated in ``tests/test_obs.py``).
"""
from __future__ import annotations

import os as _os
from typing import Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

# Versioned wire schema for the JSONL dump (obs.export) and the trace
# validator (tools/check_trace.py).  Bump on any change to the kind set
# or a kind's field mapping.
# v2: chaos kinds 18-21 (VM_REVOKE / TASK_FAIL / TASK_RETRY /
#     STRAGGLER_DETECT) — see repro.chaos.
EVENT_SCHEMA_VERSION = 2

# ---- event kinds -----------------------------------------------------------
WF_ARRIVE = 1            # workflow arrival enters the system
WF_DONE = 2              # last task of a workflow finished
TASK_READY = 3           # task entered the ready queue
TASK_PLACE = 4           # scheduler committed a placement decision
TASK_START = 5           # execution pipeline started on a VM
TASK_FINISH = 6          # task finished (actual cost billed)
VM_PROVISION = 7         # VM lease opened (provisioning begins)
VM_READY = 8             # provisioning delay elapsed
VM_BUSY = 9              # VM taken by a task pipeline
VM_IDLE = 10             # VM returned to the idle pool
VM_CONTAINER = 11        # container activation that cost time (init/cold)
VM_REAP = 12             # VM lease closed (terminate)
BUDGET_DISTRIBUTE = 13   # Algorithm 1 / MSLBL arrival-time distribution
BUDGET_REDISTRIBUTE = 14  # Algorithm 3 redistribution (either mode)
BUDGET_SPARE = 15        # spare-pool movement (MSLBL spend, round banking)
GRID_ROUND = 16          # grid-driver rendezvous round
GRID_AUCTION = 17        # batched auction call within a round
VM_REVOKE = 18           # spot lease revoked (repro.chaos)
TASK_FAIL = 19           # execution attempt failed (spend sunk)
TASK_RETRY = 20          # failed/preempted task re-entered the queue
STRAGGLER_DETECT = 21    # finish whose compute time tripped the detector

KIND_NAMES: Dict[int, str] = {
    WF_ARRIVE: "wf_arrive",
    WF_DONE: "wf_done",
    TASK_READY: "task_ready",
    TASK_PLACE: "task_place",
    TASK_START: "task_start",
    TASK_FINISH: "task_finish",
    VM_PROVISION: "vm_provision",
    VM_READY: "vm_ready",
    VM_BUSY: "vm_busy",
    VM_IDLE: "vm_idle",
    VM_CONTAINER: "vm_container",
    VM_REAP: "vm_reap",
    BUDGET_DISTRIBUTE: "budget_distribute",
    BUDGET_REDISTRIBUTE: "budget_redistribute",
    BUDGET_SPARE: "budget_spare",
    GRID_ROUND: "grid_round",
    GRID_AUCTION: "grid_auction",
    VM_REVOKE: "vm_revoke",
    TASK_FAIL: "task_fail",
    TASK_RETRY: "task_retry",
    STRAGGLER_DETECT: "straggler_detect",
}

# Per-kind payload declaration: (json_field_name, column) in column order.
# Columns: a b c d are int64, x y are float64.  Documented prose-side in
# docs/PROFILING.md § Event schema.
SCHEMA: Dict[int, tuple] = {
    WF_ARRIVE: (("wid", "a"), ("n_tasks", "b"), ("budget", "x")),
    WF_DONE: (("wid", "a"), ("cost", "x"), ("budget", "y")),
    TASK_READY: (("wid", "a"), ("tid", "b")),
    TASK_PLACE: (("wid", "a"), ("tid", "b"), ("vmid", "c"), ("tier", "d"),
                 ("est_cost", "x")),
    TASK_START: (("wid", "a"), ("tid", "b"), ("vmid", "c"), ("warmth", "d"),
                 ("missing_mb", "x"), ("total_mb", "y")),
    TASK_FINISH: (("wid", "a"), ("tid", "b"), ("vmid", "c"), ("cost", "x")),
    VM_PROVISION: (("vmid", "a"), ("vmt", "b")),
    VM_READY: (("vmid", "a"),),
    VM_BUSY: (("vmid", "a"),),
    VM_IDLE: (("vmid", "a"),),
    VM_CONTAINER: (("vmid", "a"), ("warmth", "b")),
    VM_REAP: (("vmid", "a"), ("finalized", "b")),
    BUDGET_DISTRIBUTE: (("wid", "a"), ("mode", "b"), ("spare", "x")),
    BUDGET_REDISTRIBUTE: (("wid", "a"), ("tid", "b"), ("events", "c"),
                          ("surplus", "x"), ("spare", "y")),
    BUDGET_SPARE: (("wid", "a"), ("tid", "b"), ("delta", "x"),
                   ("spare", "y")),
    GRID_ROUND: (("round", "a"), ("parked", "b"), ("ridden", "c"),
                 ("pairs", "d")),
    GRID_AUCTION: (("round", "a"), ("requests", "b"), ("pairs", "d")),
    # Chaos kinds (repro.chaos): wid/tid are -1 on VM_REVOKE when the VM
    # carried no task; ``busy`` is 1 when a pipeline was killed mid-run.
    VM_REVOKE: (("vmid", "a"), ("wid", "b"), ("tid", "c"), ("busy", "d"),
                ("wasted", "x")),
    TASK_FAIL: (("wid", "a"), ("tid", "b"), ("vmid", "c"), ("attempt", "d"),
                ("wasted", "x")),
    TASK_RETRY: (("wid", "a"), ("tid", "b"), ("attempt", "c"),
                 ("preemptions", "d")),
    STRAGGLER_DETECT: (("wid", "a"), ("tid", "b"), ("vmid", "c"),
                       ("rt_ms", "d"), ("ratio", "x")),
}

# Container-warmth codes shared by TASK_START / VM_CONTAINER (matches the
# SimState counter classification; -1 = containers disabled).
WARMTH_NONE, WARMTH_WARM, WARMTH_INIT, WARMTH_COLD = -1, 0, 1, 2


def _trace_enabled() -> bool:
    """Ambient opt-in (``REPRO_TRACE=1``) — the env default the
    ``events=`` kwargs resolve against, read per engine construction so
    tests can monkeypatch it."""
    return _os.environ.get("REPRO_TRACE") == "1"


_COLS = ("t", "kind", "a", "b", "c", "d", "x", "y")
_INT_COLS = ("t", "kind", "a", "b", "c", "d")


class EventLog:
    """Append-only (optionally ring) numpy-columned event buffer."""

    __slots__ = ("t", "kind", "a", "b", "c", "d", "x", "y",
                 "total", "capacity", "_cap", "sub")

    def __init__(self, capacity: Optional[int] = None):
        """``capacity=None`` (default) grows geometrically and keeps
        everything; ``capacity=N`` keeps only the most recent N events
        (ring), counting the overwritten prefix in :attr:`dropped`."""
        if capacity is not None and capacity <= 0:
            raise ValueError(f"capacity={capacity} (expected > 0 or None)")
        cap = capacity if capacity is not None else 1024
        for name in _INT_COLS:
            setattr(self, name, np.zeros(cap, np.int64))
        self.x = np.zeros(cap, np.float64)
        self.y = np.zeros(cap, np.float64)
        self.total = 0
        self.capacity = capacity
        self._cap = cap
        # Optional streaming subscriber (repro.obs.monitor.Monitor): an
        # object with on_event(kind, t, a, b, c, d, x, y), invoked on
        # every append *before* ring overwrite can lose the record.  A
        # single is-None check on the hot path keeps the zero-cost
        # discipline when no monitor is attached.
        self.sub = None

    # -- hot path ------------------------------------------------------------
    def append(self, kind: int, t_ms: int, a: int = 0, b: int = 0,
               c: int = 0, d: int = 0, x: float = 0.0,
               y: float = 0.0) -> None:
        i = self.total
        if self.capacity is None:
            if i == self._cap:
                self._grow()
            j = i
        else:
            j = i % self.capacity
        self.t[j] = t_ms
        self.kind[j] = kind
        self.a[j] = a
        self.b[j] = b
        self.c[j] = c
        self.d[j] = d
        self.x[j] = x
        self.y[j] = y
        self.total = i + 1
        sub = self.sub
        if sub is not None:
            sub.on_event(kind, t_ms, a, b, c, d, x, y)

    def _grow(self) -> None:
        new_cap = self._cap * 2
        for name in _COLS:
            arr = getattr(self, name)
            grown = np.zeros(new_cap, arr.dtype)
            grown[:self._cap] = arr
            setattr(self, name, grown)
        self._cap = new_cap

    # -- views ---------------------------------------------------------------
    def __len__(self) -> int:
        """Events currently stored (≤ :attr:`total` for rings)."""
        if self.capacity is None:
            return self.total
        return min(self.total, self.capacity)

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wrap-around (0 for unbounded logs)."""
        if self.capacity is None:
            return 0
        return max(0, self.total - self.capacity)

    def _order(self) -> Union[slice, np.ndarray]:
        n = len(self)
        if self.capacity is None or self.total <= self.capacity:
            return slice(0, n)
        head = self.total % self.capacity
        return np.concatenate([np.arange(head, self.capacity),
                               np.arange(0, head)])

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Chronological copies of the stored columns."""
        idx = self._order()
        return {name: getattr(self, name)[idx].copy() for name in _COLS}

    def counts(self) -> Dict[str, int]:
        """Stored events per kind name (unknown kinds keyed by number)."""
        kinds = self.kind[self._order()]
        out: Dict[str, int] = {}
        if len(kinds) == 0:
            return out
        for k, n in zip(*np.unique(kinds, return_counts=True)):
            out[KIND_NAMES.get(int(k), str(int(k)))] = int(n)
        return out

    def rows(self) -> Iterator[Dict[str, object]]:
        """Stored events as named-field dicts, chronological order
        (the JSONL dump shape; ints/floats narrowed to Python scalars)."""
        arrays = self.to_arrays()
        kind_col = arrays["kind"]
        for i in range(len(kind_col)):
            k = int(kind_col[i])
            row: Dict[str, object] = {
                "kind": KIND_NAMES.get(k, str(k)),
                "t_ms": int(arrays["t"][i]),
            }
            for field, col in SCHEMA.get(k, ()):
                v = arrays[col][i]
                row[field] = float(v) if col in ("x", "y") else int(v)
            yield row

    # -- pickling (numpy slots) ---------------------------------------------
    def __getstate__(self) -> Dict[str, object]:
        state = {name: getattr(self, name) for name in _COLS}
        state["total"] = self.total
        state["capacity"] = self.capacity
        state["_cap"] = self._cap
        state["sub"] = self.sub
        return state

    def __setstate__(self, state: Dict[str, object]) -> None:
        # Pre-subscriber pickles (stream snapshots v2 from PR 8) carry
        # no "sub" key; default it so restored logs stay well-formed.
        self.sub = None
        for name, v in state.items():
            setattr(self, name, v)


def resolve_events(
    events: Union[None, bool, EventLog],
) -> Optional[EventLog]:
    """Normalize an ``events=`` kwarg: ``None`` defers to ``REPRO_TRACE``,
    booleans toggle a fresh log, an :class:`EventLog` passes through."""
    if isinstance(events, EventLog):
        return events
    if events is None:
        events = _trace_enabled()
    return EventLog() if events else None


def events_block(logs: Sequence[Optional[EventLog]]) -> Dict[str, object]:
    """The ``dispatch_stats()["events"]`` payload: per-kind counts summed
    over a collection of logs (grid members + the driver log).  ``total``
    counts *emitted* events; ``by_kind``/``dropped`` reflect what rings
    still hold."""
    live: List[EventLog] = [log for log in logs if log is not None]
    by_kind: Dict[str, int] = {}
    total = dropped = 0
    for log in live:
        for name, n in log.counts().items():
            by_kind[name] = by_kind.get(name, 0) + n
        total += log.total
        dropped += log.dropped
    return {"enabled": bool(live), "total": total,
            "by_kind": dict(sorted(by_kind.items())), "dropped": dropped}

"""Sharding assembly: glue between ParamSpec logical axes, the mesh, and
jit in/out shardings for the train / prefill / decode entry points.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import (LONG_RULES, SERVE_RULES, TRAIN_RULES,
                             logical_to_pspec, param_pspecs)
from ..models.registry import Model

PyTree = Any


def rules_for(kind: str, long_context: bool = False) -> Dict[str, Optional[str]]:
    if kind == "train":
        return TRAIN_RULES
    return LONG_RULES if long_context else SERVE_RULES


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def named(mesh: Mesh, pspec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        pspec_tree, is_leaf=lambda x: isinstance(x, P))


def model_param_shardings(model: Model, mesh: Mesh, kind: str = "train",
                          long_context: bool = False) -> PyTree:
    rules = rules_for(kind, long_context)
    pspecs = param_pspecs(model.specs(), rules, mesh.axis_names,
                          mesh_axis_sizes(mesh))
    return named(mesh, pspecs)


def batch_shardings(model: Model, mesh: Mesh, shape_name: str,
                    kind: str = "train", long_context: bool = False) -> Dict:
    rules = rules_for(kind, long_context)
    sizes = mesh_axis_sizes(mesh)
    multi_pod = "pod" in mesh.axis_names
    axes = model.input_axes(shape_name)
    specs = model.input_specs(shape_name)
    out = {}
    for k, a in axes.items():
        # the batch axis spans (pod, data) on multi-pod meshes
        a = tuple(("pod_batch" if (x == "batch" and multi_pod) else x)
                  for x in a)
        out[k] = NamedSharding(mesh, logical_to_pspec(
            a, rules, mesh.axis_names, specs[k].shape, sizes))
    return out


def state_shardings(model: Model, mesh: Mesh, shape_name: str,
                    long_context: bool = False) -> Optional[Dict]:
    sspecs = model.state_specs(shape_name)
    if sspecs is None:
        return None
    rules = rules_for("serve", long_context)
    sizes = mesh_axis_sizes(mesh)
    multi_pod = "pod" in mesh.axis_names
    axes = model.state_axes()
    tp = sizes.get("model", 1)
    out = {}
    for k, sds in sspecs.items():
        a = axes[k]
        a = tuple(("pod_batch" if (x == "batch" and multi_pod) else x)
                  for x in a)
        if k in ("k", "v") and not long_context:
            # KV cache: prefer sharding kv heads over 'model'; when the
            # head count doesn't divide TP, shard the cache *sequence*
            # over 'model' instead (keeps per-device cache ≤ HBM for the
            # 32k decode cells of 8-KV-head archs).
            if model.cfg.n_kv_heads % tp != 0:
                a = tuple(("seq_model" if x == "seq" else x) for x in a)
                rules = dict(rules)
                rules["seq_model"] = "model"
        out[k] = NamedSharding(mesh, logical_to_pspec(
            a, rules, mesh.axis_names, sds.shape, sizes))
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def round_buffer_placement(mesh: Optional[Mesh] = None):
    """Mesh placement for the batched-round ``[B, T, V]`` pair buffers
    (``core.jax_cycles._RoundBuffers``).

    Stubbed seam: today the round buffers are host numpy staged per
    call, so the only meaningful placement is fully replicated — member
    rows are independent, and splitting B across a mesh axis is the TPU
    tuning item the ROADMAP defers.  ``core.jax_cycles`` consumes this
    lazily via ``set_round_buffer_mesh`` so this module's model imports
    stay off the simulation hot path.  Returns ``None`` (host staging)
    when no mesh is given.
    """
    if mesh is None:
        return None
    return replicated(mesh)

"""Distributed-optimization collectives (beyond-paper §Perf features).

- ``quantized_psum``: int8 all-reduce with per-tensor scale and error
  feedback — cuts the gradient-collective roofline term ~4× for
  DP/pod-level reductions at the cost of a quantization residual carried
  in the optimizer loop.
- ``seq_sharded_decode_attention``: long-context decode attention with the
  KV cache sharded by *sequence* over 'data'; each shard computes partial
  (max, sumexp, weighted-V) statistics and the exact softmax is
  reconstructed with a log-sum-exp combine — one tiny all-gather of
  [B, H, 2] stats + psum of [B, H, D] instead of gathering the full cache.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Quantized gradient all-reduce (error feedback)
# ---------------------------------------------------------------------------


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantized_psum(x: jnp.ndarray, axis_name: str,
                   residual: jnp.ndarray | None = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce mean of ``x`` over ``axis_name`` in int8.

    Returns (mean, new_residual).  Call under shard_map.  The residual
    (local quantization error) is added back into the next step's input —
    standard error-feedback so the bias does not accumulate.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    q, scale = quantize_int8(xf)
    new_residual = xf - dequantize_int8(q, scale)
    # int8 payload summed in int32 to avoid overflow; scales averaged.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # scale differs per shard → psum the dequantized correction term.
    # Single-scale approximation: use the max scale across shards.
    smax = jax.lax.pmax(scale, axis_name)
    mean = total.astype(jnp.float32) * smax / n
    return mean.astype(x.dtype), new_residual


# ---------------------------------------------------------------------------
# Sequence-sharded decode attention (LSE combine)
# ---------------------------------------------------------------------------


def _partial_attn(q, k, v, valid):
    """q: [B,H,D]; k,v: [B,S,H,D]; valid: [B,S] → partial stats."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)                                   # [B,H]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                   # [B,H]
    o = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))  # unnormalized
    return m, l, o


def seq_sharded_decode_attention(q, k_shard, v_shard, valid_shard,
                                 axis_name: str):
    """Exact distributed decode attention over a sequence-sharded cache.

    q: [B,H,D] (replicated); k/v_shard: [B,S_loc,H,D]; valid: [B,S_loc].
    Under shard_map with the cache's seq dim split over ``axis_name``.
    """
    m, l, o = _partial_attn(q, k_shard, v_shard, valid_shard)
    g = jax.lax.pmax(m, axis_name)                            # global max
    corr = jnp.exp(m - g)
    l_g = jax.lax.psum(l * corr, axis_name)
    o_g = jax.lax.psum(o * corr[..., None], axis_name)
    return (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)

"""parallel substrate."""

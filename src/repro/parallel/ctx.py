"""Trace-time sharding context.

Model code is mesh-agnostic: it calls ``constrain(x, logical_axes)`` on
hot intermediates (the residual stream, MoE buffers).  The step builders
enter a :func:`scope` *inside* the traced function, so the constraints
bind to the active mesh + rule set during tracing and no-op otherwise
(single-device tests, oracle runs).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import logical_to_pspec

_state = threading.local()


def current() -> Optional[tuple]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def scope(mesh: Mesh, rules: Dict[str, Optional[str]]):
    prev = current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint(x, axes→rules→mesh) if in scope."""
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ps = logical_to_pspec(axes, rules, mesh.axis_names, x.shape, sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))

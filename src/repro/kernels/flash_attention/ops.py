"""jit-ready wrapper for flash attention; [B, L, H, D] layout like layers.py.

On CPU (this container) the kernel runs in interpret mode; on TPU set
``interpret=False`` (the default flips automatically on TPU backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhld


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q,k,v: [B, L, H, D] → [B, Lq, H, D]."""
    if interpret is None:
        interpret = _default_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention_bhld(qt, kt, vt, causal=causal, bq=bq, bk=bk,
                             interpret=interpret)
    return jnp.swapaxes(o, 1, 2)

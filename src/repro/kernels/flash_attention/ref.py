"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q,k,v: [B, L, H, D] (Lk may differ from Lq).  fp32 softmax."""
    D = q.shape[-1]
    Lq, Lk = q.shape[1], k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(Lq)[:, None] + (Lk - Lq)
        ki = jnp.arange(Lk)[None, :]
        logits = jnp.where((qi >= ki)[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)

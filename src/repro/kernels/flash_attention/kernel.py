"""Flash attention as a Pallas TPU kernel.

Online-softmax tiling: grid = (B, H, num_q_blocks, num_k_blocks); the last
grid axis is sequential on TPU, so the output block for a given (b, h, i)
is *revisited* across k-blocks and serves as the VMEM accumulator.  Running
max ``m`` and normalizer ``l`` live in two small side outputs revisited the
same way.  Block shapes are MXU-aligned (multiples of 128 on the q/k tile
dims); the D (head) dim rides along whole.

VMEM budget per grid step ≈ (bq·D + bk·D·2 + bq·bk + bq·D) · 4B fp32;
with bq = bk = 128, D ≤ 256 that is < 1 MB — far under the ~16 MB/core
VMEM of TPU v5e, leaving room for double-buffered pipelining.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
               causal: bool, scale: float, bq: int, bk: int, lk: int,
               lq_orig: int, lk_orig: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = (q @ k.T) * scale                        # [bq, bk]
    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ki = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = ki < lk_orig                           # padding mask
    if causal:
        mask = mask & ((qi + (lk_orig - lq_orig)) >= ki)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0, 0]                          # [bq]
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)               # rescale of old accumulator
    p = jnp.exp(s - m_new[:, None])               # [bq, bk]
    l_new = l_prev * alpha + p.sum(axis=-1)
    o_ref[0, 0, :, :] = o_ref[0, 0, :, :] * alpha[:, None] + p @ v
    m_ref[0, 0, :] = m_new
    l_ref[0, 0, :] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[0, 0]
        o_ref[0, 0, :, :] = o_ref[0, 0, :, :] / jnp.maximum(l, 1e-30)[:, None]


def flash_attention_bhld(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         causal: bool = True, bq: int = 128, bk: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """q,k,v: [B, H, L, D] → [B, H, Lq, D].  Pads L to block multiples."""
    B, H, Lq, D = q.shape
    Lk = k.shape[2]
    bq = min(bq, max(8, 1 << (Lq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (Lk - 1).bit_length()))
    lq_pad = math.ceil(Lq / bq) * bq
    lk_pad = math.ceil(Lk / bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lq_pad - Lq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, lk_pad - Lk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, lk_pad - Lk), (0, 0)))
    grid = (B, H, lq_pad // bq, lk_pad // bk)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fa_kernel, causal=causal, scale=scale, bq=bq, bk=bk, lk=lk_pad,
        lq_orig=Lq, lk_orig=Lk,
    )
    o, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, lq_pad, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, lq_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, H, lq_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :, :Lq, :].astype(q.dtype)

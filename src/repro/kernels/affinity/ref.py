"""Pure-jnp oracle for the EBPSM affinity kernel (Alg. 2 inner loop).

Given T queued tasks × V pooled VMs, score every pair with the paper's
locality-aware finish-time estimate and pick, per task, the feasible VM
minimizing the lexicographic key (tier, est_finish, vmid).

Tiers follow Alg. 2: 1 = idle VM holding all the task's input data,
2 = idle VM with the task's container deployed, 3 = any idle VM.
``tier = 0`` marks pairs out of scope (busy VM, wrong owner tag).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BIG = jnp.float32(3.4e38)
MS = 1000.0


class AffinityOut(NamedTuple):
    best_vm: jnp.ndarray    # [T] int32, -1 when no feasible VM
    best_tier: jnp.ndarray  # [T] int32, 9 when none
    est_finish: jnp.ndarray  # [T] f32 ms
    est_cost: jnp.ndarray   # [T] f32 cents


CEIL_TOL = 1.0 - 1e-6  # matches core.costs.ceil_ms (see comment there)


def pair_estimates(size_mi, out_mb, missing_mb, cont_ms, vm_mips, vm_bw,
                   gs_read, gs_write, bp_ms, vm_price):
    """Vectorized Eqs. (1)-(5) without provisioning: [T,V] pipe_ms, cost."""
    in_ms = missing_mb * (1.0 / vm_bw[None, :] + 1.0 / gs_read) * MS
    out_ms = out_mb[:, None] * (1.0 / vm_bw[None, :] + 1.0 / gs_write) * MS
    rt_ms = size_mi[:, None] / vm_mips[None, :] * MS
    pipe = (jnp.ceil(in_ms * CEIL_TOL) + jnp.ceil(rt_ms * CEIL_TOL)
            + jnp.ceil(out_ms * CEIL_TOL) + cont_ms)
    cost = jnp.ceil(pipe / bp_ms) * vm_price[None, :]
    return pipe, cost


def affinity_ref(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                 vm_mips, vm_bw, vm_price, gs_read, gs_write,
                 bp_ms) -> AffinityOut:
    """All task arrays [T]; pair arrays [T,V]; vm arrays [V]."""
    pipe, cost = pair_estimates(size_mi, out_mb, missing_mb, cont_ms,
                                vm_mips, vm_bw, gs_read, gs_write, bp_ms,
                                vm_price)
    feasible = (tier > 0) & (cost <= budget[:, None] + 1e-6)
    t_eff = jnp.where(feasible, tier, 9).astype(jnp.int32)
    best_tier = jnp.min(t_eff, axis=1)
    f_eff = jnp.where(t_eff == best_tier[:, None], pipe, BIG)
    best_fin = jnp.min(f_eff, axis=1)
    vmids = jnp.arange(tier.shape[1], dtype=jnp.int32)
    v_eff = jnp.where(f_eff == best_fin[:, None], vmids[None, :], 1 << 30)
    best_vm = jnp.min(v_eff, axis=1).astype(jnp.int32)
    none = best_tier >= 9
    best_vm = jnp.where(none, -1, best_vm)
    idx = jnp.clip(best_vm, 0, tier.shape[1] - 1)
    est_f = jnp.take_along_axis(pipe, idx[:, None], axis=1)[:, 0]
    est_c = jnp.take_along_axis(cost, idx[:, None], axis=1)[:, 0]
    return AffinityOut(best_vm, best_tier,
                       jnp.where(none, BIG, est_f), jnp.where(none, BIG, est_c))

"""jit'd dispatchers for the affinity scoring: Pallas kernel or jnp oracle.

Two entry points share one core:

* :func:`affinity` — one scheduling cycle, ``[T, V]`` pair arrays.
* :func:`affinity_batch` — a whole grid of independent simulations'
  cycles, ``[B, T, V]`` (vmapped over the leading dim).  This is what
  ``core.jax_engine`` drives: one device pass scores every member's
  auction round.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import affinity_pallas
from .ref import AffinityOut, affinity_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _affinity_core(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                   vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
                   bp_ms: float, use_pallas: bool) -> AffinityOut:
    if use_pallas:
        vm, t, f, c = affinity_pallas(
            size_mi, out_mb, budget, missing_mb, cont_ms, tier,
            vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms,
            interpret=_default_interpret())
        return AffinityOut(vm, t, f, c)
    return affinity_ref(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                        vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms)


@partial(jax.jit, static_argnames=("gs_read", "gs_write", "bp_ms", "use_pallas"))
def affinity(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
             vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
             bp_ms: float, use_pallas: bool = False) -> AffinityOut:
    return _affinity_core(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                          vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms,
                          use_pallas)


@partial(jax.jit, static_argnames=("gs_read", "gs_write", "bp_ms", "use_pallas"))
def affinity_batch(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                   vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
                   bp_ms: float, use_pallas: bool = False) -> AffinityOut:
    """Batched affinity: every array carries a leading simulation dim ``B``.

    Task arrays are ``[B, T]``, pair arrays ``[B, T, V]``, VM arrays
    ``[B, V]`` (members may pool different VM fleets).  Inert members pad
    with ``tier = 0`` rows, which are infeasible by construction.
    """
    def one(s, o, b, m, c, t, mi, bw, pr):
        return _affinity_core(s, o, b, m, c, t, mi, bw, pr,
                              gs_read, gs_write, bp_ms, use_pallas)

    return jax.vmap(one)(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                         vm_mips, vm_bw, vm_price)

"""jit'd dispatcher for the affinity scoring: Pallas kernel or jnp oracle."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import affinity_pallas
from .ref import AffinityOut, affinity_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("gs_read", "gs_write", "bp_ms", "use_pallas"))
def affinity(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
             vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
             bp_ms: float, use_pallas: bool = False) -> AffinityOut:
    if use_pallas:
        vm, t, f, c = affinity_pallas(
            size_mi, out_mb, budget, missing_mb, cont_ms, tier,
            vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms,
            interpret=_default_interpret())
        return AffinityOut(vm, t, f, c)
    return affinity_ref(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                        vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms)

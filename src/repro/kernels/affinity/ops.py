"""jit'd dispatchers for the affinity scoring: Pallas kernel or jnp oracle.

Two entry points share one core:

* :func:`affinity` — one scheduling cycle, ``[T, V]`` pair arrays.
* :func:`affinity_batch` — a whole grid of independent simulations'
  cycles, ``[B, T, V]`` (vmapped over the leading dim).  This is what
  ``core.jax_engine`` drives: one device pass scores every member's
  auction round.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import affinity_pallas
from .ref import AffinityOut, affinity_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_use_pallas(flag) -> bool:
    """``"auto"`` → Pallas on TPU, jnp oracle elsewhere (the interpreter
    that backs Pallas off-TPU is orders of magnitude slower than the
    compiled jnp path, so "auto" only engages the kernel where it pays).
    Booleans pass through."""
    if flag == "auto":
        return jax.default_backend() == "tpu"
    return bool(flag)


def donation_supported() -> bool:
    """Whether input-buffer donation actually transfers ownership on the
    default backend (CPU ignores donation and warns)."""
    return jax.default_backend() in ("tpu", "gpu")


def _affinity_core(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                   vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
                   bp_ms: float, use_pallas: bool) -> AffinityOut:
    if use_pallas:
        vm, t, f, c = affinity_pallas(
            size_mi, out_mb, budget, missing_mb, cont_ms, tier,
            vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms,
            interpret=_default_interpret())
        return AffinityOut(vm, t, f, c)
    return affinity_ref(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                        vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms)


@partial(jax.jit, static_argnames=("gs_read", "gs_write", "bp_ms", "use_pallas"))
def affinity(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
             vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
             bp_ms: float, use_pallas: bool = False) -> AffinityOut:
    return _affinity_core(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                          vm_mips, vm_bw, vm_price, gs_read, gs_write, bp_ms,
                          use_pallas)


def _affinity_batch_impl(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                         vm_mips, vm_bw, vm_price, gs_read: float,
                         gs_write: float, bp_ms: float,
                         use_pallas: bool = False) -> AffinityOut:
    def one(s, o, b, m, c, t, mi, bw, pr):
        return _affinity_core(s, o, b, m, c, t, mi, bw, pr,
                              gs_read, gs_write, bp_ms, use_pallas)

    return jax.vmap(one)(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                         vm_mips, vm_bw, vm_price)


_BATCH_STATIC = ("gs_read", "gs_write", "bp_ms", "use_pallas")
_affinity_batch_jit = jax.jit(_affinity_batch_impl,
                              static_argnames=_BATCH_STATIC)
# On accelerators the round buffers' device transfers are single-use:
# donating them lets XLA reuse the staging buffers for outputs instead of
# holding both alive across the call.
_affinity_batch_donated = jax.jit(_affinity_batch_impl,
                                  static_argnames=_BATCH_STATIC,
                                  donate_argnums=tuple(range(9)))


def affinity_batch(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                   vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
                   bp_ms: float, use_pallas: bool = False,
                   donate: bool = False) -> AffinityOut:
    """Batched affinity: every array carries a leading simulation dim ``B``.

    Task arrays are ``[B, T]``, pair arrays ``[B, T, V]``, VM arrays
    ``[B, V]`` (members may pool different VM fleets).  Inert members pad
    with ``tier = 0`` rows, which are infeasible by construction.

    ``donate=True`` routes through the donating jit (see
    :func:`donation_supported`); host-side round buffers stay reusable —
    only the on-device staging copies are consumed.
    """
    fn = _affinity_batch_donated if donate else _affinity_batch_jit
    return fn(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
              vm_mips, vm_bw, vm_price, gs_read=gs_read, gs_write=gs_write,
              bp_ms=bp_ms, use_pallas=use_pallas)

"""Pallas kernel for the EBPSM task×VM affinity argmin (Alg. 2 inner loop).

At WaaS scale (1000 workflows ≈ 170k tasks, pools of hundreds of VMs) the
O(T·V) scoring loop dominates scheduler runtime.  The kernel tiles tasks
into blocks of ``bt`` and keeps the whole VM axis resident in VMEM
(V ≤ 2048 → a [bt, V] f32 tile is ≤ 64 KB at bt = 8): one grid step
computes Eqs. (1)-(5) for bt·V pairs and the three-stage lexicographic
reduction ((tier, finish, vmid) argmin) entirely on-chip, so HBM traffic
is one read of the pair features and a [bt]-sized write.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38
MS = 1000.0


def _affinity_kernel(size_ref, out_ref, bud_ref, miss_ref, cont_ref, tier_ref,
                     mips_ref, bw_ref, price_ref, scal_ref,
                     vm_ref, tierout_ref, fin_ref, cost_ref):
    gs_read, gs_write, bp_ms = scal_ref[0], scal_ref[1], scal_ref[2]
    size = size_ref[...]            # [bt]
    out_mb = out_ref[...]
    budget = bud_ref[...]
    miss = miss_ref[...]            # [bt, V]
    cont = cont_ref[...]
    tier = tier_ref[...]
    mips = mips_ref[...]            # [V]
    bw = bw_ref[...]
    price = price_ref[...]

    TOL = 1.0 - 1e-6   # tolerance-ceil; see core.costs.ceil_ms
    in_ms = miss * (1.0 / bw[None, :] + 1.0 / gs_read) * MS
    o_ms = out_mb[:, None] * (1.0 / bw[None, :] + 1.0 / gs_write) * MS
    rt_ms = size[:, None] / mips[None, :] * MS
    pipe = (jnp.ceil(in_ms * TOL) + jnp.ceil(rt_ms * TOL)
            + jnp.ceil(o_ms * TOL) + cont)
    cost = jnp.ceil(pipe / bp_ms) * price[None, :]

    feas = (tier > 0) & (cost <= budget[:, None] + 1e-6)
    t_eff = jnp.where(feas, tier, 9)
    best_t = jnp.min(t_eff, axis=1)                        # [bt]
    f_eff = jnp.where(t_eff == best_t[:, None], pipe, BIG)
    best_f = jnp.min(f_eff, axis=1)
    V = tier.shape[1]
    vmids = jax.lax.broadcasted_iota(jnp.int32, (tier.shape[0], V), 1)
    v_eff = jnp.where(f_eff == best_f[:, None], vmids, 1 << 30)
    best_v = jnp.min(v_eff, axis=1)
    none = best_t >= 9
    vm_ref[...] = jnp.where(none, -1, best_v)
    tierout_ref[...] = best_t
    idx = jnp.clip(best_v, 0, V - 1)
    onehot = (vmids == idx[:, None]).astype(jnp.float32)
    fin_ref[...] = jnp.where(none, BIG, jnp.sum(pipe * onehot, axis=1))
    cost_ref[...] = jnp.where(none, BIG, jnp.sum(cost * onehot, axis=1))


def affinity_pallas(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
                    vm_mips, vm_bw, vm_price, gs_read: float, gs_write: float,
                    bp_ms: float, bt: int = 8, interpret: bool = True):
    T, V = missing_mb.shape
    tp = math.ceil(T / bt) * bt
    padT = lambda a: jnp.pad(a, ((0, tp - T),) + ((0, 0),) * (a.ndim - 1))
    size_mi, out_mb, budget = map(padT, (size_mi, out_mb, budget))
    missing_mb, cont_ms = padT(missing_mb), padT(cont_ms)
    tier = padT(tier)
    scal = jnp.array([gs_read, gs_write, bp_ms], jnp.float32)
    grid = (tp // bt,)
    outs = pl.pallas_call(
        _affinity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt, V), lambda i: (i, 0)),
            pl.BlockSpec((bt, V), lambda i: (i, 0)),
            pl.BlockSpec((bt, V), lambda i: (i, 0)),
            pl.BlockSpec((V,), lambda i: (0,)),
            pl.BlockSpec((V,), lambda i: (0,)),
            pl.BlockSpec((V,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp,), jnp.int32),
            jax.ShapeDtypeStruct((tp,), jnp.int32),
            jax.ShapeDtypeStruct((tp,), jnp.float32),
            jax.ShapeDtypeStruct((tp,), jnp.float32),
        ],
        interpret=interpret,
    )(size_mi, out_mb, budget, missing_mb, cont_ms, tier,
      vm_mips, vm_bw, vm_price, scal)
    return tuple(o[:T] for o in outs)

"""Pallas TPU kernel for the SSD intra-chunk block + chunk-state production.

Grid = (B, H, num_chunks).  Each step loads one chunk of one head into VMEM:
x [Q,P], dt/cum [Q], B/C [Q,N] — with Q = 64..256, P = 64, N = 128 the
working set is ≈ (Q·P + 2·Q·N + Q·Q)·4 B ≲ 0.5 MB, and the two matmuls
(C·Bᵀ: [Q,N]×[N,Q]; w·x: [Q,Q]×[Q,P]) land on the MXU with 128-aligned
contraction dims.

The sequential inter-chunk state carry is NOT in the kernel — it is a
cheap [B,H,N,P] scan done in jnp by ops.py (O(nc) adds, bandwidth-trivial),
which keeps the kernel grid embarrassingly parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref,
                      y_ref, state_ref, *, chunk: int):
    x = x_ref[0, 0, 0].astype(jnp.float32)         # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)       # [Q]
    cum = cum_ref[0, 0, 0].astype(jnp.float32)     # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)           # [Q, N]

    seg = cum[:, None] - cum[None, :]              # [Q(i), Q(j)]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = Cm @ Bm.T                                 # [Q, Q]  (MXU)
    w = cb * decay * dt[None, :]
    y_ref[0, 0, 0, :, :] = w @ x                   # [Q, P]  (MXU)

    dec_end = jnp.exp(cum[-1] - cum) * dt          # [Q]
    state_ref[0, 0, 0, :, :] = Bm.T @ (x * dec_end[:, None])  # [N, P] (MXU)


def ssd_chunks(x: jnp.ndarray, dt: jnp.ndarray, cum: jnp.ndarray,
               Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
               interpret: bool = True):
    """Intra-chunk pass.

    x: [B,L,H,P]; dt,cum: [B,L,H]; Bm,Cm: [B,L,N] → (y_intra [B,L,H,P],
    states [B,nc,H,N,P]) where states lack the inter-chunk carry.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    # Layout: [B, H, nc, Q, ...] so each grid step reads a contiguous block.
    xt = jnp.transpose(x.reshape(Bsz, nc, chunk, H, P), (0, 3, 1, 2, 4))
    dtt = jnp.transpose(dt.reshape(Bsz, nc, chunk, H), (0, 3, 1, 2))
    cumt = jnp.transpose(cum.reshape(Bsz, nc, chunk, H), (0, 3, 1, 2))
    bt = Bm.reshape(Bsz, nc, chunk, N)
    ct = Cm.reshape(Bsz, nc, chunk, N)

    grid = (Bsz, H, nc)
    y, st = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, nc, chunk, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, nc, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, cumt, bt, ct)
    y = jnp.transpose(y, (0, 2, 3, 1, 4)).reshape(Bsz, L, H, P)
    st = jnp.transpose(st, (0, 2, 1, 3, 4))       # [B, nc, H, N, P]
    return y, st

"""Dispatcher for the SSD scan: Pallas kernel (intra-chunk) + jnp carry,
or the pure-jnp reference — bit-compatible shapes either way.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .kernel import ssd_chunks
from .ref import ssd_decode_ref, ssd_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, Bm: jnp.ndarray,
        Cm: jnp.ndarray, chunk: int = 64, use_pallas: bool = False,
        init_state: jnp.ndarray | None = None,
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """See ref.ssd_ref for shapes."""
    if not use_pallas:
        return ssd_ref(x, dt, A, Bm, Cm, chunk=chunk, init_state=init_state)

    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = L // chunk
    f32 = jnp.float32
    a = dt.astype(f32) * A.astype(f32)[None, None, :]
    cum = jnp.cumsum(a.reshape(Bsz, nc, chunk, H), axis=2).reshape(Bsz, L, H)

    y_intra, Sc = ssd_chunks(x, dt, cum, Bm, Cm, chunk,
                             interpret=_default_interpret())

    cumc = cum.reshape(Bsz, nc, chunk, H)
    chunk_decay = jnp.exp(cumc[:, :, -1, :])      # [B,nc,H]

    def step(h, inp):
        s_c, dec = inp
        h_prev = h
        h = dec[:, :, None, None] * h + s_c
        return h, h_prev

    h0 = (jnp.zeros((Bsz, H, N, P), f32) if init_state is None
          else init_state.astype(f32))
    final, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)

    Cc = Cm.astype(f32).reshape(Bsz, nc, chunk, N)
    y_inter = jnp.einsum("bcih,bcin,bchnp->bcihp", jnp.exp(cumc), Cc, h_prevs)
    y = y_intra.reshape(Bsz, nc, chunk, H, P) + y_inter
    return y.reshape(Bsz, L, H, P).astype(x.dtype), final


def ssd_decode(x, dt, A, Bm, Cm, state):
    return ssd_decode_ref(x, dt, A, Bm, Cm, state)

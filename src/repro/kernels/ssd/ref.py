"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) chunked scan.

Computes, per head h with scalar decay ``a_t = dt_t * A_h`` (A < 0):

    s_t = exp(a_t) * s_{t-1} + dt_t * B_t ⊗ x_t          (state  [N, P])
    y_t = C_t · s_t                                       (output [P])

via the SSD chunk decomposition: intra-chunk "masked attention" term +
inter-chunk state carry, exactly the structure the Pallas kernel tiles.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int = 64,
            init_state: jnp.ndarray | None = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,L,H,P]; dt: [B,L,H] (>0); A: [H] (<0); Bm,Cm: [B,L,N].

    Returns (y [B,L,H,P], final_state [B,H,N,P]).  fp32 internally.
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    f32 = jnp.float32
    orig_dtype = x.dtype
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bm = Bm.astype(f32)
    Cm = Cm.astype(f32)
    a = dt * A.astype(f32)[None, None, :]                     # [B,L,H] (<0)

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    ac = a.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(ac, axis=2)                              # [B,nc,Q,H]
    # Intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i·B_j) x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,nc,Q(i),Q(j),H]
    iota = jnp.arange(chunk)
    causal = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,nc,Q,Q]
    w = cb[..., None] * decay * dtc[:, :, None, :, :]         # [B,nc,i,j,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # Chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)                # [B,nc,Q,H]
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    dec_end * dtc, Bc, xc)                    # [B,nc,H,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # [B,nc,H]

    # Inter-chunk scan over chunk states.
    def step(h, inp):
        s_c, dec = inp                                        # [B,H,N,P],[B,H]
        h_prev = h
        h = dec[:, :, None, None] * h + s_c
        return h, h_prev

    h0 = (jnp.zeros((Bsz, H, N, P), f32) if init_state is None
          else init_state.astype(f32))
    final, h_prevs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # [B,nc,H,N,P]

    # Inter-chunk contribution: y_i += exp(cum_i) C_i · h_prev
    y_inter = jnp.einsum("bcih,bcin,bchnp->bcihp",
                         jnp.exp(cum), Cc, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(orig_dtype), final


def ssd_decode_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   Bm: jnp.ndarray, Cm: jnp.ndarray, state: jnp.ndarray,
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence.  x: [B,H,P]; dt: [B,H]; Bm,Cm: [B,N];
    state: [B,H,N,P] → (y [B,H,P], new_state)."""
    f32 = jnp.float32
    a = dt.astype(f32) * A.astype(f32)[None, :]
    dec = jnp.exp(a)[:, :, None, None]
    upd = jnp.einsum("bn,bhp->bhnp", Bm.astype(f32),
                     dt.astype(f32)[..., None] * x.astype(f32))
    new = dec * state.astype(f32) + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(f32), new)
    return y.astype(x.dtype), new

"""Sharded serving step builders: prefill and single-token decode."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.shapes import SHAPES
from ..models.common import LONG_RULES, SERVE_RULES
from ..models.registry import Model
from ..parallel import ctx
from ..parallel import sharding as shd


def build_prefill(model: Model, mesh: Mesh, shape_name: str):
    shape = SHAPES[shape_name]
    long_ctx = shape.seq_len > 100_000
    rules = LONG_RULES if long_ctx else SERVE_RULES
    param_sh = shd.model_param_shardings(model, mesh, "serve", long_ctx)
    batch_sh = shd.batch_shardings(model, mesh, shape_name, "serve", long_ctx)

    def prefill(params, batch):
        with ctx.scope(mesh, rules):
            return model.prefill(params, batch, shape.seq_len)

    # Pin the output cache shardings — left to 'auto', XLA replicates the
    # multi-hundred-GB KV cache across the model axis.
    state_sh = shd.state_shardings(model, mesh, shape_name, long_ctx)
    fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                 out_shardings=(None, state_sh))
    return fn, param_sh, batch_sh


def build_decode_step(model: Model, mesh: Mesh, shape_name: str):
    shape = SHAPES[shape_name]
    long_ctx = shape.seq_len > 100_000
    rules = LONG_RULES if long_ctx else SERVE_RULES
    param_sh = shd.model_param_shardings(model, mesh, "serve", long_ctx)
    state_sh = shd.state_shardings(model, mesh, shape_name, long_ctx)
    tok_sh = shd.batch_shardings(model, mesh, shape_name, "serve", long_ctx)

    def decode(params, state, tokens):
        with ctx.scope(mesh, rules):
            return model.decode_step(params, state, tokens)

    fn = jax.jit(decode,
                 in_shardings=(param_sh, state_sh, tok_sh["tokens"]),
                 out_shardings=(None, state_sh),
                 donate_argnums=(1,))
    return fn, param_sh, state_sh, tok_sh

"""serve substrate."""

"""Workload generation — Section 5 of the paper.

A workload is a stream of workflows: types drawn uniformly from the five
applications, sizes drawn uniformly from {small≈50, medium≈100, large≈1000}
tasks, arrivals Poisson at a given rate (workflows/minute), and budgets drawn
uniformly from [min_cost, max_cost] as estimated by
``core.budget.min_max_workflow_cost`` (sequential-on-cheapest vs
all-parallel-on-fastest).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core import budget as budget_mod
from ..core.types import MS, PlatformConfig, Workflow
from .dax import APP_NAMES, generate_workflow

SIZE_CLASSES = {"small": 50, "medium": 100, "large": 1000}


def assign_budgets_uniform(
    cfg: PlatformConfig,
    wfs: Sequence[Workflow],
    rng: np.random.Generator,
    lo: float,
    hi: float,
) -> None:
    """Draw each workflow's soft budget uniformly from the ``[lo, hi]``
    slice of its ``[min_cost, max_cost]`` range — THE budget-assignment
    path (§5 workload construction), shared by the closed-grid workloads
    below, the tenant mixes (``repro.tenants``), and
    ``waas.platform.assign_budgets``."""
    for wf in wfs:
        cmin, cmax = budget_mod.min_max_workflow_cost(cfg, wf)
        wf.budget = cmin + rng.uniform(lo, hi) * (cmax - cmin)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    n_workflows: int = 100
    arrival_rate_per_min: float = 1.0
    apps: Tuple[str, ...] = APP_NAMES
    sizes: Tuple[str, ...] = ("small", "medium", "large")
    seed: int = 0
    # Budget multiplier range relative to [min_cost, max_cost]; the paper
    # draws uniformly across the full range ("always assumed sufficient").
    budget_lo: float = 0.0
    budget_hi: float = 1.0


def cell_workload(
    cfg: PlatformConfig,
    app: str,
    rate: float,
    budget_interval: Tuple[float, float],
    seed: int,
    n_workflows: int,
    sizes: Tuple[str, ...] = ("small", "medium", "large"),
) -> List[Workflow]:
    """One evaluation-grid cell's workload: a single-application stream at
    the given arrival rate, budgets drawn uniformly from one quarter (the
    paper's four budget intervals) of the [min_cost, max_cost] range."""
    lo, hi = budget_interval
    spec = WorkloadSpec(n_workflows=n_workflows, arrival_rate_per_min=rate,
                        apps=(app,), sizes=sizes, seed=seed,
                        budget_lo=lo, budget_hi=hi)
    return generate_workload(cfg, spec)


def generate_workload(
    cfg: PlatformConfig, spec: WorkloadSpec
) -> List[Workflow]:
    """Build the workload; ``wid`` equals the list index (engine invariant)."""
    rng = np.random.default_rng(spec.seed)
    inter_ms = 60.0 * MS / spec.arrival_rate_per_min
    t = 0.0
    out: List[Workflow] = []
    for wid in range(spec.n_workflows):
        app = spec.apps[int(rng.integers(len(spec.apps)))]
        size = SIZE_CLASSES[spec.sizes[int(rng.integers(len(spec.sizes)))]]
        wf = generate_workflow(app, wid, size, rng)
        wf.arrival_ms = int(t)
        assign_budgets_uniform(cfg, [wf], rng,
                               spec.budget_lo, spec.budget_hi)
        out.append(wf)
        t += rng.exponential(inter_ms)
    return out

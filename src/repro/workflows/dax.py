"""Synthetic DAG generators for the paper's five workflow applications.

Structures and relative task characteristics follow the Pegasus workflow
profiles (Juve et al., "Characterizing and Profiling Scientific Workflows",
FGCS 2013) that the WorkflowGenerator tool implements, scaled to the paper's
Table 1 qualitative matrix:

============  ==============  =========  =========  ===========
workflow      parallel tasks  CPU hours  I/O reqs   peak memory
============  ==============  =========  =========  ===========
CyberShake    very high       very high  very high  very high
Epigenome     medium          low        medium     medium
LIGO          medium-high     medium     high       high
Montage       high            low        high       low
SIPHT         low             low        low        medium
============  ==============  =========  =========  ===========

Sizes are in MI (runs at `MIPS` from Table 2 ⇒ seconds on the reference VM);
data volumes in MB.  Exact magnitudes are calibrated so each family's
runtime/IO ratio matches its Table 1 class — the paper's own numbers come
from the (unpublished-seed) WorkflowGenerator, so EXPERIMENTS.md validates
*orderings and trends*, not absolute seconds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

import numpy as np

from ..core.types import Task, Workflow


def _mk(
    rng: np.random.Generator,
    sizes_mi: Tuple[float, float],
    out_mb: Tuple[float, float],
) -> Tuple[float, float]:
    """Draw (size_mi, out_mb) from truncated normals around the given
    (mean, std) pairs."""
    s = max(rng.normal(sizes_mi[0], sizes_mi[1]), sizes_mi[0] * 0.1)
    d = max(rng.normal(out_mb[0], out_mb[1]), out_mb[0] * 0.1)
    return float(s), float(d)


def _build(wid: int, app: str, spec: List[Tuple[float, float, float]],
           edges: List[Tuple[int, int]]) -> Workflow:
    tasks = [
        Task(tid=i, size_mi=s, out_mb=o, ext_in_mb=e)
        for i, (s, o, e) in enumerate(spec)
    ]
    for u, v in edges:
        tasks[u].children.append(v)
        tasks[v].parents.append(u)
    wf = Workflow(wid=wid, app=app, tasks=tasks)
    wf.validate()
    return wf


# ---------------------------------------------------------------------------
# Montage — high fan-out, I/O heavy, short CPU (mProjectPP → mDiffFit →
# mConcatFit → mBgModel → mBackground → mImgtbl → mAdd → mShrink → mJPEG).
# ---------------------------------------------------------------------------


def montage(wid: int, n: int, rng: np.random.Generator) -> Workflow:
    k = max(3, (n - 5) // 3)  # projections
    spec: List[Tuple[float, float, float]] = []
    edges: List[Tuple[int, int]] = []
    proj = []
    for _ in range(k):
        s, o = _mk(rng, (20, 5), (40, 10))
        proj.append(len(spec))
        spec.append((s, o, 30.0))          # mProjectPP: staged sky tiles
    diff = []
    for i in range(k):
        s, o = _mk(rng, (10, 3), (2, 0.5))
        d = len(spec)
        diff.append(d)
        spec.append((s, o, 0.0))           # mDiffFit over adjacent pairs
        edges.append((proj[i], d))
        edges.append((proj[(i + 1) % k], d))
    s, o = _mk(rng, (15, 4), (1, 0.2))
    concat = len(spec)
    spec.append((s, o, 0.0))               # mConcatFit
    edges += [(d, concat) for d in diff]
    s, o = _mk(rng, (15, 4), (1, 0.2))
    bg_model = len(spec)
    spec.append((s, o, 0.0))               # mBgModel
    edges.append((concat, bg_model))
    backs = []
    for i in range(k):
        s, o = _mk(rng, (10, 3), (40, 10))
        b = len(spec)
        backs.append(b)
        spec.append((s, o, 0.0))           # mBackground
        edges.append((bg_model, b))
        edges.append((proj[i], b))
    s, o = _mk(rng, (20, 5), (5, 1))
    imgtbl = len(spec)
    spec.append((s, o, 0.0))
    edges += [(b, imgtbl) for b in backs]
    s, o = _mk(rng, (60, 15), (120, 30))
    madd = len(spec)
    spec.append((s, o, 0.0))               # mAdd: big mosaic
    edges.append((imgtbl, madd))
    s, o = _mk(rng, (15, 4), (20, 5))
    shrink = len(spec)
    spec.append((s, o, 0.0))
    edges.append((madd, shrink))
    s, o = _mk(rng, (10, 2), (5, 1))
    jpeg = len(spec)
    spec.append((s, o, 0.0))
    edges.append((shrink, jpeg))
    return _build(wid, "montage", spec, edges)


# ---------------------------------------------------------------------------
# CyberShake — very high parallelism, very high CPU AND data (ExtractSGT →
# SeismogramSynthesis → PeakValCalc, + ZipSeis/ZipPSA collectors).
# ---------------------------------------------------------------------------


def cybershake(wid: int, n: int, rng: np.random.Generator) -> Workflow:
    pairs = max(2, (n - 2) // 4)
    spec: List[Tuple[float, float, float]] = []
    edges: List[Tuple[int, int]] = []
    synths = []
    peaks = []
    for _ in range(pairs):
        s, o = _mk(rng, (110, 25), (150, 40))
        sgt = len(spec)
        spec.append((s, o, 120.0))         # ExtractSGT: huge staged SGT
        for _ in range(2):
            s2, o2 = _mk(rng, (450, 100), (180, 50))
            syn = len(spec)
            synths.append(syn)
            spec.append((s2, o2, 0.0))     # SeismogramSynthesis: heavy CPU+data
            edges.append((sgt, syn))
            s3, o3 = _mk(rng, (30, 8), (1, 0.3))
            pk = len(spec)
            peaks.append(pk)
            spec.append((s3, o3, 0.0))     # PeakValCalc
            edges.append((syn, pk))
    s, o = _mk(rng, (40, 10), (60, 15))
    zipseis = len(spec)
    spec.append((s, o, 0.0))
    edges += [(x, zipseis) for x in synths]
    s, o = _mk(rng, (30, 8), (10, 3))
    zippsa = len(spec)
    spec.append((s, o, 0.0))
    edges += [(x, zippsa) for x in peaks]
    return _build(wid, "cybershake", spec, edges)


# ---------------------------------------------------------------------------
# Epigenome — CPU-bound parallel chains (split → filter → sol2sanger →
# fastq2bfq → map → merge → index → pileup).
# ---------------------------------------------------------------------------


def epigenome(wid: int, n: int, rng: np.random.Generator) -> Workflow:
    lanes = max(2, (n - 4) // 4)
    spec: List[Tuple[float, float, float]] = []
    edges: List[Tuple[int, int]] = []
    s, o = _mk(rng, (60, 10), (15, 3))
    split = len(spec)
    spec.append((s, o, 25.0))
    maps = []
    for _ in range(lanes):
        prev = split
        for stage, (mi, mb) in enumerate(
            [((90, 20), (10, 2)), ((45, 10), (10, 2)),
             ((45, 10), (8, 2)), ((900, 180), (8, 2))]  # map = CPU hog
        ):
            s2, o2 = _mk(rng, mi, mb)
            t = len(spec)
            spec.append((s2, o2, 0.0))
            edges.append((prev, t))
            prev = t
        maps.append(prev)
    s, o = _mk(rng, (120, 25), (20, 4))
    merge = len(spec)
    spec.append((s, o, 0.0))
    edges += [(m, merge) for m in maps]
    s, o = _mk(rng, (60, 12), (10, 2))
    index = len(spec)
    spec.append((s, o, 0.0))
    edges.append((merge, index))
    s, o = _mk(rng, (90, 18), (15, 3))
    pileup = len(spec)
    spec.append((s, o, 0.0))
    edges.append((index, pileup))
    return _build(wid, "epigenome", spec, edges)


# ---------------------------------------------------------------------------
# LIGO Inspiral — medium-high parallelism, medium CPU, high I/O
# (TmpltBank → Inspiral → Thinca → TrigBank → Inspiral2 → Thinca2).
# ---------------------------------------------------------------------------


def ligo(wid: int, n: int, rng: np.random.Generator) -> Workflow:
    groups = max(2, (n - 2) // 10)
    per = 4
    spec: List[Tuple[float, float, float]] = []
    edges: List[Tuple[int, int]] = []
    thincas = []
    for _ in range(groups):
        insp = []
        for _ in range(per):
            s, o = _mk(rng, (70, 15), (25, 6))
            tb = len(spec)
            spec.append((s, o, 30.0))      # TmpltBank
            s2, o2 = _mk(rng, (320, 70), (30, 8))
            ins = len(spec)
            spec.append((s2, o2, 0.0))     # Inspiral: CPU heavy
            edges.append((tb, ins))
            insp.append(ins)
        s3, o3 = _mk(rng, (25, 6), (8, 2))
        th = len(spec)
        spec.append((s3, o3, 0.0))         # Thinca
        edges += [(i, th) for i in insp]
        thincas.append(th)
        insp2 = []
        for _ in range(per):
            s4, o4 = _mk(rng, (20, 5), (6, 2))
            tb2 = len(spec)
            spec.append((s4, o4, 0.0))     # TrigBank
            edges.append((th, tb2))
            s5, o5 = _mk(rng, (280, 60), (25, 6))
            ins2 = len(spec)
            spec.append((s5, o5, 0.0))     # Inspiral round 2
            edges.append((tb2, ins2))
            insp2.append(ins2)
        s6, o6 = _mk(rng, (25, 6), (8, 2))
        th2 = len(spec)
        spec.append((s6, o6, 0.0))
        edges += [(i, th2) for i in insp2]
    return _build(wid, "ligo", spec, edges)


# ---------------------------------------------------------------------------
# SIPHT — low parallelism, low I/O, medium memory (many small analysis tools
# feeding one FindsRNA, then annotation).
# ---------------------------------------------------------------------------


def sipht(wid: int, n: int, rng: np.random.Generator) -> Workflow:
    patsers = max(2, (n - 8) // 2)
    spec: List[Tuple[float, float, float]] = []
    edges: List[Tuple[int, int]] = []
    pats = []
    for _ in range(patsers):
        s, o = _mk(rng, (25, 6), (1.5, 0.4))
        p = len(spec)
        pats.append(p)
        spec.append((s, o, 2.0))           # Patser
    s, o = _mk(rng, (15, 4), (2, 0.5))
    pconc = len(spec)
    spec.append((s, o, 0.0))               # Patser_concat
    edges += [(p, pconc) for p in pats]
    tools = []
    for mi in [(120, 25), (90, 20), (160, 30), (90, 20), (60, 15)]:
        s2, o2 = _mk(rng, mi, (4, 1))
        t = len(spec)
        tools.append(t)
        spec.append((s2, o2, 3.0))         # Blast / FindTerm / RNAMotif / ...
    s3, o3 = _mk(rng, (220, 45), (6, 1.5))
    srna = len(spec)
    spec.append((s3, o3, 0.0))             # FindsRNA
    edges += [(t, srna) for t in tools + [pconc]]
    s4, o4 = _mk(rng, (110, 22), (4, 1))
    annot = len(spec)
    spec.append((s4, o4, 0.0))             # sRNA annotate
    edges.append((srna, annot))
    return _build(wid, "sipht", spec, edges)


# ---------------------------------------------------------------------------
# Trace-import calibration (consumed by tenants.traces).
#
# Real traces record *runtimes in seconds* on some reference host and
# *file sizes in bytes*; the simulator wants MI and MB on the Table-2
# catalogue.  Per-family calibration maps trace seconds → MI at a
# reference-machine MIPS chosen so imported workflows land in the same
# magnitude band as the synthetic Table-1 generators above (e.g. Montage
# runtimes are short/I-O bound, Epigenome map stages are CPU hogs), and
# scales byte volumes to the family's I/O class.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceCalibration:
    """Reference-host calibration for one workflow family."""

    mips: float = 4.0        # MI per traced runtime second (≈ "medium")
    mb_scale: float = 1.0    # multiplier on trace MB volumes


TRACE_CALIBRATION: Dict[str, TraceCalibration] = {
    # Montage: I/O heavy, short CPU — traced on a slow reference host.
    "montage": TraceCalibration(mips=2.0, mb_scale=1.0),
    # CyberShake: very high CPU and data.
    "cybershake": TraceCalibration(mips=8.0, mb_scale=1.0),
    # Epigenome: CPU-bound chains (map ≈ hundreds of seconds).
    "epigenome": TraceCalibration(mips=4.0, mb_scale=1.0),
    # LIGO Inspiral: medium CPU, high I/O.
    "ligo": TraceCalibration(mips=4.0, mb_scale=1.0),
    # SIPHT: low everything.
    "sipht": TraceCalibration(mips=4.0, mb_scale=1.0),
    # Seismology (cross-correlation / deconvolution): CPU-leaning tasks
    # over modest waveform volumes, traced on a mid-range host.
    "seismology": TraceCalibration(mips=6.0, mb_scale=1.0),
}

DEFAULT_TRACE_CALIBRATION = TraceCalibration()

# Substring hints mapping trace names / DAX namespaces / WfCommons
# application ids onto the five Table-1 families.
TRACE_FAMILY_HINTS: Dict[str, str] = {
    "montage": "montage",
    "cybershake": "cybershake",
    "epigenom": "epigenome",       # epigenome / epigenomics / genome-seq
    "genome": "epigenome",
    "ligo": "ligo",
    "inspiral": "ligo",
    "sipht": "sipht",
    "srna": "sipht",
    "seismolog": "seismology",     # seismology / seismological
    "iterdecon": "seismology",
}


def trace_calibration(family: str) -> TraceCalibration:
    """Calibration for a (possibly unknown) family name."""
    return TRACE_CALIBRATION.get(family, DEFAULT_TRACE_CALIBRATION)


APP_GENERATORS: Dict[str, Callable[[int, int, np.random.Generator], Workflow]] = {
    "cybershake": cybershake,
    "epigenome": epigenome,
    "ligo": ligo,
    "montage": montage,
    "sipht": sipht,
}

APP_NAMES = tuple(sorted(APP_GENERATORS))


def generate_workflow(
    app: str, wid: int, n_tasks: int, rng: np.random.Generator
) -> Workflow:
    """Generate one workflow of ``app`` with ≈ ``n_tasks`` tasks."""
    wf = APP_GENERATORS[app](wid, n_tasks, rng)
    return wf

"""Synthetic scientific-workflow DAG generators and workload models."""
from .dax import APP_GENERATORS, generate_workflow  # noqa: F401
from .workload import generate_workload, WorkloadSpec  # noqa: F401

"""ML tenant jobs as workflow DAGs, costed from dry-run artifacts.

A tenant job is a DAG of ML *stages* over one of the 10 assigned archs:

  fine-tune:  prep(×K shards) → train segment chain(×M) → eval(×E) → pack
  serve:      warmup → prefill(×P parallel request chunks) → decode chain

Stage sizes come from the compiled dry-run (``flops_per_device × chips``
per step — the same artifact §Roofline reads), so the scheduler's cost
model and the framework's compiled reality stay coupled.  Task size unit:
1 MI ≡ 1 GFLOP; slice "MIPS" ≡ sustained GFLOP/s (slices.py).

Every task of arch X carries ``shared_in = [(X, weight_mb)]`` — the base
checkpoint shared across tenants.  EBPSM's tier-1 rule then lands jobs on
slices that already hold the base model: the paper's data-locality policy
becomes "don't re-stage base weights", usually the dominant overhead.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..core.types import Task, Workflow
from .slices import GFLOPS_PER_CHIP

# Analytic fallbacks when dry-run artifacts are absent (tests).
_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
           "decode_32k": 128, "long_500k": 1}


def _artifact_flops(art_dir: str) -> Dict[Tuple[str, str], float]:
    out: Dict[Tuple[str, str], float] = {}
    for p in glob.glob(os.path.join(art_dir, "singlepod__*.json")):
        with open(p) as f:
            art = json.load(f)
        if "skipped" in art or "flops_per_device" not in art:
            continue
        chips = art["mesh"]["n_devices"]
        out[(art["arch"], art["shape"])] = art["flops_per_device"] * chips
    return out


class StageCostModel:
    """GFLOPs per step per (arch, shape), dry-run-derived with fallback."""

    def __init__(self, art_dir: str = "artifacts/dryrun"):
        self.measured = _artifact_flops(art_dir) if os.path.isdir(art_dir) \
            else {}

    def step_gflops(self, arch: str, shape: str) -> float:
        if (arch, shape) in self.measured:
            return self.measured[(arch, shape)] / 1e9
        cfg = get_config(arch)
        n = cfg.n_layers * cfg.d_model * cfg.d_model * 12  # crude N proxy
        mult = {"train_4k": 6, "prefill_32k": 2, "decode_32k": 2,
                "long_500k": 2}[shape]
        return mult * n * _TOKENS[shape] / 1e9

    def weight_mb(self, arch: str) -> float:
        cfg = get_config(arch)
        # bf16 checkpoint; rough param count via a forward spec would pull
        # in jax — keep it analytic here.
        if cfg.n_experts:
            per_l = (cfg.n_experts_padded * 3 * cfg.d_model * cfg.d_ff
                     + 4 * cfg.d_model * cfg.d_model)
        elif cfg.ssm_state:
            per_l = 2 * cfg.d_model * cfg.d_inner * 2
        else:
            per_l = (3 * cfg.d_model * cfg.d_ff
                     + 4 * cfg.d_model * max(cfg.n_heads, 1) * cfg.hd // max(cfg.n_heads, 1) * 4)
            per_l = 3 * cfg.d_model * cfg.d_ff + 4 * cfg.d_model * cfg.d_model
        n = cfg.n_layers * per_l + 2 * cfg.vocab * cfg.d_model
        return n * 2 / 1e6


def finetune_job(wid: int, arch: str, cost: StageCostModel,
                 rng: np.random.Generator, n_segments: int = 4,
                 steps_per_segment: int = 20, n_shards: int = 4,
                 n_eval: int = 3) -> Workflow:
    """prep(×K) → train chain(×M) → eval(×E) → pack."""
    wmb = cost.weight_mb(arch)
    step_g = cost.step_gflops(arch, "train_4k")
    tasks: List[Task] = []

    def add(size_gf, out_mb, parents, ext_mb=0.0, shared=True) -> int:
        tid = len(tasks)
        t = Task(tid=tid, size_mi=float(size_gf), out_mb=float(out_mb),
                 ext_in_mb=float(ext_mb), parents=list(parents))
        if shared:
            t.shared_in = [(arch, wmb)]
        tasks.append(t)
        for p in parents:
            tasks[p].children.append(tid)
        return tid

    # data prep: tokenize/pack shards (I/O-ish, light compute)
    preps = [add(rng.uniform(50, 200), rng.uniform(500, 2000), [],
                 ext_mb=rng.uniform(1000, 4000), shared=False)
             for _ in range(n_shards)]
    prev = None
    for _ in range(n_segments):
        parents = preps if prev is None else [prev]
        prev = add(step_g * steps_per_segment, wmb, parents)
    evals = [add(cost.step_gflops(arch, "prefill_32k") * rng.uniform(0.5, 2),
                 rng.uniform(10, 50), [prev]) for _ in range(n_eval)]
    add(rng.uniform(20, 100), wmb, evals, shared=False)   # package/export
    wf = Workflow(wid=wid, app=arch, tasks=tasks)
    wf.validate()
    return wf


def serve_job(wid: int, arch: str, cost: StageCostModel,
              rng: np.random.Generator, n_prefill: int = 6,
              decode_tokens: int = 512) -> Workflow:
    """warmup → prefill(×P) → decode chain per prefill → collect."""
    cfg = get_config(arch)
    wmb = cost.weight_mb(arch)
    tasks: List[Task] = []

    def add(size_gf, out_mb, parents, ext_mb=0.0, shared=True) -> int:
        tid = len(tasks)
        t = Task(tid=tid, size_mi=float(size_gf), out_mb=float(out_mb),
                 ext_in_mb=float(ext_mb), parents=list(parents))
        if shared:
            t.shared_in = [(arch, wmb)]
        tasks.append(t)
        for p in parents:
            tasks[p].children.append(tid)
        return tid

    warm = add(rng.uniform(10, 50), 1.0, [])
    ends = []
    dec_g = cost.step_gflops(arch, "decode_32k") * decode_tokens
    if cfg.is_encoder_only:
        dec_g = 0.0
    for _ in range(n_prefill):
        pf = add(cost.step_gflops(arch, "prefill_32k") * rng.uniform(0.3, 1),
                 rng.uniform(100, 400), [warm])
        if dec_g > 0:
            d = add(dec_g * rng.uniform(0.5, 1.5), rng.uniform(5, 20), [pf])
            ends.append(d)
        else:
            ends.append(pf)
    add(rng.uniform(5, 20), 5.0, ends, shared=False)      # collect/respond
    wf = Workflow(wid=wid, app=arch, tasks=tasks)
    wf.validate()
    return wf


def ml_workload(n_jobs: int, arrival_rate_per_min: float, seed: int = 0,
                art_dir: str = "artifacts/dryrun",
                archs: Optional[Tuple[str, ...]] = None) -> List[Workflow]:
    """A multi-tenant stream of fine-tune + serve jobs over the arch pool."""
    rng = np.random.default_rng(seed)
    cost = StageCostModel(art_dir)
    archs = archs or ARCH_IDS
    t = 0.0
    out: List[Workflow] = []
    for wid in range(n_jobs):
        arch = archs[int(rng.integers(len(archs)))]
        if rng.random() < 0.5:
            wf = finetune_job(wid, arch, cost, rng,
                              n_segments=int(rng.integers(2, 6)),
                              steps_per_segment=int(rng.integers(5, 30)))
        else:
            wf = serve_job(wid, arch, cost, rng,
                           n_prefill=int(rng.integers(3, 10)))
        wf.arrival_ms = int(t)
        out.append(wf)
        t += rng.exponential(60_000.0 / arrival_rate_per_min)
    return out

"""Multi-tenant TPU-slice WaaS platform: EBPSM scheduling ML jobs.

Drives the *unchanged* core engine (policies, budget algebra, caches) on
the slice catalogue + ML-job DAGs.  Reporting rides the shared
:mod:`repro.exp.metrics` collector (one schema for the paper grid and
the ML bridge): per-tenant makespan/cost/budget-met, slice utilization,
locality and sharing hit rates (tier 1 = "weights already resident", the
paper's data-sharing claim restated for ML), and a straggler-recovery
comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..chaos import ChaosConfig
from ..core.engine import SimEngine
from ..obs import monitor as obs_monitor
from ..obs import report as obs_report
from ..obs import timeseries as obs_ts
from ..obs.events import EventLog
from ..core.jax_engine import (BatchSimEngine, GridMember,
                               predistribute_workload)
from ..core.scheduler import ALL_POLICIES, EBPSM, MSLBL_MW, Policy
from ..core.types import PlatformConfig, SimResult, Workflow, clone_workload
from ..exp.metrics import CellMetrics, format_row
from ..tenants import QoSClass, Tenant, TenantMix, assign_budgets_uniform
from . import mljobs, slices

# The ML bridge's service class: budgets drawn from the upper 85% of each
# job's [min_cost, max_cost] range (the historical assign_budgets default).
ML_QOS = QoSClass("ml", (0.15, 1.0), 1)


@dataclasses.dataclass
class PlatformReport:
    """One policy's platform run: the raw SimResult plus its collected
    metrics (repro.exp.metrics.CellMetrics — the shared schema)."""

    sim: SimResult
    metrics: CellMetrics
    slice_mix: Dict[str, int]
    #: Sampled-over-simulated-time summary from :mod:`repro.obs.timeseries`
    #: (fleet/busy/utilization/cost-vs-budget curves); ``None`` unless the
    #: run collected events (``run_platform(..., events=True)``).
    series: Optional[Dict[str, object]] = None
    #: Live-monitor payload (:func:`repro.obs.report.monitor_payload` —
    #: windowed series, per-QoS SLO table, alerts); ``None`` unless the
    #: run enabled the monitor (``run_platform(..., monitor=True)`` or
    #: ``REPRO_MONITOR=1``).
    monitor: Optional[Dict[str, object]] = None

    @property
    def policy(self) -> str:
        return self.metrics.policy

    @property
    def tier_hist(self) -> Dict[int, int]:
        return self.metrics.tier_hist

    @property
    def mean_makespan_s(self) -> float:
        return self.metrics.mean_makespan_s

    @property
    def p95_makespan_s(self) -> float:
        return self.metrics.p95_makespan_s

    @property
    def budget_met(self) -> float:
        return self.metrics.budget_met

    @property
    def utilization(self) -> float:
        return self.metrics.utilization

    @property
    def locality_hit_rate(self) -> float:
        return self.metrics.locality_hit_rate

    def row(self) -> str:
        return f"{format_row(self.metrics)} mix={self.slice_mix}"


def assign_budgets(cfg: PlatformConfig, wfs: Sequence[Workflow],
                   seed: int = 0, lo: float = 0.15, hi: float = 1.0) -> None:
    """Uniform budget draw — delegates to the shared
    :func:`repro.tenants.assign_budgets_uniform` code path."""
    assign_budgets_uniform(cfg, wfs, np.random.default_rng(seed), lo, hi)


def ml_tenant(n_jobs: int, rate: float, art_dir: str = "artifacts/dryrun",
              name: str = "ml-tenant", qos: QoSClass = ML_QOS) -> Tenant:
    """The ML-job stream as a :class:`repro.tenants.Tenant` — the one
    workload-construction path shared with the exp harness.  A
    single-tenant mix reproduces the legacy ``ml_workload`` +
    ``assign_budgets`` construction draw-for-draw (tenant 0 keeps the
    caller's seed)."""
    return Tenant(
        name=name, qos=qos, n_workflows=n_jobs,
        stream=lambda n, s: mljobs.ml_workload(n, rate, seed=s,
                                               art_dir=art_dir))


def ml_stream(cfg: PlatformConfig, n_jobs: int, rate: float, seed: int,
              art_dir: str = "artifacts/dryrun") -> List[Workflow]:
    """Build the budgeted ML workload through :class:`TenantMix`."""
    mix = TenantMix((ml_tenant(n_jobs, rate, art_dir),))
    return mix.build(cfg, seed).workflows


def run_platform(wfs: Sequence[Workflow], policy: Policy,
                 cfg: Optional[PlatformConfig] = None,
                 seed: int = 0,
                 events: Union[None, bool, EventLog] = None,
                 chaos: Optional[ChaosConfig] = None,
                 monitor: Union[None, bool, "obs_monitor.Monitor"] = None
                 ) -> PlatformReport:
    cfg = cfg or slices.platform_config()
    eng = SimEngine(cfg, policy, list(wfs), seed=seed, trace=True,
                    events=events, chaos=chaos, monitor=monitor)
    sim = eng.run()
    return PlatformReport(
        sim=sim,
        metrics=CellMetrics.from_result(policy.name, sim, eng.trace_rows,
                                        monitor=eng.monitor),
        slice_mix=dict(eng.pool.vm_count_by_type),
        series=(obs_ts.cell_summary(eng.elog)
                if eng.elog is not None else None),
        monitor=(obs_report.monitor_payload(eng.monitor, label=policy.name)
                 if eng.monitor is not None else None),
    )


def compare_policies(n_jobs: int = 40, rate: float = 2.0, seed: int = 0,
                     policies: Sequence[Policy] = ALL_POLICIES,
                     art_dir: str = "artifacts/dryrun"
                     ) -> List[PlatformReport]:
    cfg = slices.platform_config()
    reports = []
    for pol in policies:
        wfs = ml_stream(cfg, n_jobs, rate, seed, art_dir)
        reports.append(run_platform(wfs, pol, cfg, seed=seed))
    return reports


def sweep(n_jobs: int = 24, rates: Sequence[float] = (1.0, 4.0),
          seeds: Sequence[int] = (0,),
          policies: Sequence[Policy] = ALL_POLICIES,
          cfg: Optional[PlatformConfig] = None,
          art_dir: str = "artifacts/dryrun") -> List[Dict]:
    """The full experiment grid — policy × arrival rate × seed — in ONE
    batched engine run (core.jax_engine).

    Each (rate, seed) pair generates one workload; every policy simulates
    a structural-sharing clone of it (fresh budget fields, shared DAG
    lists), so the comparison is paired exactly as in the paper.
    Returns one summary row per grid cell.
    """
    cfg = cfg or slices.platform_config()
    members: List[GridMember] = []
    labels: List[Tuple[str, float, int]] = []
    pre: List[Dict[int, float]] = []
    for rate in rates:
        for s in seeds:
            wfs = ml_stream(cfg, n_jobs, rate, s, art_dir)
            # One arrival-time budget distribution per budget mode; every
            # policy member clones the distributed prototype.
            protos = {}
            for pol in policies:
                if pol.budget_mode not in protos:
                    protos[pol.budget_mode] = predistribute_workload(
                        cfg, wfs, pol.budget_mode)
                proto, spares = protos[pol.budget_mode]
                members.append((pol, clone_workload(proto), s))
                labels.append((pol.name, rate, s))
                pre.append(spares)
    engine = BatchSimEngine(cfg, members, trace=True, predistributed=pre)
    results = engine.run()
    rows: List[Dict] = []
    for (name, rate, s), res, st in zip(labels, results, engine.states):
        m = CellMetrics.from_result(name, res, st.trace_rows)
        rows.append({"rate_wf_per_min": rate, "seed": s, **m.to_dict()})
    return rows


def straggler_experiment(n_jobs: int = 30, rate: float = 2.0, seed: int = 0,
                         degradations: Sequence[float] = (0.1, 0.3, 0.5),
                         art_dir: str = "artifacts/dryrun",
                         slowdowns: Optional[Sequence[float]] = None,
                         straggler_prob: float = 0.1
                         ) -> Dict[str, List[Tuple[float, ...]]]:
    """Straggler mitigation = the paper's §5.2 experiment on slices:
    EBPSM's budget-update loop reallocates successors of slow stages onto
    faster slices; MSLBL's static safety net cannot.

    Two injection routes share the harness:

    * **degradation sweep** (default) — per-VM CPU degradation drawn by
      the cloud model, the paper's own perturbation.  Rows are
      ``(max_degradation, mean_makespan_s, budget_met)``.
    * **chaos sweep** (``slowdowns=(2.0, 4.0, ...)``) — seeded per-task
      runtime inflation via :class:`repro.chaos.ChaosConfig`
      (``straggler_prob`` of tasks run ``slowdown ×`` their modelled
      time), with detections (actual > ``straggler_factor ×`` estimate)
      counted by the engine.  Rows are
      ``(slowdown, mean_makespan_s, budget_met, stragglers_detected)``.
    """
    out: Dict[str, List[Tuple[float, ...]]] = {}
    for pol in (EBPSM, MSLBL_MW):
        rows: List[Tuple[float, ...]] = []
        if slowdowns is None:
            for dmax in degradations:
                cfg = slices.platform_config(
                    cpu_degradation_mean=dmax / 2, cpu_degradation_std=0.01,
                    cpu_degradation_max=dmax)
                wfs = ml_stream(cfg, n_jobs, rate, seed, art_dir)
                rep = run_platform(wfs, pol, cfg, seed=seed)
                rows.append((dmax, rep.mean_makespan_s, rep.budget_met))
        else:
            cfg = slices.platform_config()
            for slow in slowdowns:
                chaos = ChaosConfig(straggler_prob=straggler_prob,
                                    straggler_slowdown=slow,
                                    straggler_factor=max(2.0, slow / 2),
                                    seed=seed)
                wfs = ml_stream(cfg, n_jobs, rate, seed, art_dir)
                rep = run_platform(wfs, pol, cfg, seed=seed, chaos=chaos)
                rows.append((slow, rep.mean_makespan_s, rep.budget_met,
                             float(rep.metrics.stragglers_detected)))
        out[pol.name] = rows
    return out

"""WaaS-for-ML bridge: EBPSM scheduling multi-tenant TPU-slice jobs."""
from .platform import compare_policies, run_platform  # noqa: F401
from .mljobs import ml_workload  # noqa: F401

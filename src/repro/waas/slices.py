"""TPU-slice catalogue: the paper's VM types mapped onto v5e slices.

The mapping is exact (see DESIGN.md §2) — the core EBPSM engine runs
unchanged on top of it:

    VM type (MIPS, ¢/s)      → slice type (chips × eff. GFLOP/s, ¢/s)
    container image           → program + weights bundle for an arch
    container provision delay → weight/program staging from object store
    dataset in local storage  → checkpoint / dataset shard in host RAM
    task size S_t (MI)        → stage GFLOPs (from dry-run cost analysis)

Pricing stays linear in capacity (the paper's Table 2 property that makes
resource sharing profitable: compute cost is speed-invariant, overheads
price at the slice's rate).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from ..core.types import PlatformConfig, VMType

# v5e: 197 TFLOP/s bf16 per chip; MFU prior for sustained training compute.
CHIP_TFLOPS = 197.0
MFU_PRIOR = 0.40
# 1 "MI" of task size ≡ 1 GFLOP of stage work; a slice's "MIPS" is its
# sustained GFLOP/s.
GFLOPS_PER_CHIP = CHIP_TFLOPS * 1e3 * MFU_PRIOR

# Object-store staging bandwidth per slice (DCN), MB/s — plays the role of
# VM bandwidth b_vmt in Eqs. (1)-(2).
STAGE_BW_MBPS = 2_000.0
OBJ_READ_MBPS = 4_000.0
OBJ_WRITE_MBPS = 2_000.0


def slice_type(name: str, chips: int, host_ram_gb: int) -> VMType:
    return VMType(
        name=name,
        mips=chips * GFLOPS_PER_CHIP,
        storage_mb=host_ram_gb * 1024.0,
        cost_per_bp=chips * 1.0,          # ¢ per chip-second (linear)
        bandwidth_mbps=STAGE_BW_MBPS,
    )


SLICE_TYPES: Tuple[VMType, ...] = (
    slice_type("v5e-2x2", 4, 192),
    slice_type("v5e-4x4", 16, 768),
    slice_type("v5e-8x8", 64, 3072),
    slice_type("v5e-16x16", 256, 12288),
)


def platform_config(**overrides) -> PlatformConfig:
    """PlatformConfig for the TPU-slice WaaS: slice acquisition ≈ 90 s
    (cloud TPU provisioning), bundle staging modelled via Eq. (1) physics
    with the object-store rates above."""
    base = dict(
        vm_types=SLICE_TYPES,
        billing_period_ms=1_000,
        vm_provision_delay_ms=90_000,
        container_download_ms=12_000,     # program+env bundle (~24 GB @ 2 GB/s)
        container_init_ms=3_000,          # runtime + mesh init
        gs_read_mbps=OBJ_READ_MBPS,
        gs_write_mbps=OBJ_WRITE_MBPS,
        idle_threshold_ms=30_000,         # keep warm slices 30 s
    )
    base.update(overrides)
    return PlatformConfig(**base)

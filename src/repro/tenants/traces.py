"""Real-workflow trace importers: Pegasus DAX XML and WfCommons JSON.

Parses workflow descriptions from the two community formats into
:class:`core.types.Workflow`:

* **Pegasus DAX** (``<adag>`` with ``<job runtime=...>`` elements carrying
  ``<uses file=... link=input|output size=bytes/>`` and a
  ``<child><parent/></child>`` dependency section) — the format behind the
  Pegasus workflow gallery the paper's Table 1 profiles;
* **WfCommons JSON** (``workflow.tasks`` / legacy ``workflow.jobs`` arrays
  with per-task ``runtime`` seconds, ``parents`` name lists and ``files``
  size records) — the successor trace archive.

Units: traced runtime **seconds → MI** via the per-family reference-host
calibration in :mod:`repro.workflows.dax` (``TRACE_CALIBRATION``), file
**bytes → MB** scaled by the family's I/O class.  A task's ``out_mb`` is
the sum of its output file sizes (children read it as their input, exactly
like the synthetic generators); input files no task produces are staged
from global storage as ``ext_in_mb``.

Importers are **pure functions of the bytes**: no RNG, document order
preserved, every workflow passed through ``Workflow.validate`` — the same
bytes always yield an identical ``Workflow`` (gated by
``tests/test_tenants.py``), and malformed traces (cycles, dangling
parents, empty DAGs) are rejected at load time with a clear
``ValueError``, never mid-simulation.

Three small real-shaped traces are bundled under ``tenants/data/`` for
tests, docs and the ``online-*`` scenario families.
"""
from __future__ import annotations

import json
import math
import os
import xml.etree.ElementTree as ET
from typing import Dict, List, Optional, Tuple, Union

from ..core.types import Task, Workflow
from ..workflows.dax import (TRACE_FAMILY_HINTS, TraceCalibration,
                             trace_calibration)

DATA_DIR = os.path.join(os.path.dirname(__file__), "data")

Source = Union[str, bytes, os.PathLike]


def infer_family(name: str) -> Optional[str]:
    """Map a trace / namespace / application name onto a Table-1 family."""
    low = name.lower()
    for hint, family in TRACE_FAMILY_HINTS.items():
        if hint in low:
            return family
    return None


def _read(source: Source) -> bytes:
    """Accept raw bytes, an XML/JSON string, or a filesystem path."""
    if isinstance(source, bytes):
        return source
    if isinstance(source, str) and source.lstrip()[:1] in ("<", "{"):
        return source.encode("utf-8")
    with open(source, "rb") as f:
        return f.read()


def _strip_ns(tag: str) -> str:
    return tag.rsplit("}", 1)[-1]


def _finite_nonneg(x, what: str, name: str, tname: str) -> float:
    """Parse a runtime / file size field from a hostile trace: must be
    numeric, finite and non-negative — NaN runtimes would otherwise
    propagate into task sizes and poison every cost estimate
    downstream, silently."""
    try:
        v = float(x)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"trace {name!r}: task {tname!r} has non-numeric {what} "
            f"({x!r})") from e
    if math.isnan(v) or math.isinf(v):
        raise ValueError(
            f"trace {name!r}: task {tname!r} has non-finite {what} ({v!r})")
    if v < 0.0:
        raise ValueError(
            f"trace {name!r}: task {tname!r} has negative {what} ({v})")
    return v


def _finish(name: str, app: Optional[str], specs: List[dict],
            edges: List[Tuple[int, int]]) -> Workflow:
    """Assemble tasks + edges into a validated, calibrated Workflow."""
    if not specs:
        raise ValueError(f"trace {name!r}: no tasks found")
    family = infer_family(app or name)
    cal: TraceCalibration = trace_calibration(family or "")
    tasks = [
        Task(tid=i,
             size_mi=s["runtime_s"] * cal.mips,
             out_mb=s["out_mb"] * cal.mb_scale,
             ext_in_mb=s["ext_mb"] * cal.mb_scale)
        for i, s in enumerate(specs)
    ]
    for u, v in edges:
        tasks[u].children.append(v)
        tasks[v].parents.append(u)
    wf = Workflow(wid=0, app=app or family or name, tasks=tasks)
    wf.validate()
    return wf


# ---------------------------------------------------------------------------
# Pegasus DAX XML
# ---------------------------------------------------------------------------


def load_dax(source: Source, name: str = "dax") -> Workflow:
    """Parse a Pegasus DAX XML document into a Workflow."""
    try:
        root = ET.fromstring(_read(source))
    except ET.ParseError as e:
        raise ValueError(f"trace {name!r}: malformed DAX XML ({e})") from e
    if _strip_ns(root.tag) != "adag":
        raise ValueError(
            f"trace {name!r}: root element is <{_strip_ns(root.tag)}>, "
            f"expected <adag>")
    dax_name = root.get("name") or name

    ids: List[str] = []
    index: Dict[str, int] = {}
    specs: List[dict] = []
    produced: Dict[str, int] = {}          # file name -> producer position
    inputs_of: List[List[Tuple[str, float]]] = []
    namespace = None
    for el in root:
        if _strip_ns(el.tag) != "job":
            continue
        jid = el.get("id")
        if jid is None:
            raise ValueError(f"trace {name!r}: <job> without id")
        if jid in index:
            raise ValueError(f"trace {name!r}: duplicate job id {jid!r}")
        namespace = namespace or el.get("namespace")
        out_mb = 0.0
        ins: List[Tuple[str, float]] = []
        for u in el:
            if _strip_ns(u.tag) != "uses":
                continue
            fname = u.get("file") or u.get("name") or ""
            mb = _finite_nonneg(u.get("size") or 0, f"size of {fname!r}",
                                name, jid) / 1e6
            if (u.get("link") or "").lower() == "output":
                out_mb += mb
                produced[fname] = len(specs)
            else:
                ins.append((fname, mb))
        index[jid] = len(specs)
        ids.append(jid)
        specs.append({"runtime_s": _finite_nonneg(el.get("runtime") or 0.0,
                                                  "runtime", name, jid),
                      "out_mb": out_mb, "ext_mb": 0.0})
        inputs_of.append(ins)

    # Dedup repeated declarations (same parent listed twice, or the same
    # <child> relation restated): a duplicate edge would double-count the
    # parent's output in the child's input volume downstream.
    edges: List[Tuple[int, int]] = []
    seen = set()
    for el in root:
        if _strip_ns(el.tag) != "child":
            continue
        cref = el.get("ref")
        if cref not in index:
            raise ValueError(
                f"trace {name!r}: <child ref={cref!r}> names no job")
        for p in el:
            if _strip_ns(p.tag) != "parent":
                continue
            pref = p.get("ref")
            if pref not in index:
                raise ValueError(
                    f"trace {name!r}: <parent ref={pref!r}> of child "
                    f"{cref!r} names no job")
            if pref == cref:
                raise ValueError(
                    f"trace {name!r}: job {cref!r} declares itself as "
                    f"its own parent (self-edge)")
            edge = (index[pref], index[cref])
            if edge not in seen:
                seen.add(edge)
                edges.append(edge)

    # Inputs nobody produces are staged from global storage.
    for i, ins in enumerate(inputs_of):
        specs[i]["ext_mb"] = sum(
            mb for fname, mb in ins if produced.get(fname) is None)
    return _finish(dax_name, namespace.lower() if namespace else None,
                   specs, edges)


# ---------------------------------------------------------------------------
# WfCommons JSON
# ---------------------------------------------------------------------------


def load_wfcommons(source: Source, name: str = "wfcommons") -> Workflow:
    """Parse a WfCommons workflow-instance JSON into a Workflow."""
    try:
        doc = json.loads(_read(source))
    except json.JSONDecodeError as e:
        raise ValueError(
            f"trace {name!r}: malformed WfCommons JSON ({e})") from e
    if not isinstance(doc, dict):
        raise ValueError(
            f"trace {name!r}: top-level JSON is not an object")
    wf_name = doc.get("name") or name
    if not isinstance(wf_name, str):
        raise ValueError(f"trace {name!r}: workflow name is not a string")
    body = doc.get("workflow")
    if not isinstance(body, dict):
        raise ValueError(f"trace {name!r}: missing 'workflow' object")
    rows = body.get("tasks") or body.get("jobs")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"trace {name!r}: workflow has no tasks")

    index: Dict[str, int] = {}
    specs: List[dict] = []
    produced: Dict[str, int] = {}
    inputs_of: List[List[Tuple[str, float]]] = []
    for row in rows:
        if not isinstance(row, dict):
            raise ValueError(
                f"trace {name!r}: non-object task record ({row!r})")
        tname = row.get("name") or row.get("id")
        if tname is None:
            raise ValueError(f"trace {name!r}: task without name/id")
        if not isinstance(tname, str):
            raise ValueError(
                f"trace {name!r}: task name {tname!r} is not a string")
        if tname in index:
            raise ValueError(f"trace {name!r}: duplicate task {tname!r}")
        runtime = row.get("runtime", row.get("runtimeInSeconds", 0.0))
        out_mb = 0.0
        ins: List[Tuple[str, float]] = []
        files = row.get("files", [])
        if not isinstance(files, list):
            raise ValueError(
                f"trace {name!r}: task {tname!r} 'files' is not a list")
        for f in files:
            if not isinstance(f, dict):
                raise ValueError(
                    f"trace {name!r}: task {tname!r} has a non-object "
                    f"file record ({f!r})")
            fname = f.get("name") or ""
            if not isinstance(fname, str):
                raise ValueError(
                    f"trace {name!r}: task {tname!r} has a non-string "
                    f"file name ({fname!r})")
            mb = _finite_nonneg(
                f.get("sizeInBytes", f.get("size", 0)) or 0,
                f"size of {fname!r}", name, tname) / 1e6
            if str(f.get("link") or "").lower() == "output":
                out_mb += mb
                produced[fname] = len(specs)
            else:
                ins.append((fname, mb))
        index[tname] = len(specs)
        specs.append({"runtime_s": _finite_nonneg(runtime or 0.0, "runtime",
                                                  name, tname),
                      "out_mb": out_mb, "ext_mb": 0.0})
        inputs_of.append(ins)

    # Instances may declare an edge from either or both sides
    # (``parents`` and ``children``); keep first-seen order, dedup both.
    edges: List[Tuple[int, int]] = []
    seen = set()
    for row in rows:
        tname = row.get("name") or row.get("id")
        parents = row.get("parents", []) or []
        children = row.get("children", []) or []
        if not isinstance(parents, list) or not isinstance(children, list):
            raise ValueError(
                f"trace {name!r}: task {tname!r} parents/children is "
                f"not a list")
        for pref in parents:
            if not isinstance(pref, str):
                raise ValueError(
                    f"trace {name!r}: task {tname!r} has a non-string "
                    f"parent ref ({pref!r})")
            if pref not in index:
                raise ValueError(
                    f"trace {name!r}: task {tname!r} names unknown "
                    f"parent {pref!r}")
            if pref == tname:
                raise ValueError(
                    f"trace {name!r}: task {tname!r} declares itself as "
                    f"its own parent (self-edge)")
            edge = (index[pref], index[tname])
            if edge not in seen:
                seen.add(edge)
                edges.append(edge)
        for cref in children:
            if not isinstance(cref, str):
                raise ValueError(
                    f"trace {name!r}: task {tname!r} has a non-string "
                    f"child ref ({cref!r})")
            if cref not in index:
                raise ValueError(
                    f"trace {name!r}: task {tname!r} names unknown "
                    f"child {cref!r}")
            if cref == tname:
                raise ValueError(
                    f"trace {name!r}: task {tname!r} declares itself as "
                    f"its own child (self-edge)")
            edge = (index[tname], index[cref])
            if edge not in seen:
                seen.add(edge)
                edges.append(edge)

    for i, ins in enumerate(inputs_of):
        specs[i]["ext_mb"] = sum(
            mb for fname, mb in ins if produced.get(fname) is None)
    app = doc.get("application") \
        or (doc.get("workflow") or {}).get("application") or wf_name
    fam = infer_family(str(app))
    return _finish(wf_name, fam or str(app).lower(), specs, edges)


# ---------------------------------------------------------------------------
# Bundled traces + dispatch
# ---------------------------------------------------------------------------


def load_trace(path: Source, name: Optional[str] = None) -> Workflow:
    """Load a trace file, dispatching on extension (.dax/.xml vs .json)."""
    p = os.fspath(path) if not isinstance(path, bytes) else ""
    label = name or os.path.basename(p) or "trace"
    if p.endswith(".json"):
        return load_wfcommons(path, name=label)
    if p.endswith(".dax") or p.endswith(".xml"):
        return load_dax(path, name=label)
    raise ValueError(f"trace {label!r}: unknown extension (want "
                     f".dax/.xml or .json)")


def bundled_trace_names() -> Tuple[str, ...]:
    """Stems of the traces shipped under ``tenants/data/``."""
    names = [os.path.splitext(f)[0] for f in sorted(os.listdir(DATA_DIR))
             if f.endswith((".dax", ".xml", ".json"))]
    return tuple(names)


def bundled_trace(name: str) -> Workflow:
    """Parse one bundled trace by stem (fresh Workflow per call)."""
    for ext in (".dax", ".xml", ".json"):
        path = os.path.join(DATA_DIR, name + ext)
        if os.path.exists(path):
            return load_trace(path, name=name)
    raise ValueError(
        f"no bundled trace {name!r}; available: {bundled_trace_names()}")

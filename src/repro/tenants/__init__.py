"""Open-stream multi-tenant workload subsystem.

Trace importers (Pegasus DAX XML, WfCommons JSON), arrival processes
(Poisson / Markov-modulated / diurnal / trace replay), and the tenant/QoS
model that composes many tenants into one merged workflow stream for both
engines.  See README § Workloads & tenants.
"""
from .arrivals import (ArrivalProcess, Diurnal, MarkovModulated,  # noqa: F401
                       Poisson, TraceReplay)
from .model import (BRONZE, GOLD, SILVER, QoSClass, Tenant,  # noqa: F401
                    TenantMix, TenantWorkload, assign_budgets_uniform,
                    ideal_makespan_ms)
from .traces import (bundled_trace, bundled_trace_names,  # noqa: F401
                     infer_family, load_dax, load_trace, load_wfcommons)

"""Tenant and QoS model: compose many tenants into one open workflow stream.

A :class:`Tenant` is a workload source: an application mix (synthetic
Table-1 families and/or imported traces — or a legacy whole-stream
generator), an :class:`~repro.tenants.arrivals.ArrivalProcess`, and a
:class:`QoSClass` that fixes the budget-interval the tenant buys (the
paper's four budget quarters become purchasable service classes) and a
priority used to order same-millisecond arrivals.

A :class:`TenantMix` merges its tenants' streams into a single
arrival-ordered workload whose ``wid`` equals the stream position (the
engine invariant), remembers which tenant owns each workflow, and assigns
budgets per tenant via the uniform draw over ``[min_cost, max_cost]``
(``assign_budgets_uniform`` — the one budget-assignment code path shared
with ``waas.platform``).  Sub-budget *distribution* then runs through the
existing Algorithm-1 predistribution exactly as for closed grids
(``core.jax_engine.predistribute_workload``).

Everything is deterministic in (mix, cfg, seed): tenant ``i`` derives the
sub-seed ``seed + 7919·i`` (tenant 0 keeps the caller's seed, so a
single-tenant mix reproduces the legacy single-stream construction
draw-for-draw).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import budget as budget_mod
from ..core import cost_tables
from ..core.types import PlatformConfig, Workflow
from ..workflows.dax import APP_GENERATORS, generate_workflow
from ..workflows.workload import (SIZE_CLASSES,  # noqa: F401 (re-export)
                                  assign_budgets_uniform)
from . import traces
from .arrivals import ArrivalProcess

# Legacy whole-stream generator signature: (n_workflows, seed) -> list of
# arrival-stamped workflows with wid == position (budgets not yet set).
StreamFactory = Callable[[int, int], List[Workflow]]


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """A purchasable service class.

    ``budget_interval`` is the tenant's draw range over each workflow's
    ``[min_cost, max_cost]`` (the paper's budget intervals, §5);
    ``priority`` orders same-millisecond arrivals in the merged stream
    (higher first) — it does not preempt the scheduler.
    """

    name: str
    budget_interval: Tuple[float, float]
    priority: int


GOLD = QoSClass("gold", (0.75, 1.0), 2)
SILVER = QoSClass("silver", (0.40, 0.75), 1)
BRONZE = QoSClass("bronze", (0.05, 0.40), 0)


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One workload source inside a :class:`TenantMix`.

    ``apps`` entries are synthetic family names (``repro.workflows.dax``)
    or ``"trace:<stem>"`` references to bundled/imported traces; draws
    are uniform over the entries.  ``stream`` replaces the generator with
    a legacy whole-stream factory (see :data:`StreamFactory`) — used by
    ``waas.platform`` to route ML-job streams through the same mix/budget
    machinery.
    """

    name: str
    qos: QoSClass
    apps: Tuple[str, ...] = ()
    arrival: Optional[ArrivalProcess] = None
    n_workflows: int = 10
    sizes: Tuple[str, ...] = ("small",)
    start_ms: int = 0                   # stream offset (e.g. staggered tenants)
    stream: Optional[StreamFactory] = None

    def __post_init__(self):
        if self.stream is None:
            if not self.apps:
                raise ValueError(f"tenant {self.name!r}: needs apps or stream")
            if self.arrival is None:
                raise ValueError(
                    f"tenant {self.name!r}: needs an arrival process")
            for a in self.apps:
                if not a.startswith("trace:") and a not in APP_GENERATORS:
                    raise ValueError(
                        f"tenant {self.name!r}: unknown app {a!r} (not a "
                        f"family in {sorted(APP_GENERATORS)} and not a "
                        f"'trace:<stem>' reference)")


@dataclasses.dataclass
class TenantWorkload:
    """A built merged stream plus its tenant bookkeeping."""

    workflows: List[Workflow]
    tenant_of: Dict[int, str]           # wid -> tenant name
    tenants: Tuple[Tenant, ...]
    seed: int

    @property
    def qos_of(self) -> Dict[str, str]:
        return {t.name: t.qos.name for t in self.tenants}

    @property
    def priority_of(self) -> Dict[str, int]:
        return {t.name: t.qos.priority for t in self.tenants}

    def ideal_ms(self, cfg: PlatformConfig) -> Dict[int, int]:
        """Per-workflow slowdown denominators (see
        :func:`ideal_makespan_ms`)."""
        return {wf.wid: ideal_makespan_ms(cfg, wf) for wf in self.workflows}


def ideal_makespan_ms(cfg: PlatformConfig, wf: Workflow) -> int:
    """Critical-path lower bound: every task at its fastest undegraded
    per-type processing time, no queueing, no provisioning.  The slowdown
    denominator for the per-tenant online metrics."""
    table = cost_tables.table_for(cfg, wf)
    best = table.proc_ms.min(axis=1)
    finish = [0] * wf.n_tasks
    for tid in budget_mod.topological_order(wf):
        t = wf.tasks[tid]
        start = max((finish[p] for p in t.parents), default=0)
        finish[tid] = start + int(best[tid])
    return max(max(finish), 1)


def _retag(wf: Workflow, wid: int) -> None:
    """Renumber a stream member.  Engine-memoized input lists carry
    wid-keyed DataKeys, so a changed wid must drop them (clones share the
    lists by reference; cost/rank caches are wid-independent and stay)."""
    if wf.wid != wid:
        for t in wf.tasks:
            t.inputs_cache = None
        wf.wid = wid


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """A set of tenants composed into one open multi-tenant stream."""

    tenants: Tuple[Tenant, ...]

    def __post_init__(self):
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {sorted(names)}")

    @property
    def n_workflows(self) -> int:
        return sum(t.n_workflows for t in self.tenants)

    def mean_rate_per_min(self) -> float:
        return sum(t.arrival.mean_rate_per_min() for t in self.tenants
                   if t.arrival is not None)

    def budget_span(self) -> Tuple[float, float]:
        """(min lo, max hi) across the tenants' QoS budget intervals."""
        los = [t.qos.budget_interval[0] for t in self.tenants]
        his = [t.qos.budget_interval[1] for t in self.tenants]
        return (min(los), max(his))

    # -- stream construction -------------------------------------------------
    def _tenant_workflows(
        self, cfg: PlatformConfig, tenant: Tenant, tseed: int
    ) -> List[Workflow]:
        if tenant.stream is not None:
            wfs = tenant.stream(tenant.n_workflows, tseed)
            if tenant.start_ms:
                for wf in wfs:
                    wf.arrival_ms += tenant.start_ms
            rng = np.random.default_rng(tseed)
        else:
            rng = np.random.default_rng(tseed)
            times = tenant.arrival.arrival_times_ms(tenant.n_workflows, rng)
            templates: Dict[str, Workflow] = {}
            wfs = []
            for k in range(tenant.n_workflows):
                entry = tenant.apps[int(rng.integers(len(tenant.apps)))]
                if entry.startswith("trace:"):
                    stem = entry[len("trace:"):]
                    if stem not in templates:
                        templates[stem] = traces.bundled_trace(stem)
                    wf = templates[stem].clone()
                else:
                    size = SIZE_CLASSES[
                        tenant.sizes[int(rng.integers(len(tenant.sizes)))]]
                    wf = generate_workflow(entry, 0, size, rng)
                wf.arrival_ms = tenant.start_ms + times[k]
                wfs.append(wf)
        lo, hi = tenant.qos.budget_interval
        assign_budgets_uniform(cfg, wfs, rng, lo, hi)
        return wfs

    def build(self, cfg: PlatformConfig, seed: int = 0) -> TenantWorkload:
        """Generate every tenant's stream and merge by arrival time.

        Same-millisecond ties resolve by priority (higher QoS first),
        then tenant position, then submission order — the merged position
        becomes the ``wid``, which fixes the engine's same-timestamp
        arrival ordering.  Deterministic in (self, cfg, seed).
        """
        rows: List[Tuple[int, int, int, int, Workflow, Tenant]] = []
        for ti, tenant in enumerate(self.tenants):
            tseed = seed + 7919 * ti
            for k, wf in enumerate(
                    self._tenant_workflows(cfg, tenant, tseed)):
                rows.append((wf.arrival_ms, -tenant.qos.priority, ti, k,
                             wf, tenant))
        rows.sort(key=lambda r: r[:4])
        workflows: List[Workflow] = []
        tenant_of: Dict[int, str] = {}
        for i, (_, _, _, _, wf, tenant) in enumerate(rows):
            _retag(wf, i)
            workflows.append(wf)
            tenant_of[i] = tenant.name
        return TenantWorkload(workflows=workflows, tenant_of=tenant_of,
                              tenants=self.tenants, seed=seed)

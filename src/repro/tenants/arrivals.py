"""Arrival processes for open multi-tenant workflow streams.

The paper (and its companion WaaS-platform paper) evaluates a *continuous*
workload of workflows arriving at runtime; the original grid harness only
ever drew homogeneous-Poisson arrivals fixed at t=0.  This module models
the arrival side of a tenant as a first-class object:

* :class:`Poisson` — homogeneous rate (the legacy behavior as the special
  case every other process generalizes);
* :class:`MarkovModulated` — 2-state MMPP: bursty traffic that dwells in a
  quiet state and a burst state with exponential holding times;
* :class:`Diurnal` — sinusoidal rate (day/night load), sampled by Lewis &
  Shedler thinning of a dominating homogeneous process;
* :class:`TraceReplay` — replays recorded submission timestamps, optionally
  scaled and looped.

Every process is a frozen dataclass and draws exclusively from the
``numpy.random.Generator`` handed to it, so a stream is **deterministic in
(process, seed)** — the property the scenario registry, the parity tests
and the CI floors all rely on.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from ..core.types import MS


class ArrivalProcess:
    """Base class: generate ``n`` absolute arrival timestamps (ms)."""

    def arrival_times_ms(self, n: int, rng: np.random.Generator) -> List[int]:
        raise NotImplementedError

    def mean_rate_per_min(self) -> float:
        """Nominal long-run rate (reporting only)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Poisson(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate_per_min`` workflows/minute."""

    rate_per_min: float

    def __post_init__(self):
        if self.rate_per_min <= 0:
            raise ValueError(
                f"Poisson rate must be > 0, got {self.rate_per_min}")

    def arrival_times_ms(self, n: int, rng: np.random.Generator) -> List[int]:
        inter_ms = 60.0 * MS / self.rate_per_min
        gaps = rng.exponential(inter_ms, n)
        return np.floor(np.cumsum(gaps) - gaps[0]).astype(np.int64).tolist() \
            if n else []

    def mean_rate_per_min(self) -> float:
        return self.rate_per_min


@dataclasses.dataclass(frozen=True)
class MarkovModulated(ArrivalProcess):
    """2-state Markov-modulated Poisson process (bursty tenants).

    The process dwells in state 0 (``quiet_rate_per_min``) and state 1
    (``burst_rate_per_min``) with exponential holding times of mean
    ``mean_dwell_s`` each, emitting Poisson arrivals at the state's rate.
    A zero-rate state emits nothing for its whole dwell (the interrupted-
    Poisson silent/burst special case).
    """

    quiet_rate_per_min: float
    burst_rate_per_min: float
    mean_dwell_s: float = 60.0

    def __post_init__(self):
        if self.quiet_rate_per_min < 0 or self.burst_rate_per_min < 0:
            raise ValueError("MMPP rates must be >= 0")
        if self.quiet_rate_per_min == 0 and self.burst_rate_per_min == 0:
            raise ValueError("MMPP needs at least one state rate > 0")
        if self.mean_dwell_s <= 0:
            raise ValueError(
                f"MMPP mean_dwell_s must be > 0, got {self.mean_dwell_s}")

    def arrival_times_ms(self, n: int, rng: np.random.Generator) -> List[int]:
        rates = (self.quiet_rate_per_min, self.burst_rate_per_min)
        out: List[int] = []
        t = 0.0
        state = 0
        state_end = rng.exponential(self.mean_dwell_s * MS)
        while len(out) < n:
            if rates[state] == 0.0:
                # Silent state: no arrivals until the dwell expires.
                t = state_end
                state = 1 - state
                state_end = t + rng.exponential(self.mean_dwell_s * MS)
                continue
            gap = rng.exponential(60.0 * MS / rates[state])
            if t + gap >= state_end:
                # Jump to the state boundary and flip; the memorylessness
                # of the exponential makes discarding the partial gap
                # exact for an MMPP.
                t = state_end
                state = 1 - state
                state_end = t + rng.exponential(self.mean_dwell_s * MS)
                continue
            t += gap
            out.append(int(t))
        base = out[0] if out else 0
        return [x - base for x in out]

    def mean_rate_per_min(self) -> float:
        return 0.5 * (self.quiet_rate_per_min + self.burst_rate_per_min)


@dataclasses.dataclass(frozen=True)
class Diurnal(ArrivalProcess):
    """Sinusoidal-rate arrivals: rate(t) oscillates between ``base`` and
    ``peak`` workflows/minute with the given period (day/night load),
    sampled by thinning a homogeneous process at the peak rate."""

    base_rate_per_min: float
    peak_rate_per_min: float
    period_s: float = 24 * 3600.0
    phase: float = 0.0            # radians; 0 starts mid-ramp

    def __post_init__(self):
        if not 0 <= self.base_rate_per_min <= self.peak_rate_per_min:
            raise ValueError(
                f"Diurnal needs 0 <= base <= peak, got "
                f"({self.base_rate_per_min}, {self.peak_rate_per_min})")
        if self.peak_rate_per_min <= 0:
            raise ValueError("Diurnal peak rate must be > 0")
        if self.period_s <= 0:
            raise ValueError(f"Diurnal period must be > 0, got "
                             f"{self.period_s}")

    def arrival_times_ms(self, n: int, rng: np.random.Generator) -> List[int]:
        lam_max = self.peak_rate_per_min
        mid = 0.5 * (self.base_rate_per_min + self.peak_rate_per_min)
        amp = 0.5 * (self.peak_rate_per_min - self.base_rate_per_min)
        out: List[int] = []
        t = 0.0
        period_ms = self.period_s * MS
        while len(out) < n:
            t += rng.exponential(60.0 * MS / lam_max)
            lam_t = mid + amp * np.sin(
                2.0 * np.pi * t / period_ms + self.phase)
            if rng.random() * lam_max <= lam_t:
                out.append(int(t))
        base = out[0] if out else 0
        return [x - base for x in out]

    def mean_rate_per_min(self) -> float:
        return 0.5 * (self.base_rate_per_min + self.peak_rate_per_min)


@dataclasses.dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replay recorded submission times (ms), scaled by ``time_scale``;
    when the trace is shorter than ``n`` the tail loops with the trace's
    own span as the loop period.  Draws nothing from the rng — replay is
    deterministic by construction."""

    times_ms: Tuple[int, ...]
    time_scale: float = 1.0

    def arrival_times_ms(self, n: int, rng: np.random.Generator) -> List[int]:
        if not self.times_ms:
            raise ValueError("TraceReplay needs at least one timestamp")
        base = self.times_ms[0]
        rel = [int((t - base) * self.time_scale) for t in self.times_ms]
        span = max(rel[-1], 1) + (rel[1] - rel[0] if len(rel) > 1 else MS)
        out = [rel[i % len(rel)] + span * (i // len(rel)) for i in range(n)]
        return out

    def mean_rate_per_min(self) -> float:
        if len(self.times_ms) < 2:
            return 0.0
        span_min = (self.times_ms[-1] - self.times_ms[0]) \
            * self.time_scale / (60.0 * MS)
        return (len(self.times_ms) - 1) / span_min if span_min > 0 else 0.0

"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (device count locks on first jax init, and smoke tests
must see 1 device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e production topology: 16×16 = 256 chips/pod; 2 pods via DCN.

    Single-pod: ("data", "model") — FSDP/DP × TP(+EP+SP).
    Multi-pod:  ("pod", "data", "model") — 'pod' extends data parallelism
    (hierarchical gradient reduction over the DCN-class axis) or hosts
    pipeline stages (parallel/pipeline).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_desc(mesh: Mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(mesh.devices.size)}

"""Roofline analysis over dry-run artifacts (TPU v5e targets).

    compute term    = FLOPs_dev / peak_FLOPs
    memory term     = bytes_dev / HBM_bw
    collective term = wire_bytes_dev / ICI_link_bw

All three in seconds per step, per device (the per-device SPMD program is
the unit cost_analysis reports; dividing global quantities by chip count
gives the same numbers).  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE) per trained token; for serve steps 2·N(+attention KV reads) per
generated token.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (conservative single-link)


def model_flops(art: Dict[str, Any], chips: int) -> float:
    """Useful-model FLOPs per step per device."""
    n_active = art["n_active_params"]
    if art["kind"] == "train":
        from ..configs.shapes import SHAPES
        sh = SHAPES[art["shape"]]
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * n_active * tokens / chips
    if art["kind"] == "prefill":
        from ..configs.shapes import SHAPES
        sh = SHAPES[art["shape"]]
        tokens = sh.seq_len * sh.global_batch
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence in the batch
    from ..configs.shapes import SHAPES
    sh = SHAPES[art["shape"]]
    return 2.0 * n_active * sh.global_batch / chips


def analyze(art: Dict[str, Any]) -> Dict[str, Any]:
    chips = art["mesh"]["n_devices"]
    t_compute = art["flops_per_device"] / PEAK_FLOPS
    t_memory = art["bytes_accessed_per_device"] / HBM_BW
    t_coll = art["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(art, chips)
    useful = mf / art["flops_per_device"] if art["flops_per_device"] else 0.0
    bound = max(terms.values())
    # the 6·N·D yardstick overestimates for SSM/decode programs (per-layer
    # matmuls are small); the program cannot contain more useful work than
    # its compiled FLOPs, so cap the numerator at the measured compute.
    mf_eff = min(mf, art["flops_per_device"])
    mfu_bound = (mf_eff / PEAK_FLOPS) / bound if bound > 0 else 0.0
    mem = art["memory"]
    # live-bytes estimate: train/decode donate params+opt / cache, so the
    # outputs alias the arguments; prefill's cache output is fresh.
    live = mem["argument_bytes"] + mem["temp_bytes"] \
        + mem["generated_code_bytes"]
    if art["kind"] == "prefill":
        live += mem["output_bytes"]
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_flops_ratio": round(useful, 4),
        "roofline_fraction": round(min(mfu_bound, 1.0), 4),
        "live_gib": round(live / 2**30, 2),
        "hbm_fit_ok": live < 16 * 2**30,
    }


def load_artifacts(art_dir: str, mesh_tag: str = "singlepod"
                   ) -> List[Dict[str, Any]]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, f"{mesh_tag}__*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(art_dir: str, mesh_tag: str = "singlepod") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful-FLOP ratio | roofline frac | HBM ok |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for art in load_artifacts(art_dir, mesh_tag):
        if "skipped" in art:
            rows.append(f"| {art['arch']} | {art['shape']} | — | — | — | "
                        f"skipped({art['skipped']}) | — | — | — |")
            continue
        a = analyze(art)
        rows.append(
            f"| {art['arch']} | {art['shape']} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
            f"{a['dominant']} | {a['useful_flops_ratio']:.3f} | "
            f"{a['roofline_fraction']:.3f} | "
            f"{'yes' if a['hbm_fit_ok'] else 'NO'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    print(table(args.dir, args.mesh))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) cell on the
# production meshes with ShapeDtypeStruct stand-ins (no allocation), then
# record memory/cost/collective artifacts for the roofline analysis.
#
# MUST be executed as its own process (``python -m repro.launch.dryrun``):
# the XLA_FLAGS line above runs before any jax import, giving 512
# placeholder host devices.  Smoke tests / benches are separate processes
# and see 1 device.
#
# Usage:
#   python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import ARCH_IDS, cells, get_config
from ..configs.shapes import SHAPES, skip_reason
from ..models.common import RunConfig
from ..models.registry import build
from ..parallel import sharding as shd
from ..serve.serve_step import build_decode_step, build_prefill
from ..train.optim import init_opt_state
from ..train.train_step import build_train_step
from .mesh import make_production_mesh, mesh_desc

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of every collective in the optimized HLO.

    The compiled module is the per-device SPMD program, so these are
    per-device (wire-side approximation) bytes.
    """
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}
    out: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "bytes": 0.0} for op in COLLECTIVE_OPS}
    # e.g.:  %all-reduce.5 = f32[16,128]{1,0} all-reduce(...)
    pat = re.compile(
        r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    for m in pat.finditer(hlo):
        tuple_types, dt, dims, op = m.groups()
        nbytes = 0.0
        if tuple_types:
            for part in tuple_types.split(","):
                mm = re.match(r"\s*(\w+)\[([\d,]*)\]", part)
                if not mm:
                    continue
                d, shape = mm.groups()
                n = 1
                for s in shape.split(","):
                    if s:
                        n *= int(s)
                nbytes += n * dt_bytes.get(d, 4)
        else:
            n = 1
            for s in (dims or "").split(","):
                if s:
                    n *= int(s)
            nbytes = n * dt_bytes.get(dt, 4)
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    return out


def _lower_model(model, mesh, shape_name: str):
    """Lower the right entry point for the cell's shape kind."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        fn, *_ = build_train_step(model, mesh, shape_name, donate=True)
        params_abs = model.abstract()
        opt_abs = {"mu": params_abs, "nu": params_abs,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        return fn.lower(params_abs, opt_abs, model.input_specs(shape_name))
    if shape.kind == "prefill":
        fn, *_ = build_prefill(model, mesh, shape_name)
        return fn.lower(model.abstract(jnp.bfloat16),
                        model.input_specs(shape_name))
    fn, *_ = build_decode_step(model, mesh, shape_name)
    return fn.lower(model.abstract(jnp.bfloat16),
                    model.state_specs(shape_name),
                    model.input_specs(shape_name)["tokens"])


def _costs_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll_bytes": sum(v["bytes"] for v in coll.values()),
            "collectives": coll}


def _probe_costs(model, mesh, shape_name: str) -> Dict[str, Any]:
    """XLA's cost analysis counts a while-loop (layer scan) body ONCE.

    Probe: compile the model UNROLLED at two shallow depths (k, 2k layers)
    and extrapolate linearly in depth — exact for a homogeneous stack, and
    k = attn_every keeps the hybrid's shared-block cadence intact.
    """
    cfg = model.cfg
    k = max(cfg.attn_every, 2) if cfg.attn_every else 2
    run = model.run.with_(scan_layers=False)
    probes = {}
    for L in (k, 2 * k):
        from ..models.registry import Model as _Model
        pm = _Model(arch=model.arch, cfg=cfg.with_(n_layers=L), run=run)
        compiled = _lower_model(pm, mesh, shape_name).compile()
        probes[L] = _costs_of(compiled)
    L_full = cfg.n_layers
    out: Dict[str, Any] = {"probe_layers": [k, 2 * k]}
    for key in ("flops", "bytes", "coll_bytes"):
        b = (probes[2 * k][key] - probes[k][key]) / k
        a = probes[k][key] - k * b
        out[key] = a + b * L_full
        out[f"{key}_per_layer"] = b
    # collective op counts extrapolated the same way
    ops: Dict[str, Dict[str, float]] = {}
    for op in COLLECTIVE_OPS:
        b_c = (probes[2 * k]["collectives"][op]["count"]
               - probes[k]["collectives"][op]["count"]) / k
        a_c = probes[k]["collectives"][op]["count"] - k * b_c
        b_b = (probes[2 * k]["collectives"][op]["bytes"]
               - probes[k]["collectives"][op]["bytes"]) / k
        a_b = probes[k]["collectives"][op]["bytes"] - k * b_b
        ops[op] = {"count": max(a_c + b_c * L_full, 0.0),
                   "bytes": max(a_b + b_b * L_full, 0.0)}
    out["collectives"] = ops
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run: Optional[RunConfig] = None,
               probe: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline artifact dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    run = run or RunConfig(remat="full")
    model = build(arch, run)

    t0 = time.time()
    lowered = _lower_model(model, mesh, shape_name)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw = _costs_of(compiled)

    art: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_desc(mesh),
        "mesh_tag": "multipod" if multi_pod else "singlepod",
        "n_params": model.n_params(),
        "n_active_params": model.n_active_params(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "raw_scan_costs": {k: raw[k] for k in ("flops", "bytes",
                                               "coll_bytes")},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    if probe:
        p = _probe_costs(model, mesh, shape_name)
        art["flops_per_device"] = p["flops"]
        art["bytes_accessed_per_device"] = p["bytes"]
        art["collective_bytes_per_device"] = p["coll_bytes"]
        art["collectives"] = p["collectives"]
        art["probe_layers"] = p["probe_layers"]
    else:
        art["flops_per_device"] = raw["flops"]
        art["bytes_accessed_per_device"] = raw["bytes"]
        art["collective_bytes_per_device"] = raw["coll_bytes"]
        art["collectives"] = raw["collectives"]
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="artifacts/dryrun")
    # §Perf hillclimb variant knobs (tagged artifacts, never overwrite base)
    ap.add_argument("--tag", default=None,
                    help="variant tag appended to artifact names")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism")
    ap.add_argument("--cast-once", action="store_true",
                    help="bf16-cast params once per step (bf16 gathers)")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    todo = []
    if args.all:
        todo = [(a, s.name) for a, s, reason in cells() if reason is None]
        skips = [(a, s.name, r) for a, s, r in cells() if r is not None]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
        skips = []

    os.makedirs(args.out, exist_ok=True)
    run = RunConfig(remat=args.remat, seq_parallel=not args.no_sp,
                    cast_params_once=args.cast_once,
                    microbatch=args.microbatch,
                    moe_capacity=args.capacity_factor)
    failures = []
    for mp in meshes:
        tag = "multipod" if mp else "singlepod"
        if args.tag:
            tag = f"{tag}-{args.tag}"
        for arch, shape in todo:
            key = f"{tag}__{arch}__{shape}"
            path = os.path.join(args.out, key + ".json")
            if os.path.exists(path):
                print(f"[skip-cached] {key}")
                continue
            print(f"[dryrun] {key} ...", flush=True)
            try:
                art = lower_cell(arch, shape, mp, run)
                with open(path, "w") as f:
                    json.dump(art, f, indent=1)
                mem_gb = sum(art["memory"].values()) / 2**30
                print(f"  ok: compile={art['compile_s']}s "
                      f"flops/dev={art['flops_per_device']:.3e} "
                      f"mem/dev={mem_gb:.2f}GiB "
                      f"coll/dev={art['collective_bytes_per_device']:.3e}B",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((key, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
        for arch, shape, reason in skips:
            path = os.path.join(args.out, f"{tag}__{arch}__{shape}.json")
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh_tag": tag,
                           "skipped": reason}, f, indent=1)
    if failures:
        print("FAILURES:")
        for k, e in failures:
            print(" ", k, e)
        raise SystemExit(1)
    print("dry-run complete.")


if __name__ == "__main__":
    main()

"""mamba2-780m [ssm] — arXiv:2405.21060 (SSD, state-space duality).

48L d_model=1536 attention-free, vocab=50280 (padded → 50432),
ssm_state=128, expand=2 → d_inner=3072, head_dim=64 → 48 SSD heads.
Runs long_500k (constant-size recurrent state decode).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_432,     # padded from 50280
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)

"""phi3-medium-14b [dense] — arXiv:2404.14219.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE SwiGLU GQA.
Heads padded 40→48 and KV 10→16 for TP=16 divisibility (GQA ratio 3 kept);
≤20% attention-FLOP waste recorded in the roofline notes.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=48,       # padded from 40
    n_kv_heads=16,    # padded from 10
    d_ff=17_920,
    vocab=100_352,
    head_dim=128,
)

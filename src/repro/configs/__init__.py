"""Per-architecture configs (one module per assigned arch) + shapes."""
from .registry import ARCH_IDS, cells, get_config  # noqa: F401
from .shapes import SHAPES, SHAPE_ORDER, Shape, skip_reason  # noqa: F401

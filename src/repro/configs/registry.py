"""Architecture registry: ``--arch <id>`` → ModelConfig, plus the
(arch × shape) cell enumeration used by the dry-run and roofline passes.
"""
from __future__ import annotations

import importlib
from typing import Dict, Iterator, List, Optional, Tuple

from ..models.common import ModelConfig
from .shapes import SHAPE_ORDER, SHAPES, Shape, skip_reason

ARCH_MODULES: Dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-32b": "qwen3_32b",
    "llama3-8b": "llama3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-1b": "internvl2_1b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-1.2b": "zamba2_1p2b",
}

ARCH_IDS: Tuple[str, ...] = tuple(ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f".{ARCH_MODULES[arch]}", __package__)
    return mod.CONFIG


def cells() -> Iterator[Tuple[str, Shape, Optional[str]]]:
    """All 40 (arch × shape) cells with skip reasons (None → runnable)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname in SHAPE_ORDER:
            shape = SHAPES[sname]
            yield arch, shape, skip_reason(cfg, shape)

"""deepseek-coder-33b [dense] — arXiv:2401.14196 (llama-arch).

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.  Query heads
padded 56→64 for TP=16 (+14% attention FLOPs, noted); the 8 KV heads do
not divide TP=16 and are kept replicated (tiny KV projections).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=64,       # padded from 56
    n_kv_heads=8,     # replicated across TP (8 ∤ 16)
    d_ff=19_200,
    vocab=32_256,
    head_dim=128,
)

"""internvl2-1b [vlm] — arXiv:2404.16821 (InternViT + InternLM2 backbone).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 (padded → 151680).
The transformer BACKBONE only: the InternViT frontend is a STUB —
``input_specs()`` provides 256 precomputed patch embeddings (dim 1024)
that are projected and placed at the sequence prefix.  Heads padded
14→16 for TP=16; the 2 KV heads stay replicated.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=16,       # padded from 14
    n_kv_heads=2,     # replicated across TP (2 ∤ 16)
    d_ff=4864,
    vocab=151_680,    # padded from 151655
    head_dim=64,
    n_patches=256,
    patch_dim=1024,
)

"""Assigned input shapes (identical across the 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / SSM state of ``seq_len``), NOT ``train_step``.  Eligibility rules
follow the assignment:
  - long_500k only for sub-quadratic archs (ssm / hybrid);
  - decode shapes skipped for encoder-only archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def skip_reason(cfg: ModelConfig, shape: Shape) -> Optional[str]:
    """None → run the cell; str → skip with this reason (recorded)."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "full-attention arch: 500k context needs sub-quadratic mixing"
    return None

"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936; MoE 60 routed
experts top-4 + 4 shared experts (shared_ff = 4·1408 = 5632).  Routed
experts padded 60→64 for EP=16 divisibility (dead experts masked in the
router; ~6% expert-capacity waste, noted in the roofline table).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151_936,
    head_dim=128,
    n_experts=60,
    n_experts_padded=64,
    top_k=4,
    shared_ff=5_632,
)

"""hubert-xlarge [audio] — arXiv:2106.07447 (w2v2-style encoder-only).

48L d_model=1280 16H d_ff=5120 vocab=504 (padded → 512 for TP=16).
Encoder-only (bidirectional attention, no decode step).  The modality
frontend (CNN feature extractor) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings [B, L, 1280].
Training objective: masked-frame cluster prediction (CE on masked
positions), mask supplied with the batch.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=512,        # padded from 504
    head_dim=80,
    causal=False,
    frame_dim=1280,
)

"""qwen3-32b [dense] — hf:Qwen/Qwen3-8B family scaled per assignment.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk-norm enabled.
head_dim=128 per the Qwen3 family (q/k RMS-normed per head before RoPE).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,     # replicated across TP (8 ∤ 16)
    d_ff=25_600,
    vocab=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (kimi).

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840; MoE 64 routed
experts top-6 (+2 shared experts → shared_ff = 2·1408 = 2816).  64 experts
divide EP=16 exactly (4 per shard).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    head_dim=128,
    n_experts=64,
    n_experts_padded=64,
    top_k=6,
    shared_ff=2_816,
)

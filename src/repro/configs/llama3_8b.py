"""llama3-8b [dense] — arXiv:2407.21783.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, RoPE θ=500k.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,     # replicated across TP (8 ∤ 16)
    d_ff=14_336,
    vocab=128_256,
    head_dim=128,
    rope_theta=500_000.0,
)

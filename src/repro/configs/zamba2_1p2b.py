"""zamba2-1.2b [hybrid] — arXiv:2411.15242 (Mamba2 + shared attn block).

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
One weight-SHARED transformer block (attention + 8192-wide SwiGLU MLP)
applied every 6 Mamba2 layers → 6 applications, each with its own KV
cache.  Runs long_500k with the KV of the shared applications sharded
by sequence over 'data' (LSE-combined distributed attention).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,        # shared block MLP width
    vocab=32_000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
)

"""Chaos knobs and the deterministic injection draws.

Three perturbation families, one config:

* **Spot revocation** — VMs provision as spot instances at
  ``(1 - spot_discount) ×`` the on-demand price; each spot VM draws an
  exponential lifetime (mean ``1 / revocation_rate`` hours) at provision
  time and is force-terminated when it elapses.  A revocation kills the
  in-flight task (its spend so far is sunk), evicts every cache the VM
  held, requeues the task and re-runs Algorithm 3 with the wasted spend
  as *negative* surplus so the spare pool + unscheduled sub-budgets
  absorb it.  ``escalate_after=N`` switches a task's *triggered
  provisions* to on-demand (full price, non-revocable) once it has been
  preempted N times — the bounded backoff ladder.
* **Task failure** — every execution attempt flips a pre-drawn Bernoulli
  coin; a failed attempt bills its full actual cost (no refunds in
  Eq. 5), caches no output, and requeues the task through the same
  debt-absorbing path.  Attempts beyond ``max_retries`` never fail, so
  the bound also guarantees termination.
* **Stragglers** — a seeded subset of tasks runs ``straggler_slowdown ×``
  slower (compute leg only, on top of the benign CPU-degradation model);
  at finish the platform *detects* a straggler when the actual compute
  time exceeds ``straggler_factor ×`` the undegraded estimate, surfaced
  as the ``stragglers_detected`` metric and ``STRAGGLER_DETECT`` events.

Determinism contract
--------------------
Every draw is a pure function of ``(ChaosConfig, simulation seed,
stable entity id)``: task draws are pre-drawn arrays indexed by the
task's global id and attempt number (the ``degradation_tables``
pattern), VM lifetimes are keyed by vmid — and vmid allocation order is
itself deterministic and engine-independent.  The same ``(seed,
config)`` therefore yields bit-exact event streams across repeat runs,
across ``SimEngine`` vs ``BatchSimEngine``, across the SoA and object
state layouts, and through checkpoint/resume (the mutable chaos state —
attempt counters, wasted-spend tally — rides the snapshot residue;
the draws are derived state, rebuilt at construction).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# Seed-sequence namespace tag separating the chaos streams from the
# degradation tables (which consume the bare seed).
CHAOS_SEED_TAG = 0xC8A05

# Sub-stream keys under the tag (fail / straggler / vm-lifetime).
_STREAM_FAIL, _STREAM_STRAGGLER, _STREAM_LIFETIME = 1, 2, 3

MS_PER_HOUR = 3_600_000.0


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Injection knobs; all zero ⇒ disabled (the benign default).

    ``revocation_rate`` is expected revocations per spot-VM-*hour*;
    ``fail_prob`` is per execution attempt; ``straggler_prob`` is per
    task (re-executions of a straggler task stay slow — slowness models
    the task's placement/input pathology, not a coin per attempt)."""

    spot_discount: float = 0.0      # fraction off the on-demand price
    revocation_rate: float = 0.0    # revocations per spot-VM-hour
    fail_prob: float = 0.0          # per-attempt Bernoulli failure
    max_retries: int = 3            # attempts ≥ this never fail (bounded)
    escalate_after: Optional[int] = None  # preemptions → on-demand provisions
    straggler_prob: float = 0.0     # fraction of tasks inflated
    straggler_slowdown: float = 4.0  # compute-leg runtime multiplier
    straggler_factor: float = 1.5   # detection: actual > factor × estimate
    seed: int = 0                   # chaos stream seed (xor'd with sim seed)

    def __post_init__(self) -> None:
        if not 0.0 <= self.spot_discount < 1.0:
            raise ValueError(f"spot_discount={self.spot_discount} "
                             "(expected [0, 1))")
        if self.revocation_rate < 0.0:
            raise ValueError(f"revocation_rate={self.revocation_rate} < 0")
        if not 0.0 <= self.fail_prob <= 1.0:
            raise ValueError(f"fail_prob={self.fail_prob} (expected [0, 1])")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries} < 0")
        if self.escalate_after is not None and self.escalate_after < 0:
            raise ValueError(f"escalate_after={self.escalate_after} < 0")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError(f"straggler_prob={self.straggler_prob} "
                             "(expected [0, 1])")
        if self.straggler_slowdown < 1.0:
            raise ValueError(f"straggler_slowdown="
                             f"{self.straggler_slowdown} < 1")
        if self.straggler_factor < 1.0:
            raise ValueError(f"straggler_factor={self.straggler_factor} < 1")

    @property
    def enabled(self) -> bool:
        """Any injection active?  False ⇒ the engines skip every chaos
        branch (zero-cost-disabled, like ``profile``/``events``)."""
        return (self.spot_enabled or self.fail_prob > 0.0
                or self.straggler_prob > 0.0)

    @property
    def spot_enabled(self) -> bool:
        """Spot pricing/revocation active (discount without churn and
        churn without discount are both valid configurations)."""
        return self.spot_discount > 0.0 or self.revocation_rate > 0.0

    def knobs(self) -> dict:
        """JSON-ready knob dump for artifacts and reports."""
        return dataclasses.asdict(self)


class ChaosDraws:
    """Pre-drawn injection tables for one simulation (derived state:
    rebuilt bit-identically from ``(config, seed)`` — never snapshotted)."""

    __slots__ = ("cfg", "fail_u", "straggler", "_life_key", "_life_scale")

    def __init__(self, cfg: ChaosConfig, total_tasks: int, seed: int):
        self.cfg = cfg
        key = (CHAOS_SEED_TAG, cfg.seed, seed)
        # Per-(task, attempt) failure uniforms: thresholding keeps the
        # *set* of failing attempts monotone in fail_prob, and bounding
        # the table at max_retries attempts makes termination structural
        # (an attempt index past the table never fails).
        self.fail_u = (
            np.random.default_rng((*key, _STREAM_FAIL))
            .random((total_tasks, cfg.max_retries))
            if cfg.fail_prob > 0.0 and cfg.max_retries > 0
            else np.zeros((total_tasks, 0)))
        self.straggler = (
            np.random.default_rng((*key, _STREAM_STRAGGLER))
            .random(total_tasks) < cfg.straggler_prob
            if cfg.straggler_prob > 0.0
            else np.zeros(total_tasks, bool))
        self._life_key = (*key, _STREAM_LIFETIME)
        self._life_scale = (MS_PER_HOUR / cfg.revocation_rate
                            if cfg.revocation_rate > 0.0 else 0.0)

    def fails(self, gid: int, attempt: int) -> bool:
        """Does execution ``attempt`` (0-based) of global task ``gid``
        fail?  Attempts ≥ ``max_retries`` (including extra re-executions
        forced by revocations) always succeed."""
        if attempt >= self.fail_u.shape[1]:
            return False
        return bool(self.fail_u[gid, attempt] < self.cfg.fail_prob)

    def vm_lifetime_ms(self, vmid: int) -> int:
        """Exponential spot lifetime for a VM, keyed by vmid (vmids are
        append-only list indices, so the allocation order — and hence
        every lifetime — is identical across engines and layouts)."""
        rng = np.random.default_rng((*self._life_key, vmid))
        return max(1, int(math.ceil(rng.exponential(self._life_scale))))


def chaos_draws(cfg: Optional[ChaosConfig], total_tasks: int,
                seed: int) -> Optional[ChaosDraws]:
    """Build the draw tables, or None when injection is off."""
    if cfg is None or not cfg.enabled:
        return None
    return ChaosDraws(cfg, total_tasks, seed)

"""Deterministic fault injection (``repro.chaos``).

Adversarial-infrastructure layer for the WaaS simulator: spot/preemptible
VM revocation, per-task failure with bounded retry, and straggler
(runtime-inflation) injection — all first-class simulated events wired
through both engines (``core.engine.SimState`` transitions, driven by
``SimEngine`` and ``core.jax_engine.BatchSimEngine`` alike).

See :mod:`repro.chaos.inject` for the knobs and the determinism contract,
docs/ARCHITECTURE.md § Fault model for the state transitions, and the
``online-chaos-smoke`` / ``online-chaos`` scenario families
(``repro.exp.scenarios``) for the CI-gated consumers.
"""
from .inject import (CHAOS_SEED_TAG, ChaosConfig,  # noqa: F401
                     ChaosDraws, chaos_draws)

"""Scenario registry for the paper-grid evaluation.

A :class:`Scenario` names one evaluation grid: applications × arrival
rates × budget intervals × policies × seeds, plus the workload scale.
The paper's experiment design (§5, workload construction following the
authors' WaaS-platform paper) draws each cell's budgets uniformly from
one quarter of the per-workflow ``[min_cost, max_cost]`` range — the four
*budget intervals* — and streams a single application's workflows at a
Poisson arrival rate.

``paper`` is the full grid behind Figs. 3–4 (hours of simulated
scheduling — run it with ``--full``-style patience); ``paper-smoke`` is
the CI-sized reduction (2 apps × 2 rates × 2 budget intervals × all five
policies × 1 seed) that the ``exp-smoke`` CI job gates on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple, Union

from ..chaos import ChaosConfig
from ..core.scheduler import ALL_POLICIES, Policy
from ..tenants import (BRONZE, GOLD, SILVER, Diurnal, MarkovModulated,
                       Poisson, Tenant, TenantMix)

POLICY_BY_NAME: Dict[str, Policy] = {p.name: p for p in ALL_POLICIES}

# The paper's four budget intervals over [min_cost, max_cost].
PAPER_BUDGET_INTERVALS: Tuple[Tuple[float, float], ...] = (
    (0.0, 0.25), (0.25, 0.5), (0.5, 0.75), (0.75, 1.0),
)

PAPER_APPS = ("cybershake", "epigenome", "ligo", "montage", "sipht")


@dataclasses.dataclass(frozen=True)
class WorkloadCell:
    """One workload configuration (all policies simulate a clone of it)."""

    app: str
    rate: float                       # workflows / minute
    budget_interval: Tuple[float, float]
    seed: int                         # degradation seed; workload seed derives
    index: int                        # stable position in the scenario grid

    @property
    def workload_seed(self) -> int:
        """Deterministic per-cell workload draw, decorrelated from the
        degradation seed (7919 = 1000th prime, no magic beyond reuse)."""
        return 7919 * (self.seed + 1) + self.index


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    apps: Tuple[str, ...]
    rates: Tuple[float, ...]
    budget_intervals: Tuple[Tuple[float, float], ...]
    policies: Tuple[str, ...]
    seeds: Tuple[int, ...]
    n_workflows: int                  # workflows per cell workload
    sizes: Tuple[str, ...]
    # CI floor: every EBPSM cell must keep budget-met % at or above this
    # (recorded from the artifact trajectory; see exp-smoke in ci.yml).
    ebpsm_budget_met_floor: float = 0.0

    def workload_cells(self) -> Iterator[WorkloadCell]:
        idx = 0
        for app in self.apps:
            for rate in self.rates:
                for interval in self.budget_intervals:
                    for seed in self.seeds:
                        yield WorkloadCell(app, rate, interval, seed, idx)
                        idx += 1

    @property
    def n_workload_cells(self) -> int:
        return (len(self.apps) * len(self.rates)
                * len(self.budget_intervals) * len(self.seeds))

    @property
    def n_cells(self) -> int:
        return self.n_workload_cells * len(self.policies)


ALL_POLICY_NAMES = tuple(p.name for p in ALL_POLICIES)

SCENARIOS: Dict[str, Scenario] = {
    "paper": Scenario(
        name="paper",
        description=("Full Figs. 3-4 grid: 5 Pegasus apps x arrival rates "
                     "{0.5, 6, 12} wf/min x 4 budget intervals x all 5 "
                     "policies x 3 seeds, 100 workflows per cell."),
        apps=PAPER_APPS,
        rates=(0.5, 6.0, 12.0),
        budget_intervals=PAPER_BUDGET_INTERVALS,
        policies=ALL_POLICY_NAMES,
        seeds=(0, 1, 2),
        n_workflows=100,
        sizes=("small", "medium", "large"),
        ebpsm_budget_met_floor=0.80,
    ),
    "paper-smoke": Scenario(
        name="paper-smoke",
        description=("CI reduction of the paper grid: 2 apps x 2 rates x "
                     "2 budget intervals x all 5 policies x 1 seed, small "
                     "workloads."),
        apps=("montage", "sipht"),
        rates=(0.5, 6.0),
        budget_intervals=((0.25, 0.5), (0.75, 1.0)),
        policies=ALL_POLICY_NAMES,
        seeds=(0,),
        n_workflows=10,
        sizes=("small",),
        ebpsm_budget_met_floor=0.90,
    ),
    "degradation": Scenario(
        name="degradation",
        description=("Figs. 5-6 companion: EBPSM vs MSLBL_MW under the "
                     "default degradation model across rates and the two "
                     "outer budget intervals."),
        apps=("cybershake", "epigenome", "ligo"),
        rates=(6.0,),
        budget_intervals=((0.0, 0.25), (0.75, 1.0)),
        policies=("EBPSM", "MSLBL_MW"),
        seeds=(0, 1),
        n_workflows=30,
        sizes=("small", "medium"),
        ebpsm_budget_met_floor=0.70,
    ),
}


# ---------------------------------------------------------------------------
# Online (open-stream) scenario families — repro.tenants
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnlineScenario:
    """An open multi-tenant stream scenario: one :class:`TenantMix`
    streamed through every policy, per seed.

    Unlike the closed :class:`Scenario` grids (one app × one rate × one
    budget interval per cell), an online cell is the *merged* stream —
    heterogeneous apps, imported traces, bursty/diurnal arrivals and
    per-tenant QoS budget classes — with the first ``warmup_s`` of
    arrivals excluded from the metrics (cold-start truncation).
    """

    name: str
    description: str
    mix: TenantMix
    policies: Tuple[str, ...]
    seeds: Tuple[int, ...]
    warmup_s: float = 0.0
    ebpsm_budget_met_floor: float = 0.0
    # CI ceiling on EBPSM's p95 workflow slowdown (0 = not gated).
    # Recorded from the artifact trajectory like the budget-met floor.
    p95_slowdown_ceiling: float = 0.0
    # Fault-injection knobs (repro.chaos); None ⇒ the benign stream.
    chaos: Optional[ChaosConfig] = None
    # CI ceiling on EBPSM's wasted-spend fraction (cost sunk into killed/
    # failed attempts ÷ total spend; 0 = not gated).
    wasted_spend_ceiling: float = 0.0
    # CI floor on live-monitor alert counts, {alert kind name: min count}
    # summed over the scenario's cells (repro.obs.slo.ALERT_KIND_NAMES).
    # Declaring floors REQUIRES the run to carry a monitor (--report-dir
    # or REPRO_MONITOR=1): check_floors fails rather than passing
    # vacuously when the monitor block is disabled.  None ⇒ not gated.
    alert_floors: Optional[Dict[str, int]] = None

    @property
    def n_workload_cells(self) -> int:
        return len(self.seeds)

    @property
    def n_cells(self) -> int:
        return self.n_workload_cells * len(self.policies)

    @property
    def n_workflows(self) -> int:
        return self.mix.n_workflows


# The CI-gated smoke mix: three tenants spanning all four workload axes —
# synthetic + imported-trace apps, three arrival processes, three QoS
# classes — small enough for the exp-smoke job (< 60 s, see ci.yml).
ONLINE_SMOKE_MIX = TenantMix((
    Tenant("astro-survey", GOLD,
           apps=("montage", "trace:montage-18"),
           arrival=Poisson(10.0), n_workflows=24, sizes=("small",)),
    Tenant("bio-lab", SILVER,
           apps=("epigenome", "trace:epigenomics-20"),
           arrival=Diurnal(4.0, 14.0, period_s=300.0),
           n_workflows=16, sizes=("small",)),
    Tenant("seismo-batch", BRONZE,
           apps=("sipht", "trace:seismology-9"),
           arrival=MarkovModulated(2.0, 20.0, mean_dwell_s=60.0),
           n_workflows=24, sizes=("small",)),
))

# The heavy mix: every Table-1 family plus all bundled traces, higher
# rates, staggered tenant onboarding — the intended stress consumer.
ONLINE_HEAVY_MIX = TenantMix((
    Tenant("astro-survey", GOLD,
           apps=("montage", "cybershake", "trace:montage-18"),
           arrival=Poisson(12.0), n_workflows=40,
           sizes=("small", "medium")),
    Tenant("bio-lab", GOLD,
           apps=("epigenome", "trace:epigenomics-20"),
           arrival=Diurnal(4.0, 16.0, period_s=1800.0),
           n_workflows=30, sizes=("small", "medium")),
    Tenant("grav-obs", SILVER,
           apps=("ligo",),
           arrival=MarkovModulated(2.0, 20.0, mean_dwell_s=120.0),
           n_workflows=30, sizes=("small", "medium")),
    Tenant("seismo-batch", BRONZE,
           apps=("sipht", "trace:seismology-9"),
           arrival=MarkovModulated(1.0, 24.0, mean_dwell_s=90.0),
           n_workflows=40, sizes=("small",)),
    Tenant("late-joiner", BRONZE,
           apps=("montage", "sipht"),
           arrival=Poisson(8.0), n_workflows=20, sizes=("small",),
           start_ms=120_000),
))

# The long-horizon mix: ≥1k workflows across the bundled synthetic +
# trace families at low arrival rates, so the merged stream spans a
# multi-hour simulated horizon — the checkpoint/resume consumer
# (``--ckpt-every-s`` / ``--resume``) and the SoA scale testbed.
ONLINE_LONGHAUL_MIX = TenantMix((
    Tenant("astro-survey", GOLD,
           apps=("montage", "trace:montage-18"),
           arrival=Poisson(3.0), n_workflows=360, sizes=("small",)),
    Tenant("bio-lab", SILVER,
           apps=("epigenome", "trace:epigenomics-20"),
           arrival=Diurnal(1.5, 5.0, period_s=3600.0),
           n_workflows=320, sizes=("small",)),
    Tenant("seismo-batch", BRONZE,
           apps=("sipht", "trace:seismology-9"),
           arrival=MarkovModulated(1.0, 6.0, mean_dwell_s=600.0),
           n_workflows=360, sizes=("small",)),
))

# The chaos mixes stream ≥4 workflow families — montage + epigenomics +
# cybershake (seismology-family calibration) + seismology traces plus the
# synthetic generators — so injected churn hits heterogeneous DAG shapes.
ONLINE_CHAOS_MIX = TenantMix((
    Tenant("astro-survey", GOLD,
           apps=("montage", "trace:montage-18"),
           arrival=Poisson(10.0), n_workflows=20, sizes=("small",)),
    Tenant("bio-lab", SILVER,
           apps=("epigenome", "trace:epigenomics-20"),
           arrival=Diurnal(4.0, 14.0, period_s=300.0),
           n_workflows=16, sizes=("small",)),
    Tenant("seismo-batch", BRONZE,
           apps=("trace:cybershake-12", "trace:seismology-9"),
           arrival=MarkovModulated(2.0, 20.0, mean_dwell_s=60.0),
           n_workflows=20, sizes=("small",)),
))

ONLINE_CHAOS_HEAVY_MIX = TenantMix((
    Tenant("astro-survey", GOLD,
           apps=("montage", "cybershake", "trace:montage-18"),
           arrival=Poisson(12.0), n_workflows=36,
           sizes=("small", "medium")),
    Tenant("bio-lab", GOLD,
           apps=("epigenome", "trace:epigenomics-20"),
           arrival=Diurnal(4.0, 16.0, period_s=1800.0),
           n_workflows=28, sizes=("small", "medium")),
    Tenant("grav-obs", SILVER,
           apps=("ligo", "trace:cybershake-12"),
           arrival=MarkovModulated(2.0, 20.0, mean_dwell_s=120.0),
           n_workflows=28, sizes=("small", "medium")),
    Tenant("seismo-batch", BRONZE,
           apps=("sipht", "trace:seismology-9"),
           arrival=MarkovModulated(1.0, 24.0, mean_dwell_s=90.0),
           n_workflows=36, sizes=("small",)),
))

# The CI-gated chaos knobs: 60 % spot discount with a 6/hour revocation
# process, 2 % per-attempt failures (≤ 3 retries, on-demand escalation
# after 2 preemptions) and 5 % stragglers at 4× slowdown, detected at 2×
# the undegraded estimate.
CHAOS_SMOKE = ChaosConfig(
    spot_discount=0.6, revocation_rate=6.0,
    fail_prob=0.02, max_retries=3, escalate_after=2,
    straggler_prob=0.05, straggler_slowdown=4.0, straggler_factor=2.0,
)

# The heavy family doubles the churn: mean spot lifetime 5 simulated
# minutes, 5 % failures, 10 % stragglers.
CHAOS_HEAVY = ChaosConfig(
    spot_discount=0.6, revocation_rate=12.0,
    fail_prob=0.05, max_retries=3, escalate_after=2,
    straggler_prob=0.10, straggler_slowdown=4.0, straggler_factor=2.0,
)

ONLINE_SCENARIOS: Dict[str, OnlineScenario] = {
    "online-smoke": OnlineScenario(
        name="online-smoke",
        description=("CI-sized open-stream mix: 3 tenants (gold/silver/"
                     "bronze QoS) x {Poisson, diurnal, bursty MMPP} "
                     "arrivals x {synthetic, DAX-trace, WfCommons-trace} "
                     "apps, all 5 policies, warm-up truncated."),
        mix=ONLINE_SMOKE_MIX,
        policies=ALL_POLICY_NAMES,
        seeds=(0,),
        warmup_s=30.0,
        ebpsm_budget_met_floor=0.85,
    ),
    "online-heavy": OnlineScenario(
        name="online-heavy",
        description=("Stress open-stream mix: 5 tenants, 160 workflows, "
                     "bursty/diurnal arrivals, staggered onboarding, "
                     "mixed sizes — the autoscaling/admission-control "
                     "testbed."),
        mix=ONLINE_HEAVY_MIX,
        policies=("EBPSM", "MSLBL_MW"),
        seeds=(0, 1),
        warmup_s=120.0,
        ebpsm_budget_met_floor=0.60,
    ),
    "online-longhaul": OnlineScenario(
        name="online-longhaul",
        description=("Long-horizon open stream: 3 tenants, 1040 workflows "
                     "across synthetic + trace families at ~2 h of "
                     "simulated arrivals — the checkpoint/resume and "
                     "SoA-scale consumer; budget-met floor AND p95 "
                     "slowdown ceiling gated."),
        mix=ONLINE_LONGHAUL_MIX,
        policies=("EBPSM", "MSLBL_MW"),
        seeds=(0,),
        warmup_s=600.0,
        # Recorded trajectory: budget_met 0.978, p95 slowdown 10.13
        # (seed 0); floors leave ~3 pp / ~18 % headroom.
        ebpsm_budget_met_floor=0.95,
        p95_slowdown_ceiling=12.0,
    ),
    "online-chaos-smoke": OnlineScenario(
        name="online-chaos-smoke",
        description=("CI-sized adversarial-infrastructure mix: 3 tenants "
                     "across 4 workflow families (montage/epigenomics/"
                     "cybershake/seismology) under spot revocation "
                     "(60 % discount, 6/h churn), 2 % task failures and "
                     "5 % injected stragglers; gates EBPSM budget-met "
                     "and wasted-spend under churn."),
        mix=ONLINE_CHAOS_MIX,
        policies=ALL_POLICY_NAMES,
        seeds=(0,),
        warmup_s=30.0,
        chaos=CHAOS_SMOKE,
        # Recorded trajectory (seed 0): budget_met 0.971, wasted-spend
        # frac 0.073 — floors leave headroom for scheduling drift while
        # still catching absorbed-debt regressions.
        ebpsm_budget_met_floor=0.85,
        wasted_spend_ceiling=0.12,
        # Live-monitor gate: the chaos knobs must trip at least one
        # wasted-spend burn and one straggler-rate spike somewhere in
        # the stream (per-policy monitors summed; repro.obs.monitor).
        alert_floors={"budget_burn": 1, "straggler_spike": 1},
    ),
    "online-chaos": OnlineScenario(
        name="online-chaos",
        description=("Full adversarial-infrastructure stress: 4 tenants, "
                     "128 workflows across 6 families, 12/h spot churn, "
                     "5 % failures, 10 % stragglers at 4x, 2 seeds — "
                     "the resilience testbed behind the chaos metrics."),
        mix=ONLINE_CHAOS_HEAVY_MIX,
        policies=("EBPSM", "EBPSM_NS", "MSLBL_MW"),
        seeds=(0, 1),
        warmup_s=120.0,
        chaos=CHAOS_HEAVY,
        # Recorded trajectory: budget_met 0.939/1.000, wasted-spend frac
        # ~0.147 (seeds 0/1).
        ebpsm_budget_met_floor=0.85,
        wasted_spend_ceiling=0.20,
    ),
}

AnyScenario = Union[Scenario, OnlineScenario]


def get_scenario(name: str) -> AnyScenario:
    if name in SCENARIOS:
        return SCENARIOS[name]
    if name in ONLINE_SCENARIOS:
        return ONLINE_SCENARIOS[name]
    raise SystemExit(
        f"unknown grid {name!r}; choose from "
        f"{sorted(SCENARIOS) + sorted(ONLINE_SCENARIOS)}"
    )

"""Paper-grid evaluation subsystem: scenario registry, metrics collection,
and the reproduction harness (``python -m repro.exp.run --grid <name>``)."""

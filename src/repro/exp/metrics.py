"""Per-cell metrics collection for the evaluation grid.

One :class:`CellMetrics` summarizes one (scenario cell × policy)
simulation: the paper's headline quantities (makespan, cost/budget ratio,
budget-met %, VM usage) plus the resource-sharing actuals that make the
policy comparison explainable (container/data-cache hit rates, placement
tier histogram).  ``waas.platform`` and the ``repro.exp.run`` harness both
consume this collector, so every report in the repo speaks one schema —
see the metrics glossary in README.md.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.types import SimResult


@dataclasses.dataclass
class CellMetrics:
    """Summary of one simulation run (one grid cell × policy)."""

    policy: str
    n_workflows: int
    mean_makespan_s: float
    p95_makespan_s: float
    mean_cost_budget_ratio: float
    budget_met: float             # fraction of workflows with cost ≤ budget
    utilization: float            # busy-seconds / lease-seconds, all VMs
    total_vms: int
    vm_lease_s: float             # Σ leased VM-seconds (spend proxy)
    data_cache_hit_rate: float    # input MB served locally / total input MB
    container_hit_rate: float     # activations that skipped the download
    # Placement-tier histogram (1=input-data locality, 2=warm container,
    # 3=any idle, 4=new VM, 5=insufficient-budget fallback); empty when
    # the run was not traced.
    tier_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # ---- online / multi-tenant extensions (zero-valued for closed grids).
    # Slowdown = makespan ÷ critical-path ideal (tenants.ideal_makespan_ms);
    # requires ``ideal_ms`` at collection time.
    p50_slowdown: float = 0.0
    p95_slowdown: float = 0.0
    # Jain fairness index over per-tenant mean slowdowns: 1 = every tenant
    # slowed equally, 1/n = one tenant absorbs all the queueing.
    jain_fairness: float = 0.0
    # Fleet size over time (from SimResult lease intervals).
    peak_vms: int = 0
    mean_fleet_vms: float = 0.0
    # Workflows that arrived during warm-up and were excluded from every
    # statistic above (online scenarios truncate the cold-start ramp).
    n_warmup_excluded: int = 0
    # Per-tenant and per-QoS-class breakdowns:
    # {name: {n, budget_met, mean_makespan_s, p50_slowdown, p95_slowdown}}.
    by_tenant: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    by_qos: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    # ---- fault-injection tallies (repro.chaos; zeros on benign runs).
    # wasted_spend_frac = cost sunk into attempts that produced no output
    # ÷ total spend of all workflows (both unfiltered by warm-up — waste
    # is a whole-run platform quantity, not a per-workflow statistic).
    revocations: int = 0
    task_failures: int = 0
    task_retries: int = 0
    stragglers_detected: int = 0
    wasted_cost: float = 0.0
    wasted_spend_frac: float = 0.0
    spot_vms: int = 0
    # ---- live-monitor tallies (repro.obs.monitor; zeros unless the run
    # carried a monitor).  alerts_open counts alerts still firing at the
    # horizon; alerts_by_kind keys are repro.obs.slo.ALERT_KIND_NAMES.
    alerts_total: int = 0
    alerts_open: int = 0
    alerts_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)

    @staticmethod
    def _group_stats(rows: List[tuple]) -> Dict:
        """rows: (makespan_ms, met, slowdown-or-nan)."""
        mks = np.array([r[0] for r in rows], np.float64)
        slow = np.array([r[2] for r in rows], np.float64)
        have_slow = len(slow) and not np.isnan(slow).any()
        return {
            "n": len(rows),
            "budget_met": float(np.mean([r[1] for r in rows])),
            "mean_makespan_s": float(mks.mean()) / 1000.0,
            "p50_slowdown": float(np.percentile(slow, 50))
            if have_slow else 0.0,
            "p95_slowdown": float(np.percentile(slow, 95))
            if have_slow else 0.0,
        }

    @classmethod
    def from_result(
        cls,
        policy: str,
        res: SimResult,
        trace_rows: Optional[Sequence[tuple]] = None,
        tenant_of: Optional[Dict[int, str]] = None,
        qos_of: Optional[Dict[str, str]] = None,
        ideal_ms: Optional[Dict[int, int]] = None,
        warmup_ms: int = 0,
        monitor=None,
    ) -> "CellMetrics":
        """``tenant_of`` (wid → tenant), ``qos_of`` (tenant → QoS class)
        and ``ideal_ms`` (wid → critical-path lower bound) switch on the
        per-tenant online metrics; ``warmup_ms`` drops workflows that
        arrived before it from every statistic (cold-start truncation);
        ``monitor`` (a :class:`repro.obs.monitor.Monitor`) fills the
        alert tallies."""
        wfs = [w for w in res.workflows if w.arrival_ms >= warmup_ms]
        n_excluded = len(res.workflows) - len(wfs)
        mks = np.array([w.makespan_ms for w in wfs], np.float64)
        ratios = np.array([w.cost_budget_ratio for w in wfs], np.float64)
        # Truncation covers the tier histogram too: placements made by
        # warm-up-excluded workflows (trace row = (t, wid, tid, tier, ...))
        # must not bias the locality rates of the reported set.
        kept = {w.wid for w in wfs}
        tiers = (
            dict(sorted(collections.Counter(
                r[3] for r in trace_rows if r[1] in kept).items()))
            if trace_rows else {}
        )
        slowdowns = {
            w.wid: w.makespan_ms / max(ideal_ms.get(w.wid, 0), 1)
            for w in wfs
        } if ideal_ms else {}
        p50 = p95 = 0.0
        if slowdowns:
            vals = np.array(list(slowdowns.values()), np.float64)
            p50 = float(np.percentile(vals, 50))
            p95 = float(np.percentile(vals, 95))
        by_tenant: Dict[str, Dict] = {}
        by_qos: Dict[str, Dict] = {}
        jain = 0.0
        if tenant_of:
            grouped: Dict[str, List[tuple]] = {}
            for w in wfs:
                row = (w.makespan_ms, w.budget_met,
                       slowdowns.get(w.wid, float("nan")))
                grouped.setdefault(tenant_of.get(w.wid, "?"), []).append(row)
            by_tenant = {name: cls._group_stats(rows)
                         for name, rows in sorted(grouped.items())}
            if qos_of:
                q_rows: Dict[str, List[tuple]] = {}
                for name, rows in grouped.items():
                    q_rows.setdefault(qos_of.get(name, "?"), []).extend(rows)
                by_qos = {q: cls._group_stats(rows)
                          for q, rows in sorted(q_rows.items())}
            if slowdowns:
                per_tenant_mean = np.array([
                    np.mean([r[2] for r in rows])
                    for rows in grouped.values()], np.float64)
                jain = float(per_tenant_mean.sum() ** 2
                             / (len(per_tenant_mean)
                                * (per_tenant_mean ** 2).sum()))
        # Budget-met over the post-warmup set (res.budget_met_fraction
        # would include warm-up workflows).
        met = float(np.mean([w.budget_met for w in wfs])) if wfs else 1.0
        total_spend = float(sum(w.cost for w in res.workflows))
        return cls(
            policy=policy,
            n_workflows=len(wfs),
            mean_makespan_s=float(mks.mean()) / 1000.0 if len(mks) else 0.0,
            p95_makespan_s=float(np.percentile(mks, 95)) / 1000.0
            if len(mks) else 0.0,
            mean_cost_budget_ratio=float(ratios.mean()) if len(ratios) else 0.0,
            budget_met=met,
            utilization=res.avg_vm_utilization,
            total_vms=res.total_vms,
            vm_lease_s=float(sum(res.vm_seconds_by_type.values())),
            data_cache_hit_rate=res.data_cache_hit_rate,
            container_hit_rate=res.container_hit_rate,
            tier_hist=tiers,
            p50_slowdown=p50,
            p95_slowdown=p95,
            jain_fairness=jain,
            peak_vms=res.peak_vms,
            mean_fleet_vms=res.mean_fleet_vms,
            n_warmup_excluded=n_excluded,
            by_tenant=by_tenant,
            by_qos=by_qos,
            revocations=res.revocations,
            task_failures=res.task_failures,
            task_retries=res.task_retries,
            stragglers_detected=res.stragglers_detected,
            wasted_cost=res.wasted_cost,
            wasted_spend_frac=(res.wasted_cost / total_spend
                               if total_spend > 0 else 0.0),
            spot_vms=res.spot_vms,
            alerts_total=len(monitor.alerts) if monitor is not None else 0,
            alerts_open=(sum(1 for a in monitor.alerts if a.open)
                         if monitor is not None else 0),
            alerts_by_kind=(monitor.alerts_by_kind()
                            if monitor is not None else {}),
        )

    @property
    def locality_hit_rate(self) -> float:
        """Fraction of placements on a VM already holding all inputs."""
        total = sum(self.tier_hist.values())
        return self.tier_hist.get(1, 0) / total if total else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["locality_hit_rate"] = self.locality_hit_rate
        d["tier_hist"] = {str(k): v for k, v in self.tier_hist.items()}
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "CellMetrics":
        """Inverse of :meth:`to_dict` (ignores extra keys — artifact rows
        carry cell coordinates alongside the metrics)."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["tier_hist"] = {int(k): v
                           for k, v in d.get("tier_hist", {}).items()}
        return cls(**kw)


def format_row(m: CellMetrics) -> str:
    """One-line human-readable summary (examples / REPL use)."""
    return (f"{m.policy:10s} mk={m.mean_makespan_s:9.1f}s "
            f"p95={m.p95_makespan_s:9.1f}s met={m.budget_met:6.2%} "
            f"util={m.utilization:6.2%} warm={m.locality_hit_rate:6.2%} "
            f"data-hit={m.data_cache_hit_rate:6.2%} "
            f"cont-hit={m.container_hit_rate:6.2%}")


def aggregate_by_policy(cells: Sequence[CellMetrics]) -> Dict[str, Dict]:
    """Across-cell aggregates per policy: mean of the cell means (every
    cell weighs equally, matching the paper's per-configuration figures)
    plus the worst cell for the floor-gated quantities."""
    by_pol: Dict[str, List[CellMetrics]] = {}
    for m in cells:
        by_pol.setdefault(m.policy, []).append(m)
    out: Dict[str, Dict] = {}
    for pol, ms in sorted(by_pol.items()):
        out[pol] = {
            "cells": len(ms),
            "mean_makespan_s": float(np.mean([m.mean_makespan_s for m in ms])),
            "mean_cost_budget_ratio": float(
                np.mean([m.mean_cost_budget_ratio for m in ms])),
            "budget_met_mean": float(np.mean([m.budget_met for m in ms])),
            "budget_met_min": float(np.min([m.budget_met for m in ms])),
            "utilization_mean": float(np.mean([m.utilization for m in ms])),
            "data_cache_hit_rate_mean": float(
                np.mean([m.data_cache_hit_rate for m in ms])),
            "container_hit_rate_mean": float(
                np.mean([m.container_hit_rate for m in ms])),
            # Online extensions (zero for closed grids).
            "p50_slowdown_mean": float(np.mean([m.p50_slowdown for m in ms])),
            "p95_slowdown_mean": float(np.mean([m.p95_slowdown for m in ms])),
            "jain_fairness_min": float(np.min([m.jain_fairness for m in ms])),
            "peak_vms_max": int(np.max([m.peak_vms for m in ms])),
            # Chaos tallies (zeros on benign runs).
            "revocations_total": int(np.sum([m.revocations for m in ms])),
            "task_failures_total": int(np.sum([m.task_failures
                                               for m in ms])),
            "task_retries_total": int(np.sum([m.task_retries for m in ms])),
            "stragglers_total": int(np.sum([m.stragglers_detected
                                            for m in ms])),
            "wasted_spend_frac_mean": float(
                np.mean([m.wasted_spend_frac for m in ms])),
            "wasted_spend_frac_max": float(
                np.max([m.wasted_spend_frac for m in ms])),
            # Live-monitor alert tallies (zeros unless monitored).
            "alerts_total": int(np.sum([m.alerts_total for m in ms])),
            "alerts_open_total": int(np.sum([m.alerts_open for m in ms])),
        }
    return out

"""Per-cell metrics collection for the evaluation grid.

One :class:`CellMetrics` summarizes one (scenario cell × policy)
simulation: the paper's headline quantities (makespan, cost/budget ratio,
budget-met %, VM usage) plus the resource-sharing actuals that make the
policy comparison explainable (container/data-cache hit rates, placement
tier histogram).  ``waas.platform`` and the ``repro.exp.run`` harness both
consume this collector, so every report in the repo speaks one schema —
see the metrics glossary in README.md.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.types import SimResult


@dataclasses.dataclass
class CellMetrics:
    """Summary of one simulation run (one grid cell × policy)."""

    policy: str
    n_workflows: int
    mean_makespan_s: float
    p95_makespan_s: float
    mean_cost_budget_ratio: float
    budget_met: float             # fraction of workflows with cost ≤ budget
    utilization: float            # busy-seconds / lease-seconds, all VMs
    total_vms: int
    vm_lease_s: float             # Σ leased VM-seconds (spend proxy)
    data_cache_hit_rate: float    # input MB served locally / total input MB
    container_hit_rate: float     # activations that skipped the download
    # Placement-tier histogram (1=input-data locality, 2=warm container,
    # 3=any idle, 4=new VM, 5=insufficient-budget fallback); empty when
    # the run was not traced.
    tier_hist: Dict[int, int] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_result(
        cls,
        policy: str,
        res: SimResult,
        trace_rows: Optional[Sequence[tuple]] = None,
    ) -> "CellMetrics":
        mks = np.array([w.makespan_ms for w in res.workflows], np.float64)
        ratios = np.array(
            [w.cost_budget_ratio for w in res.workflows], np.float64
        )
        tiers = (
            dict(sorted(collections.Counter(r[3] for r in trace_rows).items()))
            if trace_rows else {}
        )
        return cls(
            policy=policy,
            n_workflows=len(res.workflows),
            mean_makespan_s=float(mks.mean()) / 1000.0 if len(mks) else 0.0,
            p95_makespan_s=float(np.percentile(mks, 95)) / 1000.0
            if len(mks) else 0.0,
            mean_cost_budget_ratio=float(ratios.mean()) if len(ratios) else 0.0,
            budget_met=res.budget_met_fraction,
            utilization=res.avg_vm_utilization,
            total_vms=res.total_vms,
            vm_lease_s=float(sum(res.vm_seconds_by_type.values())),
            data_cache_hit_rate=res.data_cache_hit_rate,
            container_hit_rate=res.container_hit_rate,
            tier_hist=tiers,
        )

    @property
    def locality_hit_rate(self) -> float:
        """Fraction of placements on a VM already holding all inputs."""
        total = sum(self.tier_hist.values())
        return self.tier_hist.get(1, 0) / total if total else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["locality_hit_rate"] = self.locality_hit_rate
        d["tier_hist"] = {str(k): v for k, v in self.tier_hist.items()}
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "CellMetrics":
        """Inverse of :meth:`to_dict` (ignores extra keys — artifact rows
        carry cell coordinates alongside the metrics)."""
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["tier_hist"] = {int(k): v
                           for k, v in d.get("tier_hist", {}).items()}
        return cls(**kw)


def format_row(m: CellMetrics) -> str:
    """One-line human-readable summary (examples / REPL use)."""
    return (f"{m.policy:10s} mk={m.mean_makespan_s:9.1f}s "
            f"p95={m.p95_makespan_s:9.1f}s met={m.budget_met:6.2%} "
            f"util={m.utilization:6.2%} warm={m.locality_hit_rate:6.2%} "
            f"data-hit={m.data_cache_hit_rate:6.2%} "
            f"cont-hit={m.container_hit_rate:6.2%}")


def aggregate_by_policy(cells: Sequence[CellMetrics]) -> Dict[str, Dict]:
    """Across-cell aggregates per policy: mean of the cell means (every
    cell weighs equally, matching the paper's per-configuration figures)
    plus the worst cell for the floor-gated quantities."""
    by_pol: Dict[str, List[CellMetrics]] = {}
    for m in cells:
        by_pol.setdefault(m.policy, []).append(m)
    out: Dict[str, Dict] = {}
    for pol, ms in sorted(by_pol.items()):
        out[pol] = {
            "cells": len(ms),
            "mean_makespan_s": float(np.mean([m.mean_makespan_s for m in ms])),
            "mean_cost_budget_ratio": float(
                np.mean([m.mean_cost_budget_ratio for m in ms])),
            "budget_met_mean": float(np.mean([m.budget_met for m in ms])),
            "budget_met_min": float(np.min([m.budget_met for m in ms])),
            "utilization_mean": float(np.mean([m.utilization for m in ms])),
            "data_cache_hit_rate_mean": float(
                np.mean([m.data_cache_hit_rate for m in ms])),
            "container_hit_rate_mean": float(
                np.mean([m.container_hit_rate for m in ms])),
        }
    return out

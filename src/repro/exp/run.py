"""Paper-grid reproduction harness.

    PYTHONPATH=src python -m repro.exp.run --grid paper-smoke

Runs a registered :mod:`repro.exp.scenarios` grid through the batched
engine (``core.jax_engine.BatchSimEngine``) — every policy simulates a
structural-sharing clone of the same per-cell workload, with the
arrival-time budget distribution computed once per (workload, budget
mode) — collects one :class:`repro.exp.metrics.CellMetrics` per
(cell × policy), and emits:

* ``<out>/BENCH_paper_grid.json`` — the machine-readable artifact CI
  uploads and diff-tracks across PRs;
* ``<out>/paper_grid.md`` — a human-readable report (summary table +
  per-cell makespans).

Workload cells are independent simulations, so the grid scales across
processes: ``--workers N`` fans cell batches out to a spawn-based
process pool.  Row order and every per-cell metric are identical to a
serial run; the merged dispatch stats (rounds, batched calls) reflect
the worker chunking, which re-batches cells for load balance, so they
can differ from a serial run's batching.  The full ``paper`` grid
(180 workload cells × 5 policies × 3 seeds) is the intended consumer.

``--check-floors`` turns the run into a gate: non-zero exit when any
EBPSM cell's budget-met % drops below the scenario's recorded floor, or
when EBPSM stops beating MSLBL_MW on mean makespan (the paper's headline
claim).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.jax_engine import (BatchSimEngine, GridMember,
                               predistribute_workload)
from ..core.types import PlatformConfig, clone_workload
from ..workflows.workload import cell_workload
from .metrics import CellMetrics, aggregate_by_policy
from .scenarios import POLICY_BY_NAME, Scenario, WorkloadCell, get_scenario

ARTIFACT_NAME = "BENCH_paper_grid.json"
REPORT_NAME = "paper_grid.md"


def grid_executor(workers: int):
    """Spawn-context process pool for grid batches.

    Spawn (not fork): the parent usually holds an initialized JAX/XLA
    runtime whose thread state must not be forked.  Callers that time
    repeated grids should create this once and pass it to ``run_grid``
    so worker start-up (interpreter + imports) amortizes.
    """
    import multiprocessing
    from concurrent.futures import ProcessPoolExecutor

    return ProcessPoolExecutor(
        max_workers=workers,
        mp_context=multiprocessing.get_context("spawn"),
    )


def _chunked(seq: Sequence, n: int):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def _merge_stats(parts: List[Dict]) -> Dict:
    """Combine per-engine ``dispatch_stats`` payloads."""
    out: Dict = {"rounds": 0, "batched_calls": 0, "batched_cycles": 0,
                 "serial_cycles": 0, "aggregate_pairs_hist": {},
                 "max_member_pairs_batched": 0,
                 "min_member_pairs_batched": 0}
    mins = []
    for s in parts:
        for k in ("rounds", "batched_calls", "batched_cycles",
                  "serial_cycles"):
            out[k] += s[k]
        for b, n in s["aggregate_pairs_hist"].items():
            out["aggregate_pairs_hist"][b] = \
                out["aggregate_pairs_hist"].get(b, 0) + n
        out["max_member_pairs_batched"] = max(
            out["max_member_pairs_batched"], s["max_member_pairs_batched"])
        if s["batched_cycles"]:
            mins.append(s["min_member_pairs_batched"])
    out["min_member_pairs_batched"] = min(mins) if mins else 0
    return out


def _grid_batch(
    scenario: Scenario,
    cfg: PlatformConfig,
    batch: List[WorkloadCell],
    trace: bool,
    use_pallas: object,
    batched: object,
) -> Tuple[List[Dict], Dict]:
    """Simulate one batch of workload cells × all scenario policies.

    Self-contained and picklable-argument-only: this is both the serial
    loop body and the unit of work a ``--workers`` process executes
    (cells are regenerated in-worker from their deterministic seeds —
    nothing heavy crosses the process boundary).
    """
    policies = [POLICY_BY_NAME[name] for name in scenario.policies]
    members: List[GridMember] = []
    labels: List[Tuple[WorkloadCell, str]] = []
    pre: List[Dict[int, float]] = []
    for cell in batch:
        wl = cell_workload(cfg, cell.app, cell.rate, cell.budget_interval,
                           cell.workload_seed, scenario.n_workflows,
                           scenario.sizes)
        protos = {}
        for pol in policies:
            if pol.budget_mode not in protos:
                protos[pol.budget_mode] = predistribute_workload(
                    cfg, wl, pol.budget_mode)
            proto, spares = protos[pol.budget_mode]
            members.append((pol, clone_workload(proto), cell.seed))
            labels.append((cell, pol.name))
            pre.append(spares)
    engine = BatchSimEngine(cfg, members, trace=trace, predistributed=pre,
                            use_pallas=use_pallas, batched=batched)
    results = engine.run()
    rows: List[Dict] = []
    for (cell, pol_name), res, st in zip(labels, results, engine.states):
        m = CellMetrics.from_result(pol_name, res, st.trace_rows)
        rows.append({
            "app": cell.app,
            "rate_wf_per_min": cell.rate,
            "budget_lo": cell.budget_interval[0],
            "budget_hi": cell.budget_interval[1],
            "seed": cell.seed,
            **m.to_dict(),
        })
    return rows, engine.dispatch_stats()


def run_grid(
    scenario: Scenario,
    cfg: Optional[PlatformConfig] = None,
    cells_per_batch: int = 8,
    trace: bool = True,
    verbose: bool = False,
    workers: int = 1,
    use_pallas: object = "auto",
    batched: object = "auto",
    executor=None,
) -> Dict:
    """Run the whole grid; returns the artifact payload.

    ``workers > 1`` fans the cell batches out to a process pool
    (spawn context — safe with an initialized JAX runtime in the
    parent).  ``executor`` lets callers reuse a warm pool across runs
    (the grid-wall benchmark does); it must come from
    ``grid_executor(workers)``.
    """
    cfg = cfg or PlatformConfig()
    wcells = list(scenario.workload_cells())
    t0 = time.perf_counter()

    if workers > 1 and len(wcells) > 1:
        # Small chunks load-balance heterogeneous cells across the pool.
        per = max(1, min(cells_per_batch,
                         math.ceil(len(wcells) / (workers * 2))))
    else:
        per = cells_per_batch
    batches = list(_chunked(wcells, per))

    parts: List[Tuple[List[Dict], Dict]] = []
    if workers > 1 and len(batches) > 1:
        own = executor is None
        ex = executor or grid_executor(workers)
        try:
            futs = [ex.submit(_grid_batch, scenario, cfg, b, trace,
                              use_pallas, batched) for b in batches]
            for i, f in enumerate(futs):
                parts.append(f.result())
                if verbose:
                    done = sum(len(p[0]) for p in parts)
                    print(f"  {done}/{scenario.n_cells} cells "
                          f"({time.perf_counter() - t0:.1f}s)")
        finally:
            if own:
                ex.shutdown()
    else:
        for batch in batches:
            parts.append(_grid_batch(scenario, cfg, batch, trace,
                                     use_pallas, batched))
            if verbose:
                done = sum(len(p[0]) for p in parts)
                print(f"  {done}/{scenario.n_cells} cells "
                      f"({time.perf_counter() - t0:.1f}s)")

    rows = [r for part_rows, _ in parts for r in part_rows]
    stats = _merge_stats([s for _, s in parts])
    collected = [CellMetrics.from_dict(r) for r in rows]

    summary = aggregate_by_policy(collected)
    ebpsm = summary.get("EBPSM", {})
    mslbl = summary.get("MSLBL_MW", {})
    return {
        "bench": "paper_grid",
        "scenario": scenario.name,
        "description": scenario.description,
        "n_cells": scenario.n_cells,
        "n_workflows_per_cell": scenario.n_workflows,
        "ebpsm_budget_met_floor": scenario.ebpsm_budget_met_floor,
        "wall_s": time.perf_counter() - t0,
        "workers": workers,
        "use_pallas": str(use_pallas),
        "dispatch": stats,
        "summary_by_policy": summary,
        "ebpsm_vs_mslbl_makespan_ratio": (
            ebpsm["mean_makespan_s"] / mslbl["mean_makespan_s"]
            if ebpsm.get("mean_makespan_s") and mslbl.get("mean_makespan_s")
            else None
        ),
        "cells": rows,
    }


def check_floors(art: Dict) -> List[str]:
    """CI gate: EBPSM budget-met floor per cell + the headline makespan
    win over MSLBL_MW (when both policies are in the grid)."""
    failures: List[str] = []
    floor = float(art.get("ebpsm_budget_met_floor", 0.0))
    for row in art["cells"]:
        if row["policy"] != "EBPSM":
            continue
        if row["budget_met"] < floor - 1e-9:
            failures.append(
                f"EBPSM budget-met {row['budget_met']:.2%} < floor "
                f"{floor:.2%} in cell app={row['app']} "
                f"rate={row['rate_wf_per_min']} "
                f"budget=[{row['budget_lo']},{row['budget_hi']}] "
                f"seed={row['seed']}"
            )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None and ratio >= 1.0:
        failures.append(
            f"EBPSM mean makespan no longer beats MSLBL_MW "
            f"(ratio {ratio:.3f} >= 1)"
        )
    return failures


def write_report(art: Dict, path: str) -> None:
    lines = [
        f"# Paper grid — `{art['scenario']}`",
        "",
        art["description"],
        "",
        f"{art['n_cells']} cells, {art['n_workflows_per_cell']} workflows "
        f"per cell, wall {art['wall_s']:.1f}s.",
        "",
        "## Summary by policy",
        "",
        "| policy | mean makespan (s) | cost/budget | budget met "
        "(mean / min) | util | data hit | container hit |",
        "|---|---|---|---|---|---|---|",
    ]
    for pol, s in art["summary_by_policy"].items():
        lines.append(
            f"| {pol} | {s['mean_makespan_s']:.1f} "
            f"| {s['mean_cost_budget_ratio']:.3f} "
            f"| {s['budget_met_mean']:.1%} / {s['budget_met_min']:.1%} "
            f"| {s['utilization_mean']:.1%} "
            f"| {s['data_cache_hit_rate_mean']:.1%} "
            f"| {s['container_hit_rate_mean']:.1%} |"
        )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        lines += ["", f"EBPSM / MSLBL_MW mean-makespan ratio: "
                      f"**{ratio:.3f}** (< 1 means EBPSM wins)."]
    lines += [
        "",
        "## Per-cell mean makespan (s)",
        "",
        "| app | rate | budget | seed | " + " | ".join(
            p for p in sorted({r['policy'] for r in art['cells']})) + " |",
        "|---|---|---|---|" + "---|" * len(
            {r['policy'] for r in art['cells']}),
    ]
    by_cell: Dict[tuple, Dict[str, float]] = {}
    for r in art["cells"]:
        key = (r["app"], r["rate_wf_per_min"], r["budget_lo"],
               r["budget_hi"], r["seed"])
        by_cell.setdefault(key, {})[r["policy"]] = r["mean_makespan_s"]
    pols = sorted({r["policy"] for r in art["cells"]})
    for key, vals in sorted(by_cell.items()):
        app, rate, blo, bhi, seed = key
        cells = " | ".join(f"{vals.get(p, float('nan')):.1f}" for p in pols)
        lines.append(f"| {app} | {rate} | [{blo},{bhi}] | {seed} | {cells} |")
    lines += ["", "Metrics glossary: see README.md § Reproducing the paper.",
              ""]
    with open(path, "w") as f:
        f.write("\n".join(lines))


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="paper-smoke",
                    help="scenario name (see repro.exp.scenarios)")
    ap.add_argument("--out", default="artifacts/exp")
    ap.add_argument("--cells-per-batch", type=int, default=8,
                    help="workload cells per batched engine run")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for cell batches (cells are "
                         "independent; the full paper grid parallelizes "
                         "across cores)")
    ap.add_argument("--check-floors", action="store_true",
                    help="exit non-zero on budget-met floor / makespan-win "
                         "regressions")
    args = ap.parse_args(argv)

    scenario = get_scenario(args.grid)
    print(f"grid {scenario.name}: {scenario.n_cells} cells "
          f"({scenario.n_workload_cells} workloads x "
          f"{len(scenario.policies)} policies)"
          + (f", {args.workers} workers" if args.workers > 1 else ""))
    art = run_grid(scenario, cells_per_batch=args.cells_per_batch,
                   verbose=True, workers=args.workers)

    os.makedirs(args.out, exist_ok=True)
    jpath = os.path.join(args.out, ARTIFACT_NAME)
    with open(jpath, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    mpath = os.path.join(args.out, REPORT_NAME)
    write_report(art, mpath)
    print(f"artifact: {jpath}\nreport:   {mpath}")
    for pol, s in art["summary_by_policy"].items():
        print(f"  {pol:10s} mk={s['mean_makespan_s']:8.1f}s "
              f"met={s['budget_met_mean']:6.1%} (min {s['budget_met_min']:6.1%}) "
              f"util={s['utilization_mean']:6.1%}")
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        print(f"  EBPSM/MSLBL_MW makespan ratio: {ratio:.3f}")

    if args.check_floors:
        failures = check_floors(art)
        if failures:
            raise SystemExit("FLOOR FAILURES:\n  " + "\n  ".join(failures))
        print("floor gate OK")


if __name__ == "__main__":
    main()

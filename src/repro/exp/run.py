"""Paper-grid reproduction harness.

    PYTHONPATH=src python -m repro.exp.run --grid paper-smoke

Runs a registered :mod:`repro.exp.scenarios` grid through the batched
engine (``core.jax_engine.BatchSimEngine``) — every policy simulates a
structural-sharing clone of the same per-cell workload, with the
arrival-time budget distribution computed once per (workload, budget
mode) — collects one :class:`repro.exp.metrics.CellMetrics` per
(cell × policy), and emits:

* ``<out>/BENCH_paper_grid.json`` — the machine-readable artifact CI
  uploads and diff-tracks across PRs;
* ``<out>/paper_grid.md`` — a human-readable report (summary table +
  per-cell makespans).

Workload cells are independent simulations, so the grid scales across
processes: ``--workers N`` fans cell batches out to a spawn-based
process pool.  Row order and every per-cell metric are identical to a
serial run; the merged dispatch stats (rounds, batched calls) reflect
the worker chunking, which re-batches cells for load balance, so they
can differ from a serial run's batching.  The full ``paper`` grid
(180 workload cells × 5 policies × 3 seeds) is the intended consumer.

``--check-floors`` turns the run into a gate: non-zero exit when any
EBPSM cell's budget-met % drops below the scenario's recorded floor, or
when EBPSM stops beating MSLBL_MW on mean makespan (the paper's headline
claim).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .. import ckpt
from ..core.jax_engine import (BatchSimEngine, GridMember, StreamInterrupted,
                               predistribute_workload)
from ..core.types import PlatformConfig, clone_workload
from ..obs import export as obs_export
from ..obs import monitor as obs_monitor
from ..obs import report as obs_report
from ..workflows.workload import cell_workload
from .metrics import CellMetrics, aggregate_by_policy
from .scenarios import (POLICY_BY_NAME, OnlineScenario, Scenario,
                        WorkloadCell, get_scenario)

ARTIFACT_NAME = "BENCH_paper_grid.json"
REPORT_NAME = "paper_grid.md"


def grid_executor(workers: int):
    """Spawn-context process pool for grid batches.

    Spawn (not fork): the parent usually holds an initialized JAX/XLA
    runtime whose thread state must not be forked.  Callers that time
    repeated grids should create this once and pass it to ``run_grid``
    so worker start-up (interpreter + imports) amortizes.
    """
    import multiprocessing
    from concurrent.futures import ProcessPoolExecutor

    return ProcessPoolExecutor(
        max_workers=workers,
        mp_context=multiprocessing.get_context("spawn"),
    )


def _chunked(seq: Sequence, n: int):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def _merge_stats(parts: List[Dict]) -> Dict:
    """Combine per-engine ``dispatch_stats`` payloads."""
    out: Dict = {"rounds": 0, "batched_calls": 0, "batched_cycles": 0,
                 "serial_cycles": 0, "aggregate_pairs_hist": {},
                 "max_member_pairs_batched": 0,
                 "min_member_pairs_batched": 0}
    mins = []
    profiles: List[Dict] = []
    for s in parts:
        for k in ("rounds", "batched_calls", "batched_cycles",
                  "serial_cycles"):
            out[k] += s[k]
        for b, n in s["aggregate_pairs_hist"].items():
            out["aggregate_pairs_hist"][b] = \
                out["aggregate_pairs_hist"].get(b, 0) + n
        out["max_member_pairs_batched"] = max(
            out["max_member_pairs_batched"], s["max_member_pairs_batched"])
        if s["batched_cycles"]:
            mins.append(s["min_member_pairs_batched"])
        if "profile" in s:
            profiles.append(s["profile"])
    out["min_member_pairs_batched"] = min(mins) if mins else 0
    # Structured-event counts (repro.obs): totals and per-kind counts sum
    # across engines exactly like the phase counters, so a --workers run
    # merges to the same block as a serial run of the same chunking
    # (asserted in tests/test_exp.py::test_run_grid_workers_matches_serial).
    ev_parts = [s["events"] for s in parts if "events" in s]
    if ev_parts:
        by_kind: Dict[str, int] = {}
        for e in ev_parts:
            for k, n in e["by_kind"].items():
                by_kind[k] = by_kind.get(k, 0) + n
        out["events"] = {
            "enabled": any(e["enabled"] for e in ev_parts),
            "total": sum(e["total"] for e in ev_parts),
            "by_kind": dict(sorted(by_kind.items())),
            "dropped": sum(e["dropped"] for e in ev_parts),
        }
    # Live-monitor blocks are integer-only by construction, so summing
    # them across worker chunks is exact and chunking-order-independent:
    # serial and --workers runs merge to byte-identical blocks (gated in
    # tests/test_exp.py and the exp-smoke CI job).
    mon_parts = [s["monitor"] for s in parts if "monitor" in s]
    if mon_parts:
        out["monitor"] = obs_monitor.merge_monitor_blocks(mon_parts)
    if parts:
        # Uniform across parts — every engine in a run shares the mode.
        out["redistribute_mode"] = parts[0].get("redistribute_mode",
                                                "finish")
    if profiles:
        # REPRO_PROFILE=1 phase counters: sum the absolute seconds
        # (including the per-engine walls); the artifact assembler
        # recomputes the share from the summed engine walls — the
        # parent's elapsed time is not a valid denominator when parts
        # ran concurrently in worker processes.
        agg = {k: float(sum(p[k] for p in profiles)) for k in profiles[0]
               if k != "redistribute_share_of_wall"}
        out["profile"] = agg
    return out


def _cell_label(scenario_name: str, cell: WorkloadCell,
                policy: str) -> str:
    """Deterministic filesystem-safe trace filename stem for one
    (cell, policy)."""
    blo, bhi = cell.budget_interval
    return (f"{scenario_name}__{cell.app}_r{cell.rate:g}"
            f"_b{blo:g}-{bhi:g}_s{cell.seed}__{policy}")


def _grid_batch(
    scenario: Scenario,
    cfg: PlatformConfig,
    batch: List[WorkloadCell],
    trace: bool,
    use_pallas: object,
    batched: object,
    redistribute: str = "finish",
    events: bool = False,
    trace_dir: Optional[str] = None,
    report_dir: Optional[str] = None,
    monitor: bool = False,
) -> Tuple[List[Dict], Dict]:
    """Simulate one batch of workload cells × all scenario policies.

    Self-contained and picklable-argument-only: this is both the serial
    loop body and the unit of work a ``--workers`` process executes
    (cells are regenerated in-worker from their deterministic seeds —
    nothing heavy crosses the process boundary).  ``trace_dir`` implies
    ``events`` and writes one Perfetto trace + JSONL dump per
    (cell, policy) — workers write their own cells' files directly.
    ``report_dir`` implies the live monitor (which implies events) and
    writes one ``monitor.json`` + HTML dashboard per (cell, policy);
    ``monitor`` alone collects the monitor block without report files.
    """
    policies = [POLICY_BY_NAME[name] for name in scenario.policies]
    members: List[GridMember] = []
    labels: List[Tuple[WorkloadCell, str]] = []
    pre: List[Dict[int, float]] = []
    for cell in batch:
        wl = cell_workload(cfg, cell.app, cell.rate, cell.budget_interval,
                           cell.workload_seed, scenario.n_workflows,
                           scenario.sizes)
        protos = {}
        for pol in policies:
            if pol.budget_mode not in protos:
                protos[pol.budget_mode] = predistribute_workload(
                    cfg, wl, pol.budget_mode)
            proto, spares = protos[pol.budget_mode]
            members.append((pol, clone_workload(proto), cell.seed))
            labels.append((cell, pol.name))
            pre.append(spares)
    mon_on = bool(monitor or report_dir)
    engine = BatchSimEngine(cfg, members, trace=trace, predistributed=pre,
                            use_pallas=use_pallas, batched=batched,
                            redistribute=redistribute,
                            events=bool(events or trace_dir or mon_on),
                            monitor=mon_on or None)
    results = engine.run()
    rows: List[Dict] = []
    vm_type_names = [t.name for t in cfg.vm_types]
    for (cell, pol_name), res, st in zip(labels, results, engine.states):
        label = _cell_label(scenario.name, cell, pol_name)
        if trace_dir and st.elog is not None:
            obs_export.write_cell_trace(trace_dir, label, st.elog,
                                        vm_type_names=vm_type_names)
        if report_dir and st.monitor is not None:
            obs_report.write_cell_report(report_dir, label, st.monitor)
        m = CellMetrics.from_result(pol_name, res, st.trace_rows,
                                    monitor=st.monitor)
        rows.append({
            "app": cell.app,
            "rate_wf_per_min": cell.rate,
            "budget_lo": cell.budget_interval[0],
            "budget_hi": cell.budget_interval[1],
            "seed": cell.seed,
            **m.to_dict(),
        })
    return rows, engine.dispatch_stats()


def run_grid(
    scenario: Scenario,
    cfg: Optional[PlatformConfig] = None,
    cells_per_batch: int = 8,
    trace: bool = True,
    verbose: bool = False,
    workers: int = 1,
    use_pallas: object = "auto",
    batched: object = "auto",
    redistribute: str = "finish",
    executor=None,
    events: bool = False,
    trace_dir: Optional[str] = None,
    report_dir: Optional[str] = None,
    monitor: bool = False,
) -> Dict:
    """Run the whole grid; returns the artifact payload.

    ``workers > 1`` fans the cell batches out to a process pool
    (spawn context — safe with an initialized JAX runtime in the
    parent).  ``executor`` lets callers reuse a warm pool across runs
    (the grid-wall benchmark does); it must come from
    ``grid_executor(workers)``.

    ``events`` enables structured-event collection (the artifact's
    ``dispatch.events`` block); ``trace_dir`` additionally writes one
    Perfetto trace + JSONL event dump per (cell, policy) — see
    ``repro.obs`` and docs/PROFILING.md.

    ``monitor`` enables the live SLO monitor (the artifact's
    ``dispatch.monitor`` block and per-cell alert tallies);
    ``report_dir`` additionally writes one ``monitor.json`` + HTML
    dashboard per (cell, policy) — see ``repro.obs.monitor``.
    """
    cfg = cfg or PlatformConfig()
    wcells = list(scenario.workload_cells())
    t0 = time.perf_counter()

    if workers > 1 and len(wcells) > 1:
        # Small chunks load-balance heterogeneous cells across the pool.
        per = max(1, min(cells_per_batch,
                         math.ceil(len(wcells) / (workers * 2))))
    else:
        per = cells_per_batch
    batches = list(_chunked(wcells, per))

    parts: List[Tuple[List[Dict], Dict]] = []
    if workers > 1 and len(batches) > 1:
        own = executor is None
        ex = executor or grid_executor(workers)
        try:
            futs = [ex.submit(_grid_batch, scenario, cfg, b, trace,
                              use_pallas, batched, redistribute,
                              events, trace_dir, report_dir, monitor)
                    for b in batches]
            for i, f in enumerate(futs):
                parts.append(f.result())
                if verbose:
                    done = sum(len(p[0]) for p in parts)
                    print(f"  {done}/{scenario.n_cells} cells "
                          f"({time.perf_counter() - t0:.1f}s)")
        finally:
            if own:
                ex.shutdown()
    else:
        for batch in batches:
            parts.append(_grid_batch(scenario, cfg, batch, trace,
                                     use_pallas, batched, redistribute,
                                     events, trace_dir, report_dir,
                                     monitor))
            if verbose:
                done = sum(len(p[0]) for p in parts)
                print(f"  {done}/{scenario.n_cells} cells "
                      f"({time.perf_counter() - t0:.1f}s)")

    rows = [r for part_rows, _ in parts for r in part_rows]
    stats = _merge_stats([s for _, s in parts])
    return _artifact(scenario, rows, stats,
                     wall_s=time.perf_counter() - t0, workers=workers,
                     use_pallas=use_pallas, redistribute=redistribute)


def _artifact(scenario, rows: List[Dict], stats: Dict, wall_s: float,
              workers: int, use_pallas: object, **extra) -> Dict:
    """Assemble the ``BENCH_paper_grid.json``-schema payload (shared by
    the closed-grid and online harnesses)."""
    collected = [CellMetrics.from_dict(r) for r in rows]
    summary = aggregate_by_policy(collected)
    prof = stats.get("profile")
    if prof and prof.get("engine_wall_s"):
        prof["redistribute_share_of_wall"] = \
            prof["redistribute_s"] / prof["engine_wall_s"]
    ebpsm = summary.get("EBPSM", {})
    mslbl = summary.get("MSLBL_MW", {})
    # Data-integrity warnings ride the artifact so consumers see them
    # even when the run's stdout is long gone.  A ring-truncated event
    # log means every post-hoc time series derived from it is silently
    # wrong — say so loudly (main() prints these too).
    warnings: List[str] = []
    dropped = stats.get("events", {}).get("dropped", 0)
    if dropped > 0:
        warnings.append(
            f"event ring dropped {dropped} events — post-hoc time series "
            f"(fleet/queue/cost curves, Perfetto traces) are truncated; "
            f"raise the EventLog capacity or use the live monitor "
            f"(--report-dir), which folds events before overwrite")
    return {
        "bench": "paper_grid",
        "scenario": scenario.name,
        "description": scenario.description,
        "n_cells": scenario.n_cells,
        "n_workflows_per_cell": scenario.n_workflows,
        "ebpsm_budget_met_floor": scenario.ebpsm_budget_met_floor,
        "wall_s": wall_s,
        "workers": workers,
        "use_pallas": str(use_pallas),
        "dispatch": stats,
        "summary_by_policy": summary,
        "ebpsm_vs_mslbl_makespan_ratio": (
            ebpsm["mean_makespan_s"] / mslbl["mean_makespan_s"]
            if ebpsm.get("mean_makespan_s") and mslbl.get("mean_makespan_s")
            else None
        ),
        "cells": rows,
        "warnings": warnings,
        **extra,
    }


class _StreamCkpt:
    """``BatchSimEngine.run`` checkpoint hook: writes a
    ``ckpt.save_stream`` snapshot every ``every_s`` of wall clock
    (``every_s=0`` ⇒ every rendezvous round — the deterministic cadence
    the CI resume smoke interrupts on), carrying the harness's
    cross-seed progress (completed rows + dispatch stats) in the
    manifest meta so a resumed run reassembles the identical artifact.
    ``stop_after`` > 0 stops the stream after that many saves
    (:class:`StreamInterrupted`) — a deterministic, in-band "kill"."""

    def __init__(self, ckpt_dir: str, every_s: float, meta: Dict,
                 stop_after: Optional[int] = None):
        self.ckpt_dir = ckpt_dir
        self.every_s = every_s
        self.meta = meta
        self.stop_after = stop_after
        last = ckpt.latest_step(ckpt_dir)
        # Continue numbering past earlier segments' steps: a resumed
        # run must never rewrite a step the interrupt already wrote
        # (latest_step would go stale mid-stream otherwise).
        self.step = 0 if last is None else last + 1
        self.saved = 0
        self._last_t = time.monotonic()

    def __call__(self, engine: BatchSimEngine) -> bool:
        if time.monotonic() - self._last_t < self.every_s:
            return False
        ckpt.save_stream(self.ckpt_dir, self.step, engine.snapshot(),
                         meta=self.meta)
        self.step += 1
        self.saved += 1
        self._last_t = time.monotonic()
        return self.stop_after is not None and self.saved >= self.stop_after


def run_online(
    scenario: OnlineScenario,
    cfg: Optional[PlatformConfig] = None,
    trace: bool = True,
    verbose: bool = False,
    use_pallas: object = "auto",
    batched: object = "auto",
    redistribute: str = "finish",
    ckpt_dir: Optional[str] = None,
    ckpt_every_s: Optional[float] = None,
    resume: bool = False,
    stop_after_ckpts: Optional[int] = None,
    events: bool = False,
    trace_dir: Optional[str] = None,
    report_dir: Optional[str] = None,
    monitor: bool = False,
) -> Dict:
    """Stream an :class:`OnlineScenario`'s tenant mix through the batched
    engine, one merged multi-tenant stream per seed × every policy.

    Every policy simulates a structural-sharing clone of the *same* merged
    stream (budget distribution predistributed once per budget mode), so
    policy comparisons stay paired; metrics truncate the warm-up window
    and carry the per-tenant extensions (slowdown percentiles, per-QoS
    budget-met, fleet size, Jain fairness).  Returns the same artifact
    schema as :func:`run_grid`.

    ``ckpt_dir`` + ``ckpt_every_s`` enable long-horizon checkpointing
    (see :class:`_StreamCkpt`); ``resume=True`` restores the latest
    snapshot in ``ckpt_dir`` — the stream continues bit-identically, so
    the final artifact's rows and dispatch stats match an uninterrupted
    run.  ``stop_after_ckpts`` raises :class:`StreamInterrupted` after
    that many saves (deterministic interruption for tests/CI).

    ``events`` enables structured-event collection; ``trace_dir``
    additionally writes one Perfetto trace + JSONL dump per
    (seed, policy), with task slices categorized by tenant and QoS.
    Event logs ride the stream snapshots, so a resumed run's traces are
    byte-identical with an uninterrupted one (tests/test_obs.py).

    ``monitor`` enables the live SLO monitor (one independent
    :class:`repro.obs.monitor.Monitor` per (seed, policy) member, fed by
    tenant/QoS maps so per-QoS burn rates and slowdown SLIs resolve);
    ``report_dir`` additionally writes one ``monitor.json`` + HTML
    dashboard per (seed, policy) and implies ``monitor``.  Monitors ride
    the member event logs through stream snapshots, so a resumed run's
    alerts and windows are byte-identical with an uninterrupted one.
    """
    cfg = cfg or PlatformConfig()
    mon_on = bool(monitor or report_dir)
    t0 = time.perf_counter()
    warmup_ms = int(scenario.warmup_s * 1000)
    blo, bhi = scenario.mix.budget_span()
    policies = [POLICY_BY_NAME[name] for name in scenario.policies]
    rows: List[Dict] = []
    stats_parts: List[Dict] = []
    resume_snap = None
    start_seed_idx = 0
    if resume:
        if not ckpt_dir:
            raise SystemExit("--resume requires --ckpt-dir")
        resume_snap, step, meta = ckpt.restore_stream(ckpt_dir)
        if meta.get("scenario") != scenario.name:
            raise SystemExit(
                f"checkpoint in {ckpt_dir} is for scenario "
                f"{meta.get('scenario')!r}, not {scenario.name!r}")
        if meta.get("redistribute") != redistribute:
            raise SystemExit(
                f"checkpoint was written with "
                f"--redistribute {meta.get('redistribute')}, "
                f"this run uses {redistribute}")
        rows = list(meta.get("rows", []))
        stats_parts = list(meta.get("stats", []))
        start_seed_idx = int(meta.get("seed_index", 0))
        if verbose:
            print(f"  resuming {scenario.name} from step {step} "
                  f"(seed index {start_seed_idx}, "
                  f"{len(rows)} completed rows)")
    for seed_idx, seed in enumerate(scenario.seeds):
        if seed_idx < start_seed_idx:
            continue  # fully covered by the restored rows
        tw = scenario.mix.build(cfg, seed)
        ideal = tw.ideal_ms(cfg)
        protos = {}
        members: List[GridMember] = []
        labels: List[str] = []
        pre: List[Dict[int, float]] = []
        for pol in policies:
            if pol.budget_mode not in protos:
                protos[pol.budget_mode] = predistribute_workload(
                    cfg, tw.workflows, pol.budget_mode)
            proto, spares = protos[pol.budget_mode]
            members.append((pol, clone_workload(proto), seed))
            labels.append(pol.name)
            pre.append(spares)
        engine = BatchSimEngine(cfg, members, trace=trace,
                                predistributed=pre, use_pallas=use_pallas,
                                batched=batched, redistribute=redistribute,
                                events=bool(events or trace_dir or mon_on),
                                chaos=scenario.chaos,
                                monitor=mon_on or None,
                                monitor_maps=(tw.tenant_of, tw.qos_of,
                                              ideal))
        if resume_snap is not None:
            engine.load_snapshot(resume_snap)
            resume_snap = None
        hook = None
        if ckpt_dir and ckpt_every_s is not None:
            hook = _StreamCkpt(ckpt_dir, ckpt_every_s, meta={
                "scenario": scenario.name,
                "redistribute": redistribute,
                "seed": seed,
                "seed_index": seed_idx,
                "rows": rows,
                "stats": stats_parts,
            }, stop_after=stop_after_ckpts)
        results = engine.run(ckpt_hook=hook)
        for name, res, st in zip(labels, results, engine.states):
            if trace_dir and st.elog is not None:
                obs_export.write_cell_trace(
                    trace_dir, f"{scenario.name}__seed{seed}__{name}",
                    st.elog,
                    vm_type_names=[t.name for t in cfg.vm_types],
                    tenant_of=tw.tenant_of, qos_of=tw.qos_of)
            if report_dir and st.monitor is not None:
                obs_report.write_cell_report(
                    report_dir, f"{scenario.name}__seed{seed}__{name}",
                    st.monitor)
            m = CellMetrics.from_result(
                name, res, st.trace_rows, tenant_of=tw.tenant_of,
                qos_of=tw.qos_of, ideal_ms=ideal, warmup_ms=warmup_ms,
                monitor=st.monitor)
            rows.append({
                "app": "mixed",
                "rate_wf_per_min": round(
                    scenario.mix.mean_rate_per_min(), 3),
                "budget_lo": blo,
                "budget_hi": bhi,
                "seed": seed,
                **m.to_dict(),
            })
        stats_parts.append(engine.dispatch_stats())
        if verbose:
            print(f"  seed {seed}: {len(labels)} policies x "
                  f"{len(tw.workflows)} workflows "
                  f"({time.perf_counter() - t0:.1f}s)")
    return _artifact(
        scenario, rows, _merge_stats(stats_parts),
        wall_s=time.perf_counter() - t0, workers=1, use_pallas=use_pallas,
        redistribute=redistribute,
        scenario_kind="online",
        warmup_s=scenario.warmup_s,
        p95_slowdown_ceiling=scenario.p95_slowdown_ceiling,
        wasted_spend_ceiling=scenario.wasted_spend_ceiling,
        alert_floors=scenario.alert_floors,
        chaos=scenario.chaos.knobs() if scenario.chaos else None,
        tenants=[{
            "name": t.name,
            "qos": t.qos.name,
            "priority": t.qos.priority,
            "budget_interval": list(t.qos.budget_interval),
            "n_workflows": t.n_workflows,
            "apps": list(t.apps),
            "arrival": type(t.arrival).__name__ if t.arrival else "stream",
            "mean_rate_per_min": (t.arrival.mean_rate_per_min()
                                  if t.arrival else None),
        } for t in scenario.mix.tenants],
    )


def check_floors(art: Dict) -> List[str]:
    """CI gate: EBPSM budget-met floor per cell, the p95-slowdown and
    wasted-spend ceilings (online scenarios that record them), and the
    headline makespan win over MSLBL_MW (when both policies are in the
    grid)."""
    failures: List[str] = []
    floor = float(art.get("ebpsm_budget_met_floor", 0.0))
    ceiling = float(art.get("p95_slowdown_ceiling", 0.0))
    waste_ceiling = float(art.get("wasted_spend_ceiling", 0.0))
    for row in art["cells"]:
        if row["policy"] != "EBPSM":
            continue
        if ceiling > 0 and row.get("p95_slowdown", 0.0) > ceiling + 1e-9:
            failures.append(
                f"EBPSM p95 slowdown {row['p95_slowdown']:.2f} > ceiling "
                f"{ceiling:.2f} in cell app={row['app']} "
                f"rate={row['rate_wf_per_min']} seed={row['seed']}"
            )
        if waste_ceiling > 0 and row.get("wasted_spend_frac", 0.0) \
                > waste_ceiling + 1e-9:
            failures.append(
                f"EBPSM wasted-spend fraction "
                f"{row['wasted_spend_frac']:.2%} > ceiling "
                f"{waste_ceiling:.2%} in cell app={row['app']} "
                f"rate={row['rate_wf_per_min']} seed={row['seed']}"
            )
        if row.get("n_workflows", 1) == 0:
            # A cell whose workflows were all warm-up-excluded would pass
            # the floor vacuously (budget_met defaults to 1.0) — fail
            # loudly instead.
            failures.append(
                f"EBPSM cell has no post-warmup workflows (all "
                f"{row.get('n_warmup_excluded', 0)} excluded) in cell "
                f"app={row['app']} rate={row['rate_wf_per_min']} "
                f"seed={row['seed']}"
            )
            continue
        if row["budget_met"] < floor - 1e-9:
            failures.append(
                f"EBPSM budget-met {row['budget_met']:.2%} < floor "
                f"{floor:.2%} in cell app={row['app']} "
                f"rate={row['rate_wf_per_min']} "
                f"budget=[{row['budget_lo']},{row['budget_hi']}] "
                f"seed={row['seed']}"
            )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None and ratio >= 1.0:
        failures.append(
            f"EBPSM mean makespan no longer beats MSLBL_MW "
            f"(ratio {ratio:.3f} >= 1)"
        )
    alert_floors = art.get("alert_floors") or {}
    if alert_floors:
        # Declared floors REQUIRE the live monitor: a run without it
        # would pass vacuously (zero alerts observed because none were
        # looked for), which is exactly the silent-regression mode this
        # gate exists to catch.
        mon = art.get("dispatch", {}).get("monitor", {})
        if not mon.get("enabled"):
            failures.append(
                "alert floors declared but monitoring disabled — run "
                "with --report-dir or REPRO_MONITOR=1 so the floors "
                "are actually evaluated")
        else:
            by_kind = mon.get("alerts_by_kind", {})
            for kind, floor_n in sorted(alert_floors.items()):
                got = int(by_kind.get(kind, 0))
                if got < int(floor_n):
                    failures.append(
                        f"alert floor: {got} {kind!r} alerts fired "
                        f"< floor {floor_n} — the chaos scenario no "
                        f"longer trips its detector")
    return failures


def write_report(art: Dict, path: str) -> None:
    lines = [
        f"# Paper grid — `{art['scenario']}`",
        "",
        art["description"],
        "",
        f"{art['n_cells']} cells, {art['n_workflows_per_cell']} workflows "
        f"per cell, wall {art['wall_s']:.1f}s.",
        "",
        "## Summary by policy",
        "",
        "| policy | mean makespan (s) | cost/budget | budget met "
        "(mean / min) | util | data hit | container hit |",
        "|---|---|---|---|---|---|---|",
    ]
    for pol, s in art["summary_by_policy"].items():
        lines.append(
            f"| {pol} | {s['mean_makespan_s']:.1f} "
            f"| {s['mean_cost_budget_ratio']:.3f} "
            f"| {s['budget_met_mean']:.1%} / {s['budget_met_min']:.1%} "
            f"| {s['utilization_mean']:.1%} "
            f"| {s['data_cache_hit_rate_mean']:.1%} "
            f"| {s['container_hit_rate_mean']:.1%} |"
        )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        lines += ["", f"EBPSM / MSLBL_MW mean-makespan ratio: "
                      f"**{ratio:.3f}** (< 1 means EBPSM wins)."]
    lines += [
        "",
        "## Per-cell mean makespan (s)",
        "",
        "| app | rate | budget | seed | " + " | ".join(
            p for p in sorted({r['policy'] for r in art['cells']})) + " |",
        "|---|---|---|---|" + "---|" * len(
            {r['policy'] for r in art['cells']}),
    ]
    by_cell: Dict[tuple, Dict[str, float]] = {}
    for r in art["cells"]:
        key = (r["app"], r["rate_wf_per_min"], r["budget_lo"],
               r["budget_hi"], r["seed"])
        by_cell.setdefault(key, {})[r["policy"]] = r["mean_makespan_s"]
    pols = sorted({r["policy"] for r in art["cells"]})
    for key, vals in sorted(by_cell.items()):
        app, rate, blo, bhi, seed = key
        cells = " | ".join(f"{vals.get(p, float('nan')):.1f}" for p in pols)
        lines.append(f"| {app} | {rate} | [{blo},{bhi}] | {seed} | {cells} |")
    lines += ["", "Metrics glossary: see README.md § Reproducing the paper.",
              ""]
    with open(path, "w") as f:
        f.write("\n".join(lines))


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="paper-smoke",
                    help="scenario name (see repro.exp.scenarios)")
    ap.add_argument("--out", default="artifacts/exp")
    ap.add_argument("--cells-per-batch", type=int, default=8,
                    help="workload cells per batched engine run")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for cell batches (cells are "
                         "independent; the full paper grid parallelizes "
                         "across cores)")
    ap.add_argument("--redistribute", choices=("finish", "round"),
                    default="finish",
                    help="Algorithm-3 mode: per-task-finish (paper "
                         "semantics, default) or round-batched (one "
                         "pooled redistribution per workflow per "
                         "scheduling cycle; coalesces surplus flows, "
                         "A/B-gated — see docs/PROFILING.md)")
    ap.add_argument("--check-floors", action="store_true",
                    help="exit non-zero on budget-met floor / makespan-win "
                         "regressions")
    ap.add_argument("--ckpt-dir", default=None,
                    help="stream-checkpoint directory (online grids only): "
                         "with --ckpt-every-s, snapshots land here; with "
                         "--resume, the latest snapshot restores from here")
    ap.add_argument("--ckpt-every-s", type=float, default=None,
                    help="seconds of wall clock between stream snapshots "
                         "(0 = every rendezvous round — deterministic, "
                         "what the CI resume smoke uses)")
    ap.add_argument("--resume", action="store_true",
                    help="resume the online stream from the latest "
                         "checkpoint in --ckpt-dir (bit-identical "
                         "continuation)")
    ap.add_argument("--stop-after-ckpts", type=int, default=None,
                    help="interrupt the stream after N checkpoint saves "
                         "(exit code 3) — deterministic interruption for "
                         "the CI resume smoke")
    ap.add_argument("--trace-dir", default=None,
                    help="write one Perfetto/Chrome-trace JSON + JSONL "
                         "event dump per (cell, policy) into this "
                         "directory (implies event collection; load in "
                         "ui.perfetto.dev — see docs/PROFILING.md)")
    ap.add_argument("--trace-events", action="store_true",
                    help="collect structured events without writing trace "
                         "files (the artifact's dispatch.events block; "
                         "REPRO_TRACE=1 is the env equivalent)")
    ap.add_argument("--report-dir", default=None,
                    help="write one monitor.json + self-contained HTML "
                         "dashboard per (cell, policy) into this directory "
                         "(implies the live SLO monitor and event "
                         "collection; REPRO_MONITOR=1 enables the monitor "
                         "without reports; validate with "
                         "tools/check_report.py)")
    args = ap.parse_args(argv)

    scenario = get_scenario(args.grid)
    if isinstance(scenario, OnlineScenario):
        if args.workers > 1:
            print(f"note: --workers {args.workers} ignored — online grids "
                  f"run single-process (policies within a stream share "
                  f"one batched engine run)")
        print(f"online grid {scenario.name}: {scenario.n_cells} cells "
              f"({len(scenario.seeds)} seeds x "
              f"{len(scenario.policies)} policies, "
              f"{scenario.n_workflows} workflows/stream, "
              f"warm-up {scenario.warmup_s:.0f}s)")
        try:
            art = run_online(scenario, verbose=True,
                             redistribute=args.redistribute,
                             ckpt_dir=args.ckpt_dir,
                             ckpt_every_s=args.ckpt_every_s,
                             resume=args.resume,
                             stop_after_ckpts=args.stop_after_ckpts,
                             events=args.trace_events,
                             trace_dir=args.trace_dir,
                             report_dir=args.report_dir)
        except StreamInterrupted as e:
            print(f"interrupted: {e} — resume with --resume "
                  f"--ckpt-dir {args.ckpt_dir}")
            raise SystemExit(3)
    else:
        if args.ckpt_dir or args.resume:
            raise SystemExit("--ckpt-dir/--resume are online-grid flags "
                             f"({scenario.name} is a closed grid)")
        print(f"grid {scenario.name}: {scenario.n_cells} cells "
              f"({scenario.n_workload_cells} workloads x "
              f"{len(scenario.policies)} policies)"
              + (f", {args.workers} workers" if args.workers > 1 else ""))
        art = run_grid(scenario, cells_per_batch=args.cells_per_batch,
                       verbose=True, workers=args.workers,
                       redistribute=args.redistribute,
                       events=args.trace_events, trace_dir=args.trace_dir,
                       report_dir=args.report_dir)
    if args.trace_dir:
        n_traces = len([f for f in os.listdir(args.trace_dir)
                        if f.endswith(".trace.json")])
        print(f"traces:   {args.trace_dir} ({n_traces} Perfetto traces; "
              f"validate with tools/check_trace.py)")
    if args.report_dir:
        n_dash = len([f for f in os.listdir(args.report_dir)
                      if f.endswith(".dashboard.html")])
        print(f"reports:  {args.report_dir} ({n_dash} dashboards; "
              f"validate with tools/check_report.py)")
    for w in art.get("warnings", []):
        print(f"WARNING: {w}")

    os.makedirs(args.out, exist_ok=True)
    jpath = os.path.join(args.out, ARTIFACT_NAME)
    with open(jpath, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    mpath = os.path.join(args.out, REPORT_NAME)
    write_report(art, mpath)
    print(f"artifact: {jpath}\nreport:   {mpath}")
    for pol, s in art["summary_by_policy"].items():
        print(f"  {pol:10s} mk={s['mean_makespan_s']:8.1f}s "
              f"met={s['budget_met_mean']:6.1%} (min {s['budget_met_min']:6.1%}) "
              f"util={s['utilization_mean']:6.1%}")
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        print(f"  EBPSM/MSLBL_MW makespan ratio: {ratio:.3f}")

    if args.check_floors:
        failures = check_floors(art)
        if failures:
            raise SystemExit("FLOOR FAILURES:\n  " + "\n  ".join(failures))
        print("floor gate OK")


if __name__ == "__main__":
    main()

"""Paper-grid reproduction harness.

    PYTHONPATH=src python -m repro.exp.run --grid paper-smoke

Runs a registered :mod:`repro.exp.scenarios` grid through the batched
engine (``core.jax_engine.BatchSimEngine``) — every policy simulates a
structural-sharing clone of the same per-cell workload, with the
arrival-time budget distribution computed once per (workload, budget
mode) — collects one :class:`repro.exp.metrics.CellMetrics` per
(cell × policy), and emits:

* ``<out>/BENCH_paper_grid.json`` — the machine-readable artifact CI
  uploads and diff-tracks across PRs;
* ``<out>/paper_grid.md`` — a human-readable report (summary table +
  per-cell makespans).

``--check-floors`` turns the run into a gate: non-zero exit when any
EBPSM cell's budget-met % drops below the scenario's recorded floor, or
when EBPSM stops beating MSLBL_MW on mean makespan (the paper's headline
claim).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.jax_engine import (BatchSimEngine, GridMember,
                               predistribute_workload)
from ..core.types import PlatformConfig, clone_workload
from ..workflows.workload import cell_workload
from .metrics import CellMetrics, aggregate_by_policy
from .scenarios import POLICY_BY_NAME, Scenario, WorkloadCell, get_scenario

ARTIFACT_NAME = "BENCH_paper_grid.json"
REPORT_NAME = "paper_grid.md"


def _chunked(seq: Sequence, n: int):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]


def run_grid(
    scenario: Scenario,
    cfg: Optional[PlatformConfig] = None,
    cells_per_batch: int = 8,
    trace: bool = True,
    verbose: bool = False,
) -> Dict:
    """Run the whole grid; returns the artifact payload."""
    cfg = cfg or PlatformConfig()
    policies = [POLICY_BY_NAME[name] for name in scenario.policies]
    wcells = list(scenario.workload_cells())
    t0 = time.perf_counter()
    rows: List[Dict] = []
    collected: List[CellMetrics] = []

    for batch in _chunked(wcells, cells_per_batch):
        members: List[GridMember] = []
        labels: List[Tuple[WorkloadCell, str]] = []
        pre: List[Dict[int, float]] = []
        for cell in batch:
            wl = cell_workload(cfg, cell.app, cell.rate, cell.budget_interval,
                               cell.workload_seed, scenario.n_workflows,
                               scenario.sizes)
            protos = {}
            for pol in policies:
                if pol.budget_mode not in protos:
                    protos[pol.budget_mode] = predistribute_workload(
                        cfg, wl, pol.budget_mode)
                proto, spares = protos[pol.budget_mode]
                members.append((pol, clone_workload(proto), cell.seed))
                labels.append((cell, pol.name))
                pre.append(spares)
        engine = BatchSimEngine(cfg, members, trace=trace, predistributed=pre)
        results = engine.run()
        for (cell, pol_name), res, st in zip(labels, results, engine.states):
            m = CellMetrics.from_result(pol_name, res, st.trace_rows)
            collected.append(m)
            rows.append({
                "app": cell.app,
                "rate_wf_per_min": cell.rate,
                "budget_lo": cell.budget_interval[0],
                "budget_hi": cell.budget_interval[1],
                "seed": cell.seed,
                **m.to_dict(),
            })
        if verbose:
            done = len(rows)
            print(f"  {done}/{scenario.n_cells} cells "
                  f"({time.perf_counter() - t0:.1f}s)")

    summary = aggregate_by_policy(collected)
    ebpsm = summary.get("EBPSM", {})
    mslbl = summary.get("MSLBL_MW", {})
    return {
        "bench": "paper_grid",
        "scenario": scenario.name,
        "description": scenario.description,
        "n_cells": scenario.n_cells,
        "n_workflows_per_cell": scenario.n_workflows,
        "ebpsm_budget_met_floor": scenario.ebpsm_budget_met_floor,
        "wall_s": time.perf_counter() - t0,
        "summary_by_policy": summary,
        "ebpsm_vs_mslbl_makespan_ratio": (
            ebpsm["mean_makespan_s"] / mslbl["mean_makespan_s"]
            if ebpsm.get("mean_makespan_s") and mslbl.get("mean_makespan_s")
            else None
        ),
        "cells": rows,
    }


def check_floors(art: Dict) -> List[str]:
    """CI gate: EBPSM budget-met floor per cell + the headline makespan
    win over MSLBL_MW (when both policies are in the grid)."""
    failures: List[str] = []
    floor = float(art.get("ebpsm_budget_met_floor", 0.0))
    for row in art["cells"]:
        if row["policy"] != "EBPSM":
            continue
        if row["budget_met"] < floor - 1e-9:
            failures.append(
                f"EBPSM budget-met {row['budget_met']:.2%} < floor "
                f"{floor:.2%} in cell app={row['app']} "
                f"rate={row['rate_wf_per_min']} "
                f"budget=[{row['budget_lo']},{row['budget_hi']}] "
                f"seed={row['seed']}"
            )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None and ratio >= 1.0:
        failures.append(
            f"EBPSM mean makespan no longer beats MSLBL_MW "
            f"(ratio {ratio:.3f} >= 1)"
        )
    return failures


def write_report(art: Dict, path: str) -> None:
    lines = [
        f"# Paper grid — `{art['scenario']}`",
        "",
        art["description"],
        "",
        f"{art['n_cells']} cells, {art['n_workflows_per_cell']} workflows "
        f"per cell, wall {art['wall_s']:.1f}s.",
        "",
        "## Summary by policy",
        "",
        "| policy | mean makespan (s) | cost/budget | budget met "
        "(mean / min) | util | data hit | container hit |",
        "|---|---|---|---|---|---|---|",
    ]
    for pol, s in art["summary_by_policy"].items():
        lines.append(
            f"| {pol} | {s['mean_makespan_s']:.1f} "
            f"| {s['mean_cost_budget_ratio']:.3f} "
            f"| {s['budget_met_mean']:.1%} / {s['budget_met_min']:.1%} "
            f"| {s['utilization_mean']:.1%} "
            f"| {s['data_cache_hit_rate_mean']:.1%} "
            f"| {s['container_hit_rate_mean']:.1%} |"
        )
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        lines += ["", f"EBPSM / MSLBL_MW mean-makespan ratio: "
                      f"**{ratio:.3f}** (< 1 means EBPSM wins)."]
    lines += [
        "",
        "## Per-cell mean makespan (s)",
        "",
        "| app | rate | budget | seed | " + " | ".join(
            p for p in sorted({r['policy'] for r in art['cells']})) + " |",
        "|---|---|---|---|" + "---|" * len(
            {r['policy'] for r in art['cells']}),
    ]
    by_cell: Dict[tuple, Dict[str, float]] = {}
    for r in art["cells"]:
        key = (r["app"], r["rate_wf_per_min"], r["budget_lo"],
               r["budget_hi"], r["seed"])
        by_cell.setdefault(key, {})[r["policy"]] = r["mean_makespan_s"]
    pols = sorted({r["policy"] for r in art["cells"]})
    for key, vals in sorted(by_cell.items()):
        app, rate, blo, bhi, seed = key
        cells = " | ".join(f"{vals.get(p, float('nan')):.1f}" for p in pols)
        lines.append(f"| {app} | {rate} | [{blo},{bhi}] | {seed} | {cells} |")
    lines += ["", "Metrics glossary: see README.md § Reproducing the paper.",
              ""]
    with open(path, "w") as f:
        f.write("\n".join(lines))


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="paper-smoke",
                    help="scenario name (see repro.exp.scenarios)")
    ap.add_argument("--out", default="artifacts/exp")
    ap.add_argument("--cells-per-batch", type=int, default=8,
                    help="workload cells per batched engine run")
    ap.add_argument("--check-floors", action="store_true",
                    help="exit non-zero on budget-met floor / makespan-win "
                         "regressions")
    args = ap.parse_args(argv)

    scenario = get_scenario(args.grid)
    print(f"grid {scenario.name}: {scenario.n_cells} cells "
          f"({scenario.n_workload_cells} workloads x "
          f"{len(scenario.policies)} policies)")
    art = run_grid(scenario, cells_per_batch=args.cells_per_batch,
                   verbose=True)

    os.makedirs(args.out, exist_ok=True)
    jpath = os.path.join(args.out, ARTIFACT_NAME)
    with open(jpath, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    mpath = os.path.join(args.out, REPORT_NAME)
    write_report(art, mpath)
    print(f"artifact: {jpath}\nreport:   {mpath}")
    for pol, s in art["summary_by_policy"].items():
        print(f"  {pol:10s} mk={s['mean_makespan_s']:8.1f}s "
              f"met={s['budget_met_mean']:6.1%} (min {s['budget_met_min']:6.1%}) "
              f"util={s['utilization_mean']:6.1%}")
    ratio = art.get("ebpsm_vs_mslbl_makespan_ratio")
    if ratio is not None:
        print(f"  EBPSM/MSLBL_MW makespan ratio: {ratio:.3f}")

    if args.check_floors:
        failures = check_floors(art)
        if failures:
            raise SystemExit("FLOOR FAILURES:\n  " + "\n  ".join(failures))
        print("floor gate OK")


if __name__ == "__main__":
    main()

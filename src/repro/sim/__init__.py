"""Cloud-infrastructure substrate for the WaaS simulation."""
from .cloud import VM, VMPool  # noqa: F401

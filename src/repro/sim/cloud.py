"""IaaS substrate: VMs with FIFO local-storage caches and container images.

This is the infrastructure layer shared by every scheduling policy — the
policies differ only in *selection*, *budget handling* and *deprovisioning*,
never in the physics modelled here.

Lifecycle bookkeeping contract
------------------------------
Every VM status transition goes through a :class:`VMPool` method
(``mark_busy`` / ``mark_idle`` / ``terminate``), never through an ad-hoc
``vm.status = ...`` write.  The pool maintains a **live-state registry**
on top of the append-only ``vms`` list:

* ``_live``  — vmid → VM for every non-terminated VM;
* ``_idle``  — vmid → VM for the idle subset (``idle_vms`` is O(live),
  not O(every VM ever provisioned));
* ``data_index`` — inverted DataKey → {vmid} index over *live holders
  only* (emptied entries are pruned on eviction and termination);
* ``app_image`` / ``app_active`` — per-app vmid sets mirroring the
  container-image caches (the batched scheduling cycle builds its
  container-delay vectors from these instead of per-VM Python calls);
* ``tag_members`` — owner_tag → vmid set (sharing-scope masks);
* per-vmid ``mips`` / ``bandwidth`` / ``price`` float64 arrays plus the
  ``type_idx`` int array, grown amortized on provision (device-friendly
  gathers by vmid; float64 so the vectorized scheduler reproduces the
  scalar estimates bit-for-bit, cast to f32 only at the kernel boundary).

``VM.idle_epoch`` increments on every →IDLE transition; deferred REAP
events carry the epoch they were armed for, so a reap can never kill a
VM that was reused after the reap was scheduled (the old
``idle_since_ms`` timestamp marker collides when a VM goes busy and
returns to idle within the same millisecond).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.types import MS, PlatformConfig, VMType

# Data items are keyed by their producer: ("out", wid, tid) for task outputs,
# ("ext", wid, tid) for staged external inputs.
DataKey = Tuple[str, int, int]

VM_PROVISIONING = 1
VM_IDLE = 2
VM_BUSY = 3
VM_TERMINATED = 4


@dataclasses.dataclass(slots=True)
class VM:
    vmid: int
    vmt_idx: int
    vmt: VMType
    status: int = VM_PROVISIONING
    lease_start_ms: int = 0
    ready_ms: int = 0                 # provisioning completes
    idle_since_ms: int = 0
    idle_epoch: int = 0               # bumps on every →IDLE transition
    busy_ms: int = 0                  # accumulated busy time (utilization)
    terminated_ms: int = -1
    active_container: Optional[str] = None
    owner_tag: Optional[object] = None  # NS: wid; WS: app; else None
    # Spot market (repro.chaos): spot leases bill at price_per_bp — the
    # discounted rate — and may be revoked; on-demand leases keep
    # price_per_bp == vmt.cost_per_bp (set by __post_init__, so direct
    # VM(...) construction bills identically to the benign model).
    spot: bool = False
    price_per_bp: float = -1.0
    # FIFO caches: plain dicts (insertion-ordered since 3.7) — membership
    # checks on these are the hottest ops in the scheduler, and dict
    # lookups beat OrderedDict's doubly-linked bookkeeping.  FIFO
    # eviction pops the first key via iteration order.
    image_cache: Dict[str, bool] = dataclasses.field(default_factory=dict)
    data_cache: Dict[DataKey, float] = dataclasses.field(
        default_factory=dict
    )
    cached_mb: float = 0.0

    def __post_init__(self) -> None:
        if self.price_per_bp < 0.0:
            self.price_per_bp = self.vmt.cost_per_bp

    # ----- container image cache ------------------------------------------
    def container_ms(self, cfg: PlatformConfig, app: str, use_containers: bool) -> int:
        """Time to make ``app``'s container active on this VM."""
        if not use_containers:
            return 0
        if self.active_container == app:
            return 0
        if app in self.image_cache:
            return cfg.container_init_ms
        return cfg.container_provision_ms

    def activate_container(
        self,
        cfg: PlatformConfig,
        app: str,
        use_containers: bool,
        evicted: Optional[List[str]] = None,
    ) -> int:
        ms = self.container_ms(cfg, app, use_containers)
        if not use_containers:
            return 0
        if app not in self.image_cache:
            self.image_cache[app] = True
        self.active_container = app
        while len(self.image_cache) > cfg.image_slots:
            old = next(iter(self.image_cache))  # FIFO eviction
            del self.image_cache[old]
            if self.active_container == old:
                # An evicted image can't stay active — otherwise later
                # container_ms calls report 0 for an uncached image.
                self.active_container = None
            if evicted is not None:
                evicted.append(old)
        return ms

    # ----- data cache -------------------------------------------------------
    def has_data(self, key: DataKey) -> bool:
        return key in self.data_cache

    def missing_mb(self, inputs: List[Tuple[DataKey, float]]) -> float:
        return sum(mb for key, mb in inputs if key not in self.data_cache)

    def has_all_inputs(self, inputs: List[Tuple[DataKey, float]]) -> bool:
        return all(key in self.data_cache for key, mb in inputs if mb > 0)

    def cache_put(self, cfg: PlatformConfig, key: DataKey, mb: float,
                  index: Optional[Dict[DataKey, set]] = None) -> None:
        if mb <= 0:
            return
        if key in self.data_cache:
            return  # already cached; FIFO order unchanged (paper: FIFO, not LRU)
        self.data_cache[key] = mb
        self.cached_mb += mb
        if index is not None:
            index.setdefault(key, set()).add(self.vmid)
        cap_mb = self.vmt.storage_mb
        while (
            self.cached_mb > cap_mb or len(self.data_cache) > cfg.cache_slots
        ) and self.data_cache:
            old_key = next(iter(self.data_cache))   # FIFO eviction
            old_mb = self.data_cache.pop(old_key)
            self.cached_mb -= old_mb
            if index is not None and old_key in index:
                holders = index[old_key]
                holders.discard(self.vmid)
                if not holders:
                    del index[old_key]  # keep the index free of dead entries


class VMPool:
    """The platform's leased-VM inventory plus lifetime accounting.

    ``vms`` is the append-only historical record (vmids are list indices
    and never reused); the live-state registry documented in the module
    docstring keeps every per-cycle query O(live).
    """

    def __init__(self, cfg: PlatformConfig):
        self.cfg = cfg
        self.vms: List[VM] = []
        self.data_index: Dict[DataKey, set] = {}
        # Live-state registry (vmid-keyed; see module docstring).
        self._live: Dict[int, VM] = {}
        self._idle: Dict[int, VM] = {}
        self.app_image: Dict[str, set] = {}
        self.app_active: Dict[str, set] = {}
        self.tag_members: Dict[object, set] = {}
        # Per-vmid static VM-type attributes, grown amortized on provision.
        # float64: the vectorized scheduler.select computes the same IEEE
        # doubles as the scalar reference from these (the affinity kernel
        # casts to f32 at its buffer boundary, same rounding as before).
        self.mips = np.empty(64, np.float64)
        self.bandwidth = np.empty(64, np.float64)
        self.price = np.empty(64, np.float64)
        self.type_idx = np.zeros(64, np.int64)
        self.vm_seconds_by_type: Dict[str, float] = {
            v.name: 0.0 for v in cfg.vm_types
        }
        self.vm_busy_seconds_by_type: Dict[str, float] = {
            v.name: 0.0 for v in cfg.vm_types
        }
        self.vm_count_by_type: Dict[str, int] = {v.name: 0 for v in cfg.vm_types}

    # ----- lifecycle transitions -------------------------------------------
    def provision(self, vmt_idx: int, now_ms: int, owner_tag=None,
                  spot: bool = False,
                  price_per_bp: Optional[float] = None) -> VM:
        """``spot``/``price_per_bp``: spot-market lease terms
        (repro.chaos).  The pool's ``price`` array deliberately keeps
        the on-demand list price either way — the scheduler *plans* at
        list price and the pipeline *bills* at ``vm.price_per_bp``, so
        selection math (and engine parity with the benign model) is
        untouched by the discount."""
        vmt = self.cfg.vm_types[vmt_idx]
        vm = VM(
            vmid=len(self.vms),
            vmt_idx=vmt_idx,
            vmt=vmt,
            status=VM_PROVISIONING,
            lease_start_ms=now_ms,
            ready_ms=now_ms + self.cfg.vm_provision_delay_ms,
            owner_tag=owner_tag,
            spot=spot,
            price_per_bp=(vmt.cost_per_bp if price_per_bp is None
                          else price_per_bp),
        )
        self.vms.append(vm)
        self._live[vm.vmid] = vm
        self.tag_members.setdefault(owner_tag, set()).add(vm.vmid)
        if vm.vmid >= len(self.mips):
            grow = max(len(self.mips) * 2, vm.vmid + 1)
            for name in ("mips", "bandwidth", "price", "type_idx"):
                old = getattr(self, name)
                arr = np.empty(grow, old.dtype)
                arr[: len(old)] = old
                setattr(self, name, arr)
        self.mips[vm.vmid] = vmt.mips
        self.bandwidth[vm.vmid] = vmt.bandwidth_mbps
        self.price[vm.vmid] = vmt.cost_per_bp
        self.type_idx[vm.vmid] = vmt_idx
        self.vm_count_by_type[vmt.name] += 1
        return vm

    def mark_busy(self, vm: VM) -> None:
        """IDLE/PROVISIONING → BUSY (a pipeline starts on the VM)."""
        vm.status = VM_BUSY
        self._idle.pop(vm.vmid, None)

    def mark_idle(self, vm: VM, now_ms: int) -> None:
        """→ IDLE: registers the VM for reuse and opens a new idle epoch."""
        vm.status = VM_IDLE
        vm.idle_since_ms = now_ms
        vm.idle_epoch += 1
        self._idle[vm.vmid] = vm

    def activate_container(self, vm: VM, app: str, use_containers: bool) -> int:
        """``VM.activate_container`` + incremental app_image/app_active sync."""
        if not use_containers:
            return 0
        prev_active = vm.active_container
        evicted: List[str] = []
        ms = vm.activate_container(self.cfg, app, use_containers, evicted)
        if prev_active is not None and prev_active != vm.active_container:
            s = self.app_active.get(prev_active)
            if s is not None:
                s.discard(vm.vmid)
                if not s:
                    del self.app_active[prev_active]
        if vm.active_container is not None:
            self.app_active.setdefault(vm.active_container, set()).add(vm.vmid)
        for old in evicted:
            s = self.app_image.get(old)
            if s is not None:
                s.discard(vm.vmid)
                if not s:
                    del self.app_image[old]
        if app in vm.image_cache:
            self.app_image.setdefault(app, set()).add(vm.vmid)
        return ms

    def terminate(self, vm: VM, now_ms: int) -> None:
        assert vm.status in (VM_IDLE, VM_PROVISIONING), "cannot kill busy VM"
        self._close(vm, now_ms)

    def revoke(self, vm: VM, now_ms: int) -> None:
        """Spot revocation (repro.chaos): the *infrastructure* ends the
        lease, so — unlike :meth:`terminate`, where the scheduler must
        never kill a busy VM — any non-terminated status is legal here,
        including BUSY with a pipeline in flight (the engine requeues
        the killed task).  Cache eviction and index pruning are the
        same close-of-lease bookkeeping."""
        assert vm.status != VM_TERMINATED, "revoking a closed lease"
        self._close(vm, now_ms)

    def _close(self, vm: VM, now_ms: int) -> None:
        vm.status = VM_TERMINATED
        vm.terminated_ms = now_ms
        self._live.pop(vm.vmid, None)
        self._idle.pop(vm.vmid, None)
        tag = self.tag_members.get(vm.owner_tag)
        if tag is not None:
            tag.discard(vm.vmid)
            if not tag:
                del self.tag_members[vm.owner_tag]
        for key in vm.data_cache:
            holders = self.data_index.get(key)
            if holders is not None:
                holders.discard(vm.vmid)
                if not holders:
                    # Prune: the index must only ever name live holders.
                    del self.data_index[key]
        for app in vm.image_cache:
            s = self.app_image.get(app)
            if s is not None:
                s.discard(vm.vmid)
                if not s:
                    del self.app_image[app]
        if vm.active_container is not None:
            s = self.app_active.get(vm.active_container)
            if s is not None:
                s.discard(vm.vmid)
                if not s:
                    del self.app_active[vm.active_container]
        lease_ms = now_ms - vm.lease_start_ms
        self.vm_seconds_by_type[vm.vmt.name] += lease_ms / MS
        self.vm_busy_seconds_by_type[vm.vmt.name] += vm.busy_ms / MS

    def finalize(self, now_ms: int) -> None:
        """Close the books on any VM still alive at simulation end."""
        for vm in list(self._live.values()):
            if vm.status == VM_BUSY:
                vm.status = VM_IDLE  # should not happen on a drained sim
            self.terminate(vm, now_ms)

    # ----- live-state queries ----------------------------------------------
    def idle_vms(self) -> List[VM]:
        """Idle VMs in ascending-vmid order (the order every consumer —
        tie-breaks, auction columns — depends on), O(live)."""
        return [self._idle[k] for k in sorted(self._idle)]

    def live_vms(self) -> List[VM]:
        return [self._live[k] for k in sorted(self._live)]

    @property
    def n_live(self) -> int:
        return len(self._live)

    @property
    def n_idle(self) -> int:
        return len(self._idle)

    def check_invariants(self) -> None:
        """Registry ≡ full-history scan; indexes name live holders only.
        O(all VMs ever) — test/debug use, never on the hot path."""
        assert set(self._idle) == {
            vm.vmid for vm in self.vms if vm.status == VM_IDLE
        }, "idle registry diverged from VM statuses"
        assert set(self._live) == {
            vm.vmid for vm in self.vms if vm.status != VM_TERMINATED
        }, "live registry diverged from VM statuses"
        for key, holders in self.data_index.items():
            assert holders, f"empty holder set left in data_index for {key}"
            for vid in holders:
                vm = self.vms[vid]
                assert vm.status != VM_TERMINATED and vm.has_data(key)
        for app, holders in self.app_image.items():
            assert holders, f"empty holder set in app_image for {app}"
            for vid in holders:
                assert app in self.vms[vid].image_cache
        for app, holders in self.app_active.items():
            assert holders, f"empty holder set in app_active for {app}"
            for vid in holders:
                assert self.vms[vid].active_container == app

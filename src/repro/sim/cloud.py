"""IaaS substrate: VMs with FIFO local-storage caches and container images.

This is the infrastructure layer shared by every scheduling policy — the
policies differ only in *selection*, *budget handling* and *deprovisioning*,
never in the physics modelled here.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from ..core.types import MS, PlatformConfig, VMType

# Data items are keyed by their producer: ("out", wid, tid) for task outputs,
# ("ext", wid, tid) for staged external inputs.
DataKey = Tuple[str, int, int]

VM_PROVISIONING = 1
VM_IDLE = 2
VM_BUSY = 3
VM_TERMINATED = 4


@dataclasses.dataclass
class VM:
    vmid: int
    vmt_idx: int
    vmt: VMType
    status: int = VM_PROVISIONING
    lease_start_ms: int = 0
    ready_ms: int = 0                 # provisioning completes
    idle_since_ms: int = 0
    busy_ms: int = 0                  # accumulated busy time (utilization)
    terminated_ms: int = -1
    active_container: Optional[str] = None
    owner_tag: Optional[object] = None  # NS: wid; WS: app; else None
    # FIFO caches (insertion-ordered).
    image_cache: "OrderedDict[str, bool]" = dataclasses.field(
        default_factory=OrderedDict
    )
    data_cache: "OrderedDict[DataKey, float]" = dataclasses.field(
        default_factory=OrderedDict
    )
    cached_mb: float = 0.0

    # ----- container image cache ------------------------------------------
    def container_ms(self, cfg: PlatformConfig, app: str, use_containers: bool) -> int:
        """Time to make ``app``'s container active on this VM."""
        if not use_containers:
            return 0
        if self.active_container == app:
            return 0
        if app in self.image_cache:
            return cfg.container_init_ms
        return cfg.container_provision_ms

    def activate_container(self, cfg: PlatformConfig, app: str, use_containers: bool) -> int:
        ms = self.container_ms(cfg, app, use_containers)
        if not use_containers:
            return 0
        if app not in self.image_cache:
            self.image_cache[app] = True
            while len(self.image_cache) > cfg.image_slots:
                self.image_cache.popitem(last=False)  # FIFO eviction
        self.active_container = app
        return ms

    # ----- data cache -------------------------------------------------------
    def has_data(self, key: DataKey) -> bool:
        return key in self.data_cache

    def missing_mb(self, inputs: List[Tuple[DataKey, float]]) -> float:
        return sum(mb for key, mb in inputs if key not in self.data_cache)

    def has_all_inputs(self, inputs: List[Tuple[DataKey, float]]) -> bool:
        return all(key in self.data_cache for key, mb in inputs if mb > 0)

    def cache_put(self, cfg: PlatformConfig, key: DataKey, mb: float,
                  index: Optional[Dict[DataKey, set]] = None) -> None:
        if mb <= 0:
            return
        if key in self.data_cache:
            return  # already cached; FIFO order unchanged (paper: FIFO, not LRU)
        self.data_cache[key] = mb
        self.cached_mb += mb
        if index is not None:
            index.setdefault(key, set()).add(self.vmid)
        cap_mb = self.vmt.storage_mb
        while (
            self.cached_mb > cap_mb or len(self.data_cache) > cfg.cache_slots
        ) and self.data_cache:
            old_key, old_mb = self.data_cache.popitem(last=False)
            self.cached_mb -= old_mb
            if index is not None and old_key in index:
                index[old_key].discard(self.vmid)


class VMPool:
    """The platform's leased-VM inventory plus lifetime accounting.

    ``data_index`` is an inverted index DataKey → {vmid}: which live VMs
    hold a given dataset.  The batched (JAX) scheduling cycle reads it to
    build the task×VM missing-bytes matrix without touching per-VM dicts.
    """

    def __init__(self, cfg: PlatformConfig):
        self.cfg = cfg
        self.vms: List[VM] = []
        self.data_index: Dict[DataKey, set] = {}
        self.vm_seconds_by_type: Dict[str, float] = {
            v.name: 0.0 for v in cfg.vm_types
        }
        self.vm_busy_seconds_by_type: Dict[str, float] = {
            v.name: 0.0 for v in cfg.vm_types
        }
        self.vm_count_by_type: Dict[str, int] = {v.name: 0 for v in cfg.vm_types}

    def provision(self, vmt_idx: int, now_ms: int, owner_tag=None) -> VM:
        vmt = self.cfg.vm_types[vmt_idx]
        vm = VM(
            vmid=len(self.vms),
            vmt_idx=vmt_idx,
            vmt=vmt,
            status=VM_PROVISIONING,
            lease_start_ms=now_ms,
            ready_ms=now_ms + self.cfg.vm_provision_delay_ms,
            owner_tag=owner_tag,
        )
        self.vms.append(vm)
        self.vm_count_by_type[vmt.name] += 1
        return vm

    def terminate(self, vm: VM, now_ms: int) -> None:
        assert vm.status in (VM_IDLE, VM_PROVISIONING), "cannot kill busy VM"
        vm.status = VM_TERMINATED
        vm.terminated_ms = now_ms
        for key in vm.data_cache:
            if key in self.data_index:
                self.data_index[key].discard(vm.vmid)
        lease_ms = now_ms - vm.lease_start_ms
        self.vm_seconds_by_type[vm.vmt.name] += lease_ms / MS
        self.vm_busy_seconds_by_type[vm.vmt.name] += vm.busy_ms / MS

    def finalize(self, now_ms: int) -> None:
        """Close the books on any VM still alive at simulation end."""
        for vm in self.vms:
            if vm.status != VM_TERMINATED:
                if vm.status == VM_BUSY:
                    vm.status = VM_IDLE  # should not happen on a drained sim
                self.terminate(vm, now_ms)

    def idle_vms(self) -> List[VM]:
        return [vm for vm in self.vms if vm.status == VM_IDLE]

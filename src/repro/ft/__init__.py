"""ft substrate."""

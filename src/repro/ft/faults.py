"""Fault tolerance: failure injection, restart driver, straggler model.

The paper explicitly defers task failure to future work; we implement it
as a beyond-paper feature at two levels:

1. **Training level** — ``FaultyTrainer`` wraps a train loop with
   (a) periodic async-ish checkpointing, (b) injected step failures
   (probability per step), (c) restart-from-latest with elastic re-shard
   (the restore may target a different mesh).
2. **Scheduler level** — the WaaS simulator can mark tasks failed at
   runtime; EBPSM re-queues them and the budget-update loop (Alg. 3)
   absorbs the wasted cost exactly like any other uncertainty.  Straggler
   mitigation reuses the paper's own mechanism: a task whose actual
   runtime exceeds ``straggler_factor ×`` estimate triggers sub-budget
   re-distribution for its successors onto faster VMs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np

from .. import ckpt


@dataclasses.dataclass
class FaultPlan:
    fail_prob: float = 0.0          # per-step failure probability
    seed: int = 0
    ckpt_every: int = 10
    keep: int = 2


class StepFailure(RuntimeError):
    pass


class FaultyTrainer:
    """Drives (train_step, state) with failure injection + restart."""

    def __init__(self, ckpt_dir: str, plan: FaultPlan):
        self.ckpt_dir = ckpt_dir
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.restarts = 0
        self.failed_steps: list[int] = []

    def maybe_fail(self, step: int) -> None:
        if self.rng.random() < self.plan.fail_prob:
            self.failed_steps.append(step)
            raise StepFailure(f"injected failure at step {step}")

    def run(self, *, params, opt, n_steps: int, step_fn: Callable,
            batch_fn: Callable[[int], Any], shardings=None,
            start_step: int = 0):
        """Returns (params, opt, history).  ``step_fn(params,opt,batch)``."""
        history: Dict[str, list] = {"loss": [], "step": []}
        step = start_step
        while step < n_steps:
            try:
                self.maybe_fail(step)
                params, opt, metrics = step_fn(params, opt, batch_fn(step))
                history["loss"].append(float(metrics["loss"]))
                history["step"].append(step)
                step += 1
                if step % self.plan.ckpt_every == 0:
                    ckpt.save_sections(self.ckpt_dir, step,
                                       {"params": params, "opt": opt})
                    ckpt.prune(self.ckpt_dir, self.plan.keep)
            except StepFailure:
                self.restarts += 1
                last = ckpt.latest_step(self.ckpt_dir)
                restore_to = start_step if last is None else last
                # Roll the history back with the parameters: entries at
                # or past the restore point are about to be re-executed
                # and would otherwise appear twice (and the final
                # history would carry losses from abandoned lineages).
                # Steps ascend, so one reverse scan finds the cut.
                cut = len(history["step"])
                while cut > 0 and history["step"][cut - 1] >= restore_to:
                    cut -= 1
                del history["step"][cut:]
                del history["loss"][cut:]
                if last is None:     # no checkpoint yet → restart from init
                    step = start_step
                    continue
                params, _ = ckpt.restore_section(self.ckpt_dir, last, params,
                                                 shardings, "params")
                opt, _ = ckpt.restore_section(self.ckpt_dir, last, opt,
                                              None, "opt")
                step = last
        return params, opt, history

"""End-to-end training driver: a ~100M-param llama-family model, sharded
train step, synthetic data pipeline, checkpointing, and fault-tolerant
restart — the framework path a real run uses, scaled to one CPU host.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --params 100
    PYTHONPATH=src python examples/train_lm.py --steps 40 --params 25   # quick

Use --fail-prob to watch the FaultyTrainer checkpoint/restart machinery.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.faults import FaultPlan, FaultyTrainer
from repro.launch.mesh import make_host_mesh
from repro.models.common import ModelConfig, RunConfig
from repro.models.registry import Model
from repro.train.optim import init_opt_state
from repro.train.train_step import build_train_step


def model_for(params_m: int) -> Model:
    """A llama-style dense decoder sized to ≈ params_m million params."""
    if params_m >= 100:
        cfg = ModelConfig(name=f"lm-{params_m}m", family="dense",
                          n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                          d_ff=2048, vocab=32_000, head_dim=64)
    else:
        cfg = ModelConfig(name=f"lm-{params_m}m", family="dense",
                          n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
                          d_ff=1024, vocab=16_000, head_dim=64)
    run = RunConfig(remat="none", learning_rate=3e-4)
    return Model(arch=cfg.name, cfg=cfg, run=run)


def batches(cfg, B: int, L: int):
    def at(step: int):
        rng = np.random.default_rng((13, step))
        # order-2 markov-ish synthetic text: learnable structure
        base = rng.integers(0, cfg.vocab // 64, (B, L)).astype(np.int32)
        toks = (base * 64 + np.roll(base, 1, axis=1) % 64) % cfg.vocab
        t = jnp.asarray(toks)
        return {"tokens": t, "labels": t}
    return at


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--params", type=int, default=100, help="size in M")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_example")
    args = ap.parse_args()

    m = model_for(args.params)
    print(f"model: {m.arch}, {m.n_params()/1e6:.1f}M params")
    mesh = make_host_mesh(model=1)
    fn, *_ = build_train_step(m, mesh, donate=False)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch_at = batches(m.cfg, args.batch, args.seq)

    trainer = FaultyTrainer(args.ckpt_dir,
                            FaultPlan(fail_prob=args.fail_prob, seed=1,
                                      ckpt_every=25))
    t0 = time.time()
    params, opt, hist = trainer.run(params=params, opt=opt,
                                    n_steps=args.steps, step_fn=fn,
                                    batch_fn=batch_at)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"steps={args.steps} wall={dt:.1f}s ({tok_s:,.0f} tok/s) "
          f"restarts={trainer.restarts}")
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")
    if args.steps >= 50:   # too few steps sit inside the LR warmup
        assert hist["loss"][-1] < hist["loss"][0]


if __name__ == "__main__":
    main()

"""Serving driver: prefill a batch of prompts, then decode tokens with a
KV cache — the framework's serve path on one CPU host.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import RunConfig, build


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    m = build(args.arch, RunConfig(remat="none"), smoke=True)
    params = m.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 m.cfg.vocab)
    batch = {"tokens": prompts}
    if m.cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (args.batch, m.cfg.n_patches, m.cfg.patch_dim),
            jnp.bfloat16)

    max_seq = args.prompt_len + args.tokens
    prefill = jax.jit(lambda p, b: m.prefill(p, b, max_seq))
    decode = jax.jit(m.decode_step)

    t0 = time.time()
    logits, state = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.tokens - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    seq = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"arch={args.arch} (smoke config) prefill {args.prompt_len} tok, "
          f"decoded {args.tokens} tok in {dt:.2f}s")
    for b in range(args.batch):
        print(f"  seq[{b}]:", seq[b].tolist())


if __name__ == "__main__":
    main()

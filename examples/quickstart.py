"""Quickstart: schedule a multi-tenant workflow workload with EBPSM.

    PYTHONPATH=src python examples/quickstart.py

Generates a small WaaS workload (five Pegasus-profile applications,
Poisson arrivals), runs all five scheduling policies, and prints the
paper's headline comparison (makespan / budget-met / utilization).
"""
import numpy as np

from repro.core.engine import simulate
from repro.core.scheduler import ALL_POLICIES
from repro.core.types import PlatformConfig
from repro.workflows.workload import WorkloadSpec, generate_workload


def main() -> None:
    cfg = PlatformConfig()
    spec = WorkloadSpec(n_workflows=60, arrival_rate_per_min=6.0, seed=7,
                        sizes=("small", "medium"))
    print(f"workload: {spec.n_workflows} workflows, "
          f"{spec.arrival_rate_per_min} wf/min\n")
    print(f"{'policy':10s} {'makespan':>10s} {'budget-met':>11s} "
          f"{'util':>7s} {'#VMs':>6s}")
    for policy in ALL_POLICIES:
        wfs = generate_workload(cfg, spec)
        res = simulate(cfg, policy, wfs, seed=0)
        mk = np.mean([w.makespan_ms for w in res.workflows]) / 1000
        print(f"{policy.name:10s} {mk:9.1f}s {res.budget_met_fraction:10.1%} "
              f"{res.avg_vm_utilization:6.1%} {res.total_vms:6d}")


if __name__ == "__main__":
    main()

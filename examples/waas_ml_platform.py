"""The paper's policy running a multi-tenant TPU-slice ML platform.

    PYTHONPATH=src python examples/waas_ml_platform.py

Tenants submit fine-tune and serve jobs over the 10 assigned
architectures; stage costs come from the compiled dry-run artifacts when
available (run ``python -m repro.launch.dryrun --all`` first for the
coupled version — falls back to analytic costs otherwise).
"""
from repro.waas.platform import compare_policies, straggler_experiment


def main() -> None:
    print("== multi-tenant ML platform: policy comparison ==")
    for rep in compare_policies(n_jobs=40, rate=2.0, seed=7):
        print(rep.row())  # repro.exp.metrics schema (see README glossary)
        print(f"    placement tiers (1=warm weights, 2=warm program, "
              f"3=any idle slice, 4=new slice): {rep.tier_hist}")
        print(f"    slice mix: {rep.slice_mix}  "
              f"cached-input bytes: {rep.metrics.data_cache_hit_rate:.1%}")

    print("\n== straggler sensitivity (slice perf degradation) ==")
    st = straggler_experiment(n_jobs=20, rate=2.0, seed=7,
                              degradations=(0.1, 0.3, 0.5))
    for pol, rows in st.items():
        for dmax, mk, met in rows:
            print(f"  {pol:10s} degradation≤{dmax:.0%}: "
                  f"makespan={mk:8.1f}s budget-met={met:.1%}")


if __name__ == "__main__":
    main()

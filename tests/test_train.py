"""Training substrate: optimizer, loop, checkpoint/restart, faults."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.ft.faults import FaultPlan, FaultyTrainer
from repro.launch.mesh import make_host_mesh
from repro.models import RunConfig, build
from repro.train.optim import adamw_update, init_opt_state, lr_schedule
from repro.train.train_step import build_train_step, make_train_step

RUN = RunConfig(remat="none", learning_rate=1e-3)


def tiny_model():
    return build("llama3-8b", RUN, smoke=True)


def tiny_batch(cfg, step=0, B=4, L=32):
    rng = np.random.default_rng(step)
    # learnable: constant-ish mapping
    toks = rng.integers(0, 16, (B, L)).astype(np.int32)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def test_loss_decreases():
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(m))
    losses = []
    for i in range(16):
        params, opt, metrics = step(params, opt, tiny_batch(m.cfg, 0))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
    assert int(opt["step"]) == 16


def test_grad_accumulation_equivalence():
    m1 = tiny_model()
    m2 = build("llama3-8b", RUN.with_(microbatch=2), smoke=True)
    params = m1.init(jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    b = tiny_batch(m1.cfg, 3)
    p1, _, met1 = jax.jit(make_train_step(m1))(params, opt, b)
    p2, _, met2 = jax.jit(make_train_step(m2))(params, opt, b)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3   # accumulation ≈ full batch


def test_build_train_step_on_host_mesh():
    mesh = make_host_mesh(model=1)
    m = tiny_model()
    fn, psh, osh, bsh = build_train_step(m, mesh, donate=False)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    params, opt, metrics = fn(params, opt, tiny_batch(m.cfg))
    assert jnp.isfinite(metrics["loss"])


def test_lr_schedule():
    assert float(lr_schedule(jnp.asarray(0), 1e-3)) == 0.0
    assert float(lr_schedule(jnp.asarray(100), 1e-3)) == pytest.approx(1e-3)
    assert float(lr_schedule(jnp.asarray(10_000), 1e-3)) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    d = str(tmp_path)
    ckpt.save(d, 7, params, opt, extra={"note": "x"})
    assert ckpt.latest_step(d) == 7
    restored, step = ckpt.restore(d, None, params)
    assert step == 7
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                       params, restored)
    assert max(jax.tree.leaves(err)) == 0.0
    opt_r, _ = ckpt.restore(d, 7, opt, section="opt")
    assert int(opt_r["step"]) == int(opt["step"])


def test_checkpoint_prune(tmp_path):
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, params)
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 4
    assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_faulty_trainer_recovers(tmp_path):
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(m))
    plan = FaultPlan(fail_prob=0.25, seed=1, ckpt_every=3, keep=2)
    tr = FaultyTrainer(str(tmp_path), plan)
    params, opt, hist = tr.run(params=params, opt=opt, n_steps=15,
                               step_fn=step,
                               batch_fn=lambda s: tiny_batch(m.cfg, 0))
    assert tr.restarts > 0, "fault injection never fired — raise fail_prob"
    assert int(opt["step"]) >= 15        # made it to the end despite faults
    assert hist["loss"][-1] < hist["loss"][0]


def test_faulty_trainer_history_rolls_back_with_restart(tmp_path):
    """A restart truncates history to the restore point: the final
    history is exactly one entry per step with no duplicates from
    re-executed (abandoned-lineage) steps."""
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(m))
    plan = FaultPlan(fail_prob=0.3, seed=5, ckpt_every=4, keep=2)
    tr = FaultyTrainer(str(tmp_path), plan)
    params, opt, hist = tr.run(params=params, opt=opt, n_steps=12,
                               step_fn=step,
                               batch_fn=lambda s: tiny_batch(m.cfg, 0))
    assert tr.restarts > 0, "fault injection never fired — raise fail_prob"
    assert hist["step"] == list(range(12))
    assert len(hist["loss"]) == len(hist["step"])


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoint written unsharded restores onto a mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    d = str(tmp_path)
    ckpt.save(d, 1, params)
    mesh = make_host_mesh(model=1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored, _ = ckpt.restore(d, 1, params, shardings=sh)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                       params, restored)
    assert max(jax.tree.leaves(err)) == 0.0


def test_faulty_trainer_elastic_reshard(tmp_path):
    """Restart path restores onto a *different* mesh's shardings — the
    elastic re-shard route FaultyTrainer.run takes after a failure."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(m))
    mesh = make_host_mesh(model=1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    plan = FaultPlan(fail_prob=0.35, seed=7, ckpt_every=2, keep=3)
    tr = FaultyTrainer(str(tmp_path), plan)
    params, opt, hist = tr.run(params=params, opt=opt, n_steps=10,
                               step_fn=step,
                               batch_fn=lambda s: tiny_batch(m.cfg, 0),
                               shardings=sh)
    assert tr.restarts > 0, "fault injection never fired — raise fail_prob"
    assert int(opt["step"]) >= 10
    # Restored-then-trained params landed on the target mesh's sharding.
    leaf = jax.tree.leaves(params)[0]
    assert leaf.sharding.is_equivalent_to(NamedSharding(mesh, P()),
                                          ndim=leaf.ndim)


def test_restore_section_rejects_shape_mismatch(tmp_path):
    """A template whose leaf shapes disagree with the checkpoint must
    fail loudly — elastic restore re-shards meshes, never array shapes."""
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    ckpt.save(str(tmp_path), 1, params)
    bad = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore_section(str(tmp_path), 1, bad, None, "params")


def test_data_pipeline_deterministic_and_shardable():
    dc = DataConfig(seed=3, seq_len=64, global_batch=8)
    a = batch_at(dc, 5)
    b = batch_at(dc, 5)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = batch_at(dc, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])

"""End-to-end paper-claim tests (scaled-down, fixed seeds).

These assert the paper's *qualitative* claims on small workloads — the
full-scale versions live in benchmarks/ and EXPERIMENTS.md.
"""
import numpy as np
import pytest

from repro.core.engine import simulate
from repro.core.scheduler import (ALL_POLICIES, EBPSM, EBPSM_NC, EBPSM_NS,
                                  EBPSM_WS, MSLBL_MW)
from repro.core.types import PlatformConfig
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def mean_makespan(policy, rate, n=60, seed=2, sizes=("small", "medium")):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=sizes)
    res = simulate(CFG, policy, generate_workload(CFG, spec), seed=0)
    return np.mean([w.makespan_ms for w in res.workflows]), res


def test_sharing_beats_dedicated_at_high_rate():
    """Fig. 3 claim: sharing variants improve with arrival density while
    the dedicated (NS) baseline stays flat."""
    mk_e_lo, _ = mean_makespan(EBPSM, 1.0)
    mk_e_hi, _ = mean_makespan(EBPSM, 12.0)
    mk_ns_lo, _ = mean_makespan(EBPSM_NS, 1.0)
    mk_ns_hi, _ = mean_makespan(EBPSM_NS, 12.0)
    assert mk_e_hi < mk_e_lo            # sharing improves with density
    assert abs(mk_ns_hi - mk_ns_lo) / mk_ns_lo < 0.02   # NS flat
    assert mk_e_hi < mk_ns_hi           # sharing beats dedicated


def test_ebpsm_beats_mslbl_at_density():
    mk_e, _ = mean_makespan(EBPSM, 12.0)
    mk_m, _ = mean_makespan(MSLBL_MW, 12.0)
    assert mk_e < mk_m


def test_nc_marginally_better_than_containers():
    """Fig. 3: container init delay costs a little; difference marginal."""
    mk_e, _ = mean_makespan(EBPSM, 6.0)
    mk_nc, _ = mean_makespan(EBPSM_NC, 6.0)
    assert mk_nc <= mk_e
    assert (mk_e - mk_nc) / mk_nc < 0.35


def test_budget_met_rate():
    """Fig. 4a claim: ≥95% budget-met (n=120 to keep CI fast)."""
    spec = WorkloadSpec(n_workflows=120, arrival_rate_per_min=6.0, seed=5,
                        sizes=("small", "medium"))
    res = simulate(CFG, EBPSM, generate_workload(CFG, spec), seed=0)
    assert res.budget_met_fraction >= 0.93


def test_ebpsm_uses_fewer_vms_than_mslbl():
    """Sharing + delayed reaping → fewer, better-utilized VMs."""
    _, res_e = mean_makespan(EBPSM, 6.0)
    _, res_m = mean_makespan(MSLBL_MW, 6.0)
    assert res_e.total_vms < res_m.total_vms


def test_degradation_sensitivity_ordering():
    """Fig. 5 claim: EBPSM degrades more gracefully than MSLBL_MW."""
    def run(policy, dmax):
        cfg = CFG.with_(cpu_degradation_mean=dmax / 2,
                        cpu_degradation_std=0.01, cpu_degradation_max=dmax)
        spec = WorkloadSpec(n_workflows=40, arrival_rate_per_min=6.0,
                            seed=4, sizes=("small",))
        res = simulate(cfg, policy, generate_workload(cfg, spec), seed=0)
        return res.budget_met_fraction

    met_e = run(EBPSM, 0.6)
    met_m = run(MSLBL_MW, 0.6)
    assert met_e >= met_m - 0.05

"""Discrete-event engine invariants (reference implementation)."""
import numpy as np
import pytest

from repro.core.engine import SimEngine, simulate
from repro.core.scheduler import ALL_POLICIES, EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def small_workload(seed=0, n=12, rate=2.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",))
    return generate_workload(CFG, spec)


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_all_tasks_complete(policy):
    wfs = small_workload()
    res = simulate(CFG, policy, wfs, seed=0)
    assert len(res.workflows) == len(wfs)
    for w, r in zip(wfs, res.workflows):
        assert r.n_tasks == w.n_tasks
        assert r.finish_ms >= r.arrival_ms
        assert r.cost > 0


def test_determinism():
    a = simulate(CFG, EBPSM, small_workload(), seed=0)
    b = simulate(CFG, EBPSM, small_workload(), seed=0)
    assert [w.finish_ms for w in a.workflows] == \
        [w.finish_ms for w in b.workflows]
    assert [w.cost for w in a.workflows] == [w.cost for w in b.workflows]


def test_parents_finish_before_children_start():
    wfs = small_workload(seed=3, n=6)
    eng = SimEngine(CFG, EBPSM, wfs, seed=0, trace=True)
    eng.run()
    # trace rows: (now, wid, tid, tier, est_cost) at schedule time
    sched_time = {(r[1], r[2]): r[0] for r in eng.trace_rows}
    for wf in wfs:
        for t in wf.tasks:
            for p in t.parents:
                assert sched_time[(wf.wid, p)] <= sched_time[(wf.wid, t.tid)]


def test_utilization_bounded():
    for policy in ALL_POLICIES:
        res = simulate(CFG, policy, small_workload(seed=1), seed=0)
        assert 0.0 < res.avg_vm_utilization <= 1.0 + 1e-9


def test_no_degradation_costs_match_estimates_closely():
    cfg = CFG.with_(cpu_degradation_max=0.0, cpu_degradation_mean=0.0,
                    cpu_degradation_std=0.0, bw_degradation_max=0.0,
                    bw_degradation_mean=0.0, bw_degradation_std=0.0)
    wfs = small_workload(seed=5, n=8)
    res = simulate(cfg, EBPSM, wfs, seed=0)
    # without uncertainty, violations should be extremely rare
    assert res.budget_met_fraction >= 0.8


def test_owner_isolation_ns():
    """EBPSM_NS never shares VMs across workflows: every VM has a wf tag."""
    from repro.core.scheduler import EBPSM_NS
    wfs = small_workload(seed=2, n=6)
    eng = SimEngine(CFG, EBPSM_NS, wfs, seed=0)
    eng.run()
    tags = {vm.owner_tag for vm in eng.pool.vms}
    assert all(t is not None and t[0] == "wf" for t in tags)
    assert len({t[1] for t in tags}) > 1

"""VM-lifecycle correctness: idle-epoch reaping, image-eviction accounting,
data-index pruning, and the VMPool live-state registry invariants.

Regression tests for the three lifecycle bugs fixed alongside the
registry: (1) a deferred REAP armed before a reuse could kill the VM when
the reuse started and ended within the same millisecond (the old
``idle_since_ms`` timestamp marker cannot tell the two idle periods
apart); (2) FIFO image eviction could leave ``active_container``
pointing at an image no longer cached, making later ``container_ms``
calls report 0 for an image that must be re-provisioned; (3)
``VMPool.terminate`` discarded vmids from ``data_index`` holder sets but
never pruned emptied sets, so the index grew monotonically over long
multi-tenant runs.
"""
import dataclasses

import pytest

from repro.core.engine import SimEngine, SimState
from repro.core.scheduler import EBPSM, EBPSM_NC
from repro.core.types import PlatformConfig
from repro.sim.cloud import VM, VM_IDLE, VM_TERMINATED, VMPool
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def mk_vm(vmt_idx=0):
    return VM(vmid=0, vmt_idx=vmt_idx, vmt=CFG.vm_types[vmt_idx])


# ---------------------------------------------------------------------------
# Bugfix 1 — stale REAP vs same-millisecond reuse
# ---------------------------------------------------------------------------


def test_stale_reap_spares_same_millisecond_reuse():
    """A REAP belongs to the idle period it was armed for.  A reuse whose
    zero-length pipeline (containers off, warm cache, 0-ms runtime)
    returns the VM to idle within the same millisecond leaves
    ``idle_since_ms`` unchanged — the old timestamp-marker check killed
    the VM; the idle-epoch counter must not."""
    st = SimState(CFG, EBPSM, [])
    vm = st.pool.provision(0, now_ms=0)
    st.now = 100
    st.pool.mark_idle(vm, 100)              # idle period 1 opens
    stale_epoch = vm.idle_epoch              # payload of period 1's REAP
    st.pool.mark_busy(vm)                    # reused: zero-length pipeline…
    st.pool.mark_idle(vm, 100)              # …idle again in the same ms
    assert vm.idle_since_ms == 100           # the timestamp cannot tell
    st.now = 100 + EBPSM.idle_threshold_ms
    st._handle_reap(vm.vmid, stale_epoch)    # period 1's REAP fires
    assert vm.status == VM_IDLE, \
        "stale REAP killed a VM that was reused after it was armed"


def test_current_epoch_reap_still_terminates():
    """The fix must not break legitimate reaping: the reap armed for the
    *current* idle period terminates an untouched VM."""
    st = SimState(CFG, EBPSM, [])
    vm = st.pool.provision(0, now_ms=0)
    st.now = 100
    st.pool.mark_idle(vm, 100)
    st.now = 100 + EBPSM.idle_threshold_ms
    st._handle_reap(vm.vmid, vm.idle_epoch)
    assert vm.status == VM_TERMINATED


def test_finish_arms_reap_with_current_epoch():
    """End-to-end: every REAP event the engine queues carries exactly the
    idle epoch current at arming time (captured at the _push call, before
    any later transition can bump it)."""
    from repro.core.engine import REAP

    spec = WorkloadSpec(n_workflows=3, arrival_rate_per_min=6.0, seed=0,
                        sizes=("small",), budget_lo=0.5, budget_hi=1.0)
    eng = SimEngine(CFG, EBPSM, generate_workload(CFG, spec), seed=0)
    orig_push = eng._push
    armed = []
    def spy(t_ms, kind, payload):
        if kind == REAP:
            vmid, epoch = payload
            armed.append(epoch == eng.pool.vms[vmid].idle_epoch)
        orig_push(t_ms, kind, payload)
    eng._push = spy
    eng.run()
    assert armed, "run armed no REAP events"
    assert all(armed), "a REAP was armed with a non-current idle epoch"


# ---------------------------------------------------------------------------
# Bugfix 2 — image eviction vs active_container
# ---------------------------------------------------------------------------


def test_eviction_invalidates_active_container():
    """When FIFO eviction removes the image backing ``active_container``
    (tight image_slots), the pointer must be invalidated — otherwise
    ``container_ms`` reports 0 for an image that is no longer cached."""
    cfg = CFG.with_(image_slots=0)
    vm = mk_vm()
    vm.activate_container(cfg, "llama", True)
    assert "llama" not in vm.image_cache
    assert vm.active_container != "llama", \
        "active_container points at an evicted image"
    assert vm.container_ms(cfg, "llama", True) == cfg.container_provision_ms


def test_eviction_keeps_fifo_accounting():
    """Normal-slots behavior is unchanged: the newly activated image
    survives, the oldest is evicted, and the pointer follows the
    activation."""
    cfg = CFG.with_(image_slots=2)
    vm = mk_vm()
    vm.activate_container(cfg, "a", True)
    vm.activate_container(cfg, "b", True)
    vm.activate_container(cfg, "c", True)      # evicts "a"
    assert list(vm.image_cache) == ["b", "c"]
    assert vm.active_container == "c"
    assert vm.container_ms(cfg, "a", True) == cfg.container_provision_ms
    assert vm.container_ms(cfg, "b", True) == cfg.container_init_ms
    assert vm.container_ms(cfg, "c", True) == 0


def test_pool_activate_container_syncs_app_indexes():
    """The pool wrapper mirrors activations and evictions into the
    incremental app_image / app_active sets the batched cycle reads."""
    cfg = CFG.with_(image_slots=1)
    pool = VMPool(cfg)
    vm = pool.provision(0, now_ms=0)
    pool.activate_container(vm, "a", True)
    assert pool.app_image == {"a": {vm.vmid}}
    assert pool.app_active == {"a": {vm.vmid}}
    pool.activate_container(vm, "b", True)     # evicts "a"
    assert pool.app_image == {"b": {vm.vmid}}
    assert pool.app_active == {"b": {vm.vmid}}
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Bugfix 3 — data_index pruning
# ---------------------------------------------------------------------------


def test_terminate_prunes_data_index():
    """Terminating the last holder of a dataset removes the key outright;
    the inverted index must not accumulate dead entries over long runs."""
    pool = VMPool(CFG)
    vm = pool.provision(0, now_ms=0)
    pool.mark_idle(vm, 0)
    vm.cache_put(CFG, ("out", 0, 0), 10.0, pool.data_index)
    vm.cache_put(CFG, ("out", 0, 1), 10.0, pool.data_index)
    assert len(pool.data_index) == 2
    pool.terminate(vm, now_ms=1_000)
    assert pool.data_index == {}, \
        "terminate left empty holder sets in data_index"


def test_eviction_prunes_data_index():
    """FIFO capacity eviction of the last holder also prunes the key."""
    pool = VMPool(CFG)
    vm = pool.provision(0, now_ms=0)
    cap = vm.vmt.storage_mb
    vm.cache_put(CFG, ("out", 0, 0), cap * 0.6, pool.data_index)
    vm.cache_put(CFG, ("out", 0, 1), cap * 0.6, pool.data_index)  # evicts 0
    assert ("out", 0, 0) not in pool.data_index
    assert pool.data_index == {("out", 0, 1): {vm.vmid}}
    pool.check_invariants()


def test_shared_holder_not_pruned_early():
    """A key with surviving holders keeps its (pruned) holder set."""
    pool = VMPool(CFG)
    a = pool.provision(0, now_ms=0)
    b = pool.provision(0, now_ms=0)
    for vm in (a, b):
        pool.mark_idle(vm, 0)
        vm.cache_put(CFG, ("shared", "ckpt", 0), 5.0, pool.data_index)
    pool.terminate(a, now_ms=1_000)
    assert pool.data_index == {("shared", "ckpt", 0): {b.vmid}}
    pool.terminate(b, now_ms=2_000)
    assert pool.data_index == {}


# ---------------------------------------------------------------------------
# Live-state registry
# ---------------------------------------------------------------------------


def test_registry_tracks_transitions():
    pool = VMPool(CFG)
    a = pool.provision(0, now_ms=0)
    b = pool.provision(1, now_ms=0)
    assert pool.n_live == 2 and pool.n_idle == 0
    pool.mark_idle(a, 10)
    pool.mark_idle(b, 10)
    assert [vm.vmid for vm in pool.idle_vms()] == [a.vmid, b.vmid]
    pool.mark_busy(a)
    assert [vm.vmid for vm in pool.idle_vms()] == [b.vmid]
    pool.check_invariants()
    pool.mark_idle(a, 20)
    pool.terminate(b, 30)
    assert [vm.vmid for vm in pool.idle_vms()] == [a.vmid]
    assert pool.n_live == 1
    pool.check_invariants()


def test_registry_invariants_after_full_run():
    """Registry bookkeeping survives a real multi-workflow run with
    deferred reaping, and finalize drains everything (the pruned
    data_index ends empty)."""
    spec = WorkloadSpec(n_workflows=6, arrival_rate_per_min=6.0, seed=3,
                        sizes=("small",), budget_lo=0.5, budget_hi=1.0)
    for pol in (EBPSM, EBPSM_NC,
                dataclasses.replace(EBPSM, name="EBPSM_1S",
                                    idle_threshold_ms=1_000)):
        eng = SimEngine(CFG, pol, generate_workload(CFG, spec), seed=0)
        eng.run()
        eng.pool.check_invariants()
        assert eng.pool.n_live == 0
        assert eng.pool.data_index == {}
        assert eng.pool.app_image == {} and eng.pool.app_active == {}
        assert eng.pool.tag_members == {}

"""repro.chaos: deterministic fault injection through both engines.

The contract under test (PR 9):

* **determinism** — injection is a pure function of ``(seed, config)``:
  repeat runs produce identical results, counters and event streams;
* **parity** — ``SimEngine`` (object and SoA layouts) and
  ``BatchSimEngine`` agree bit-exactly under revocations, failures and
  stragglers, and a stream interrupted/resumed through a revocation
  round finishes bit-exact with the uninterrupted run;
* **zero-cost disabled** — ``chaos=None`` (or an all-zero config) is
  bit-identical to an engine built without the argument;
* **semantics** — retries are bounded by ``max_retries``, spot leases
  are billed at the discounted rate, wasted spend is absorbed by
  Algorithm 3 (scalar and vectorized redistribution agree), and the new
  obs kinds appear in the trace at schema v2.
"""
import pytest

from repro import ckpt
from repro.chaos import ChaosConfig, chaos_draws
from repro.core import budget as budget_mod
from repro.core.engine import SimEngine
from repro.core.jax_engine import BatchSimEngine, StreamInterrupted
from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig
from repro.obs.events import EVENT_SCHEMA_VERSION, EventLog
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()

CHAOS = ChaosConfig(spot_discount=0.6, revocation_rate=8.0, fail_prob=0.05,
                    max_retries=3, escalate_after=2, straggler_prob=0.1,
                    straggler_slowdown=4.0, straggler_factor=2.0, seed=0)


def workload(seed, n=8, rate=20.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",), budget_lo=0.5, budget_hi=1.0)
    return generate_workload(CFG, spec)


def signature(res):
    return ([(w.wid, w.finish_ms, w.cost) for w in res.workflows],
            res.vm_count_by_type, res.vm_seconds_by_type,
            (res.revocations, res.task_failures, res.task_retries,
             res.stragglers_detected, res.wasted_cost, res.spot_vms))


def run_one(policy=EBPSM, seed=0, chaos=CHAOS, **kw):
    eng = SimEngine(CFG, policy, workload(seed), seed=seed, chaos=chaos, **kw)
    return eng, eng.run()


# ---------------------------------------------------------------------------
# Config + draws
# ---------------------------------------------------------------------------


def test_chaos_config_validates_knobs():
    with pytest.raises(ValueError):
        ChaosConfig(spot_discount=1.5)
    with pytest.raises(ValueError):
        ChaosConfig(revocation_rate=-1.0)
    with pytest.raises(ValueError):
        ChaosConfig(fail_prob=2.0)
    with pytest.raises(ValueError):
        ChaosConfig(fail_prob=0.1, max_retries=-1)
    with pytest.raises(ValueError):
        ChaosConfig(straggler_prob=0.1, straggler_slowdown=0.5)
    assert not ChaosConfig().enabled          # all-zero = disabled
    assert CHAOS.enabled and CHAOS.spot_enabled


def test_chaos_draws_deterministic_and_none_when_disabled():
    assert chaos_draws(None, 100, 0) is None
    a = chaos_draws(CHAOS, 100, 3)
    b = chaos_draws(CHAOS, 100, 3)
    assert (a.fail_u == b.fail_u).all()
    assert (a.straggler == b.straggler).all()
    assert a.vm_lifetime_ms(7) == b.vm_lifetime_ms(7)
    # A failed attempt past the table width never fails again: the
    # retry bound is structural, not probabilistic.
    assert not a.fails(0, CHAOS.max_retries)
    # Different sim seed, different draws.
    c = chaos_draws(CHAOS, 100, 4)
    assert (a.fail_u != c.fail_u).any()


# ---------------------------------------------------------------------------
# Zero-cost disabled + determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("off", [None, ChaosConfig()],
                         ids=["none", "all-zero"])
def test_chaos_disabled_is_bit_exact_benign(off):
    _, base = run_one(chaos=None)
    eng = SimEngine(CFG, EBPSM, workload(0), seed=0)   # no chaos arg at all
    assert signature(eng.run()) == signature(base)
    _, res = run_one(chaos=off)
    assert signature(res) == signature(base)
    assert res.revocations == 0 and res.spot_vms == 0


@pytest.mark.parametrize("policy", [EBPSM, MSLBL_MW], ids=lambda p: p.name)
def test_chaos_deterministic_across_repeat_runs(policy):
    _, a = run_one(policy)
    _, b = run_one(policy)
    assert signature(a) == signature(b)
    # And the injection actually fired.
    assert a.revocations > 0
    assert a.task_retries > 0
    assert a.stragglers_detected > 0
    assert a.wasted_cost > 0
    assert a.spot_vms > 0


def test_chaos_seed_changes_injection():
    _, a = run_one()
    _, b = run_one(chaos=ChaosConfig(**{**CHAOS.knobs(), "seed": 1}))
    assert signature(a) != signature(b)


# ---------------------------------------------------------------------------
# Engine / layout parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", [EBPSM, MSLBL_MW], ids=lambda p: p.name)
def test_chaos_engine_parity_sim_vs_batch(policy):
    _, ref = run_one(policy)
    beng = BatchSimEngine(CFG, [(policy, workload(0), 0)], chaos=CHAOS)
    assert signature(beng.run()[0]) == signature(ref)


def test_chaos_object_vs_soa_parity():
    _, soa = run_one(soa=True)
    _, obj = run_one(soa=False)
    assert signature(soa) == signature(obj)


def test_chaos_scalar_vs_vector_redistribution(monkeypatch):
    """Wasted-spend absorption takes the same Algorithm-3 result whether
    the pooled vectorized update or the scalar reference runs it."""
    _, vec = run_one()
    monkeypatch.setattr(budget_mod, "_ARRAY_REDIST", False)
    _, sca = run_one()
    assert signature(sca) == signature(vec)


# ---------------------------------------------------------------------------
# Interrupt / resume through revocation rounds
# ---------------------------------------------------------------------------


def _chaos_members():
    return [(EBPSM, workload(0), 0), (MSLBL_MW, workload(1), 1)]


@pytest.mark.parametrize("cut_round", [1, 4])
def test_chaos_interrupt_resume_bit_exact(cut_round, tmp_path):
    ref = BatchSimEngine(CFG, _chaos_members(), chaos=CHAOS)
    want = [signature(r) for r in ref.run()]
    assert ref.states[0].revocations > 0     # the cut spans real churn

    eng = BatchSimEngine(CFG, _chaos_members(), chaos=CHAOS)
    cut = {}

    def hook(e):
        if e.rounds >= cut_round:
            cut["snap"] = e.snapshot()
            return True
        return False

    with pytest.raises(StreamInterrupted):
        eng.run(ckpt_hook=hook)
    # Round-trip the snapshot through the on-disk stream format too.
    ckpt.save_stream(str(tmp_path), 0, cut["snap"])
    back, _, _ = ckpt.restore_stream(str(tmp_path))
    eng2 = BatchSimEngine(CFG, _chaos_members(), chaos=CHAOS)
    eng2.load_snapshot(back)
    assert [signature(r) for r in eng2.run()] == want


# ---------------------------------------------------------------------------
# Semantics: retries, spot billing, events
# ---------------------------------------------------------------------------


def test_retries_bounded_and_all_tasks_finish():
    heavy = ChaosConfig(fail_prob=0.3, max_retries=2, seed=0)
    eng, res = run_one(chaos=heavy)
    assert res.task_failures > 0
    for (wid, tid), attempts in eng.task_attempts.items():
        assert attempts <= heavy.max_retries + 1
    # Every workflow still completed (failures only delay, never strand).
    for w in res.workflows:
        assert w.finish_ms >= w.arrival_ms


def test_spot_discount_reduces_cost_without_revocation():
    """Pure spot (no churn) bills busy-periods at the discounted rate —
    strictly cheaper in aggregate.  (Schedules may legitimately diverge
    from benign: EBPSM's budget updates see the cheaper actual spend and
    can afford faster VM types downstream.)"""
    _, base = run_one(chaos=None)
    spot = ChaosConfig(spot_discount=0.5, seed=0)
    _, res = run_one(chaos=spot)
    assert res.spot_vms > 0 and res.revocations == 0
    assert sum(w.cost for w in res.workflows) < \
        sum(w.cost for w in base.workflows)


def test_chaos_event_kinds_in_trace():
    elog = EventLog()
    eng = SimEngine(CFG, EBPSM, workload(0), seed=0, chaos=CHAOS,
                    events=elog)
    eng.run()
    assert EVENT_SCHEMA_VERSION == 2
    kinds = set(elog.kind[:elog.total].tolist())
    from repro.obs.events import (STRAGGLER_DETECT, TASK_FAIL, TASK_RETRY,
                                  VM_REVOKE)
    assert {VM_REVOKE, TASK_FAIL, TASK_RETRY, STRAGGLER_DETECT} <= kinds

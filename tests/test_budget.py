"""Property tests for Algorithms 1 & 3 (budget distribution / update)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import budget as bmod
from repro.core import costs
from repro.core.mslbl import distribute_budget_mslbl
from repro.core.types import PlatformConfig, Task, Workflow
from repro.workflows.dax import generate_workflow

CFG = PlatformConfig()


def random_wf(seed: int, n: int = 30, app: str = "montage") -> Workflow:
    rng = np.random.default_rng(seed)
    return generate_workflow(app, 0, n, rng)


@st.composite
def wf_and_budget(draw):
    seed = draw(st.integers(0, 10_000))
    app = draw(st.sampled_from(["montage", "sipht", "epigenome",
                                "ligo", "cybershake"]))
    n = draw(st.integers(10, 80))
    wf = random_wf(seed, n, app)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    u = draw(st.floats(0.0, 1.0))
    return wf, lo + u * (hi - lo)


@given(wf_and_budget())
@settings(max_examples=40, deadline=None)
def test_distribution_conserves_budget(wb):
    wf, beta = wb
    leftover = bmod.distribute_budget(CFG, wf, beta)
    total = sum(t.budget for t in wf.tasks) + leftover
    assert total <= beta + 1e-6
    assert all(t.budget >= 0 for t in wf.tasks)
    assert leftover >= 0


@given(wf_and_budget())
@settings(max_examples=40, deadline=None)
def test_distribution_exhausts_or_caps(wb):
    """If budget is left over, no single next-tier upgrade is affordable
    (the SFTD sweep stopped for a reason)."""
    wf, beta = wb
    leftover = bmod.distribute_budget(CFG, wf, beta)
    if leftover > 1e-6:
        by_speed = sorted(CFG.vm_types, key=lambda v: v.mips)
        for t in wf.tasks:
            mb = bmod.input_mb(wf, t)
            tiers = [costs.estimate_full_cost(CFG, v, t, mb)
                     for v in by_speed]
            next_up = [c for c in tiers if c > t.budget + 1e-9]
            if next_up:
                delta = min(next_up) - t.budget
                assert delta > leftover - 1e-6, (t.tid, delta, leftover)


@given(wf_and_budget())
@settings(max_examples=30, deadline=None)
def test_levels_and_ranks(wb):
    wf, beta = wb
    bmod.distribute_budget(CFG, wf, beta)
    for t in wf.tasks:
        for p in t.parents:
            assert wf.tasks[p].level < t.level
            assert wf.tasks[p].rank < t.rank  # level-major order


@given(wf_and_budget(), st.floats(0.0, 2.0), st.integers(0, 29))
@settings(max_examples=40, deadline=None)
def test_update_budget_no_money_creation(wb, cost_factor, fin_idx):
    wf, beta = wb
    spare0 = bmod.distribute_budget(CFG, wf, beta)
    fin = fin_idx % wf.n_tasks
    unscheduled = [t.tid for t in wf.tasks if t.tid != fin]
    pool_before = sum(wf.tasks[t].budget for t in unscheduled) \
        + wf.tasks[fin].budget + spare0
    actual = cost_factor * max(wf.tasks[fin].budget, 1.0)
    spare1 = bmod.update_budget(CFG, wf, fin, actual, spare0, unscheduled)
    pool_after = sum(wf.tasks[t].budget for t in unscheduled) + spare1
    # conservation: money after ≤ money before − min(actual, headroom)…
    assert pool_after <= pool_before - min(actual, pool_before) + 1e-6 \
        or pool_after <= pool_before + 1e-6
    assert spare1 >= 0


@given(wf_and_budget())
@settings(max_examples=30, deadline=None)
def test_mslbl_interpolates(wb):
    wf, beta = wb
    distribute_budget_mslbl(CFG, wf, beta)
    cheap = min(CFG.vm_types, key=lambda v: v.mips)
    fast = max(CFG.vm_types, key=lambda v: v.mips)
    for t in wf.tasks:
        mb = bmod.input_mb(wf, t)
        cmin = costs.estimate_full_cost(CFG, cheap, t, mb)
        cmax = costs.estimate_full_cost(CFG, fast, t, mb)
        assert cmin - 1e-6 <= t.budget <= cmax + 1e-6


def test_min_max_cost_order():
    wf = random_wf(7, 40)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    assert 0 < lo < hi

"""core.cost_tables: bit-exact agreement with the scalar cost model,
memoization/sharing semantics, and distribution equivalence."""
import numpy as np
import pytest

from repro.core import budget as bmod
from repro.core import cost_tables, costs
from repro.core.types import PlatformConfig, clone_workload
from repro.workflows.dax import APP_NAMES, generate_workflow

CFG = PlatformConfig()


def wf_of(app, seed=0, n=30):
    return generate_workflow(app, 0, n, np.random.default_rng(seed))


@pytest.mark.parametrize("app", APP_NAMES)
def test_table_matches_scalar_cost_model_bit_exact(app):
    """Every table entry equals the scalar reference — not approximately:
    the tolerance-ceil discretization must land on the same integer ms and
    the billing on the same cent, or the two engines' budget algebra
    diverges."""
    wf = wf_of(app, seed=7)
    table = cost_tables.build_table(CFG, wf)
    for t in wf.tasks:
        mb = bmod.input_mb(wf, t)
        assert table.in_mb[t.tid] == mb
        for v, vmt in enumerate(CFG.vm_types):
            assert table.proc_ms[t.tid, v] == costs.processing_ms(
                CFG, vmt, t, mb)
            assert table.rt_out_ms[t.tid, v] == (
                costs.runtime_ms(vmt, t.size_mi)
                + costs.transfer_out_ms(CFG, vmt, t.out_mb))
            assert table.est_full_cost[t.tid, v] == costs.estimate_full_cost(
                CFG, vmt, t, mb)
            assert table.cost_bare[t.tid, v] == costs.task_cost(
                CFG, vmt, t, mb, include_vm_provision=False, container_ms=0)


def test_table_memoized_and_shared_by_clones():
    wf = wf_of("montage", seed=3)
    t1 = cost_tables.table_for(CFG, wf)
    assert cost_tables.table_for(CFG, wf) is t1
    clone = wf.clone()
    assert cost_tables.table_for(CFG, clone) is t1
    grid = clone_workload([wf])
    assert cost_tables.table_for(CFG, grid[0]) is t1


def test_table_invalidated_by_config_change():
    wf = wf_of("sipht", seed=4)
    t1 = cost_tables.table_for(CFG, wf)
    cfg2 = CFG.with_(gs_read_mbps=25.0)
    t2 = cost_tables.table_for(cfg2, wf)
    assert t2 is not t1
    assert (t2.proc_ms != t1.proc_ms).any()
    # Same-value config (fresh object) hits the cache by equality.
    assert cost_tables.table_for(PlatformConfig(), wf) is t2 or \
        cost_tables.table_for(PlatformConfig(), wf).cfg == PlatformConfig()


def _distribute_budget_scalar(cfg, wf, budget, task_ids=None):
    """The pre-table reference implementation of Algorithm 1 (verbatim
    semantics: sequential pass-1 allocation + one-tier SFTD sweeps)."""
    if task_ids is None:
        order = bmod.execution_order(cfg, wf)
    else:
        order = sorted(task_ids, key=lambda tid: wf.tasks[tid].rank)
    if not order:
        return budget
    cheapest = cfg.vm_types[0]
    alloc = {}
    remaining = budget
    for tid in order:
        t = wf.tasks[tid]
        want = costs.estimate_full_cost(cfg, cheapest, t, bmod.input_mb(wf, t))
        give = min(want, max(remaining, 0.0))
        alloc[tid] = give
        remaining -= give
    if remaining > 0:
        by_speed = sorted(range(len(cfg.vm_types)),
                          key=lambda i: cfg.vm_types[i].mips)
        tier_cost = {}
        tier_of = {}
        for tid in order:
            t = wf.tasks[tid]
            mb = bmod.input_mb(wf, t)
            tier_cost[tid] = [
                costs.estimate_full_cost(cfg, cfg.vm_types[i], t, mb)
                for i in by_speed
            ]
            tier_of[tid] = 0
            for k in range(len(by_speed) - 1, -1, -1):
                if alloc[tid] >= tier_cost[tid][k] - 1e-9:
                    tier_of[tid] = k
                    break
        changed = True
        while remaining > 1e-9 and changed:
            changed = False
            for tid in order:
                k = tier_of[tid]
                if k + 1 >= len(by_speed):
                    continue
                delta = tier_cost[tid][k + 1] - alloc[tid]
                if 0 < delta <= remaining + 1e-9:
                    alloc[tid] = tier_cost[tid][k + 1]
                    tier_of[tid] = k + 1
                    remaining -= delta
                    changed = True
                elif delta <= 0:
                    tier_of[tid] = k + 1
                    changed = True
    return alloc, max(remaining, 0.0)


@pytest.mark.parametrize("app", ["montage", "cybershake", "epigenome"])
@pytest.mark.parametrize("frac", [0.1, 0.5, 0.9])
def test_distribute_budget_equals_scalar_reference(app, frac):
    wf = wf_of(app, seed=11)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    beta = lo + frac * (hi - lo)
    ref_alloc, ref_left = _distribute_budget_scalar(CFG, wf.clone(), beta)
    left = bmod.distribute_budget(CFG, wf, beta)
    for tid, want in ref_alloc.items():
        assert wf.tasks[tid].budget == pytest.approx(want, abs=1e-6)
    assert left == pytest.approx(ref_left, abs=1e-6)


def test_min_max_matches_scalar():
    wf = wf_of("ligo", seed=5)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    cheapest = CFG.vm_types[0]
    fastest = max(CFG.vm_types, key=lambda v: v.mips)
    ref_lo = sum(
        costs.task_cost(CFG, cheapest, t, bmod.input_mb(wf, t),
                        include_vm_provision=False, container_ms=0)
        for t in wf.tasks
    ) + costs.billed_cost(
        CFG, cheapest,
        CFG.vm_provision_delay_ms + CFG.container_provision_ms)
    ref_hi = sum(
        costs.estimate_full_cost(CFG, fastest, t, bmod.input_mb(wf, t))
        for t in wf.tasks
    )
    assert lo == pytest.approx(ref_lo, rel=1e-12)
    assert hi == pytest.approx(ref_hi, rel=1e-12)

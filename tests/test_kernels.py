"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.affinity.ops import affinity
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_decode_ref, ssd_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("B,L,H,D,causal,dtype", [
    (2, 256, 4, 64, True, jnp.float32),
    (1, 128, 2, 128, False, jnp.float32),
    (2, 200, 3, 64, True, jnp.float32),       # non-multiple of block
    (1, 96, 1, 32, True, jnp.float32),
    (2, 256, 2, 64, True, jnp.bfloat16),
])
def test_flash_attention_sweep(B, L, H, D, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, L, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, L, H, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, L, H, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,L,H,P,N,Q", [
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 128, 64),
    (2, 64, 4, 16, 32, 16),
    (1, 128, 1, 64, 64, 128),
])
def test_ssd_kernel_sweep(B, L, H, P, N, Q):
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, L, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, L, N)), jnp.float32)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm, chunk=Q)
    y_pal, s_pal = ssd(x, dt, A, Bm, Cm, chunk=Q, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               atol=1e-4)


def test_ssd_chunked_equals_sequential_recurrence():
    B, L, H, P, N = 1, 64, 2, 16, 8
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, L, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, L, N)), jnp.float32)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm, chunk=16)
    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(L):
        y, state = ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t],
                                  state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               atol=1e-4)


@pytest.mark.parametrize("T,V", [(16, 32), (37, 100), (64, 7), (1, 1)])
def test_affinity_kernel_matches_ref(T, V):
    rng = np.random.default_rng(T * 1000 + V)
    args = (
        jnp.asarray(rng.uniform(10, 900, T), jnp.float32),
        jnp.asarray(rng.uniform(1, 150, T), jnp.float32),
        jnp.asarray(rng.uniform(5, 500, T), jnp.float32),
        jnp.asarray(rng.uniform(0, 200, (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0., 400., 10000.], (T, V)), jnp.float32),
        jnp.asarray(rng.choice([0, 1, 2, 3], (T, V)), jnp.int32),
        jnp.asarray(rng.choice([2., 4., 8., 16.], V), jnp.float32),
        jnp.full((V,), 20.0, jnp.float32),
        jnp.asarray(rng.choice([1., 2., 4., 8.], V), jnp.float32),
    )
    r = affinity(*args, gs_read=50., gs_write=30., bp_ms=1000.,
                 use_pallas=False)
    p = affinity(*args, gs_read=50., gs_write=30., bp_ms=1000.,
                 use_pallas=True)
    for name, a, b in zip(r._fields, r, p):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_affinity_tier_priority():
    """A slower tier-1 VM must beat a faster tier-3 VM (Alg. 2 ordering)."""
    T, V = 1, 2
    size = jnp.asarray([100.0]); out_mb = jnp.asarray([10.0])
    budget = jnp.asarray([1e6])
    missing = jnp.asarray([[0.0, 0.0]])
    cont = jnp.asarray([[0.0, 0.0]])
    tier = jnp.asarray([[1, 3]], jnp.int32)
    mips = jnp.asarray([2.0, 16.0])       # tier-3 VM is 8× faster
    bw = jnp.full((V,), 20.0); price = mips / 2
    r = affinity(size, out_mb, budget, missing, cont, tier, mips, bw, price,
                 gs_read=50., gs_write=30., bp_ms=1000.)
    assert int(r.best_vm[0]) == 0
    assert int(r.best_tier[0]) == 1

"""Infrastructure physics: FIFO caches, billing, transfer model."""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import costs
from repro.core.types import PlatformConfig, Task
from repro.sim.cloud import VM, VMPool

CFG = PlatformConfig()


def mk_vm(vmt_idx=0):
    return VM(vmid=0, vmt_idx=vmt_idx, vmt=CFG.vm_types[vmt_idx])


def test_fifo_eviction_by_capacity():
    vm = mk_vm(0)  # small: 20 GB
    cap = CFG.vm_types[0].storage_mb
    vm.cache_put(CFG, ("out", 0, 0), cap * 0.6)
    vm.cache_put(CFG, ("out", 0, 1), cap * 0.6)   # evicts the first
    assert not vm.has_data(("out", 0, 0))
    assert vm.has_data(("out", 0, 1))
    assert vm.cached_mb <= cap


def test_fifo_order_not_lru():
    vm = mk_vm(0)
    cap = CFG.vm_types[0].storage_mb
    vm.cache_put(CFG, ("out", 0, 0), cap * 0.4)
    vm.cache_put(CFG, ("out", 0, 1), cap * 0.4)
    # touch item 0 again — FIFO ignores recency
    vm.cache_put(CFG, ("out", 0, 0), cap * 0.4)
    vm.cache_put(CFG, ("out", 0, 2), cap * 0.4)   # evicts item 0 (oldest)
    assert not vm.has_data(("out", 0, 0))
    assert vm.has_data(("out", 0, 1))
    assert vm.has_data(("out", 0, 2))


def test_container_cache_and_delays():
    vm = mk_vm()
    assert vm.container_ms(CFG, "llama", True) == CFG.container_provision_ms
    vm.activate_container(CFG, "llama", True)
    assert vm.container_ms(CFG, "llama", True) == 0
    assert vm.container_ms(CFG, "qwen", True) == CFG.container_provision_ms
    vm.activate_container(CFG, "qwen", True)
    # llama image still cached → only init delay to re-activate
    assert vm.container_ms(CFG, "llama", True) == CFG.container_init_ms
    assert vm.container_ms(CFG, "llama", False) == 0


@given(st.floats(1, 1e6), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_billing_ceil(size_mi, vmt_idx):
    vmt = CFG.vm_types[vmt_idx]
    ms = costs.runtime_ms(vmt, size_mi)
    c = costs.billed_cost(CFG, vmt, ms)
    periods = math.ceil(ms / CFG.billing_period_ms)
    assert c == pytest.approx(periods * vmt.cost_per_bp)


@given(st.floats(0.1, 1e4))
@settings(max_examples=30, deadline=None)
def test_linear_pricing_cost_speed_invariance(size_mi):
    """Table 2 economics: pure-compute cost is identical across VM types
    (price ∝ speed), up to billing-period rounding."""
    vals = []
    for vmt in CFG.vm_types:
        ms = costs.runtime_ms(vmt, size_mi)
        vals.append(costs.billed_cost(CFG, vmt, ms))
    assert max(vals) - min(vals) <= max(v.cost_per_bp
                                        for v in CFG.vm_types) + 1e-9


def test_transfer_eqs_monotone():
    t1 = costs.transfer_in_ms(CFG, CFG.vm_types[0], 10)
    t2 = costs.transfer_in_ms(CFG, CFG.vm_types[0], 20)
    assert t2 >= t1 > 0
    assert costs.transfer_in_ms(CFG, CFG.vm_types[0], 0) == 0
    d = costs.transfer_in_ms(CFG, CFG.vm_types[0], 10, bw_deg=0.15)
    assert d >= t1


def test_pool_accounting():
    pool = VMPool(CFG)
    vm = pool.provision(2, now_ms=0)
    vm.status = 2  # idle
    vm.busy_ms = 5_000
    pool.terminate(vm, now_ms=20_000)
    assert pool.vm_seconds_by_type["large"] == pytest.approx(20.0)
    assert pool.vm_busy_seconds_by_type["large"] == pytest.approx(5.0)
    with pytest.raises(AssertionError):
        pool.terminate(vm, 30_000)  # already terminated

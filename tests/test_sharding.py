"""Sharding rules, divisibility guards, and multi-device equivalence
(the latter in a subprocess with forced host device count)."""
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import build, RunConfig
from repro.models.common import (LONG_RULES, SERVE_RULES, TRAIN_RULES,
                                 logical_to_pspec, param_pspecs)


def test_logical_to_pspec_basic():
    names = ("data", "model")
    ps = logical_to_pspec(("embed", "ffn"), TRAIN_RULES, names)
    assert ps == P("data", "model")
    ps = logical_to_pspec(("vocab", "embed"), TRAIN_RULES, names)
    assert ps == P("model", "data")
    # unknown logical axis → replicated
    assert logical_to_pspec(("nope",), TRAIN_RULES, names) == P(None)


def test_divisibility_guard():
    names = ("data", "model")
    sizes = {"data": 16, "model": 16}
    # 8 kv heads don't divide model=16 → replicated
    ps = logical_to_pspec(("embed", "kv_heads", None), TRAIN_RULES, names,
                          shape=(4096, 8, 128), axis_sizes=sizes)
    assert ps == P("data", None, None)
    ps = logical_to_pspec(("embed", "kv_heads", None), TRAIN_RULES, names,
                          shape=(4096, 16, 128), axis_sizes=sizes)
    assert ps == P("data", "model", None)


def test_no_repeated_mesh_axes():
    names = ("data", "model")
    ps = logical_to_pspec(("vocab", "heads"), TRAIN_RULES, names)
    # both map to 'model' — second occurrence dropped
    assert ps == P("model", None)


def test_param_pspecs_cover_all_leaves():
    m = build("qwen3-32b")
    specs = m.specs()
    pspecs = param_pspecs(specs, TRAIN_RULES, ("data", "model"),
                          {"data": 16, "model": 16})
    n_leaves = len(jax.tree.leaves(specs,
                                   is_leaf=lambda x: hasattr(x, "axes")))
    n_ps = len(jax.tree.leaves(pspecs,
                               is_leaf=lambda x: isinstance(x, P)))
    assert n_leaves == n_ps > 10


def test_serve_rules_replicate_fsdp_axis():
    assert SERVE_RULES["embed"] is None
    assert TRAIN_RULES["embed"] == "data"
    assert LONG_RULES["seq"] == "data"
    assert LONG_RULES["batch"] is None


MULTIDEV_SCRIPT = r"""
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np
from repro.models import build, RunConfig
from repro.models.common import TRAIN_RULES
from repro.train.optim import init_opt_state
from repro.train.train_step import build_train_step, make_train_step

run = RunConfig(remat="none", learning_rate=1e-3)
m = build("qwen2-moe-a2.7b", run, smoke=True)
params = m.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 64, (4, 32)), jnp.int32)}
batch["labels"] = batch["tokens"]

# single-device reference
p1, o1, met1 = jax.jit(make_train_step(m))(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
fn, *_ = build_train_step(m, mesh, donate=False)
p2, o2, met2 = fn(params, opt, batch)
d = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                       - jnp.asarray(b, jnp.float32)))),
    p1, p2)))
print("MAXDIFF", d, "LOSS", float(met1["loss"]), float(met2["loss"]))
assert d < 5e-2, d
assert abs(float(met1["loss"]) - float(met2["loss"])) < 5e-2
print("MULTIDEV-OK")
"""


def test_sharded_train_step_matches_single_device():
    """8 fake host devices: sharded MoE train step ≈ single-device step."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           # Force the CPU backend: without it, a TPU-enabled jaxlib probes
           # the GCE metadata server (30 retries per variable ⇒ minutes of
           # hang) before falling back.  Fake host devices are CPU anyway.
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, cwd=".", timeout=420)
    assert "MULTIDEV-OK" in r.stdout, r.stdout + r.stderr

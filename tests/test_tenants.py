"""repro.tenants: trace importers, arrival processes, tenant/QoS mixes."""
import dataclasses
import os

import numpy as np
import pytest

from repro.core.engine import SimEngine
from repro.core.jax_engine import BatchSimEngine
from repro.core.scheduler import EBPSM
from repro.core.types import PlatformConfig, Task, Workflow
from repro.tenants import (BRONZE, GOLD, SILVER, Diurnal, MarkovModulated,
                           Poisson, Tenant, TenantMix, TraceReplay,
                           assign_budgets_uniform, bundled_trace,
                           bundled_trace_names, ideal_makespan_ms,
                           infer_family, load_dax, load_trace,
                           load_wfcommons)
from repro.tenants.traces import DATA_DIR
from repro.workflows.dax import TRACE_CALIBRATION

CFG = PlatformConfig()


# ---------------------------------------------------------------------------
# Workflow.validate: malformed inputs must raise clear ValueErrors
# ---------------------------------------------------------------------------


def _chain(n=3):
    tasks = [Task(tid=i, size_mi=10.0, out_mb=1.0) for i in range(n)]
    for i in range(n - 1):
        tasks[i].children.append(i + 1)
        tasks[i + 1].parents.append(i)
    return Workflow(wid=0, app="t", tasks=tasks)


def test_validate_accepts_wellformed():
    _chain().validate()


def test_validate_rejects_empty():
    with pytest.raises(ValueError, match="empty"):
        Workflow(wid=0, app="t", tasks=[]).validate()


def test_validate_rejects_cycle():
    wf = _chain(3)
    wf.tasks[2].children.append(0)
    wf.tasks[0].parents.append(2)
    with pytest.raises(ValueError, match="cycle"):
        wf.validate()


def test_validate_rejects_out_of_range_parent():
    wf = _chain(2)
    wf.tasks[0].parents.append(7)
    with pytest.raises(ValueError, match="outside"):
        wf.validate()


def test_validate_rejects_dangling_edges():
    wf = _chain(3)
    wf.tasks[2].parents.append(0)      # 0 never lists 2 as a child
    with pytest.raises(ValueError, match="dangling"):
        wf.validate()
    wf2 = _chain(3)
    wf2.tasks[0].children.append(2)    # 2 never lists 0 as a parent
    with pytest.raises(ValueError, match="dangling"):
        wf2.validate()


def test_validate_rejects_tid_mismatch():
    wf = _chain(2)
    wf.tasks[1].tid = 5
    with pytest.raises(ValueError, match="tid"):
        wf.validate()


# ---------------------------------------------------------------------------
# Trace importers
# ---------------------------------------------------------------------------


def test_bundled_traces_round_trip_deterministically():
    """Same bytes in → identical Workflow, for every bundled trace."""
    names = bundled_trace_names()
    assert len(names) >= 3
    for name in names:
        a, b = bundled_trace(name), bundled_trace(name)
        assert a == b
        assert a is not b
        a.validate()


def test_dax_import_structure_and_calibration():
    wf = bundled_trace("montage-18")
    assert wf.app == "montage"
    assert wf.n_tasks == 18
    # runtime seconds × montage reference MIPS.
    cal = TRACE_CALIBRATION["montage"]
    assert wf.tasks[0].size_mi == pytest.approx(12.40 * cal.mips)
    # mProjectPP stages its sky tile + shared header from global storage.
    assert wf.tasks[0].ext_in_mb == pytest.approx(31.3)
    # Interior tasks read parent outputs, not external staging.
    assert wf.tasks[4].ext_in_mb == 0.0
    assert wf.tasks[4].parents == [0, 1]
    # mAdd's mosaic output.
    assert wf.tasks[15].out_mb == pytest.approx(122.0)
    assert wf.exit_tasks() == [17]


def test_wfcommons_import_both_spellings():
    epi = bundled_trace("epigenomics-20")     # schema 1.4 "tasks"+parents
    assert epi.app == "epigenome"
    assert epi.n_tasks == 20
    assert len(epi.entry_tasks()) == 1
    seis = bundled_trace("seismology-9")      # legacy "jobs"+children
    assert seis.app == "seismology"
    assert seis.n_tasks == 9
    assert len(seis.entry_tasks()) == 8
    assert seis.tasks[8].parents == list(range(8))


def test_importer_rejects_cycle():
    doc = """{"name": "bad", "workflow": {"tasks": [
        {"name": "a", "runtime": 1, "parents": ["b"]},
        {"name": "b", "runtime": 1, "parents": ["a"]}]}}"""
    with pytest.raises(ValueError, match="cycle"):
        load_wfcommons(doc)


def test_importer_rejects_dangling_parent():
    doc = """{"name": "bad", "workflow": {"tasks": [
        {"name": "a", "runtime": 1, "parents": ["ghost"]}]}}"""
    with pytest.raises(ValueError, match="unknown"):
        load_wfcommons(doc)


def test_importer_rejects_empty_and_malformed():
    with pytest.raises(ValueError, match="no tasks"):
        load_wfcommons('{"name": "x", "workflow": {"tasks": []}}')
    with pytest.raises(ValueError, match="malformed"):
        load_wfcommons('{nope')
    with pytest.raises(ValueError, match="malformed"):
        load_dax("<adag><job </adag>")
    with pytest.raises(ValueError, match="adag"):
        load_dax("<notadax/>")
    with pytest.raises(ValueError, match="duplicate"):
        load_dax('<adag><job id="J1" runtime="1"/>'
                 '<job id="J1" runtime="1"/></adag>')
    with pytest.raises(ValueError, match="names no job"):
        load_dax('<adag><job id="J1" runtime="1"/>'
                 '<child ref="J9"><parent ref="J1"/></child></adag>')


def test_importer_rejects_hostile_fields():
    """NaN / negative / non-numeric runtimes and sizes, self-edges —
    descriptive ValueErrors, never a silent clip or a mid-sim crash."""
    with pytest.raises(ValueError, match="non-finite"):
        load_wfcommons('{"workflow": {"tasks": ['
                       '{"name": "a", "runtime": NaN}]}}')
    with pytest.raises(ValueError, match="negative"):
        load_wfcommons('{"workflow": {"tasks": ['
                       '{"name": "a", "runtime": -3.0}]}}')
    with pytest.raises(ValueError, match="non-numeric"):
        load_wfcommons('{"workflow": {"tasks": ['
                       '{"name": "a", "runtime": "soon"}]}}')
    with pytest.raises(ValueError, match="negative"):
        load_wfcommons('{"workflow": {"tasks": [{"name": "a", "runtime": 1,'
                       ' "files": [{"name": "f", "sizeInBytes": -5}]}]}}')
    with pytest.raises(ValueError, match="self-edge"):
        load_wfcommons('{"workflow": {"tasks": ['
                       '{"name": "a", "runtime": 1, "parents": ["a"]}]}}')
    with pytest.raises(ValueError, match="self-edge"):
        load_wfcommons('{"workflow": {"jobs": ['
                       '{"name": "a", "runtime": 1, "children": ["a"]}]}}')
    with pytest.raises(ValueError, match="duplicate"):
        load_wfcommons('{"workflow": {"tasks": [{"name": "a", "runtime": 1},'
                       ' {"name": "a", "runtime": 2}]}}')
    with pytest.raises(ValueError, match="not a list"):
        load_wfcommons('{"workflow": {"tasks": ['
                       '{"name": "a", "runtime": 1, "files": 7}]}}')
    with pytest.raises(ValueError, match="non-numeric"):
        load_dax('<adag><job id="J1" runtime="soon"/></adag>')
    with pytest.raises(ValueError, match="negative"):
        load_dax('<adag><job id="J1" runtime="1">'
                 '<uses file="f" link="output" size="-9"/></job></adag>')
    with pytest.raises(ValueError, match="self-edge"):
        load_dax('<adag><job id="J1" runtime="1"/>'
                 '<child ref="J1"><parent ref="J1"/></child></adag>')


def _mutate(data: bytes, rng: np.random.default_rng) -> bytes:
    """One seeded mutation: truncate, delete a span, duplicate a span,
    or flip bytes — the classic fuzz moves over trace bytes."""
    n = len(data)
    op = rng.integers(0, 4)
    if op == 0:                                    # truncate
        return data[:rng.integers(0, n)]
    i = int(rng.integers(0, n))
    j = min(n, i + int(rng.integers(1, 64)))
    if op == 1:                                    # delete span
        return data[:i] + data[j:]
    if op == 2:                                    # duplicate span
        return data[:j] + data[i:j] + data[j:]
    flipped = bytearray(data)                      # flip bytes
    for k in range(i, j):
        flipped[k] ^= int(rng.integers(1, 256))
    return bytes(flipped)


@pytest.mark.parametrize("name", ["montage-18", "epigenomics-20",
                                  "seismology-9", "cybershake-12"])
def test_fuzzed_traces_fail_closed(name):
    """Seeded mutation fuzz over every bundled trace: each mutant either
    parses into a *valid* Workflow or raises ValueError — no other
    exception type, no invalid DAG, ever escapes the importer."""
    for ext in (".dax", ".json"):
        path = os.path.join(DATA_DIR, name + ext)
        if os.path.exists(path):
            break
    with open(path, "rb") as f:
        pristine = f.read()
    loader = load_wfcommons if ext == ".json" else load_dax
    rng = np.random.default_rng(0xF022 + len(name))
    for trial in range(60):
        mutant = _mutate(pristine, rng)
        try:
            wf = loader(mutant, name=f"{name}#{trial}")
        except ValueError:
            continue
        wf.validate()                    # parsed → must be a legal DAG
        for t in wf.tasks:
            assert t.size_mi >= 0 and t.out_mb >= 0 and t.ext_in_mb >= 0


def test_load_trace_dispatches_on_extension():
    wf = load_trace(os.path.join(DATA_DIR, "montage-18.dax"))
    assert wf.n_tasks == 18
    with pytest.raises(ValueError, match="extension"):
        load_trace("/tmp/foo.csv")
    with pytest.raises(ValueError, match="no bundled trace"):
        bundled_trace("no-such-trace")


def test_infer_family():
    assert infer_family("Montage") == "montage"
    assert infer_family("1000genome-chr21") == "epigenome"
    assert infer_family("unknown-app") is None


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proc", [
    Poisson(6.0),
    MarkovModulated(1.0, 12.0, mean_dwell_s=30.0),
    Diurnal(2.0, 10.0, period_s=300.0),
    TraceReplay(times_ms=(0, 500, 2_000, 9_000)),
], ids=lambda p: type(p).__name__)
def test_arrivals_deterministic_sorted_nonnegative(proc):
    a = proc.arrival_times_ms(40, np.random.default_rng(7))
    b = proc.arrival_times_ms(40, np.random.default_rng(7))
    assert a == b
    assert a == sorted(a)
    assert a[0] == 0
    assert len(a) == 40
    assert proc.mean_rate_per_min() > 0


def test_poisson_rate_roughly_matches():
    times = Poisson(6.0).arrival_times_ms(600, np.random.default_rng(0))
    rate = 599 / (times[-1] / 60_000.0)
    assert 5.0 < rate < 7.0


def test_trace_replay_loops_past_trace_end():
    proc = TraceReplay(times_ms=(0, 1_000, 3_000))
    times = proc.arrival_times_ms(7, np.random.default_rng(0))
    assert times[:3] == [0, 1000, 3000]
    assert times[3] > times[2]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# Tenant / TenantMix
# ---------------------------------------------------------------------------

TINY_MIX = TenantMix((
    Tenant("gold-astro", GOLD, apps=("montage", "trace:montage-18"),
           arrival=Poisson(8.0), n_workflows=4),
    Tenant("silver-bio", SILVER, apps=("trace:epigenomics-20",),
           arrival=Diurnal(3.0, 12.0, period_s=240.0), n_workflows=3),
    Tenant("bronze-seis", BRONZE, apps=("sipht", "trace:seismology-9"),
           arrival=MarkovModulated(2.0, 16.0, mean_dwell_s=45.0),
           n_workflows=4),
))


def test_tenant_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown app"):
        Tenant("t", GOLD, apps=("not-a-family",), arrival=Poisson(1.0))
    with pytest.raises(ValueError, match="arrival"):
        Tenant("t", GOLD, apps=("montage",))
    with pytest.raises(ValueError, match="apps or stream"):
        Tenant("t", GOLD)
    with pytest.raises(ValueError, match="duplicate tenant"):
        TenantMix((Tenant("t", GOLD, apps=("montage",),
                          arrival=Poisson(1.0)),
                   Tenant("t", BRONZE, apps=("sipht",),
                          arrival=Poisson(1.0))))


def test_mix_build_is_deterministic_and_well_formed():
    tw1 = TINY_MIX.build(CFG, seed=3)
    tw2 = TINY_MIX.build(CFG, seed=3)
    assert [w.arrival_ms for w in tw1.workflows] == \
        [w.arrival_ms for w in tw2.workflows]
    assert [w.budget for w in tw1.workflows] == \
        [w.budget for w in tw2.workflows]
    assert tw1.tenant_of == tw2.tenant_of
    # Engine invariants: wid == position, arrival-sorted.
    assert [w.wid for w in tw1.workflows] == list(range(11))
    arr = [w.arrival_ms for w in tw1.workflows]
    assert arr == sorted(arr)
    # Every tenant contributed its quota.
    names = list(tw1.tenant_of.values())
    assert names.count("gold-astro") == 4
    assert names.count("silver-bio") == 3
    assert names.count("bronze-seis") == 4
    assert tw1.qos_of == {"gold-astro": "gold", "silver-bio": "silver",
                          "bronze-seis": "bronze"}
    for wf in tw1.workflows:
        wf.validate()
        assert wf.budget > 0
    # Different seed, different draws.
    tw3 = TINY_MIX.build(CFG, seed=4)
    assert [w.budget for w in tw3.workflows] != \
        [w.budget for w in tw1.workflows]


def test_mix_budgets_respect_qos_interval():
    from repro.core.budget import min_max_workflow_cost
    tw = TINY_MIX.build(CFG, seed=0)
    for wf in tw.workflows:
        lo, hi = min_max_workflow_cost(CFG, wf)
        t = next(t for t in TINY_MIX.tenants
                 if t.name == tw.tenant_of[wf.wid])
        blo, bhi = t.qos.budget_interval
        u = (wf.budget - lo) / max(hi - lo, 1e-9)
        assert blo - 1e-9 <= u <= bhi + 1e-9


def test_mix_stream_runs_through_both_engines():
    """A trace-bearing merged stream simulates end-to-end, and renumbered
    trace clones keep their caches coherent (every task completes)."""
    tw = TINY_MIX.build(CFG, seed=0)
    res = SimEngine(CFG, EBPSM, tw.workflows, seed=0).run()
    assert len(res.workflows) == 11
    for w in res.workflows:
        assert w.finish_ms >= w.arrival_ms
        assert w.cost > 0
    assert res.peak_vms > 0
    assert res.mean_fleet_vms > 0


def test_ideal_makespan_is_positive_critical_path():
    wf = bundled_trace("seismology-9")
    ideal = ideal_makespan_ms(CFG, wf)
    # Fan-in DAG: ideal ≥ slowest decon + the sift wrapper lower bounds.
    assert ideal > 0
    chain = bundled_trace("epigenomics-20")
    assert ideal_makespan_ms(CFG, chain) > ideal


def test_assign_budgets_uniform_bounds():
    from repro.core.budget import min_max_workflow_cost
    wf = bundled_trace("montage-18")
    assign_budgets_uniform(CFG, [wf], np.random.default_rng(0), 0.0, 1.0)
    lo, hi = min_max_workflow_cost(CFG, wf)
    assert lo - 1e-9 <= wf.budget <= hi + 1e-9


# ---------------------------------------------------------------------------
# profile=True per-phase counters (core.engine satellite)
# ---------------------------------------------------------------------------


def test_profile_counters_opt_in(monkeypatch):
    tw = TINY_MIX.build(CFG, seed=0)
    eng = SimEngine(CFG, EBPSM, tw.workflows, seed=0)
    assert eng.profile is None           # off by default
    # The per-engine kwarg opts in without touching os.environ ...
    members = [(EBPSM, TenantMix(TINY_MIX.tenants[:1]).build(
        CFG, seed=0).workflows, 0)]
    beng = BatchSimEngine(CFG, members, batched="auto", profile=True)
    ref = SimEngine(CFG, EBPSM, TenantMix(TINY_MIX.tenants[:1]).build(
        CFG, seed=0).workflows, seed=0, profile=True)
    res_b = beng.run()[0]
    res_r = ref.run()
    # Profiling must not perturb results.
    assert [w.finish_ms for w in res_b.workflows] == \
        [w.finish_ms for w in res_r.workflows]
    stats = beng.dispatch_stats()
    prof = stats["profile"]
    assert prof["redistributions"] > 0
    assert prof["redistribute_s"] > 0.0
    assert prof["distributions"] == 4    # one Algorithm-1 run per workflow
    assert prof["selects"] > 0
    assert 0.0 <= prof["redistribute_share_of_wall"] <= 1.0
    # ... and self-reports its own instrumentation cost.
    assert prof["profile_overhead_s"] >= 0.0
    assert prof["profile_overhead_s"] < prof["engine_wall_s"] + 1e-9
    assert ref.profile is not None and ref.profile["redistributions"] > 0
    # ... while REPRO_PROFILE=1 stays the ambient default source.
    monkeypatch.setenv("REPRO_PROFILE", "1")
    env_eng = SimEngine(CFG, EBPSM, tw.workflows, seed=0)
    assert env_eng.profile is not None
    assert SimEngine(CFG, EBPSM, tw.workflows, seed=0,
                     profile=False).profile is None


# ---------------------------------------------------------------------------
# Review-driven regressions
# ---------------------------------------------------------------------------


def test_dax_dedups_repeated_edge_declarations():
    doc = """<adag name="dup">
      <job id="J0" runtime="1"><uses file="a" link="output" size="1000000"/></job>
      <job id="J1" runtime="1"><uses file="a" link="input" size="1000000"/></job>
      <child ref="J1"><parent ref="J0"/><parent ref="J0"/></child>
      <child ref="J1"><parent ref="J0"/></child>
    </adag>"""
    wf = load_dax(doc)
    assert wf.tasks[1].parents == [0]
    assert wf.tasks[0].children == [1]


def test_stream_tenant_applies_start_ms():
    def stream(n, seed):
        wfs = [_chain(2) for _ in range(n)]
        for i, wf in enumerate(wfs):
            wf.wid = i
            wf.arrival_ms = i * 1_000
        return wfs

    mix = TenantMix((
        dataclasses.replace(
            Tenant("late", GOLD, stream=stream, n_workflows=3),
            start_ms=60_000),
    ))
    tw = mix.build(CFG, seed=0)
    assert [w.arrival_ms for w in tw.workflows] == [60_000, 61_000, 62_000]


def test_arrival_processes_reject_bad_rates():
    with pytest.raises(ValueError, match="> 0"):
        Poisson(0.0)
    with pytest.raises(ValueError, match=">= 0"):
        MarkovModulated(-1.0, 5.0)
    with pytest.raises(ValueError, match="at least one"):
        MarkovModulated(0.0, 0.0)
    with pytest.raises(ValueError, match="dwell"):
        MarkovModulated(1.0, 5.0, mean_dwell_s=0.0)
    with pytest.raises(ValueError, match="base <= peak"):
        Diurnal(5.0, 2.0)
    with pytest.raises(ValueError, match="period"):
        Diurnal(1.0, 2.0, period_s=0.0)


def test_interrupted_poisson_silent_state_works():
    """quiet_rate=0 is the textbook IPP: silence between bursts, not a
    crash."""
    proc = MarkovModulated(0.0, 20.0, mean_dwell_s=30.0)
    a = proc.arrival_times_ms(50, np.random.default_rng(1))
    b = proc.arrival_times_ms(50, np.random.default_rng(1))
    assert a == b == sorted(a)
    assert len(a) == 50
    # Bursty: some inter-arrival gap spans a whole silent dwell.
    gaps = np.diff(a)
    assert gaps.max() > 10 * np.median(gaps[gaps > 0])

"""Parity and edge-case tests for the array-path Algorithm 3.

``core.budget`` carries two bit-exact implementations of the per-finish
redistribution (scalar ``update_budget`` reference vs array
``update_budget_fast`` over a ``RedistState``) plus the opt-in
round-batched pooled form.  These tests pin:

* property-style randomized parity (spares and every task budget exactly
  equal, including chained updates and the ``budget_vec`` mirror);
* the edge cases the sweep regimes are built around — zero surplus, debt
  (negative surplus), a single unscheduled task, everyone topping out,
  and the zero-pool identity skip;
* engine-level parity: array vs forced-scalar hot path in both
  redistribute modes, and SimEngine vs BatchSimEngine cross-engine
  parity in both modes.
"""
import os

import numpy as np
import pytest

from repro.core import budget as bmod
from repro.core import cost_tables
from repro.core.engine import SimEngine, SimState
from repro.core.jax_engine import simulate_batch
from repro.core.scheduler import ALL_POLICIES
from repro.core.types import PlatformConfig
from repro.workflows.dax import generate_workflow
from repro.workflows.workload import cell_workload

CFG = PlatformConfig()
EBPSM = next(p for p in ALL_POLICIES if p.name == "EBPSM")


def _prepared_wf(seed, n=40, app="montage", frac=0.6, rng=None):
    """Workflow with distributed budgets + a random scheduled subset.

    Returns (wf, spare, finished_tid, unscheduled_list).
    """
    rng = rng or np.random.default_rng(seed)
    wf = generate_workflow(app, 0, n, rng)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    spare = bmod.distribute_budget(CFG, wf, lo + frac * (hi - lo))
    nsched = int(rng.integers(1, wf.n_tasks + 1))
    sched = rng.choice(wf.n_tasks, size=nsched, replace=False).tolist()
    fin = int(sched[0])
    unscheduled = [t.tid for t in wf.tasks if t.tid not in set(sched)]
    return wf, spare, fin, unscheduled


def _assert_pair(wf_a, wf_b, spare_a, spare_b, unscheduled, rs=None):
    assert spare_a == spare_b
    for tid in unscheduled:
        assert wf_a.tasks[tid].budget == wf_b.tasks[tid].budget, tid
        if rs is not None:
            assert rs.budget_vec[tid] == wf_b.tasks[tid].budget, tid


# ---------------------------------------------------------------------------
# property-style parity: scalar oracle vs array path
# ---------------------------------------------------------------------------

def test_update_budget_parity_randomized():
    rng = np.random.default_rng(42)
    apps = ["montage", "sipht", "epigenome", "ligo", "cybershake"]
    for trial in range(60):
        n = int(rng.integers(5, 180)) if trial % 6 else \
            int(rng.integers(300, 700))
        wf, spare, fin, uns = _prepared_wf(
            trial, n, apps[trial % 5], float(rng.uniform(0, 1)), rng)
        wf2 = wf.clone()
        actual = float(rng.uniform(0, 2.5)) * max(wf.tasks[fin].budget, 1.0)

        spare_a = bmod.update_budget(CFG, wf, fin, actual, spare, uns)
        rs = bmod.RedistState(CFG, wf2, uns)
        spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, actual, spare)
        _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)

        # Chained second update exercises mark_scheduled + the carried
        # budget_vec state (the mirror must stay exact across calls).
        if len(uns) > 1:
            fin2, uns2 = uns[0], uns[1:]
            actual2 = float(rng.uniform(0, 2.0)) \
                * max(wf.tasks[fin2].budget, 1.0)
            spare_a2 = bmod.update_budget(CFG, wf, fin2, actual2,
                                          spare_a, uns2)
            rs.mark_scheduled(fin2)
            spare_b2 = bmod.update_budget_fast(CFG, wf2, rs, fin2,
                                               actual2, spare_b)
            _assert_pair(wf, wf2, spare_a2, spare_b2, uns2, rs)


def test_update_budget_pooled_parity_randomized():
    rng = np.random.default_rng(7)
    for trial in range(40):
        n = int(rng.integers(5, 300))
        wf, spare, _fin, uns = _prepared_wf(
            trial, n, ["montage", "cybershake"][trial % 2],
            float(rng.uniform(0, 1)), rng)
        wf2 = wf.clone()
        surplus = float(rng.normal(0.0, 5.0))
        spare_a = bmod.update_budget_pooled_scalar(CFG, wf, surplus,
                                                   spare, uns)
        rs = bmod.RedistState(CFG, wf2, uns)
        spare_b = bmod.update_budget_pooled(CFG, wf2, rs, surplus, spare)
        _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

def test_zero_surplus():
    """actual == headroom: the pool is exactly the unscheduled budgets."""
    wf, spare, fin, uns = _prepared_wf(3, 60, "montage", 0.4)
    wf2 = wf.clone()
    actual = wf.tasks[fin].budget + spare   # consumes headroom exactly
    pool_before = sum(wf.tasks[t].budget for t in uns)

    spare_a = bmod.update_budget(CFG, wf, fin, actual, spare, uns)
    rs = bmod.RedistState(CFG, wf2, uns)
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, actual, spare)
    _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)
    pool_after = sum(wf.tasks[t].budget for t in uns) + spare_a
    assert pool_after <= pool_before + 1e-6      # conservation
    assert spare_a >= 0.0


def test_debt_negative_surplus():
    """Actual cost far above headroom: the debt drains the pool; when it
    exceeds the pool entirely, every unscheduled budget clamps to 0."""
    wf, spare, fin, uns = _prepared_wf(11, 50, "cybershake", 0.3)
    wf2 = wf.clone()
    pool = sum(wf.tasks[t].budget for t in uns) \
        + wf.tasks[fin].budget + spare
    actual = pool * 10.0 + 100.0                 # debt > whole pool

    spare_a = bmod.update_budget(CFG, wf, fin, actual, spare, uns)
    rs = bmod.RedistState(CFG, wf2, uns)
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, actual, spare)
    _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)
    assert spare_a == 0.0
    assert all(wf.tasks[t].budget == 0.0 for t in uns)


def test_single_unscheduled_task():
    wf, spare, fin, _ = _prepared_wf(5, 30, "sipht", 0.5)
    uns = [t.tid for t in wf.tasks if t.tid != fin][:1]
    wf2 = wf.clone()
    actual = 0.5 * max(wf.tasks[fin].budget, 1.0)

    spare_a = bmod.update_budget(CFG, wf, fin, actual, spare, uns)
    rs = bmod.RedistState(CFG, wf2, uns)
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, actual, spare)
    _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)
    # Alg 1 on one task: it can never exceed its top-tier cost.
    table = cost_tables.table_for(CFG, wf)
    assert wf.tasks[uns[0]].budget <= table.top_arr[uns[0]] + 1e-9
    assert spare_a >= 0.0


def test_all_tasks_topped_out():
    """A pool big enough to top everyone out pins every unscheduled
    budget at its top-tier cost, identically on both paths."""
    rng = np.random.default_rng(17)
    wf = generate_workflow("montage", 0, 120, rng)
    lo, hi = bmod.min_max_workflow_cost(CFG, wf)
    bmod.distribute_budget(CFG, wf, lo)
    uns = [t.tid for t in wf.tasks if t.tid != 0]
    wf2 = wf.clone()
    table = cost_tables.table_for(CFG, wf)
    huge = 10.0 * hi                              # tops out with room over

    spare_a = bmod.update_budget(CFG, wf, 0, 0.0, huge, uns)
    rs = bmod.RedistState(CFG, wf2, uns)
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, 0, 0.0, huge)
    _assert_pair(wf, wf2, spare_a, spare_b, uns, rs)
    if table.tiers_monotone:
        for tid in uns:
            assert wf.tasks[tid].budget == table.top_arr[tid], tid
    assert spare_a > 0.0


def test_zero_pool_identity_skip():
    """Pool 0 over all-zero budgets: the array path returns without
    touching the tasks and agrees with the scalar result."""
    wf, _spare, fin, uns = _prepared_wf(23, 40, "ligo", 0.2)
    for t in wf.tasks:
        t.budget = 0.0
    wf2 = wf.clone()

    spare_a = bmod.update_budget(CFG, wf, fin, 5.0, 0.0, uns)
    rs = bmod.RedistState(CFG, wf2, uns)
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, 5.0, 0.0)
    assert spare_a == spare_b == 0.0
    assert all(wf2.tasks[t].budget == 0.0 for t in uns)
    assert not rs.budget_vec.any()


def test_empty_unscheduled_returns_pool():
    wf, spare, fin, _ = _prepared_wf(29, 20, "montage", 0.5)
    wf2 = wf.clone()
    actual = 0.25 * max(wf.tasks[fin].budget, 1.0)
    spare_a = bmod.update_budget(CFG, wf, fin, actual, spare, [])
    rs = bmod.RedistState(CFG, wf2, [])
    spare_b = bmod.update_budget_fast(CFG, wf2, rs, fin, actual, spare)
    assert spare_a == spare_b
    assert spare_a == max(wf.tasks[fin].budget + spare - actual, 0.0) \
        or spare_a >= 0.0


# ---------------------------------------------------------------------------
# engine-level parity
# ---------------------------------------------------------------------------

def _workload():
    return cell_workload(CFG, "montage", 6.0, (0.0, 0.25), seed=3,
                         n_workflows=10, sizes=("small", "medium"))


def _key(res):
    return [(w.wid, w.cost, w.finish_ms) for w in res.workflows]


def _run_engine(wl, redistribute, scalar, monkeypatch):
    monkeypatch.setattr(bmod, "_ARRAY_REDIST", not scalar)
    wfs = [w.clone() for w in wl]
    return SimEngine(CFG, EBPSM, wfs, seed=0,
                     redistribute=redistribute).run()


@pytest.mark.parametrize("mode", ["finish", "round"])
def test_engine_array_vs_scalar_parity(mode, monkeypatch):
    wl = _workload()
    r_arr = _run_engine(wl, mode, scalar=False, monkeypatch=monkeypatch)
    r_sca = _run_engine(wl, mode, scalar=True, monkeypatch=monkeypatch)
    assert _key(r_arr) == _key(r_sca)


@pytest.mark.parametrize("mode", ["finish", "round"])
def test_cross_engine_parity(mode):
    wl = _workload()
    seq = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                    redistribute=mode).run()
    bat = simulate_batch(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                         redistribute=mode)
    assert _key(bat.results[0]) == _key(seq)


def test_round_mode_coalesces_events():
    wl = cell_workload(CFG, "cybershake", 8.0, (0.0, 0.25), seed=1,
                       n_workflows=8, sizes=("medium",))

    def prof(mode):
        eng = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                        redistribute=mode, profile=True)
        eng.run()
        return eng.profile

    p_fin = prof("finish")
    assert p_fin["redistribute_events"] == p_fin["redistributions"] > 0
    p_rnd = prof("round")
    assert p_rnd["redistribute_events"] == p_fin["redistribute_events"]
    assert p_rnd["redistributions"] <= p_rnd["redistribute_events"]
    assert p_rnd["redistributions"] > 0


def test_redistribute_mode_validated():
    wl = _workload()[:1]
    with pytest.raises(ValueError):
        SimEngine(CFG, EBPSM, [wl[0].clone()], seed=0,
                  redistribute="never")

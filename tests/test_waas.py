"""WaaS→ML bridge: job DAGs, shared-weight locality, policy ordering."""
import numpy as np
import pytest

from repro.core.scheduler import EBPSM, EBPSM_NS, MSLBL_MW
from repro.waas import mljobs, slices
from repro.waas.platform import (assign_budgets, compare_policies,
                                 run_platform, straggler_experiment)


def test_job_dags_valid():
    rng = np.random.default_rng(0)
    cost = mljobs.StageCostModel(art_dir="/nonexistent")  # analytic fallback
    for arch in ("llama3-8b", "qwen2-moe-a2.7b", "hubert-xlarge",
                 "mamba2-780m"):
        ft = mljobs.finetune_job(0, arch, cost, rng)
        ft.validate()
        sv = mljobs.serve_job(1, arch, cost, rng)
        sv.validate()
        assert all(t.shared_in for t in ft.tasks[4:5])  # train tasks share
        assert ft.n_tasks >= 8 and sv.n_tasks >= 5


def test_encoder_serve_has_no_decode():
    rng = np.random.default_rng(0)
    cost = mljobs.StageCostModel(art_dir="/nonexistent")
    sv = mljobs.serve_job(0, "hubert-xlarge", cost, rng, n_prefill=4)
    # warm + 4 prefills + collect = 6 (no decode stages)
    assert sv.n_tasks == 6


def test_workload_poisson_arrivals():
    wfs = mljobs.ml_workload(20, 3.0, seed=1, art_dir="/nonexistent")
    arr = [w.arrival_ms for w in wfs]
    assert arr == sorted(arr)
    assert len({w.app for w in wfs}) > 3


def test_ebpsm_beats_mslbl_on_platform():
    cfg = slices.platform_config()
    wfs = mljobs.ml_workload(25, 2.0, seed=3, art_dir="/nonexistent")
    assign_budgets(cfg, wfs, seed=3)
    r_e = run_platform(wfs, EBPSM, cfg, seed=0)
    wfs = mljobs.ml_workload(25, 2.0, seed=3, art_dir="/nonexistent")
    assign_budgets(cfg, wfs, seed=3)
    r_m = run_platform(wfs, MSLBL_MW, cfg, seed=0)
    assert r_e.mean_makespan_s < r_m.mean_makespan_s
    assert r_e.locality_hit_rate > 0.15     # warm base-weight placements
    assert r_m.locality_hit_rate == 0.0     # MSLBL ignores locality tiers


def test_shared_weights_cross_tenant():
    """Two tenants fine-tuning the same arch share warm slices under
    EBPSM (tier-1 hits across wids) but not under EBPSM_NS."""
    cfg = slices.platform_config()
    rng = np.random.default_rng(5)
    cost = mljobs.StageCostModel(art_dir="/nonexistent")
    wfs = [mljobs.finetune_job(i, "llama3-8b", cost, rng) for i in range(4)]
    for i, w in enumerate(wfs):
        w.arrival_ms = i * 30_000
    assign_budgets(cfg, wfs, seed=5)
    r_share = run_platform(wfs, EBPSM, cfg, seed=0)
    for w in wfs:
        for t in w.tasks:
            pass
    rng = np.random.default_rng(5)
    wfs = [mljobs.finetune_job(i, "llama3-8b", cost, rng) for i in range(4)]
    for i, w in enumerate(wfs):
        w.arrival_ms = i * 30_000
    assign_budgets(cfg, wfs, seed=5)
    r_ns = run_platform(wfs, EBPSM_NS, cfg, seed=0)
    assert r_share.sim.total_vms <= r_ns.sim.total_vms


def test_straggler_mitigation_trend():
    out = straggler_experiment(n_jobs=12, rate=2.0, seed=2,
                               degradations=(0.1, 0.5),
                               art_dir="/nonexistent")
    e = out["EBPSM"]
    m = out["MSLBL_MW"]
    # both degrade with stragglers, EBPSM stays ahead at high degradation
    assert e[-1][1] <= m[-1][1]


def test_sweep_grid():
    """waas.platform.sweep: one batched run covers policy × rate × seed,
    and each cell matches a standalone run_platform simulation."""
    from repro.waas.platform import sweep
    rows = sweep(n_jobs=6, rates=(2.0,), seeds=(0,),
                 policies=(EBPSM, MSLBL_MW), art_dir="/nonexistent")
    assert len(rows) == 2
    by_pol = {r["policy"]: r for r in rows}
    cfg = slices.platform_config()
    for pol in (EBPSM, MSLBL_MW):
        wfs = mljobs.ml_workload(6, 2.0, seed=0, art_dir="/nonexistent")
        assign_budgets(cfg, wfs, seed=0)
        rep = run_platform(wfs, pol, cfg, seed=0)
        assert by_pol[pol.name]["mean_makespan_s"] == \
            pytest.approx(rep.mean_makespan_s)
        assert by_pol[pol.name]["budget_met"] == pytest.approx(rep.budget_met)

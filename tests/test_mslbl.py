"""MSLBL_MW budget mechanics: budget-level clipping at both extremes and
the single-spare-pool rollover on task completion (engine path)."""
import numpy as np
import pytest

from repro.core import costs
from repro.core.budget import input_mb
from repro.core.engine import SimEngine
from repro.core.mslbl import distribute_budget_mslbl
from repro.core.scheduler import MSLBL_MW
from repro.core.types import PlatformConfig, Task, Workflow
from repro.workflows.dax import generate_workflow

CFG = PlatformConfig()


def _minmax_costs(wf):
    cheap = min(CFG.vm_types, key=lambda v: v.mips)
    fast = max(CFG.vm_types, key=lambda v: v.mips)
    c_min, c_max = [], []
    for t in wf.tasks:
        mb = input_mb(wf, t)
        c_min.append(costs.estimate_full_cost(CFG, cheap, t, mb))
        c_max.append(costs.estimate_full_cost(CFG, fast, t, mb))
    return c_min, c_max


@pytest.mark.parametrize("app", ["montage", "cybershake"])
def test_budget_level_clips_low(app):
    """β below Σ c_min ⇒ level clipped to 0 ⇒ every task gets exactly its
    cheapest-execution cost (the safety net never under-allocates)."""
    wf = generate_workflow(app, 0, 30, np.random.default_rng(1))
    c_min, _ = _minmax_costs(wf)
    distribute_budget_mslbl(CFG, wf, budget=0.5 * sum(c_min))
    for t in wf.tasks:
        assert t.budget == pytest.approx(c_min[t.tid], rel=1e-12)


@pytest.mark.parametrize("app", ["montage", "sipht"])
def test_budget_level_clips_high(app):
    """β above Σ c_max ⇒ level clipped to 1 ⇒ every task gets exactly its
    fastest-execution cost (surplus is never distributed past c_max)."""
    wf = generate_workflow(app, 0, 30, np.random.default_rng(2))
    _, c_max = _minmax_costs(wf)
    distribute_budget_mslbl(CFG, wf, budget=2.0 * sum(c_max))
    for t in wf.tasks:
        assert t.budget == pytest.approx(c_max[t.tid], rel=1e-12)


def test_budget_level_interpolates_midrange():
    wf = generate_workflow("ligo", 0, 25, np.random.default_rng(3))
    c_min, c_max = _minmax_costs(wf)
    lo, hi = sum(c_min), sum(c_max)
    beta = lo + 0.5 * (hi - lo)
    distribute_budget_mslbl(CFG, wf, budget=beta)
    level = (beta - lo) / (hi - lo)
    for t in wf.tasks:
        want = c_min[t.tid] + level * (c_max[t.tid] - c_min[t.tid])
        assert t.budget == pytest.approx(want, rel=1e-9)
    # The safety net conserves the budget level exactly.
    assert sum(t.budget for t in wf.tasks) == pytest.approx(beta, rel=1e-9)


def _chain_wf(b0: float, b1: float) -> Workflow:
    """Two-task chain with hand-set sub-budgets (predistributed path)."""
    t0 = Task(tid=0, size_mi=10.0, out_mb=0.0)
    t1 = Task(tid=1, size_mi=10.0, out_mb=0.0)
    t0.children.append(1)
    t1.parents.append(0)
    wf = Workflow(wid=0, app="bench", tasks=[t0, t1], budget=b0 + b1)
    wf.validate()
    t0.budget, t1.budget = b0, b1
    return wf


def _run_chain(b0: float, b1: float) -> SimEngine:
    eng = SimEngine(CFG, MSLBL_MW, [_chain_wf(b0, b1)], seed=0, trace=True,
                    predistributed={0: 0.0})
    eng.run()
    return eng


def test_spare_pool_rollover_unlocks_successor():
    """Task 0 under-spends its generous allocation; the leftover rolls
    into the single spare pool and funds task 1 (whose own sub-budget is
    zero): the successor schedules in-budget (tier 3 reuse) instead of
    falling to the insufficient-budget tier 5."""
    eng = _run_chain(b0=150.0, b1=0.0)
    tier_of = {row[2]: row[3] for row in eng.trace_rows}
    assert tier_of[1] == 3, eng.trace_rows

    # Control: no leftover (task 0's allocation is fully consumed), so the
    # spare pool stays empty and task 1 hits the tier-5 fallback.
    ctl = _run_chain(b0=0.0, b1=0.0)
    ctl_tier_of = {row[2]: row[3] for row in ctl.trace_rows}
    assert ctl_tier_of[1] == 5, ctl.trace_rows


def test_spare_pool_accounting_is_single_pool():
    """Spare = Σ(allocation − actual) − Σ consumed-at-scheduling: one pool
    per workflow, debited by the amount the placement estimate exceeds the
    task's own sub-budget."""
    eng = _run_chain(b0=150.0, b1=0.0)
    st = eng.wf_state[0]
    res = eng.finalize()
    total_actual = res.workflows[0].cost
    # Task 1's placement estimate (5 cents: 5 s pipeline on the idle small
    # VM) was debited from the pool; both tasks' (budget − actual) flowed in.
    est1 = next(row[4] for row in eng.trace_rows if row[2] == 1)
    assert st.spare == pytest.approx(150.0 + 0.0 - total_actual - est1,
                                     abs=1e-9)


def test_spare_never_negative_at_scheduling():
    """The scheduler only ever debits what the pool holds (no negative
    effective budgets from the rollover)."""
    eng = _run_chain(b0=0.0, b1=0.0)
    st = eng.wf_state[0]
    # Pool went negative only through the *finish* accounting (debt),
    # never through scheduling debits beyond the held amount.
    assert st.spare == pytest.approx(-eng.finalize().workflows[0].cost,
                                     abs=1e-9)

"""repro.obs.monitor / slo / report — the live SLO monitor gates.

The contract under test (PR 10):

* zero cost when disabled — ``monitor=None`` without ``REPRO_MONITOR=1``
  leaves ``eng.monitor is None``; an events-on run with the monitor off
  allocates nothing in ``obs/monitor.py`` (the hot path is one ``sub is
  not None`` check in ``EventLog.append``);
* streaming aggregates agree with the event log they fold (events seen,
  placements, completions, arrivals) and never perturb results;
* determinism — ``monitor.json`` and the HTML dashboard are
  byte-identical across SimEngine vs BatchSimEngine, object vs SoA
  state layout, repeat runs, and an interrupt/resume cut mid-stream
  (the monitor rides the pickled ``elog.sub`` in stream snapshots);
* alert mechanics — burn-rate algebra, the threshold+MAD rule, and
  fire/clear hysteresis on a synthetic event stream;
* the chaos gate — ``online-chaos-smoke`` fires the ``budget_burn`` and
  ``straggler_spike`` detectors (the CI alert floors) while the benign
  detectors stay quiet on clean streams;
* the exp harness — ``dispatch_stats()["monitor"]`` blocks are
  integer-only and merge exactly across worker chunks; written reports
  pass ``tools/check_report.py``.
"""
import dataclasses
import pickle
import tracemalloc

import numpy as np
import pytest

from repro.core.engine import SimEngine
from repro.core.jax_engine import BatchSimEngine, StreamInterrupted
from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig
from repro.exp.run import run_online
from repro.exp.scenarios import ONLINE_SCENARIOS
from repro.obs import events as ev
from repro.obs import monitor as mon_mod
from repro.obs import report as rep
from repro.obs import slo
from repro.obs.monitor import Monitor, MonitorConfig
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def workload(seed, n=6, rate=12.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",), budget_lo=0.5, budget_hi=1.0)
    return generate_workload(CFG, spec)


# ---------------------------------------------------------------------------
# Enable/disable plumbing
# ---------------------------------------------------------------------------


def test_monitor_disabled_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_MONITOR", raising=False)
    eng = SimEngine(CFG, EBPSM, workload(0, n=3), seed=0)
    assert eng.monitor is None and eng.elog is None
    eng.run()
    assert eng.monitor is None


def test_resolve_monitor(monkeypatch):
    monkeypatch.delenv("REPRO_MONITOR", raising=False)
    assert mon_mod.resolve_monitor(None) is None
    assert mon_mod.resolve_monitor(False) is None
    assert isinstance(mon_mod.resolve_monitor(True), Monitor)
    m = Monitor()
    assert mon_mod.resolve_monitor(m) is m          # pass-through
    monkeypatch.setenv("REPRO_MONITOR", "1")
    assert isinstance(mon_mod.resolve_monitor(None), Monitor)
    assert mon_mod.resolve_monitor(False) is None   # explicit False beats env


def test_repro_monitor_env_enables(monkeypatch):
    monkeypatch.setenv("REPRO_MONITOR", "1")
    eng = SimEngine(CFG, EBPSM, workload(0, n=3), seed=0)
    assert eng.monitor is not None
    assert eng.elog is not None                     # monitor implies events
    assert eng.elog.sub is eng.monitor
    eng.run()
    assert eng.monitor.ticks > 0
    assert eng.monitor.finalized_ms == eng.now


def test_monitor_off_allocates_nothing_in_monitor_module():
    wl = workload(4, n=4)
    warm = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                     events=True)
    warm.run()                                  # warm caches outside tracing
    eng = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                    events=True)
    assert eng.monitor is None and eng.elog is not None
    mon_filter = tracemalloc.Filter(True, "*repro/obs/monitor.py")
    tracemalloc.start()
    try:
        eng.run()
        snap = tracemalloc.take_snapshot().filter_traces([mon_filter])
        mon_bytes = sum(stat.size for stat in snap.statistics("filename"))
    finally:
        tracemalloc.stop()
    assert mon_bytes == 0


# ---------------------------------------------------------------------------
# Aggregate invariants
# ---------------------------------------------------------------------------


def test_monitor_counts_match_event_log():
    eng = SimEngine(CFG, EBPSM, workload(1, n=8), seed=0, monitor=True)
    res = eng.run()
    m, counts = eng.monitor, eng.elog.counts()
    assert m.events_seen == eng.elog.total
    assert m.placements == counts["task_place"]
    assert m.completions == counts["wf_done"] == len(res.workflows)
    assert m.arrivals == counts["wf_arrive"]
    assert m.churn == counts["vm_provision"] + counts["vm_reap"]
    assert m.fleet == 0 and m.busy == 0 and m.queue == 0  # post-finalize
    assert m.cost == pytest.approx(sum(w.cost for w in res.workflows))
    # The sampled series cover the horizon and end on the final state.
    s = m.series()
    assert int(s["t_ms"][-1]) == eng.now
    assert int(s["fleet"][-1]) == 0
    assert float(s["cum_cost"][-1]) == pytest.approx(m.cost)
    assert all(len(v) == len(s["t_ms"]) for v in s.values())


def test_monitor_does_not_perturb_results():
    wl = workload(2, n=6)
    plain = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0).run()
    mon = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                    monitor=True).run()
    assert [(w.wid, w.finish_ms, w.cost) for w in mon.workflows] == \
        [(w.wid, w.finish_ms, w.cost) for w in plain.workflows]
    assert mon.vm_count_by_type == plain.vm_count_by_type


# ---------------------------------------------------------------------------
# Determinism across engines, layouts, repeats
# ---------------------------------------------------------------------------


def _report_bytes(m, label="cell"):
    return rep.monitor_json(m, label), rep.dashboard_html(m, label)


def test_reports_identical_across_engines_and_layouts():
    runs = {}
    seq = SimEngine(CFG, EBPSM, workload(7, n=5), seed=0, monitor=True)
    seq.run()
    runs["seq"] = _report_bytes(seq.monitor)
    for name, soa in (("obj1", False), ("obj2", False), ("soa", True)):
        eng = BatchSimEngine(CFG, [(EBPSM, workload(7, n=5), 0)],
                             monitor=True, soa=soa)
        eng.run()
        runs[name] = _report_bytes(eng.states[0].monitor)
    assert runs["obj1"] == runs["obj2"]        # repeat-run determinism
    assert runs["obj1"] == runs["soa"]         # layout independence
    assert runs["obj1"] == runs["seq"]         # sequential-oracle parity


def test_monitor_pickles_with_event_log():
    eng = SimEngine(CFG, EBPSM, workload(3, n=4), seed=0, monitor=True)
    eng.run()
    back = pickle.loads(pickle.dumps(eng.elog))
    assert isinstance(back.sub, Monitor)
    assert _report_bytes(back.sub) == _report_bytes(eng.monitor)
    # Pre-monitor pickles (no ``sub`` key) restore with sub = None.
    state = eng.elog.__getstate__()
    state.pop("sub")
    old = ev.EventLog.__new__(ev.EventLog)
    old.__setstate__(state)
    assert old.sub is None


# ---------------------------------------------------------------------------
# Alert mechanics (synthetic streams)
# ---------------------------------------------------------------------------


def test_burn_rate_algebra():
    assert slo.burn_rate(1.0, 0.9) == 0.0
    assert slo.burn_rate(0.9, 0.9) == pytest.approx(1.0)
    assert slo.burn_rate(0.8, 0.9) == pytest.approx(2.0)
    assert slo.burn_rate(0.9, 1.0) == pytest.approx(100.0)  # degenerate tgt


def test_mad_fire_rule():
    hist = np.array([1.0] * 20)
    assert not slo.mad_fire(hist, 1.0, k=6.0, min_abs=2.0, min_samples=12)
    assert slo.mad_fire(hist, 4.0, k=6.0, min_abs=2.0, min_samples=12)
    # All-quiet history (MAD = 0): the absolute floor keeps small ticks
    # from flagging.
    assert not slo.mad_fire(hist, 2.5, k=6.0, min_abs=2.0, min_samples=12)
    # Too little history never fires.
    assert not slo.mad_fire(hist[:5], 99.0, k=6.0, min_abs=2.0,
                            min_samples=12)


def test_target_for_falls_back_to_all():
    assert slo.target_for("gold").budget_met == 0.90
    assert slo.target_for("nonesuch") == slo.DEFAULT_TARGETS["all"]


def _synthetic_monitor():
    return Monitor(MonitorConfig(sample_ms=1_000, short_window_ms=5_000,
                                 long_window_ms=10_000))


def test_budget_burn_fires_and_clears():
    m = _synthetic_monitor()
    t = 0
    # Phase 1: every other task fails — wasted/spend far over the 4% fire
    # threshold on both windows.
    for i in range(40):
        t = i * 500
        kind = ev.TASK_FAIL if i % 2 else ev.TASK_FINISH
        m.on_event(kind, t, 0, i, 0, 0, 0.5, 0.0)
    # Phase 2: clean finishes only; the windows slide past the failures
    # and the short-window fraction drops below the 1% clear threshold.
    for i in range(40, 140):
        t = i * 500
        m.on_event(ev.TASK_FINISH, t, 0, i, 0, 0, 0.5, 0.0)
    m.finalize(t)
    burns = [a for a in m.alerts if a.kind == slo.ALERT_BUDGET_BURN]
    assert len(burns) == 1
    a = burns[0]
    assert a.scope == "platform" and not a.open
    assert 0 < a.fired_ms < a.cleared_ms <= t
    assert a.value >= m.cfg.waste_frac_fire


def test_straggler_spike_fires_and_clears():
    m = _synthetic_monitor()
    for i in range(4):
        m.on_event(ev.STRAGGLER_DETECT, 1_000 + i * 100, 0, i, 0, 0,
                   0.0, 0.0)
    for i in range(30):
        m.on_event(ev.TASK_FINISH, 2_000 + i * 1_000, 0, i, 0, 0, 0.1, 0.0)
    m.finalize(32_000)
    spikes = [a for a in m.alerts if a.kind == slo.ALERT_STRAGGLER_SPIKE]
    assert len(spikes) == 1 and not spikes[0].open
    assert spikes[0].value >= m.cfg.straggler_fire


def test_alert_gate_hysteresis():
    g = slo.AlertGate(slo.ALERT_BUDGET_BURN, "platform")
    alerts = []
    g.step(alerts, 10, fire=False, clear=True, value=0.0, threshold=1.0)
    assert alerts == []
    g.step(alerts, 20, fire=True, clear=False, value=2.0, threshold=1.0)
    g.step(alerts, 30, fire=True, clear=False, value=3.0, threshold=1.0)
    assert len(alerts) == 1 and alerts[0].open      # no re-fire while open
    g.step(alerts, 40, fire=False, clear=True, value=0.0, threshold=1.0)
    assert alerts[0].cleared_ms == 40 and not alerts[0].open
    g.step(alerts, 50, fire=True, clear=False, value=2.0, threshold=1.0)
    assert len(alerts) == 2                          # re-arms after clear


def test_tick_before_event_boundary():
    """A sample at boundary B records state from events with t < B."""
    m = _synthetic_monitor()
    m.on_event(ev.TASK_READY, 500, 0, 0, 0, 0, 0.0, 0.0)
    m.on_event(ev.TASK_READY, 1_000, 0, 1, 0, 0, 0.0, 0.0)  # flushes t=1000
    assert m.ticks == 1
    assert int(m.s_gauges[0, 2]) == 1   # only the t=500 READY is sampled
    m.finalize(1_500)
    s = m.series()
    assert s["t_ms"].tolist() == [1_000, 1_500]
    assert s["queue"].tolist() == [1, 2]


# ---------------------------------------------------------------------------
# Chaos separation: detectors fire on chaos, stay quiet on benign streams
# ---------------------------------------------------------------------------


def _chaos_scenario(**kw):
    base = ONLINE_SCENARIOS["online-chaos-smoke"]
    return dataclasses.replace(base, **kw)


def test_chaos_smoke_fires_alert_floors():
    scen = _chaos_scenario(policies=("EBPSM",))
    art = run_online(scen, monitor=True)
    blk = art["dispatch"]["monitor"]
    assert blk["enabled"] and blk["members"] == 1
    by_kind = blk["alerts_by_kind"]
    for kind, floor in scen.alert_floors.items():
        assert by_kind.get(kind, 0) >= floor, (kind, by_kind)
    assert art["alert_floors"] == scen.alert_floors
    # Per-cell alert tallies land on the rows too.
    row = art["cells"][0]
    assert row["alerts_total"] == sum(by_kind.values())
    assert sum(row["alerts_by_kind"].values()) == row["alerts_total"]


def test_benign_stream_keeps_chaos_detectors_quiet():
    base = ONLINE_SCENARIOS["online-smoke"]
    scen = dataclasses.replace(base, policies=("EBPSM",))
    art = run_online(scen, monitor=True)
    by_kind = art["dispatch"]["monitor"]["alerts_by_kind"]
    assert by_kind.get("budget_burn", 0) == 0
    assert by_kind.get("straggler_spike", 0) == 0


# ---------------------------------------------------------------------------
# Harness integration: resume identity, merged blocks, validator
# ---------------------------------------------------------------------------


def _read_all(d):
    return {p.name: p.read_bytes() for p in sorted(d.iterdir())}


def test_reports_identical_across_interrupt_resume(tmp_path):
    """The acceptance gate: dashboards and monitor.json from a stream
    interrupted mid-flight and resumed are byte-identical with an
    uninterrupted run (the monitor rides the snapshot's elog residue)."""
    scen = _chaos_scenario(policies=("EBPSM", "MSLBL_MW"))
    d_ref, d_res, ck = tmp_path / "ref", tmp_path / "res", tmp_path / "ck"
    run_online(scen, report_dir=str(d_ref))
    ref = _read_all(d_ref)
    assert any(n.endswith(".monitor.json") for n in ref)
    with pytest.raises(StreamInterrupted):
        run_online(scen, report_dir=str(d_res), ckpt_dir=str(ck),
                   ckpt_every_s=0.0, stop_after_ckpts=2)
    got = run_online(scen, report_dir=str(d_res), ckpt_dir=str(ck),
                     resume=True)
    assert _read_all(d_res) == ref
    assert got["dispatch"]["monitor"]["enabled"]


def test_monitor_block_integer_only_and_merge_exact():
    eng = BatchSimEngine(
        CFG, [(EBPSM, workload(5, n=4), 0), (MSLBL_MW, workload(6, n=4), 1)],
        monitor=True)
    eng.run()
    blk = eng.dispatch_stats()["monitor"]
    for key, v in blk.items():
        if key == "alerts_by_kind":
            assert all(isinstance(n, int) for n in v.values())
        elif key != "enabled":
            assert isinstance(v, int), key
    # Splitting members across chunks and merging the blocks reproduces
    # the single-block numbers exactly (the serial-vs-workers CI gate).
    solo = [mon_mod.monitor_block([st.monitor]) for st in eng.states]
    assert mon_mod.merge_monitor_blocks(solo) == blk
    off = mon_mod.monitor_block([None, None])
    assert off["enabled"] is False and off["alerts_total"] == 0


def test_written_reports_pass_validator(tmp_path):
    import os
    import subprocess
    import sys
    scen = _chaos_scenario(policies=("EBPSM",))
    run_online(scen, report_dir=str(tmp_path / "r"))
    checker = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "check_report.py")
    proc = subprocess.run(
        [sys.executable, checker, str(tmp_path / "r"),
         "--require-alert", "budget_burn",
         "--require-alert", "straggler_spike"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # A corrupted document fails it.
    bad = tmp_path / "r" / "bad.monitor.json"
    bad.write_text('{"schema": "nope"}')
    proc = subprocess.run(
        [sys.executable, checker, str(tmp_path / "r")],
        capture_output=True, text=True)
    assert proc.returncode == 1
    # An empty directory is its own error.
    (tmp_path / "empty").mkdir()
    proc = subprocess.run(
        [sys.executable, checker, str(tmp_path / "empty")],
        capture_output=True, text=True)
    assert proc.returncode == 2


def test_dashboard_and_payload_shape(tmp_path):
    eng = SimEngine(CFG, EBPSM, workload(9, n=5), seed=0, monitor=True)
    eng.run()
    pay = rep.monitor_payload(eng.monitor, label="unit")
    assert pay["schema"] == rep.MONITOR_SCHEMA
    assert pay["version"] == rep.MONITOR_SCHEMA_VERSION
    assert set(pay["samples"]["series"]) >= set(mon_mod.SERIES_NAMES)
    assert pay["qos"] == ["all"]
    assert "all" in pay["slo"]
    html = rep.dashboard_html(eng.monitor, label="unit")
    assert html.startswith("<!DOCTYPE html>")
    assert rep.DASHBOARD_MARKER in html
    assert "<script" not in html                    # static, no scripts
    jp, hp = rep.write_cell_report(str(tmp_path), "unit", eng.monitor)
    assert open(jp).read().rstrip("\n") == rep.monitor_json(
        eng.monitor, "unit")
    assert rep.DASHBOARD_MARKER in open(hp).read()

"""repro.obs — event bus, time-series, and trace-export gates.

The contract under test (PR 8):

* :class:`repro.obs.events.EventLog` — append/growth, ring wrap-around,
  chronological views, pickling;
* zero cost when disabled — a run without ``events=`` allocates nothing
  in the obs layer and leaves ``elog is None``;
* event-count invariants — the log agrees with the aggregate
  ``SimResult``/``CellMetrics`` numbers it shadows (placements ==
  scheduled tasks, provisions == fleet, event-derived peak == reported
  peak via the shared ``peak_and_mean`` reconstruction);
* byte-determinism — the same cell + seed exports identical Perfetto
  JSON and JSONL bytes across repeat runs, SoA vs object state layout,
  and a checkpoint/resume cut mid-stream;
* the exp harness merge — ``--workers`` events blocks equal serial
  (asserted in ``tests/test_exp.py::test_run_grid_workers_matches_serial``).
"""
import dataclasses
import pickle
import tracemalloc

import numpy as np
import pytest

from repro.core.engine import SimEngine, profile_overhead_s
from repro.core.jax_engine import BatchSimEngine, StreamInterrupted
from repro.core.scheduler import EBPSM, MSLBL_MW
from repro.core.types import PlatformConfig
from repro.exp.run import run_online
from repro.exp.scenarios import ONLINE_SCENARIOS
from repro.obs import events as ev
from repro.obs import export as ex
from repro.obs import timeseries as ts
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def workload(seed, n=6, rate=12.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",), budget_lo=0.5, budget_hi=1.0)
    return generate_workload(CFG, spec)


# ---------------------------------------------------------------------------
# EventLog mechanics
# ---------------------------------------------------------------------------


def test_eventlog_append_and_growth():
    log = ev.EventLog()
    for i in range(3000):                      # crosses the 1024 → 2048 grow
        log.append(ev.TASK_READY, i, a=i, x=i * 0.5)
    assert len(log) == log.total == 3000
    assert log.dropped == 0
    arrays = log.to_arrays()
    assert arrays["t"].tolist() == list(range(3000))
    assert arrays["a"][2999] == 2999 and arrays["x"][1] == 0.5
    assert log.counts() == {"task_ready": 3000}


def test_eventlog_ring_keeps_most_recent():
    log = ev.EventLog(capacity=4)
    for i in range(6):
        log.append(ev.TASK_READY, i, a=10 + i)
    assert log.total == 6 and len(log) == 4 and log.dropped == 2
    arrays = log.to_arrays()                   # chronological despite wrap
    assert arrays["t"].tolist() == [2, 3, 4, 5]
    assert arrays["a"].tolist() == [12, 13, 14, 15]
    assert [r["t_ms"] for r in log.rows()] == [2, 3, 4, 5]


def test_eventlog_capacity_validated():
    with pytest.raises(ValueError):
        ev.EventLog(capacity=0)


def test_eventlog_pickle_roundtrip():
    log = ev.EventLog(capacity=3)
    for i in range(5):
        log.append(ev.VM_PROVISION, i, a=i, b=1)
    back = pickle.loads(pickle.dumps(log))
    assert back.total == 5 and back.dropped == 2
    assert back.to_arrays()["t"].tolist() == [2, 3, 4]
    back.append(ev.VM_REAP, 9, a=0)            # still appendable after load
    assert back.total == 6


def test_resolve_events(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert ev.resolve_events(None) is None
    assert ev.resolve_events(False) is None
    assert isinstance(ev.resolve_events(True), ev.EventLog)
    log = ev.EventLog()
    assert ev.resolve_events(log) is log       # pass-through, not a copy
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert isinstance(ev.resolve_events(None), ev.EventLog)
    assert ev.resolve_events(False) is None    # explicit False beats env


def test_events_block_sums_logs():
    a, b = ev.EventLog(), ev.EventLog(capacity=2)
    a.append(ev.TASK_READY, 0)
    for i in range(3):
        b.append(ev.TASK_READY, i)
    blk = ev.events_block([a, None, b])
    assert blk["enabled"] and blk["total"] == 4 and blk["dropped"] == 1
    assert blk["by_kind"] == {"task_ready": 3}   # rings report what they hold
    off = ev.events_block([None, None])
    assert off == {"enabled": False, "total": 0, "by_kind": {}, "dropped": 0}


# ---------------------------------------------------------------------------
# Time series
# ---------------------------------------------------------------------------


def test_step_series_coalesces_ties():
    s = ts.step_series("q", [5, 1, 5], [1.0, 1.0, -1.0])
    assert s.t_ms.tolist() == [1, 5]
    assert s.v.tolist() == [1.0, 1.0]          # same-t deltas coalesce
    assert s.at(0) == 0.0 and s.at(3) == 1.0 and s.final() == 1.0


def test_peak_and_mean_matches_hand_computation():
    # [0,30] + [10,15] + [20,25]: peak 2, vm-time 40 over horizon 30.
    peak, mean = ts.peak_and_mean([0, 10, 20], [30, 15, 25])
    assert peak == 2
    assert mean == pytest.approx(40.0 / 30.0)
    assert ts.peak_and_mean([], []) == (0, 0.0)


def test_sample_step_hold():
    s = ts.step_series("s", [10, 20], [2.0, 3.0])
    grid = np.array([0, 10, 15, 20, 99], np.int64)
    assert ts.sample(s, grid).tolist() == [0.0, 2.0, 2.0, 5.0, 5.0]


def test_series_from_empty_log():
    log = ev.EventLog()
    for series in (ts.fleet_series(log), ts.busy_series(log),
                   ts.utilization_series(log),
                   ts.cumulative_cost_series(log),
                   ts.cumulative_budget_series(log)):
        assert len(series.t_ms) == 0
        assert series.final() == 0.0 and series.at(10_000) == 0.0
    assert ts.queue_depth_series(log)["all"].final() == 0.0
    summary = ts.cell_summary(log)
    assert summary["peak_vms"] == 0 and summary["horizon_ms"] == 0
    assert summary["t_ms"] == []
    assert all(v == [] for v in summary["series"].values())


def test_series_from_dropped_ring_residue():
    """A ring that overwrote every provision but kept the reaps still
    yields a well-formed (if negative-going) step series — derivation
    never crashes on truncated logs, it just reflects what survived."""
    log = ev.EventLog(capacity=2)
    log.append(ev.VM_PROVISION, 10, a=0)
    log.append(ev.VM_REAP, 50, a=0)
    log.append(ev.VM_REAP, 60, a=1)            # evicts the provision
    assert log.dropped == 1
    fleet = ts.fleet_series(log)
    assert fleet.t_ms.tolist() == [50, 60]
    assert fleet.v.tolist() == [-1.0, -2.0]
    summary = ts.cell_summary(log)
    assert summary["horizon_ms"] == 60


def test_single_event_series():
    log = ev.EventLog()
    log.append(ev.VM_PROVISION, 1_000, a=0)
    fleet = ts.fleet_series(log)
    assert fleet.t_ms.tolist() == [1_000]
    assert fleet.at(999) == 0.0 and fleet.at(1_000) == 1.0
    assert fleet.final() == 1.0
    util = ts.utilization_series(log)
    assert util.at(1_000) == 0.0               # fleet without busy VMs


def test_peak_and_mean_zero_length_leases():
    assert ts.peak_and_mean([0], [0]) == (0, 0.0)
    # A zero-length lease at t>0 contributes no area and no concurrency
    # (the end's -1 sorts before the start's +1 at the same ms).
    peak, mean = ts.peak_and_mean([5, 0], [5, 10])
    assert peak == 1
    assert mean == pytest.approx(1.0)
    assert ts.peak_and_mean([], []) == (0, 0.0)


def test_fleet_series_counts_revocations_as_closes():
    log = ev.EventLog()
    log.append(ev.VM_PROVISION, 0, a=0)
    log.append(ev.VM_PROVISION, 10, a=1)
    log.append(ev.VM_REVOKE, 20, a=0, d=1, x=0.5)
    log.append(ev.VM_REAP, 30, a=1)
    fleet = ts.fleet_series(log)
    assert fleet.at(15) == 2.0
    assert fleet.at(20) == 1.0                 # revocation closes the lease
    assert fleet.final() == 0.0
    cost = ts.cumulative_cost_series(log)
    assert cost.final() == pytest.approx(0.5)  # sunk spend counted


def test_series_from_engine_log_match_result():
    eng = SimEngine(CFG, EBPSM, workload(3, n=5), seed=0, events=True)
    res = eng.run()
    fleet = ts.fleet_series(eng.elog)
    assert int(fleet.v.max()) == res.peak_vms
    assert fleet.final() == 0.0                # finalize reaps every VM
    busy = ts.busy_series(eng.elog)
    assert busy.final() == 0.0 and busy.v.min() >= 0.0
    util = ts.utilization_series(eng.elog)
    assert 0.0 <= util.v.max() <= 1.0
    cost = ts.cumulative_cost_series(eng.elog)
    assert cost.final() == pytest.approx(
        sum(w.cost for w in res.workflows))
    summary = ts.cell_summary(eng.elog)
    assert summary["peak_vms"] == res.peak_vms
    assert set(summary["series"]) == {"fleet", "busy", "utilization",
                                      "cumulative_cost",
                                      "cumulative_budget"}
    n = len(summary["t_ms"])
    assert all(len(v) == n for v in summary["series"].values())


# ---------------------------------------------------------------------------
# Engine emission invariants
# ---------------------------------------------------------------------------


def test_event_counts_match_result_aggregates():
    wl = workload(1, n=8)
    eng = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0, events=True)
    res = eng.run()
    counts = eng.elog.counts()
    n_tasks = sum(w.n_tasks for w in res.workflows)
    assert counts["task_place"] == counts["task_start"] == \
        counts["task_finish"] == n_tasks
    assert counts["task_ready"] == n_tasks
    assert counts["wf_arrive"] == counts["wf_done"] == len(res.workflows)
    assert counts["vm_provision"] == counts["vm_reap"] == res.total_vms
    assert counts["budget_distribute"] == len(res.workflows)
    # Every event timestamp is within the simulated horizon.
    arrays = eng.elog.to_arrays()
    assert arrays["t"].min() >= 0
    assert arrays["t"].max() <= eng.now


def test_events_do_not_perturb_results():
    wl = workload(2, n=6)
    plain = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0).run()
    traced = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0,
                       events=True).run()
    assert [(w.wid, w.finish_ms, w.cost) for w in traced.workflows] == \
        [(w.wid, w.finish_ms, w.cost) for w in plain.workflows]
    assert traced.vm_count_by_type == plain.vm_count_by_type


def test_disabled_path_allocates_nothing_in_obs():
    wl = workload(4, n=4)
    warm = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0)
    warm.run()                                  # warm caches outside tracing
    eng = SimEngine(CFG, EBPSM, [w.clone() for w in wl], seed=0)
    assert eng.elog is None and eng.profile is None
    # The event bus itself must not allocate when disabled.  (The shared
    # peak_and_mean reconstruction in obs/timeseries.py still runs once
    # in finalize — that path predates the event log and is exempt.)
    obs_filter = tracemalloc.Filter(True, "*repro/obs/events.py")
    tracemalloc.start()
    try:
        eng.run()
        snap = tracemalloc.take_snapshot().filter_traces([obs_filter])
        obs_bytes = sum(stat.size for stat in snap.statistics("filename"))
    finally:
        tracemalloc.stop()
    assert obs_bytes == 0


def test_dispatch_stats_events_block():
    members = [(EBPSM, workload(5, n=4), 0), (MSLBL_MW, workload(6, n=4), 1)]
    eng = BatchSimEngine(CFG, members, events=True)
    eng.run()
    blk = eng.dispatch_stats()["events"]
    assert blk["enabled"] and blk["dropped"] == 0
    assert blk["total"] == sum(blk["by_kind"].values())
    # The driver's last round is an empty termination probe (no member
    # yields a point) and emits no GRID_ROUND.
    assert blk["by_kind"]["grid_round"] == eng.rounds - 1
    off = BatchSimEngine(CFG, [(EBPSM, workload(5, n=3), 0)])
    off.run()
    assert off.dispatch_stats()["events"] == {
        "enabled": False, "total": 0, "by_kind": {}, "dropped": 0}


def test_profile_overhead_self_measured():
    prof = {"distributions": 10.0, "redistributions": 5.0, "selects": 20.0,
            "pipelines": 15.0}
    est = profile_overhead_s(prof)
    assert est > 0.0
    assert est == pytest.approx(profile_overhead_s(prof))  # deterministic


# ---------------------------------------------------------------------------
# Export determinism
# ---------------------------------------------------------------------------


def _trace_bytes(events_log, **kw):
    return (ex._dumps(ex.chrome_trace(events_log, **kw)),
            ex.events_jsonl(events_log))


def test_export_bytes_identical_across_runs_and_layouts():
    runs = {}
    for name, soa in (("obj1", False), ("obj2", False), ("soa", True)):
        eng = BatchSimEngine(CFG, [(EBPSM, workload(7, n=5), 0)],
                             events=True, soa=soa)
        eng.run()
        runs[name] = _trace_bytes(eng.states[0].elog, label="cell")
    assert runs["obj1"] == runs["obj2"]        # repeat-run determinism
    assert runs["obj1"] == runs["soa"]         # layout independence


def test_chrome_trace_structure():
    eng = SimEngine(CFG, EBPSM, workload(8, n=4), seed=0, events=True,
                    trace=True)
    res = eng.run()
    tenant_of = {w.wid: ("even" if w.wid % 2 == 0 else "odd")
                 for w in res.workflows}
    doc = ex.chrome_trace(eng.elog, label="unit",
                          vm_type_names=[t.name for t in CFG.vm_types],
                          tenant_of=tenant_of,
                          qos_of={"even": "gold", "odd": "silver"})
    assert doc["metadata"]["schema"] == ex.TRACE_SCHEMA
    evs = doc["traceEvents"]
    slices = [e for e in evs if e["ph"] == "X"]
    assert len(slices) == sum(w.n_tasks for w in res.workflows)
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in slices)
    assert {e["cat"] for e in slices} == {"even", "odd"}
    assert all(e["args"]["qos"] in ("gold", "silver") for e in slices)
    assert all("tier" in e["args"] and "est_cost" in e["args"]
               for e in slices)
    names = [e for e in evs if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(names) == res.total_vms
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"fleet", "busy", "cumulative_cost",
            "cumulative_budget"} <= counters
    assert any(c.startswith("queue_depth") for c in counters)


def test_events_jsonl_shape():
    eng = SimEngine(CFG, EBPSM, workload(9, n=3), seed=0, events=True)
    eng.run()
    text = ex.events_jsonl(eng.elog, label="u")
    lines = text.splitlines()
    import json
    header = json.loads(lines[0])
    assert header["schema"] == ex.EVENTS_SCHEMA
    assert header["version"] == ev.EVENT_SCHEMA_VERSION
    assert header["n_events"] == len(lines) - 1 == len(eng.elog)
    assert header["dropped"] == 0
    kinds = {json.loads(l)["kind"] for l in lines[1:]}
    assert kinds <= set(ev.KIND_NAMES.values())


# ---------------------------------------------------------------------------
# Harness-level trace determinism (uninterrupted vs checkpoint/resume)
# ---------------------------------------------------------------------------


def _tiny_online():
    base = ONLINE_SCENARIOS["online-smoke"]
    return dataclasses.replace(base, name="online-smoke",
                               policies=("EBPSM", "MSLBL_MW"))


def _read_all(trace_dir):
    out = {}
    for p in sorted(trace_dir.iterdir()):
        out[p.name] = p.read_bytes()
    return out


def test_run_online_trace_deterministic_and_resume_identical(tmp_path):
    """The acceptance gate: the same scenario + seed writes byte-identical
    trace files across repeat runs AND across a mid-stream checkpoint cut
    resumed in a fresh process state."""
    scen = _tiny_online()
    d_ref = tmp_path / "ref"
    d_rep = tmp_path / "rep"
    d_res = tmp_path / "res"
    run_online(scen, trace_dir=str(d_ref))
    run_online(scen, trace_dir=str(d_rep))
    ref = _read_all(d_ref)
    assert ref and set(n for n in ref if n.endswith(".trace.json"))
    assert ref == _read_all(d_rep)

    ck = tmp_path / "ck"
    with pytest.raises(StreamInterrupted):
        run_online(scen, trace_dir=str(d_res), ckpt_dir=str(ck),
                   ckpt_every_s=0.0, stop_after_ckpts=2)
    got = run_online(scen, trace_dir=str(d_res), ckpt_dir=str(ck),
                     resume=True)
    assert _read_all(d_res) == ref
    assert got["dispatch"]["events"]["enabled"]


def test_written_traces_pass_validator(tmp_path):
    import os
    import subprocess
    import sys
    scen = _tiny_online()
    run_online(scen, trace_dir=str(tmp_path / "t"))
    checker = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tools", "check_trace.py")
    proc = subprocess.run(
        [sys.executable, checker, str(tmp_path / "t")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr

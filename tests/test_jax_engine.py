"""Batched JAX engine (core.jax_engine) ≡ sequential reference (SimEngine).

The parity suite draws budgets from the upper half of [min, max] — the
paper's "budgets always assumed sufficient" regime, where the auction's
fixed point provably equals the sequential interleaving (see
core.jax_cycles).  MSLBL members exercise the shared per-task path, so
their parity is unconditional.
"""
import numpy as np
import pytest

from repro.core.engine import SimEngine
from repro.core.jax_engine import BatchSimEngine, simulate_batch
from repro.core.scheduler import ALL_POLICIES, EBPSM, EBPSM_NC, MSLBL_MW
from repro.core.types import PlatformConfig
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()

POLICY_BY_NAME = {p.name: p for p in ALL_POLICIES}


def workload(seed, n=8, rate=6.0, budget_lo=0.5, budget_hi=1.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",), budget_lo=budget_lo,
                        budget_hi=budget_hi)
    return generate_workload(CFG, spec)


def assert_same(ref, res):
    assert [w.finish_ms for w in ref.workflows] == \
        [w.finish_ms for w in res.workflows]
    assert [w.cost for w in ref.workflows] == \
        [w.cost for w in res.workflows]
    assert ref.vm_count_by_type == res.vm_count_by_type
    assert ref.vm_seconds_by_type == res.vm_seconds_by_type


@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_simulate_batch_matches_reference(policy, seed):
    """Bit-exact makespans/costs for every policy across ≥3 seeds, with
    the auction forced on (the batched engine's raison d'être)."""
    ref = SimEngine(CFG, policy, workload(seed), seed=seed).run()
    res = simulate_batch(CFG, policy, workload(seed), seed=seed,
                         batched=True).results[0]
    assert_same(ref, res)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_simulate_batch_auto_matches_reference(seed):
    """Default ("auto") batching: decisions match SimEngine path-for-path."""
    ref = SimEngine(CFG, EBPSM, workload(seed), seed=seed).run()
    res = simulate_batch(CFG, EBPSM, workload(seed), seed=seed).results[0]
    assert_same(ref, res)


def test_grid_members_are_independent():
    """A full policies × workloads × seeds grid in ONE lockstep run matches
    each member simulated alone — interleaving leaks no state."""
    grid = simulate_batch(CFG, ALL_POLICIES, [workload(0), workload(3)],
                          seed=[0, 5], batched=True)
    assert len(grid.entries) == len(ALL_POLICIES) * 2 * 2
    for e in grid.entries:
        ref = SimEngine(CFG, POLICY_BY_NAME[e.policy],
                        workload((0, 3)[e.workload]), seed=e.seed).run()
        assert_same(ref, e.result)


def test_trace_matches_reference():
    """Placement-level parity: same (time, task, tier, cost, vm) rows."""
    ref = SimEngine(CFG, EBPSM, workload(4), seed=0, batched=False,
                    trace=True)
    ref.run()
    eng = BatchSimEngine(CFG, [(EBPSM, workload(4), 0)], trace=True,
                         batched=True)
    eng.run()
    assert eng.states[0].trace_rows == ref.trace_rows


def test_workloads_not_mutated_by_grid():
    """simulate_batch deep-copies members; caller workflows stay pristine."""
    wl = workload(2)
    budgets_before = [[t.budget for t in wf.tasks] for wf in wl]
    simulate_batch(CFG, [EBPSM, EBPSM_NC], wl, seed=[0, 1])
    budgets_after = [[t.budget for t in wf.tasks] for wf in wl]
    assert budgets_before == budgets_after


def test_batched_calls_are_shared():
    """The whole grid's cycles ride a shared batched scoring pass: the
    number of device auction calls must be far below the per-member sum."""
    members = [(EBPSM, workload(s), s) for s in range(4)]
    eng = BatchSimEngine(CFG, members, batched=True)
    eng.run()
    solo_calls = 0
    for s in range(4):
        solo = BatchSimEngine(CFG, [(EBPSM, workload(s), s)], batched=True)
        solo.run()
        solo_calls += solo.batched_calls
    assert eng.batched_calls > 0
    assert eng.batched_calls < solo_calls


def test_mslbl_member_in_mixed_grid():
    """MSLBL members (sequential path) coexist with auctioned EBPSM
    members in one lockstep run."""
    grid = simulate_batch(CFG, [EBPSM, MSLBL_MW], workload(1), seed=2,
                          batched=True)
    for e in grid.entries:
        ref = SimEngine(CFG, POLICY_BY_NAME[e.policy], workload(1),
                        seed=2).run()
        assert_same(ref, e.result)


def test_stress_scale_parity_live_registry():
    """Stress-scale parity through the live-VM registry: a larger grid
    with deferred reaping (idle_threshold_ms > 0, including a shortened
    1 s threshold for extra reap/reuse churn) stays bit-exact between
    both engines, and every member's pool ends with clean registry
    invariants (terminated VMs pruned from every index)."""
    import dataclasses

    from repro.core.jax_engine import BatchSimEngine as _BSE

    ebpsm_1s = dataclasses.replace(EBPSM, name="EBPSM_1S",
                                   idle_threshold_ms=1_000)
    pols = (EBPSM, ebpsm_1s)
    wl_seeds = (9, 11)
    members, keys = [], []
    for pol in pols:
        for ws in wl_seeds:
            for s in (0, 3):
                members.append((pol, workload(ws, n=14, rate=20.0), s))
                keys.append((pol, ws, s))
    eng = _BSE(CFG, members, batched=True)
    results = eng.run()
    for (pol, ws, s), res in zip(keys, results):
        ref = SimEngine(CFG, pol, workload(ws, n=14, rate=20.0),
                        seed=s).run()
        assert_same(ref, res)
    for st in eng.states:
        st.pool.check_invariants()
        assert st.pool.n_live == 0
        assert st.pool.data_index == {}, "index not pruned after finalize"


@pytest.mark.parametrize("policy", [EBPSM, EBPSM_NC], ids=lambda p: p.name)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_insufficient_budget_tier5_parity(policy, seed):
    """Gate for the lowered auction threshold: with budgets drawn from the
    bottom of the range, cycles hit the insufficient-budget tier-5 rule
    (which may *reuse* an idle VM mid-cycle), and the auction must
    replicate that interleaving exactly — forced batched=True vs the
    sequential reference."""
    wl = workload(seed, n=10, rate=20.0, budget_lo=0.0, budget_hi=0.1)
    ref_eng = SimEngine(CFG, policy, workload(seed, n=10, rate=20.0,
                                              budget_lo=0.0, budget_hi=0.1),
                        seed=seed, trace=True)
    ref = ref_eng.run()
    eng = BatchSimEngine(CFG, [(policy, wl, seed)], trace=True, batched=True)
    res = eng.run()[0]
    assert_same(ref, res)
    assert eng.states[0].trace_rows == ref_eng.trace_rows
    # The low-budget regime must actually exercise tier 5 for the gate
    # to mean anything.
    tiers = {r[3] for r in ref_eng.trace_rows}
    assert 5 in tiers, f"workload never hit tier 5 (tiers seen: {tiers})"


def test_all_tasks_complete_batch():
    grid = simulate_batch(CFG, ALL_POLICIES, workload(6, n=6), seed=0)
    for e in grid.entries:
        assert len(e.result.workflows) == 6
        for w in e.result.workflows:
            assert w.finish_ms >= w.arrival_ms
            assert w.cost > 0


def test_online_mixed_tenant_stream_parity():
    """Bit-exact parity on an open multi-tenant stream (repro.tenants):
    heterogeneous apps incl. imported DAX/WfCommons traces, three arrival
    processes, per-QoS budgets — batched forced on, trace-row exact, with
    the predistributed-budget path the online harness uses."""
    from repro.core.jax_engine import predistribute_workload
    from repro.core.types import clone_workload
    from repro.tenants import (BRONZE, GOLD, SILVER, Diurnal,
                               MarkovModulated, Poisson, Tenant, TenantMix)

    mix = TenantMix((
        Tenant("astro", GOLD, apps=("montage", "trace:montage-18"),
               arrival=Poisson(10.0), n_workflows=5),
        Tenant("bio", SILVER, apps=("trace:epigenomics-20",),
               arrival=Diurnal(4.0, 14.0, period_s=240.0), n_workflows=3),
        Tenant("seis", BRONZE, apps=("sipht", "trace:seismology-9"),
               arrival=MarkovModulated(2.0, 18.0, mean_dwell_s=45.0),
               n_workflows=5),
    ))
    tw = mix.build(CFG, seed=0)
    for policy in (EBPSM, EBPSM_NC, MSLBL_MW):
        ref_eng = SimEngine(CFG, policy, clone_workload(tw.workflows),
                            seed=0, trace=True)
        ref = ref_eng.run()
        proto, spares = predistribute_workload(CFG, tw.workflows,
                                               policy.budget_mode)
        eng = BatchSimEngine(CFG, [(policy, clone_workload(proto), 0)],
                             trace=True, batched=True,
                             predistributed=[spares])
        res = eng.run()[0]
        assert_same(ref, res)
        assert eng.states[0].trace_rows == ref_eng.trace_rows
        assert res.peak_vms == ref.peak_vms
        assert res.mean_fleet_vms == ref.mean_fleet_vms

"""Per-arch smoke tests: reduced configs, one forward/loss (+ decode
consistency for decoder families).  Runs on CPU with 1 device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, skip_reason
from repro.models import RunConfig, build

RUN = RunConfig(remat="none")
RNG = jax.random.PRNGKey(0)


def smoke_batch(cfg, B=2, L=32):
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        return {"frames": jnp.asarray(rng.normal(size=(B, L, cfg.frame_dim)),
                                      jnp.bfloat16),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, L)),
                                      jnp.int32),
                "mask": jnp.ones((B, L), bool)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, L)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.patch_dim)), jnp.bfloat16)
        mask = np.ones((B, L), bool)
        mask[:, :min(cfg.n_patches, L)] = False
        batch["mask"] = jnp.asarray(mask)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(arch):
    m = build(arch, RUN, smoke=True)
    params = m.init(RNG)
    batch = smoke_batch(m.cfg)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    logits = m.forward(params, batch)
    B, L = batch.get("tokens", batch.get("frames"))[...].shape[:2]
    assert logits.shape[:2] == (B, L)
    assert logits.shape[-1] == m.cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-32b",
                                  "qwen2-moe-a2.7b", "internvl2-1b",
                                  "mamba2-780m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    m = build(arch, RUN, smoke=True)
    cfg = m.cfg
    params = m.init(RNG)
    B, L, S = 2, 16, 24
    toks = jax.random.randint(RNG, (B, L + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :L]}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            RNG, (B, cfg.n_patches, cfg.patch_dim), jnp.bfloat16)
    _, state = jax.jit(lambda p, b: m.prefill(p, b, S))(params, batch)
    logits_dec, state2 = jax.jit(m.decode_step)(params, state,
                                                toks[:, L:L + 1])
    full_batch = dict(batch)
    full_batch["tokens"] = toks
    full = m.forward(params, full_batch)
    ref = full[:, L, :].astype(jnp.float32)
    got = logits_dec[:, 0, :].astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) or 1.0
    assert float(jnp.max(jnp.abs(got - ref))) < 0.15 * max(scale, 1.0)
    assert int(state2["length"]) == L + 1


def test_cell_enumeration_covers_40():
    cs = list(cells())
    assert len(cs) == 40
    skips = [c for c in cs if c[2] is not None]
    # encoder-only: 2 decode skips; 7 non-sub-quadratic archs skip long_500k
    assert len(skips) == 2 + 7


def test_skip_rules():
    hubert = get_config("hubert-xlarge")
    assert skip_reason(hubert, SHAPES["decode_32k"])
    assert skip_reason(hubert, SHAPES["long_500k"])
    assert skip_reason(get_config("llama3-8b"), SHAPES["long_500k"])
    assert not skip_reason(get_config("mamba2-780m"), SHAPES["long_500k"])
    assert not skip_reason(get_config("zamba2-1.2b"), SHAPES["long_500k"])


def test_param_counts_full_configs():
    """Full (non-smoke) configs land near the published sizes."""
    expect = {"llama3-8b": (7e9, 9.5e9),
              "deepseek-coder-33b": (30e9, 37e9),
              "phi3-medium-14b": (13e9, 18e9),     # heads padded 40→48
              "mamba2-780m": (0.6e9, 1.0e9),
              "zamba2-1.2b": (1.0e9, 1.6e9),
              "qwen2-moe-a2.7b": (13e9, 16e9)}     # total (not active)
    for arch, (lo, hi) in expect.items():
        n = build(arch).n_params()
        assert lo < n < hi, (arch, n)

"""Dispatcher matrix: every way a scheduling cycle can be scored must be
bit-exact with the sequential reference.

Axes covered:

* ``batched`` ∈ {False, True, "auto" (aggregate-round), "member"
  (legacy per-member threshold)} on randomized mixed grids — EBPSM
  family + MSLBL members, sufficient and insufficient budgets;
* ``use_pallas`` ∈ {False (jnp oracle), True (Pallas, interpreted on
  CPU)};
* ``select`` scalar loop (``REPRO_SCALAR_SELECT`` oracle) vs the
  vectorized numpy path;
* the small-subset pure-Python budget distribution vs the numpy branch;
* aggregate-round engagement itself: the auction must fire on rounds
  whose individual members sit below the legacy 2048-pair threshold.
"""
import random

import pytest

import repro.core.budget as budget_mod
import repro.core.jax_engine as je
import repro.core.scheduler as sched
from repro.core import cost_tables
from repro.core.engine import SimEngine
from repro.core.jax_cycles import _RoundBuffers
from repro.core.jax_engine import BatchSimEngine
from repro.core.scheduler import (ALL_POLICIES, EBPSM, EBPSM_NC, EBPSM_NS,
                                  EBPSM_WS, MSLBL_MW, select)
from repro.core.types import PlatformConfig
from repro.sim.cloud import VMPool
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def workload(seed, n=6, rate=12.0, budget_lo=0.5, budget_hi=1.0):
    spec = WorkloadSpec(n_workflows=n, arrival_rate_per_min=rate, seed=seed,
                        sizes=("small",), budget_lo=budget_lo,
                        budget_hi=budget_hi)
    return generate_workload(CFG, spec)


def assert_same(ref, res, what=""):
    assert [w.finish_ms for w in ref.workflows] == \
        [w.finish_ms for w in res.workflows], what
    assert [w.cost for w in ref.workflows] == \
        [w.cost for w in res.workflows], what
    assert ref.vm_count_by_type == res.vm_count_by_type, what
    assert ref.vm_seconds_by_type == res.vm_seconds_by_type, what


def _mixed_members(rng):
    """Randomized mixed grid: EBPSM family + MSLBL, a couple of
    insufficient-budget cells in the draw."""
    members = []
    pols = [EBPSM, EBPSM_NS, EBPSM_WS, EBPSM_NC, MSLBL_MW]
    for i in range(6):
        pol = pols[rng.randrange(len(pols))]
        lo, hi = (0.0, 0.1) if i % 3 == 0 else (0.5, 1.0)
        ws = rng.randrange(100)
        members.append(
            (pol, workload(ws, n=4 + i % 3, budget_lo=lo, budget_hi=hi),
             rng.randrange(5), ws, lo, hi))
    return members


@pytest.mark.parametrize("batched", [False, True, "auto", "member"],
                         ids=["serial", "forced", "aggregate-auto",
                              "member-legacy"])
def test_dispatcher_matrix_randomized(batched, monkeypatch):
    """Mixed grids are bit-exact with per-member SimEngine references on
    every dispatcher path.  "auto" runs with a tiny aggregate threshold
    so the aggregate decision actually exercises the batched path."""
    if batched == "auto":
        monkeypatch.setattr(je, "AUCTION_MIN_PAIRS_ROUND", 16)
    members = _mixed_members(random.Random(1234))
    eng = BatchSimEngine(CFG, [(p, wl, s) for p, wl, s, *_ in members],
                         batched=batched)
    results = eng.run()
    # References run on identical fresh workloads (the draw is
    # deterministic in the rng seed).
    members2 = _mixed_members(random.Random(1234))
    for (pol, wl, seed, *_), res in zip(members2, results):
        ref = SimEngine(CFG, pol, wl, seed=seed).run()
        assert_same(ref, res, f"{pol.name} seed={seed} batched={batched}")


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas-interpret"])
def test_pallas_vs_jnp_paths(use_pallas):
    """Kernel backends are interchangeable: forced-batched grids match
    the sequential reference with the jnp oracle and with the Pallas
    kernel (interpreted off-TPU)."""
    wl = workload(3, n=5)
    eng = BatchSimEngine(CFG, [(EBPSM, wl, 0)], batched=True,
                         use_pallas=use_pallas)
    res = eng.run()[0]
    ref = SimEngine(CFG, EBPSM, workload(3, n=5), seed=0).run()
    assert_same(ref, res, f"use_pallas={use_pallas}")
    assert eng.batched_calls > 0


def test_aggregate_engagement_below_member_threshold(monkeypatch):
    """The aggregate-round dispatcher's reason to exist: rounds engage
    the kernel although every member is far below the legacy per-member
    2048-pair threshold — and stay bit-exact."""
    monkeypatch.setattr(je, "AUCTION_MIN_PAIRS_ROUND", 64)
    members = [(EBPSM, workload(s, n=5), s) for s in range(4)]
    eng = BatchSimEngine(CFG, members, batched="auto")
    results = eng.run()
    assert eng.batched_calls > 0
    assert eng.batched_cycles > 0
    assert max(eng.batched_member_pairs) < 2048, \
        "members this small must sit below the legacy threshold"
    stats = eng.dispatch_stats()
    assert stats["batched_calls"] == eng.batched_calls
    assert stats["max_member_pairs_batched"] < 2048
    for (pol, _, seed), res, s in zip(members, results, range(4)):
        ref = SimEngine(CFG, pol, workload(s, n=5), seed=seed).run()
        assert_same(ref, res)


def test_member_mode_keeps_legacy_gating():
    """batched="member" reproduces the old rule: small members never
    clear the per-member threshold, so no cycle rides the kernel."""
    members = [(EBPSM, workload(s, n=4), s) for s in range(3)]
    eng = BatchSimEngine(CFG, members, batched="member")
    results = eng.run()
    assert eng.batched_cycles == 0
    for (pol, _, seed), res, s in zip(members, results, range(3)):
        ref = SimEngine(CFG, pol, workload(s, n=4), seed=seed).run()
        assert_same(ref, res)


# ---------------------------------------------------------------------------
# select: scalar oracle vs vectorized path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", [EBPSM, EBPSM_NS, EBPSM_WS, EBPSM_NC,
                                    MSLBL_MW], ids=lambda p: p.name)
@pytest.mark.parametrize("seed", [0, 7])
def test_select_scalar_vs_vector_full_sim(policy, seed, monkeypatch):
    """Whole-simulation property: forcing every select through the
    vectorized path produces the trace the scalar oracle produces."""
    e1 = SimEngine(CFG, policy, workload(seed, n=8, rate=30.0), seed=seed,
                   trace=True)
    monkeypatch.setattr(sched, "_SCALAR_FORCED", True)
    e1.run()
    monkeypatch.setattr(sched, "_SCALAR_FORCED", False)
    monkeypatch.setattr(sched, "VECTOR_SELECT_MIN_VMS", 1)
    e2 = SimEngine(CFG, policy, workload(seed, n=8, rate=30.0), seed=seed,
                   trace=True)
    e2.run()
    assert e1.trace_rows == e2.trace_rows
    assert_same(e1.finalize(), e2.finalize())


def _random_pool(rng, n_vms, apps, keys):
    pool = VMPool(CFG)
    vms = []
    for i in range(n_vms):
        tag = rng.choice([None, ("wf", rng.randrange(3)),
                          ("app", rng.choice(apps))])
        vm = pool.provision(rng.randrange(len(CFG.vm_types)), 0, tag)
        pool.mark_idle(vm, 0)
        if rng.random() < 0.7:
            pool.activate_container(vm, rng.choice(apps), True)
        for key in rng.sample(keys, rng.randrange(len(keys))):
            vm.cache_put(CFG, key, rng.uniform(1, 600), pool.data_index)
        vms.append(vm)
    return pool, vms


@pytest.mark.parametrize("trial", range(12))
def test_select_scalar_vs_vector_random_pools(trial, monkeypatch):
    """Unit-level property test on synthetic pools: random caches,
    containers, sharing tags, budgets (incl. infeasible) — the scalar
    and vectorized paths agree on the placement decision."""
    rng = random.Random(1000 + trial)
    apps = ["montage", "sipht"]
    keys = [("out", 0, i) for i in range(6)] + [("ext", 1, 0)]
    pool, vms = _random_pool(rng, rng.randrange(1, 12), apps, keys)
    wl = workload(trial % 4, n=2)
    wf = wl[0]
    budget_mod.distribute_budget(CFG, wf, wf.budget)
    table = cost_tables.table_for(CFG, wf)
    for policy in (EBPSM, EBPSM_NS, EBPSM_WS, EBPSM_NC, MSLBL_MW):
        for task in wf.tasks[:4]:
            inputs = [(k, rng.uniform(0, 200)) for k in
                      rng.sample(keys, rng.randrange(1, 4))]
            budget = rng.choice([0.001, 0.5, 5.0, 500.0])
            args = (CFG, policy, task, wf.wid, wf.app, inputs, budget,
                    vms)
            monkeypatch.setattr(sched, "_SCALAR_FORCED", True)
            p_scalar = select(*args, table=table, pool=pool)
            monkeypatch.setattr(sched, "_SCALAR_FORCED", False)
            monkeypatch.setattr(sched, "VECTOR_SELECT_MIN_VMS", 1)
            p_vec = select(*args, table=table, pool=pool)
            key = lambda p: (p.vm.vmid if p.vm else None, p.new_vmt_idx,
                             p.tier, p.est_finish_ms, p.est_cost)
            assert key(p_scalar) == key(p_vec), \
                f"{policy.name} tid={task.tid} budget={budget}"


# ---------------------------------------------------------------------------
# budget distribution: pure-Python small path vs numpy branch
# ---------------------------------------------------------------------------


def test_distribute_small_vs_numpy_branch(monkeypatch):
    """The small-subset pure-Python distribution is bit-exact with the
    numpy branch on random subsets and budgets."""
    rng = random.Random(5)
    for seed in range(3):
        wl = workload(seed, n=3)
        for wf in wl:
            budget_mod.distribute_budget(CFG, wf, wf.budget)
            for _ in range(25):
                n = rng.randint(1, wf.n_tasks)
                ids = rng.sample(range(wf.n_tasks), n)
                b = rng.random() * max(wf.budget, 1.0) * 1.5
                saved = [t.budget for t in wf.tasks]

                monkeypatch.setattr(budget_mod, "_PY_DISTRIBUTE_MAX", -1)
                rem_np = budget_mod.distribute_budget(
                    CFG, wf, b, task_ids=list(ids))
                got_np = [t.budget for t in wf.tasks]

                for t, v in zip(wf.tasks, saved):
                    t.budget = v
                monkeypatch.setattr(budget_mod, "_PY_DISTRIBUTE_MAX",
                                    10 ** 9)
                rem_py = budget_mod.distribute_budget(
                    CFG, wf, b, task_ids=list(ids))
                got_py = [t.budget for t in wf.tasks]

                assert rem_np == rem_py
                assert got_np == got_py


# ---------------------------------------------------------------------------
# resident round buffers
# ---------------------------------------------------------------------------


def test_round_buffers_cover_and_reset():
    """A smaller round rides the resident covering bucket (no fresh
    allocation), and the used-region reset restores inert padding."""
    rb = _RoundBuffers()
    big = rb.get(4, 16, 16)
    tier_big = big[5]
    tier_big[:2, :8, :8] = 7   # simulate a round's writes
    # Smaller request within the cover slack: must reuse + reset.
    again = rb.get(4, 16, 8)
    assert again[5] is tier_big, "covering bucket should be reused"
    assert not tier_big.any(), "used region must be reset to inert 0"
    assert big[2][0, 0] == -1.0, "budget buffer resets to -1 sentinel"
    # Far-smaller request (beyond the slack): gets its own bucket so the
    # kernel does not waste compute on a mostly-inert giant tile.
    tiny = rb.get(1, 2, 2)
    assert tiny[5] is not tier_big
    assert tiny[5].shape == (1, 2, 2)


def test_round_buffers_lru_cap():
    """Total resident elements stay bounded; over-cap requests are
    one-shot and leave resident buckets alone."""
    class SmallRB(_RoundBuffers):
        MAX_RESIDENT_ELEMS = 2500   # fits one 1024- and one 2048-bucket,
                                    # but not both

    rb = SmallRB()
    a = rb.get(4, 16, 16)              # 1024 elems, resident
    rb.get(64, 64, 64)                 # over cap: one-shot
    assert (4, 16, 16) in rb.buckets
    assert (64, 64, 64) not in rb.buckets
    b = rb.get(2, 16, 16)              # fits under the (4,16,16) bucket
    assert b[5] is a[5]
    rb.get(4, 16, 32)                  # 2048 elems: evicts the LRU bucket
    assert (4, 16, 32) in rb.buckets
    assert (4, 16, 16) not in rb.buckets


# ---------------------------------------------------------------------------
# State layout: SoA StreamState vs legacy per-workflow objects
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batched", [False, "auto"],
                         ids=["serial", "aggregate-auto"])
def test_state_layout_parity(batched, monkeypatch):
    """SoA BatchSimEngine grids are bit-exact with *object-layout*
    SimEngine references (and the cross pairing), on both dispatcher
    paths — the state layout must be invisible to semantics."""
    if batched == "auto":
        monkeypatch.setattr(je, "AUCTION_MIN_PAIRS_ROUND", 16)
    members = _mixed_members(random.Random(4321))
    eng = BatchSimEngine(CFG, [(p, wl, s) for p, wl, s, *_ in members],
                         batched=batched, soa=True)
    results = eng.run()
    assert eng.stream is not None, "soa=True must allocate the pool"
    members2 = _mixed_members(random.Random(4321))
    for (pol, wl, seed, *_), res in zip(members2, results):
        ref = SimEngine(CFG, pol, wl, seed=seed, soa=False).run()
        assert_same(ref, res,
                    f"{pol.name} seed={seed} batched={batched} soa-vs-obj")


def test_object_state_escape_hatch(monkeypatch):
    """REPRO_OBJECT_STATE=1 forces the legacy object layout on both
    engines without touching call sites — and stays bit-exact with the
    SoA default."""
    wl = workload(11, n=5)
    soa = BatchSimEngine(CFG, [(EBPSM, wl, 0)], soa=True)
    assert soa.stream is not None
    res_soa = soa.run()[0]
    monkeypatch.setenv("REPRO_OBJECT_STATE", "1")
    obj = BatchSimEngine(CFG, [(EBPSM, workload(11, n=5), 0)])
    assert obj.stream is None, "hatch must suppress the pooled arrays"
    assert_same(res_soa, obj.run()[0], "REPRO_OBJECT_STATE hatch")

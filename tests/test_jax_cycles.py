"""Batched (JAX) scheduling cycle ≡ sequential reference.

Equivalence holds while budgets avoid the tier-5 insufficiency fallback
(the auction resolves reuse globally; tier-5 interleaving differs), so
workloads here draw budgets from the upper half of [min, max].
"""
import numpy as np
import pytest

from repro.core.engine import SimEngine
from repro.core.scheduler import EBPSM, EBPSM_NS, EBPSM_WS
from repro.core.types import PlatformConfig
from repro.workflows.workload import WorkloadSpec, generate_workload

CFG = PlatformConfig()


def workload(seed):
    spec = WorkloadSpec(n_workflows=14, arrival_rate_per_min=6.0, seed=seed,
                        sizes=("small",), budget_lo=0.4, budget_hi=1.0)
    return generate_workload(CFG, spec)


@pytest.mark.parametrize("policy", [EBPSM, EBPSM_NS, EBPSM_WS],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("seed", [0, 3])
def test_batched_equals_sequential(policy, seed):
    seq = SimEngine(CFG, policy, workload(seed), seed=0,
                    batched=False).run()
    bat = SimEngine(CFG, policy, workload(seed), seed=0,
                    batched=True).run()
    assert [w.finish_ms for w in seq.workflows] == \
        [w.finish_ms for w in bat.workflows]
    np.testing.assert_allclose([w.cost for w in seq.workflows],
                               [w.cost for w in bat.workflows], rtol=1e-6)
    assert seq.vm_count_by_type == bat.vm_count_by_type


def test_batched_trace_tiers_match():
    e1 = SimEngine(CFG, EBPSM, workload(7), seed=0, batched=False,
                   trace=True)
    e1.run()
    e2 = SimEngine(CFG, EBPSM, workload(7), seed=0, batched=True, trace=True)
    e2.run()
    assert e1.trace_rows == e2.trace_rows


def test_data_index_consistent():
    eng = SimEngine(CFG, EBPSM, workload(1), seed=0, batched=True)
    eng.run()
    # the inverted index matches per-VM caches for every live VM
    for vm in eng.pool.vms:
        if vm.terminated_ms >= 0:
            continue
        for key in vm.data_cache:
            assert vm.vmid in eng.pool.data_index.get(key, set())
    for key, holders in eng.pool.data_index.items():
        for vid in holders:
            assert key in eng.pool.vms[vid].data_cache
